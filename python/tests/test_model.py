"""L2 model correctness: stage decomposition must be exact.

The pipeline splits one model into stage functions with rematerializing
backwards; chaining the stages must reproduce the monolithic model's loss
and gradients bit-for-bit (same dtype/ops), and the Adam artifact must match
a reference implementation.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model


@pytest.fixture(scope="module")
def cfg():
    return model.preset("gpt-tiny")


@pytest.fixture(scope="module")
def params(cfg):
    key = jax.random.PRNGKey(7)
    out = {}
    for stage in cfg.stages:
        key, sub = jax.random.split(key)
        out[stage] = model.init_stage_params(cfg, stage, sub)
    return out


@pytest.fixture(scope="module")
def batch(cfg):
    key = jax.random.PRNGKey(3)
    k1, k2 = jax.random.split(key)
    tokens = jax.random.randint(k1, (cfg.batch, cfg.seq), 0, cfg.vocab)
    labels = jax.random.randint(k2, (cfg.batch, cfg.seq), 0, cfg.vocab)
    return tokens, labels


def chain_forward(cfg, params, tokens, labels):
    h = model.embed_fwd(cfg, params["embed"], tokens)
    acts = {"embed": tokens}
    for i in range(cfg.block_stages):
        acts[f"block{i}"] = h
        h = model.block_fwd(cfg, params[f"block{i}"], h)
    acts["head"] = h
    loss = model.head_loss(cfg, params["head"], h, labels)
    return loss, acts


def test_stage_chain_matches_full_model(cfg, params, batch):
    tokens, labels = batch
    loss_chain, _ = chain_forward(cfg, params, tokens, labels)
    loss_full = model.full_forward_loss(cfg, params, tokens, labels)
    np.testing.assert_allclose(loss_chain, loss_full, rtol=1e-6)
    # Sanity: an untrained model's CE is near ln(vocab).
    assert abs(float(loss_full) - np.log(cfg.vocab)) < 1.0


def test_stagewise_backward_matches_monolithic_grad(cfg, params, batch):
    """Chain head_bwd → block_bwd → embed_bwd and compare every gradient to
    jax.grad of the full model."""
    tokens, labels = batch
    _, acts = chain_forward(cfg, params, tokens, labels)

    # Stage-wise backward.
    out = model.head_bwd(cfg, params["head"], acts["head"], labels)
    dh, dhead, loss = out[0], out[1:-1], out[-1]
    stage_grads = {"head": dhead}
    for i in reversed(range(cfg.block_stages)):
        outs = model.block_bwd(cfg, params[f"block{i}"], acts[f"block{i}"], dh)
        dh, dblock = outs[0], outs[1:]
        stage_grads[f"block{i}"] = dblock
    stage_grads["embed"] = model.embed_bwd(cfg, params["embed"], tokens, dh)

    # Monolithic gradients.
    def full(ps):
        return model.full_forward_loss(cfg, ps, tokens, labels)

    mono = jax.grad(lambda ps: full(ps))({k: list(v) for k, v in params.items()})

    for stage in cfg.stages:
        for i, (g_stage, g_mono) in enumerate(zip(stage_grads[stage], mono[stage])):
            np.testing.assert_allclose(
                g_stage, g_mono, rtol=1e-4, atol=1e-6,
                err_msg=f"{stage} param {i}")
    np.testing.assert_allclose(loss, full(params), rtol=1e-6)


def test_adam_update_matches_reference(cfg):
    """adam_update must agree with a hand-rolled Adam (same as rust's)."""
    key = jax.random.PRNGKey(0)
    shapes = [(4, 8), (8,), (3, 3)]
    ps, gs = [], []
    for i, s in enumerate(shapes):
        key, a, b = jax.random.split(key, 3)
        ps.append(jax.random.normal(a, s))
        gs.append(jax.random.normal(b, s))
    ms = [jnp.zeros(s) for s in shapes]
    vs = [jnp.zeros(s) for s in shapes]
    out = model.adam_update(cfg, ps, gs, ms, vs, jnp.int32(1))
    n = len(shapes)
    new_p = out[:n]
    # Reference: first step with bias correction ⇒ p − lr·g/(|g|+eps).
    for p, g, np_ in zip(ps, gs, new_p):
        expect = p - cfg.lr * g / (jnp.abs(g) + 1e-8)
        np.testing.assert_allclose(np_, expect, rtol=1e-3, atol=1e-6)


def test_adam_converges_on_quadratic(cfg):
    target = jnp.array([1.0, -2.0, 3.0])
    p = [jnp.zeros(3)]
    m = [jnp.zeros(3)]
    v = [jnp.zeros(3)]
    for step in range(1, 1500):
        g = [2.0 * (p[0] - target)]
        out = model.adam_update(cfg, p, g, m, v, jnp.int32(step))
        p, m, v = [out[0]], [out[1]], [out[2]]
    np.testing.assert_allclose(p[0], target, atol=0.05)


def test_pallas_and_ref_attention_models_agree(batch):
    """The whole stage stack with use_pallas=True must match the ref path."""
    cfg_ref = model.preset("gpt-tiny", use_pallas=False)
    cfg_pal = model.preset("gpt-tiny", use_pallas=True)
    key = jax.random.PRNGKey(11)
    params = {}
    for stage in cfg_ref.stages:
        key, sub = jax.random.split(key)
        params[stage] = model.init_stage_params(cfg_ref, stage, sub)
    tokens, labels = batch
    loss_ref = model.full_forward_loss(cfg_ref, params, tokens, labels)
    loss_pal = model.full_forward_loss(cfg_pal, params, tokens, labels)
    np.testing.assert_allclose(loss_ref, loss_pal, rtol=1e-5, atol=1e-6)


def test_head_logits_consistent_with_loss(cfg, params, batch):
    tokens, labels = batch
    h = model.embed_fwd(cfg, params["embed"], tokens)
    for i in range(cfg.block_stages):
        h = model.block_fwd(cfg, params[f"block{i}"], h)
    logits = model.head_logits(cfg, params["head"], h)
    logp = jax.nn.log_softmax(logits, -1)
    nll = -jnp.take_along_axis(logp, labels[..., None], -1).mean()
    loss = model.head_loss(cfg, params["head"], h, labels)
    np.testing.assert_allclose(nll, loss, rtol=1e-6)


def test_training_reduces_loss_end_to_end(cfg):
    """A few full pipeline steps (fwd chain + stage bwds + adam) on a fixed
    batch must reduce the loss — the python-side twin of the rust e2e."""
    key = jax.random.PRNGKey(5)
    params = {}
    opt_m, opt_v = {}, {}
    for stage in cfg.stages:
        key, sub = jax.random.split(key)
        params[stage] = model.init_stage_params(cfg, stage, sub)
        opt_m[stage] = [jnp.zeros_like(p) for p in params[stage]]
        opt_v[stage] = [jnp.zeros_like(p) for p in params[stage]]
    k1, k2 = jax.random.split(key)
    tokens = jax.random.randint(k1, (cfg.batch, cfg.seq), 0, cfg.vocab)
    labels = jax.random.randint(k2, (cfg.batch, cfg.seq), 0, cfg.vocab)

    losses = []
    for step in range(1, 16):
        _, acts = chain_forward(cfg, params, tokens, labels)
        out = model.head_bwd(cfg, params["head"], acts["head"], labels)
        dh, grads, loss = out[0], {"head": out[1:-1]}, out[-1]
        losses.append(float(loss))
        for i in reversed(range(cfg.block_stages)):
            outs = model.block_bwd(cfg, params[f"block{i}"], acts[f"block{i}"], dh)
            dh, grads[f"block{i}"] = outs[0], outs[1:]
        grads["embed"] = model.embed_bwd(cfg, params["embed"], tokens, dh)
        for stage in cfg.stages:
            n = len(params[stage])
            out = model.adam_update(cfg, params[stage], grads[stage],
                                    opt_m[stage], opt_v[stage], jnp.int32(step))
            params[stage] = list(out[:n])
            opt_m[stage] = list(out[n:2 * n])
            opt_v[stage] = list(out[2 * n:])
    assert losses[-1] < losses[0] * 0.8, losses
