"""AOT export contract tests: manifest structure, HLO-text validity, and
the positional ABI the rust runtime depends on."""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def exported(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    cfg = model.preset("gpt-tiny")
    dest = aot.export(cfg, str(out), "gpt-tiny")
    with open(os.path.join(dest, "manifest.json")) as f:
        return cfg, dest, json.load(f)


def test_manifest_lists_all_artifacts(exported):
    cfg, dest, manifest = exported
    names = set(manifest["artifacts"])
    for stage in cfg.stages:
        for kind in ("fwd", "bwd", "update"):
            assert f"{stage}_{kind}" in names
    assert "head_logits" in names
    assert "act_quant_roundtrip" in names
    for spec in manifest["artifacts"].values():
        assert os.path.exists(os.path.join(dest, spec["file"]))


def test_hlo_text_is_parseable_shape(exported):
    _, dest, manifest = exported
    for spec in manifest["artifacts"].values():
        text = open(os.path.join(dest, spec["file"])).read()
        assert text.startswith("HloModule"), spec["file"]
        assert "ENTRY" in text
        # The interchange contract: text, not serialized proto.
        assert "\x00" not in text


def test_parameter_counts_keep_unused(exported):
    """ENTRY must keep EVERY positional argument (keep_unused contract)."""
    cfg, dest, manifest = exported
    n_block = len(model.stage_param_specs(cfg, "block0"))
    expect = {
        "embed_fwd": 2 + 1,
        "embed_bwd": 2 + 2,
        "block0_fwd": n_block + 1,
        "block0_bwd": n_block + 2,
        "head_fwd": 4 + 2,
        "head_bwd": 4 + 2,
        "head_logits": 4 + 1,
        "embed_update": 4 * 2 + 1,
        "block0_update": 4 * n_block + 1,
        "head_update": 4 * 4 + 1,
        "act_quant_roundtrip": 1,
    }
    for name, want in expect.items():
        text = open(os.path.join(dest, manifest["artifacts"][name]["file"])).read()
        entry = text[text.index("ENTRY"):]
        got = entry.count("parameter(")
        assert got == want, f"{name}: {got} params, want {want}"


def test_manifest_param_specs_match_model(exported):
    cfg, _, manifest = exported
    for stage in cfg.stages:
        specs = model.stage_param_specs(cfg, stage)
        mspecs = manifest["stage_params"][stage]
        assert len(specs) == len(mspecs)
        for (name, shape, init, std), m in zip(specs, mspecs):
            assert m["name"] == name
            assert tuple(m["shape"]) == tuple(shape)
            assert m["init"] == init
            if init == "normal":
                assert m["std"] == pytest.approx(std)


def test_n_outputs_recorded(exported):
    cfg, _, manifest = exported
    n_block = len(model.stage_param_specs(cfg, "block0"))
    a = manifest["artifacts"]
    assert a["embed_fwd"]["n_outputs"] == 1
    assert a["embed_bwd"]["n_outputs"] == 2          # dparams (wte, wpe)
    assert a["block0_bwd"]["n_outputs"] == n_block + 1  # dh + dparams
    assert a["head_bwd"]["n_outputs"] == 4 + 2       # dh + dparams + loss
    assert a["block0_update"]["n_outputs"] == 3 * n_block


def test_config_roundtrip(exported):
    cfg, _, manifest = exported
    c = manifest["config"]
    assert c["vocab"] == cfg.vocab
    assert c["seq"] == cfg.seq
    assert c["batch"] == cfg.batch
    assert c["block_stages"] == cfg.block_stages
    assert manifest["stages"][0] == "embed"
    assert manifest["stages"][-1] == "head"


def test_pallas_variant_exports(tmp_path):
    """--use-pallas lowers the attention kernel into the artifacts."""
    cfg = model.preset("gpt-tiny", use_pallas=True)
    dest = aot.export(cfg, str(tmp_path), "gpt-tiny-pallas")
    text = open(os.path.join(dest, "block0_fwd.hlo.txt")).read()
    assert text.startswith("HloModule")
    # interpret-mode pallas lowers to plain HLO control flow — executable
    # by any PJRT backend (the while-loop over k-blocks survives lowering).
    assert "while" in text


def test_exported_fwd_matches_eager(exported):
    """Numerics: the lowered embed_fwd must equal eager embed_fwd."""
    cfg, dest, manifest = exported
    ps = [jax.ShapeDtypeStruct(tuple(s["shape"]), jnp.float32)
          for s in manifest["stage_params"]["embed"]]
    key = jax.random.PRNGKey(0)
    wte = jax.random.normal(key, ps[0].shape) * 0.02
    wpe = jax.random.normal(key, ps[1].shape) * 0.01
    tokens = jax.random.randint(key, (cfg.batch, cfg.seq), 0, cfg.vocab)
    eager = model.embed_fwd(cfg, [wte, wpe], tokens)
    jitted = jax.jit(lambda a, b, t: model.embed_fwd(cfg, [a, b], t))(wte, wpe, tokens)
    import numpy as np
    np.testing.assert_allclose(eager, jitted, rtol=1e-6)
