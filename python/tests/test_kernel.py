"""L1 kernel correctness: Pallas vs pure-jnp oracles.

Hypothesis sweeps shapes; assert_allclose against ref.py is the core
correctness signal for the kernels that end up inside the AOT artifacts.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.attention import (attention_pallas, vmem_bytes_estimate)
from compile.kernels.quantize import (dequantize_pallas, quantize_pallas,
                                      roundtrip)
from compile.kernels.ref import attention_ref, dequantize_ref, quantize_ref

# Hypothesis strategy: shapes the kernel contract supports (S divisible by
# block sizes is handled inside by clamping blocks to S; we use powers of 2).
attn_shapes = st.tuples(
    st.integers(1, 3),                      # batch
    st.integers(1, 4),                      # heads
    st.sampled_from([8, 16, 32, 64]),       # seq
    st.sampled_from([4, 8, 16, 32]),        # head dim
    st.booleans(),                          # causal
    st.integers(0, 2 ** 31 - 1),            # seed
)


def rand(key, shape):
    return jax.random.normal(key, shape, jnp.float32)


@settings(max_examples=25, deadline=None)
@given(attn_shapes)
def test_attention_matches_ref(params):
    b, h, s, dh, causal, seed = params
    keys = jax.random.split(jax.random.PRNGKey(seed), 3)
    q, k, v = (rand(keys[i], (b, h, s, dh)) for i in range(3))
    out_pallas = attention_pallas(q, k, v, causal=causal)
    out_ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(out_pallas, out_ref, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("block_q,block_k", [(8, 8), (16, 8), (8, 16), (32, 32)])
def test_attention_block_shapes_agree(block_q, block_k):
    """Different tilings must give identical numerics (block-shape sweep of
    the §Perf iteration)."""
    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    q, k, v = (rand(keys[i], (2, 2, 32, 16)) for i in range(3))
    base = attention_ref(q, k, v, causal=True)
    out = attention_pallas(q, k, v, causal=True, block_q=block_q, block_k=block_k)
    np.testing.assert_allclose(out, base, rtol=2e-5, atol=2e-5)


def test_attention_causality():
    """Perturbing a future token must not change earlier outputs."""
    keys = jax.random.split(jax.random.PRNGKey(1), 3)
    q, k, v = (rand(keys[i], (1, 2, 16, 8)) for i in range(3))
    out1 = attention_pallas(q, k, v, causal=True)
    q2 = q.at[:, :, -1, :].add(10.0)
    k2 = k.at[:, :, -1, :].add(10.0)
    v2 = v.at[:, :, -1, :].add(10.0)
    out2 = attention_pallas(q2, k2, v2, causal=True)
    np.testing.assert_allclose(out1[:, :, :-1, :], out2[:, :, :-1, :],
                               rtol=1e-6, atol=1e-6)
    assert not np.allclose(out1[:, :, -1, :], out2[:, :, -1, :])


def test_attention_softmax_stability():
    """Large logits must not produce NaN (online-softmax max subtraction)."""
    q = jnp.full((1, 1, 16, 8), 30.0, jnp.float32)
    k = jnp.full((1, 1, 16, 8), 30.0, jnp.float32)
    v = rand(jax.random.PRNGKey(2), (1, 1, 16, 8))
    out = attention_pallas(q, k, v, causal=False)
    assert np.isfinite(np.asarray(out)).all()


def test_vmem_estimate_within_budget():
    """The default tiling must fit far under a 16 MiB VMEM budget."""
    est = vmem_bytes_estimate(16, 16, 128)
    assert est < 256 * 1024, est


quant_shapes = st.tuples(
    st.sampled_from([1, 2, 4, 8, 16]),      # rows
    st.integers(1, 96),                     # cols
    st.integers(0, 2 ** 31 - 1),            # seed
)


@settings(max_examples=25, deadline=None)
@given(quant_shapes)
def test_quantize_matches_ref(params):
    r, c, seed = params
    x = rand(jax.random.PRNGKey(seed), (r, c))
    qp, sp = quantize_pallas(x, block_r=min(8, r))
    qr, sr = quantize_ref(x)
    np.testing.assert_array_equal(np.asarray(qp), np.asarray(qr))
    np.testing.assert_allclose(sp, sr, rtol=1e-6)
    # dequant agreement
    np.testing.assert_allclose(
        dequantize_pallas(qp, sp, block_r=min(8, r)),
        dequantize_ref(qr, sr), rtol=1e-6)


@settings(max_examples=15, deadline=None)
@given(quant_shapes)
def test_quantize_error_bound(params):
    r, c, seed = params
    x = rand(jax.random.PRNGKey(seed), (r, c))
    y = roundtrip(x, block_r=min(8, r))
    # per-row bound: half a quantization step
    amax = np.abs(np.asarray(x)).max(axis=1, keepdims=True)
    bound = amax / 127.0 / 2.0 + 1e-6
    assert (np.abs(np.asarray(y) - np.asarray(x)) <= bound).all()


def test_quantize_zero_row_safe():
    x = jnp.zeros((8, 16), jnp.float32)
    y = roundtrip(x)
    np.testing.assert_array_equal(np.asarray(y), np.zeros((8, 16), np.float32))
