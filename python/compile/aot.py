"""AOT export: lower every pipeline-stage function to HLO **text** and write
the artifact manifest the rust runtime consumes.

HLO text — NOT ``lowered.serialize()`` / serialized HloModuleProto — is the
interchange format: jax ≥ 0.5 emits protos with 64-bit instruction ids that
the image's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text
parser reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Artifacts per preset (calling conventions in rust/src/exec/xla_engine.rs):

  {stage}_fwd / {stage}_bwd / {stage}_update   for every stage
  head_logits                                  (serving path)
  act_quant_roundtrip                          (L1 quantize kernel demo)

Usage:
  python -m compile.aot --preset gpt-tiny --out ../artifacts
  python -m compile.aot --preset gpt-e2e  --out ../artifacts
  python -m compile.aot --preset gpt-tiny --use-pallas --suffix -pallas ...
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model
from compile.kernels import quantize


def to_hlo_text(lowered) -> str:
    """stablehlo → XlaComputation (return_tuple=True) → HLO text."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def param_specs(cfg, stage):
    return [spec(shape) for _, shape, _, _ in model.stage_param_specs(cfg, stage)]


def n_outputs_of(fn, *args):
    out = jax.eval_shape(fn, *args)
    return len(out) if isinstance(out, (tuple, list)) else 1


def export(cfg: model.ModelConfig, out_dir: str, preset_dir_name: str) -> str:
    dest = os.path.join(out_dir, preset_dir_name)
    os.makedirs(dest, exist_ok=True)
    artifacts = {}

    def lower(name, fn, *args):
        # keep_unused: the rust side feeds arguments positionally, so the
        # lowered program's parameter list must match even when jax could
        # prune an argument (e.g. a bias whose value no gradient depends on).
        lowered = jax.jit(fn, keep_unused=True).lower(*args)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(dest, fname), "w") as f:
            f.write(text)
        artifacts[name] = {"file": fname, "n_outputs": n_outputs_of(fn, *args)}
        print(f"  {name:<24} {len(text):>10} chars  ({artifacts[name]['n_outputs']} outputs)")

    tok = spec((cfg.batch, cfg.seq), jnp.int32)
    act = spec((cfg.batch, cfg.seq, cfg.dim))
    step = spec((), jnp.int32)

    for stage in cfg.stages:
        ps = param_specs(cfg, stage)
        if stage == "embed":
            lower(f"{stage}_fwd",
                  lambda *a: model.embed_fwd(cfg, a[:len(ps)], a[len(ps)]),
                  *ps, tok)
            lower(f"{stage}_bwd",
                  lambda *a: model.embed_bwd(cfg, a[:len(ps)], a[len(ps)], a[len(ps) + 1]),
                  *ps, tok, act)
        elif stage == "head":
            lower(f"{stage}_fwd",
                  lambda *a: model.head_loss(cfg, a[:len(ps)], a[len(ps)], a[len(ps) + 1]),
                  *ps, act, tok)
            lower(f"{stage}_bwd",
                  lambda *a: model.head_bwd(cfg, a[:len(ps)], a[len(ps)], a[len(ps) + 1]),
                  *ps, act, tok)
            lower("head_logits",
                  lambda *a: model.head_logits(cfg, a[:len(ps)], a[len(ps)]),
                  *ps, act)
        else:
            lower(f"{stage}_fwd",
                  lambda *a: model.block_fwd(cfg, a[:len(ps)], a[len(ps)]),
                  *ps, act)
            lower(f"{stage}_bwd",
                  lambda *a: model.block_bwd(cfg, a[:len(ps)], a[len(ps)], a[len(ps) + 1]),
                  *ps, act, act)
        # Adam update: params…, grads…, m…, v…, step → params…, m…, v…
        n = len(ps)
        lower(f"{stage}_update",
              lambda *a, n=n: model.adam_update(
                  cfg, a[:n], a[n:2 * n], a[2 * n:3 * n], a[3 * n:4 * n], a[4 * n]),
              *ps, *ps, *ps, *ps, step)

    # L1 quantize-kernel artifact: f32 [B·S, D] → int8 roundtrip.
    rows = cfg.batch * cfg.seq
    lower("act_quant_roundtrip",
          lambda x: quantize.roundtrip(x),
          spec((rows, cfg.dim)))

    manifest = {
        "preset": preset_dir_name,
        "config": {
            "vocab": cfg.vocab,
            "seq": cfg.seq,
            "batch": cfg.batch,
            "layers": cfg.layers,
            "dim": cfg.dim,
            "heads": cfg.heads,
            "ffn_hidden": cfg.ffn_hidden,
            "block_stages": cfg.block_stages,
            "lr": cfg.lr,
            "use_pallas": int(cfg.use_pallas),
        },
        "stages": cfg.stages,
        "artifacts": artifacts,
        "stage_params": {
            stage: [
                {"name": name, "shape": list(shape), "init": init,
                 **({"std": std} if init == "normal" else {})}
                for name, shape, init, std in model.stage_param_specs(cfg, stage)
            ]
            for stage in cfg.stages
        },
    }
    with open(os.path.join(dest, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {dest}/manifest.json ({len(artifacts)} artifacts)")
    return dest


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="gpt-tiny")
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--use-pallas", action="store_true",
                    help="route attention through the L1 Pallas kernel")
    ap.add_argument("--suffix", default="",
                    help="artifact dir name suffix (e.g. -pallas)")
    args = ap.parse_args()
    cfg = model.preset(args.preset, use_pallas=args.use_pallas)
    export(cfg, args.out, args.preset + args.suffix)


if __name__ == "__main__":
    main()
