"""L2: the transformer pipeline-stage compute in JAX.

Build-time only — never imported at runtime. Each pipeline stage is a pure
function over a FLAT parameter list (ordering fixed here and mirrored in
the artifact manifest so the rust coordinator can initialize/feed params
positionally):

  embed:    [wte (V,D), wpe (S,D)]
  block{i}: per layer [ln1_g, ln1_b, wqkv (D,3D), bqkv (3D), wo (D,D),
            bo (D), ln2_g, ln2_b, w1 (D,F), b1 (F), w2 (F,D), b2 (D)]
  head:     [lnf_g, lnf_b, w_head (D,V), b_head (V)]

Backward stage functions REMATERIALIZE the forward internally (jax.vjp over
the stage function), so a compnode stashes only stage inputs per microbatch
— the memory/compute trade the paper cites for low-memory devices (§2.4).

Attention runs either through the L1 Pallas kernel
(`kernels.attention.attention_pallas`, interpret mode) or the pure-jnp
reference — both lower into the same HLO artifact shape; `aot.py` picks via
--use-pallas.
"""

import dataclasses
from typing import List

import jax
import jax.numpy as jnp

from compile.kernels.attention import attention as attention_pallas_ad
from compile.kernels.ref import attention_ref

PARAMS_PER_LAYER = 12
EMBED_PARAMS = 2
HEAD_PARAMS = 4


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Mirror of the rust `TransformerConfig` presets."""

    name: str
    vocab: int
    seq: int
    batch: int
    layers: int
    dim: int
    heads: int
    ffn_hidden: int
    block_stages: int  # transformer blocks are split into this many stages
    lr: float = 1e-3
    use_pallas: bool = False

    @property
    def layers_per_stage(self) -> int:
        assert self.layers % self.block_stages == 0
        return self.layers // self.block_stages

    @property
    def stages(self) -> List[str]:
        return ["embed"] + [f"block{i}" for i in range(self.block_stages)] + ["head"]


def preset(name: str, use_pallas: bool = False) -> ModelConfig:
    """Named presets matching rust `models::transformer`."""
    if name == "gpt-tiny":
        return ModelConfig(name=name, vocab=256, seq=16, batch=2, layers=2,
                           dim=32, heads=2, ffn_hidden=64, block_stages=2,
                           lr=1e-2, use_pallas=use_pallas)
    if name == "gpt-small":
        # ~12M params — CI-speed e2e config.
        return ModelConfig(name=name, vocab=4096, seq=64, batch=4, layers=4,
                           dim=256, heads=4, ffn_hidden=1024, block_stages=2,
                           lr=2e-3, use_pallas=use_pallas)
    if name == "gpt-e2e":
        # ~110M params — the paper-scale end-to-end driver.
        return ModelConfig(name=name, vocab=16384, seq=128, batch=8, layers=12,
                           dim=768, heads=12, ffn_hidden=3072, block_stages=3,
                           lr=1e-3, use_pallas=use_pallas)
    raise ValueError(f"unknown preset '{name}'")


# ---------------------------------------------------------------------------
# parameter specs (shapes + init) — the manifest source of truth
# ---------------------------------------------------------------------------

def stage_param_specs(cfg: ModelConfig, stage: str):
    """[(name, shape, init, std)] for one stage, in flat order."""
    d, f = cfg.dim, cfg.ffn_hidden
    if stage == "embed":
        return [
            ("wte", (cfg.vocab, d), "normal", 0.02),
            ("wpe", (cfg.seq, d), "normal", 0.01),
        ]
    if stage == "head":
        return [
            ("lnf_g", (d,), "ones", 0.0),
            ("lnf_b", (d,), "zeros", 0.0),
            ("w_head", (d, cfg.vocab), "normal", d ** -0.5),
            ("b_head", (cfg.vocab,), "zeros", 0.0),
        ]
    assert stage.startswith("block"), stage
    specs = []
    for l in range(cfg.layers_per_stage):
        specs += [
            (f"l{l}.ln1_g", (d,), "ones", 0.0),
            (f"l{l}.ln1_b", (d,), "zeros", 0.0),
            (f"l{l}.wqkv", (d, 3 * d), "normal", d ** -0.5),
            (f"l{l}.bqkv", (3 * d,), "zeros", 0.0),
            (f"l{l}.wo", (d, d), "normal", (d ** -0.5) / (2 * cfg.layers) ** 0.5),
            (f"l{l}.bo", (d,), "zeros", 0.0),
            (f"l{l}.ln2_g", (d,), "ones", 0.0),
            (f"l{l}.ln2_b", (d,), "zeros", 0.0),
            (f"l{l}.w1", (d, f), "normal", d ** -0.5),
            (f"l{l}.b1", (f,), "zeros", 0.0),
            (f"l{l}.w2", (f, d), "normal", (f ** -0.5) / (2 * cfg.layers) ** 0.5),
            (f"l{l}.b2", (d,), "zeros", 0.0),
        ]
    return specs


def init_stage_params(cfg: ModelConfig, stage: str, key):
    """Materialize initial parameters (used by tests; rust re-derives from
    the manifest with its own RNG)."""
    params = []
    for name, shape, init, std in stage_param_specs(cfg, stage):
        key, sub = jax.random.split(key)
        if init == "zeros":
            params.append(jnp.zeros(shape, jnp.float32))
        elif init == "ones":
            params.append(jnp.ones(shape, jnp.float32))
        else:
            params.append(jax.random.normal(sub, shape, jnp.float32) * std)
    return params


# ---------------------------------------------------------------------------
# stage forward functions
# ---------------------------------------------------------------------------

def _layernorm(x, g, b, eps=1e-5):
    mu = x.mean(axis=-1, keepdims=True)
    var = ((x - mu) ** 2).mean(axis=-1, keepdims=True)
    return g * (x - mu) * jax.lax.rsqrt(var + eps) + b


def _attention(cfg: ModelConfig, x, wqkv, bqkv, wo, bo):
    b, s, d = x.shape
    h = cfg.heads
    dh = d // h
    qkv = x @ wqkv + bqkv  # [B, S, 3D]
    q, k, v = jnp.split(qkv, 3, axis=-1)
    # [B, S, D] → [B, H, S, Dh]
    q = q.reshape(b, s, h, dh).transpose(0, 2, 1, 3)
    k = k.reshape(b, s, h, dh).transpose(0, 2, 1, 3)
    v = v.reshape(b, s, h, dh).transpose(0, 2, 1, 3)
    attn = attention_pallas_ad if cfg.use_pallas else attention_ref
    ctx = attn(q, k, v, causal=True)
    ctx = ctx.transpose(0, 2, 1, 3).reshape(b, s, d)
    return ctx @ wo + bo


def _block_layer(cfg: ModelConfig, x, p):
    """One pre-LN transformer layer; p = the 12-tuple for this layer."""
    (ln1_g, ln1_b, wqkv, bqkv, wo, bo, ln2_g, ln2_b, w1, b1, w2, b2) = p
    x = x + _attention(cfg, _layernorm(x, ln1_g, ln1_b), wqkv, bqkv, wo, bo)
    h = _layernorm(x, ln2_g, ln2_b) @ w1 + b1
    h = jax.nn.gelu(h)
    return x + h @ w2 + b2


def embed_fwd(cfg: ModelConfig, params, tokens):
    """tokens [B, S] i32 → h [B, S, D]."""
    wte, wpe = params
    return wte[tokens] + wpe[None, :, :]


def block_fwd(cfg: ModelConfig, params, h):
    """h [B, S, D] → h [B, S, D] through layers_per_stage layers."""
    for l in range(cfg.layers_per_stage):
        layer = tuple(params[l * PARAMS_PER_LAYER:(l + 1) * PARAMS_PER_LAYER])
        h = _block_layer(cfg, h, layer)
    return h


def head_logits(cfg: ModelConfig, params, h):
    """h [B, S, D] → logits [B, S, V]."""
    lnf_g, lnf_b, w_head, b_head = params
    return _layernorm(h, lnf_g, lnf_b) @ w_head + b_head


def head_loss(cfg: ModelConfig, params, h, labels):
    """Mean next-token cross entropy (labels already shifted upstream)."""
    logits = head_logits(cfg, params, h)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)
    return nll.mean()


# ---------------------------------------------------------------------------
# stage backward functions (remat: vjp over the forward)
# ---------------------------------------------------------------------------

def embed_bwd(cfg: ModelConfig, params, tokens, dh):
    """→ dparams (tokens carry no gradient)."""
    _, vjp = jax.vjp(lambda p: embed_fwd(cfg, p, tokens), list(params))
    (dparams,) = vjp(dh)
    return tuple(dparams)


def block_bwd(cfg: ModelConfig, params, h, dy):
    """→ (dh, *dparams)."""
    _, vjp = jax.vjp(lambda p, x: block_fwd(cfg, p, x), list(params), h)
    dparams, dh = vjp(dy)
    return (dh, *dparams)


def head_bwd(cfg: ModelConfig, params, h, labels):
    """→ (dh, *dparams, loss). Seeds dL/dL = 1 internally."""
    loss, vjp = jax.vjp(lambda p, x: head_loss(cfg, p, x, labels), list(params), h)
    dparams, dh = vjp(jnp.ones((), jnp.float32))
    return (dh, *dparams, loss)


# ---------------------------------------------------------------------------
# optimizer (mirrors rust exec::optim::Adam)
# ---------------------------------------------------------------------------

def adam_update(cfg: ModelConfig, params, grads, m, v, step,
                beta1=0.9, beta2=0.999, eps=1e-8):
    """One Adam step over a flat param list. `step` is 1-based i32.

    Returns (params…, m…, v…) flattened in that order.
    """
    step_f = step.astype(jnp.float32)
    b1t = 1.0 - beta1 ** step_f
    b2t = 1.0 - beta2 ** step_f
    new_p, new_m, new_v = [], [], []
    for p, g, mi, vi in zip(params, grads, m, v):
        mi = beta1 * mi + (1.0 - beta1) * g
        vi = beta2 * vi + (1.0 - beta2) * g * g
        mhat = mi / b1t
        vhat = vi / b2t
        new_p.append(p - cfg.lr * mhat / (jnp.sqrt(vhat) + eps))
        new_m.append(mi)
        new_v.append(vi)
    return (*new_p, *new_m, *new_v)


# ---------------------------------------------------------------------------
# full-model reference (for pytest only — the runtime never sees this)
# ---------------------------------------------------------------------------

def full_forward_loss(cfg: ModelConfig, stage_params, tokens, labels):
    """Chain every stage: the oracle for stage-composition tests."""
    h = embed_fwd(cfg, stage_params["embed"], tokens)
    for i in range(cfg.block_stages):
        h = block_fwd(cfg, stage_params[f"block{i}"], h)
    return head_loss(cfg, stage_params["head"], h, labels)
