"""Pure-jnp reference oracles for the L1 Pallas kernels.

These are the CORE correctness signal: every Pallas kernel must match its
reference here to tight tolerances across a hypothesis-swept shape/dtype
grid (see python/tests/test_kernel.py).
"""

import jax.numpy as jnp


def attention_ref(q, k, v, causal: bool = True):
    """Multi-head scaled-dot-product attention.

    Args:
      q, k, v: [B, H, S, Dh]
      causal: apply a lower-triangular mask.

    Returns:
      [B, H, S, Dh] context.
    """
    dh = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, dtype=q.dtype))
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    if causal:
        s = q.shape[2]
        mask = jnp.tril(jnp.ones((s, s), dtype=bool))
        scores = jnp.where(mask[None, None, :, :], scores, -jnp.inf)
    probs = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v)


def quantize_ref(x):
    """Symmetric per-row int8 quantization.

    Args:
      x: [R, C] float32.

    Returns:
      (q int8 [R, C], scale float32 [R, 1]) with q = round(x / scale).
    """
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.where(amax == 0.0, 1.0, amax / 127.0)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_ref(q, scale):
    """Inverse of quantize_ref (lossy)."""
    return q.astype(jnp.float32) * scale
