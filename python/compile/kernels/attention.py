"""L1 Pallas kernel: tiled flash-attention-style multi-head attention.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's substrate
is CUDA consumer GPUs, where FlashAttention stages K/V tiles through
threadblock shared memory. On the TPU-flavoured Pallas model the same
insight maps to **VMEM tiling**: the grid iterates (batch·heads, q-blocks),
each program holds one `[BLOCK_Q, Dh]` query tile resident in VMEM and
streams `[BLOCK_K, Dh]` key/value tiles from HBM, maintaining the online
softmax running max/denominator so the full `S×S` score matrix never
materializes. Matmuls are shaped for the MXU (tile sizes multiples of 8).

The kernel MUST be lowered with ``interpret=True`` on this image: real-TPU
lowering emits a Mosaic custom-call the CPU PJRT plugin cannot execute.
Numerics are validated against ``ref.attention_ref`` by hypothesis-driven
pytest sweeps over shapes.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# VMEM tile sizes. BLOCK_Q × Dh and BLOCK_K × Dh tiles must fit comfortably
# in ~16 MiB VMEM alongside accumulators; these defaults keep the footprint
# under 256 KiB for Dh ≤ 128 (see DESIGN.md §Perf for the roofline math).
DEFAULT_BLOCK_Q = 16
DEFAULT_BLOCK_K = 16


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k: int, causal: bool,
                 q_block: int, seq: int):
    """One grid program: one query tile vs all key/value tiles."""
    qi = pl.program_id(1)  # query-block index
    q = q_ref[...]  # [block_q, dh]
    dh = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, dtype=jnp.float32))

    block_q = q.shape[0]
    q_start = qi * q_block

    # Online softmax state.
    m = jnp.full((block_q, 1), -jnp.inf, dtype=jnp.float32)  # running max
    l = jnp.zeros((block_q, 1), dtype=jnp.float32)           # running denom
    acc = jnp.zeros((block_q, dh), dtype=jnp.float32)        # weighted V sum

    num_k_blocks = seq // block_k

    def body(ki, state):
        m, l, acc = state
        k_start = ki * block_k
        k = pl.load(k_ref, (pl.dslice(k_start, block_k), slice(None)))
        v = pl.load(v_ref, (pl.dslice(k_start, block_k), slice(None)))
        scores = jnp.dot(q, k.T) * scale  # [block_q, block_k] on the MXU
        if causal:
            q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 0)
            k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
            scores = jnp.where(q_pos >= k_pos, scores, -jnp.inf)
        m_new = jnp.maximum(m, scores.max(axis=-1, keepdims=True))
        # Guard fully-masked rows (m_new = -inf): contribute nothing.
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(jnp.where(jnp.isfinite(scores), scores - m_safe, -jnp.inf))
        alpha = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l_new = alpha * l + p.sum(axis=-1, keepdims=True)
        acc_new = alpha * acc + jnp.dot(p, v)
        return m_new, l_new, acc_new

    m, l, acc = jax.lax.fori_loop(0, num_k_blocks, body, (m, l, acc))
    o_ref[...] = (acc / jnp.where(l == 0.0, 1.0, l)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k"))
def attention_pallas(q, k, v, causal: bool = True,
                     block_q: int = DEFAULT_BLOCK_Q,
                     block_k: int = DEFAULT_BLOCK_K):
    """Tiled attention over [B, H, S, Dh] via a Pallas kernel.

    Shapes: S must be divisible by both block sizes (callers pick blocks
    accordingly; the AOT path always uses compatible shapes).
    """
    b, h, s, dh = q.shape
    bq = min(block_q, s)
    bk = min(block_k, s)
    assert s % bq == 0 and s % bk == 0, (s, bq, bk)

    # Collapse (B, H) into the grid's first axis.
    qf = q.reshape(b * h, s, dh)
    kf = k.reshape(b * h, s, dh)
    vf = v.reshape(b * h, s, dh)

    grid = (b * h, s // bq)
    out = pl.pallas_call(
        functools.partial(_attn_kernel, block_k=bk, causal=causal,
                          q_block=bq, seq=s),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, bq, dh), lambda g, qi: (g, qi, 0)),
            pl.BlockSpec((None, s, dh), lambda g, qi: (g, 0, 0)),
            pl.BlockSpec((None, s, dh), lambda g, qi: (g, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, bq, dh), lambda g, qi: (g, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, dh), q.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(qf, kf, vf)
    return out.reshape(b, h, s, dh)


# ---------------------------------------------------------------------------
# Differentiable wrapper: jax cannot trace a VJP *through* an interpret-mode
# pallas_call (pallas calls cannot nest inside the interpreter's traces), so
# the backward pass is defined explicitly as the VJP of the mathematically
# identical reference. Forward = the tiled kernel; backward = exact formula.
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def attention(q, k, v, causal: bool = True):
    """Differentiable tiled attention (kernel fwd, analytic bwd)."""
    return attention_pallas(q, k, v, causal=causal)


def _attention_fwd(q, k, v, causal):
    return attention_pallas(q, k, v, causal=causal), (q, k, v)


def _attention_bwd(causal, res, g):
    from compile.kernels.ref import attention_ref

    q, k, v = res
    _, vjp = jax.vjp(lambda q, k, v: attention_ref(q, k, v, causal=causal), q, k, v)
    return vjp(g)


attention.defvjp(_attention_fwd, _attention_bwd)


def vmem_bytes_estimate(block_q: int, block_k: int, dh: int) -> int:
    """Estimated VMEM working set of one program (f32): Q tile + K/V tiles +
    softmax state + accumulator + score tile. Used by the §Perf notes."""
    return 4 * (
        block_q * dh        # q
        + 2 * block_k * dh  # k, v tiles
        + block_q * block_k # scores
        + block_q * (2 + dh)  # m, l, acc
    )
