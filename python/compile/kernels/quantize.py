"""L1 Pallas kernel: symmetric per-row int8 quantization.

The communication hot-spot of the decentralized system (paper §2.3): before
an activation/gradient tensor leaves a compnode it is quantized to int8
(4× smaller on the wire). The kernel processes one row block per grid
program — rows are independent, so the grid parallelizes trivially and the
per-program VMEM footprint is one `[BLOCK_R, C]` tile plus the scale
column.

``interpret=True`` as everywhere (CPU PJRT). Oracle: ``ref.quantize_ref``.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_R = 8


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...]
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.where(amax == 0.0, 1.0, amax / 127.0)
    q = jnp.clip(jnp.round(x / scale), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale.astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_r",))
def quantize_pallas(x, block_r: int = DEFAULT_BLOCK_R):
    """Quantize [R, C] float32 → (int8 [R, C], scales [R, 1])."""
    r, c = x.shape
    br = min(block_r, r)
    assert r % br == 0, (r, br)
    grid = (r // br,)
    return pl.pallas_call(
        _quant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((br, c), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((br, c), lambda i: (i, 0)),
            pl.BlockSpec((br, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r, c), jnp.int8),
            jax.ShapeDtypeStruct((r, 1), jnp.float32),
        ],
        interpret=True,
    )(x)


def _dequant_kernel(q_ref, s_ref, x_ref):
    x_ref[...] = q_ref[...].astype(jnp.float32) * s_ref[...]


@functools.partial(jax.jit, static_argnames=("block_r",))
def dequantize_pallas(q, scale, block_r: int = DEFAULT_BLOCK_R):
    """Inverse kernel: (int8 [R, C], [R, 1]) → float32 [R, C]."""
    r, c = q.shape
    br = min(block_r, r)
    assert r % br == 0, (r, br)
    grid = (r // br,)
    return pl.pallas_call(
        _dequant_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, c), lambda i: (i, 0)),
            pl.BlockSpec((br, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((br, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, c), jnp.float32),
        interpret=True,
    )(q, scale)


def roundtrip(x, block_r: int = DEFAULT_BLOCK_R):
    """f32 → int8 → f32 (what the AOT `act_quant_roundtrip` artifact runs)."""
    q, s = quantize_pallas(x, block_r=block_r)
    return dequantize_pallas(q, s, block_r=block_r)
