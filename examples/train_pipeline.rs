//! End-to-end driver (DESIGN.md deliverable): pipeline-parallel training of
//! a GPT model across simulated decentralized compnodes with **real XLA
//! compute** on the request path.
//!
//! Every piece of the stack is exercised:
//!   L1 Pallas kernels → L2 jax stage functions → AOT HLO artifacts →
//!   rust PJRT runtime → per-compnode threads → GPipe microbatching →
//!   α-β WAN accounting → DHT data provider → Adam updates → loss curve.
//!
//! Presets: `--preset gpt-small` (~12M params, CI-speed) or
//! `--preset gpt-e2e` (~110M params, the paper-scale run recorded in
//! EXPERIMENTS.md). Build artifacts first: `make artifacts`.
//!
//! Run: `cargo run --release --example train_pipeline -- --preset gpt-small --steps 100`

use std::collections::HashMap;

use fusionai::cluster::{PipelineTrainer, TrainConfig};
use fusionai::compress::Codec;
use fusionai::perf::comm::LinkModel;
use fusionai::util::{human_bytes, human_secs};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut flags: HashMap<String, String> = HashMap::new();
    let mut i = 0;
    while i + 1 < args.len() {
        if let Some(k) = args[i].strip_prefix("--") {
            flags.insert(k.to_string(), args[i + 1].clone());
        }
        i += 2;
    }
    let preset = flags.get("preset").map(String::as_str).unwrap_or("gpt-small");
    let steps: usize = flags.get("steps").map(|s| s.parse().unwrap()).unwrap_or(100);
    let microbatches: usize =
        flags.get("microbatches").map(|s| s.parse().unwrap()).unwrap_or(2);
    let codec = match flags.get("codec").map(String::as_str) {
        Some("int8") => Some(Codec::Int8),
        Some("topk") => Some(Codec::TopK { ratio: 0.1 }),
        _ => None,
    };

    let mut cfg = TrainConfig::new(format!("artifacts/{preset}"));
    cfg.steps = steps;
    cfg.microbatches = microbatches;
    cfg.codec = codec;
    cfg.link = LinkModel::from_ms_mbps(5.0, 1000.0);
    let trainer = PipelineTrainer::new(cfg)?;
    let stages = trainer.manifest.stages.len();
    let params: usize = trainer
        .manifest
        .stage_params
        .values()
        .flat_map(|v| v.iter().map(|p| p.shape.iter().product::<usize>()))
        .sum();
    println!(
        "== train_pipeline: preset {preset} | {:.1}M params | {stages} stages | {steps} steps × {microbatches} microbatches | codec {:?}",
        params as f64 / 1e6,
        codec,
    );

    let report = trainer.run()?;

    println!("\nloss curve (every ~{} steps):", (steps / 20).max(1));
    let stride = (report.losses.len() / 20).max(1);
    for (i, (step, loss)) in report
        .losses
        .to_csv()
        .lines()
        .skip(1)
        .enumerate()
        .filter_map(|(i, l)| {
            let mut it = l.split(',');
            Some((i, (it.next()?.parse::<usize>().ok()?, it.next()?.parse::<f32>().ok()?)))
        })
        .filter(|(i, _)| i % stride == 0 || *i + 1 == steps)
        .map(|(i, p)| (i, p))
    {
        let _ = i;
        println!("  step {:>4}  loss {:.4}", step, loss);
    }
    let (s0, l0) = report.losses.first().unwrap();
    let (s1, l1) = report.losses.last().unwrap();
    println!("\nloss {l0:.4} @step {s0} → {l1:.4} @step {s1} (tail-5 mean {:.4})", report.losses.tail_mean(5));
    println!(
        "wall {:.1}s | {:.0} tokens/s | comm {} | modelled WAN time {}",
        report.wall_seconds,
        report.tokens_per_second,
        human_bytes(report.comm_bytes),
        human_secs(report.comm_model_seconds)
    );

    // Persist the loss curve for EXPERIMENTS.md.
    let out = format!("train_{preset}_loss.csv");
    report.losses.save_csv(std::path::Path::new(&out))?;
    println!("loss curve written to {out}");

    anyhow::ensure!(l1 < l0, "training must reduce the loss ({l0} → {l1})");
    println!("train_pipeline OK");
    Ok(())
}
