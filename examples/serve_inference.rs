//! Deployment path: load the AOT-compiled model and serve batched greedy
//! generation, reporting per-request latency and throughput (the
//! "deploying LLMs" half of the paper's title).
//!
//! Run: `cargo run --release --example serve_inference -- --preset gpt-small`

use std::collections::HashMap;
use std::path::Path;

use fusionai::serve::{run_trace, InferenceServer, Request};
use fusionai::util::{human_secs, Rng};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut flags: HashMap<String, String> = HashMap::new();
    let mut i = 0;
    while i + 1 < args.len() {
        if let Some(k) = args[i].strip_prefix("--") {
            flags.insert(k.to_string(), args[i + 1].clone());
        }
        i += 2;
    }
    let preset = flags.get("preset").map(String::as_str).unwrap_or("gpt-small");
    let n_requests: usize = flags.get("requests").map(|s| s.parse().unwrap()).unwrap_or(12);
    let n_new: usize = flags.get("new-tokens").map(|s| s.parse().unwrap()).unwrap_or(8);

    let server = InferenceServer::load(Path::new(&format!("artifacts/{preset}")), 7)?;
    println!(
        "serving preset {preset}: batch {} × seq {} × vocab {} | {} new tokens/request",
        server.batch, server.seq, server.vocab, n_new
    );

    // A Poisson-ish arrival trace of prompts.
    let mut rng = Rng::new(2024);
    let prompt_len = (server.seq / 4).max(1);
    let mut t = 0.0;
    let requests: Vec<Request> = (0..n_requests)
        .map(|id| {
            t += rng.uniform(0.0, 0.2);
            Request {
                id,
                prompt: (0..prompt_len)
                    .map(|_| rng.below(server.vocab as u64) as i32)
                    .collect(),
                arrival_s: t,
            }
        })
        .collect();

    let (responses, stats) = run_trace(&server, requests, n_new)?;

    println!("\nper-request:");
    for r in responses.iter().take(6) {
        println!(
            "  req {:>2}: latency {:>10}  continuation {:?}",
            r.id,
            human_secs(r.latency_s),
            &r.tokens[prompt_len..]
        );
    }
    println!(
        "\n{} requests | {:.2} req/s | {:.1} new tokens/s | latency p50 {} p99 {}",
        stats.completed,
        stats.requests_per_second,
        stats.tokens_per_second,
        human_secs(stats.latency.median()),
        human_secs(stats.latency.p99())
    );

    // Determinism check: greedy decoding of the same prompt twice matches.
    let p: Vec<i32> = (0..prompt_len).map(|i| (i % server.vocab) as i32).collect();
    let a = server.generate(&[p.clone()], n_new)?;
    let b = server.generate(&[p], n_new)?;
    anyhow::ensure!(a == b, "greedy decoding must be deterministic");
    println!("serve_inference OK");
    Ok(())
}
