//! Churn tolerance: compnodes leave mid-training; the broker detects the
//! failure through missed heartbeats, promotes a replacement from the
//! backup pool (paper §3.2) and the replacement resumes from the supernode
//! parameter checkpoint (§3.5) — loss continuity is verified.
//!
//! Run: `cargo run --release --example churn_tolerance`

use std::sync::Arc;

use fusionai::broker::{Broker, NodeClass, NodeState};
use fusionai::cluster::SimCluster;
use fusionai::decompose::Decomposition;
use fusionai::exec::{Adam, RefEngine};
use fusionai::models::transformer::TransformerConfig;
use fusionai::net::{NetworkSim, Topology};
use fusionai::perf::comm::LinkModel;
use fusionai::perf::gpus::lookup;
use fusionai::tensor::Tensor;
use fusionai::util::Rng;

fn main() -> anyhow::Result<()> {
    // A 4-way pipeline of a tiny transformer on RefEngine + 2 backups.
    let cfg = TransformerConfig::tiny();
    let graph = cfg.build_graph();
    let decomp = Decomposition::chain_balanced(&graph, 4);

    let mut broker = Broker::new(3.0); // 3 s heartbeat timeout
    for _ in 0..4 {
        broker.register(lookup("RTX 3070").unwrap(), 0.5, NodeClass::Antnode, 0.0, false);
    }
    for _ in 0..2 {
        broker.register(lookup("RTX 3080").unwrap(), 0.6, NodeClass::Supernode, 0.0, true);
    }
    println!("active {:?} | backup pool {:?}", broker.active_nodes(), broker.backup_pool());

    let net = Arc::new(NetworkSim::new(
        Topology::uniform(LinkModel::from_ms_mbps(20.0, 100.0)),
        0.0,
    ));
    let mut cluster = SimCluster::new(
        graph,
        decomp,
        net,
        Box::new(|| Box::new(RefEngine::new())),
        Box::new(|| Box::new(Adam::new(0.01))),
        1,
    )?;

    let mut rng = Rng::new(99);
    let feed = |cluster: &mut SimCluster, rng: &mut Rng| -> anyhow::Result<()> {
        let tokens: Vec<i32> = (0..cfg.batch * cfg.seq)
            .map(|i| ((i * 7 + 3) % cfg.vocab) as i32)
            .collect();
        let labels: Vec<i32> =
            tokens.iter().map(|&t| ((t as usize + 7) % cfg.vocab) as i32).collect();
        let _ = rng;
        cluster.feed("tokens", Tensor::from_ivec(&[cfg.batch, cfg.seq], tokens))?;
        cluster.feed("labels", Tensor::from_ivec(&[cfg.batch, cfg.seq], labels))?;
        Ok(())
    };

    // Phase 1: healthy training.
    let mut pre_crash_loss = f32::NAN;
    for step in 0..15 {
        feed(&mut cluster, &mut rng)?;
        let r = cluster.train_step()?;
        pre_crash_loss = r.loss.unwrap();
        // All nodes — active and backup — heartbeat while healthy.
        for n in 0..6 {
            broker.heartbeat(n, step as f64)?;
        }
        if step % 5 == 0 {
            println!("step {:>2}  loss {:.4}", step, pre_crash_loss);
        }
    }

    // Phase 2: compnode 2 crashes (stops heartbeating and loses state).
    println!("\n!! compnode 2 crashes at t=15");
    cluster.fail_compnode(2);
    // Everyone but node 2 keeps heartbeating; node 2 goes silent.
    for t in 15..20 {
        for n in (0..6).filter(|&n| n != 2) {
            broker.heartbeat(n, t as f64)?;
        }
    }
    let dead = broker.check_liveness(19.5);
    println!("broker detected offline: {dead:?}");
    assert_eq!(dead, vec![2]);

    // A training step now fails — the pipeline is cut.
    feed(&mut cluster, &mut rng)?;
    let err = cluster.train_step().unwrap_err();
    println!("training step failed as expected: {err}");

    // Phase 3: promote a backup, restore from checkpoint, resume.
    let replacement = broker.promote_backup(2).expect("backup pool non-empty");
    println!(
        "promoted backup node {replacement} ({})",
        broker.info(replacement).unwrap().gpu.name
    );
    assert_eq!(broker.state(replacement), Some(NodeState::Active));
    cluster.recover_compnode(2)?;

    let mut post_loss = f32::NAN;
    for step in 20..35 {
        feed(&mut cluster, &mut rng)?;
        let r = cluster.train_step()?;
        post_loss = r.loss.unwrap();
        if step % 5 == 0 {
            println!("step {:>2}  loss {:.4}", step, post_loss);
        }
    }

    println!(
        "\npre-crash loss {pre_crash_loss:.4} | post-recovery loss {post_loss:.4}"
    );
    assert!(
        post_loss < pre_crash_loss * 1.15,
        "recovery must resume near the checkpoint, not restart"
    );
    println!("event log:");
    for e in &broker.events {
        println!("  {e:?}");
    }
    println!("churn_tolerance OK");
    Ok(())
}
