//! §Perf tool: per-artifact wall-clock breakdown of one preset's stage
//! functions (fwd / bwd / update) through the cached-buffer hot path.
//! This is how the EXPERIMENTS.md §Perf iteration log was produced.
//!
//! Run: `cargo run --release --example prof_stage [preset]`

use std::time::Instant;

use fusionai::exec::xla_engine::XlaEngine;
use fusionai::tensor::Tensor;
use fusionai::util::Rng;

fn main() -> anyhow::Result<()> {
    let preset = std::env::args().nth(1).unwrap_or_else(|| "gpt-small".into());
    let dir_s = format!("artifacts/{preset}");
    let dir = std::path::Path::new(&dir_s);
    let probe = XlaEngine::load_stage(dir, "embed")?;
    let stages = probe.manifest().stages.clone();
    println!(
        "preset {preset}: {} stages (times are 5-run means, first run includes warmup)",
        stages.len()
    );
    for stage in &stages {
        let eng = XlaEngine::load_stage(dir, stage)?;
        let mut rng = Rng::new(1);
        let mut st = eng.new_stage_state(stage, &mut rng)?;
        let m = eng.manifest();
        let (b, s, d) = (
            m.config_usize("batch").unwrap(),
            m.config_usize("seq").unwrap(),
            m.config_usize("dim").unwrap(),
        );
        let vocab = m.config_usize("vocab").unwrap();
        let x = Tensor::randn(&[b, s, d], 1.0, &mut rng);
        let tokens =
            Tensor::from_ivec(&[b, s], (0..b * s).map(|i| (i % vocab) as i32).collect());
        let labels = tokens.clone();
        let fwd_in: Vec<&Tensor> = match stage.as_str() {
            "embed" => vec![&tokens],
            "head" => vec![&x, &labels],
            _ => vec![&x],
        };
        if stage != "head" {
            let t0 = Instant::now();
            for _ in 0..5 {
                eng.forward_cached(&st, &fwd_in)?;
            }
            println!("  {stage}_fwd    {:8.1} ms", t0.elapsed().as_secs_f64() / 5.0 * 1e3);
        }
        let dy = Tensor::randn(&[b, s, d], 0.01, &mut rng);
        let grad = if stage == "head" { None } else { Some(&dy) };
        let t0 = Instant::now();
        let mut dparams = None;
        for _ in 0..5 {
            let (_, dp, _) = eng.backward_cached(&st, &fwd_in, grad)?;
            dparams = Some(dp);
        }
        println!("  {stage}_bwd    {:8.1} ms", t0.elapsed().as_secs_f64() / 5.0 * 1e3);
        let dp = dparams.unwrap();
        let t0 = Instant::now();
        for i in 0..5 {
            eng.update_cached(&mut st, &dp, i + 1)?;
        }
        println!("  {stage}_update {:8.1} ms", t0.elapsed().as_secs_f64() / 5.0 * 1e3);
    }
    Ok(())
}
