//! The paper's "what-if" tool: describe a GPU fleet + network in TOML and
//! get the §4 analytic estimate (Eq. 3 latency, Eq. 4 pipelined
//! throughput) against a 4×H100 datacenter baseline — the headline
//! comparison of the paper, interactive.
//!
//! Run: `cargo run --release --example estimate_cluster [fleet.toml]`
//! Without an argument it runs the paper's own configuration (50× RTX 3080
//! on Bert-Large, n_b = 512) across a bandwidth sweep.

use fusionai::benchutil::Table;
use fusionai::config::ExperimentConfig;
use fusionai::decompose::Decomposition;
use fusionai::models::transformer::TransformerConfig;
use fusionai::perf::comm::LinkModel;
use fusionai::perf::gpus::lookup;
use fusionai::perf::paleo::{DeviceProfile, PaleoModel};
use fusionai::pipeline::analytics::PipelineEstimate;
use fusionai::util::human_secs;

const PAPER_CONFIG: &str = r#"
# The paper's headline setup (§4, Figures 4–5).
[job]
model = "bert-large"
batches = 512
training = false

[network]
bandwidth_mbps = 1000.0
latency_ms = 5.0

[[fleet]]
gpu = "RTX 3080"
count = 50
lambda = 0.5
"#;

fn estimate_for(cfg: &ExperimentConfig, link: LinkModel) -> PipelineEstimate {
    let g = cfg.model.build_graph();
    let n = cfg.total_devices();
    let d = Decomposition::chain_balanced(&g, n);
    let mut models = Vec::new();
    for f in &cfg.fleet {
        for _ in 0..f.count {
            models.push(PaleoModel::new(DeviceProfile::with_lambda(&f.gpu, f.lambda)));
        }
    }
    PipelineEstimate::from_decomposition(&g, &d, &models, link, cfg.training)
}

fn h100_baseline(model: &TransformerConfig, training: bool) -> PipelineEstimate {
    let g = model.build_graph();
    let d = Decomposition::chain_balanced(&g, 4);
    let models: Vec<PaleoModel> = (0..4)
        .map(|_| PaleoModel::new(DeviceProfile::with_lambda(lookup("H100").unwrap(), 0.5)))
        .collect();
    PipelineEstimate::from_decomposition(&g, &d, &models, LinkModel::datacenter(), training)
}

fn main() -> anyhow::Result<()> {
    let toml_src = match std::env::args().nth(1) {
        Some(path) => std::fs::read_to_string(path)?,
        None => PAPER_CONFIG.to_string(),
    };
    let cfg = ExperimentConfig::from_toml(&toml_src)?;
    let n_b = cfg.batches;
    println!(
        "fleet: {} ({} devices) | model {} | n_b = {n_b}\n",
        cfg.fleet
            .iter()
            .map(|f| format!("{}×{}", f.count, f.gpu.name))
            .collect::<Vec<_>>()
            .join(" + "),
        cfg.total_devices(),
        cfg.model.name
    );

    let baseline = h100_baseline(&cfg.model, cfg.training);
    println!(
        "baseline 4×H100 (NVLink-class): latency {}, steady throughput {:.2} batches/s\n",
        human_secs(baseline.latency()),
        baseline.steady_state_throughput()
    );

    // Sweep the Figure-5 axes: bandwidth AND latency.
    let mut table = Table::new(&[
        "link (α, bw)", "latency(Eq.3)", "T_512(Eq.4)", "throughput", "vs 4×H100", "regime",
    ]);
    for (alpha_ms, mbps) in [
        (50.0, 10.0),        // poor consumer WAN
        (20.0, 100.0),       // typical broadband
        (5.0, 1_000.0),      // fiber
        (1.0, 10_000.0),     // metro 10GbE
        (0.1, 100_000.0),    // co-located 100GbE
        (0.005, 400_000.0),  // datacenter-class
    ] {
        let link = LinkModel::from_ms_mbps(alpha_ms, mbps);
        let est = estimate_for(&cfg, link);
        let ratio = est.steady_state_throughput() / baseline.steady_state_throughput();
        table.row(&[
            format!("{alpha_ms} ms, {mbps:.0} Mbps"),
            human_secs(est.latency()),
            human_secs(est.pipelined_time(n_b)),
            format!("{:.3} b/s", est.throughput(n_b)),
            format!("{:.2}×", ratio),
            if est.comm_bound() { "comm-bound" } else { "compute-bound" }.to_string(),
        ]);
    }
    table.print();

    let at_cfg = estimate_for(&cfg, cfg.link);
    println!(
        "\nat the configured link ({:.0} ms, {:.0} Mbps): latency {}, {} for {n_b} batches, bubble {:.1}%",
        cfg.link.alpha * 1e3,
        cfg.link.bandwidth() * 8.0 / 1e6,
        human_secs(at_cfg.latency()),
        human_secs(at_cfg.pipelined_time(n_b)),
        at_cfg.bubble_fraction(n_b) * 100.0
    );
    println!(
        "cost: fleet ≈ ${:.0} vs 4×H100 ≈ ${:.0}",
        cfg.fleet.iter().map(|f| f.count as f64 * f.gpu.price_usd).sum::<f64>(),
        4.0 * lookup("H100").unwrap().price_usd
    );

    // Energy & carbon (paper §2.8) for the n_b-batch run at the configured link.
    use fusionai::perf::energy::{carbon_kg, pipeline_energy, tdp_watts};
    let mut tdps = Vec::new();
    for f in &cfg.fleet {
        for _ in 0..f.count {
            tdps.push(tdp_watts(f.gpu.name));
        }
    }
    let fleet_e = pipeline_energy(&at_cfg, &tdps, n_b);
    let base_e = pipeline_energy(&baseline, &vec![tdp_watts("H100"); 4], n_b);
    println!(
        "energy for {n_b} batches: fleet {:.3} kWh (duty {:.0}%) vs 4×H100 {:.4} kWh (duty {:.0}%); \
         ≈{:.2} vs {:.3} kg CO₂e @0.4 kg/kWh",
        fleet_e.kwh,
        fleet_e.duty_cycle * 100.0,
        base_e.kwh,
        base_e.duty_cycle * 100.0,
        carbon_kg(fleet_e.kwh, 0.4),
        carbon_kg(base_e.kwh, 0.4),
    );
    Ok(())
}
