//! Quickstart: the full FusionAI pipeline on the paper's own example.
//!
//! Builds the Figure-3 DAG, decomposes it into the paper's Table-3
//! three-compnode partition, registers the compnodes with a broker,
//! schedules, and trains for a few steps on the simulated WAN with the
//! pure-rust execution engine — no artifacts needed.
//!
//! Run: `cargo run --release --example quickstart`

use std::sync::Arc;

use fusionai::broker::{Broker, NodeClass};
use fusionai::cluster::SimCluster;
use fusionai::decompose::Decomposition;
use fusionai::exec::{Adam, RefEngine};
use fusionai::models::fig3;
use fusionai::net::{NetworkSim, Topology};
use fusionai::perf::comm::LinkModel;
use fusionai::perf::gpus::lookup;
use fusionai::tensor::Tensor;
use fusionai::util::{human_bytes, human_secs, Rng};

fn main() -> anyhow::Result<()> {
    // 1. The IR plane: the paper's example DAG (Fig. 3 / Tables 2–3).
    let graph = fig3::build();
    println!("DAG: {} operators", graph.len());
    for node in &graph.nodes {
        println!(
            "  {:<14} {:<18} shape {}",
            node.name,
            node.kind.category().to_string(),
            node.out_shape
        );
    }

    // 2. Broker: three heterogeneous compnodes join.
    let mut broker = Broker::new(5.0);
    for gpu in ["RTX 3080", "RTX 3070", "RTX 3060"] {
        broker.register(lookup(gpu).unwrap(), 0.5, NodeClass::Antnode, 0.0, false);
    }
    println!("\nactive compnodes: {:?}", broker.active_nodes());

    // 3. Decompose exactly as the paper's Table 3 and build the cluster
    //    over a consumer-WAN network model.
    let decomp = Decomposition::from_assignment(&graph, &fig3::paper_partition(&graph));
    for s in 0..decomp.num_subgraphs() {
        let attrs = decomp.attrs(&graph, s);
        println!(
            "subgraph {}: nodes {:?} → compnode users {:?}",
            s + 1,
            decomp.subgraphs[s].nodes.iter().map(|&n| graph.node(n).name.as_str()).collect::<Vec<_>>(),
            attrs.compnode_users.iter().map(|u| u + 1).collect::<Vec<_>>()
        );
    }
    let net = Arc::new(NetworkSim::new(
        Topology::uniform(LinkModel::from_ms_mbps(10.0, 100.0)),
        0.0,
    ));
    let mut cluster = SimCluster::new(
        graph,
        decomp,
        net,
        Box::new(|| Box::new(RefEngine::new())),
        Box::new(|| Box::new(Adam::new(0.02))),
        42,
    )?;

    // 4. Train: FP → BP → Update across the three compnodes.
    println!("\ntraining (FP/BP/Update tasks over the simulated WAN):");
    let mut rng = Rng::new(7);
    let input = Tensor::randn(&[fig3::BATCH, fig3::CH, fig3::HW, fig3::HW], 1.0, &mut rng);
    let n_lab = fig3::BATCH * 2 * fig3::CH * fig3::HW;
    let labels = Tensor::from_ivec(
        &[fig3::BATCH, 2 * fig3::CH, fig3::HW],
        (0..n_lab).map(|i| (i % fig3::CLASSES) as i32).collect(),
    );
    for step in 0..20 {
        cluster.feed("Input", input.clone())?;
        cluster.feed("Label", labels.clone())?;
        let r = cluster.train_step()?;
        if step % 5 == 0 || step == 19 {
            println!(
                "  step {:>2}  loss {:.4}  comm {} ({} modelled)  peak resident {}",
                step,
                r.loss.unwrap(),
                human_bytes(r.comm_bytes),
                human_secs(r.comm_seconds),
                human_bytes(r.peak_resident_bytes)
            );
        }
    }
    println!("\nquickstart OK");
    Ok(())
}
