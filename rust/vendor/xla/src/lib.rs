//! Minimal offline stand-in for the `xla` crate (PJRT bindings).
//!
//! The host-side pieces — [`Literal`], [`ArrayShape`], [`ElementType`] —
//! are real, so literal round-trips and manifest-driven code work without
//! any native library. Everything that needs a live PJRT runtime
//! ([`PjRtClient::cpu`] and downstream compile/execute calls) returns an
//! error instead; callers are expected to surface or skip on it.

use std::fmt;

/// Stub-level XLA error.
#[derive(Debug)]
pub struct XlaError {
    msg: String,
}

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for XlaError {}

pub type Result<T> = std::result::Result<T, XlaError>;

fn unavailable(what: &str) -> XlaError {
    XlaError {
        msg: format!(
            "{what}: PJRT runtime unavailable (offline `xla` stub; link the real xla crate to execute artifacts)"
        ),
    }
}

fn err(msg: String) -> XlaError {
    XlaError { msg }
}

/// Element types the workspace exchanges with artifacts.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

/// Shape of an array literal: dimensions plus element type.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

/// Conversion between rust scalar types and [`Literal`] storage.
pub trait NativeType: Copy {
    fn vec1(data: &[Self]) -> Literal;
    fn to_vec(lit: &Literal) -> Result<Vec<Self>>;
}

impl NativeType for f32 {
    fn vec1(data: &[Self]) -> Literal {
        Literal::F32 { dims: vec![data.len() as i64], data: data.to_vec() }
    }

    fn to_vec(lit: &Literal) -> Result<Vec<Self>> {
        match lit {
            Literal::F32 { data, .. } => Ok(data.clone()),
            other => Err(err(format!("literal is not f32: {:?}", other.element_kind()))),
        }
    }
}

impl NativeType for i32 {
    fn vec1(data: &[Self]) -> Literal {
        Literal::S32 { dims: vec![data.len() as i64], data: data.to_vec() }
    }

    fn to_vec(lit: &Literal) -> Result<Vec<Self>> {
        match lit {
            Literal::S32 { data, .. } => Ok(data.clone()),
            other => Err(err(format!("literal is not s32: {:?}", other.element_kind()))),
        }
    }
}

/// A host-side literal: dense array data plus shape, or a tuple of
/// literals (artifact results are 1-tuples of tuples).
#[derive(Debug, Clone, PartialEq)]
pub enum Literal {
    F32 { dims: Vec<i64>, data: Vec<f32> },
    S32 { dims: Vec<i64>, data: Vec<i32> },
    Tuple(Vec<Literal>),
}

impl Literal {
    /// Build a rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        T::vec1(data)
    }

    fn element_kind(&self) -> &'static str {
        match self {
            Literal::F32 { .. } => "f32",
            Literal::S32 { .. } => "s32",
            Literal::Tuple(_) => "tuple",
        }
    }

    /// Reinterpret with new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        match self {
            Literal::F32 { data, .. } => {
                if want as usize != data.len() {
                    return Err(err(format!("reshape {} elements to {dims:?}", data.len())));
                }
                Ok(Literal::F32 { dims: dims.to_vec(), data: data.clone() })
            }
            Literal::S32 { data, .. } => {
                if want as usize != data.len() {
                    return Err(err(format!("reshape {} elements to {dims:?}", data.len())));
                }
                Ok(Literal::S32 { dims: dims.to_vec(), data: data.clone() })
            }
            Literal::Tuple(_) => Err(err("cannot reshape a tuple literal".to_string())),
        }
    }

    /// Array shape of a non-tuple literal.
    pub fn array_shape(&self) -> Result<ArrayShape> {
        match self {
            Literal::F32 { dims, .. } => {
                Ok(ArrayShape { dims: dims.clone(), ty: ElementType::F32 })
            }
            Literal::S32 { dims, .. } => {
                Ok(ArrayShape { dims: dims.clone(), ty: ElementType::S32 })
            }
            Literal::Tuple(_) => Err(err("tuple literal has no array shape".to_string())),
        }
    }

    /// Copy the elements out as a host vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::to_vec(self)
    }

    /// Decompose a tuple literal into its elements.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self {
            Literal::Tuple(items) => Ok(items),
            other => Err(err(format!("literal is not a tuple: {}", other.element_kind()))),
        }
    }
}

/// Stub PJRT module proto: retains the HLO text it was parsed from.
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    pub text: String,
}

impl HloModuleProto {
    /// Read an HLO-text file. Parsing is deferred to compile time in the
    /// real crate; the stub only validates that the file is readable.
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| err(format!("reading HLO text {path}: {e}")))?;
        Ok(HloModuleProto { text })
    }
}

/// Stub computation wrapper.
#[derive(Debug, Clone)]
pub struct XlaComputation {
    _text: String,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _text: proto.text.clone() }
    }
}

/// Stub PJRT client: construction always fails in the offline stub.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "offline-stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(unavailable("PjRtClient::buffer_from_host_buffer"))
    }
}

/// Stub loaded executable (unreachable: the client never constructs one).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _inputs: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }

    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute_b"))
    }
}

/// Stub device buffer (unreachable: the client never constructs one).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let r = l.reshape(&[2, 3]).unwrap();
        let shape = r.array_shape().unwrap();
        assert_eq!(shape.dims(), &[2, 3]);
        assert_eq!(shape.ty(), ElementType::F32);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert!(r.to_vec::<i32>().is_err());
        assert!(l.reshape(&[7]).is_err());
    }

    #[test]
    fn tuple_decomposes() {
        let t = Literal::Tuple(vec![Literal::vec1(&[1i32]), Literal::vec1(&[2.0f32])]);
        let items = t.to_tuple().unwrap();
        assert_eq!(items.len(), 2);
        assert!(Literal::vec1(&[0.0f32]).to_tuple().is_err());
    }

    #[test]
    fn client_reports_unavailable() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("PJRT runtime unavailable"));
    }
}
