//! Minimal offline stand-in for the `log` crate.
//!
//! Provides the five level macros. Records go to stderr only when the
//! `FUSIONAI_LOG` environment variable is set, mirroring how the real crate
//! is silent until a logger is installed.

/// Backing emitter for the level macros (public so the macros can expand
/// from downstream crates; not part of the real `log` API).
pub fn __emit(level: &str, args: std::fmt::Arguments<'_>) {
    if std::env::var_os("FUSIONAI_LOG").is_some() {
        eprintln!("[{level}] {args}");
    }
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)*) => { $crate::__emit("ERROR", ::std::format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)*) => { $crate::__emit("WARN", ::std::format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::__emit("INFO", ::std::format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => { $crate::__emit("DEBUG", ::std::format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)*) => { $crate::__emit("TRACE", ::std::format_args!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    #[test]
    fn macros_expand_without_env() {
        // Silent by default; just exercise the expansion paths.
        info!("step {}: loss {:.4}", 1, 0.25_f32);
        warn!("w");
        error!("e");
        debug!("d");
        trace!("t");
    }
}
