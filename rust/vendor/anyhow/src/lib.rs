//! Minimal offline stand-in for the `anyhow` crate.
//!
//! Implements the subset of the real API this workspace uses: [`Error`]
//! with a context chain, [`Result`] with a defaulted error type, the
//! [`Context`] extension trait, and the `anyhow!` / `bail!` / `ensure!`
//! macros. Display follows anyhow's convention: `{}` prints the outermost
//! message, `{:#}` prints the whole chain separated by `: `, and `{:?}`
//! prints the message plus a `Caused by:` list.

use std::fmt;

/// A dynamic error: an outermost message plus an optional cause chain.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string(), source: None }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: context.to_string(), source: Some(Box::new(self)) }
    }

    /// Iterate the chain from the outermost message inward.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        let mut next = Some(self);
        std::iter::from_fn(move || {
            let cur = next?;
            next = cur.source.as_deref();
            Some(cur.msg.as_str())
        })
    }

    /// The innermost message of the chain.
    pub fn root_cause(&self) -> &str {
        self.chain().last().unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        if f.alternate() {
            let mut src = self.source.as_deref();
            while let Some(e) = src {
                write!(f, ": {}", e.msg)?;
                src = e.source.as_deref();
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        let mut src = self.source.as_deref();
        if src.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(e) = src {
            write!(f, "\n    {}", e.msg)?;
            src = e.source.as_deref();
        }
        Ok(())
    }
}

// Mirrors anyhow's blanket conversion. Coherent because this `Error` does
// not itself implement `std::error::Error`, so the impl can never overlap
// with the reflexive `From<Error> for Error`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut msgs = vec![e.to_string()];
        let mut cur: Option<&(dyn std::error::Error + 'static)> = e.source();
        while let Some(s) = cur {
            msgs.push(s.to_string());
            cur = s.source();
        }
        let mut built: Option<Error> = None;
        for msg in msgs.into_iter().rev() {
            built = Some(Error { msg, source: built.map(Box::new) });
        }
        built.expect("at least one message")
    }
}

/// `anyhow::Result<T>` — the error type defaults to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(...)` / `.with_context(...)` to results.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] if the condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails_io() -> Result<()> {
        Err(std::io::Error::new(std::io::ErrorKind::NotFound, "no such file"))?;
        Ok(())
    }

    #[test]
    fn context_chains_and_alternate_display() {
        let err = fails_io().context("reading config").unwrap_err();
        assert_eq!(err.to_string(), "reading config");
        assert_eq!(format!("{err:#}"), "reading config: no such file");
        assert_eq!(err.root_cause(), "no such file");
    }

    #[test]
    fn macros_build_errors() {
        let e = anyhow!("plain");
        assert_eq!(e.to_string(), "plain");
        let v = 3;
        let e = anyhow!("value {v} bad");
        assert_eq!(e.to_string(), "value 3 bad");
        fn inner() -> Result<()> {
            bail!("boom {}", 7)
        }
        assert_eq!(inner().unwrap_err().to_string(), "boom 7");
        fn checked(ok: bool) -> Result<u32> {
            ensure!(ok, "must hold");
            Ok(1)
        }
        assert!(checked(true).is_ok());
        assert_eq!(checked(false).unwrap_err().to_string(), "must hold");
    }

    #[test]
    fn with_context_is_lazy() {
        let ok: Result<u32, std::num::ParseIntError> = "42".parse();
        let got = ok.with_context(|| -> String { unreachable!("not called on Ok") });
        assert_eq!(got.unwrap(), 42);
    }
}
