//! Wavefront executor properties (§Perf "Execution plan").
//!
//! 1. **Bitwise determinism**: on random shape-preserving DAGs, a full
//!    FP→BP cycle through [`SubDagExecutor`] at wave widths 1, 2 and 8 is
//!    bit-for-bit identical — and all of them match an independent serial
//!    oracle that walks the graph in plain topological order with immediate
//!    gradient accumulation (no plan, no waves, no scratch reuse).
//! 2. **Memory**: on the paper's Figure-3 cluster, liveness-driven freeing
//!    keeps the peak resident bytes strictly below the keep-everything
//!    baseline while leaving the loss bits untouched.
//!
//! Shapes are `[64, 128]` so Linear-bearing waves clear
//! `WAVE_PAR_MIN_FLOPS` and the fan-out path genuinely runs.

use std::collections::{BTreeMap, HashMap};
use std::sync::Arc;

use fusionai::cluster::SimCluster;
use fusionai::compnode::SubDagExecutor;
use fusionai::dag::autodiff::{backward_plan, BackwardPlan};
use fusionai::dag::{DType, Graph, NodeId, OpCategory, OpKind, Shape};
use fusionai::decompose::Decomposition;
use fusionai::exec::{set_wave_threads, Adam, Engine, RefEngine};
use fusionai::models::fig3;
use fusionai::net::{NetworkSim, Topology};
use fusionai::perf::comm::LinkModel;
use fusionai::proptesting::{check, Gen};
use fusionai::tensor::Tensor;
use fusionai::util::Rng;

const B: usize = 64;
const D: usize = 128;

/// Random DAG of shape-preserving `[B, D]` ops ending in
/// `MseLoss(Linear(last), target)`. Returns the graph plus the two
/// placeholder ids to feed.
fn random_dag(gn: &mut Gen) -> (Graph, NodeId, NodeId) {
    let mut g = Graph::new();
    let x = g.placeholder("x", Shape::of(&[B, D]), DType::F32);
    let mut pool = vec![x];
    let n_ops = gn.usize(5, 12);
    for i in 0..n_ops {
        let a = *gn.choose(&pool);
        let lin = OpKind::Linear { in_features: D, out_features: D, bias: true };
        let id = match gn.usize(0, 7) {
            0 => g.op(&format!("relu{i}"), OpKind::Relu, &[a]).unwrap(),
            1 => g.op(&format!("gelu{i}"), OpKind::Gelu, &[a]).unwrap(),
            2 => g.op(&format!("sm{i}"), OpKind::Softmax, &[a]).unwrap(),
            3 => g.op(&format!("ln{i}"), OpKind::LayerNorm { dim: D }, &[a]).unwrap(),
            4 => g.op(&format!("fc{i}"), lin, &[a]).unwrap(),
            5 => {
                let b = *gn.choose(&pool);
                g.op(&format!("add{i}"), OpKind::Add, &[a, b]).unwrap()
            }
            _ => {
                let b = *gn.choose(&pool);
                g.op(&format!("mul{i}"), OpKind::Multiply, &[a, b]).unwrap()
            }
        };
        pool.push(id);
    }
    // A parametric head guarantees the loss depends on trainable state.
    let head = g
        .op(
            "head",
            OpKind::Linear { in_features: D, out_features: D, bias: true },
            &[*pool.last().unwrap()],
        )
        .unwrap();
    let target = g.placeholder("target", Shape::of(&[B, D]), DType::F32);
    g.op("loss", OpKind::MseLoss, &[head, target]).unwrap();
    (g, x, target)
}

type GradBits = BTreeMap<NodeId, Vec<Vec<u32>>>;

fn bits_of(grads: &[Tensor]) -> Vec<Vec<u32>> {
    grads.iter().map(|t| t.f().iter().map(|v| v.to_bits()).collect()).collect()
}

/// Run one FP→BP cycle through the plan-based executor at the given wave
/// width. Returns (loss bits, param-grad bits, checkpointed params).
#[allow(clippy::type_complexity)]
fn run_executor(
    g: &Arc<Graph>,
    d: &Arc<Decomposition>,
    plan: &BackwardPlan,
    feeds: &[(NodeId, Tensor)],
    seed: u64,
    threads: usize,
) -> (u32, GradBits, HashMap<NodeId, Vec<Tensor>>) {
    set_wave_threads(threads);
    let mut rng = Rng::new(seed);
    let mut e = SubDagExecutor::new(
        g.clone(),
        d.clone(),
        0,
        Box::new(RefEngine::new()),
        &|| Box::new(Adam::new(0.01)),
        &mut rng,
    )
    .unwrap();
    let ckpt = e.checkpoint();
    for (n, t) in feeds {
        e.feed(*n, t.clone());
    }
    assert!(e.run_fp().unwrap().is_empty(), "single sub sends nothing");
    let loss_id = g.by_name("loss").unwrap().id;
    let loss = e.activation(loss_id).unwrap().item().to_bits();
    assert!(e.run_bp(plan).unwrap().is_empty());
    let mut grads: GradBits = BTreeMap::new();
    for (&n, pg) in &e.param_grads {
        grads.insert(n, bits_of(pg));
    }
    set_wave_threads(1);
    (loss, grads, ckpt)
}

/// Independent serial oracle: forward in node-id (= topological) order,
/// backward in plan order with immediate axpy accumulation. Shares nothing
/// with the wavefront executor beyond the per-op kernels.
fn run_oracle(
    g: &Graph,
    plan: &BackwardPlan,
    params: &HashMap<NodeId, Vec<Tensor>>,
    feeds: &[(NodeId, Tensor)],
) -> (u32, GradBits) {
    let mut eng = RefEngine::new();
    let mut acts: Vec<Option<Tensor>> = vec![None; g.len()];
    for (n, t) in feeds {
        acts[*n] = Some(t.clone());
    }
    for node in &g.nodes {
        if node.kind.category() == OpCategory::Placeholder {
            continue;
        }
        let inputs: Vec<&Tensor> = node.args.iter().map(|&a| acts[a].as_ref().unwrap()).collect();
        let p = params.get(&node.id).map(Vec::as_slice).unwrap_or(&[]);
        let out = eng.forward(node, &inputs, p).unwrap();
        acts[node.id] = Some(out);
    }
    let loss = acts[g.by_name("loss").unwrap().id].as_ref().unwrap().item().to_bits();
    let mut grads: Vec<Option<Tensor>> = vec![None; g.len()];
    let mut pgrads: GradBits = BTreeMap::new();
    for &n in &plan.order {
        let node = g.node(n);
        let task = plan.task(n).unwrap();
        let upstream = if node.kind.category() == OpCategory::Loss {
            None
        } else {
            Some(grads[n].clone().expect("upstream grad ready"))
        };
        let inputs: Vec<&Tensor> = node.args.iter().map(|&a| acts[a].as_ref().unwrap()).collect();
        let p = params.get(&n).map(Vec::as_slice).unwrap_or(&[]);
        let out = eng.backward(node, &inputs, p, upstream.as_ref()).unwrap();
        if !out.param_grads.is_empty() {
            pgrads.insert(n, bits_of(&out.param_grads));
        }
        for (ai, gt) in out.input_grads.into_iter().enumerate() {
            let Some(gt) = gt else { continue };
            let arg = node.args[ai];
            if !task.grad_targets.contains(&arg) {
                continue;
            }
            match &mut grads[arg] {
                None => grads[arg] = Some(gt),
                Some(acc) => acc.axpy(1.0, &gt),
            }
        }
    }
    (loss, pgrads)
}

#[test]
fn wavefront_is_bitwise_identical_to_serial_oracle_on_random_dags() {
    check("wavefront-bitwise", 6, |gn| {
        let (g, x, target) = random_dag(gn);
        let g = Arc::new(g);
        let assign: Vec<(NodeId, usize)> = (0..g.len()).map(|n| (n, 0)).collect();
        let d = Arc::new(Decomposition::from_assignment(&g, &assign));
        let plan = backward_plan(&g);
        let feeds = vec![
            (x, Tensor::F32 { shape: vec![B, D], data: gn.vec_f32(B * D, 1.0) }),
            (target, Tensor::F32 { shape: vec![B, D], data: gn.vec_f32(B * D, 1.0) }),
        ];
        let seed = gn.seed;
        let (l1, g1, ckpt) = run_executor(&g, &d, &plan, &feeds, seed, 1);
        for threads in [2, 8] {
            let (lt, gt, _) = run_executor(&g, &d, &plan, &feeds, seed, threads);
            if lt != l1 {
                return Err(format!("loss bits diverged at {threads} threads"));
            }
            if gt != g1 {
                return Err(format!("param grads diverged at {threads} threads"));
            }
        }
        let (lo, go) = run_oracle(&g, &plan, &ckpt, &feeds);
        if lo != l1 {
            return Err("loss bits diverged from serial oracle".into());
        }
        if go != g1 {
            return Err("param grads diverged from serial oracle".into());
        }
        Ok(())
    });
}

fn fig3_cluster() -> SimCluster {
    let g = fig3::build();
    let d = Decomposition::from_assignment(&g, &fig3::paper_partition(&g));
    let net = Arc::new(NetworkSim::new(Topology::uniform(LinkModel::local()), 0.0));
    SimCluster::new(
        g,
        d,
        net,
        Box::new(|| Box::new(RefEngine::new())),
        Box::new(|| Box::new(Adam::new(0.02))),
        42,
    )
    .unwrap()
}

fn fig3_step(cluster: &mut SimCluster) -> fusionai::cluster::StepReport {
    let mut rng = Rng::new(7);
    let input = Tensor::randn(&[fig3::BATCH, fig3::CH, fig3::HW, fig3::HW], 1.0, &mut rng);
    let n_lab = fig3::BATCH * 2 * fig3::CH * fig3::HW;
    let labels = Tensor::from_ivec(
        &[fig3::BATCH, 2 * fig3::CH, fig3::HW],
        (0..n_lab).map(|i| (i % fig3::CLASSES) as i32).collect(),
    );
    cluster.feed("Input", input).unwrap();
    cluster.feed("Label", labels).unwrap();
    cluster.train_step().unwrap()
}

/// Figure-3 memory deliverable: liveness freeing strictly undercuts the
/// keep-everything baseline's peak, at identical loss bits.
#[test]
fn fig3_peak_resident_drops_under_liveness_freeing() {
    let mut freeing = fig3_cluster();
    let r_free = fig3_step(&mut freeing);
    let mut baseline = fig3_cluster();
    baseline.set_liveness_freeing(false);
    let r_base = fig3_step(&mut baseline);
    assert!(r_free.peak_resident_bytes > 0);
    assert!(
        r_free.peak_resident_bytes < r_base.peak_resident_bytes,
        "freeing peak {} must be strictly below baseline {}",
        r_free.peak_resident_bytes,
        r_base.peak_resident_bytes
    );
    assert_eq!(
        r_free.loss.unwrap().to_bits(),
        r_base.loss.unwrap().to_bits(),
        "freeing must not change numerics"
    );
}
