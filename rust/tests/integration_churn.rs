//! Integration: dynamic join/leave (paper §3.2) — broker liveness, backup
//! promotion, rescheduling, DHT data survival and cluster recovery, driven
//! through scripted and randomized churn.

use std::sync::Arc;

use fusionai::broker::{Broker, Event, NodeClass, NodeState};
use fusionai::cluster::data::{DataProvider, SyntheticCorpus};
use fusionai::cluster::SimCluster;
use fusionai::decompose::Decomposition;
use fusionai::dht::Dht;
use fusionai::exec::{Adam, RefEngine};
use fusionai::models::transformer::TransformerConfig;
use fusionai::net::{NetworkSim, Topology};
use fusionai::perf::comm::LinkModel;
use fusionai::perf::gpus::lookup;
use fusionai::tensor::Tensor;
use fusionai::util::Rng;

#[test]
fn broker_survives_random_churn() {
    let mut broker = Broker::new(1.5);
    let mut rng = Rng::new(123);
    let gpu = lookup("RTX 3080").unwrap();
    // 10 active + 5 backups.
    for i in 0..15 {
        broker.register(gpu, 0.5, NodeClass::Antnode, 0.0, i >= 10);
    }
    let g = TransformerConfig::tiny().build_graph();
    let job = broker.submit_job(g, 10, true).unwrap();

    let mut clock = 0.0;
    let mut failures = 0;
    for round in 0..20 {
        clock += 1.0;
        // Random subset heartbeats; ~10% of nodes go silent each round.
        let ids: Vec<usize> = (0..15).collect();
        for &n in &ids {
            if broker.state(n) == Some(NodeState::Offline) {
                continue;
            }
            if rng.chance(0.8) {
                broker.heartbeat(n, clock).unwrap();
            }
        }
        for dead in broker.check_liveness(clock) {
            failures += 1;
            // Only reschedule if the dead node carried tasks for this job.
            let carried = {
                let j = broker.job(job).unwrap();
                (0..j.tasks.len()).any(|k| j.node_of_task(k) == dead)
            };
            if carried {
                broker.handle_failure(job, dead).unwrap();
            }
        }
        let _ = round;
    }
    // Whatever happened, every task is on a live node.
    let j = broker.job(job).unwrap();
    for k in 0..j.tasks.len() {
        let node = j.node_of_task(k);
        assert_eq!(broker.state(node), Some(NodeState::Active), "task {k} on dead node");
    }
    assert!(failures > 0, "churn scenario must actually kill nodes");
    assert!(broker.events.iter().any(|e| matches!(e, Event::Rescheduled { .. })));
}

#[test]
fn dht_data_survives_provider_churn() {
    let mut dht = Dht::new(3);
    for p in 0..8 {
        dht.join(p).unwrap();
    }
    let dht = Arc::new(std::sync::Mutex::new(dht));
    let corpus = SyntheticCorpus::new(128, 8, 2);
    let provider = DataProvider::new(corpus.clone(), dht.clone());
    for step in 0..5 {
        provider.publish_step(step, 4).unwrap();
    }
    // Two storage peers die.
    {
        let mut d = dht.lock().unwrap();
        d.leave(0).unwrap();
        d.leave(3).unwrap();
    }
    // Every batch is still retrievable and identical.
    for step in 0..5 {
        for mb in 0..4 {
            let t = fusionai::cluster::data::fetch_tokens(&dht, step, mb, "tokens", &[2, 8])
                .unwrap();
            let (want, _) = corpus.batch((step * 4 + mb) as u64);
            assert_eq!(t, want);
        }
    }
}

#[test]
fn repeated_crash_recover_cycles_keep_training() {
    let cfg = TransformerConfig::tiny();
    let g = cfg.build_graph();
    let d = Decomposition::chain_balanced(&g, 4);
    let net = Arc::new(NetworkSim::new(Topology::uniform(LinkModel::local()), 0.0));
    let mut cluster = SimCluster::new(
        g,
        d,
        net,
        Box::new(|| Box::new(RefEngine::new())),
        Box::new(|| Box::new(Adam::new(0.01))),
        3,
    )
    .unwrap();
    let feed = |c: &mut SimCluster| {
        let tokens: Vec<i32> =
            (0..cfg.batch * cfg.seq).map(|i| ((i * 3 + 1) % cfg.vocab) as i32).collect();
        let labels: Vec<i32> =
            tokens.iter().map(|&t| ((t as usize + 3) % cfg.vocab) as i32).collect();
        c.feed("tokens", Tensor::from_ivec(&[cfg.batch, cfg.seq], tokens)).unwrap();
        c.feed("labels", Tensor::from_ivec(&[cfg.batch, cfg.seq], labels)).unwrap();
    };
    let mut rng = Rng::new(77);
    let mut last = f32::INFINITY;
    let mut first = None;
    for step in 0..30 {
        // Crash a random compnode every 6 steps, recover immediately.
        if step % 6 == 5 {
            let victim = rng.below(4) as usize;
            cluster.fail_compnode(victim);
            cluster.recover_compnode(victim).unwrap();
        }
        feed(&mut cluster);
        let r = cluster.train_step().unwrap();
        let l = r.loss.unwrap();
        first.get_or_insert(l);
        last = l;
    }
    assert!(
        last < first.unwrap() * 0.9,
        "training with churn every 6 steps must still converge: {first:?} → {last}"
    );
}

#[test]
fn backup_pool_exhaustion_reported() {
    let mut broker = Broker::new(1.0);
    let gpu = lookup("RTX 3080").unwrap();
    broker.register(gpu, 0.5, NodeClass::Antnode, 0.0, false);
    broker.register(gpu, 0.5, NodeClass::Antnode, 0.0, true);
    assert!(broker.promote_backup(0).is_some());
    assert!(broker.promote_backup(0).is_none(), "pool exhausted");
}

#[test]
fn rejoin_after_offline_gets_fresh_id() {
    // The paper gives each registration a unique id; a returning provider
    // re-registers rather than resurrecting its old id.
    let mut broker = Broker::new(1.0);
    let gpu = lookup("RTX 3080").unwrap();
    let a = broker.register(gpu, 0.5, NodeClass::Antnode, 0.0, false);
    broker.deregister(a);
    let b = broker.register(gpu, 0.5, NodeClass::Antnode, 10.0, false);
    assert_ne!(a, b);
    assert_eq!(broker.state(a), Some(NodeState::Offline));
    assert_eq!(broker.state(b), Some(NodeState::Active));
}
