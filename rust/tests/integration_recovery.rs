//! Supervised-recovery integration: the whole coordinator/worker/broker/
//! checkpoint loop driven end-to-end with deterministic fault injection.
//!
//! Uses the sim stage backend (`SimStageFactory`) — pure host math, no
//! compiled artifacts — so these run in a fresh checkout. The headline
//! property throughout: a run that crashes and recovers finishes with
//! losses **bitwise-identical** to an uninterrupted run of the same seed
//! (float `Display` round-trips, so CSV equality is bit equality).

use std::path::PathBuf;
use std::sync::Arc;

use fusionai::broker::Event;
use fusionai::cluster::{
    FaultPlan, PipelineTrainer, SimStageFactory, SimStagesConfig, TrainConfig, TrainReport,
};

/// Per-test scratch dir (checkpoints land here); cleaned on entry so a
/// previous run's files can't leak in.
fn scratch(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("fusionai-recovery-{}-{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn trainer(dir: PathBuf, faults: Option<FaultPlan>) -> PipelineTrainer {
    let mut cfg = TrainConfig::new(dir);
    cfg.steps = 8;
    cfg.microbatches = 2;
    cfg.ckpt_every = 2;
    cfg.seed = 7;
    cfg.log_every = 0;
    cfg.hop_timeout_s = 1.0;
    cfg.recovery_backoff_ms = 1;
    cfg.faults = faults.map(Arc::new);
    let sim = SimStagesConfig::default();
    let manifest = sim.manifest();
    PipelineTrainer::with_backend(cfg, manifest, Arc::new(SimStageFactory { cfg: sim }))
        .unwrap()
}

fn baseline(name: &str) -> TrainReport {
    trainer(scratch(name), None).run().unwrap()
}

fn assert_bitwise_equal(a: &TrainReport, b: &TrainReport) {
    assert_eq!(a.losses.len(), b.losses.len());
    assert_eq!(a.losses.to_csv(), b.losses.to_csv(), "recovered run diverged from baseline");
}

#[test]
fn clean_run_trains_checkpoints_and_reports() {
    let t = trainer(scratch("clean"), None);
    let report = t.run().unwrap();
    assert_eq!(report.steps, 8);
    assert_eq!(report.losses.len(), 8);
    let (_, l0) = report.losses.first().unwrap();
    assert!(l0.is_finite());
    assert_eq!(report.recoveries, 0);
    assert_eq!(report.stage_failures, 0);
    assert_eq!(report.messages_dropped, 0);
    // Step boundaries 2, 4, 6, 8 (8 is also the final step — one write).
    assert_eq!(report.checkpoints_written, 4);
    // 4 stages + 2 backups registered, nobody promoted.
    assert_eq!(
        report.broker_events.iter().filter(|e| matches!(e, Event::Registered { .. })).count(),
        4 + 2
    );
    assert!(!report.broker_events.iter().any(|e| matches!(e, Event::Promoted { .. })));
    // The final v1 checkpoint (what `serve` loads) was published.
    let ckpt = fusionai::cluster::checkpoint::default_path(&t.config.artifacts_dir);
    assert!(ckpt.exists());
}

#[test]
fn killed_stage_recovers_bitwise_from_v2_checkpoint() {
    let base = baseline("kill-base");
    // Stage 1 dies at the top of step 5; the last step boundary is 4, so
    // the supervisor must resume from the v2 checkpoint (params + Adam
    // moments + step) and replay steps 4..8 exactly.
    let t = trainer(scratch("kill"), Some(FaultPlan::parse("kill:stage=1,step=5").unwrap()));
    let report = t.run().unwrap();
    assert_eq!(report.recoveries, 1);
    assert!(report.stage_failures >= 1);
    assert_eq!(report.losses.len(), 8);
    assert_bitwise_equal(&base, &report);
    // The broker replaced the dead node with a backup.
    assert!(report.broker_events.iter().any(|e| matches!(e, Event::Promoted { .. })));
    assert_eq!(t.metrics.counter("train.recoveries"), 1);
}

#[test]
fn killed_stage_before_first_checkpoint_restarts_from_scratch() {
    let base = baseline("kill0-base");
    // Death at step 1 — before any step boundary — must replay from step 0
    // with the same seed and still match bitwise.
    let t = trainer(scratch("kill0"), Some(FaultPlan::parse("kill:stage=2,step=1").unwrap()));
    let report = t.run().unwrap();
    assert_eq!(report.recoveries, 1);
    assert_bitwise_equal(&base, &report);
}

#[test]
fn dropped_hop_times_out_and_recovers() {
    let base = baseline("drop-base");
    // One activation hop from stage 0 to stage 1 at step 3 vanishes in
    // flight. Nothing crashes — the receiver's bounded hop wait has to
    // notice and the supervisor has to treat it as a stage failure. The
    // old unbounded `recv` would hang forever here.
    let t = trainer(scratch("drop"), Some(FaultPlan::parse("drop:from=0,to=1,step=3").unwrap()));
    let report = t.run().unwrap();
    assert_eq!(report.messages_dropped, 1);
    assert_eq!(report.recoveries, 1);
    assert_bitwise_equal(&base, &report);
}

#[test]
fn truncated_checkpoint_falls_back_to_previous_generation() {
    let base = baseline("trunc-base");
    // The step-4 checkpoint is corrupted right after it is written; when
    // stage 1 dies at step 5, recovery must reject the torn file and
    // resume from the `.prev` generation (step 2) — never from garbage.
    let plan = FaultPlan::parse("truncate:step=4,keep=16;kill:stage=1,step=5").unwrap();
    let t = trainer(scratch("trunc"), Some(plan));
    let report = t.run().unwrap();
    assert_eq!(report.recoveries, 1);
    assert_eq!(t.metrics.counter("train.checkpoint_load_failures"), 1);
    assert_bitwise_equal(&base, &report);
}

#[test]
fn delayed_hop_is_harmless() {
    let base = baseline("delay-base");
    // A late message is not a failure: the hop wait tolerates it and the
    // math is unchanged.
    let t =
        trainer(scratch("delay"), Some(FaultPlan::parse("delay:from=1,to=2,step=2,ms=50").unwrap()));
    let report = t.run().unwrap();
    assert_eq!(report.recoveries, 0);
    assert_bitwise_equal(&base, &report);
}

#[test]
fn recovery_budget_exhaustion_reports_the_failing_stage() {
    // Two kills on the same stage across attempts, but a budget of one
    // recovery: the run must fail — naming the stage — not hang or loop.
    let mut cfg = TrainConfig::new(scratch("budget"));
    cfg.steps = 8;
    cfg.microbatches = 2;
    cfg.ckpt_every = 2;
    cfg.log_every = 0;
    cfg.hop_timeout_s = 1.0;
    cfg.recovery_backoff_ms = 1;
    cfg.max_recoveries = 1;
    cfg.faults = Some(Arc::new(
        FaultPlan::parse("kill:stage=1,step=3;kill:stage=1,step=5").unwrap(),
    ));
    let sim = SimStagesConfig::default();
    let manifest = sim.manifest();
    let t = PipelineTrainer::with_backend(cfg, manifest, Arc::new(SimStageFactory { cfg: sim }))
        .unwrap();
    let err = t.run().unwrap_err().to_string();
    assert!(err.contains("block0"), "error must name the failed stage: {err}");
    assert!(err.contains("recover"), "error must mention the exhausted budget: {err}");
}

#[test]
fn exhausted_backup_pool_is_a_clean_error() {
    let mut cfg = TrainConfig::new(scratch("nobackup"));
    cfg.steps = 8;
    cfg.microbatches = 2;
    cfg.ckpt_every = 2;
    cfg.log_every = 0;
    cfg.hop_timeout_s = 1.0;
    cfg.recovery_backoff_ms = 1;
    cfg.backup_nodes = 0;
    cfg.faults = Some(Arc::new(FaultPlan::parse("kill:stage=2,step=2").unwrap()));
    let sim = SimStagesConfig::default();
    let manifest = sim.manifest();
    let t = PipelineTrainer::with_backend(cfg, manifest, Arc::new(SimStageFactory { cfg: sim }))
        .unwrap();
    let err = t.run().unwrap_err().to_string();
    assert!(err.contains("backup"), "got: {err}");
}

#[test]
fn sim_backend_reaches_a_sane_loss() {
    // Not a recovery test — anchors the sim model itself: CE starts near
    // ln(vocab) and training for 8 steps moves it down, so the bitwise
    // assertions above compare *meaningful* trajectories, not constants.
    let report = baseline("sanity");
    let (_, l0) = report.losses.first().unwrap();
    let (_, l1) = report.losses.last().unwrap();
    assert!((l0 - (64f32).ln()).abs() < 0.5, "initial CE ≈ ln(64), got {l0}");
    assert!(l1 < l0, "loss must decrease: {l0} → {l1}");
}
