//! Adversarial fixtures for the static verifier (§Static analysis).
//!
//! One hand-broken graph / plan / schedule per diagnostic code, each
//! asserting that *exactly* that error code fires — the staged gating in
//! the analyzers is what keeps a single root cause from cascading. Plus
//! two properties: random DAGs normalized by `PassManager::standard()`
//! lint clean, and corrupting any single `fwd_uses` entry of a valid
//! `ExecPlan` is always caught.

use fusionai::dag::autodiff::backward_plan;
use fusionai::dag::{DType, Graph, NodeId, OpKind, PassManager, Shape};
use fusionai::decompose::SUBGRAPH_KEY;
use fusionai::exec::ExecPlan;
use fusionai::models::fig3;
use fusionai::models::transformer::TransformerConfig;
use fusionai::pipeline::{MicrobatchSchedule, PipeEvent, PipeEventKind};
use fusionai::proptesting::{check, Gen};
use fusionai::verify::{
    check_plan, check_schedule, check_schedule_with_deps, lint_graph, Code, Report,
};

/// x → fc1 → relu → fc2 → loss(y): one of everything the checkers track.
fn mlp() -> Graph {
    let mut g = Graph::new();
    let x = g.placeholder("x", Shape::of(&[4, 8]), DType::F32);
    let y = g.placeholder("y", Shape::of(&[4, 2]), DType::F32);
    let h = g
        .op("fc1", OpKind::Linear { in_features: 8, out_features: 16, bias: true }, &[x])
        .unwrap();
    let r = g.op("relu", OpKind::Relu, &[h]).unwrap();
    let o = g
        .op("fc2", OpKind::Linear { in_features: 16, out_features: 2, bias: true }, &[r])
        .unwrap();
    g.op("loss", OpKind::MseLoss, &[o, y]).unwrap();
    g
}

fn node(g: &Graph, name: &str) -> NodeId {
    g.by_name(name).unwrap().id
}

/// The fixture contract: exactly one error code (possibly several findings
/// carrying it), nothing else at error severity.
fn assert_exactly(report: &Report, code: Code) {
    assert_eq!(
        report.error_codes(),
        vec![code],
        "expected exactly {code:?}:\n{}",
        report.render()
    );
}

// ---------------------------------------------------------------- graph lints

#[test]
fn fa001_duplicate_name() {
    let mut g = mlp();
    g.nodes[1].name = "x".to_string(); // y masquerades as x
    assert_exactly(&lint_graph(&g), Code::DuplicateName);
}

#[test]
fn fa002_arity_mismatch() {
    let mut g = mlp();
    let relu = node(&g, "relu");
    let x = node(&g, "x");
    g.nodes[relu].args.push(x); // unary op with two inputs
    assert_exactly(&lint_graph(&g), Code::ArityMismatch);
}

#[test]
fn fa003_dtype_violation() {
    let mut g = Graph::new();
    let t = g.placeholder("tok", Shape::of(&[4, 16]), DType::I32);
    g.op("r", OpKind::Relu, &[t]).unwrap(); // f32 math over token ids
    assert_exactly(&lint_graph(&g), Code::DtypeViolation);
}

#[test]
fn fa004_shape_incoherent() {
    let mut g = mlp();
    let relu = node(&g, "relu");
    g.set_shape(relu, Shape::of(&[99]), DType::F32); // stale after a "rewrite"
    assert_exactly(&lint_graph(&g), Code::ShapeIncoherent);
}

#[test]
fn fa005_dangling_input() {
    let mut g = mlp();
    let relu = node(&g, "relu");
    g.nodes[relu].args = vec![99]; // reads a node that does not exist
    assert_exactly(&lint_graph(&g), Code::DanglingInput);
}

#[test]
fn fa006_unreachable_node_is_a_warning() {
    let mut g = mlp();
    let x = node(&g, "x");
    g.op("dead", OpKind::Gelu, &[x]).unwrap(); // never reaches the loss
    let report = lint_graph(&g);
    assert!(report.has(Code::UnreachableNode), "{}", report.render());
    assert!(report.error_codes().is_empty(), "dead code must stay a warning");
}

#[test]
fn fa007_backward_cross_stage_edge() {
    let mut g = Graph::new();
    let x = g.placeholder("x", Shape::of(&[2, 4]), DType::F32);
    let a = g.op("a", OpKind::Relu, &[x]).unwrap();
    let b = g.op("b", OpKind::Gelu, &[a]).unwrap();
    g.set_kwarg(x, SUBGRAPH_KEY, "1");
    g.set_kwarg(a, SUBGRAPH_KEY, "1");
    g.set_kwarg(b, SUBGRAPH_KEY, "0"); // downstream node on an earlier stage
    assert_exactly(&lint_graph(&g), Code::StagePartition);
}

// ---------------------------------------------------------------- plan checks

#[test]
fn fa101_node_dropped_from_wave() {
    let g = mlp();
    let bwd = backward_plan(&g);
    let mut plan = ExecPlan::compile_full(&g, &bwd).unwrap();
    let popped = plan.waves.last_mut().unwrap().pop();
    assert!(popped.is_some());
    assert_exactly(&check_plan(&g, &bwd, &plan), Code::WavePartition);
}

#[test]
fn fa102_swapped_waves_break_topology() {
    let g = mlp();
    let bwd = backward_plan(&g);
    let mut plan = ExecPlan::compile_full(&g, &bwd).unwrap();
    // Swap relu's and fc2's waves: fc2 now runs before its input.
    let relu = node(&g, "relu");
    let fc2 = node(&g, "fc2");
    let w_relu = plan.waves.iter().position(|w| w.contains(&relu)).unwrap();
    let w_fc2 = plan.waves.iter().position(|w| w.contains(&fc2)).unwrap();
    plan.waves.swap(w_relu, w_fc2);
    assert_exactly(&check_plan(&g, &bwd, &plan), Code::WaveOrdering);
}

#[test]
fn fa103_inflated_fwd_uses() {
    let g = mlp();
    let bwd = backward_plan(&g);
    let mut plan = ExecPlan::compile_full(&g, &bwd).unwrap();
    plan.fwd_uses[node(&g, "x")] += 1; // over-count: leaks, never frees
    assert_exactly(&check_plan(&g, &bwd, &plan), Code::FwdUseCount);
}

#[test]
fn fa104_inflated_stash_uses() {
    let g = mlp();
    let bwd = backward_plan(&g);
    let mut plan = ExecPlan::compile_full(&g, &bwd).unwrap();
    let relu = node(&g, "relu");
    assert!(plan.stash_uses[relu] > 0, "fc2's VJP re-reads relu");
    plan.stash_uses[relu] += 1;
    assert_exactly(&check_plan(&g, &bwd, &plan), Code::StashUseCount);
}

#[test]
fn fa105_undercounted_refcount_is_use_after_free() {
    // Inference chain: every link has exactly one consumer.
    let mut g = Graph::new();
    let mut prev = g.placeholder("x", Shape::of(&[2, 8]), DType::F32);
    for i in 0..5 {
        prev = g.op(&format!("r{i}"), OpKind::Relu, &[prev]).unwrap();
    }
    let bwd = backward_plan(&g);
    let mut plan = ExecPlan::compile_full(&g, &bwd).unwrap();
    let r1 = node(&g, "r1");
    plan.fwd_uses[r1] = 0; // the runtime would free (or wrap) under r2's read
    assert_exactly(&check_plan(&g, &bwd, &plan), Code::UseAfterFree);
}

#[test]
fn fa106_loss_evicted_from_keep_set() {
    let g = mlp();
    let bwd = backward_plan(&g);
    let mut plan = ExecPlan::compile_full(&g, &bwd).unwrap();
    let loss = node(&g, "loss");
    plan.keep_always[loss] = false;
    plan.keep_after_fp[loss] = false; // loss must stay queryable all step
    assert_exactly(&check_plan(&g, &bwd, &plan), Code::KeepSetViolation);
}

#[test]
fn fa107_merged_bwd_waves() {
    let g = mlp();
    let bwd = backward_plan(&g);
    let mut plan = ExecPlan::compile_full(&g, &bwd).unwrap();
    assert!(plan.bwd_waves.len() >= 2);
    // Merge the last two backward waves: a task lands beside its grad source.
    let last = plan.bwd_waves.pop().unwrap();
    plan.bwd_waves.last_mut().unwrap().extend(last);
    let f = plan.bwd_wave_flops.pop().unwrap();
    *plan.bwd_wave_flops.last_mut().unwrap() += f;
    assert_exactly(&check_plan(&g, &bwd, &plan), Code::BwdOrdering);
}

// ------------------------------------------------------------ schedule checks

#[test]
fn fa201_cyclic_dependency_relation() {
    let s = MicrobatchSchedule::gpipe(2, 2);
    let report = check_schedule_with_deps(&s, |ev| {
        let mut d = s.deps(ev);
        // Forward of m0 additionally waits on its own backward: a cycle
        // with the real Backward → Forward stash dependency.
        if ev.kind == PipeEventKind::Forward && ev.microbatch == 0 {
            d.push(PipeEvent { stage: ev.stage, microbatch: 0, kind: PipeEventKind::Backward });
        }
        d
    });
    assert_exactly(&report, Code::DepsCycle);
}

#[test]
fn fa202_reordered_stage_list_deadlocks() {
    let mut s = MicrobatchSchedule::gpipe(1, 2);
    let evs = &mut s.per_stage[0];
    let f = evs.iter().position(|e| e.kind == PipeEventKind::Forward && e.microbatch == 1).unwrap();
    let b = evs.iter().position(|e| e.kind == PipeEventKind::Backward && e.microbatch == 1).unwrap();
    evs.swap(f, b); // backward before its own forward: acyclic, yet stuck
    assert_exactly(&check_schedule(&s), Code::ScheduleDeadlock);
}

#[test]
fn fa203_missing_backward_event() {
    let mut s = MicrobatchSchedule::gpipe(2, 3);
    s.per_stage[1].retain(|e| !(e.kind == PipeEventKind::Backward && e.microbatch == 1));
    assert_exactly(&check_schedule(&s), Code::MicrobatchCoverage);
}

// --------------------------------------------------- valid artifacts verify

#[test]
fn every_legitimate_artifact_verifies_clean() {
    // Graphs the system actually builds…
    for (name, g) in [
        ("mlp", mlp()),
        ("fig3", fig3::build()),
        ("transformer-tiny", TransformerConfig::tiny().build_graph()),
    ] {
        let report = lint_graph(&g);
        assert!(report.is_clean(), "{name}: {}", report.render());
        // …and every plan compiled from them, full and partitioned.
        let bwd = backward_plan(&g);
        let plan = ExecPlan::compile_full(&g, &bwd).unwrap();
        let report = check_plan(&g, &bwd, &plan);
        assert!(report.is_clean(), "{name} plan: {}", report.render());
    }
    let g = fig3::build();
    let bwd = backward_plan(&g);
    for sub in 1..=3 {
        let mut in_set = vec![false; g.len()];
        for (id, s) in fig3::paper_partition(&g) {
            in_set[id] = s == sub;
        }
        let plan = ExecPlan::compile(&g, &in_set, &bwd).unwrap();
        let report = check_plan(&g, &bwd, &plan);
        assert!(report.is_clean(), "fig3 sub {sub}: {}", report.render());
    }
    for (stages, micro) in [(1, 1), (2, 4), (4, 8)] {
        let s = MicrobatchSchedule::gpipe(stages, micro);
        let report = check_schedule(&s);
        assert!(report.is_clean(), "gpipe {stages}×{micro}: {}", report.render());
    }
}

// ------------------------------------------------------------------ properties

const B: usize = 8;
const D: usize = 16;

/// Random shape-preserving DAG ending in `MseLoss(Linear(last), target)` —
/// the same family the wavefront bitwise tests use.
fn random_dag(gn: &mut Gen) -> Graph {
    let mut g = Graph::new();
    let x = g.placeholder("x", Shape::of(&[B, D]), DType::F32);
    let mut pool = vec![x];
    let n_ops = gn.usize(4, 12);
    for i in 0..n_ops {
        let a = *gn.choose(&pool);
        let id = match gn.usize(0, 6) {
            0 => g.op(&format!("relu{i}"), OpKind::Relu, &[a]).unwrap(),
            1 => g.op(&format!("gelu{i}"), OpKind::Gelu, &[a]).unwrap(),
            2 => g.op(&format!("ln{i}"), OpKind::LayerNorm { dim: D }, &[a]).unwrap(),
            3 => g
                .op(
                    &format!("fc{i}"),
                    OpKind::Linear { in_features: D, out_features: D, bias: true },
                    &[a],
                )
                .unwrap(),
            4 => {
                let b = *gn.choose(&pool);
                g.op(&format!("add{i}"), OpKind::Add, &[a, b]).unwrap()
            }
            _ => {
                let b = *gn.choose(&pool);
                g.op(&format!("mul{i}"), OpKind::Multiply, &[a, b]).unwrap()
            }
        };
        pool.push(id);
    }
    let head = g
        .op(
            "head",
            OpKind::Linear { in_features: D, out_features: D, bias: true },
            &[*pool.last().unwrap()],
        )
        .unwrap();
    let target = g.placeholder("target", Shape::of(&[B, D]), DType::F32);
    g.op("loss", OpKind::MseLoss, &[head, target]).unwrap();
    g
}

#[test]
fn prop_random_dags_lint_clean_after_standard_pipeline() {
    check("lint-clean-after-standard", 40, |gn| {
        let mut g = random_dag(gn);
        PassManager::standard().run(&mut g).map_err(|e| e.to_string())?;
        let report = lint_graph(&g);
        if report.is_clean() {
            Ok(())
        } else {
            Err(report.render())
        }
    });
}

#[test]
fn prop_any_single_fwd_uses_mutation_is_caught() {
    check("fwd-uses-mutation-caught", 15, |gn| {
        let g = random_dag(gn);
        let bwd = backward_plan(&g);
        let plan = ExecPlan::compile_full(&g, &bwd).unwrap();
        if check_plan(&g, &bwd, &plan).has_errors() {
            return Err("pristine plan must verify".into());
        }
        for id in 0..g.len() {
            let mut broken = plan.clone();
            broken.fwd_uses[id] += 1;
            if !check_plan(&g, &bwd, &broken).has_errors() {
                return Err(format!("fwd_uses[{id}] += 1 went unnoticed"));
            }
            if plan.fwd_uses[id] > 0 {
                let mut broken = plan.clone();
                broken.fwd_uses[id] -= 1;
                if !check_plan(&g, &bwd, &broken).has_errors() {
                    return Err(format!("fwd_uses[{id}] -= 1 went unnoticed"));
                }
            }
        }
        Ok(())
    });
}
