//! Property-based tests over the coordinator's core invariants
//! (DESIGN.md §7): scheduler, DAG/decomposition, autodiff, DHT,
//! compression and pipeline-schedule properties, each over hundreds of
//! seeded random cases via the in-tree `proptesting` harness.

use fusionai::compress::{topk, Codec};
use fusionai::dag::autodiff::backward_plan;
use fusionai::dag::{DType, Graph, OpCategory, OpKind, Shape};
use fusionai::decompose::Decomposition;
use fusionai::dht::Dht;
use fusionai::models::transformer::TransformerConfig;
use fusionai::perf::gpus::GPU_DB;
use fusionai::pipeline::schedule::{MicrobatchSchedule, PipeEventKind};
use fusionai::proptesting::{check, Gen};
use fusionai::sched::{self, PeerSpec, TaskSpec};

fn random_tasks(g: &mut Gen, n: usize) -> Vec<TaskSpec> {
    (0..n)
        .map(|id| TaskSpec {
            id,
            flops: g.f64(1e9, 1e13),
            gpu_bytes: g.usize(1, 1 << 28) as u64,
            cpu_bytes: g.usize(1, 1 << 28) as u64,
            disk_bytes: g.usize(1, 1 << 28) as u64,
        })
        .collect()
}

fn random_peers(g: &mut Gen, n: usize) -> Vec<PeerSpec> {
    (0..n)
        .map(|id| {
            let gpu = g.choose(GPU_DB);
            let mut p = sched::build::uniform_peers(gpu, g.f64(0.2, 0.9), 1).remove(0);
            p.id = id;
            p
        })
        .collect()
}

#[test]
fn prop_schedule_respects_all_constraints() {
    check("schedule-constraints", 150, |g| {
        let nt = g.usize(1, 40);
        let np = g.usize(1, 12);
        let tasks = random_tasks(g, nt);
        let peers = random_peers(g, np);
        match sched::schedule(&tasks, &peers) {
            Ok(s) => {
                s.validate(&tasks, &peers).map_err(|e| e)?;
                // Makespan bounds: ≥ the largest single task on the fastest
                // peer; ≤ serial time on the slowest peer.
                let fastest = peers
                    .iter()
                    .map(|p| p.profile.achieved_flops())
                    .fold(0.0f64, f64::max);
                let slowest = peers
                    .iter()
                    .map(|p| p.profile.achieved_flops())
                    .fold(f64::INFINITY, f64::min);
                let lb = tasks.iter().map(|t| t.flops).fold(0.0f64, f64::max) / fastest;
                let ub = tasks.iter().map(|t| t.flops).sum::<f64>() / slowest + 1e-9;
                if s.makespan() < lb - 1e-9 {
                    return Err(format!("makespan {} below lower bound {lb}", s.makespan()));
                }
                if s.makespan() > ub {
                    return Err(format!("makespan {} above serial bound {ub}", s.makespan()));
                }
                Ok(())
            }
            // Infeasible is legal when memory genuinely doesn't fit anywhere.
            Err(_) => Ok(()),
        }
    });
}

#[test]
fn prop_reschedule_preserves_validity() {
    check("reschedule-validity", 100, |g| {
        let nt = g.usize(2, 30);
        let np = g.usize(3, 10);
        let tasks = random_tasks(g, nt);
        let peers = random_peers(g, np);
        let Ok(mut s) = sched::schedule(&tasks, &peers) else { return Ok(()) };
        let failed = g.usize(0, peers.len());
        match sched::reschedule_failure(&mut s, &tasks, &peers, failed, None) {
            Ok(_) => {
                s.validate(&tasks, &peers).map_err(|e| e)?;
                if s.of_task.iter().any(|&p| p == failed) {
                    return Err("task left on failed peer".into());
                }
                Ok(())
            }
            Err(_) => Ok(()), // survivors genuinely can't hold it
        }
    });
}

#[test]
fn prop_decomposition_partitions_exactly() {
    check("decomposition-partition", 60, |g| {
        let cfg = TransformerConfig {
            name: "rand".into(),
            vocab: 64 << g.usize(0, 3),
            seq: 8 << g.usize(0, 2),
            batch: 1 + g.usize(0, 3),
            layers: 1 + g.usize(0, 5),
            dim: 16 << g.usize(0, 2),
            heads: 2,
            ffn_hidden: 32,
            causal: g.bool(0.5),
            lm_head: g.bool(0.5),
        };
        let graph = cfg.build_graph();
        let k = 1 + g.usize(0, graph.len().min(20));
        let d = Decomposition::chain_balanced(&graph, k);
        d.validate(&graph)?;
        // Cut edges = exactly the cross-subgraph edges.
        let cuts = d.cut_edges(&graph);
        for &(a, b) in &cuts {
            if d.of_node[a] == d.of_node[b] {
                return Err("cut edge within one subgraph".into());
            }
        }
        let mut expected = 0;
        for node in &graph.nodes {
            for &a in &node.args {
                if d.of_node[a] != d.of_node[node.id] {
                    expected += 1;
                }
            }
        }
        if cuts.len() != expected {
            return Err(format!("{} cuts vs {} cross edges", cuts.len(), expected));
        }
        // Chain property: cuts only flow forward.
        for (a, b) in cuts {
            if d.of_node[a] > d.of_node[b] {
                return Err("backward cut in chain decomposition".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_autodiff_covers_exactly_the_grad_flow() {
    check("autodiff-coverage", 60, |g| {
        let cfg = TransformerConfig::tiny();
        let graph = cfg.build_graph();
        let plan = backward_plan(&graph);
        let _ = g.int(0, 2);
        for node in &graph.nodes {
            let has_task = plan.task(node.id).is_some();
            match node.kind.category() {
                OpCategory::Placeholder => {
                    if has_task {
                        return Err(format!("placeholder {} got a bwd task", node.name));
                    }
                }
                OpCategory::Parametric | OpCategory::Variable => {
                    if !has_task {
                        return Err(format!("trainable {} lacks a bwd task", node.name));
                    }
                    if !plan.task(node.id).unwrap().wants_param_grad {
                        return Err(format!("{} missing param grad", node.name));
                    }
                }
                _ => {}
            }
        }
        Ok(())
    });
}

#[test]
fn prop_dht_get_after_put_under_churn() {
    check("dht-churn", 80, |g| {
        let repl = g.usize(2, 4);
        let mut dht = Dht::new(repl);
        let n0 = g.usize(repl + 1, 12);
        for p in 0..n0 {
            dht.join(p).unwrap();
        }
        let n_keys = g.usize(5, 50);
        for i in 0..n_keys {
            dht.put(&format!("k{i}"), vec![i as u8]).unwrap();
        }
        // Random churn: kill up to repl−1 peers, add a few.
        let kills = g.usize(0, repl);
        for k in 0..kills {
            let peers = dht.peers();
            if peers.len() <= 1 {
                break;
            }
            let victim = *g.choose(&peers);
            dht.leave(victim).unwrap();
            let _ = k;
        }
        for j in 0..g.usize(0, 3) {
            dht.join(100 + j).unwrap();
        }
        for i in 0..n_keys {
            let v = dht
                .get(&format!("k{i}"))
                .map_err(|e| format!("lost k{i}: {e}"))?;
            if v != [i as u8] {
                return Err(format!("k{i} corrupted"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_codecs_roundtrip_contracts() {
    check("codec-contracts", 150, |g| {
        let n = g.usize(1, 4096);
        let scale = g.f64(0.01, 100.0) as f32;
        let x = g.vec_f32(n, scale);
        // Raw: exact.
        let c = Codec::None;
        if c.decode(&c.encode(&x), n) != x {
            return Err("raw roundtrip not exact".into());
        }
        // Int8: bounded error, exact wire size.
        let c = Codec::Int8;
        let enc = c.encode(&x);
        if enc.len() as u64 != c.wire_bytes(n) {
            return Err("int8 wire size mismatch".into());
        }
        let y = c.decode(&enc, n);
        let amax = x.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        let bound = amax / 127.0 / 2.0 + 1e-6;
        for (a, b) in x.iter().zip(&y) {
            if (a - b).abs() > bound {
                return Err(format!("int8 error {} > bound {bound}", (a - b).abs()));
            }
        }
        // TopK: preserves the k largest exactly, zeroes the rest.
        let ratio = g.f64(0.01, 1.0);
        let c = Codec::TopK { ratio };
        let y = c.decode(&c.encode(&x), n);
        let kept = topk(&x, ratio);
        for (i, v) in &kept {
            if y[*i] != *v {
                return Err("topk lost a kept value".into());
            }
        }
        let kept_set: std::collections::HashSet<usize> =
            kept.iter().map(|&(i, _)| i).collect();
        for (i, &v) in y.iter().enumerate() {
            if !kept_set.contains(&i) && v != 0.0 {
                return Err("topk leaked a non-kept value".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_gpipe_schedule_dependencies_hold() {
    check("gpipe-deps", 80, |g| {
        let stages = g.usize(1, 6);
        let mbs = g.usize(1, 10);
        let s = MicrobatchSchedule::gpipe(stages, mbs);
        // Per-stage: every Forward precedes every Backward of the same mb,
        // Update is last.
        for evs in &s.per_stage {
            let pos = |kind: PipeEventKind, mb: usize| {
                evs.iter().position(|e| e.kind == kind && e.microbatch == mb)
            };
            for mb in 0..mbs {
                let f = pos(PipeEventKind::Forward, mb).ok_or("missing fwd")?;
                let b = pos(PipeEventKind::Backward, mb).ok_or("missing bwd")?;
                if f >= b {
                    return Err(format!("fwd {f} after bwd {b}"));
                }
            }
            if evs.last().unwrap().kind != PipeEventKind::Update {
                return Err("update not last".into());
            }
        }
        // Simulated makespan matches the GPipe closed form for equal costs.
        let t = s.simulate(1.0, 1.0, 0.0);
        let expect = (mbs as f64 + stages as f64 - 1.0) * 2.0;
        if (t - expect).abs() > 1e-9 {
            return Err(format!("makespan {t} vs closed form {expect}"));
        }
        Ok(())
    });
}

#[test]
fn prop_graph_shape_inference_total() {
    // Arbitrary small op chains never produce inconsistent shapes.
    check("shape-inference", 120, |g| {
        let mut graph = Graph::new();
        let b = g.usize(1, 4);
        let f = 4 << g.usize(0, 3);
        let mut cur =
            graph.placeholder("in", Shape::of(&[b, f]), DType::F32);
        let depth = g.usize(1, 8);
        for i in 0..depth {
            let cur_f = *graph.node(cur).out_shape.dims().last().unwrap();
            let kind = match g.usize(0, 4) {
                0 => OpKind::Relu,
                1 => OpKind::Gelu,
                2 => OpKind::Softmax,
                _ => OpKind::Linear {
                    in_features: cur_f,
                    out_features: 4 << g.usize(0, 3),
                    bias: g.bool(0.5),
                },
            };
            cur = graph.op(&format!("op{i}"), kind, &[cur]).map_err(|e| e.to_string())?;
        }
        graph.topo_order().map_err(|e| e.to_string())?;
        if graph.node(cur).out_shape.dims()[0] != b {
            return Err("batch dim changed".into());
        }
        Ok(())
    });
}
