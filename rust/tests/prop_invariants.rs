//! Property-based tests over the coordinator's core invariants
//! (DESIGN.md §7): scheduler, DAG/decomposition, autodiff, DHT,
//! compression and pipeline-schedule properties, each over hundreds of
//! seeded random cases via the in-tree `proptesting` harness.

use fusionai::compress::{topk, Codec};
use fusionai::dag::autodiff::backward_plan;
use fusionai::dag::{DType, Graph, OpCategory, OpKind, PassManager, Shape};
use fusionai::decompose::Decomposition;
use fusionai::dht::Dht;
use fusionai::exec::{Engine, RefEngine};
use fusionai::models::transformer::TransformerConfig;
use fusionai::perf::gpus::GPU_DB;
use fusionai::pipeline::schedule::{MicrobatchSchedule, PipeEventKind};
use fusionai::proptesting::{check, Gen};
use fusionai::sched::{self, PeerSpec, TaskSpec};
use fusionai::tensor::Tensor;
use fusionai::util::Rng;

fn random_tasks(g: &mut Gen, n: usize) -> Vec<TaskSpec> {
    (0..n)
        .map(|id| TaskSpec {
            id,
            flops: g.f64(1e9, 1e13),
            gpu_bytes: g.usize(1, 1 << 28) as u64,
            cpu_bytes: g.usize(1, 1 << 28) as u64,
            disk_bytes: g.usize(1, 1 << 28) as u64,
        })
        .collect()
}

fn random_peers(g: &mut Gen, n: usize) -> Vec<PeerSpec> {
    (0..n)
        .map(|id| {
            let gpu = g.choose(GPU_DB);
            let mut p = sched::build::uniform_peers(gpu, g.f64(0.2, 0.9), 1).remove(0);
            p.id = id;
            p
        })
        .collect()
}

#[test]
fn prop_schedule_respects_all_constraints() {
    check("schedule-constraints", 150, |g| {
        let nt = g.usize(1, 40);
        let np = g.usize(1, 12);
        let tasks = random_tasks(g, nt);
        let peers = random_peers(g, np);
        match sched::schedule(&tasks, &peers) {
            Ok(s) => {
                s.validate(&tasks, &peers).map_err(|e| e)?;
                // Makespan bounds: ≥ the largest single task on the fastest
                // peer; ≤ serial time on the slowest peer.
                let fastest = peers
                    .iter()
                    .map(|p| p.profile.achieved_flops())
                    .fold(0.0f64, f64::max);
                let slowest = peers
                    .iter()
                    .map(|p| p.profile.achieved_flops())
                    .fold(f64::INFINITY, f64::min);
                let lb = tasks.iter().map(|t| t.flops).fold(0.0f64, f64::max) / fastest;
                let ub = tasks.iter().map(|t| t.flops).sum::<f64>() / slowest + 1e-9;
                if s.makespan() < lb - 1e-9 {
                    return Err(format!("makespan {} below lower bound {lb}", s.makespan()));
                }
                if s.makespan() > ub {
                    return Err(format!("makespan {} above serial bound {ub}", s.makespan()));
                }
                Ok(())
            }
            // Infeasible is legal when memory genuinely doesn't fit anywhere.
            Err(_) => Ok(()),
        }
    });
}

#[test]
fn prop_reschedule_preserves_validity() {
    check("reschedule-validity", 100, |g| {
        let nt = g.usize(2, 30);
        let np = g.usize(3, 10);
        let tasks = random_tasks(g, nt);
        let peers = random_peers(g, np);
        let Ok(mut s) = sched::schedule(&tasks, &peers) else { return Ok(()) };
        let failed = g.usize(0, peers.len());
        match sched::reschedule_failure(&mut s, &tasks, &peers, failed, None) {
            Ok(_) => {
                s.validate(&tasks, &peers).map_err(|e| e)?;
                if s.of_task.iter().any(|&p| p == failed) {
                    return Err("task left on failed peer".into());
                }
                Ok(())
            }
            Err(_) => Ok(()), // survivors genuinely can't hold it
        }
    });
}

#[test]
fn prop_decomposition_partitions_exactly() {
    check("decomposition-partition", 60, |g| {
        let cfg = TransformerConfig {
            name: "rand".into(),
            vocab: 64 << g.usize(0, 3),
            seq: 8 << g.usize(0, 2),
            batch: 1 + g.usize(0, 3),
            layers: 1 + g.usize(0, 5),
            dim: 16 << g.usize(0, 2),
            heads: 2,
            ffn_hidden: 32,
            causal: g.bool(0.5),
            lm_head: g.bool(0.5),
        };
        let graph = cfg.build_graph();
        let k = 1 + g.usize(0, graph.len().min(20));
        let d = Decomposition::chain_balanced(&graph, k);
        d.validate(&graph)?;
        // Cut edges = exactly the cross-subgraph edges.
        let cuts = d.cut_edges(&graph);
        for &(a, b) in &cuts {
            if d.of_node[a] == d.of_node[b] {
                return Err("cut edge within one subgraph".into());
            }
        }
        let mut expected = 0;
        for node in &graph.nodes {
            for &a in &node.args {
                if d.of_node[a] != d.of_node[node.id] {
                    expected += 1;
                }
            }
        }
        if cuts.len() != expected {
            return Err(format!("{} cuts vs {} cross edges", cuts.len(), expected));
        }
        // Chain property: cuts only flow forward.
        for (a, b) in cuts {
            if d.of_node[a] > d.of_node[b] {
                return Err("backward cut in chain decomposition".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_autodiff_covers_exactly_the_grad_flow() {
    check("autodiff-coverage", 60, |g| {
        let cfg = TransformerConfig::tiny();
        let graph = cfg.build_graph();
        let plan = backward_plan(&graph);
        let _ = g.int(0, 2);
        for node in &graph.nodes {
            let has_task = plan.task(node.id).is_some();
            match node.kind.category() {
                OpCategory::Placeholder => {
                    if has_task {
                        return Err(format!("placeholder {} got a bwd task", node.name));
                    }
                }
                OpCategory::Parametric | OpCategory::Variable => {
                    if !has_task {
                        return Err(format!("trainable {} lacks a bwd task", node.name));
                    }
                    if !plan.task(node.id).unwrap().wants_param_grad {
                        return Err(format!("{} missing param grad", node.name));
                    }
                }
                _ => {}
            }
        }
        Ok(())
    });
}

#[test]
fn prop_dht_get_after_put_under_churn() {
    check("dht-churn", 80, |g| {
        let repl = g.usize(2, 4);
        let mut dht = Dht::new(repl);
        let n0 = g.usize(repl + 1, 12);
        for p in 0..n0 {
            dht.join(p).unwrap();
        }
        let n_keys = g.usize(5, 50);
        for i in 0..n_keys {
            dht.put(&format!("k{i}"), vec![i as u8]).unwrap();
        }
        // Random churn: kill up to repl−1 peers, add a few.
        let kills = g.usize(0, repl);
        for k in 0..kills {
            let peers = dht.peers();
            if peers.len() <= 1 {
                break;
            }
            let victim = *g.choose(&peers);
            dht.leave(victim).unwrap();
            let _ = k;
        }
        for j in 0..g.usize(0, 3) {
            dht.join(100 + j).unwrap();
        }
        for i in 0..n_keys {
            let v = dht
                .get(&format!("k{i}"))
                .map_err(|e| format!("lost k{i}: {e}"))?;
            if v != [i as u8] {
                return Err(format!("k{i} corrupted"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_codecs_roundtrip_contracts() {
    check("codec-contracts", 150, |g| {
        let n = g.usize(1, 4096);
        let scale = g.f64(0.01, 100.0) as f32;
        let x = g.vec_f32(n, scale);
        // Raw: exact.
        let c = Codec::None;
        if c.decode(&c.encode(&x), n) != x {
            return Err("raw roundtrip not exact".into());
        }
        // Int8: bounded error, exact wire size.
        let c = Codec::Int8;
        let enc = c.encode(&x);
        if enc.len() as u64 != c.wire_bytes(n) {
            return Err("int8 wire size mismatch".into());
        }
        let y = c.decode(&enc, n);
        let amax = x.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        let bound = amax / 127.0 / 2.0 + 1e-6;
        for (a, b) in x.iter().zip(&y) {
            if (a - b).abs() > bound {
                return Err(format!("int8 error {} > bound {bound}", (a - b).abs()));
            }
        }
        // TopK: preserves the k largest exactly, zeroes the rest.
        let ratio = g.f64(0.01, 1.0);
        let c = Codec::TopK { ratio };
        let y = c.decode(&c.encode(&x), n);
        let kept = topk(&x, ratio);
        for (i, v) in &kept {
            if y[*i] != *v {
                return Err("topk lost a kept value".into());
            }
        }
        let kept_set: std::collections::HashSet<usize> =
            kept.iter().map(|&(i, _)| i).collect();
        for (i, &v) in y.iter().enumerate() {
            if !kept_set.contains(&i) && v != 0.0 {
                return Err("topk leaked a non-kept value".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_gpipe_schedule_dependencies_hold() {
    check("gpipe-deps", 80, |g| {
        let stages = g.usize(1, 6);
        let mbs = g.usize(1, 10);
        let s = MicrobatchSchedule::gpipe(stages, mbs);
        // Per-stage: every Forward precedes every Backward of the same mb,
        // Update is last.
        for evs in &s.per_stage {
            let pos = |kind: PipeEventKind, mb: usize| {
                evs.iter().position(|e| e.kind == kind && e.microbatch == mb)
            };
            for mb in 0..mbs {
                let f = pos(PipeEventKind::Forward, mb).ok_or("missing fwd")?;
                let b = pos(PipeEventKind::Backward, mb).ok_or("missing bwd")?;
                if f >= b {
                    return Err(format!("fwd {f} after bwd {b}"));
                }
            }
            if evs.last().unwrap().kind != PipeEventKind::Update {
                return Err("update not last".into());
            }
        }
        // Simulated makespan matches the GPipe closed form for equal costs.
        let t = s.simulate(1.0, 1.0, 0.0);
        let expect = (mbs as f64 + stages as f64 - 1.0) * 2.0;
        if (t - expect).abs() > 1e-9 {
            return Err(format!("makespan {t} vs closed form {expect}"));
        }
        Ok(())
    });
}

/// Build a random op chain over `[b, f]` with deliberate junk for the
/// pass pipeline to clean up: `Relu(Relu(x))` ladders (constant-foldable)
/// and a dead side branch, capped by an MSE loss.
fn random_messy_graph(g: &mut fusionai::proptesting::Gen) -> Graph {
    let mut graph = Graph::new();
    let b = g.usize(1, 4);
    let f = 4 << g.usize(0, 3);
    let mut cur = graph.placeholder("in", Shape::of(&[b, f]), DType::F32);
    let depth = g.usize(1, 6);
    for i in 0..depth {
        let cur_f = *graph.node(cur).out_shape.dims().last().unwrap();
        cur = match g.usize(0, 4) {
            0 => {
                // A foldable relu ladder.
                let r1 = graph.op(&format!("r{i}a"), OpKind::Relu, &[cur]).unwrap();
                graph.op(&format!("r{i}b"), OpKind::Relu, &[r1]).unwrap()
            }
            1 => graph.op(&format!("g{i}"), OpKind::Gelu, &[cur]).unwrap(),
            2 => graph.op(&format!("s{i}"), OpKind::Softmax, &[cur]).unwrap(),
            _ => graph
                .op(
                    &format!("fc{i}"),
                    OpKind::Linear {
                        in_features: cur_f,
                        out_features: 4 << g.usize(0, 3),
                        bias: g.bool(0.5),
                    },
                    &[cur],
                )
                .unwrap(),
        };
        if g.bool(0.3) {
            // Dead side branch: produced, never consumed, not a loss.
            graph.op(&format!("dead{i}"), OpKind::Relu, &[cur]).unwrap();
        }
    }
    let out_f = *graph.node(cur).out_shape.dims().last().unwrap();
    let target = graph.placeholder("target", Shape::of(&[b, out_f]), DType::F32);
    graph.op("loss", OpKind::MseLoss, &[cur, target]).unwrap();
    graph
}

#[test]
fn prop_standard_pipeline_is_idempotent() {
    check("pass-idempotence", 60, |g| {
        let mut graph = random_messy_graph(g);
        PassManager::standard().run(&mut graph).map_err(|e| e.to_string())?;
        let first = graph.to_json();
        let report = PassManager::standard().run(&mut graph).map_err(|e| e.to_string())?;
        if report.changed() {
            return Err("second standard run still reported changes".into());
        }
        if graph.to_json() != first {
            return Err("second standard run altered the graph".into());
        }
        Ok(())
    });
}

#[test]
fn prop_dce_leaves_valid_loss_reaching_graph() {
    check("dce-topo-validity", 60, |g| {
        let mut graph = random_messy_graph(g);
        let had = graph.len();
        PassManager::standard().run(&mut graph).map_err(|e| e.to_string())?;
        // Still a valid graph (dense ids, consistent users, acyclic).
        PassManager::validation().run(&mut graph).map_err(|e| e.to_string())?;
        if graph.loss_nodes().is_empty() {
            return Err("DCE dropped the loss".into());
        }
        if graph.by_name("in").is_none() {
            return Err("DCE dropped the live input".into());
        }
        // Dead branches and folded relu ladders must actually be gone:
        // every non-placeholder sink is a loss node.
        for node in &graph.nodes {
            if graph.users(node.id).is_empty()
                && !matches!(node.kind, OpKind::MseLoss | OpKind::CrossEntropy { .. })
                && node.kind.category() != OpCategory::Placeholder
            {
                return Err(format!("non-loss sink '{}' survived DCE", node.name));
            }
        }
        if graph.len() > had {
            return Err("passes grew the graph".into());
        }
        Ok(())
    });
}

#[test]
fn prop_kernel_vjp_agrees_with_finite_differences() {
    // Randomized spot-check of registry kernels through the public Engine
    // trait: analytic input gradients vs central differences on Σ w∘y.
    check("kernel-vjp-fd", 40, |g| {
        let b = g.usize(1, 3);
        let f = 2 + g.usize(0, 5);
        let kind = match g.usize(0, 5) {
            0 => OpKind::Relu,
            1 => OpKind::Gelu,
            2 => OpKind::Softmax,
            3 => OpKind::LayerNorm { dim: f },
            _ => OpKind::Linear {
                in_features: f,
                out_features: 2 + g.usize(0, 4),
                bias: g.bool(0.5),
            },
        };
        let mut graph = Graph::new();
        let x = graph.placeholder("x", Shape::of(&[b, f]), DType::F32);
        let id = graph.op("op", kind, &[x]).unwrap();
        let node = graph.node(id).clone();

        let mut eng = RefEngine::new();
        let mut rng = Rng::new(g.seed);
        let params = eng.init_params(&node, &mut rng).map_err(|e| e.to_string())?;
        // Nudge inputs away from relu's kink at 0.
        let xs = Tensor::from_vec(
            &[b, f],
            g.vec_f32(b * f, 1.0).iter().map(|&v| v + 0.05 * v.signum()).collect(),
        );
        let w = Tensor::from_vec(node.out_shape.dims(), g.vec_f32(node.out_shape.numel(), 1.0));

        let bwd = eng.backward(&node, &[&xs], &params, Some(&w)).map_err(|e| e.to_string())?;
        let analytic = bwd.input_grads[0].as_ref().ok_or("no input grad")?;

        let loss = |eng: &mut RefEngine, t: &Tensor| -> Result<f32, String> {
            let y = eng.forward(&node, &[t], &params).map_err(|e| e.to_string())?;
            Ok(y.f().iter().zip(w.f()).map(|(a, b)| a * b).sum())
        };
        const H: f32 = 1e-2;
        for probe in 0..4 {
            let idx = (probe * 2654435761usize) % (b * f);
            let mut p = xs.clone();
            p.f_mut()[idx] += H;
            let mut m = xs.clone();
            m.f_mut()[idx] -= H;
            let fd = (loss(&mut eng, &p)? - loss(&mut eng, &m)?) / (2.0 * H);
            let an = analytic.f()[idx];
            if (fd - an).abs() > 4e-2 * (1.0 + fd.abs().max(an.abs())) {
                return Err(format!("{}: fd {fd} vs analytic {an} at {idx}", node.kind.name()));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_blocked_and_threaded_gemm_match_naive_bitwise() {
    // The blocked/packed GEMMs and their row-partitioned threaded variants
    // must be *bitwise* equal to the retained naive loops: every output
    // element is one ascending-k accumulation chain in every code path
    // (DESIGN.md §Perf determinism contract). Shapes deliberately straddle
    // the MR=4 / NR=16 tile boundaries and push k past the packing panel.
    use fusionai::tensor::{
        matmul, matmul_at, matmul_at_into_threaded, matmul_bt, matmul_bt_into_threaded,
        matmul_into_threaded, naive,
    };
    check("gemm-bitwise", 60, |g| {
        let m = g.usize(1, 10);
        let n = g.usize(1, 48);
        let k = g.usize(1, 520);
        let a = g.vec_f32(m * k, 1.0);
        let b = g.vec_f32(k * n, 1.0);
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();

        // C = A·B
        let want = naive::matmul(&a, &b, m, k, n);
        let got = matmul(&a, &b, m, k, n);
        if bits(&want) != bits(&got) {
            return Err(format!("blocked matmul != naive at m={m} k={k} n={n}"));
        }
        let threads = g.usize(1, 5);
        let mut c = vec![0.0f32; m * n];
        matmul_into_threaded(&a, &b, &mut c, m, k, n, threads);
        if bits(&want) != bits(&c) {
            return Err(format!("threaded({threads}) matmul != naive at m={m} k={k} n={n}"));
        }

        // C = A·Bᵀ  (b_t is [n, k])
        let b_t = g.vec_f32(n * k, 1.0);
        let want = naive::matmul_bt(&a, &b_t, m, k, n);
        let got = matmul_bt(&a, &b_t, m, k, n);
        if bits(&want) != bits(&got) {
            return Err(format!("blocked matmul_bt != naive at m={m} k={k} n={n}"));
        }
        matmul_bt_into_threaded(&a, &b_t, &mut c, m, k, n, threads);
        if bits(&want) != bits(&c) {
            return Err(format!("threaded({threads}) matmul_bt != naive at m={m} k={k} n={n}"));
        }

        // C = Aᵀ·B  (a_t is [k, m])
        let a_t = g.vec_f32(k * m, 1.0);
        let want = naive::matmul_at(&a_t, &b, m, k, n);
        let got = matmul_at(&a_t, &b, m, k, n);
        if bits(&want) != bits(&got) {
            return Err(format!("blocked matmul_at != naive at m={m} k={k} n={n}"));
        }
        matmul_at_into_threaded(&a_t, &b, &mut c, m, k, n, threads);
        if bits(&want) != bits(&c) {
            return Err(format!("threaded({threads}) matmul_at != naive at m={m} k={k} n={n}"));
        }
        Ok(())
    });
}

#[test]
fn prop_graph_shape_inference_total() {
    // Arbitrary small op chains never produce inconsistent shapes.
    check("shape-inference", 120, |g| {
        let mut graph = Graph::new();
        let b = g.usize(1, 4);
        let f = 4 << g.usize(0, 3);
        let mut cur =
            graph.placeholder("in", Shape::of(&[b, f]), DType::F32);
        let depth = g.usize(1, 8);
        for i in 0..depth {
            let cur_f = *graph.node(cur).out_shape.dims().last().unwrap();
            let kind = match g.usize(0, 4) {
                0 => OpKind::Relu,
                1 => OpKind::Gelu,
                2 => OpKind::Softmax,
                _ => OpKind::Linear {
                    in_features: cur_f,
                    out_features: 4 << g.usize(0, 3),
                    bias: g.bool(0.5),
                },
            };
            cur = graph.op(&format!("op{i}"), kind, &[cur]).map_err(|e| e.to_string())?;
        }
        graph.topo_order().map_err(|e| e.to_string())?;
        if graph.node(cur).out_shape.dims()[0] != b {
            return Err("batch dim changed".into());
        }
        Ok(())
    });
}
