//! Integration: broker + decomposer + scheduler + compnode executors +
//! simulated WAN, end-to-end on the pure-rust engine.

use std::sync::Arc;

use fusionai::broker::{Broker, NodeClass};
use fusionai::cluster::sim::required_feeds;
use fusionai::cluster::SimCluster;
use fusionai::decompose::Decomposition;
use fusionai::exec::{Adam, RefEngine};
use fusionai::models::transformer::TransformerConfig;
use fusionai::net::{NetworkSim, Topology};
use fusionai::perf::comm::LinkModel;
use fusionai::perf::gpus::lookup;
use fusionai::tensor::Tensor;

fn tiny_cluster(stages: usize, link: LinkModel) -> (TransformerConfig, SimCluster) {
    let cfg = TransformerConfig::tiny();
    let g = cfg.build_graph();
    let d = Decomposition::chain_balanced(&g, stages);
    let net = Arc::new(NetworkSim::new(Topology::uniform(link), 0.0));
    let cluster = SimCluster::new(
        g,
        d,
        net,
        Box::new(|| Box::new(RefEngine::new())),
        Box::new(|| Box::new(Adam::new(0.01))),
        11,
    )
    .unwrap();
    (cfg, cluster)
}

fn feed(cfg: &TransformerConfig, c: &mut SimCluster) {
    let tokens: Vec<i32> =
        (0..cfg.batch * cfg.seq).map(|i| ((i * 11 + 5) % cfg.vocab) as i32).collect();
    let labels: Vec<i32> =
        tokens.iter().map(|&t| ((t as usize + 11) % cfg.vocab) as i32).collect();
    c.feed("tokens", Tensor::from_ivec(&[cfg.batch, cfg.seq], tokens)).unwrap();
    c.feed("labels", Tensor::from_ivec(&[cfg.batch, cfg.seq], labels)).unwrap();
}

#[test]
fn transformer_trains_across_four_compnodes() {
    let (cfg, mut cluster) = tiny_cluster(4, LinkModel::from_ms_mbps(10.0, 100.0));
    let mut first = None;
    let mut last = f32::NAN;
    for _ in 0..25 {
        feed(&cfg, &mut cluster);
        let r = cluster.train_step().unwrap();
        let l = r.loss.unwrap();
        assert!(l.is_finite());
        first.get_or_insert(l);
        last = l;
        assert!(r.comm_bytes > 0, "pipeline must move activations");
    }
    assert!(last < first.unwrap() * 0.9, "loss {first:?} → {last}");
}

#[test]
fn stage_count_does_not_change_numerics() {
    // Same seed ⇒ same init ⇒ same first-step loss regardless of partition.
    let losses: Vec<f32> = [1usize, 2, 4]
        .iter()
        .map(|&k| {
            let (cfg, mut cluster) = tiny_cluster(k, LinkModel::local());
            feed(&cfg, &mut cluster);
            cluster.train_step().unwrap().loss.unwrap()
        })
        .collect();
    // Init order differs per executor RNG consumption, so exact equality
    // isn't guaranteed — but all must be near ln(vocab) for an untrained LM.
    let expect = (256f32).ln();
    for l in losses {
        assert!((l - expect).abs() < 0.5, "loss {l} vs ln(V) {expect}");
    }
}

#[test]
fn comm_time_scales_with_link_quality() {
    let (cfg, mut fast) = tiny_cluster(4, LinkModel::from_ms_mbps(1.0, 1000.0));
    let (_, mut slow) = tiny_cluster(4, LinkModel::from_ms_mbps(50.0, 10.0));
    feed(&cfg, &mut fast);
    feed(&cfg, &mut slow);
    let rf = fast.train_step().unwrap();
    let rs = slow.train_step().unwrap();
    assert_eq!(rf.comm_bytes, rs.comm_bytes, "same data either way");
    assert!(rs.comm_seconds > 10.0 * rf.comm_seconds);
}

#[test]
fn broker_schedules_submitted_job_over_fleet() {
    let mut broker = Broker::new(5.0);
    for gpu in ["RTX 3080", "RTX 3070", "RTX 3060", "RTX 4090"] {
        broker.register(lookup(gpu).unwrap(), 0.5, NodeClass::Antnode, 0.0, false);
    }
    // Homogeneous-ish task sizes (no dominating LM head) so the
    // speed-proportionality assertion below is meaningful.
    let mut cfg = TransformerConfig::tiny();
    cfg.layers = 6;
    cfg.lm_head = false;
    let g = cfg.build_graph();
    let job = broker.submit_job(g, 24, true).unwrap();
    let job = broker.job(job).unwrap();
    // Faster devices must carry at least as much load as slower ones.
    let load_of = |gpu: &str| -> f64 {
        let id = job
            .peer_ids
            .iter()
            .position(|&p| broker.info(p).unwrap().gpu.name == gpu)
            .unwrap();
        job.schedule.loads[id]
    };
    let l4090 = load_of("RTX 4090");
    let l3060 = load_of("RTX 3060");
    // makespan-balanced: loads should be comparable, so the 4090 must hold
    // MORE work (more flops) — check via assigned task flops.
    let flops_of = |gpu: &str| -> f64 {
        let idx = job
            .peer_ids
            .iter()
            .position(|&p| broker.info(p).unwrap().gpu.name == gpu)
            .unwrap();
        job.tasks
            .iter()
            .enumerate()
            .filter(|(t, _)| job.schedule.of_task[*t] == idx)
            .map(|(_, task)| task.flops)
            .sum()
    };
    assert!(flops_of("RTX 4090") > flops_of("RTX 3060"));
    // Loads (times) should be within 3× of each other after balancing.
    assert!(l4090 < 3.0 * l3060.max(1e-12) + 1e-9 || l3060 == 0.0);
}

#[test]
fn inference_only_path() {
    let (cfg, mut cluster) = tiny_cluster(3, LinkModel::local());
    feed(&cfg, &mut cluster);
    let logits = cluster.infer("lm_head").unwrap();
    assert_eq!(logits.shape(), &[cfg.batch, cfg.seq, cfg.vocab]);
    assert!(logits.f().iter().all(|v| v.is_finite()));
}

#[test]
fn required_feeds_reported() {
    let g = TransformerConfig::tiny().build_graph();
    assert_eq!(required_feeds(&g), vec!["tokens".to_string(), "labels".to_string()]);
}

#[test]
fn network_accounting_matches_reports() {
    let (cfg, mut cluster) = tiny_cluster(4, LinkModel::from_ms_mbps(10.0, 100.0));
    feed(&cfg, &mut cluster);
    let r = cluster.train_step().unwrap();
    let net_total = cluster.network().total_remote_bytes();
    assert_eq!(net_total, r.comm_bytes);
}
