//! Integration over the REAL artifact path: AOT HLO text → PJRT → rust.
//!
//! Requires `make artifacts` (gpt-tiny + gpt-tiny-pallas). Tests skip with a
//! loud message when artifacts are absent so plain `cargo test` stays green
//! in a fresh checkout.

use std::path::Path;

use fusionai::cluster::{PipelineTrainer, TrainConfig};
use fusionai::compress::Codec;
use fusionai::exec::xla_engine::XlaEngine;
use fusionai::perf::comm::LinkModel;
use fusionai::serve::{run_trace, InferenceServer, Request};
use fusionai::tensor::Tensor;
use fusionai::util::Rng;

fn artifacts(preset: &str) -> Option<std::path::PathBuf> {
    let p = Path::new("artifacts").join(preset);
    if p.join("manifest.json").exists() {
        Some(p)
    } else {
        eprintln!("SKIP: artifacts/{preset} missing — run `make artifacts`");
        None
    }
}

#[test]
fn live_pipeline_training_reduces_loss() {
    let Some(dir) = artifacts("gpt-tiny") else { return };
    let mut cfg = TrainConfig::new(dir);
    cfg.steps = 60;
    cfg.microbatches = 2;
    cfg.link = LinkModel::from_ms_mbps(5.0, 1000.0);
    let trainer = PipelineTrainer::new(cfg).unwrap();
    let report = trainer.run().unwrap();
    assert_eq!(report.losses.len(), 60);
    let (_, l0) = report.losses.first().unwrap();
    let tail = report.losses.tail_mean(5);
    assert!(tail < l0 * 0.95, "loss {l0} → tail {tail}");
    assert!(report.comm_bytes > 0);
    assert!(report.tokens_per_second > 0.0);
}

#[test]
fn microbatch_count_only_changes_throughput_not_convergence() {
    let Some(dir) = artifacts("gpt-tiny") else { return };
    let run = |mb: usize| {
        let mut cfg = TrainConfig::new(dir.clone());
        cfg.steps = 30;
        cfg.microbatches = mb;
        PipelineTrainer::new(cfg).unwrap().run().unwrap()
    };
    let r1 = run(1);
    let r4 = run(4);
    // 4 microbatches see 4× the data per step: loss should drop at least
    // as much, and never diverge.
    assert!(r4.losses.tail_mean(5) <= r1.losses.first().unwrap().1);
    assert!(r4.losses.tail_mean(5).is_finite());
}

#[test]
fn compressed_pipeline_still_converges() {
    let Some(dir) = artifacts("gpt-tiny") else { return };
    let mut cfg = TrainConfig::new(dir);
    cfg.steps = 60;
    cfg.microbatches = 2;
    cfg.codec = Some(Codec::Int8);
    let trainer = PipelineTrainer::new(cfg).unwrap();
    let report = trainer.run().unwrap();
    let (_, l0) = report.losses.first().unwrap();
    let tail = report.losses.tail_mean(5);
    assert!(tail < l0 * 0.97, "int8-compressed training must still learn: {l0} → {tail}");
    // And the wire moved ~4× less than raw f32 would.
    let raw = PipelineTrainer::new({
        let mut c = TrainConfig::new(Path::new("artifacts/gpt-tiny").to_path_buf());
        c.steps = 60;
        c.microbatches = 2;
        c
    })
    .unwrap()
    .run()
    .unwrap();
    assert!(
        (report.comm_bytes as f64) < 0.35 * raw.comm_bytes as f64,
        "int8 {} vs raw {}",
        report.comm_bytes,
        raw.comm_bytes
    );
}

#[test]
fn pallas_artifacts_match_ref_artifacts() {
    // The SAME stage compiled two ways — attention via the L1 Pallas kernel
    // vs the pure-jnp reference — must produce near-identical outputs when
    // executed through PJRT by the rust runtime. This is the cross-layer
    // proof that the Pallas kernel is a drop-in for the reference math.
    let (Some(ref_dir), Some(pal_dir)) = (artifacts("gpt-tiny"), artifacts("gpt-tiny-pallas"))
    else {
        return;
    };
    let eng_ref = XlaEngine::load(&ref_dir).unwrap();
    let eng_pal = XlaEngine::load(&pal_dir).unwrap();
    let mut rng = Rng::new(33);
    let params = eng_ref.init_stage_params("block0", &mut rng).unwrap();
    let m = eng_ref.manifest();
    let (b, s, d) = (
        m.config_usize("batch").unwrap(),
        m.config_usize("seq").unwrap(),
        m.config_usize("dim").unwrap(),
    );
    let x = Tensor::randn(&[b, s, d], 1.0, &mut Rng::new(5));
    let y_ref = eng_ref.stage_forward("block0", &params, &[&x]).unwrap();
    let y_pal = eng_pal.stage_forward("block0", &params, &[&x]).unwrap();
    assert_eq!(y_ref.shape(), y_pal.shape());
    let max_diff = y_ref
        .f()
        .iter()
        .zip(y_pal.f())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 1e-4, "pallas vs ref artifact divergence {max_diff}");
}

#[test]
fn quantize_kernel_artifact_roundtrip() {
    // The L1 int8 quantization kernel, AOT-compiled, executed from rust.
    let Some(dir) = artifacts("gpt-tiny") else { return };
    let eng = XlaEngine::load(&dir).unwrap();
    let m = eng.manifest();
    let rows = m.config_usize("batch").unwrap() * m.config_usize("seq").unwrap();
    let dim = m.config_usize("dim").unwrap();
    let x = Tensor::randn(&[rows, dim], 1.0, &mut Rng::new(9));
    let out = eng.runtime().run("act_quant_roundtrip", &[x.clone()]).unwrap();
    let y = &out[0];
    assert_eq!(y.shape(), x.shape());
    // Error bound: half a quantization step per row.
    for (row_x, row_y) in x.f().chunks(dim).zip(y.f().chunks(dim)) {
        let amax = row_x.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        let bound = amax / 127.0 / 2.0 + 1e-6;
        for (a, b) in row_x.iter().zip(row_y) {
            assert!((a - b).abs() <= bound, "{a} vs {b} (bound {bound})");
        }
    }
}

#[test]
fn stage_backward_gradients_flow() {
    let Some(dir) = artifacts("gpt-tiny") else { return };
    let eng = XlaEngine::load(&dir).unwrap();
    let mut rng = Rng::new(1);
    let m = eng.manifest();
    let (b, s, d) = (
        m.config_usize("batch").unwrap(),
        m.config_usize("seq").unwrap(),
        m.config_usize("dim").unwrap(),
    );
    // head: loss + gradients
    let hp = eng.init_stage_params("head", &mut rng).unwrap();
    let h = Tensor::randn(&[b, s, d], 1.0, &mut rng);
    let labels = Tensor::from_ivec(&[b, s], (0..b * s).map(|i| (i % 256) as i32).collect());
    let (dx, dparams, loss) = eng.stage_backward("head", &hp, &[&h, &labels], None).unwrap();
    let loss = loss.unwrap();
    assert!((loss - (256f32).ln()).abs() < 1.5, "untrained CE ≈ ln(V), got {loss}");
    let dx = dx.unwrap();
    assert_eq!(dx.shape(), &[b, s, d]);
    assert!(dx.norm() > 0.0);
    assert_eq!(dparams.len(), hp.len());
    // update applies finite changes
    let mut params = hp.clone();
    let mut mm: Vec<Tensor> = params.iter().map(|p| Tensor::zeros(p.shape())).collect();
    let mut vv: Vec<Tensor> = params.iter().map(|p| Tensor::zeros(p.shape())).collect();
    eng.stage_update("head", &mut params, &dparams, &mut mm, &mut vv, 1).unwrap();
    let delta: f32 =
        params.iter().zip(&hp).map(|(a, b)| a.zip(b, |x, y| (x - y).abs()).sum()).sum();
    assert!(delta > 0.0, "update must change parameters");
    assert!(params.iter().all(|p| p.f().iter().all(|v| v.is_finite())));
}

#[test]
fn serving_generates_deterministically() {
    let Some(dir) = artifacts("gpt-tiny") else { return };
    let server = InferenceServer::load(&dir, 7).unwrap();
    let prompt: Vec<i32> = vec![1, 2, 3, 4];
    let a = server.generate(&[prompt.clone()], 4).unwrap();
    let b = server.generate(&[prompt.clone()], 4).unwrap();
    assert_eq!(a, b);
    assert_eq!(a[0].len(), prompt.len() + 4);
    // Trace with more requests than one batch exercises the batcher.
    let reqs: Vec<Request> = (0..2 * server.batch + 1)
        .map(|id| Request { id, prompt: prompt.clone(), arrival_s: 0.0 })
        .collect();
    let n = reqs.len();
    let (responses, stats) = run_trace(&server, reqs, 2).unwrap();
    assert_eq!(responses.len(), n);
    assert_eq!(stats.completed, n);
    // Identical prompts ⇒ identical continuations across batches.
    for r in &responses[1..] {
        assert_eq!(r.tokens, responses[0].tokens);
    }
}

#[test]
fn train_checkpoint_feeds_serving() {
    // Train briefly, then verify the published checkpoint matches the
    // manifest and that the server restores it (the train→deploy bridge).
    let Some(dir) = artifacts("gpt-tiny") else { return };
    let mut cfg = TrainConfig::new(dir.clone());
    cfg.steps = 8;
    cfg.microbatches = 1;
    cfg.save_checkpoint = true;
    PipelineTrainer::new(cfg).unwrap().run().unwrap();
    let ckpt_path = fusionai::cluster::checkpoint::default_path(&dir);
    assert!(ckpt_path.exists());
    let ckpt = fusionai::cluster::checkpoint::load(&ckpt_path).unwrap();
    let eng = XlaEngine::load(&dir).unwrap();
    for stage in &eng.manifest().stages {
        let specs = &eng.manifest().stage_params[stage];
        let tensors = ckpt.get(stage).expect("stage missing from checkpoint");
        assert_eq!(tensors.len(), specs.len(), "{stage} arity");
        for (t, s) in tensors.iter().zip(specs) {
            assert_eq!(t.shape(), &s.shape[..], "{stage}/{}", s.name);
            assert!(t.f().iter().all(|v| v.is_finite()));
        }
    }
    // Server restores the trained weights verbatim.
    let server = InferenceServer::load(&dir, 999).unwrap();
    let out = server.generate(&[vec![1, 2, 3]], 2).unwrap();
    assert_eq!(out[0].len(), 5);
}

#[test]
fn trainer_errors_cleanly_without_artifacts() {
    let cfg = TrainConfig::new("artifacts/definitely-missing");
    let err = match PipelineTrainer::new(cfg) {
        Ok(_) => panic!("must fail without artifacts"),
        Err(e) => e.to_string(),
    };
    assert!(err.contains("manifest"), "got: {err}");
}
