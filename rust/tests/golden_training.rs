//! Golden test for the pass pipeline: a `PassManager::standard()`-processed
//! graph must train **bitwise identically** to the raw builder output.
//!
//! The tiny transformer contains no foldable patterns and no dead nodes, so
//! the standard pipeline is a structural no-op (asserted in
//! `dag::passes::tests::transformer_graph_is_already_normal`); with node
//! order unchanged, parameter-init RNG consumption is unchanged, and every
//! f32 of every step's loss must match exactly.

use std::sync::Arc;

use fusionai::cluster::SimCluster;
use fusionai::dag::{Graph, PassManager};
use fusionai::decompose::Decomposition;
use fusionai::exec::{Adam, RefEngine};
use fusionai::models::transformer::TransformerConfig;
use fusionai::net::{NetworkSim, Topology};
use fusionai::perf::comm::LinkModel;
use fusionai::tensor::Tensor;

const STEPS: usize = 8;
const STAGES: usize = 3;
const SEED: u64 = 42;

fn train_losses(cfg: &TransformerConfig, g: Graph) -> Vec<f32> {
    let d = Decomposition::chain_balanced(&g, STAGES);
    let net = Arc::new(NetworkSim::new(Topology::uniform(LinkModel::local()), 0.0));
    let mut cluster = SimCluster::new(
        g,
        d,
        net,
        Box::new(|| Box::new(RefEngine::new())),
        Box::new(|| Box::new(Adam::new(0.01))),
        SEED,
    )
    .unwrap();
    let mut losses = Vec::with_capacity(STEPS);
    for step in 0..STEPS {
        let tokens: Vec<i32> = (0..cfg.batch * cfg.seq)
            .map(|i| ((i * 11 + 5 + step) % cfg.vocab) as i32)
            .collect();
        let labels: Vec<i32> =
            tokens.iter().map(|&t| ((t as usize + 11) % cfg.vocab) as i32).collect();
        cluster.feed("tokens", Tensor::from_ivec(&[cfg.batch, cfg.seq], tokens)).unwrap();
        cluster.feed("labels", Tensor::from_ivec(&[cfg.batch, cfg.seq], labels)).unwrap();
        losses.push(cluster.train_step().unwrap().loss.unwrap());
    }
    losses
}

#[test]
fn passmanager_processed_graph_trains_bitwise_identically() {
    let cfg = TransformerConfig::tiny();

    let raw = cfg.build_graph();

    let mut processed = cfg.build_graph();
    let report = PassManager::standard().run(&mut processed).unwrap();
    assert!(!report.changed(), "pipeline must be a no-op here: {:?}", report.entries);

    let golden = train_losses(&cfg, raw);
    let piped = train_losses(&cfg, processed);

    assert_eq!(golden.len(), piped.len());
    for (step, (a, b)) in golden.iter().zip(&piped).enumerate() {
        assert!(a.is_finite());
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "step {step}: raw loss {a} != processed loss {b}"
        );
    }
    // And it genuinely trained.
    assert!(golden.last().unwrap() < golden.first().unwrap(), "{golden:?}");
}

#[test]
fn threaded_gemm_trains_bitwise_identically() {
    // The row-partitioned threaded GEMM must be invisible in the numerics:
    // each output element is still a single ascending-k accumulation chain,
    // so a 4-thread run reproduces the single-thread losses bit for bit.
    // (The scratch pool is always on — RefEngine owns one — so this also
    // pins down that pooled-buffer reuse does not perturb training.)
    let cfg = TransformerConfig::tiny();

    fusionai::tensor::set_gemm_threads(1);
    let single = train_losses(&cfg, cfg.build_graph());

    fusionai::tensor::set_gemm_threads(4);
    let threaded = train_losses(&cfg, cfg.build_graph());
    fusionai::tensor::set_gemm_threads(1);

    assert_eq!(single.len(), threaded.len());
    for (step, (a, b)) in single.iter().zip(&threaded).enumerate() {
        assert!(a.is_finite());
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "step {step}: single-thread loss {a} != threaded loss {b}"
        );
    }
}

#[test]
fn serde_roundtripped_graph_trains_bitwise_identically() {
    // from_json(to_json(g)) must also preserve training numerics exactly —
    // the round-trip keeps ids, kwargs, shapes and dtypes intact.
    let cfg = TransformerConfig::tiny();
    let raw = cfg.build_graph();
    let restored = Graph::from_json(&raw.to_json()).unwrap();

    let golden = train_losses(&cfg, raw);
    let rt = train_losses(&cfg, restored);
    for (a, b) in golden.iter().zip(&rt) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
}
