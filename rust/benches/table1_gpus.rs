//! Regenerates paper **Table 1**: "Comparing different GPUs", extended with
//! the achievable-throughput column our perf model derives (λ = 0.5) and
//! the aggregate-FLOPS headline ratio.
//!
//! Run: `cargo bench --bench table1_gpus`

use fusionai::benchutil::{bench, Table};
use fusionai::perf::gpus::{lookup, GpuLevel, GPU_DB};

fn main() {
    println!("=== Table 1: Comparing different GPUs ===\n");
    let mut t = Table::new(&[
        "GPU",
        "TFLOPS (FP32)",
        "TFLOPS FP32 Tensor Core",
        "Memory",
        "Level",
        "achieved @λ=0.5",
        "$/TFLOP",
    ]);
    for g in GPU_DB {
        t.row(&[
            g.name.to_string(),
            format!("{:.2}", g.tflops_fp32),
            format!("{:.2}", g.tflops_tensor),
            format!("{:.0}GB", g.memory_gb),
            g.level.to_string(),
            format!("{:.1} TFLOPS", 0.5 * g.tflops_tensor),
            format!("{:.0}", g.price_usd / g.tflops_tensor),
        ]);
    }
    t.print();

    // The paper's aggregate argument: 50 consumer cards vs 4 flagships.
    let r3080 = lookup("RTX 3080").unwrap();
    let h100 = lookup("H100").unwrap();
    let flops_ratio = 50.0 * r3080.peak_tensor_flops() / (4.0 * h100.peak_tensor_flops());
    let price_ratio = 50.0 * r3080.price_usd / (4.0 * h100.price_usd);
    println!(
        "\n50× RTX 3080 vs 4× H100: aggregate tensor FLOPS ratio {:.2}× at {:.2}× the price",
        flops_ratio, price_ratio
    );
    assert!((0.9..1.1).contains(&flops_ratio));

    let consumer_total: f64 = GPU_DB
        .iter()
        .filter(|g| g.level == GpuLevel::Consumer)
        .map(|g| g.tflops_tensor)
        .sum();
    println!("consumer rows in DB: Σ tensor TFLOPS = {consumer_total:.0}");

    // Micro: DB lookup cost (used on every registration).
    bench("gpu_db_lookup", 100, 1000, |i| {
        let name = GPU_DB[i % GPU_DB.len()].name;
        lookup(name).unwrap().tflops_tensor
    });
}
