//! Regenerates paper **Figure 4**: "Partitioned sub-DAGs of Bert-Large on
//! 50 RTX 3080" — 24 transformer layers, each split into an attention block
//! and an FFN block, partitioned with the Eq.-2 load-balancing scheduler;
//! plus the paper's 4×H100 grouping (sub-DAGs 1, 2–25, 26–49, 50).
//!
//! Run: `cargo bench --bench fig4_partition`

use fusionai::benchutil::{bench, Table};
use fusionai::decompose::Decomposition;
use fusionai::models::transformer::TransformerConfig;
use fusionai::perf::gpus::lookup;
use fusionai::sched;
use fusionai::util::{human_flops, human_secs};

fn main() {
    let cfg = TransformerConfig::bert_large();
    let g = cfg.build_graph();
    println!(
        "Bert-Large: {} layers × (attention block + FFN block) | {} ops | {} params | {} fwd FLOPs/batch(B={})",
        cfg.layers,
        g.len(),
        cfg.param_count(),
        human_flops(g.total_fwd_flops()),
        cfg.batch,
    );

    // ---- 50× RTX 3080 (Figure 4 proper) ----
    let d50 = Decomposition::chain_balanced(&g, 50);
    d50.validate(&g).unwrap();
    let loads: Vec<f64> = (0..50).map(|s| d50.sub_flops(&g, s)).collect();
    let total: f64 = loads.iter().sum();
    let max = loads.iter().cloned().fold(0.0, f64::max);
    let nonzero = loads.iter().filter(|&&l| l > 0.0).count();
    println!(
        "\n50-way partition: {} non-empty sub-DAGs | max/mean load {:.3} | cut traffic {} bytes/batch",
        nonzero,
        max / (total / 50.0),
        d50.cut_bytes(&g)
    );
    let mut t = Table::new(&["sub-DAG", "ops", "FLOPs", "share", "blocks inside"]);
    for s in [0usize, 1, 2, 24, 25, 48, 49] {
        let blocks: Vec<String> = d50.subgraphs[s]
            .nodes
            .iter()
            .map(|&n| g.node(n).name.clone())
            .filter(|n| n.ends_with(".attn") || n.ends_with(".ffn"))
            .collect();
        t.row(&[
            (s + 1).to_string(),
            d50.subgraphs[s].nodes.len().to_string(),
            human_flops(loads[s]),
            format!("{:.2}%", 100.0 * loads[s] / total),
            if blocks.is_empty() { "-".into() } else { blocks.join(", ") },
        ]);
    }
    t.print();

    // Per-device time via the scheduler (Eq. 2) on a uniform 3080 fleet.
    let tasks = sched::build::tasks_from_decomposition(&g, &d50, false);
    let peers = sched::build::uniform_peers(lookup("RTX 3080").unwrap(), 0.5, 50);
    let s = sched::schedule(&tasks, &peers).unwrap();
    s.validate(&tasks, &peers).unwrap();
    println!(
        "\nEq.2 schedule onto 50×3080: makespan {} | min load {} | spread {:.1}%",
        human_secs(s.makespan()),
        human_secs(s.loads.iter().cloned().fold(f64::INFINITY, f64::min)),
        100.0 * (s.makespan() - s.loads.iter().cloned().fold(f64::INFINITY, f64::min))
            / s.makespan()
    );

    // ---- the paper's 4×H100 grouping: sub-DAGs 1, 2–25, 26–49, 50 ----
    println!("\n4×H100 grouping of the same 50 sub-DAGs (paper §4):");
    let groups: [(usize, usize); 4] = [(0, 1), (1, 25), (25, 49), (49, 50)];
    let h100 = lookup("H100").unwrap();
    let mut t = Table::new(&["H100", "sub-DAGs", "FLOPs", "time @λ=0.5"]);
    for (i, (lo, hi)) in groups.iter().enumerate() {
        let fl: f64 = (*lo..*hi).map(|s| loads[s]).sum();
        t.row(&[
            (i + 1).to_string(),
            format!("{}–{}", lo + 1, hi),
            human_flops(fl),
            human_secs(fl / (0.5 * h100.peak_tensor_flops())),
        ]);
    }
    t.print();

    // Heterogeneous variant: proportional split over a mixed fleet.
    let speeds: Vec<f64> = (0..50)
        .map(|i| if i % 5 == 0 { 97.5e12 } else { 59.5e12 }) // 4080s sprinkled in
        .collect();
    let dh = Decomposition::chain_proportional(&g, &speeds);
    dh.validate(&g).unwrap();
    let t_max = (0..50)
        .map(|s| dh.sub_flops(&g, s) / (0.5 * speeds[s]))
        .fold(0.0f64, f64::max);
    println!(
        "\nheterogeneous fleet (every 5th card a 4080): proportional split stage time {} (uniform split would be {})",
        human_secs(t_max),
        human_secs(max / (0.5 * 59.5e12)),
    );

    // Partition cost itself (the broker pays this per job submission).
    bench("chain_balanced_50way_bert", 3, 20, |_| {
        Decomposition::chain_balanced(&g, 50).num_subgraphs()
    });
    bench("eq2_schedule_50tasks_50peers", 3, 50, |_| {
        sched::schedule(&tasks, &peers).unwrap().makespan()
    });
}
