//! Regenerates paper **Figure 3 / Table 2 / Table 3**: the example DAG, its
//! per-operator attributes, and the per-subgraph message-passing attributes
//! under the paper's 3-compnode partition.
//!
//! Run: `cargo bench --bench table23_dag`

use fusionai::benchutil::{bench, Table};
use fusionai::dag::{Graph, NodeId, PassManager};
use fusionai::decompose::Decomposition;
use fusionai::models::fig3;
use fusionai::models::transformer::TransformerConfig;

fn main() {
    let g = fig3::build();
    let d = Decomposition::from_assignment(&g, &fig3::paper_partition(&g));
    let name = |id: NodeId| g.node(id).name.clone();
    let names = |ids: &[NodeId]| {
        if ids.is_empty() {
            "-".to_string()
        } else {
            ids.iter().map(|&i| name(i)).collect::<Vec<_>>().join(", ")
        }
    };

    println!("=== Table 2: OP nodes and their attributes ===\n");
    let mut t2 = Table::new(&[
        "OP names", "OP users", "Type", "Args", "Kwargs", "Compnode location", "Compnode users",
    ]);
    for node in &g.nodes {
        let users: Vec<NodeId> = g.users(node.id).to_vec();
        let mut comp_users: Vec<usize> =
            users.iter().map(|&u| d.of_node[u] + 1).collect();
        comp_users.sort();
        comp_users.dedup();
        let kwargs = if node.kwargs.is_empty() {
            "-".to_string()
        } else {
            node.kwargs.iter().map(|(k, v)| format!("{k}: {v}")).collect::<Vec<_>>().join(", ")
        };
        t2.row(&[
            node.name.clone(),
            names(&users),
            node.kind.category().to_string(),
            names(&node.args),
            kwargs,
            (d.of_node[node.id] + 1).to_string(),
            if comp_users.is_empty() {
                (d.of_node[node.id] + 1).to_string()
            } else {
                comp_users.iter().map(usize::to_string).collect::<Vec<_>>().join(", ")
            },
        ]);
    }
    t2.print();

    println!("\n=== Table 3: Sub-graphs and their attributes ===\n");
    let mut t3 = Table::new(&[
        "Subgraph", "Compnode", "Nodes", "Inner required data", "Outer required data",
        "Outwards data", "Compnode users",
    ]);
    for s in 0..d.num_subgraphs() {
        let a = d.attrs(&g, s);
        t3.row(&[
            (s + 1).to_string(),
            (s + 1).to_string(),
            names(&d.subgraphs[s].nodes),
            names(&a.inner_required),
            names(&a.outer_required),
            names(&a.outwards),
            if a.compnode_users.is_empty() {
                "-".to_string()
            } else {
                a.compnode_users.iter().map(|u| (u + 1).to_string()).collect::<Vec<_>>().join(",")
            },
        ]);
    }
    t3.print();

    println!("\ncut edges (the black message-passing lines of Figure 3):");
    for (src, dst) in d.cut_edges(&g) {
        println!(
            "  {} (compnode {}) → {} (compnode {})",
            name(src),
            d.of_node[src] + 1,
            name(dst),
            d.of_node[dst] + 1
        );
    }

    // Micro: decomposition attribute derivation cost.
    bench("table3_attrs_derivation", 10, 200, |_| {
        (0..3).map(|s| d.attrs(&g, s).outer_required.len()).sum::<usize>()
    });
    bench("fig3_graph_build", 10, 200, |_| fig3::build().len());

    // Compiler-pipeline costs on a realistic training graph: the standard
    // normalization pipeline and the serde round-trip.
    let tiny = TransformerConfig::tiny().build_graph();
    bench("passmanager_standard_tiny", 10, 50, |_| {
        let mut g = tiny.clone();
        PassManager::standard().run(&mut g).unwrap();
        g.len()
    });
    bench("graph_json_roundtrip_tiny", 10, 50, |_| {
        Graph::from_json(&tiny.to_json()).unwrap().len()
    });
}
