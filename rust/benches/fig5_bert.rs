//! Regenerates paper **Figure 5**: "System performance of Bert-Large with
//! different communication bandwidth and latency" — Eq.-3 latency and
//! Eq.-4 pipelined throughput (n_b = 512) of 50× RTX 3080 across the
//! (bandwidth, latency) grid, against the 4× H100 baseline, plus the
//! §2.3 compression mitigation.
//!
//! Run: `cargo bench --bench fig5_bert`

use fusionai::benchutil::Table;
use fusionai::compress::Codec;
use fusionai::decompose::Decomposition;
use fusionai::models::transformer::TransformerConfig;
use fusionai::perf::comm::LinkModel;
use fusionai::perf::gpus::lookup;
use fusionai::perf::paleo::{DeviceProfile, PaleoModel};
use fusionai::pipeline::analytics::PipelineEstimate;
use fusionai::util::human_secs;

const N_B: usize = 512;

fn estimate(
    cfg: &TransformerConfig,
    devices: usize,
    gpu: &str,
    link: LinkModel,
    codec: Option<Codec>,
) -> PipelineEstimate {
    let g = cfg.build_graph();
    let d = Decomposition::chain_balanced(&g, devices);
    let models: Vec<PaleoModel> = (0..devices)
        .map(|_| PaleoModel::new(DeviceProfile::with_lambda(lookup(gpu).unwrap(), 0.5)))
        .collect();
    let mut est = PipelineEstimate::from_decomposition(&g, &d, &models, link, false);
    // Compression shrinks the bandwidth-proportional share of every wire
    // payload by the codec ratio (§2.3); the α latency share is unaffected.
    // Exact for one inbound tensor per stage: r·(α+βM) + (1−r)·α = α + β·rM.
    if let Some(c) = codec {
        let ratio = c.ratio(1_000_000);
        for s in est.stages.iter_mut() {
            s.comm_s = s.comm_s * ratio + link.alpha * (1.0 - ratio);
        }
    }
    est
}

fn main() {
    let cfg = TransformerConfig::bert_large();
    println!(
        "=== Figure 5: Bert-Large (B={}, S={}) | 50× RTX 3080 vs 4× H100 | n_b = {N_B} ===\n",
        cfg.batch, cfg.seq
    );

    let baseline = estimate(&cfg, 4, "H100", LinkModel::datacenter(), None);
    println!(
        "4×H100 baseline: latency {} | T_512 {} | throughput {:.1} batches/s\n",
        human_secs(baseline.latency()),
        human_secs(baseline.pipelined_time(N_B)),
        baseline.throughput(N_B)
    );

    let bandwidths = [10.0, 100.0, 1_000.0, 10_000.0, 100_000.0, 400_000.0];
    let latencies = [1.0, 10.0, 50.0];

    for &alpha_ms in &latencies {
        println!("--- link latency α = {alpha_ms} ms ---");
        let mut t = Table::new(&[
            "bandwidth (Mbps)", "latency Eq.3", "T_512 Eq.4", "throughput (b/s)",
            "vs H100", "regime", "w/ int8 comp: vs H100",
        ]);
        for &mbps in &bandwidths {
            let link = LinkModel::from_ms_mbps(alpha_ms, mbps);
            let est = estimate(&cfg, 50, "RTX 3080", link, None);
            let est_c = estimate(&cfg, 50, "RTX 3080", link, Some(Codec::Int8));
            let ratio = est.steady_state_throughput() / baseline.steady_state_throughput();
            let ratio_c =
                est_c.steady_state_throughput() / baseline.steady_state_throughput();
            t.row(&[
                format!("{mbps:.0}"),
                human_secs(est.latency()),
                human_secs(est.pipelined_time(N_B)),
                format!("{:.3}", est.throughput(N_B)),
                format!("{ratio:.3}×"),
                if est.comm_bound() { "comm" } else { "compute" }.to_string(),
                format!("{ratio_c:.3}×"),
            ]);
        }
        t.print();
        println!();
    }

    // Shape assertions the paper's narrative requires.
    let slow = estimate(&cfg, 50, "RTX 3080", LinkModel::from_ms_mbps(10.0, 10.0), None);
    let fast = estimate(&cfg, 50, "RTX 3080", LinkModel::datacenter(), None);
    assert!(slow.latency() > baseline.latency() * 100.0, "consumer latency >> H100");
    let fast_ratio = fast.steady_state_throughput() / baseline.steady_state_throughput();
    assert!(
        (0.5..2.0).contains(&fast_ratio),
        "compute-bound consumer throughput ≈ H100 (got {fast_ratio:.2}×)"
    );
    println!(
        "shape check: latency gap at 10 Mbps = {:.0}×; compute-bound throughput ratio = {fast_ratio:.2}×",
        slow.latency() / baseline.latency()
    );
    println!(
        "takeaway (paper §4): latency with 50×3080 is far larger, but once links keep\n\
         R_p ≤ C_p the pipelined throughput matches 4×H100 at ~29% of the hardware cost;\n\
         int8 compression (§2.3) moves the crossover ~4× down the bandwidth axis."
    );
}
