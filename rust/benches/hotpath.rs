//! Hot-path microbenchmarks (§Perf deliverable): the L3 loops that run per
//! message / per step / per job, with the targets from DESIGN.md §Perf.
//! Before/after numbers for the optimization pass live in EXPERIMENTS.md.
//!
//! Run: `cargo bench --bench hotpath`

use std::sync::Arc;

use fusionai::benchutil::{bench, black_box};
use fusionai::cluster::SimCluster;
use fusionai::compress::Codec;
use fusionai::dag::autodiff::backward_plan;
use fusionai::decompose::Decomposition;
use fusionai::dht::Dht;
use fusionai::exec::{Adam, Engine, RefEngine};
use fusionai::models::transformer::TransformerConfig;
use fusionai::net::{NetworkSim, Topology};
use fusionai::perf::comm::LinkModel;
use fusionai::perf::gpus::lookup;
use fusionai::pipeline::schedule::MicrobatchSchedule;
use fusionai::runtime::Runtime;
use fusionai::sched;
use fusionai::tensor::{matmul_into, Tensor};
use fusionai::util::{json, Rng};

fn main() {
    let mut rng = Rng::new(1);

    // --- L3 numeric kernels (RefEngine path) ---
    let m = 128;
    let a: Vec<f32> = (0..m * m).map(|_| rng.normal() as f32).collect();
    let b: Vec<f32> = (0..m * m).map(|_| rng.normal() as f32).collect();
    let mut c = vec![0.0f32; m * m];
    let r = bench("matmul_128x128x128", 5, 50, |_| {
        matmul_into(&a, &b, &mut c, m, m, m);
        c[0]
    });
    let gflops = 2.0 * (m as f64).powi(3) / r.median_s / 1e9;
    println!("  ↳ {gflops:.2} GFLOP/s single-thread");

    let g = TransformerConfig::tiny().build_graph();
    let attn_node = g.by_name("layer0.attn").unwrap().clone();
    let mut eng = RefEngine::new();
    let params = eng.init_params(&attn_node, &mut rng).unwrap();
    let x = Tensor::randn(&[2, 16, 32], 1.0, &mut rng);
    bench("ref_attention_fwd_2x16x32", 5, 100, |_| {
        eng.forward(&attn_node, &[&x], &params).unwrap().numel()
    });
    let dy = Tensor::randn(&[2, 16, 32], 1.0, &mut rng);
    bench("ref_attention_bwd_2x16x32", 5, 100, |_| {
        eng.backward(&attn_node, &[&x], &params, Some(&dy)).unwrap().param_grads.len()
    });

    // --- scheduler on job-submission scale (target: <100 ms for
    //     Bert-Large-scale DAGs on 50 nodes) ---
    let bert = TransformerConfig::bert_large().build_graph();
    let r = bench("decompose_bert_50way", 3, 20, |_| {
        Decomposition::chain_balanced(&bert, 50).num_subgraphs()
    });
    assert!(r.median_s < 0.1, "decompose target <100ms, got {}", r.median_s);
    let d = Decomposition::chain_balanced(&bert, 50);
    let tasks = sched::build::tasks_from_decomposition(&bert, &d, true);
    let peers = sched::build::uniform_peers(lookup("RTX 3080").unwrap(), 0.5, 50);
    let r = bench("schedule_50x50", 3, 50, |_| {
        sched::schedule(&tasks, &peers).unwrap().makespan()
    });
    assert!(r.median_s < 0.1, "schedule target <100ms, got {}", r.median_s);
    bench("backward_plan_bert", 3, 50, |_| backward_plan(&bert).len());

    // --- DHT ops (per-message path) ---
    let mut dht = Dht::new(3);
    for p in 0..32 {
        dht.join(p).unwrap();
    }
    let blob = vec![0u8; 4096];
    bench("dht_put_4k_repl3", 10, 2000, |i| {
        dht.put(&format!("bench/{}", i % 512), blob.clone()).unwrap().len()
    });
    bench("dht_get_4k", 10, 2000, |i| dht.get(&format!("bench/{}", i % 512)).unwrap().len());
    bench("dht_join_leave_rebalance", 2, 20, |i| {
        dht.join(1000 + i).unwrap();
        dht.leave(1000 + i).unwrap();
        0
    });

    // --- codecs (per-hop payload path) ---
    let act: Vec<f32> = (0..64 * 1024).map(|_| rng.normal() as f32).collect();
    for codec in [Codec::None, Codec::Int8, Codec::TopK { ratio: 0.1 }] {
        let enc = codec.encode(&act);
        bench(&format!("encode_256KiB_{codec:?}"), 3, 50, |_| codec.encode(&act).len());
        bench(&format!("decode_256KiB_{codec:?}"), 3, 50, |_| {
            codec.decode(&enc, act.len()).len()
        });
    }

    // --- manifest/json (job-submission path) ---
    let manifest = std::fs::read_to_string("artifacts/gpt-tiny/manifest.json").ok();
    if let Some(text) = manifest {
        bench("manifest_json_parse", 5, 200, |_| {
            json::parse(&text).unwrap().get("stages").is_some() as usize
        });
    }

    // --- pipeline schedule simulation (planning path) ---
    bench("gpipe_schedule_8x32_simulate", 3, 100, |_| {
        MicrobatchSchedule::gpipe(8, 32).simulate(1.0, 2.0, 0.5) as usize
    });

    // --- SimCluster full train step (tiny transformer, 4 compnodes) ---
    let cfg = TransformerConfig::tiny();
    let mk = || {
        let g = cfg.build_graph();
        let d = Decomposition::chain_balanced(&g, 4);
        let net = Arc::new(NetworkSim::new(Topology::uniform(LinkModel::local()), 0.0));
        SimCluster::new(
            g,
            d,
            net,
            Box::new(|| Box::new(RefEngine::new())),
            Box::new(|| Box::new(Adam::new(0.01))),
            5,
        )
        .unwrap()
    };
    let mut cluster = mk();
    let tokens: Vec<i32> =
        (0..cfg.batch * cfg.seq).map(|i| ((i * 7 + 3) % cfg.vocab) as i32).collect();
    let labels: Vec<i32> =
        tokens.iter().map(|&t| ((t as usize + 7) % cfg.vocab) as i32).collect();
    bench("simcluster_train_step_tiny_4way", 3, 30, |_| {
        cluster
            .feed("tokens", Tensor::from_ivec(&[cfg.batch, cfg.seq], tokens.clone()))
            .unwrap();
        cluster
            .feed("labels", Tensor::from_ivec(&[cfg.batch, cfg.seq], labels.clone()))
            .unwrap();
        cluster.train_step().unwrap().updated
    });

    // --- XLA stage execution (the production hot path), if artifacts exist ---
    if std::path::Path::new("artifacts/gpt-tiny/manifest.json").exists() {
        let mut rt = Runtime::cpu().unwrap();
        let manifest = rt.load_dir(std::path::Path::new("artifacts/gpt-tiny")).unwrap();
        let specs = &manifest.stage_params["block0"];
        let mut prng = Rng::new(2);
        let mut args: Vec<Tensor> = specs.iter().map(|s| s.materialize(&mut prng)).collect();
        let batch = manifest.config_usize("batch").unwrap();
        let seq = manifest.config_usize("seq").unwrap();
        let dim = manifest.config_usize("dim").unwrap();
        args.push(Tensor::randn(&[batch, seq, dim], 1.0, &mut prng));
        bench("xla_block0_fwd_gpt_tiny", 5, 100, |_| {
            black_box(rt.run("block0_fwd", &args).unwrap().len())
        });
    } else {
        println!("(artifacts/gpt-tiny missing — run `make artifacts` for the XLA hot-path bench)");
    }
}
