//! Hot-path microbenchmarks (§Perf deliverable): the L3 loops that run per
//! message / per step / per job, with the targets from DESIGN.md §Perf.
//! Before/after numbers for the optimization pass live in EXPERIMENTS.md.
//!
//! Run: `cargo bench --bench hotpath`
//!
//! The GEMM section compares the retained naive kernels
//! (`tensor::naive::*`, the pre-optimization loops) against the blocked
//! single-thread implementation and the row-partitioned threaded variant,
//! and writes machine-readable results to `BENCH_hotpath.json` at the repo
//! root. Set `FUSIONAI_BENCH_SMOKE=1` for a fast CI smoke run (one short
//! iteration per case, latency targets not asserted).

use std::sync::Arc;

use fusionai::benchutil::{bench, black_box, BenchResult};
use fusionai::cluster::SimCluster;
use fusionai::compress::Codec;
use fusionai::dag::autodiff::backward_plan;
use fusionai::dag::{DType, Graph, OpKind, Shape};
use fusionai::decompose::Decomposition;
use fusionai::dht::Dht;
use fusionai::exec::{Adam, Engine, RefEngine, WaveRunner};
use fusionai::models::transformer::TransformerConfig;
use fusionai::net::{NetworkSim, Topology};
use fusionai::perf::comm::LinkModel;
use fusionai::perf::gpus::lookup;
use fusionai::pipeline::schedule::MicrobatchSchedule;
use fusionai::runtime::Runtime;
use fusionai::sched;
use fusionai::tensor::{
    matmul_at_into, matmul_bt_into, matmul_into, matmul_into_threaded, naive, Tensor,
};
use fusionai::util::{json, Rng};

/// One recorded bench case, with optional GFLOP/s for the GEMM cases.
struct Record {
    result: BenchResult,
    gflops: Option<f64>,
}

fn record(records: &mut Vec<Record>, result: BenchResult) {
    records.push(Record { result, gflops: None });
}

fn record_gemm(records: &mut Vec<Record>, result: BenchResult, flops: f64) -> f64 {
    let gflops = flops / result.median_s / 1e9;
    println!("  ↳ {gflops:.2} GFLOP/s");
    records.push(Record { result, gflops: Some(gflops) });
    gflops
}

fn write_json(records: &[Record], smoke: bool, speedup_blocked_vs_naive: f64) {
    let mut out = String::from("{\n");
    out.push_str(&format!("  \"smoke\": {smoke},\n"));
    out.push_str(&format!(
        "  \"speedup_blocked_vs_naive_128\": {speedup_blocked_vs_naive:.3},\n"
    ));
    out.push_str("  \"cases\": [\n");
    for (i, rec) in records.iter().enumerate() {
        let r = &rec.result;
        out.push_str(&format!(
            "    {{\"name\": \"{}\", \"iters\": {}, \"median_s\": {:e}, \"mean_s\": {:e}, \
             \"p99_s\": {:e}, \"min_s\": {:e}",
            r.name, r.iters, r.median_s, r.mean_s, r.p99_s, r.min_s
        ));
        if let Some(g) = rec.gflops {
            out.push_str(&format!(", \"gflops\": {g:.3}"));
        }
        out.push_str(if i + 1 == records.len() { "}\n" } else { "},\n" });
    }
    out.push_str("  ]\n}\n");
    let path = format!("{}/../BENCH_hotpath.json", env!("CARGO_MANIFEST_DIR"));
    match std::fs::write(&path, &out) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => println!("could not write {path}: {e}"),
    }
}

fn main() {
    let smoke = std::env::var("FUSIONAI_BENCH_SMOKE").map(|v| v == "1").unwrap_or(false);
    // (warmup, iters) scalers: smoke mode runs each case once, unwarmed.
    let wu = |w: usize| if smoke { 0 } else { w };
    let it = |n: usize| if smoke { 1 } else { n };
    let mut records: Vec<Record> = Vec::new();
    let mut rng = Rng::new(1);

    // --- L3 numeric kernels (RefEngine path): naive vs blocked vs threaded ---
    let m = 128;
    let flops = 2.0 * (m as f64).powi(3);
    let a: Vec<f32> = (0..m * m).map(|_| rng.normal() as f32).collect();
    let b: Vec<f32> = (0..m * m).map(|_| rng.normal() as f32).collect();
    let mut c = vec![0.0f32; m * m];

    let r = bench("matmul_naive_128x128x128", wu(5), it(50), |_| {
        black_box(naive::matmul(&a, &b, m, m, m))[0]
    });
    let g_naive = record_gemm(&mut records, r, flops);

    let r = bench("matmul_128x128x128", wu(5), it(50), |_| {
        matmul_into(&a, &b, &mut c, m, m, m);
        c[0]
    });
    let g_blocked = record_gemm(&mut records, r, flops);

    let r = bench("matmul_threaded4_128x128x128", wu(5), it(50), |_| {
        matmul_into_threaded(&a, &b, &mut c, m, m, m, 4);
        c[0]
    });
    record_gemm(&mut records, r, flops);

    let speedup = g_blocked / g_naive;
    println!("  ↳ blocked vs naive speedup: {speedup:.2}x");

    // Transposed-operand GEMMs (the backward-pass shapes).
    let r = bench("matmul_bt_128x128x128", wu(5), it(50), |_| {
        matmul_bt_into(&a, &b, &mut c, m, m, m);
        c[0]
    });
    record_gemm(&mut records, r, flops);
    let r = bench("matmul_at_128x128x128", wu(5), it(50), |_| {
        matmul_at_into(&a, &b, &mut c, m, m, m);
        c[0]
    });
    record_gemm(&mut records, r, flops);

    let g = TransformerConfig::tiny().build_graph();
    let attn_node = g.by_name("layer0.attn").unwrap().clone();
    let mut eng = RefEngine::new();
    let params = eng.init_params(&attn_node, &mut rng).unwrap();
    let x = Tensor::randn(&[2, 16, 32], 1.0, &mut rng);
    let r = bench("ref_attention_fwd_2x16x32", wu(5), it(100), |_| {
        eng.forward(&attn_node, &[&x], &params).unwrap().numel()
    });
    record(&mut records, r);
    let dy = Tensor::randn(&[2, 16, 32], 1.0, &mut rng);
    let r = bench("ref_attention_bwd_2x16x32", wu(5), it(100), |_| {
        eng.backward(&attn_node, &[&x], &params, Some(&dy)).unwrap().param_grads.len()
    });
    record(&mut records, r);
    let (hits, misses) = eng.scratch_stats();
    println!("  ↳ scratch pool: {hits} hits / {misses} misses");

    // --- scheduler on job-submission scale (target: <100 ms for
    //     Bert-Large-scale DAGs on 50 nodes) ---
    let bert = TransformerConfig::bert_large().build_graph();
    let r = bench("decompose_bert_50way", wu(3), it(20), |_| {
        Decomposition::chain_balanced(&bert, 50).num_subgraphs()
    });
    if !smoke {
        assert!(r.median_s < 0.1, "decompose target <100ms, got {}", r.median_s);
    }
    record(&mut records, r);
    let d = Decomposition::chain_balanced(&bert, 50);
    let tasks = sched::build::tasks_from_decomposition(&bert, &d, true);
    let peers = sched::build::uniform_peers(lookup("RTX 3080").unwrap(), 0.5, 50);
    let r = bench("schedule_50x50", wu(3), it(50), |_| {
        sched::schedule(&tasks, &peers).unwrap().makespan()
    });
    if !smoke {
        assert!(r.median_s < 0.1, "schedule target <100ms, got {}", r.median_s);
    }
    record(&mut records, r);
    let r = bench("backward_plan_bert", wu(3), it(50), |_| backward_plan(&bert).len());
    record(&mut records, r);

    // --- DHT ops (per-message path) ---
    let mut dht = Dht::new(3);
    for p in 0..32 {
        dht.join(p).unwrap();
    }
    let blob = vec![0u8; 4096];
    let r = bench("dht_put_4k_repl3", wu(10), it(2000), |i| {
        dht.put(&format!("bench/{}", i % 512), blob.clone()).unwrap().len()
    });
    record(&mut records, r);
    let r = bench("dht_get_4k", wu(10), it(2000), |i| {
        dht.get(&format!("bench/{}", i % 512)).unwrap().len()
    });
    record(&mut records, r);
    let r = bench("dht_join_leave_rebalance", wu(2), it(20), |i| {
        dht.join(1000 + i).unwrap();
        dht.leave(1000 + i).unwrap();
        0
    });
    record(&mut records, r);

    // --- codecs (per-hop payload path) ---
    let act: Vec<f32> = (0..64 * 1024).map(|_| rng.normal() as f32).collect();
    for codec in [Codec::None, Codec::Int8, Codec::TopK { ratio: 0.1 }] {
        let enc = codec.encode(&act);
        let r = bench(&format!("encode_256KiB_{codec:?}"), wu(3), it(50), |_| {
            codec.encode(&act).len()
        });
        record(&mut records, r);
        let r = bench(&format!("decode_256KiB_{codec:?}"), wu(3), it(50), |_| {
            codec.decode(&enc, act.len()).len()
        });
        record(&mut records, r);
    }

    // --- manifest/json (job-submission path) ---
    let manifest = std::fs::read_to_string("artifacts/gpt-tiny/manifest.json").ok();
    if let Some(text) = manifest {
        let r = bench("manifest_json_parse", wu(5), it(200), |_| {
            json::parse(&text).unwrap().get("stages").is_some() as usize
        });
        record(&mut records, r);
    }

    // --- pipeline schedule simulation (planning path) ---
    let r = bench("gpipe_schedule_8x32_simulate", wu(3), it(100), |_| {
        MicrobatchSchedule::gpipe(8, 32).simulate(1.0, 2.0, 0.5) as usize
    });
    record(&mut records, r);

    // --- SimCluster full train step (tiny transformer, 4 compnodes) ---
    let cfg = TransformerConfig::tiny();
    let mk = || {
        let g = cfg.build_graph();
        let d = Decomposition::chain_balanced(&g, 4);
        let net = Arc::new(NetworkSim::new(Topology::uniform(LinkModel::local()), 0.0));
        SimCluster::new(
            g,
            d,
            net,
            Box::new(|| Box::new(RefEngine::new())),
            Box::new(|| Box::new(Adam::new(0.01))),
            5,
        )
        .unwrap()
    };
    let mut cluster = mk();
    let tokens: Vec<i32> =
        (0..cfg.batch * cfg.seq).map(|i| ((i * 7 + 3) % cfg.vocab) as i32).collect();
    let labels: Vec<i32> =
        tokens.iter().map(|&t| ((t as usize + 7) % cfg.vocab) as i32).collect();
    let r = bench("simcluster_train_step_tiny_4way", wu(3), it(30), |_| {
        cluster
            .feed("tokens", Tensor::from_ivec(&[cfg.batch, cfg.seq], tokens.clone()))
            .unwrap();
        cluster
            .feed("labels", Tensor::from_ivec(&[cfg.batch, cfg.seq], labels.clone()))
            .unwrap();
        cluster.train_step().unwrap().updated
    });
    record(&mut records, r);

    // --- wavefront executor: one wide wave of GEMM-heavy branches, serial
    //     vs fanned out across threads (§Perf: graph-level wavefront case;
    //     each branch is 2·64·128·128 FLOPs, at the fan-out threshold) ---
    let mut wg = Graph::new();
    let x = wg.placeholder("x", Shape::of(&[64, 128]), DType::F32);
    let branches: Vec<_> = (0..8)
        .map(|i| {
            wg.op(
                &format!("branch{i}"),
                OpKind::Linear { in_features: 128, out_features: 128, bias: true },
                &[x],
            )
            .unwrap()
        })
        .collect();
    let mut weng = RefEngine::new();
    let mut wparams = std::collections::HashMap::new();
    for &b in &branches {
        wparams.insert(b, weng.init_params(wg.node(b), &mut rng).unwrap());
    }
    let mut wacts: Vec<Option<Tensor>> = (0..wg.len()).map(|_| None).collect();
    wacts[x] = Some(Tensor::randn(&[64, 128], 1.0, &mut rng));
    let mut runner = WaveRunner::new();
    let r = bench("wavefront_wave8_linear_serial", wu(3), it(30), |_| {
        runner.forward_wave(&wg, &branches, &wacts, &wparams, 1).unwrap().len()
    });
    record(&mut records, r);
    let r = bench("wavefront_wave8_linear_threads4", wu(3), it(30), |_| {
        runner.forward_wave(&wg, &branches, &wacts, &wparams, 4).unwrap().len()
    });
    record(&mut records, r);

    // --- XLA stage execution (the production hot path), if artifacts exist
    //     and a PJRT runtime is linked in (the vendored stub always errors) ---
    if std::path::Path::new("artifacts/gpt-tiny/manifest.json").exists() {
        match Runtime::cpu() {
            Ok(mut rt) => {
                let manifest = rt.load_dir(std::path::Path::new("artifacts/gpt-tiny")).unwrap();
                let specs = &manifest.stage_params["block0"];
                let mut prng = Rng::new(2);
                let mut args: Vec<Tensor> =
                    specs.iter().map(|s| s.materialize(&mut prng)).collect();
                let batch = manifest.config_usize("batch").unwrap();
                let seq = manifest.config_usize("seq").unwrap();
                let dim = manifest.config_usize("dim").unwrap();
                args.push(Tensor::randn(&[batch, seq, dim], 1.0, &mut prng));
                let r = bench("xla_block0_fwd_gpt_tiny", wu(5), it(100), |_| {
                    black_box(rt.run("block0_fwd", &args).unwrap().len())
                });
                record(&mut records, r);
            }
            Err(e) => println!("(PJRT runtime unavailable — skipping XLA bench: {e})"),
        }
    } else {
        println!("(artifacts/gpt-tiny missing — run `make artifacts` for the XLA hot-path bench)");
    }

    write_json(&records, smoke, speedup);
}
