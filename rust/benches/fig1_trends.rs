//! Regenerates paper **Figure 1**: "The trends of GPU and model memory" —
//! the motivating gap between model memory requirements and single-GPU
//! memory, as data series plus fitted growth rates.
//!
//! Run: `cargo bench --bench fig1_trends`

use fusionai::benchutil::Table;
use fusionai::perf::trends::{growth_gap, GPU_TREND, MODEL_TREND};
use fusionai::util::human_bytes;

fn main() {
    println!("=== Figure 1: the trends of GPU and model memory ===\n");
    println!("series A — landmark models (fp16 inference / Adam training footprint):");
    let mut t = Table::new(&["year", "model", "params", "infer mem", "train mem"]);
    for m in MODEL_TREND {
        t.row(&[
            m.year.to_string(),
            m.name.to_string(),
            format!("{:.2e}", m.params),
            human_bytes(m.infer_bytes() as u64),
            human_bytes(m.train_bytes() as u64),
        ]);
    }
    t.print();

    println!("\nseries B — flagship training GPUs:");
    let mut t = Table::new(&["year", "GPU", "memory"]);
    for g in GPU_TREND {
        t.row(&[g.year.to_string(), g.name.to_string(), format!("{:.0} GB", g.memory_gb)]);
    }
    t.print();

    let (model_cagr, gpu_cagr) = growth_gap();
    println!(
        "\nfitted growth: model memory {:.0}%/yr vs GPU memory {:.0}%/yr ({}× faster)",
        model_cagr * 100.0,
        gpu_cagr * 100.0,
        (model_cagr / gpu_cagr).round()
    );
    println!(
        "figure-1 conclusion reproduced: model-memory growth outpaces GPU memory → \
         multi-device (and, the paper argues, decentralized consumer-device) execution is forced."
    );
    assert!(model_cagr > 5.0 * gpu_cagr);

    // The gap, concretely: how many flagship GPUs to HOLD each model.
    println!("\nGPUs-to-hold (contemporary flagship, training footprint):");
    let mut t = Table::new(&["model", "year", "contemporary GPU", "GPUs needed"]);
    for m in MODEL_TREND {
        let gpu = GPU_TREND
            .iter()
            .rev()
            .find(|g| g.year <= m.year)
            .unwrap_or(&GPU_TREND[0]);
        let need = (m.train_bytes() / (gpu.memory_gb * 1e9)).ceil();
        t.row(&[
            m.name.to_string(),
            m.year.to_string(),
            gpu.name.to_string(),
            format!("{need:.0}"),
        ]);
    }
    t.print();
}
