//! Regenerates paper **Figure 6**: "System performance of GPT3 (24 layers
//! with the hidden size of 4096)" — the Figure-5 sweep on the larger model,
//! where activations are 8× bigger ([1, 2048, 4096] vs [8, 512, 1024] is
//! the same bytes but FLOPs/stage are ~5× higher, so the compute-bound
//! crossover arrives at lower bandwidth.
//!
//! Run: `cargo bench --bench fig6_gpt3`

use fusionai::benchutil::Table;
use fusionai::decompose::Decomposition;
use fusionai::models::transformer::TransformerConfig;
use fusionai::perf::comm::LinkModel;
use fusionai::perf::gpus::lookup;
use fusionai::perf::paleo::{DeviceProfile, PaleoModel};
use fusionai::pipeline::analytics::PipelineEstimate;
use fusionai::util::{human_bytes, human_flops, human_secs};

const N_B: usize = 512;

fn estimate(
    cfg: &TransformerConfig,
    devices: usize,
    gpu: &str,
    link: LinkModel,
) -> PipelineEstimate {
    let g = cfg.build_graph();
    let d = Decomposition::chain_balanced(&g, devices);
    let models: Vec<PaleoModel> = (0..devices)
        .map(|_| PaleoModel::new(DeviceProfile::with_lambda(lookup(gpu).unwrap(), 0.5)))
        .collect();
    PipelineEstimate::from_decomposition(&g, &d, &models, link, false)
}

fn main() {
    let cfg = TransformerConfig::gpt3_24x4096();
    let g = cfg.build_graph();
    println!(
        "=== Figure 6: GPT-3 variant (24 layers, hidden 4096; B={}, S={}) ===",
        cfg.batch, cfg.seq
    );
    println!(
        "{} params | {} fwd FLOPs/batch | stage activation {}\n",
        cfg.param_count(),
        human_flops(g.total_fwd_flops()),
        human_bytes((cfg.batch * cfg.seq * cfg.dim * 4) as u64)
    );

    let baseline = estimate(&cfg, 4, "H100", LinkModel::datacenter());
    println!(
        "4×H100 baseline: latency {} | throughput {:.2} batches/s\n",
        human_secs(baseline.latency()),
        baseline.throughput(N_B)
    );

    for &alpha_ms in &[1.0, 10.0, 50.0] {
        println!("--- link latency α = {alpha_ms} ms ---");
        let mut t = Table::new(&[
            "bandwidth (Mbps)", "latency Eq.3", "T_512 Eq.4", "throughput (b/s)", "vs H100", "regime",
        ]);
        for &mbps in &[10.0, 100.0, 1_000.0, 10_000.0, 100_000.0, 400_000.0] {
            let link = LinkModel::from_ms_mbps(alpha_ms, mbps);
            let est = estimate(&cfg, 50, "RTX 3080", link);
            let ratio = est.steady_state_throughput() / baseline.steady_state_throughput();
            t.row(&[
                format!("{mbps:.0}"),
                human_secs(est.latency()),
                human_secs(est.pipelined_time(N_B)),
                format!("{:.3}", est.throughput(N_B)),
                format!("{ratio:.3}×"),
                if est.comm_bound() { "comm" } else { "compute" }.to_string(),
            ]);
        }
        t.print();
        println!();
    }

    // GPT-3's memory wall: which devices can even hold a 50-way shard?
    let d50 = Decomposition::chain_balanced(&g, 50);
    let max_shard: u64 = (0..50).map(|s| d50.sub_gpu_bytes(&g, s)).max().unwrap();
    println!(
        "memory: largest 50-way training shard needs {} — {} on an RTX 3080 (10 GB), the\n\
        fine-grained-partition motivation of §3.1 P3",
        human_bytes(max_shard),
        if max_shard <= lookup("RTX 3080").unwrap().memory_bytes() { "fits" } else { "does NOT fit" },
    );

    // Shape checks mirroring Figure 6's narrative.
    let fast = estimate(&cfg, 50, "RTX 3080", LinkModel::datacenter());
    let slow = estimate(&cfg, 50, "RTX 3080", LinkModel::from_ms_mbps(10.0, 100.0));
    let fast_ratio = fast.steady_state_throughput() / baseline.steady_state_throughput();
    assert!((0.5..2.0).contains(&fast_ratio), "compute-bound ratio {fast_ratio}");
    assert!(slow.steady_state_throughput() < 0.1 * baseline.steady_state_throughput());
    // Crossover happens at LOWER bandwidth than Bert-Large (more FLOPs per
    // byte moved): find first compute-bound bandwidth at α=1ms.
    let bert = TransformerConfig::bert_large();
    let crossover = |cfg: &TransformerConfig| -> f64 {
        for &mbps in &[10.0, 100.0, 1_000.0, 10_000.0, 100_000.0, 400_000.0, 4_000_000.0] {
            if !estimate(cfg, 50, "RTX 3080", LinkModel::from_ms_mbps(0.1, mbps)).comm_bound() {
                return mbps;
            }
        }
        f64::INFINITY
    };
    let (xb, xg) = (crossover(&bert), crossover(&cfg));
    println!("compute-bound crossover: bert-large at {xb:.0} Mbps vs gpt3 at {xg:.0} Mbps");
    assert!(xg <= xb, "bigger model ⇒ earlier crossover");
}
