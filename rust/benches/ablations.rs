//! Ablations over the design choices DESIGN.md calls out:
//!
//! 1. **Scheduler** (Eq. 2): LPT+refine vs plain LPT vs round-robin vs
//!    random, on a heterogeneous fleet — the "heterogeneity-aware
//!    scheduling matters" claim of §2.2/§3.8.
//! 2. **Compression** (§2.3): wire bytes + error for none/int8/top-k, and
//!    the comm-bound throughput each buys.
//! 3. **Fault tolerance** (§3.2): backup-pool takeover + checkpoint restore
//!    vs cold restart — steps of progress lost.
//! 4. **Local-SGD** (§2.3): parameter-sync traffic vs sync period.
//!
//! Run: `cargo bench --bench ablations`

use std::sync::Arc;

use fusionai::benchutil::Table;
use fusionai::cluster::SimCluster;
use fusionai::compress::{Codec, LocalSgdPolicy};
use fusionai::decompose::Decomposition;
use fusionai::exec::{Adam, RefEngine};
use fusionai::models::transformer::TransformerConfig;
use fusionai::net::{NetworkSim, Topology};
use fusionai::perf::comm::LinkModel;
use fusionai::perf::gpus::lookup;
use fusionai::sched::{self, PeerSpec, TaskSpec};
use fusionai::tensor::Tensor;
use fusionai::util::{human_bytes, human_secs, Rng};

fn main() {
    scheduler_ablation();
    compression_ablation();
    fault_tolerance_ablation();
    local_sgd_ablation();
}

fn scheduler_ablation() {
    println!("=== ablation 1: scheduling strategy (Eq. 2) ===\n");
    // Heterogeneous fleet: 3080s, 3060s, a couple of 4090s.
    let mut peers: Vec<PeerSpec> = Vec::new();
    for (gpu, n) in [("RTX 3080", 10), ("RTX 3060", 10), ("RTX 4090", 2)] {
        for _ in 0..n {
            let mut p = sched::build::uniform_peers(lookup(gpu).unwrap(), 0.5, 1);
            p[0].id = peers.len();
            peers.push(p.remove(0));
        }
    }
    // Bert-Large split into 66 sub-tasks.
    let g = TransformerConfig::bert_large().build_graph();
    let d = Decomposition::chain_balanced(&g, 66);
    let tasks: Vec<TaskSpec> = sched::build::tasks_from_decomposition(&g, &d, true);

    let mut rng = Rng::new(7);
    let mut t = Table::new(&["strategy", "makespan", "vs best"]);
    let full = sched::schedule(&tasks, &peers).unwrap().makespan();
    let lpt_only = sched::lpt(&tasks, &peers).unwrap().makespan();
    let rr = sched::round_robin(&tasks, &peers).unwrap().makespan();
    let rand: f64 = (0..10)
        .map(|_| sched::random_schedule(&tasks, &peers, &mut rng).unwrap().makespan())
        .sum::<f64>()
        / 10.0;
    for (name, v) in [
        ("LPT + local search (ours)", full),
        ("LPT only", lpt_only),
        ("round-robin (hetero-blind)", rr),
        ("random (10-run mean)", rand),
    ] {
        t.row(&[name.to_string(), human_secs(v), format!("{:.2}×", v / full)]);
    }
    t.print();
    assert!(full <= lpt_only + 1e-12);
    assert!(full < rr && full < rand);
    println!();
}

fn compression_ablation() {
    println!("=== ablation 2: communication compression (§2.3) ===\n");
    let n = 512 * 1024; // a Bert-Large-ish activation, elements
    let mut rng = Rng::new(3);
    let x: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
    let link = LinkModel::from_ms_mbps(10.0, 100.0);
    let mut t = Table::new(&["codec", "wire bytes", "ratio", "max |err|", "T_comm @100Mbps"]);
    for codec in [Codec::None, Codec::Int8, Codec::TopK { ratio: 0.1 }, Codec::TopK { ratio: 0.01 }] {
        let enc = codec.encode(&x);
        let dec = codec.decode(&enc, n);
        let err = x.iter().zip(&dec).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
        t.row(&[
            format!("{codec:?}"),
            human_bytes(enc.len() as u64),
            format!("{:.3}", codec.ratio(n)),
            format!("{err:.4}"),
            human_secs(link.time(enc.len() as u64)),
        ]);
    }
    t.print();
    println!();
}

fn fault_tolerance_ablation() {
    println!("=== ablation 3: backup pool + checkpoint vs cold restart (§3.2) ===\n");
    let cfg = TransformerConfig::tiny();
    let make_cluster = || {
        let g = cfg.build_graph();
        let d = Decomposition::chain_balanced(&g, 4);
        let net =
            Arc::new(NetworkSim::new(Topology::uniform(LinkModel::local()), 0.0));
        SimCluster::new(
            g,
            d,
            net,
            Box::new(|| Box::new(RefEngine::new())),
            Box::new(|| Box::new(Adam::new(0.01))),
            5,
        )
        .unwrap()
    };
    let feed = |c: &mut SimCluster| {
        let tokens: Vec<i32> =
            (0..cfg.batch * cfg.seq).map(|i| ((i * 7 + 3) % cfg.vocab) as i32).collect();
        let labels: Vec<i32> =
            tokens.iter().map(|&t| ((t as usize + 7) % cfg.vocab) as i32).collect();
        c.feed("tokens", Tensor::from_ivec(&[cfg.batch, cfg.seq], tokens)).unwrap();
        c.feed("labels", Tensor::from_ivec(&[cfg.batch, cfg.seq], labels)).unwrap();
    };

    // Train 20 steps, crash, recover from checkpoint, train 10 more.
    let mut warm = make_cluster();
    for _ in 0..20 {
        feed(&mut warm);
        warm.train_step().unwrap();
    }
    warm.fail_compnode(2);
    warm.recover_compnode(2).unwrap();
    let mut warm_loss = f32::NAN;
    for _ in 0..10 {
        feed(&mut warm);
        warm_loss = warm.train_step().unwrap().loss.unwrap();
    }

    // Cold restart: lose everything at the crash, 10 steps from scratch.
    let mut cold = make_cluster();
    let mut cold_loss = f32::NAN;
    for _ in 0..10 {
        feed(&mut cold);
        cold_loss = cold.train_step().unwrap().loss.unwrap();
    }

    let mut t = Table::new(&["strategy", "loss after crash + 10 steps"]);
    t.row(&["backup + supernode checkpoint (ours)".into(), format!("{warm_loss:.4}")]);
    t.row(&["cold restart".into(), format!("{cold_loss:.4}")]);
    t.print();
    assert!(warm_loss < cold_loss, "checkpoint recovery must retain progress");
    println!();
}

fn local_sgd_ablation() {
    println!("=== ablation 4: Local-SGD sync period (§2.3) ===\n");
    // Parameter-sync traffic for a 110M-param model over 1000 steps.
    let param_bytes: u64 = 110_000_000 * 4;
    let steps = 1000u64;
    let link = LinkModel::from_ms_mbps(10.0, 100.0);
    let mut t = Table::new(&["sync period", "syncs", "traffic", "modelled sync time"]);
    for period in [1usize, 4, 16, 64] {
        let mut policy = LocalSgdPolicy::every(period);
        let syncs = (0..steps).filter(|_| policy.tick()).count() as u64;
        let bytes = syncs * param_bytes;
        t.row(&[
            format!("every {period}"),
            syncs.to_string(),
            human_bytes(bytes),
            human_secs(link.time(param_bytes) * syncs as f64),
        ]);
    }
    t.print();
}
