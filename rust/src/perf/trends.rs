//! Figure-1 dataset: "The trends of GPU and model memory".
//!
//! The paper's Figure 1 plots the memory required by landmark models against
//! the memory of contemporary flagship GPUs over time, showing model memory
//! outpacing hardware. We encode the canonical public numbers so
//! `benches/fig1_trends.rs` can regenerate the figure's data series and its
//! growth-rate conclusion.

/// One model datapoint: year, name, parameter count, and the bytes needed
/// just to *hold* the parameters in fp16 (inference floor).
#[derive(Debug, Clone, Copy)]
pub struct ModelPoint {
    pub year: u32,
    pub name: &'static str,
    pub params: f64,
}

impl ModelPoint {
    /// fp16 parameter bytes.
    pub fn infer_bytes(&self) -> f64 {
        self.params * 2.0
    }
    /// Adam-trained fp16/fp32-mixed training footprint ≈ 16 bytes/param
    /// (params + grads + fp32 master + m + v), the standard estimate.
    pub fn train_bytes(&self) -> f64 {
        self.params * 16.0
    }
}

/// One GPU datapoint: year and device memory in GiB.
#[derive(Debug, Clone, Copy)]
pub struct GpuPoint {
    pub year: u32,
    pub name: &'static str,
    pub memory_gb: f64,
}

/// Landmark models, AlexNet → GPT-4 era (public figures).
pub static MODEL_TREND: &[ModelPoint] = &[
    ModelPoint { year: 2012, name: "AlexNet", params: 6.1e7 },
    ModelPoint { year: 2014, name: "VGG-19", params: 1.44e8 },
    ModelPoint { year: 2015, name: "ResNet-152", params: 6.0e7 },
    ModelPoint { year: 2018, name: "BERT-Large", params: 3.4e8 },
    ModelPoint { year: 2019, name: "GPT-2", params: 1.5e9 },
    ModelPoint { year: 2020, name: "GPT-3", params: 1.75e11 },
    ModelPoint { year: 2021, name: "Megatron-Turing", params: 5.3e11 },
    ModelPoint { year: 2022, name: "PaLM", params: 5.4e11 },
    ModelPoint { year: 2023, name: "GPT-4 (est.)", params: 1.8e12 },
];

/// Flagship training GPUs by launch year.
pub static GPU_TREND: &[GpuPoint] = &[
    GpuPoint { year: 2012, name: "K20 (GK110)", memory_gb: 5.0 },
    GpuPoint { year: 2014, name: "K80", memory_gb: 24.0 },
    GpuPoint { year: 2016, name: "P100", memory_gb: 16.0 },
    GpuPoint { year: 2017, name: "V100", memory_gb: 32.0 },
    GpuPoint { year: 2020, name: "A100", memory_gb: 80.0 },
    GpuPoint { year: 2022, name: "H100", memory_gb: 80.0 },
];

/// Compound annual growth rate of a series of `(year, value)` points,
/// fitted in log-space.
pub fn cagr(points: &[(u32, f64)]) -> f64 {
    let xs: Vec<f64> = points.iter().map(|&(y, _)| y as f64).collect();
    let ys: Vec<f64> = points.iter().map(|&(_, v)| v.ln()).collect();
    let (_, slope) = crate::util::stats::linfit(&xs, &ys);
    slope.exp() - 1.0
}

/// The Figure-1 takeaway, computed: model-memory CAGR vs GPU-memory CAGR.
pub fn growth_gap() -> (f64, f64) {
    let model: Vec<(u32, f64)> =
        MODEL_TREND.iter().map(|m| (m.year, m.train_bytes())).collect();
    let gpu: Vec<(u32, f64)> =
        GPU_TREND.iter().map(|g| (g.year, g.memory_gb * 1e9)).collect();
    (cagr(&model), cagr(&gpu))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn model_growth_outpaces_gpu_growth() {
        let (model, gpu) = growth_gap();
        assert!(model > gpu, "model CAGR {model} must exceed GPU CAGR {gpu}");
        // Figure 1's qualitative claim: model memory grows ~10x faster.
        assert!(model > 5.0 * gpu, "gap too small: {model} vs {gpu}");
    }

    #[test]
    fn cagr_of_doubling_series() {
        let pts: Vec<(u32, f64)> = (0..6).map(|i| (2000 + i, 2f64.powi(i as i32))).collect();
        let r = cagr(&pts);
        assert!((r - 1.0).abs() < 1e-9, "doubling = 100% CAGR, got {r}");
    }

    #[test]
    fn gpt3_doesnt_fit_any_gpu() {
        let gpt3 = MODEL_TREND.iter().find(|m| m.name == "GPT-3").unwrap();
        let biggest = GPU_TREND.iter().map(|g| g.memory_gb * 1e9).fold(0.0, f64::max);
        assert!(gpt3.infer_bytes() > biggest);
    }
}
