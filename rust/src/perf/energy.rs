//! Energy & carbon accounting (paper §2.8).
//!
//! "The energy consumption of high-end GPUs has become a bottleneck for
//! training large models. In contrast, our proposed FusionAI can address
//! this bottleneck by providing feasibility in terms of power consumption."
//!
//! A simple but standard estimator: `E = Σ_devices TDP · utilization · T`,
//! with utilization split between the compute-busy fraction (the Eq.-4
//! steady-state duty cycle of each stage) and an idle floor. This is the
//! model behind the energy columns of `examples/estimate_cluster.rs`.

use crate::pipeline::analytics::PipelineEstimate;

/// Board power (W) for the devices in the GPU database.
pub fn tdp_watts(gpu_name: &str) -> f64 {
    match gpu_name {
        "RTX 4090" => 450.0,
        "RTX 4080" => 320.0,
        "RTX 3090" => 350.0,
        "RTX 3080" => 320.0,
        "RTX 3070" => 220.0,
        "RTX 3060" => 170.0,
        "GTX 1080 Ti" => 250.0,
        "H100" => 700.0,
        "A100" => 400.0,
        "V100" => 300.0,
        _ => 300.0,
    }
}

/// Idle power as a fraction of TDP (consumer boards idle low; datacenter
/// boards in a loaded chassis less so).
pub const IDLE_FRACTION: f64 = 0.1;

/// Energy estimate for one pipelined run.
#[derive(Debug, Clone)]
pub struct EnergyEstimate {
    /// Joules consumed across the fleet.
    pub joules: f64,
    /// kWh, for humans.
    pub kwh: f64,
    /// Mean per-device duty cycle (busy fraction).
    pub duty_cycle: f64,
}

/// Estimate fleet energy for processing `n_b` batches on a pipeline whose
/// per-stage costs come from the §4 analytic model. A device draws full
/// TDP only while *computing* (`C_p` per batch); waiting on the network
/// draws the idle floor — which is exactly why a comm-bound fleet has an
/// abysmal duty cycle.
pub fn pipeline_energy(
    est: &PipelineEstimate,
    tdps: &[f64],
    n_b: usize,
) -> EnergyEstimate {
    assert_eq!(est.stages.len(), tdps.len());
    let wall = est.pipelined_time(n_b);
    let mut joules = 0.0;
    let mut duty_sum = 0.0;
    for (s, &tdp) in est.stages.iter().zip(tdps) {
        // Device computes n_b times for C_p each.
        let busy = (n_b as f64 * s.compute_s).min(wall);
        let idle = wall - busy;
        joules += tdp * busy + IDLE_FRACTION * tdp * idle;
        duty_sum += busy / wall;
    }
    EnergyEstimate {
        joules,
        kwh: joules / 3.6e6,
        duty_cycle: duty_sum / est.stages.len() as f64,
    }
}

/// Grid carbon intensity (kg CO₂e per kWh) presets.
pub fn carbon_kg(kwh: f64, intensity_kg_per_kwh: f64) -> f64 {
    kwh * intensity_kg_per_kwh
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decompose::Decomposition;
    use crate::models::transformer::TransformerConfig;
    use crate::perf::comm::LinkModel;
    use crate::perf::gpus::lookup;
    use crate::perf::paleo::{DeviceProfile, PaleoModel};

    fn est(n: usize, gpu: &str, link: LinkModel) -> PipelineEstimate {
        let g = TransformerConfig::bert_large().build_graph();
        let d = Decomposition::chain_balanced(&g, n);
        let models: Vec<PaleoModel> = (0..n)
            .map(|_| PaleoModel::new(DeviceProfile::with_lambda(lookup(gpu).unwrap(), 0.5)))
            .collect();
        PipelineEstimate::from_decomposition(&g, &d, &models, link, false)
    }

    #[test]
    fn known_tdps() {
        assert_eq!(tdp_watts("H100"), 700.0);
        assert_eq!(tdp_watts("RTX 3080"), 320.0);
        assert_eq!(tdp_watts("something else"), 300.0);
    }

    #[test]
    fn energy_scales_with_batches() {
        let e = est(4, "H100", LinkModel::datacenter());
        let tdps = vec![700.0; 4];
        let e1 = pipeline_energy(&e, &tdps, 64);
        let e2 = pipeline_energy(&e, &tdps, 512);
        assert!(e2.joules > 6.0 * e1.joules, "{} vs {}", e2.joules, e1.joules);
        assert!(e1.duty_cycle > 0.0 && e1.duty_cycle <= 1.0);
    }

    #[test]
    fn comm_bound_fleet_wastes_energy_idling() {
        // At 100 Mbps, the consumer fleet's devices idle most of the time —
        // low duty cycle, poor joules-per-batch vs the compute-bound H100s.
        let consumer = est(50, "RTX 3080", LinkModel::from_ms_mbps(10.0, 100.0));
        let dc = est(4, "H100", LinkModel::datacenter());
        let ec = pipeline_energy(&consumer, &vec![320.0; 50], 512);
        let ed = pipeline_energy(&dc, &vec![700.0; 4], 512);
        assert!(ec.duty_cycle < 0.2, "duty {}", ec.duty_cycle);
        // Joules per batch: consumer fleet is far worse when comm-bound.
        assert!(ec.joules / 512.0 > 5.0 * ed.joules / 512.0);
    }

    #[test]
    fn carbon_conversion() {
        assert!((carbon_kg(10.0, 0.4) - 4.0).abs() < 1e-12);
    }
}
