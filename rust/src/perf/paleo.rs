//! PALEO-style per-operator execution-time model (paper §3.7).
//!
//! `T(f, p) = R(Pa(f)) + C(f, p) + W(f, p)` where
//! * `C(f,p) = FLOPs(f) / S(p)` — compute time,
//! * `S(p) = λ_p · S*(p)` — achieved speed = scaling-down factor × peak,
//! * `R(Pa(f))` — time to retrieve inputs from parents (communication when
//!   the parent lives on another compnode, paper Eq. 1),
//! * `W(f,p)` — time to write outputs to local memory.
//!
//! `λ_p` is fitted by a short profiling run ([`fit_lambda`]), exactly the
//! "regression-based scaling-down factor" of the paper.

use crate::dag::{flops, Graph, Node, NodeId};
use crate::perf::comm::LinkModel;
use crate::perf::gpus::GpuSpec;
use crate::util::stats::linfit_origin;

/// A device (compnode hardware) as the performance model sees it.
#[derive(Debug, Clone)]
pub struct DeviceProfile {
    pub gpu: GpuSpec,
    /// Scaling-down factor λ_p ∈ (0, 1]: achieved/peak.
    pub lambda: f64,
    /// Effective device-memory bandwidth in bytes/s (for the W term).
    pub mem_bw: f64,
}

impl DeviceProfile {
    /// A device running at a fraction of peak. The paper notes real speed
    /// "may not reach the peak performance"; 0.3–0.6 is typical for mixed
    /// transformer workloads.
    pub fn with_lambda(gpu: &GpuSpec, lambda: f64) -> DeviceProfile {
        DeviceProfile {
            gpu: gpu.clone(),
            lambda,
            // Rough HBM/GDDR bandwidth proportional to compute class.
            mem_bw: 0.5e12,
        }
    }

    /// Achieved speed S(p) = λ·S*(p) in FLOP/s (tensor-core peak, which is
    /// what the paper's §4 estimate uses).
    pub fn achieved_flops(&self) -> f64 {
        self.lambda * self.gpu.peak_tensor_flops()
    }
}

/// The assembled PALEO model for one device.
#[derive(Debug, Clone)]
pub struct PaleoModel {
    pub device: DeviceProfile,
}

impl PaleoModel {
    pub fn new(device: DeviceProfile) -> PaleoModel {
        PaleoModel { device }
    }

    /// `C(f,p)`: compute time of node `f` (forward).
    pub fn compute_time(&self, f: &Node) -> f64 {
        flops::fwd_flops(f) / self.device.achieved_flops()
    }

    /// `C(f,p)` for the backward task of `f`.
    pub fn compute_time_bwd(&self, f: &Node) -> f64 {
        flops::bwd_flops(f) / self.device.achieved_flops()
    }

    /// `W(f,p)`: write the output activation to local memory.
    pub fn write_time(&self, f: &Node) -> f64 {
        flops::activation_bytes(f) as f64 / self.device.mem_bw
    }

    /// `R(Pa(f))`: retrieve inputs from parents. `remote` gives, per parent,
    /// the link to cross (None = same compnode → local read, costed at
    /// memory bandwidth; the paper removes this term entirely for co-located
    /// parents, and it is indeed negligible).
    pub fn read_time(&self, g: &Graph, f: &Node, remote: &dyn Fn(NodeId) -> Option<LinkModel>) -> f64 {
        f.args
            .iter()
            .map(|&a| {
                let bytes = flops::activation_bytes(g.node(a));
                match remote(a) {
                    Some(link) => link.time(bytes),
                    None => bytes as f64 / self.device.mem_bw,
                }
            })
            .sum()
    }

    /// Full Eq. 1: `T(f,p) = R + C + W` for the forward task.
    pub fn node_time(
        &self,
        g: &Graph,
        f: NodeId,
        remote: &dyn Fn(NodeId) -> Option<LinkModel>,
    ) -> f64 {
        let node = g.node(f);
        self.read_time(g, node, remote) + self.compute_time(node) + self.write_time(node)
    }

    /// Execution time of a whole sub-DAG on this device, assuming serial
    /// execution of its operators (the paper bounds the true value by
    /// `[max_i T(fᶦ,p), Σ_i T(fᶦ,p)]`; pipeline-parallel models are
    /// sequential chains, so the upper bound is exact for them and is what
    /// §4 uses).
    pub fn subgraph_time(
        &self,
        g: &Graph,
        nodes: &[NodeId],
        remote: &dyn Fn(NodeId) -> Option<LinkModel>,
    ) -> f64 {
        nodes.iter().map(|&f| self.node_time(g, f, remote)).sum()
    }

    /// The paper's lower/upper bound interval for a sub-DAG.
    pub fn subgraph_time_bounds(
        &self,
        g: &Graph,
        nodes: &[NodeId],
        remote: &dyn Fn(NodeId) -> Option<LinkModel>,
    ) -> (f64, f64) {
        let times: Vec<f64> = nodes.iter().map(|&f| self.node_time(g, f, remote)).collect();
        let max = times.iter().copied().fold(0.0, f64::max);
        let sum = times.iter().sum();
        (max, sum)
    }
}

/// Fit λ_p from profiling pairs `(work_flops, measured_seconds)`:
/// measured ≈ work / (λ·S*) ⇒ measured ≈ (1/(λ·S*)) · work, a
/// through-origin regression on work→time whose slope is `1/(λ·S*)`.
pub fn fit_lambda(peak_flops: f64, samples: &[(f64, f64)]) -> f64 {
    let xs: Vec<f64> = samples.iter().map(|&(w, _)| w).collect();
    let ys: Vec<f64> = samples.iter().map(|&(_, t)| t).collect();
    let slope = linfit_origin(&xs, &ys);
    if slope <= 0.0 {
        return 1.0;
    }
    (1.0 / (slope * peak_flops)).clamp(1e-4, 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dag::{DType, Graph, OpKind, Shape};
    use crate::perf::gpus::lookup;

    fn toy() -> (Graph, NodeId) {
        let mut g = Graph::new();
        let x = g.placeholder("x", Shape::of(&[32, 1024]), DType::F32);
        let l = g
            .op("fc", OpKind::Linear { in_features: 1024, out_features: 1024, bias: false }, &[x])
            .unwrap();
        (g, l)
    }

    #[test]
    fn compute_time_scales_with_lambda() {
        let (g, l) = toy();
        let gpu = lookup("RTX 3080").unwrap();
        let fast = PaleoModel::new(DeviceProfile::with_lambda(gpu, 0.8));
        let slow = PaleoModel::new(DeviceProfile::with_lambda(gpu, 0.4));
        let tf = fast.compute_time(g.node(l));
        let ts = slow.compute_time(g.node(l));
        assert!((ts / tf - 2.0).abs() < 1e-9);
    }

    #[test]
    fn remote_read_dominates_on_wan() {
        let (g, l) = toy();
        let gpu = lookup("RTX 3080").unwrap();
        let m = PaleoModel::new(DeviceProfile::with_lambda(gpu, 0.5));
        let local = m.node_time(&g, l, &|_| None);
        let wan = m.node_time(&g, l, &|_| Some(LinkModel::consumer_wan()));
        assert!(wan > 10.0 * local, "wan={wan} local={local}");
    }

    #[test]
    fn subgraph_bounds_ordered() {
        let (g, _) = toy();
        let gpu = lookup("A100").unwrap();
        let m = PaleoModel::new(DeviceProfile::with_lambda(gpu, 0.5));
        let ids: Vec<NodeId> = g.nodes.iter().map(|n| n.id).collect();
        let (lo, hi) = m.subgraph_time_bounds(&g, &ids, &|_| None);
        let serial = m.subgraph_time(&g, &ids, &|_| None);
        assert!(lo <= hi);
        assert!((serial - hi).abs() < 1e-12);
    }

    #[test]
    fn lambda_fit_recovers_truth() {
        let gpu = lookup("RTX 3080").unwrap();
        let truth = 0.45;
        let s = truth * gpu.peak_tensor_flops();
        let samples: Vec<(f64, f64)> =
            [1e9, 5e9, 2e10, 8e10].iter().map(|&w| (w, w / s)).collect();
        let fitted = fit_lambda(gpu.peak_tensor_flops(), &samples);
        assert!((fitted - truth).abs() < 1e-6, "fitted {fitted}");
    }

    #[test]
    fn lambda_fit_clamps_degenerate() {
        let gpu = lookup("RTX 3080").unwrap();
        assert_eq!(fit_lambda(gpu.peak_tensor_flops(), &[]), 1.0);
    }
}
