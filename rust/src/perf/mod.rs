//! Analytic hardware performance modeling (paper §3.7 & §4).
//!
//! * [`gpus`] — the GPU spec database (paper Table 1 plus the other devices
//!   referenced in the text);
//! * [`paleo`] — the PALEO-style per-operator time model
//!   `T(f,p) = R(Pa(f)) + C(f,p) + W(f,p)` with the regression-fitted
//!   scaling-down factor `λ_p` so that `S(p) = λ_p · S*(p)`;
//! * [`comm`] — the α-β communication model `T = α + β·M` and link fitting;
//! * [`trends`] — the Figure-1 model-vs-GPU memory trend dataset.

pub mod comm;
pub mod energy;
pub mod gpus;
pub mod paleo;
pub mod trends;

pub use comm::LinkModel;
pub use gpus::{GpuSpec, GPU_DB};
pub use paleo::{DeviceProfile, PaleoModel};
