//! GPU specification database (paper Table 1, extended).
//!
//! FLOPS figures are the vendor peak numbers the paper quotes; the paper's
//! throughput estimates use the **FP32 Tensor Core** column ("We coarsely
//! estimate the computation time C_p based on FLOPs of sub-DAGs and TFLOPS
//! (FP32 Tensor Core) of GPUs", §4).

/// Market segment of a device (paper Table 1 "Level").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GpuLevel {
    Consumer,
    DataCenter,
}

impl std::fmt::Display for GpuLevel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            GpuLevel::Consumer => write!(f, "Consumer"),
            GpuLevel::DataCenter => write!(f, "Data Center"),
        }
    }
}

/// One GPU's static specification.
#[derive(Debug, Clone)]
pub struct GpuSpec {
    pub name: &'static str,
    /// Peak FP32 (CUDA-core) TFLOPS.
    pub tflops_fp32: f64,
    /// Peak FP32 Tensor-Core (TF32) TFLOPS — the column the paper's estimate
    /// uses.
    pub tflops_tensor: f64,
    /// Device memory in GiB.
    pub memory_gb: f64,
    pub level: GpuLevel,
    /// Approximate launch-year street price in USD (used by the
    /// cost-efficiency analysis in `examples/estimate_cluster.rs`; the paper
    /// argues 50×3080 is "much lower price" than 4×H100).
    pub price_usd: f64,
}

impl GpuSpec {
    /// Peak tensor FLOPS in FLOP/s (not TFLOPS).
    pub fn peak_tensor_flops(&self) -> f64 {
        self.tflops_tensor * 1e12
    }
    /// Peak FP32 FLOPS in FLOP/s.
    pub fn peak_fp32_flops(&self) -> f64 {
        self.tflops_fp32 * 1e12
    }
    /// Device memory in bytes.
    pub fn memory_bytes(&self) -> u64 {
        (self.memory_gb * 1024.0 * 1024.0 * 1024.0) as u64
    }
}

/// The database. The first five rows are exactly paper Table 1.
pub static GPU_DB: &[GpuSpec] = &[
    GpuSpec { name: "RTX 4090", tflops_fp32: 82.58, tflops_tensor: 82.58, memory_gb: 24.0, level: GpuLevel::Consumer, price_usd: 1599.0 },
    GpuSpec { name: "RTX 4080", tflops_fp32: 48.74, tflops_tensor: 97.5, memory_gb: 16.0, level: GpuLevel::Consumer, price_usd: 1199.0 },
    GpuSpec { name: "RTX 3080", tflops_fp32: 29.77, tflops_tensor: 59.5, memory_gb: 10.0, level: GpuLevel::Consumer, price_usd: 699.0 },
    GpuSpec { name: "H100", tflops_fp32: 51.22, tflops_tensor: 756.0, memory_gb: 80.0, level: GpuLevel::DataCenter, price_usd: 30000.0 },
    GpuSpec { name: "A100", tflops_fp32: 19.49, tflops_tensor: 155.92, memory_gb: 80.0, level: GpuLevel::DataCenter, price_usd: 15000.0 },
    // Referenced elsewhere in the paper / useful for heterogeneous fleets.
    GpuSpec { name: "V100", tflops_fp32: 14.13, tflops_tensor: 112.0, memory_gb: 32.0, level: GpuLevel::DataCenter, price_usd: 10000.0 },
    GpuSpec { name: "RTX 3090", tflops_fp32: 35.58, tflops_tensor: 71.0, memory_gb: 24.0, level: GpuLevel::Consumer, price_usd: 1499.0 },
    GpuSpec { name: "RTX 3070", tflops_fp32: 20.31, tflops_tensor: 40.6, memory_gb: 8.0, level: GpuLevel::Consumer, price_usd: 499.0 },
    GpuSpec { name: "RTX 3060", tflops_fp32: 12.74, tflops_tensor: 25.4, memory_gb: 12.0, level: GpuLevel::Consumer, price_usd: 329.0 },
    GpuSpec { name: "GTX 1080 Ti", tflops_fp32: 11.34, tflops_tensor: 11.34, memory_gb: 11.0, level: GpuLevel::Consumer, price_usd: 699.0 },
];

/// Look a GPU up by (case-insensitive) name.
pub fn lookup(name: &str) -> Option<&'static GpuSpec> {
    let want = name.to_ascii_lowercase();
    GPU_DB.iter().find(|g| g.name.to_ascii_lowercase() == want)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_rows_present() {
        for name in ["RTX 4090", "RTX 4080", "RTX 3080", "H100", "A100"] {
            assert!(lookup(name).is_some(), "missing {name}");
        }
    }

    #[test]
    fn table1_values_exact() {
        let g3080 = lookup("rtx 3080").unwrap();
        assert_eq!(g3080.tflops_fp32, 29.77);
        assert_eq!(g3080.tflops_tensor, 59.5);
        assert_eq!(g3080.memory_gb, 10.0);
        assert_eq!(g3080.level, GpuLevel::Consumer);
        let h100 = lookup("H100").unwrap();
        assert_eq!(h100.tflops_tensor, 756.0);
        assert_eq!(h100.level, GpuLevel::DataCenter);
    }

    #[test]
    fn headline_flops_ratio() {
        // The paper's headline: 50×3080 ≈ 4×H100 in aggregate tensor FLOPS.
        let r3080 = lookup("RTX 3080").unwrap().peak_tensor_flops();
        let h100 = lookup("H100").unwrap().peak_tensor_flops();
        let ratio = (50.0 * r3080) / (4.0 * h100);
        assert!((0.9..1.1).contains(&ratio), "aggregate ratio {ratio}");
    }

    #[test]
    fn memory_bytes() {
        assert_eq!(lookup("H100").unwrap().memory_bytes(), 80 * 1024 * 1024 * 1024);
    }
}
