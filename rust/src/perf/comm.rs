//! The α-β communication model (paper §3.3):
//! `T_comm^{ij}(M) = α^{ij} + β^{ij} · M`
//! where `α` is link latency (s), `β` the inverse bandwidth (s/byte) and `M`
//! the message size in bytes.
//!
//! [`LinkModel::fit`] recovers `(α, β)` from measured (size, time) pairs by
//! least squares — the "short period of profiling to fit a few parameters"
//! of §3.7, applied to links.

use crate::util::stats::linfit;

/// One directed link's α-β parameters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkModel {
    /// Latency in seconds.
    pub alpha: f64,
    /// Inverse bandwidth in seconds per byte.
    pub beta: f64,
}

impl LinkModel {
    /// From latency (seconds) + bandwidth (bytes/sec).
    pub fn new(alpha_s: f64, bandwidth_bps: f64) -> LinkModel {
        LinkModel { alpha: alpha_s, beta: 1.0 / bandwidth_bps }
    }

    /// Convenience: latency in ms, bandwidth in Mbit/s (the units of the
    /// paper's Figure 5/6 sweeps).
    pub fn from_ms_mbps(alpha_ms: f64, mbps: f64) -> LinkModel {
        LinkModel::new(alpha_ms * 1e-3, mbps * 1e6 / 8.0)
    }

    /// Loopback/local: effectively free (the paper drops R(Pa(f)) when
    /// producer and consumer share a device).
    pub fn local() -> LinkModel {
        LinkModel { alpha: 0.0, beta: 0.0 }
    }

    /// A typical datacenter NVLink-class link (used for the H100 baseline):
    /// ~5 µs latency, 400 Gbit/s effective.
    pub fn datacenter() -> LinkModel {
        LinkModel::new(5e-6, 400e9 / 8.0)
    }

    /// A typical consumer broadband WAN link: 20 ms, 100 Mbit/s.
    pub fn consumer_wan() -> LinkModel {
        LinkModel::from_ms_mbps(20.0, 100.0)
    }

    /// Predicted transfer time for `bytes`.
    pub fn time(&self, bytes: u64) -> f64 {
        self.alpha + self.beta * bytes as f64
    }

    /// Bandwidth in bytes/sec.
    #[allow(clippy::float_cmp)] // beta == 0.0 means an explicitly infinite link
    pub fn bandwidth(&self) -> f64 {
        if self.beta == 0.0 {
            f64::INFINITY
        } else {
            1.0 / self.beta
        }
    }

    /// Least-squares fit from `(message_bytes, seconds)` measurements.
    /// Negative fitted parameters are clamped to 0 (noise on tiny samples).
    pub fn fit(samples: &[(u64, f64)]) -> LinkModel {
        let xs: Vec<f64> = samples.iter().map(|&(m, _)| m as f64).collect();
        let ys: Vec<f64> = samples.iter().map(|&(_, t)| t).collect();
        let (a, b) = linfit(&xs, &ys);
        LinkModel { alpha: a.max(0.0), beta: b.max(0.0) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_is_affine() {
        let l = LinkModel::new(0.01, 1_000_000.0);
        assert!((l.time(0) - 0.01).abs() < 1e-12);
        assert!((l.time(1_000_000) - 1.01).abs() < 1e-9);
    }

    #[test]
    fn mbps_conversion() {
        // 100 Mbit/s = 12.5 MB/s; 12.5 MB should take ~1 s + latency.
        let l = LinkModel::from_ms_mbps(10.0, 100.0);
        let t = l.time(12_500_000);
        assert!((t - 1.01).abs() < 1e-9, "t={t}");
    }

    #[test]
    fn fit_recovers_parameters() {
        let truth = LinkModel::new(0.02, 50e6);
        let samples: Vec<(u64, f64)> =
            [1_000u64, 100_000, 1_000_000, 10_000_000].iter().map(|&m| (m, truth.time(m))).collect();
        let fitted = LinkModel::fit(&samples);
        assert!((fitted.alpha - truth.alpha).abs() < 1e-9);
        assert!((fitted.beta - truth.beta).abs() < 1e-15);
    }

    #[test]
    fn local_is_free() {
        assert_eq!(LinkModel::local().time(u64::MAX / 2), 0.0);
    }

    #[test]
    fn wan_slower_than_datacenter() {
        let m = 10_000_000u64;
        assert!(LinkModel::consumer_wan().time(m) > 100.0 * LinkModel::datacenter().time(m));
    }
}
