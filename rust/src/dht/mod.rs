//! Distributed hash table (paper §3.4, §3.9).
//!
//! "For efficient management of distributed storage and lookup of data, we
//! leverage the power of Distributed Hash Table. […] Each compnode
//! independently stores and retrieves data, making the system resilient to
//! individual node failures."
//!
//! Implementation: a consistent-hash ring with virtual nodes and k-way
//! successor replication. Keys are strings (e.g. `"dataset/shard/17"`,
//! `"act/job3/node41/mb2"`); values are opaque byte blobs. Node join/leave
//! triggers the minimal re-replication consistent hashing promises, and
//! reads fall back across replicas — `get` succeeds as long as at least one
//! replica survives, which is the churn-resilience property the paper
//! relies on for dataset and activation distribution.

use std::collections::{BTreeMap, HashMap, HashSet};

use crate::util::fnv1a;

/// Peer identifier (same id space as compnodes).
pub type PeerId = usize;

/// Number of virtual nodes per peer on the ring (smooths key distribution).
const VNODES: usize = 32;

/// One peer's local key-value store.
#[derive(Debug, Default, Clone)]
pub struct LocalStore {
    map: HashMap<String, Vec<u8>>,
}

impl LocalStore {
    pub fn len(&self) -> usize {
        self.map.len()
    }
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
    pub fn bytes(&self) -> u64 {
        self.map.values().map(|v| v.len() as u64).sum()
    }
}

/// The DHT: ring membership + per-peer stores + replication policy.
#[derive(Debug)]
pub struct Dht {
    ring: BTreeMap<u64, PeerId>,
    stores: HashMap<PeerId, LocalStore>,
    replication: usize,
}

/// DHT operation errors.
#[derive(Debug, PartialEq)]
pub enum DhtError {
    Empty,
    NotFound(String),
    AlreadyJoined(PeerId),
    UnknownPeer(PeerId),
}

impl std::fmt::Display for DhtError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DhtError::Empty => write!(f, "no peers in the ring"),
            DhtError::NotFound(key) => write!(f, "key '{key}' not found on any live replica"),
            DhtError::AlreadyJoined(p) => write!(f, "peer {p} already joined"),
            DhtError::UnknownPeer(p) => write!(f, "peer {p} not in the ring"),
        }
    }
}

impl std::error::Error for DhtError {}

/// SplitMix64 finalizer: FNV on short, similar strings clusters in the low
/// bits; this scatters ring positions uniformly.
fn mix64(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

fn vnode_hash(peer: PeerId, v: usize) -> u64 {
    mix64(fnv1a(format!("peer:{peer}:vnode:{v}").as_bytes()))
}

fn key_hash(key: &str) -> u64 {
    mix64(fnv1a(key.as_bytes()))
}

impl Dht {
    /// Create with a replication factor (k successors store each key).
    pub fn new(replication: usize) -> Dht {
        Dht { ring: BTreeMap::new(), stores: HashMap::new(), replication: replication.max(1) }
    }

    pub fn peers(&self) -> Vec<PeerId> {
        let mut p: Vec<PeerId> = self.stores.keys().copied().collect();
        p.sort();
        p
    }

    pub fn len_peers(&self) -> usize {
        self.stores.len()
    }

    /// Add a peer; re-replicates affected keys.
    pub fn join(&mut self, peer: PeerId) -> Result<(), DhtError> {
        if self.stores.contains_key(&peer) {
            return Err(DhtError::AlreadyJoined(peer));
        }
        self.stores.insert(peer, LocalStore::default());
        for v in 0..VNODES {
            self.ring.insert(vnode_hash(peer, v), peer);
        }
        self.rebalance();
        Ok(())
    }

    /// Graceful or crash departure: the peer's store is dropped (crash
    /// semantics — data survives only via replicas), ring entries removed,
    /// then re-replication restores the invariant.
    pub fn leave(&mut self, peer: PeerId) -> Result<(), DhtError> {
        if self.stores.remove(&peer).is_none() {
            return Err(DhtError::UnknownPeer(peer));
        }
        for v in 0..VNODES {
            self.ring.remove(&vnode_hash(peer, v));
        }
        self.rebalance();
        Ok(())
    }

    /// The replica set for a key: first `replication` *distinct* peers
    /// clockwise from the key's hash.
    pub fn owners(&self, key: &str) -> Vec<PeerId> {
        let h = key_hash(key);
        let mut owners = Vec::new();
        let mut seen = HashSet::new();
        for (_, &p) in self.ring.range(h..).chain(self.ring.range(..h)) {
            if seen.insert(p) {
                owners.push(p);
                if owners.len() == self.replication.min(self.stores.len()) {
                    break;
                }
            }
        }
        owners
    }

    /// Store a value on all replicas.
    pub fn put(&mut self, key: &str, value: Vec<u8>) -> Result<Vec<PeerId>, DhtError> {
        let owners = self.owners(key);
        if owners.is_empty() {
            return Err(DhtError::Empty);
        }
        for &p in &owners {
            self.stores.get_mut(&p).unwrap().map.insert(key.to_string(), value.clone());
        }
        Ok(owners)
    }

    /// Read from the first replica holding the key.
    pub fn get(&self, key: &str) -> Result<&[u8], DhtError> {
        if self.stores.is_empty() {
            return Err(DhtError::Empty);
        }
        for p in self.owners(key) {
            if let Some(v) = self.stores.get(&p).and_then(|s| s.map.get(key)) {
                return Ok(v);
            }
        }
        // Fall back to a full scan (a replica may hold stale extra copies
        // after churn; correctness over elegance).
        for s in self.stores.values() {
            if let Some(v) = s.map.get(key) {
                return Ok(v);
            }
        }
        Err(DhtError::NotFound(key.to_string()))
    }

    /// Remove a key everywhere.
    pub fn delete(&mut self, key: &str) {
        for s in self.stores.values_mut() {
            s.map.remove(key);
        }
    }

    /// Restore the replication invariant after membership changes: every
    /// key present anywhere must live exactly on its current owner set.
    fn rebalance(&mut self) {
        if self.stores.is_empty() {
            return;
        }
        // Collect all (key, value) pairs (replicas dedupe by key).
        let mut all: HashMap<String, Vec<u8>> = HashMap::new();
        for s in self.stores.values() {
            for (k, v) in &s.map {
                all.entry(k.clone()).or_insert_with(|| v.clone());
            }
        }
        for s in self.stores.values_mut() {
            s.map.clear();
        }
        for (k, v) in all {
            let owners = self.owners(&k);
            for p in owners {
                self.stores.get_mut(&p).unwrap().map.insert(k.clone(), v.clone());
            }
        }
    }

    /// Per-peer key counts (used by balance tests / metrics).
    pub fn distribution(&self) -> HashMap<PeerId, usize> {
        self.stores.iter().map(|(&p, s)| (p, s.len())).collect()
    }

    /// Total stored bytes (including replication).
    pub fn total_bytes(&self) -> u64 {
        self.stores.values().map(|s| s.bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dht_with(n: usize, repl: usize) -> Dht {
        let mut d = Dht::new(repl);
        for p in 0..n {
            d.join(p).unwrap();
        }
        d
    }

    #[test]
    fn put_get_roundtrip() {
        let mut d = dht_with(5, 2);
        d.put("hello", b"world".to_vec()).unwrap();
        assert_eq!(d.get("hello").unwrap(), b"world");
        assert_eq!(d.get("missing"), Err(DhtError::NotFound("missing".into())));
    }

    #[test]
    fn replication_factor_respected() {
        let mut d = dht_with(5, 3);
        let owners = d.put("k", vec![1]).unwrap();
        assert_eq!(owners.len(), 3);
        let holding = d.stores.values().filter(|s| s.map.contains_key("k")).count();
        assert_eq!(holding, 3);
    }

    #[test]
    fn survives_replica_failures() {
        let mut d = dht_with(6, 3);
        for i in 0..100 {
            d.put(&format!("key/{i}"), vec![i as u8]).unwrap();
        }
        // Kill two peers — with replication 3 every key must survive.
        let victims: Vec<PeerId> = d.peers().into_iter().take(2).collect();
        for v in victims {
            d.leave(v).unwrap();
        }
        for i in 0..100 {
            assert_eq!(d.get(&format!("key/{i}")).unwrap(), &[i as u8]);
        }
    }

    #[test]
    fn join_rebalances_and_preserves_data() {
        let mut d = dht_with(3, 2);
        for i in 0..50 {
            d.put(&format!("k{i}"), vec![i as u8]).unwrap();
        }
        d.join(99).unwrap();
        for i in 0..50 {
            assert_eq!(d.get(&format!("k{i}")).unwrap(), &[i as u8]);
        }
        // Invariant: every key lives exactly on its owner set.
        for i in 0..50 {
            let key = format!("k{i}");
            let owners: HashSet<PeerId> = d.owners(&key).into_iter().collect();
            for (&p, s) in &d.stores {
                assert_eq!(s.map.contains_key(&key), owners.contains(&p), "key {key} peer {p}");
            }
        }
    }

    #[test]
    fn distribution_roughly_balanced() {
        let mut d = dht_with(8, 1);
        for i in 0..2000 {
            d.put(&format!("obj/{i}"), vec![0u8]).unwrap();
        }
        let dist = d.distribution();
        let min = *dist.values().min().unwrap();
        let max = *dist.values().max().unwrap();
        // Virtual nodes keep skew moderate.
        assert!(min > 0, "some peer owns nothing");
        assert!((max as f64) < 4.0 * (min as f64).max(1.0), "skew {min}..{max}");
    }

    #[test]
    fn membership_errors() {
        let mut d = dht_with(2, 1);
        assert_eq!(d.join(0), Err(DhtError::AlreadyJoined(0)));
        assert_eq!(d.leave(42), Err(DhtError::UnknownPeer(42)));
        let empty = Dht::new(2);
        assert_eq!(empty.get("x"), Err(DhtError::Empty));
    }

    #[test]
    fn delete_removes_everywhere() {
        let mut d = dht_with(4, 2);
        d.put("gone", vec![9]).unwrap();
        d.delete("gone");
        assert!(matches!(d.get("gone"), Err(DhtError::NotFound(_))));
    }
}
