//! Dense row-major f32/i32 tensors and the numeric kernels used by the
//! pure-rust reference engine ([`crate::exec::RefEngine`]).
//!
//! This is deliberately simple, correct, testable code — the *execution
//! plane* contract (paper §3.1, P3/P4) is that a compnode may run sub-DAGs
//! on any backend; `RefEngine` is the backend that needs no artifacts and
//! runs anywhere, used by the simulator, the quickstart example and as the
//! numerics oracle opposite the XLA engine in cross-engine tests.

use crate::dag::Shape;

/// A dense row-major tensor. `data` is either f32 or i32 storage.
#[derive(Debug, Clone, PartialEq)]
pub enum Tensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor::F32 { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor::F32 { shape: shape.to_vec(), data }
    }

    pub fn from_ivec(shape: &[usize], data: Vec<i32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor::I32 { shape: shape.to_vec(), data }
    }

    pub fn scalar(v: f32) -> Tensor {
        Tensor::F32 { shape: vec![], data: vec![v] }
    }

    /// Gaussian init with the given std (He/Xavier-style scaling chosen by
    /// callers).
    pub fn randn(shape: &[usize], std: f32, rng: &mut crate::util::Rng) -> Tensor {
        let n: usize = shape.iter().product();
        let data = (0..n).map(|_| rng.normal() as f32 * std).collect();
        Tensor::F32 { shape: shape.to_vec(), data }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F32 { shape, .. } | Tensor::I32 { shape, .. } => shape,
        }
    }

    pub fn numel(&self) -> usize {
        self.shape().iter().product()
    }

    pub fn bytes(&self) -> u64 {
        (self.numel() * 4) as u64
    }

    pub fn to_shape_struct(&self) -> Shape {
        Shape::of(self.shape())
    }

    /// f32 view (panics on i32 tensors — callers route by dtype).
    pub fn f(&self) -> &[f32] {
        match self {
            Tensor::F32 { data, .. } => data,
            Tensor::I32 { .. } => panic!("expected f32 tensor"),
        }
    }

    pub fn f_mut(&mut self) -> &mut [f32] {
        match self {
            Tensor::F32 { data, .. } => data,
            Tensor::I32 { .. } => panic!("expected f32 tensor"),
        }
    }

    pub fn i(&self) -> &[i32] {
        match self {
            Tensor::I32 { data, .. } => data,
            Tensor::F32 { .. } => panic!("expected i32 tensor"),
        }
    }

    pub fn is_f32(&self) -> bool {
        matches!(self, Tensor::F32 { .. })
    }

    /// Scalar value of a 0-d/1-element tensor.
    pub fn item(&self) -> f32 {
        let f = self.f();
        assert_eq!(f.len(), 1, "item() on non-scalar");
        f[0]
    }

    /// Elementwise binary op producing a new tensor (equal shapes).
    pub fn zip(&self, other: &Tensor, f: impl Fn(f32, f32) -> f32) -> Tensor {
        assert_eq!(self.shape(), other.shape(), "zip shape mismatch");
        let a = self.f();
        let b = other.f();
        Tensor::F32 {
            shape: self.shape().to_vec(),
            data: a.iter().zip(b).map(|(&x, &y)| f(x, y)).collect(),
        }
    }

    /// Elementwise unary map.
    pub fn map(&self, f: impl Fn(f32) -> f32) -> Tensor {
        Tensor::F32 { shape: self.shape().to_vec(), data: self.f().iter().map(|&x| f(x)).collect() }
    }

    /// In-place `self += alpha * other`.
    pub fn axpy(&mut self, alpha: f32, other: &Tensor) {
        assert_eq!(self.shape(), other.shape(), "axpy shape mismatch");
        let b = other.f().to_vec();
        for (x, y) in self.f_mut().iter_mut().zip(b) {
            *x += alpha * y;
        }
    }

    /// Sum of all elements.
    pub fn sum(&self) -> f32 {
        self.f().iter().sum()
    }

    /// L2 norm.
    pub fn norm(&self) -> f32 {
        self.f().iter().map(|&x| x * x).sum::<f32>().sqrt()
    }
}

/// `C[m,n] = A[m,k] · B[k,n]` — blocked ikj loop, the RefEngine matmul.
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut c = vec![0.0f32; m * n];
    matmul_into(a, b, &mut c, m, k, n);
    c
}

/// Matmul into an existing buffer (hot-path variant; avoids allocation).
pub fn matmul_into(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    c.fill(0.0);
    // ikj order: streams B and C rows, good cache behaviour without tiling.
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &b[kk * n..(kk + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
}

/// `C[m,n] = A[m,k] · Bᵀ[n,k]`.
pub fn matmul_bt(a: &[f32], b_t: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    assert_eq!(a.len(), m * k);
    assert_eq!(b_t.len(), n * k);
    let mut c = vec![0.0f32; m * n];
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        for j in 0..n {
            let brow = &b_t[j * k..(j + 1) * k];
            let mut s = 0.0;
            for (x, y) in arow.iter().zip(brow) {
                s += x * y;
            }
            c[i * n + j] = s;
        }
    }
    c
}

/// `C[m,n] = Aᵀ[k,m] · B[k,n]` (for weight gradients).
pub fn matmul_at(a_t: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    assert_eq!(a_t.len(), k * m);
    assert_eq!(b.len(), k * n);
    let mut c = vec![0.0f32; m * n];
    for kk in 0..k {
        let arow = &a_t[kk * m..(kk + 1) * m];
        let brow = &b[kk * n..(kk + 1) * n];
        for i in 0..m {
            let av = arow[i];
            if av == 0.0 {
                continue;
            }
            let crow = &mut c[i * n..(i + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
    c
}

/// Numerically stable softmax over the last axis, in place.
pub fn softmax_lastaxis(data: &mut [f32], row: usize) {
    assert!(row > 0 && data.len() % row == 0);
    for chunk in data.chunks_mut(row) {
        let mx = chunk.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0;
        for x in chunk.iter_mut() {
            *x = (*x - mx).exp();
            sum += *x;
        }
        for x in chunk.iter_mut() {
            *x /= sum;
        }
    }
}

/// GELU (tanh approximation — matches jax.nn.gelu default).
pub fn gelu(x: f32) -> f32 {
    const C: f32 = 0.7978845608; // sqrt(2/π)
    0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
}

/// d/dx GELU (tanh approximation).
pub fn gelu_grad(x: f32) -> f32 {
    const C: f32 = 0.7978845608;
    let x3 = 0.044715 * x * x * x;
    let t = (C * (x + x3)).tanh();
    let sech2 = 1.0 - t * t;
    0.5 * (1.0 + t) + 0.5 * x * sech2 * C * (1.0 + 3.0 * 0.044715 * x * x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn matmul_identity() {
        let a = vec![1.0, 2.0, 3.0, 4.0];
        let i = vec![1.0, 0.0, 0.0, 1.0];
        assert_eq!(matmul(&a, &i, 2, 2, 2), a);
    }

    #[test]
    fn matmul_known() {
        // [[1,2],[3,4]] · [[5,6],[7,8]] = [[19,22],[43,50]]
        let a = vec![1.0, 2.0, 3.0, 4.0];
        let b = vec![5.0, 6.0, 7.0, 8.0];
        assert_eq!(matmul(&a, &b, 2, 2, 2), vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn matmul_variants_agree() {
        let mut rng = Rng::new(2);
        let (m, k, n) = (3, 5, 4);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        let c = matmul(&a, &b, m, k, n);
        // b_t[n,k]
        let mut bt = vec![0.0; n * k];
        for i in 0..k {
            for j in 0..n {
                bt[j * k + i] = b[i * n + j];
            }
        }
        let c2 = matmul_bt(&a, &bt, m, k, n);
        for (x, y) in c.iter().zip(&c2) {
            assert!((x - y).abs() < 1e-4);
        }
        // a_t[k,m]
        let mut at = vec![0.0; k * m];
        for i in 0..m {
            for j in 0..k {
                at[j * m + i] = a[i * k + j];
            }
        }
        let c3 = matmul_at(&at, &b, m, k, n);
        for (x, y) in c.iter().zip(&c3) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let mut d = vec![1.0, 2.0, 3.0, -1.0, 0.0, 1.0];
        softmax_lastaxis(&mut d, 3);
        let s1: f32 = d[..3].iter().sum();
        let s2: f32 = d[3..].iter().sum();
        assert!((s1 - 1.0).abs() < 1e-6);
        assert!((s2 - 1.0).abs() < 1e-6);
        assert!(d[2] > d[1] && d[1] > d[0]);
    }

    #[test]
    fn softmax_stable_at_large_logits() {
        let mut d = vec![1000.0, 1001.0];
        softmax_lastaxis(&mut d, 2);
        assert!(d.iter().all(|x| x.is_finite()));
        assert!((d[0] + d[1] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn gelu_grad_matches_finite_difference() {
        for &x in &[-2.0f32, -0.5, 0.0, 0.3, 1.7] {
            let h = 1e-3;
            let fd = (gelu(x + h) - gelu(x - h)) / (2.0 * h);
            assert!((gelu_grad(x) - fd).abs() < 1e-3, "x={x}");
        }
    }

    #[test]
    fn tensor_basics() {
        let mut rng = Rng::new(1);
        let t = Tensor::randn(&[4, 4], 0.1, &mut rng);
        assert_eq!(t.numel(), 16);
        assert_eq!(t.bytes(), 64);
        let z = Tensor::zeros(&[4, 4]);
        let s = t.zip(&z, |a, b| a + b);
        assert_eq!(s, t);
        let mut acc = Tensor::zeros(&[4, 4]);
        acc.axpy(2.0, &t);
        for (a, b) in acc.f().iter().zip(t.f()) {
            assert!((a - 2.0 * b).abs() < 1e-6);
        }
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn bad_shape_panics() {
        Tensor::from_vec(&[2, 2], vec![1.0]);
    }
}
