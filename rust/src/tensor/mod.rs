//! Dense row-major f32/i32 tensors and the numeric kernels used by the
//! pure-rust reference engine ([`crate::exec::RefEngine`]).
//!
//! This is deliberately simple, correct, testable code — the *execution
//! plane* contract (paper §3.1, P3/P4) is that a compnode may run sub-DAGs
//! on any backend; `RefEngine` is the backend that needs no artifacts and
//! runs anywhere, used by the simulator, the quickstart example and as the
//! numerics oracle opposite the XLA engine in cross-engine tests.
//!
//! The three GEMMs (`matmul_into`, `matmul_bt_into`, `matmul_at_into`) are
//! register-tiled and panel-packed (DESIGN.md §Perf), with an opt-in
//! row-partitioned thread fan-out. Every variant accumulates each output
//! element as a single chain over ascending `k`, so blocked, threaded and
//! [`naive`] results are **bitwise identical** — the determinism contract
//! `tests/golden_training.rs` relies on.

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::dag::Shape;

/// A dense row-major tensor. `data` is either f32 or i32 storage.
#[derive(Debug, Clone, PartialEq)]
pub enum Tensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor::F32 { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor::F32 { shape: shape.to_vec(), data }
    }

    pub fn from_ivec(shape: &[usize], data: Vec<i32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor::I32 { shape: shape.to_vec(), data }
    }

    pub fn scalar(v: f32) -> Tensor {
        Tensor::F32 { shape: vec![], data: vec![v] }
    }

    /// Gaussian init with the given std (He/Xavier-style scaling chosen by
    /// callers).
    pub fn randn(shape: &[usize], std: f32, rng: &mut crate::util::Rng) -> Tensor {
        let n: usize = shape.iter().product();
        let data = (0..n).map(|_| rng.normal() as f32 * std).collect();
        Tensor::F32 { shape: shape.to_vec(), data }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F32 { shape, .. } | Tensor::I32 { shape, .. } => shape,
        }
    }

    pub fn numel(&self) -> usize {
        self.shape().iter().product()
    }

    pub fn bytes(&self) -> u64 {
        (self.numel() * 4) as u64
    }

    pub fn to_shape_struct(&self) -> Shape {
        Shape::of(self.shape())
    }

    /// f32 view (panics on i32 tensors — callers route by dtype).
    pub fn f(&self) -> &[f32] {
        match self {
            Tensor::F32 { data, .. } => data,
            Tensor::I32 { .. } => panic!("expected f32 tensor"),
        }
    }

    pub fn f_mut(&mut self) -> &mut [f32] {
        match self {
            Tensor::F32 { data, .. } => data,
            Tensor::I32 { .. } => panic!("expected f32 tensor"),
        }
    }

    pub fn i(&self) -> &[i32] {
        match self {
            Tensor::I32 { data, .. } => data,
            Tensor::F32 { .. } => panic!("expected i32 tensor"),
        }
    }

    pub fn is_f32(&self) -> bool {
        matches!(self, Tensor::F32 { .. })
    }

    /// Scalar value of a 0-d/1-element tensor.
    pub fn item(&self) -> f32 {
        let f = self.f();
        assert_eq!(f.len(), 1, "item() on non-scalar");
        f[0]
    }

    /// Elementwise binary op producing a new tensor (equal shapes).
    pub fn zip(&self, other: &Tensor, f: impl Fn(f32, f32) -> f32) -> Tensor {
        assert_eq!(self.shape(), other.shape(), "zip shape mismatch");
        let a = self.f();
        let b = other.f();
        Tensor::F32 {
            shape: self.shape().to_vec(),
            data: a.iter().zip(b).map(|(&x, &y)| f(x, y)).collect(),
        }
    }

    /// Elementwise unary map.
    pub fn map(&self, f: impl Fn(f32) -> f32) -> Tensor {
        Tensor::F32 { shape: self.shape().to_vec(), data: self.f().iter().map(|&x| f(x)).collect() }
    }

    /// In-place `self += alpha * other`.
    pub fn axpy(&mut self, alpha: f32, other: &Tensor) {
        assert_eq!(self.shape(), other.shape(), "axpy shape mismatch");
        let b = other.f();
        for (x, &y) in self.f_mut().iter_mut().zip(b) {
            *x += alpha * y;
        }
    }

    /// Sum of all elements.
    pub fn sum(&self) -> f32 {
        self.f().iter().sum()
    }

    /// L2 norm.
    pub fn norm(&self) -> f32 {
        self.f().iter().map(|&x| x * x).sum::<f32>().sqrt()
    }
}

// ---------------------------------------------------------------------------
// GEMM threading configuration
// ---------------------------------------------------------------------------

/// 0 = unresolved; resolved lazily from `FUSIONAI_GEMM_THREADS` (default 1).
static GEMM_THREADS: AtomicUsize = AtomicUsize::new(0);

/// Below this many FLOPs (2·m·k·n) a GEMM always runs single-threaded:
/// thread spawn/join overhead dominates small problems.
const GEMM_PAR_MIN_FLOPS: usize = 1 << 21;

/// Set the process-wide GEMM fan-out (1 = single-threaded, the default).
pub fn set_gemm_threads(threads: usize) {
    GEMM_THREADS.store(threads.max(1), Ordering::Relaxed);
}

/// Current GEMM fan-out; first call resolves `FUSIONAI_GEMM_THREADS`.
pub fn gemm_threads() -> usize {
    match GEMM_THREADS.load(Ordering::Relaxed) {
        0 => {
            let n = std::env::var("FUSIONAI_GEMM_THREADS")
                .ok()
                .and_then(|v| v.parse::<usize>().ok())
                .filter(|&n| n >= 1)
                .unwrap_or(1);
            GEMM_THREADS.store(n, Ordering::Relaxed);
            n
        }
        n => n,
    }
}

/// Threads to use for one GEMM of the given extent (FLOP-thresholded).
fn plan_threads(m: usize, k: usize, n: usize) -> usize {
    let t = gemm_threads();
    if t <= 1 {
        return 1;
    }
    let flops = 2usize.saturating_mul(m).saturating_mul(k).saturating_mul(n);
    if flops < GEMM_PAR_MIN_FLOPS {
        1
    } else {
        t.min(m).max(1)
    }
}

/// Fan `m` output rows out over `threads` contiguous chunks of `c`.
/// `body(i0, chunk)` computes rows `i0..i0+chunk.len()/n`. Each output
/// element is produced by exactly one chunk with the same per-element
/// arithmetic as the single-threaded path, so results are bitwise
/// independent of the thread count.
fn par_rows(
    c: &mut [f32],
    m: usize,
    n: usize,
    threads: usize,
    body: &(dyn Fn(usize, &mut [f32]) + Sync),
) {
    let t = threads.min(m).max(1);
    if t <= 1 {
        body(0, c);
        return;
    }
    let rows_per = m.div_ceil(t);
    std::thread::scope(|s| {
        let mut rest = c;
        let mut i0 = 0;
        while i0 < m {
            let take = rows_per.min(m - i0);
            let (chunk, tail) = std::mem::take(&mut rest).split_at_mut(take * n);
            rest = tail;
            s.spawn(move || body(i0, chunk));
            i0 += take;
        }
    });
}

// ---------------------------------------------------------------------------
// Blocked GEMM kernels
// ---------------------------------------------------------------------------

/// Register-tile height (output rows per micro-kernel).
const MR: usize = 4;
/// Register-tile width (output columns per micro-kernel).
const NR: usize = 16;

/// `C[m,n] = A[m,k] · B[k,n]` — allocating wrapper over [`matmul_into`].
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut c = vec![0.0f32; m * n];
    matmul_into(a, b, &mut c, m, k, n);
    c
}

/// Blocked matmul into an existing buffer (hot-path variant).
pub fn matmul_into(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    matmul_into_threaded(a, b, c, m, k, n, plan_threads(m, k, n));
}

/// [`matmul_into`] with an explicit thread count (benches/property tests).
pub fn matmul_into_threaded(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    threads: usize,
) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    if n == 0 {
        return;
    }
    par_rows(c, m, n, threads, &|i0, chunk| {
        let rows = chunk.len() / n;
        gemm_block(&a[i0 * k..(i0 + rows) * k], b, chunk, k, n);
    });
}

/// Micro-kernel driver for a contiguous block of A/C rows: MR×NR register
/// tiles over a packed A panel, each `acc` element a single ascending-k
/// chain (the bitwise-determinism invariant).
fn gemm_block(a: &[f32], b: &[f32], c: &mut [f32], k: usize, n: usize) {
    let rows = if k == 0 { c.len() / n } else { a.len() / k };
    if k == 0 {
        c.fill(0.0);
        return;
    }
    // Packed MR×k panel of A, interleaved so the micro-kernel reads MR
    // contiguous values per k-step: pack[kk*MR + r] = a[(i+r)*k + kk].
    let mut pack = vec![0.0f32; MR * k];
    let mut i = 0;
    while i + MR <= rows {
        for r in 0..MR {
            let arow = &a[(i + r) * k..][..k];
            for (kk, &v) in arow.iter().enumerate() {
                pack[kk * MR + r] = v;
            }
        }
        let mut j = 0;
        while j + NR <= n {
            let mut acc = [[0.0f32; NR]; MR];
            for kk in 0..k {
                let ap = &pack[kk * MR..][..MR];
                let bp = &b[kk * n + j..][..NR];
                for r in 0..MR {
                    let av = ap[r];
                    for (x, &bv) in acc[r].iter_mut().zip(bp) {
                        *x += av * bv;
                    }
                }
            }
            for (r, accr) in acc.iter().enumerate() {
                c[(i + r) * n + j..][..NR].copy_from_slice(accr);
            }
            j += NR;
        }
        for jj in j..n {
            for r in 0..MR {
                let mut s = 0.0f32;
                for kk in 0..k {
                    s += pack[kk * MR + r] * b[kk * n + jj];
                }
                c[(i + r) * n + jj] = s;
            }
        }
        i += MR;
    }
    for r in i..rows {
        let arow = &a[r * k..][..k];
        for jj in 0..n {
            let mut s = 0.0f32;
            for (kk, &av) in arow.iter().enumerate() {
                s += av * b[kk * n + jj];
            }
            c[r * n + jj] = s;
        }
    }
}

/// `C[m,n] = A[m,k] · Bᵀ[n,k]` — allocating wrapper over
/// [`matmul_bt_into`].
pub fn matmul_bt(a: &[f32], b_t: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut c = vec![0.0f32; m * n];
    matmul_bt_into(a, b_t, &mut c, m, k, n);
    c
}

/// Blocked `A · Bᵀ` into an existing buffer.
pub fn matmul_bt_into(a: &[f32], b_t: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    matmul_bt_into_threaded(a, b_t, c, m, k, n, plan_threads(m, k, n));
}

/// [`matmul_bt_into`] with an explicit thread count.
pub fn matmul_bt_into_threaded(
    a: &[f32],
    b_t: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    threads: usize,
) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b_t.len(), n * k);
    assert_eq!(c.len(), m * n);
    if n == 0 {
        return;
    }
    par_rows(c, m, n, threads, &|i0, chunk| {
        let rows = chunk.len() / n;
        gemm_bt_block(&a[i0 * k..(i0 + rows) * k], b_t, chunk, k, n);
    });
}

/// 4×4 dot-product register tile: both operands stream contiguously along
/// k; 16 independent accumulator chains give the ILP the single-chain
/// naive loop lacks, while each chain stays ascending-k (bitwise match).
fn gemm_bt_block(a: &[f32], b_t: &[f32], c: &mut [f32], k: usize, n: usize) {
    let rows = if k == 0 { c.len() / n } else { a.len() / k };
    if k == 0 {
        c.fill(0.0);
        return;
    }
    const TR: usize = 4;
    let mut i = 0;
    while i + TR <= rows {
        let a0 = &a[i * k..][..k];
        let a1 = &a[(i + 1) * k..][..k];
        let a2 = &a[(i + 2) * k..][..k];
        let a3 = &a[(i + 3) * k..][..k];
        let mut j = 0;
        while j + TR <= n {
            let b0 = &b_t[j * k..][..k];
            let b1 = &b_t[(j + 1) * k..][..k];
            let b2 = &b_t[(j + 2) * k..][..k];
            let b3 = &b_t[(j + 3) * k..][..k];
            let mut acc = [[0.0f32; TR]; TR];
            for kk in 0..k {
                let av = [a0[kk], a1[kk], a2[kk], a3[kk]];
                let bv = [b0[kk], b1[kk], b2[kk], b3[kk]];
                for (accr, &ar) in acc.iter_mut().zip(&av) {
                    for (x, &bc) in accr.iter_mut().zip(&bv) {
                        *x += ar * bc;
                    }
                }
            }
            for (r, accr) in acc.iter().enumerate() {
                c[(i + r) * n + j..][..TR].copy_from_slice(accr);
            }
            j += TR;
        }
        for jj in j..n {
            let brow = &b_t[jj * k..][..k];
            for (r, arow) in [a0, a1, a2, a3].iter().enumerate() {
                let mut s = 0.0f32;
                for (x, y) in arow.iter().zip(brow) {
                    s += x * y;
                }
                c[(i + r) * n + jj] = s;
            }
        }
        i += TR;
    }
    for r in i..rows {
        let arow = &a[r * k..][..k];
        for jj in 0..n {
            let brow = &b_t[jj * k..][..k];
            let mut s = 0.0f32;
            for (x, y) in arow.iter().zip(brow) {
                s += x * y;
            }
            c[r * n + jj] = s;
        }
    }
}

/// `C[m,n] = Aᵀ[k,m] · B[k,n]` (weight gradients) — allocating wrapper
/// over [`matmul_at_into`].
pub fn matmul_at(a_t: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut c = vec![0.0f32; m * n];
    matmul_at_into(a_t, b, &mut c, m, k, n);
    c
}

/// Blocked `Aᵀ · B` into an existing buffer.
pub fn matmul_at_into(a_t: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    matmul_at_into_threaded(a_t, b, c, m, k, n, plan_threads(m, k, n));
}

/// [`matmul_at_into`] with an explicit thread count.
pub fn matmul_at_into_threaded(
    a_t: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    threads: usize,
) {
    assert_eq!(a_t.len(), k * m);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    if n == 0 {
        return;
    }
    par_rows(c, m, n, threads, &|i0, chunk| {
        gemm_at_block(a_t, b, chunk, i0, m, k, n);
    });
}

/// MR×NR register tile over `Aᵀ·B`: per k-step the tile reads MR
/// contiguous A-transpose values and NR contiguous B values. `i0` is the
/// first global output row of this chunk (A columns are addressed
/// globally when the work is row-partitioned across threads).
fn gemm_at_block(
    a_t: &[f32],
    b: &[f32],
    c: &mut [f32],
    i0: usize,
    m: usize,
    k: usize,
    n: usize,
) {
    let rows = c.len() / n;
    if k == 0 {
        c.fill(0.0);
        return;
    }
    let mut i = 0;
    while i + MR <= rows {
        let gi = i0 + i;
        let mut j = 0;
        while j + NR <= n {
            let mut acc = [[0.0f32; NR]; MR];
            for kk in 0..k {
                let ap = &a_t[kk * m + gi..][..MR];
                let bp = &b[kk * n + j..][..NR];
                for r in 0..MR {
                    let av = ap[r];
                    for (x, &bv) in acc[r].iter_mut().zip(bp) {
                        *x += av * bv;
                    }
                }
            }
            for (r, accr) in acc.iter().enumerate() {
                c[(i + r) * n + j..][..NR].copy_from_slice(accr);
            }
            j += NR;
        }
        for jj in j..n {
            for r in 0..MR {
                let mut s = 0.0f32;
                for kk in 0..k {
                    s += a_t[kk * m + gi + r] * b[kk * n + jj];
                }
                c[(i + r) * n + jj] = s;
            }
        }
        i += MR;
    }
    for r in i..rows {
        let gi = i0 + r;
        for jj in 0..n {
            let mut s = 0.0f32;
            for kk in 0..k {
                s += a_t[kk * m + gi] * b[kk * n + jj];
            }
            c[r * n + jj] = s;
        }
    }
}

/// Reference GEMMs: the pre-optimization loops, minus the data-dependent
/// `if av == 0.0` skips (the skips broke bitwise equality on signed
/// zeros and non-finite values and defeated autovectorization). Property
/// tests assert the blocked/threaded kernels match these **bitwise**.
pub mod naive {
    /// `C[m,n] = A[m,k] · B[k,n]`, ikj order.
    pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        assert_eq!(a.len(), m * k);
        assert_eq!(b.len(), k * n);
        let mut c = vec![0.0f32; m * n];
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let crow = &mut c[i * n..(i + 1) * n];
            for (kk, &av) in arow.iter().enumerate() {
                let brow = &b[kk * n..(kk + 1) * n];
                for (cv, &bv) in crow.iter_mut().zip(brow) {
                    *cv += av * bv;
                }
            }
        }
        c
    }

    /// `C[m,n] = A[m,k] · Bᵀ[n,k]`, row-by-row dot products.
    pub fn matmul_bt(a: &[f32], b_t: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        assert_eq!(a.len(), m * k);
        assert_eq!(b_t.len(), n * k);
        let mut c = vec![0.0f32; m * n];
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            for j in 0..n {
                let brow = &b_t[j * k..(j + 1) * k];
                let mut s = 0.0;
                for (x, y) in arow.iter().zip(brow) {
                    s += x * y;
                }
                c[i * n + j] = s;
            }
        }
        c
    }

    /// `C[m,n] = Aᵀ[k,m] · B[k,n]`, kij order.
    pub fn matmul_at(a_t: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        assert_eq!(a_t.len(), k * m);
        assert_eq!(b.len(), k * n);
        let mut c = vec![0.0f32; m * n];
        for kk in 0..k {
            let arow = &a_t[kk * m..(kk + 1) * m];
            let brow = &b[kk * n..(kk + 1) * n];
            for (i, &av) in arow.iter().enumerate() {
                let crow = &mut c[i * n..(i + 1) * n];
                for (cv, &bv) in crow.iter_mut().zip(brow) {
                    *cv += av * bv;
                }
            }
        }
        c
    }
}

/// Numerically stable softmax over the last axis, in place.
pub fn softmax_lastaxis(data: &mut [f32], row: usize) {
    assert!(row > 0 && data.len() % row == 0);
    for chunk in data.chunks_mut(row) {
        let mx = chunk.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0;
        for x in chunk.iter_mut() {
            *x = (*x - mx).exp();
            sum += *x;
        }
        for x in chunk.iter_mut() {
            *x /= sum;
        }
    }
}

/// GELU (tanh approximation — matches jax.nn.gelu default).
pub fn gelu(x: f32) -> f32 {
    const C: f32 = 0.7978845608; // sqrt(2/π)
    0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
}

/// d/dx GELU (tanh approximation).
pub fn gelu_grad(x: f32) -> f32 {
    const C: f32 = 0.7978845608;
    let x3 = 0.044715 * x * x * x;
    let t = (C * (x + x3)).tanh();
    let sech2 = 1.0 - t * t;
    0.5 * (1.0 + t) + 0.5 * x * sech2 * C * (1.0 + 3.0 * 0.044715 * x * x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn matmul_identity() {
        let a = vec![1.0, 2.0, 3.0, 4.0];
        let i = vec![1.0, 0.0, 0.0, 1.0];
        assert_eq!(matmul(&a, &i, 2, 2, 2), a);
    }

    #[test]
    fn matmul_known() {
        // [[1,2],[3,4]] · [[5,6],[7,8]] = [[19,22],[43,50]]
        let a = vec![1.0, 2.0, 3.0, 4.0];
        let b = vec![5.0, 6.0, 7.0, 8.0];
        assert_eq!(matmul(&a, &b, 2, 2, 2), vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn matmul_variants_agree() {
        let mut rng = Rng::new(2);
        let (m, k, n) = (3, 5, 4);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        let c = matmul(&a, &b, m, k, n);
        // b_t[n,k]
        let mut bt = vec![0.0; n * k];
        for i in 0..k {
            for j in 0..n {
                bt[j * k + i] = b[i * n + j];
            }
        }
        let c2 = matmul_bt(&a, &bt, m, k, n);
        for (x, y) in c.iter().zip(&c2) {
            assert!((x - y).abs() < 1e-4);
        }
        // a_t[k,m]
        let mut at = vec![0.0; k * m];
        for i in 0..m {
            for j in 0..k {
                at[j * m + i] = a[i * k + j];
            }
        }
        let c3 = matmul_at(&at, &b, m, k, n);
        for (x, y) in c.iter().zip(&c3) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    /// The central determinism contract: blocked kernels equal the naive
    /// reference bitwise on shapes that exercise every tile-remainder
    /// combination (rows % MR, cols % NR and % 4, tiny k, k > NR).
    #[test]
    fn blocked_matches_naive_bitwise_across_remainders() {
        let mut rng = Rng::new(7);
        for &(m, k, n) in &[
            (1, 1, 1),
            (4, 16, 16),
            (5, 3, 17),
            (7, 33, 19),
            (8, 64, 16),
            (9, 7, 31),
            (16, 40, 33),
            (3, 64, 5),
        ] {
            let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
            let b: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
            let want = naive::matmul(&a, &b, m, k, n);
            let got = matmul(&a, &b, m, k, n);
            assert_eq!(bits(&want), bits(&got), "matmul {m}x{k}x{n}");

            let bt: Vec<f32> = transpose(&b, k, n);
            let want = naive::matmul_bt(&a, &bt, m, k, n);
            let got = matmul_bt(&a, &bt, m, k, n);
            assert_eq!(bits(&want), bits(&got), "matmul_bt {m}x{k}x{n}");

            let at: Vec<f32> = transpose(&a, m, k);
            let want = naive::matmul_at(&at, &b, m, k, n);
            let got = matmul_at(&at, &b, m, k, n);
            assert_eq!(bits(&want), bits(&got), "matmul_at {m}x{k}x{n}");
        }
    }

    /// Thread-count invariance: the row partition never changes any output
    /// element's arithmetic, so 1..=4 threads are bitwise identical.
    #[test]
    fn threaded_matches_single_thread_bitwise() {
        let mut rng = Rng::new(13);
        let (m, k, n) = (23, 37, 29);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        let bt = transpose(&b, k, n);
        let at = transpose(&a, m, k);
        let mut base = vec![0.0f32; m * n];
        matmul_into_threaded(&a, &b, &mut base, m, k, n, 1);
        let mut base_bt = vec![0.0f32; m * n];
        matmul_bt_into_threaded(&a, &bt, &mut base_bt, m, k, n, 1);
        let mut base_at = vec![0.0f32; m * n];
        matmul_at_into_threaded(&at, &b, &mut base_at, m, k, n, 1);
        for threads in 2..=4 {
            let mut c = vec![0.0f32; m * n];
            matmul_into_threaded(&a, &b, &mut c, m, k, n, threads);
            assert_eq!(bits(&base), bits(&c), "matmul threads={threads}");
            let mut c = vec![0.0f32; m * n];
            matmul_bt_into_threaded(&a, &bt, &mut c, m, k, n, threads);
            assert_eq!(bits(&base_bt), bits(&c), "matmul_bt threads={threads}");
            let mut c = vec![0.0f32; m * n];
            matmul_at_into_threaded(&at, &b, &mut c, m, k, n, threads);
            assert_eq!(bits(&base_at), bits(&c), "matmul_at threads={threads}");
        }
    }

    #[test]
    fn degenerate_gemm_extents() {
        // k = 0 must produce zeros, not stale data.
        let mut c = vec![9.0f32; 6];
        matmul_into(&[], &[], &mut c, 2, 0, 3);
        assert_eq!(c, vec![0.0; 6]);
        let mut c = vec![9.0f32; 6];
        matmul_bt_into(&[], &[], &mut c, 2, 0, 3);
        assert_eq!(c, vec![0.0; 6]);
        let mut c = vec![9.0f32; 6];
        matmul_at_into(&[], &[], &mut c, 2, 0, 3);
        assert_eq!(c, vec![0.0; 6]);
        // n = 0 / m = 0 are no-ops.
        matmul_into(&[1.0, 2.0], &[], &mut [], 1, 2, 0);
        matmul_into(&[], &[1.0, 2.0], &mut [], 0, 1, 2);
    }

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    fn transpose(v: &[f32], rows: usize, cols: usize) -> Vec<f32> {
        let mut t = vec![0.0f32; v.len()];
        for r in 0..rows {
            for c in 0..cols {
                t[c * rows + r] = v[r * cols + c];
            }
        }
        t
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let mut d = vec![1.0, 2.0, 3.0, -1.0, 0.0, 1.0];
        softmax_lastaxis(&mut d, 3);
        let s1: f32 = d[..3].iter().sum();
        let s2: f32 = d[3..].iter().sum();
        assert!((s1 - 1.0).abs() < 1e-6);
        assert!((s2 - 1.0).abs() < 1e-6);
        assert!(d[2] > d[1] && d[1] > d[0]);
    }

    #[test]
    fn softmax_stable_at_large_logits() {
        let mut d = vec![1000.0, 1001.0];
        softmax_lastaxis(&mut d, 2);
        assert!(d.iter().all(|x| x.is_finite()));
        assert!((d[0] + d[1] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn gelu_grad_matches_finite_difference() {
        for &x in &[-2.0f32, -0.5, 0.0, 0.3, 1.7] {
            let h = 1e-3;
            let fd = (gelu(x + h) - gelu(x - h)) / (2.0 * h);
            assert!((gelu_grad(x) - fd).abs() < 1e-3, "x={x}");
        }
    }

    #[test]
    fn tensor_basics() {
        let mut rng = Rng::new(1);
        let t = Tensor::randn(&[4, 4], 0.1, &mut rng);
        assert_eq!(t.numel(), 16);
        assert_eq!(t.bytes(), 64);
        let z = Tensor::zeros(&[4, 4]);
        let s = t.zip(&z, |a, b| a + b);
        assert_eq!(s, t);
        let mut acc = Tensor::zeros(&[4, 4]);
        acc.axpy(2.0, &t);
        for (a, b) in acc.f().iter().zip(t.f()) {
            assert!((a - 2.0 * b).abs() < 1e-6);
        }
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn bad_shape_panics() {
        Tensor::from_vec(&[2, 2], vec![1.0]);
    }
}
