//! Communication compression (paper §2.3).
//!
//! "To reduce the large communication time caused by the extremely low
//! communication bandwidth […] FusionAI incorporates these techniques and
//! conducts scheduling with them." We implement the two data-plane codecs
//! the paper names — **quantization** (int8, symmetric per-tensor) and
//! **top-k sparsification** — behind a single [`Codec`] enum used by the
//! cluster message layer, plus a [`LocalSgdPolicy`] helper implementing the
//! reduced-synchronization schedule (Local-SGD) for parameter traffic.
//!
//! Codecs are *lossy on values, lossless on shape*: `decode(encode(x))`
//! yields a tensor of identical shape with bounded (quantization) or
//! structured (top-k) error. Error bounds are property-tested.

/// Wire codec for f32 tensors.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Codec {
    /// Raw little-endian f32.
    None,
    /// Symmetric per-tensor int8 quantization (4× smaller).
    Int8,
    /// Keep the `k = ceil(ratio·n)` largest-magnitude entries as
    /// (index, value) pairs. `ratio ∈ (0, 1]`.
    TopK { ratio: f64 },
}

impl Codec {
    /// Encode `data` into wire bytes.
    pub fn encode(&self, data: &[f32]) -> Vec<u8> {
        match *self {
            Codec::None => {
                let mut out = Vec::with_capacity(4 * data.len());
                for &x in data {
                    out.extend_from_slice(&x.to_le_bytes());
                }
                out
            }
            Codec::Int8 => {
                let (q, scale) = quantize_int8(data);
                let mut out = Vec::with_capacity(4 + q.len());
                out.extend_from_slice(&scale.to_le_bytes());
                out.extend(q.iter().map(|&v| v as u8));
                out
            }
            Codec::TopK { ratio } => {
                let kept = topk(data, ratio);
                let mut out = Vec::with_capacity(4 + 8 * kept.len());
                out.extend_from_slice(&(kept.len() as u32).to_le_bytes());
                for (i, v) in kept {
                    out.extend_from_slice(&(i as u32).to_le_bytes());
                    out.extend_from_slice(&v.to_le_bytes());
                }
                out
            }
        }
    }

    /// Decode wire bytes back into `n` f32 values.
    pub fn decode(&self, bytes: &[u8], n: usize) -> Vec<f32> {
        match *self {
            Codec::None => {
                assert_eq!(bytes.len(), 4 * n, "raw payload size mismatch");
                bytes.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect()
            }
            Codec::Int8 => {
                assert_eq!(bytes.len(), 4 + n, "int8 payload size mismatch");
                let scale = f32::from_le_bytes(bytes[..4].try_into().unwrap());
                bytes[4..].iter().map(|&b| (b as i8) as f32 * scale).collect()
            }
            Codec::TopK { .. } => {
                let k = u32::from_le_bytes(bytes[..4].try_into().unwrap()) as usize;
                assert_eq!(bytes.len(), 4 + 8 * k, "topk payload size mismatch");
                let mut out = vec![0.0f32; n];
                for c in bytes[4..].chunks_exact(8) {
                    let i = u32::from_le_bytes(c[..4].try_into().unwrap()) as usize;
                    let v = f32::from_le_bytes(c[4..].try_into().unwrap());
                    out[i] = v;
                }
                out
            }
        }
    }

    /// Wire size in bytes for an n-element tensor (for the perf model: this
    /// is the `M` that enters `T_comm = α + β·M`).
    pub fn wire_bytes(&self, n: usize) -> u64 {
        match *self {
            Codec::None => 4 * n as u64,
            Codec::Int8 => 4 + n as u64,
            Codec::TopK { ratio } => {
                let k = ((ratio * n as f64).ceil() as u64).max(1).min(n as u64);
                4 + 8 * k
            }
        }
    }

    /// Compression ratio vs raw f32.
    pub fn ratio(&self, n: usize) -> f64 {
        self.wire_bytes(n) as f64 / (4.0 * n as f64)
    }
}

/// Symmetric per-tensor int8 quantization: `q = round(x / scale)` with
/// `scale = max|x| / 127`. Returns `(q, scale)`.
#[allow(clippy::float_cmp)] // amax == 0.0 iff the tensor is exactly all-zero
pub fn quantize_int8(data: &[f32]) -> (Vec<i8>, f32) {
    let amax = data.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
    if amax == 0.0 {
        return (vec![0; data.len()], 1.0);
    }
    let scale = amax / 127.0;
    let q = data.iter().map(|&x| (x / scale).round().clamp(-127.0, 127.0) as i8).collect();
    (q, scale)
}

/// Indices and values of the k largest-magnitude entries,
/// `k = max(1, ceil(ratio·n))`.
pub fn topk(data: &[f32], ratio: f64) -> Vec<(usize, f32)> {
    let n = data.len();
    if n == 0 {
        return vec![];
    }
    let k = ((ratio * n as f64).ceil() as usize).clamp(1, n);
    let mut idx: Vec<usize> = (0..n).collect();
    // Partial selection: k-th largest magnitude.
    idx.select_nth_unstable_by(k - 1, |&a, &b| data[b].abs().total_cmp(&data[a].abs()));
    let mut kept: Vec<(usize, f32)> = idx[..k].iter().map(|&i| (i, data[i])).collect();
    kept.sort_by_key(|&(i, _)| i);
    kept
}

/// Local-SGD synchronization policy (paper §2.3 "Local-SGD permits flexible
/// communication frequencies"): sync parameters every `period` local steps.
#[derive(Debug, Clone)]
pub struct LocalSgdPolicy {
    pub period: usize,
    step: usize,
}

impl LocalSgdPolicy {
    pub fn every(period: usize) -> LocalSgdPolicy {
        LocalSgdPolicy { period: period.max(1), step: 0 }
    }

    /// Advance one local step; returns true when this step must synchronize.
    pub fn tick(&mut self) -> bool {
        self.step += 1;
        self.step % self.period == 0
    }

    /// Fraction of sync rounds vs fully-synchronous SGD.
    pub fn comm_fraction(&self) -> f64 {
        1.0 / self.period as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn random_vec(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn raw_roundtrip_exact() {
        let x = random_vec(257, 1);
        let c = Codec::None;
        assert_eq!(c.decode(&c.encode(&x), x.len()), x);
    }

    #[test]
    fn int8_error_bounded_by_half_scale() {
        let x = random_vec(4096, 2);
        let c = Codec::Int8;
        let y = c.decode(&c.encode(&x), x.len());
        let amax = x.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        let bound = amax / 127.0 / 2.0 + 1e-6;
        for (a, b) in x.iter().zip(&y) {
            assert!((a - b).abs() <= bound, "{a} vs {b}");
        }
    }

    #[test]
    fn int8_wire_size() {
        let x = random_vec(1000, 3);
        assert_eq!(Codec::Int8.encode(&x).len() as u64, Codec::Int8.wire_bytes(1000));
        assert!(Codec::Int8.ratio(1000) < 0.26);
    }

    #[test]
    fn int8_zeros_safe() {
        let x = vec![0.0f32; 16];
        let c = Codec::Int8;
        assert_eq!(c.decode(&c.encode(&x), 16), x);
    }

    #[test]
    fn topk_keeps_largest() {
        let x = vec![0.1, -5.0, 0.2, 3.0, -0.05];
        let kept = topk(&x, 0.4); // k = 2
        assert_eq!(kept.len(), 2);
        let idxs: Vec<usize> = kept.iter().map(|&(i, _)| i).collect();
        assert_eq!(idxs, vec![1, 3]);
    }

    #[test]
    fn topk_roundtrip_preserves_selected() {
        let x = random_vec(512, 4);
        let c = Codec::TopK { ratio: 0.1 };
        let y = c.decode(&c.encode(&x), x.len());
        let kept = topk(&x, 0.1);
        for (i, v) in kept {
            assert_eq!(y[i], v);
        }
        // Everything else zeroed.
        let nonzero = y.iter().filter(|&&v| v != 0.0).count();
        assert!(nonzero <= 52);
    }

    #[test]
    fn topk_ratio_one_is_lossless() {
        let x = random_vec(100, 5);
        let c = Codec::TopK { ratio: 1.0 };
        assert_eq!(c.decode(&c.encode(&x), 100), x);
    }

    #[test]
    fn wire_bytes_monotone_in_ratio() {
        assert!(Codec::TopK { ratio: 0.01 }.wire_bytes(10_000)
            < Codec::TopK { ratio: 0.5 }.wire_bytes(10_000));
        assert!(Codec::TopK { ratio: 0.05 }.ratio(10_000) < 0.11);
    }

    #[test]
    fn local_sgd_schedule() {
        let mut p = LocalSgdPolicy::every(4);
        let syncs: Vec<bool> = (0..8).map(|_| p.tick()).collect();
        assert_eq!(syncs, vec![false, false, false, true, false, false, false, true]);
        assert_eq!(p.comm_fraction(), 0.25);
    }
}
