//! Deployment path: batched inference serving over the pipelined model
//! (the paper's title promises *deploying* LLMs, not just training).
//!
//! A [`InferenceServer`] loads every stage artifact, holds the parameters,
//! and serves greedy token generation. A [`Batcher`] groups queued requests
//! into fixed-size batches (the artifact's compiled batch dimension) and
//! the driver measures per-request latency and aggregate throughput —
//! `examples/serve_inference.rs` reports them.

use std::collections::VecDeque;
use std::path::Path;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use crate::exec::xla_engine::XlaEngine;
use crate::runtime::Manifest;
use crate::tensor::Tensor;
use crate::util::stats::Sample;
use crate::util::Rng;

/// One generation request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: usize,
    pub prompt: Vec<i32>,
    /// Arrival time relative to trace start (seconds).
    pub arrival_s: f64,
}

/// One completed response.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: usize,
    pub tokens: Vec<i32>,
    /// End-to-end latency: queue wait + batch compute.
    pub latency_s: f64,
}

/// The server: all stages resident, greedy decoding.
pub struct InferenceServer {
    engine: XlaEngine,
    stages: Vec<String>,
    params: Vec<Vec<Tensor>>,
    pub batch: usize,
    pub seq: usize,
    pub vocab: usize,
}

impl InferenceServer {
    /// Load artifacts and parameters. If `<dir>/checkpoint.bin` exists
    /// (written by the trainer) the trained weights are restored; otherwise
    /// parameters are freshly initialized (mechanics are identical).
    pub fn load(dir: &Path, seed: u64) -> Result<InferenceServer> {
        let engine = XlaEngine::load(dir).context("loading artifacts for serving")?;
        let manifest: &Manifest = engine.manifest();
        let stages = manifest.stages.clone();
        let batch = manifest.config_usize("batch").ok_or_else(|| anyhow!("manifest missing batch"))?;
        let seq = manifest.config_usize("seq").ok_or_else(|| anyhow!("manifest missing seq"))?;
        let vocab = manifest.config_usize("vocab").ok_or_else(|| anyhow!("manifest missing vocab"))?;
        let mut rng = Rng::new(seed);
        let ckpt_path = crate::cluster::checkpoint::default_path(dir);
        let ckpt = if ckpt_path.exists() {
            Some(crate::cluster::checkpoint::load(&ckpt_path)?)
        } else {
            None
        };
        let params = stages
            .iter()
            .map(|s| match ckpt.as_ref().and_then(|c| c.get(s)) {
                Some(trained) => Ok(trained.clone()),
                None => engine.init_stage_params(s, &mut rng),
            })
            .collect::<Result<Vec<_>>>()?;
        if ckpt.is_some() {
            log::info!("restored trained checkpoint from {}", ckpt_path.display());
        }
        Ok(InferenceServer { engine, stages, params, batch, seq, vocab })
    }

    /// Forward a full `[B, S]` token batch through every stage; returns
    /// `[B, S, V]` logits via the `head_logits` artifact.
    pub fn forward_logits(&self, tokens: &Tensor) -> Result<Tensor> {
        let mut h = self.engine.stage_forward(&self.stages[0], &self.params[0], &[tokens])?;
        for (i, stage) in self.stages.iter().enumerate().take(self.stages.len() - 1).skip(1) {
            h = self.engine.stage_forward(stage, &self.params[i], &[&h])?;
        }
        // head_logits: params…, h → logits
        let last = self.stages.len() - 1;
        let mut args: Vec<Tensor> = self.params[last].clone();
        args.push(h);
        let mut out = self.engine.runtime().run("head_logits", &args)?;
        Ok(out.remove(0))
    }

    /// Greedy-decode `n_new` tokens for up to `batch` prompts at once.
    /// Prompts are right-padded into the fixed `[B, S]` shape.
    pub fn generate(&self, prompts: &[Vec<i32>], n_new: usize) -> Result<Vec<Vec<i32>>> {
        if prompts.len() > self.batch {
            return Err(anyhow!("batch {} exceeds compiled batch {}", prompts.len(), self.batch));
        }
        let mut seqs: Vec<Vec<i32>> = prompts.to_vec();
        for s in &seqs {
            if s.is_empty() || s.len() + n_new > self.seq {
                return Err(anyhow!(
                    "prompt length {} + {n_new} new tokens exceeds seq {}",
                    s.len(),
                    self.seq
                ));
            }
        }
        for _ in 0..n_new {
            // Pack into [B, S] (pad with token 0; padded rows unused).
            let mut flat = vec![0i32; self.batch * self.seq];
            for (b, s) in seqs.iter().enumerate() {
                flat[b * self.seq..b * self.seq + s.len()].copy_from_slice(s);
            }
            let tokens = Tensor::from_ivec(&[self.batch, self.seq], flat);
            let logits = self.forward_logits(&tokens)?;
            let lf = logits.f();
            for (b, s) in seqs.iter_mut().enumerate() {
                let pos = s.len() - 1; // causal model: next-token logits
                let row = &lf[(b * self.seq + pos) * self.vocab..][..self.vocab];
                let next = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.total_cmp(b.1))
                    .map(|(i, _)| i as i32)
                    .unwrap();
                s.push(next);
            }
        }
        Ok(seqs)
    }
}

/// Groups queued requests into batches of at most `max_batch`.
pub struct Batcher {
    queue: VecDeque<Request>,
    pub max_batch: usize,
}

impl Batcher {
    pub fn new(max_batch: usize) -> Batcher {
        Batcher { queue: VecDeque::new(), max_batch }
    }
    pub fn push(&mut self, r: Request) {
        self.queue.push_back(r);
    }
    pub fn len(&self) -> usize {
        self.queue.len()
    }
    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }
    /// Pop the next batch (FIFO).
    pub fn next_batch(&mut self) -> Vec<Request> {
        let n = self.queue.len().min(self.max_batch);
        self.queue.drain(..n).collect()
    }
}

/// Serving statistics over one trace.
#[derive(Debug)]
pub struct ServeStats {
    pub completed: usize,
    pub wall_seconds: f64,
    pub requests_per_second: f64,
    pub tokens_per_second: f64,
    pub latency: Sample,
}

/// Run a request trace to completion: requests become visible at their
/// arrival times (simulated by processing in arrival order), batched FIFO.
pub fn run_trace(
    server: &InferenceServer,
    mut requests: Vec<Request>,
    n_new: usize,
) -> Result<(Vec<Response>, ServeStats)> {
    requests.sort_by(|a, b| a.arrival_s.total_cmp(&b.arrival_s));
    let mut batcher = Batcher::new(server.batch);
    for r in requests {
        batcher.push(r);
    }
    let t0 = Instant::now();
    let mut responses = Vec::new();
    let mut latency = Sample::new();
    while !batcher.is_empty() {
        let batch = batcher.next_batch();
        // Respect arrival times: the server cannot start a batch before its
        // requests exist. (Trace time is real time here.)
        let latest_arrival =
            batch.iter().map(|r| r.arrival_s).fold(0.0f64, f64::max);
        let now = t0.elapsed().as_secs_f64();
        if latest_arrival > now {
            std::thread::sleep(std::time::Duration::from_secs_f64(latest_arrival - now));
        }
        let prompts: Vec<Vec<i32>> = batch.iter().map(|r| r.prompt.clone()).collect();
        let outs = server.generate(&prompts, n_new)?;
        let now = t0.elapsed().as_secs_f64();
        for (req, tokens) in batch.into_iter().zip(outs) {
            // Latency = completion − arrival (arrival clamped to ≥ 0).
            let lat = (now - req.arrival_s).max(0.0);
            latency.add(lat);
            responses.push(Response { id: req.id, tokens, latency_s: lat });
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let completed = responses.len();
    let stats = ServeStats {
        completed,
        wall_seconds: wall,
        requests_per_second: completed as f64 / wall,
        tokens_per_second: (completed * n_new) as f64 / wall,
        latency,
    };
    Ok((responses, stats))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batcher_fifo_and_caps() {
        let mut b = Batcher::new(3);
        for id in 0..7 {
            b.push(Request { id, prompt: vec![1], arrival_s: id as f64 });
        }
        let b1 = b.next_batch();
        assert_eq!(b1.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2]);
        assert_eq!(b.next_batch().len(), 3);
        assert_eq!(b.next_batch().len(), 1);
        assert!(b.is_empty());
    }

    // Server tests need artifacts; covered by integration_runtime.rs and
    // examples/serve_inference.rs.
}
