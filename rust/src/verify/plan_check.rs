//! ExecPlan verifier: proves a compiled plan is safe to execute.
//!
//! Given a [`Graph`], its global [`BackwardPlan`] and an [`ExecPlan`], this
//! establishes (stable codes, see [`crate::verify::diag::Code`]):
//!
//! - FA101 forward waves are a partition of `order` over exactly the in-set
//!   nodes (no drops, no duplicates, no strays);
//! - FA102 topological legality: every in-set input sits in a strictly
//!   earlier wave — two nodes of one wave therefore never share an edge, so
//!   the `WaveRunner` thread fan-out is race-free by construction;
//! - FA107 the backward order/waves/positions agree with the global
//!   backward plan and respect gradient-flow dependencies;
//! - FA106 keep-set closure: stashes, losses, sinks and activations
//!   messaged to other compnodes survive as long as their readers need;
//! - FA105 a symbolic replay of the forward and backward sweeps, mirroring
//!   the runtime's refcount bookkeeping exactly, never reads a freed tensor
//!   and never underflows a refcount;
//! - FA103/FA104 `fwd_uses`/`stash_uses` equal the consumer counts
//!   recomputed from scratch.
//!
//! Checks are staged root-cause-first: structural breaks (FA101/FA102/FA107)
//! suppress the downstream phases, keep-set breaks suppress the replay, and
//! the replay suppresses the recounts — a single corrupted field reports its
//! own code instead of a cascade. The replay uses signed counters where the
//! runtime uses `u32`, so an underflow is a diagnostic, not a wrap.

use crate::dag::autodiff::BackwardPlan;
use crate::dag::{Graph, NodeId, OpCategory};
use crate::exec::ExecPlan;

use super::diag::{Code, Report, Span};

/// Verify `plan` against the graph and global backward plan it was compiled
/// from. Pure and panic-free on arbitrary (possibly corrupted) plans.
pub fn check_plan(g: &Graph, bwd: &BackwardPlan, plan: &ExecPlan) -> Report {
    let mut report = Report::new();
    let n = g.len();

    // ---- Phase 0: field lengths and id bounds. Anything indexed below
    // must be safe to index, so a violation here aborts immediately.
    let lengths = [
        ("mine", plan.mine.len()),
        ("fwd_uses", plan.fwd_uses.len()),
        ("keep_after_fp", plan.keep_after_fp.len()),
        ("keep_always", plan.keep_always.len()),
        ("stash_uses", plan.stash_uses.len()),
        ("bwd_pos", plan.bwd_pos.len()),
    ];
    for (field, len) in lengths {
        if len != n {
            report.push(
                Code::WavePartition,
                Span::Global,
                format!("{field} has {len} entries for a {n}-node graph"),
            );
        }
    }
    if bwd.tasks.len() != n {
        report.push(
            Code::WavePartition,
            Span::Global,
            format!("backward plan covers {} nodes, graph has {n}", bwd.tasks.len()),
        );
    }
    for &id in plan.order.iter().chain(plan.waves.iter().flatten()) {
        if id >= n {
            report.push(
                Code::WavePartition,
                Span::Global,
                format!("forward plan references nonexistent node {id}"),
            );
        }
    }
    for &id in plan.bwd_order.iter().chain(plan.bwd_waves.iter().flatten()) {
        if id >= n {
            report.push(
                Code::BwdOrdering,
                Span::Global,
                format!("backward plan references nonexistent node {id}"),
            );
        }
    }
    if report.has_errors() {
        return report;
    }

    // ---- Phase A: wave structure.

    // FA101 — `order` holds exactly the in-set nodes, once each, and the
    // waves are a partition of it.
    let mut seen_in_order = vec![false; n];
    for &id in &plan.order {
        if !plan.mine[id] {
            report.push(
                Code::WavePartition,
                Span::Node(id),
                format!("'{}' is scheduled but not in the executed set", g.node(id).name),
            );
        }
        if std::mem::replace(&mut seen_in_order[id], true) {
            report.push(
                Code::WavePartition,
                Span::Node(id),
                format!("'{}' appears twice in the forward order", g.node(id).name),
            );
        }
    }
    for id in 0..n {
        if plan.mine[id] && !seen_in_order[id] {
            report.push(
                Code::WavePartition,
                Span::Node(id),
                format!("in-set node '{}' is missing from the forward order", g.node(id).name),
            );
        }
    }
    let mut wave_of = vec![usize::MAX; n];
    for (wi, wave) in plan.waves.iter().enumerate() {
        for &id in wave {
            if wave_of[id] != usize::MAX {
                report.push(
                    Code::WavePartition,
                    Span::Wave(wi),
                    format!("'{}' already sits in wave {}", g.node(id).name, wave_of[id]),
                );
            } else if !seen_in_order[id] {
                report.push(
                    Code::WavePartition,
                    Span::Wave(wi),
                    format!("'{}' is in a wave but not in the forward order", g.node(id).name),
                );
            }
            wave_of[id] = wi;
        }
    }
    for &id in &plan.order {
        if wave_of[id] == usize::MAX {
            report.push(
                Code::WavePartition,
                Span::Node(id),
                format!("ordered node '{}' was dropped from every wave", g.node(id).name),
            );
        }
    }
    if plan.wave_flops.len() != plan.waves.len() {
        report.push(
            Code::WavePartition,
            Span::Global,
            format!(
                "wave_flops has {} entries for {} waves",
                plan.wave_flops.len(),
                plan.waves.len()
            ),
        );
    }

    // FA102 — topological legality and intra-wave independence. An in-set
    // arg in the same wave is a read/write race under the thread fan-out.
    let mut pos_in_order = vec![usize::MAX; n];
    for (i, &id) in plan.order.iter().enumerate() {
        pos_in_order[id] = i;
    }
    for &id in &plan.order {
        for &a in &g.node(id).args {
            if a >= n || !plan.mine[a] {
                continue;
            }
            if pos_in_order[a] == usize::MAX || pos_in_order[a] >= pos_in_order[id] {
                report.push(
                    Code::WaveOrdering,
                    Span::Edge { from: a, to: id },
                    format!(
                        "'{}' must be ordered before its consumer '{}'",
                        g.node(a).name,
                        g.node(id).name
                    ),
                );
            }
            if wave_of[a] != usize::MAX && wave_of[id] != usize::MAX && wave_of[a] >= wave_of[id] {
                report.push(
                    Code::WaveOrdering,
                    Span::Wave(wave_of[id]),
                    format!(
                        "'{}' and its input '{}' share wave {} (or the input comes later) — \
                         the wave fan-out would race",
                        g.node(id).name,
                        g.node(a).name,
                        wave_of[id]
                    ),
                );
            }
        }
    }

    // FA107 — backward structure against the global plan.
    let want_bwd: Vec<NodeId> = bwd.order.iter().copied().filter(|&id| plan.mine[id]).collect();
    if plan.bwd_order != want_bwd {
        report.push(
            Code::BwdOrdering,
            Span::Global,
            format!(
                "bwd_order has {} task(s) and disagrees with the global backward plan \
                 restricted to the set ({} task(s))",
                plan.bwd_order.len(),
                want_bwd.len()
            ),
        );
    }
    let want_pos = bwd.positions();
    if plan.bwd_pos != want_pos {
        report.push(
            Code::BwdOrdering,
            Span::Global,
            "bwd_pos disagrees with BackwardPlan::positions() — gradient folds would \
             accumulate in the wrong order"
                .to_string(),
        );
    }
    let mut bwave_of = vec![usize::MAX; n];
    let mut bwd_flat = 0usize;
    for (wi, wave) in plan.bwd_waves.iter().enumerate() {
        for &id in wave {
            bwd_flat += 1;
            if bwave_of[id] != usize::MAX {
                report.push(
                    Code::BwdOrdering,
                    Span::BwdWave(wi),
                    format!("task '{}' already sits in bwd wave {}", g.node(id).name, bwave_of[id]),
                );
            }
            bwave_of[id] = wi;
        }
    }
    for &id in &plan.bwd_order {
        if bwave_of[id] == usize::MAX {
            report.push(
                Code::BwdOrdering,
                Span::Node(id),
                format!("backward task '{}' was dropped from every bwd wave", g.node(id).name),
            );
        }
        match bwd.task(id) {
            None => report.push(
                Code::BwdOrdering,
                Span::Node(id),
                format!("'{}' has no task in the global backward plan", g.node(id).name),
            ),
            Some(task) => {
                // Upstream gradients come from the tasks of in-set users:
                // those must have fired in a strictly earlier bwd wave.
                for &s in &task.grad_sources {
                    if s < n
                        && plan.mine[s]
                        && bwave_of[id] != usize::MAX
                        && (bwave_of[s] == usize::MAX || bwave_of[s] >= bwave_of[id])
                    {
                        report.push(
                            Code::BwdOrdering,
                            Span::BwdWave(bwave_of[id]),
                            format!(
                                "task '{}' needs the gradient from '{}' which is not in an \
                                 earlier bwd wave",
                                g.node(id).name,
                                g.node(s).name
                            ),
                        );
                    }
                }
            }
        }
    }
    if bwd_flat != plan.bwd_order.len() {
        report.push(
            Code::BwdOrdering,
            Span::Global,
            format!("bwd waves hold {} task(s), bwd_order holds {}", bwd_flat, plan.bwd_order.len()),
        );
    }
    if plan.bwd_wave_flops.len() != plan.bwd_waves.len() {
        report.push(
            Code::BwdOrdering,
            Span::Global,
            format!(
                "bwd_wave_flops has {} entries for {} bwd waves",
                plan.bwd_wave_flops.len(),
                plan.bwd_waves.len()
            ),
        );
    }
    if report.has_errors() {
        return report;
    }

    // ---- Phase B: keep-set closure, then the replay. Structure is sound
    // here, so every index below is in bounds.

    for id in 0..n {
        if plan.keep_always[id] && !plan.keep_after_fp[id] {
            report.push(
                Code::KeepSetViolation,
                Span::Node(id),
                format!("'{}' is keep_always but not keep_after_fp", g.node(id).name),
            );
        }
        if plan.stash_uses[id] > 0 && !plan.keep_after_fp[id] {
            report.push(
                Code::KeepSetViolation,
                Span::Node(id),
                format!(
                    "'{}' is re-read by {} backward task(s) but not kept past the forward sweep",
                    g.node(id).name,
                    plan.stash_uses[id]
                ),
            );
        }
        if !plan.mine[id] {
            continue;
        }
        let is_loss = g.node(id).kind.category() == OpCategory::Loss;
        let is_sink = g.users(id).is_empty();
        if (is_loss || is_sink) && !plan.keep_always[id] {
            report.push(
                Code::KeepSetViolation,
                Span::Node(id),
                format!(
                    "{} '{}' must stay queryable for the whole step (keep_always)",
                    if is_loss { "loss" } else { "sink" },
                    g.node(id).name
                ),
            );
        }
        if g.users(id).iter().any(|&u| !plan.mine[u]) && !plan.keep_after_fp[id] {
            report.push(
                Code::KeepSetViolation,
                Span::Node(id),
                format!(
                    "'{}' is messaged to another compnode but freed during the forward sweep",
                    g.node(id).name
                ),
            );
        }
    }
    if report.has_errors() {
        return report;
    }

    // Symbolic replay of the forward sweep: per wave, all reads happen
    // first, then each arg occurrence decrements its refcount and a count
    // reaching zero frees the buffer unless keep_after_fp — exactly the
    // runtime's bookkeeping, with i64 counters so underflow is observable.
    let mut live: Vec<i64> = plan.fwd_uses.iter().map(|&u| i64::from(u)).collect();
    let mut freed = vec![false; n];
    for (wi, wave) in plan.waves.iter().enumerate() {
        for &id in wave {
            for &a in &g.node(id).args {
                if freed[a] {
                    report.push(
                        Code::UseAfterFree,
                        Span::Wave(wi),
                        format!(
                            "'{}' reads '{}' which was already freed by the forward sweep",
                            g.node(id).name,
                            g.node(a).name
                        ),
                    );
                }
            }
        }
        for &id in wave {
            for &a in &g.node(id).args {
                live[a] -= 1;
                if live[a] < 0 {
                    report.push(
                        Code::UseAfterFree,
                        Span::Wave(wi),
                        format!(
                            "fwd_uses of '{}' underflows at its read by '{}' — the runtime \
                             refcount would wrap",
                            g.node(a).name,
                            g.node(id).name
                        ),
                    );
                } else if live[a] == 0 && !plan.keep_after_fp[a] {
                    freed[a] = true;
                }
            }
        }
    }
    // Backward sweep: the pre-pass drops every stash no task will read,
    // then each task re-reads its node's args; keep_always survives.
    if !plan.bwd_order.is_empty() {
        let mut stash: Vec<i64> = plan.stash_uses.iter().map(|&u| i64::from(u)).collect();
        for id in 0..n {
            if plan.stash_uses[id] == 0 && !plan.keep_always[id] {
                freed[id] = true;
            }
        }
        for (wi, wave) in plan.bwd_waves.iter().enumerate() {
            for &id in wave {
                for &a in &g.node(id).args {
                    if freed[a] {
                        report.push(
                            Code::UseAfterFree,
                            Span::BwdWave(wi),
                            format!(
                                "VJP of '{}' reads stash '{}' after the backward sweep freed it",
                                g.node(id).name,
                                g.node(a).name
                            ),
                        );
                    }
                }
            }
            for &id in wave {
                for &a in &g.node(id).args {
                    stash[a] -= 1;
                    if stash[a] < 0 {
                        report.push(
                            Code::UseAfterFree,
                            Span::BwdWave(wi),
                            format!(
                                "stash_uses of '{}' underflows at the VJP of '{}'",
                                g.node(a).name,
                                g.node(id).name
                            ),
                        );
                    } else if stash[a] == 0 && !plan.keep_always[a] {
                        freed[a] = true;
                    }
                }
            }
        }
        for id in 0..n {
            if plan.keep_always[id] && freed[id] {
                report.push(
                    Code::KeepSetViolation,
                    Span::Node(id),
                    format!("keep_always node '{}' did not survive the replay", g.node(id).name),
                );
            }
        }
    }
    if report.has_errors() {
        return report;
    }

    // ---- Phase C: refcount seeds equal the consumer counts recomputed
    // from scratch. (Runs last: a replay that is provably clean can still
    // over-count, which leaks memory rather than corrupting it.)
    let mut want_fwd = vec![0u32; n];
    for &id in &plan.order {
        for &a in &g.node(id).args {
            want_fwd[a] += 1;
        }
    }
    for id in 0..n {
        if want_fwd[id] != plan.fwd_uses[id] {
            report.push(
                Code::FwdUseCount,
                Span::Node(id),
                format!(
                    "fwd_uses of '{}' is {} but {} in-set consumer(s) read it",
                    g.node(id).name,
                    plan.fwd_uses[id],
                    want_fwd[id]
                ),
            );
        }
    }
    let mut want_stash = vec![0u32; n];
    for &id in &plan.bwd_order {
        for &a in &g.node(id).args {
            want_stash[a] += 1;
        }
    }
    for id in 0..n {
        if want_stash[id] != plan.stash_uses[id] {
            report.push(
                Code::StashUseCount,
                Span::Node(id),
                format!(
                    "stash_uses of '{}' is {} but {} backward task(s) read it",
                    g.node(id).name,
                    plan.stash_uses[id],
                    want_stash[id]
                ),
            );
        }
    }

    report
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;
    use crate::dag::autodiff::backward_plan;
    use crate::dag::{DType, OpKind, Shape};
    use crate::models::fig3;

    #[test]
    fn fig3_full_plan_verifies_clean() {
        let g = fig3::build();
        let bwd = backward_plan(&g);
        let plan = ExecPlan::compile_full(&g, &bwd).unwrap();
        let report = check_plan(&g, &bwd, &plan);
        assert!(report.is_clean(), "{}", report.render());
    }

    #[test]
    fn fig3_partition_plans_verify_clean() {
        let g = fig3::build();
        let bwd = backward_plan(&g);
        for sub in 1..=3 {
            let mut in_set = vec![false; g.len()];
            for (id, s) in fig3::paper_partition(&g) {
                in_set[id] = s == sub;
            }
            let plan = ExecPlan::compile(&g, &in_set, &bwd).unwrap();
            let report = check_plan(&g, &bwd, &plan);
            assert!(report.is_clean(), "sub {sub}: {}", report.render());
        }
    }

    #[test]
    fn dropping_a_node_from_its_wave_is_fa101() {
        let g = fig3::build();
        let bwd = backward_plan(&g);
        let mut plan = ExecPlan::compile_full(&g, &bwd).unwrap();
        plan.waves.last_mut().unwrap().pop();
        let report = check_plan(&g, &bwd, &plan);
        assert!(report.has(Code::WavePartition), "{}", report.render());
    }

    #[test]
    fn intra_wave_edge_is_fa102() {
        let mut g = crate::dag::Graph::new();
        let x = g.placeholder("x", Shape::of(&[2, 4]), DType::F32);
        let a = g.op("a", OpKind::Relu, &[x]).unwrap();
        let b = g.op("b", OpKind::Gelu, &[a]).unwrap();
        let bwd = backward_plan(&g);
        let mut plan = ExecPlan::compile_full(&g, &bwd).unwrap();
        // Merge b into a's wave: they share the edge a→b.
        let wb = plan.waves.iter().position(|w| w.contains(&b)).unwrap();
        plan.waves[wb].retain(|&id| id != b);
        let wa = plan.waves.iter().position(|w| w.contains(&a)).unwrap();
        plan.waves[wa].push(b);
        let report = check_plan(&g, &bwd, &plan);
        assert!(report.has(Code::WaveOrdering), "{}", report.render());
    }
}
