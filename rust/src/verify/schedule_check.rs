//! Pipeline schedule legality checker.
//!
//! A [`MicrobatchSchedule`] is legal when (stable codes, see
//! [`crate::verify::diag::Code`]):
//!
//! - FA203 it covers the work: per stage, exactly one Forward and one
//!   Backward per microbatch and exactly one Update, with every event filed
//!   under its own stage;
//! - FA201 the dependency relation over its events is acyclic;
//! - FA202 the per-stage execution order admits progress: the head-pointer
//!   executor (stages run their lists strictly in order, an event fires once
//!   its dependencies completed) drains every event without deadlocking.
//!
//! FA202 is stronger than FA201: an acyclic dependency relation can still
//! deadlock when a stage's list orders an event before one of its
//! prerequisites on the *same* stage — the head pointer never advances.
//! Checks gate each other (coverage → acyclicity → progress) so a broken
//! schedule reports its root cause, not a cascade.

use std::collections::HashMap;

use crate::pipeline::{MicrobatchSchedule, PipeEvent, PipeEventKind};

use super::diag::{Code, Report, Span};

fn key(e: &PipeEvent) -> (usize, usize, u8) {
    (e.stage, e.microbatch, e.kind as u8)
}

/// Check `s` against its own dependency relation
/// ([`MicrobatchSchedule::deps`]).
pub fn check_schedule(s: &MicrobatchSchedule) -> Report {
    check_schedule_with_deps(s, |ev| s.deps(ev))
}

/// Check `s` against an arbitrary dependency relation. Dependencies on
/// events the schedule does not contain are treated as already satisfied
/// (cross-step data is available before the step starts); tests use this
/// entry point to exercise the cycle detector with adversarial relations.
pub fn check_schedule_with_deps<F>(s: &MicrobatchSchedule, deps: F) -> Report
where
    F: Fn(PipeEvent) -> Vec<PipeEvent>,
{
    let mut report = Report::new();

    // ---- FA203: coverage.
    if s.stages == 0 || s.microbatches == 0 {
        report.push(
            Code::MicrobatchCoverage,
            Span::Global,
            format!("degenerate schedule: {} stage(s) × {} microbatch(es)", s.stages, s.microbatches),
        );
        return report;
    }
    if s.per_stage.len() != s.stages {
        report.push(
            Code::MicrobatchCoverage,
            Span::Global,
            format!("{} per-stage event lists for {} stages", s.per_stage.len(), s.stages),
        );
        return report;
    }
    for (si, evs) in s.per_stage.iter().enumerate() {
        let mut fwd = vec![0usize; s.microbatches];
        let mut bwd = vec![0usize; s.microbatches];
        let mut updates = 0usize;
        for ev in evs {
            if ev.stage != si {
                report.push(
                    Code::MicrobatchCoverage,
                    Span::Stage(si),
                    format!("stage {si}'s list holds an event of stage {}", ev.stage),
                );
                continue;
            }
            match ev.kind {
                PipeEventKind::Update => updates += 1,
                kind => {
                    if ev.microbatch >= s.microbatches {
                        report.push(
                            Code::MicrobatchCoverage,
                            Span::Event { stage: si, microbatch: ev.microbatch },
                            format!(
                                "microbatch {} out of range (schedule has {})",
                                ev.microbatch, s.microbatches
                            ),
                        );
                    } else if kind == PipeEventKind::Forward {
                        fwd[ev.microbatch] += 1;
                    } else {
                        bwd[ev.microbatch] += 1;
                    }
                }
            }
        }
        for m in 0..s.microbatches {
            if fwd[m] != 1 {
                report.push(
                    Code::MicrobatchCoverage,
                    Span::Event { stage: si, microbatch: m },
                    format!("stage {si} runs forward of microbatch {m} {} time(s), expected 1", fwd[m]),
                );
            }
            if bwd[m] != 1 {
                report.push(
                    Code::MicrobatchCoverage,
                    Span::Event { stage: si, microbatch: m },
                    format!("stage {si} runs backward of microbatch {m} {} time(s), expected 1", bwd[m]),
                );
            }
        }
        if updates != 1 {
            report.push(
                Code::MicrobatchCoverage,
                Span::Stage(si),
                format!("stage {si} has {updates} update event(s), expected exactly 1"),
            );
        }
    }
    if report.has_errors() {
        return report;
    }

    // ---- FA201: the dependency relation restricted to the schedule's
    // events must be acyclic (Kahn). Coverage passed, so keys are unique.
    let events: Vec<PipeEvent> = s.per_stage.iter().flatten().copied().collect();
    let index: HashMap<(usize, usize, u8), usize> =
        events.iter().enumerate().map(|(i, e)| (key(e), i)).collect();
    let mut edges: Vec<Vec<usize>> = vec![Vec::new(); events.len()]; // dep → dependent
    let mut indeg = vec![0usize; events.len()];
    for (i, ev) in events.iter().enumerate() {
        for d in deps(*ev) {
            if let Some(&j) = index.get(&key(&d)) {
                edges[j].push(i);
                indeg[i] += 1;
            }
        }
    }
    let mut queue: Vec<usize> = (0..events.len()).filter(|&i| indeg[i] == 0).collect();
    let mut head = 0;
    let mut processed = 0;
    while head < queue.len() {
        let u = queue[head];
        head += 1;
        processed += 1;
        for &v in &edges[u] {
            indeg[v] -= 1;
            if indeg[v] == 0 {
                queue.push(v);
            }
        }
    }
    if processed != events.len() {
        for (i, ev) in events.iter().enumerate() {
            if indeg[i] > 0 {
                report.push(
                    Code::DepsCycle,
                    Span::Event { stage: ev.stage, microbatch: ev.microbatch },
                    format!(
                        "{:?} of microbatch {} at stage {} sits on a dependency cycle",
                        ev.kind, ev.microbatch, ev.stage
                    ),
                );
                break; // one witness is enough
            }
        }
        return report;
    }

    // ---- FA202: the head-pointer executor must drain the schedule. This
    // mirrors `MicrobatchSchedule::simulate` without durations: stages fire
    // their head event whenever its dependencies have completed.
    let mut done: HashMap<(usize, usize, u8), bool> = HashMap::new();
    let mut heads = vec![0usize; s.stages];
    let total: usize = s.per_stage.iter().map(Vec::len).sum();
    let mut completed = 0usize;
    loop {
        let mut progressed = false;
        for (si, evs) in s.per_stage.iter().enumerate() {
            while heads[si] < evs.len() {
                let ev = evs[heads[si]];
                let blocked = deps(ev)
                    .iter()
                    .any(|d| index.contains_key(&key(d)) && !done.contains_key(&key(d)));
                if blocked {
                    break;
                }
                done.insert(key(&ev), true);
                heads[si] += 1;
                completed += 1;
                progressed = true;
            }
        }
        if completed == total {
            break;
        }
        if !progressed {
            for (si, evs) in s.per_stage.iter().enumerate() {
                if heads[si] < evs.len() {
                    let ev = evs[heads[si]];
                    report.push(
                        Code::ScheduleDeadlock,
                        Span::Event { stage: si, microbatch: ev.microbatch },
                        format!(
                            "stage {si} is stuck at {:?} of microbatch {} — a dependency can \
                             never complete under this event order",
                            ev.kind, ev.microbatch
                        ),
                    );
                }
            }
            return report;
        }
    }

    report
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;

    #[test]
    fn gpipe_schedules_are_legal() {
        for (stages, micro) in [(1, 1), (1, 5), (3, 2), (4, 8)] {
            let s = MicrobatchSchedule::gpipe(stages, micro);
            let report = check_schedule(&s);
            assert!(report.is_clean(), "{stages}×{micro}: {}", report.render());
        }
    }

    #[test]
    fn injected_cycle_is_fa201() {
        let s = MicrobatchSchedule::gpipe(2, 2);
        // Adversarial relation: forward of m0 additionally waits on its own
        // backward — a cycle with the real Backward→Forward stash dep.
        let report = check_schedule_with_deps(&s, |ev| {
            let mut d = s.deps(ev);
            if ev.kind == PipeEventKind::Forward && ev.microbatch == 0 {
                d.push(PipeEvent { stage: ev.stage, microbatch: 0, kind: PipeEventKind::Backward });
            }
            d
        });
        assert!(report.has(Code::DepsCycle), "{}", report.render());
        assert!(!report.has(Code::ScheduleDeadlock));
    }

    #[test]
    fn reordered_stage_list_is_fa202() {
        let mut s = MicrobatchSchedule::gpipe(1, 2);
        // Put backward of m1 before its own forward: acyclic deps, but the
        // head pointer can never pass it.
        let evs = &mut s.per_stage[0];
        let fpos = evs
            .iter()
            .position(|e| e.kind == PipeEventKind::Forward && e.microbatch == 1)
            .unwrap();
        let bpos = evs
            .iter()
            .position(|e| e.kind == PipeEventKind::Backward && e.microbatch == 1)
            .unwrap();
        evs.swap(fpos, bpos);
        let report = check_schedule(&s);
        assert!(report.has(Code::ScheduleDeadlock), "{}", report.render());
        assert!(!report.has(Code::DepsCycle));
    }

    #[test]
    fn missing_backward_is_fa203() {
        let mut s = MicrobatchSchedule::gpipe(2, 3);
        s.per_stage[1].retain(|e| !(e.kind == PipeEventKind::Backward && e.microbatch == 1));
        let report = check_schedule(&s);
        assert!(report.has(Code::MicrobatchCoverage), "{}", report.render());
        // Coverage gates the later phases: no cascade into FA201/FA202.
        assert!(!report.has(Code::DepsCycle) && !report.has(Code::ScheduleDeadlock));
    }
}
