//! Diagnostics framework for the static verifier.
//!
//! Every analyzer ([`crate::verify::graph_lint`], [`crate::verify::plan_check`],
//! [`crate::verify::schedule_check`]) reports findings as [`Diagnostic`]s with
//! a stable [`Code`] (`FA001`, `FA002`, …), a [`Severity`] and a [`Span`]
//! locating the finding in a graph, plan or schedule. Codes are part of the
//! tool's contract: tests assert on them and CI greps rendered reports, so a
//! code is never reused for a different condition once published (see
//! DESIGN.md §Static analysis for the full table).

use std::fmt;

use crate::dag::NodeId;

/// How bad a finding is. Errors fail `PassManager::validation()`, plan
/// compilation under `FUSIONAI_VERIFY=1` and the `lint` subcommand; warnings
/// are advisory (e.g. dead code that `DeadNodeElimination` would remove).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    Warning,
    Error,
}

impl fmt::Display for Severity {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Severity::Warning => write!(f, "warning"),
            Severity::Error => write!(f, "error"),
        }
    }
}

/// Stable diagnostic codes. `FA0xx` = graph lints, `FA1xx` = execution-plan
/// proofs, `FA2xx` = pipeline-schedule legality.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Code {
    /// FA001 — two nodes share a name.
    DuplicateName,
    /// FA002 — fan-in arity does not match the operator kind.
    ArityMismatch,
    /// FA003 — an i32 tensor feeds an operator that only takes f32.
    DtypeViolation,
    /// FA004 — declared shape/dtype disagrees with re-inference (or
    /// inference fails outright).
    ShapeIncoherent,
    /// FA005 — an arg references a node that does not exist, or ids are not
    /// dense.
    DanglingInput,
    /// FA006 — node cannot influence any loss/sink (dead code).
    UnreachableNode,
    /// FA007 — stage-partition invariant broken: missing/unparsable
    /// `"subgraph"` kwarg or a backward cross-stage edge.
    StagePartition,
    /// FA101 — forward waves are not a partition of the plan's order.
    WavePartition,
    /// FA102 — a node and one of its inputs share a wave (data race) or the
    /// input is scheduled later.
    WaveOrdering,
    /// FA103 — `fwd_uses` disagrees with the recounted in-set consumers.
    FwdUseCount,
    /// FA104 — `stash_uses` disagrees with the recounted backward readers.
    StashUseCount,
    /// FA105 — symbolic replay reads a tensor after its refcount freed it
    /// (or a refcount underflows).
    UseAfterFree,
    /// FA106 — keep-set violation: a stash, loss, sink or messaged output
    /// would not survive as long as its readers need it.
    KeepSetViolation,
    /// FA107 — backward order/waves/positions disagree with the global
    /// backward plan.
    BwdOrdering,
    /// FA201 — the schedule's dependency relation has a cycle.
    DepsCycle,
    /// FA202 — per-stage event order deadlocks (a stage's head event waits
    /// on an event that can never complete first).
    ScheduleDeadlock,
    /// FA203 — microbatch coverage broken: missing/duplicated
    /// forward/backward/update events or misfiled stages.
    MicrobatchCoverage,
}

impl Code {
    /// The stable wire form, `FA001`…
    pub fn as_str(self) -> &'static str {
        match self {
            Code::DuplicateName => "FA001",
            Code::ArityMismatch => "FA002",
            Code::DtypeViolation => "FA003",
            Code::ShapeIncoherent => "FA004",
            Code::DanglingInput => "FA005",
            Code::UnreachableNode => "FA006",
            Code::StagePartition => "FA007",
            Code::WavePartition => "FA101",
            Code::WaveOrdering => "FA102",
            Code::FwdUseCount => "FA103",
            Code::StashUseCount => "FA104",
            Code::UseAfterFree => "FA105",
            Code::KeepSetViolation => "FA106",
            Code::BwdOrdering => "FA107",
            Code::DepsCycle => "FA201",
            Code::ScheduleDeadlock => "FA202",
            Code::MicrobatchCoverage => "FA203",
        }
    }

    /// Default severity: everything is an error except dead code.
    pub fn default_severity(self) -> Severity {
        match self {
            Code::UnreachableNode => Severity::Warning,
            _ => Severity::Error,
        }
    }
}

impl fmt::Display for Code {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.as_str())
    }
}

/// Where a diagnostic points.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Span {
    /// One graph node.
    Node(NodeId),
    /// A data edge `from → to`.
    Edge { from: NodeId, to: NodeId },
    /// A forward wave of an execution plan.
    Wave(usize),
    /// A backward wave of an execution plan.
    BwdWave(usize),
    /// A pipeline stage.
    Stage(usize),
    /// One pipeline event `(stage, microbatch)`.
    Event { stage: usize, microbatch: usize },
    /// The whole artifact.
    Global,
}

impl fmt::Display for Span {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Span::Node(id) => write!(f, "node {id}"),
            Span::Edge { from, to } => write!(f, "edge {from}→{to}"),
            Span::Wave(w) => write!(f, "wave {w}"),
            Span::BwdWave(w) => write!(f, "bwd wave {w}"),
            Span::Stage(s) => write!(f, "stage {s}"),
            Span::Event { stage, microbatch } => write!(f, "event (s{stage}, m{microbatch})"),
            Span::Global => write!(f, "global"),
        }
    }
}

/// One finding.
#[derive(Debug, Clone)]
pub struct Diagnostic {
    pub code: Code,
    pub severity: Severity,
    pub span: Span,
    pub message: String,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{} {} at {}: {}", self.code, self.severity, self.span, self.message)
    }
}

/// An ordered collection of findings from one analyzer run.
#[derive(Debug, Clone, Default)]
pub struct Report {
    pub diags: Vec<Diagnostic>,
}

impl Report {
    pub fn new() -> Report {
        Report::default()
    }

    /// Record a finding at the code's default severity.
    pub fn push(&mut self, code: Code, span: Span, message: String) {
        self.diags.push(Diagnostic { code, severity: code.default_severity(), span, message });
    }

    /// Append every finding of `other`.
    pub fn merge(&mut self, other: Report) {
        self.diags.extend(other.diags);
    }

    pub fn has_errors(&self) -> bool {
        self.diags.iter().any(|d| d.severity == Severity::Error)
    }

    pub fn error_count(&self) -> usize {
        self.diags.iter().filter(|d| d.severity == Severity::Error).count()
    }

    pub fn warning_count(&self) -> usize {
        self.diags.iter().filter(|d| d.severity == Severity::Warning).count()
    }

    /// No findings at all — not even warnings.
    pub fn is_clean(&self) -> bool {
        self.diags.is_empty()
    }

    /// Whether any finding carries `code`.
    pub fn has(&self, code: Code) -> bool {
        self.diags.iter().any(|d| d.code == code)
    }

    /// Error-severity codes in report order, consecutive repeats collapsed
    /// (the form the adversarial-fixture tests assert on).
    pub fn error_codes(&self) -> Vec<Code> {
        let mut v: Vec<Code> = self
            .diags
            .iter()
            .filter(|d| d.severity == Severity::Error)
            .map(|d| d.code)
            .collect();
        v.dedup();
        v
    }

    /// Human-readable multi-line report.
    pub fn render(&self) -> String {
        if self.diags.is_empty() {
            return "verify: clean (no diagnostics)".to_string();
        }
        let mut s = String::new();
        for d in &self.diags {
            s.push_str(&d.to_string());
            s.push('\n');
        }
        s.push_str(&format!(
            "verify: {} error(s), {} warning(s)",
            self.error_count(),
            self.warning_count()
        ));
        s
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;

    #[test]
    fn codes_are_stable_and_unique() {
        let all = [
            Code::DuplicateName,
            Code::ArityMismatch,
            Code::DtypeViolation,
            Code::ShapeIncoherent,
            Code::DanglingInput,
            Code::UnreachableNode,
            Code::StagePartition,
            Code::WavePartition,
            Code::WaveOrdering,
            Code::FwdUseCount,
            Code::StashUseCount,
            Code::UseAfterFree,
            Code::KeepSetViolation,
            Code::BwdOrdering,
            Code::DepsCycle,
            Code::ScheduleDeadlock,
            Code::MicrobatchCoverage,
        ];
        let mut seen = std::collections::BTreeSet::new();
        for c in all {
            assert!(c.as_str().starts_with("FA"));
            assert!(seen.insert(c.as_str()), "code {c} reused");
        }
        assert_eq!(seen.len(), 17);
    }

    #[test]
    fn report_counts_and_rendering() {
        let mut r = Report::new();
        assert!(r.is_clean());
        assert!(r.render().contains("clean"));
        r.push(Code::UnreachableNode, Span::Node(3), "dead".into());
        assert!(!r.has_errors(), "dead code is only a warning");
        r.push(Code::WaveOrdering, Span::Wave(1), "race".into());
        assert!(r.has_errors());
        assert_eq!(r.error_count(), 1);
        assert_eq!(r.warning_count(), 1);
        assert_eq!(r.error_codes(), vec![Code::WaveOrdering]);
        let text = r.render();
        assert!(text.contains("FA006 warning at node 3: dead"));
        assert!(text.contains("FA102 error at wave 1: race"));
        assert!(text.contains("1 error(s), 1 warning(s)"));
    }
}
