//! DAG linter: structural and semantic well-formedness of a [`Graph`].
//!
//! Checks (stable codes, see [`crate::verify::diag::Code`]):
//! - FA001 duplicate node names
//! - FA002 fan-in arity per [`OpKind`]
//! - FA003 i32 tensors feeding f32-only operators
//! - FA004 declared shape/dtype vs re-inference through [`infer_shape`]
//! - FA005 dangling inputs / non-dense ids
//! - FA006 nodes that cannot influence any loss/sink (warning)
//! - FA007 stage-partition invariants from [`ChainPartitionPass`] kwargs
//!
//! Runs on arbitrary (possibly hand-broken or deserialized) node lists, so
//! every check guards its own preconditions: a node that fails FA005 is
//! excluded from FA002/FA003/FA004 instead of cascading or panicking.

use crate::dag::ir::{infer_shape, DType, Graph, GraphError, OpKind, Shape};
use crate::dag::{GraphPass, NodeId, OpCategory};
use crate::decompose::SUBGRAPH_KEY;

use super::diag::{Code, Report, Span};

/// Exact fan-in for fixed-arity operators; `None` for variadic ones
/// (`Concat` ≥1, `StageCall` 0..n — pipeline builders append label edges via
/// `Graph::add_arg`).
fn expected_arity(kind: &OpKind) -> Option<usize> {
    use OpKind::*;
    match kind {
        Placeholder | Variable => Some(0),
        Conv2d { .. } | Linear { .. } | Embedding { .. } | LayerNorm { .. }
        | Attention { .. } | FeedForward { .. } | Relu | Gelu | Softmax
        | MaxPool2d { .. } => Some(1),
        Add | Multiply | CrossEntropy { .. } | MseLoss => Some(2),
        Concat { .. } | StageCall { .. } => None,
    }
}

/// Operators whose contract admits an i32 input. Everything else computes in
/// f32 and would reinterpret integer payloads.
fn accepts_i32(kind: &OpKind) -> bool {
    matches!(kind, OpKind::Embedding { .. } | OpKind::CrossEntropy { .. } | OpKind::StageCall { .. })
}

/// Lint `g` and return every finding. Never panics, never mutates.
pub fn lint_graph(g: &Graph) -> Report {
    let mut report = Report::new();
    let n = g.len();

    // FA001 — duplicate names.
    let mut names = std::collections::BTreeMap::new();
    for node in &g.nodes {
        if let Some(&first) = names.get(node.name.as_str()) {
            report.push(
                Code::DuplicateName,
                Span::Node(node.id.min(n.saturating_sub(1))),
                format!("name '{}' already used by node {first}", node.name),
            );
        } else {
            names.insert(node.name.as_str(), node.id);
        }
    }

    // FA005 — dense ids and in-bounds args. Nodes failing this are skipped
    // by the value-level checks below.
    let mut structurally_ok = vec![true; n];
    for (i, node) in g.nodes.iter().enumerate() {
        if node.id != i {
            report.push(
                Code::DanglingInput,
                Span::Node(i),
                format!("node '{}' carries id {} at index {i} (ids must be dense)", node.name, node.id),
            );
            structurally_ok[i] = false;
        }
        for &a in &node.args {
            if a >= n {
                report.push(
                    Code::DanglingInput,
                    Span::Node(i),
                    format!("node '{}' reads nonexistent node {a} (graph has {n} nodes)", node.name),
                );
                structurally_ok[i] = false;
            }
        }
    }

    // FA002 — arity. Gates FA003/FA004 for the same node so one broken
    // fan-in yields one root-cause code, not a cascade.
    let mut arity_ok = vec![true; n];
    for (i, node) in g.nodes.iter().enumerate() {
        if !structurally_ok[i] {
            continue;
        }
        match expected_arity(&node.kind) {
            Some(want) if node.args.len() != want => {
                report.push(
                    Code::ArityMismatch,
                    Span::Node(i),
                    format!(
                        "{} '{}' takes {want} input(s), got {}",
                        node.kind.name(),
                        node.name,
                        node.args.len()
                    ),
                );
                arity_ok[i] = false;
            }
            None if matches!(node.kind, OpKind::Concat { .. }) && node.args.is_empty() => {
                report.push(
                    Code::ArityMismatch,
                    Span::Node(i),
                    format!("Concat '{}' needs at least one input", node.name),
                );
                arity_ok[i] = false;
            }
            _ => {}
        }
    }

    // FA003 — i32 flowing into f32-only operators.
    for (i, node) in g.nodes.iter().enumerate() {
        if !structurally_ok[i] || !arity_ok[i] || accepts_i32(&node.kind) {
            continue;
        }
        for &a in &node.args {
            if g.nodes[a].out_dtype == DType::I32 {
                report.push(
                    Code::DtypeViolation,
                    Span::Edge { from: a, to: i },
                    format!(
                        "i32 output of '{}' feeds {} '{}' which computes in f32",
                        g.nodes[a].name,
                        node.kind.name(),
                        node.name
                    ),
                );
            }
        }
    }

    // FA004 — declared shape/dtype must agree with re-inference. Leaves keep
    // their declared shapes and StageCall shapes are owned by the artifact
    // (same exemptions as the ShapeInference pass).
    for (i, node) in g.nodes.iter().enumerate() {
        if !structurally_ok[i] || !arity_ok[i] {
            continue;
        }
        match node.kind {
            OpKind::Placeholder | OpKind::Variable | OpKind::StageCall { .. } => continue,
            _ => {}
        }
        let args: Vec<(&Shape, DType)> =
            node.args.iter().map(|&a| (&g.nodes[a].out_shape, g.nodes[a].out_dtype)).collect();
        match infer_shape(&node.name, &node.kind, &args) {
            Err(e) => report.push(
                Code::ShapeIncoherent,
                Span::Node(i),
                format!("shape inference failed: {e}"),
            ),
            Ok((shape, dtype)) => {
                if shape != node.out_shape || dtype != node.out_dtype {
                    report.push(
                        Code::ShapeIncoherent,
                        Span::Node(i),
                        format!(
                            "'{}' declares {}:{} but inference gives {}:{}",
                            node.name, node.out_shape, node.out_dtype, shape, dtype
                        ),
                    );
                }
            }
        }
    }

    // FA006 — reachability (warning). Roots are the losses when the graph
    // has any (training), else every sink (inference). Walk upward through
    // `args` — never the cached reverse adjacency, which hand-edited graphs
    // can leave stale.
    let losses: Vec<NodeId> =
        g.nodes.iter().filter(|nd| nd.kind.category() == OpCategory::Loss).map(|nd| nd.id).collect();
    let roots: Vec<NodeId> = if losses.is_empty() {
        let mut consumed = vec![false; n];
        for node in &g.nodes {
            for &a in &node.args {
                if a < n {
                    consumed[a] = true;
                }
            }
        }
        (0..n).filter(|&i| !consumed[i]).collect()
    } else {
        losses
    };
    let mut reached = vec![false; n];
    let mut stack: Vec<usize> = roots.into_iter().filter(|&r| r < n).collect();
    while let Some(id) = stack.pop() {
        if std::mem::replace(&mut reached[id], true) {
            continue;
        }
        stack.extend(g.nodes[id].args.iter().copied().filter(|&a| a < n));
    }
    for (i, node) in g.nodes.iter().enumerate() {
        if !reached[i] {
            report.push(
                Code::UnreachableNode,
                Span::Node(i),
                format!("'{}' cannot influence any loss/sink (dead code)", node.name),
            );
        }
    }

    // FA007 — stage-partition invariants, only once a partition exists
    // (ChainPartitionPass annotates *every* node). Segment indices must
    // parse, cover every node, and never decrease along a data edge — a
    // backward cross-stage edge would make the pipeline acyclic claim false.
    if g.nodes.iter().any(|nd| nd.kwargs.contains_key(SUBGRAPH_KEY)) {
        let mut seg: Vec<Option<usize>> = vec![None; n];
        for (i, node) in g.nodes.iter().enumerate() {
            match node.kwargs.get(SUBGRAPH_KEY) {
                None => report.push(
                    Code::StagePartition,
                    Span::Node(i),
                    format!(
                        "graph is partitioned but '{}' has no '{SUBGRAPH_KEY}' kwarg",
                        node.name
                    ),
                ),
                Some(raw) => match raw.parse::<usize>() {
                    Ok(s) => seg[i] = Some(s),
                    Err(_) => report.push(
                        Code::StagePartition,
                        Span::Node(i),
                        format!("'{}' has unparsable '{SUBGRAPH_KEY}' kwarg '{raw}'", node.name),
                    ),
                },
            }
        }
        for (i, node) in g.nodes.iter().enumerate() {
            if !structurally_ok[i] {
                continue;
            }
            for &a in &node.args {
                if let (Some(sa), Some(si)) = (seg[a], seg[i]) {
                    if sa > si {
                        report.push(
                            Code::StagePartition,
                            Span::Edge { from: a, to: i },
                            format!(
                                "edge from '{}' (segment {sa}) back into '{}' (segment {si}) crosses stages backward",
                                g.nodes[a].name, node.name
                            ),
                        );
                    }
                }
            }
        }
    }

    report
}

/// [`GraphPass`] wrapper so the linter slots into
/// `PassManager::validation()`. Errors fail the pipeline with the rendered
/// report; warnings (FA006 dead code) pass — `DeadNodeElimination` handles
/// those, and validation-only pipelines must accept graphs that still carry
/// dead branches.
pub struct GraphLintPass;

impl GraphPass for GraphLintPass {
    fn name(&self) -> &'static str {
        "graph-lint"
    }

    fn run(&self, g: &mut Graph) -> Result<bool, GraphError> {
        let report = lint_graph(g);
        if report.has_errors() {
            return Err(GraphError::Invalid(format!("lint failed\n{}", report.render())));
        }
        Ok(false)
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;
    use crate::dag::ir::{DType, Graph, OpKind, Shape};

    fn mlp() -> Graph {
        let mut g = Graph::new();
        let x = g.placeholder("x", Shape::of(&[4, 8]), DType::F32);
        let y = g.placeholder("y", Shape::of(&[4, 2]), DType::F32);
        let h = g
            .op("fc1", OpKind::Linear { in_features: 8, out_features: 16, bias: true }, &[x])
            .unwrap();
        let r = g.op("relu", OpKind::Relu, &[h]).unwrap();
        let o = g
            .op("fc2", OpKind::Linear { in_features: 16, out_features: 2, bias: true }, &[r])
            .unwrap();
        g.op("loss", OpKind::MseLoss, &[o, y]).unwrap();
        g
    }

    #[test]
    fn clean_graph_is_clean() {
        let report = lint_graph(&mlp());
        assert!(report.is_clean(), "{}", report.render());
    }

    #[test]
    fn lint_pass_accepts_clean_and_rejects_broken() {
        let mut g = mlp();
        assert!(GraphLintPass.run(&mut g).is_ok());
        let relu = g.by_name("relu").unwrap().id;
        g.nodes[relu].args.push(relu); // arity break (self-edge too)
        let err = GraphLintPass.run(&mut g).unwrap_err();
        assert!(err.to_string().contains("FA002"), "{err}");
    }

    #[test]
    fn fa006_is_warning_only() {
        let mut g = mlp();
        let x = g.by_name("x").unwrap().id;
        g.op("dead", OpKind::Gelu, &[x]).unwrap();
        let report = lint_graph(&g);
        assert!(report.has(Code::UnreachableNode));
        assert!(!report.has_errors(), "{}", report.render());
        // Validation pipelines therefore still pass.
        assert!(GraphLintPass.run(&mut g).is_ok());
    }
}
