//! Self-hosted static verifier.
//!
//! Three analyzers over the system's three planes, united by one
//! diagnostics framework ([`diag`]):
//!
//! * [`graph_lint`] — is the DAG IR well-formed? (dtype/shape coherence,
//!   arity, dangling inputs, reachability, stage-partition invariants)
//! * [`plan_check`] — is a compiled [`crate::exec::ExecPlan`] safe?
//!   (waves partition the order topologically ⇒ the thread fan-out is
//!   race-free; a symbolic replay of both sweeps proves the liveness
//!   refcounts never free a tensor someone still reads)
//! * [`schedule_check`] — is a [`crate::pipeline::MicrobatchSchedule`]
//!   legal? (coverage, acyclic deps, per-stage order admits progress)
//!
//! Wiring: `PassManager::validation()` runs the linter, `ExecPlan::compile`
//! verifies its own output and `MicrobatchSchedule::gpipe` checks its
//! schedule whenever [`verify_enabled`] — always in debug builds, opt-in
//! for release via `FUSIONAI_VERIFY=1` (the golden/bitwise CI suites run
//! with it on). The `lint` CLI subcommand exposes the same analyzers over
//! graph JSON files and exits non-zero on any error diagnostic.

#![deny(clippy::unwrap_used)]

pub mod diag;
pub mod graph_lint;
pub mod plan_check;
pub mod schedule_check;

pub use diag::{Code, Diagnostic, Report, Severity, Span};
pub use graph_lint::{lint_graph, GraphLintPass};
pub use plan_check::check_plan;
pub use schedule_check::{check_schedule, check_schedule_with_deps};

use std::sync::atomic::{AtomicUsize, Ordering};

/// 0 = unresolved, 1 = off, 2 = on.
static VERIFY: AtomicUsize = AtomicUsize::new(0);

/// Force the always-on verification gate (overrides `FUSIONAI_VERIFY`).
pub fn set_verify(on: bool) {
    VERIFY.store(if on { 2 } else { 1 }, Ordering::Relaxed);
}

/// Whether the in-line verification hooks run: always in debug builds,
/// otherwise when `FUSIONAI_VERIFY=1` (resolved once, cached).
pub fn verify_enabled() -> bool {
    if cfg!(debug_assertions) {
        return true;
    }
    match VERIFY.load(Ordering::Relaxed) {
        2 => true,
        1 => false,
        _ => {
            let on = std::env::var("FUSIONAI_VERIFY").map(|v| v == "1").unwrap_or(false);
            VERIFY.store(if on { 2 } else { 1 }, Ordering::Relaxed);
            on
        }
    }
}
