//! The assembled decentralized cluster.
//!
//! * [`data`] — synthetic token corpus + the DHT-backed data provider
//!   (paper §3.9: inputs/labels are retrieved from data providers through
//!   the DHT);
//! * [`sim`] — a deterministic in-process cluster over fine-grained DAGs
//!   and the [`crate::exec::RefEngine`], with virtual-time α-β networking,
//!   checkpoint-to-supernode and churn recovery;
//! * [`train`] — the live pipeline trainer: one OS thread per compnode,
//!   each owning a private PJRT runtime ([`crate::exec::XlaEngine`]),
//!   GPipe microbatching over real channels with simulated WAN delays and
//!   optional compression, under a supervising coordinator that detects
//!   stage failure and replays from the last recovery checkpoint. This is
//!   the end-to-end production path;
//! * [`stage_backend`] — the per-stage compute contract the trainer drives
//!   (XLA artifacts, or a deterministic host simulator for fault tests);
//! * [`faults`] — deterministic fault injection exercised by the recovery
//!   integration tests;
//! * [`checkpoint`] — the `FAICKPT` formats: v1 (params, what `serve`
//!   loads) and v2 (params + Adam moments + step, what recovery resumes
//!   from).

pub mod checkpoint;
pub mod data;
pub mod faults;
pub mod sim;
pub mod stage_backend;
pub mod train;

pub use checkpoint::{CheckpointV2, StageSnapshot};
pub use faults::{Fault, FaultPlan, HopFault};
pub use sim::{SimCluster, StepReport};
pub use stage_backend::{
    SimStageFactory, SimStagesConfig, StageBackend, StageBackendFactory, XlaStageFactory,
};
pub use train::{PipelineTrainer, TrainConfig, TrainReport};
