//! The assembled decentralized cluster.
//!
//! * [`data`] — synthetic token corpus + the DHT-backed data provider
//!   (paper §3.9: inputs/labels are retrieved from data providers through
//!   the DHT);
//! * [`sim`] — a deterministic in-process cluster over fine-grained DAGs
//!   and the [`crate::exec::RefEngine`], with virtual-time α-β networking,
//!   checkpoint-to-supernode and churn recovery;
//! * [`train`] — the live pipeline trainer: one OS thread per compnode,
//!   each owning a private PJRT runtime ([`crate::exec::XlaEngine`]),
//!   GPipe microbatching over real channels with simulated WAN delays and
//!   optional compression. This is the end-to-end production path.

pub mod checkpoint;
pub mod data;
pub mod sim;
pub mod train;

pub use sim::{SimCluster, StepReport};
pub use train::{PipelineTrainer, TrainConfig, TrainReport};
