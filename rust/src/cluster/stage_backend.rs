//! Pluggable per-stage compute for the live pipeline trainer.
//!
//! The trainer's supervisor (see [`crate::cluster::train`]) must not care
//! *how* a stage computes — only that it can run forward/backward/update
//! and snapshot/restore its full training state for recovery. That contract
//! is [`StageBackend`]; stage threads build their backend through a shared
//! [`StageBackendFactory`] (backends themselves are deliberately not
//! `Send`: the XLA backend holds thread-affine PJRT handles, so each stage
//! thread constructs its own).
//!
//! Two backends ship in-tree:
//!
//! * [`XlaStageFactory`] → AOT-compiled PJRT artifacts (the production hot
//!   path; requires a real PJRT plugin);
//! * [`SimStageFactory`] → a tiny pure-rust residual-tanh LM
//!   (embed → block… → head with softmax cross-entropy). Bitwise
//!   deterministic, no artifacts needed — this is what the fault-injection
//!   tests and CI drive the full supervisor/recovery machinery with.

use anyhow::{anyhow, bail, Result};

use crate::cluster::checkpoint::StageSnapshot;
use crate::exec::xla_engine::{stage_kind, StageKind, StageState, XlaEngine};
use crate::runtime::{InitKind, Manifest, ParamSpec};
use crate::tensor::{self, Tensor};
use crate::util::Rng;

/// One pipeline stage's compute + optimizer state.
///
/// The backward contract mirrors `XlaEngine`: returns
/// `(dx, param_grads, loss)` where `dx` is `None` for the embed stage and
/// `loss` is `Some` only for the head stage. `backward` rematerializes —
/// it recomputes forward intermediates from `inputs`, so callers stash only
/// stage inputs per microbatch.
pub trait StageBackend {
    fn stage(&self) -> &str;
    /// Forward: `[tokens]` (embed), `[h]` (block) — head stages train
    /// through `backward` directly.
    fn forward(&mut self, inputs: &[&Tensor]) -> Result<Tensor>;
    /// Backward: embed `[tokens]` + dh, block `[x]` + dh', head
    /// `[h, labels]` + `None`.
    fn backward(
        &mut self,
        inputs: &[&Tensor],
        out_grad: Option<&Tensor>,
    ) -> Result<(Option<Tensor>, Vec<Tensor>, Option<f32>)>;
    /// Adam update; `step` is 1-based so resumed runs bias-correct exactly
    /// like uninterrupted ones.
    fn update(&mut self, grads: &[Tensor], step: i32) -> Result<()>;
    /// Full training state (params + Adam moments) as host tensors.
    fn snapshot(&self) -> StageSnapshot;
    /// Replace training state from a snapshot (recovery restore).
    fn restore(&mut self, snap: &StageSnapshot) -> Result<()>;
    fn n_params(&self) -> usize;
}

/// Thread-safe constructor of per-stage backends. `seed` is the run seed;
/// implementations derive the per-stage init stream from it the same way
/// (`seed ^ stage_idx << 17`) so trajectories are comparable across
/// backends of the same numerics.
pub trait StageBackendFactory: Send + Sync {
    fn make(&self, stage: &str, stage_idx: usize, seed: u64) -> Result<Box<dyn StageBackend>>;
}

fn stage_rng(seed: u64, stage_idx: usize) -> Rng {
    Rng::new(seed ^ (stage_idx as u64) << 17)
}

// ---------------------------------------------------------------------------
// XLA-backed stages
// ---------------------------------------------------------------------------

/// Factory for artifact-backed stages (one `XlaEngine` per stage thread).
pub struct XlaStageFactory {
    pub dir: std::path::PathBuf,
}

impl StageBackendFactory for XlaStageFactory {
    fn make(&self, stage: &str, stage_idx: usize, seed: u64) -> Result<Box<dyn StageBackend>> {
        let engine = XlaEngine::load_stage(&self.dir, stage)?;
        let mut rng = stage_rng(seed, stage_idx);
        let state = engine.new_stage_state(stage, &mut rng)?;
        Ok(Box::new(XlaStageBackend { engine, state }))
    }
}

struct XlaStageBackend {
    engine: XlaEngine,
    state: StageState,
}

impl StageBackend for XlaStageBackend {
    fn stage(&self) -> &str {
        &self.state.stage
    }

    fn forward(&mut self, inputs: &[&Tensor]) -> Result<Tensor> {
        self.engine.forward_cached(&self.state, inputs)
    }

    fn backward(
        &mut self,
        inputs: &[&Tensor],
        out_grad: Option<&Tensor>,
    ) -> Result<(Option<Tensor>, Vec<Tensor>, Option<f32>)> {
        self.engine.backward_cached(&self.state, inputs, out_grad)
    }

    fn update(&mut self, grads: &[Tensor], step: i32) -> Result<()> {
        self.engine.update_cached(&mut self.state, grads, step)
    }

    fn snapshot(&self) -> StageSnapshot {
        StageSnapshot {
            params: self.state.params.clone(),
            opt_m: self.state.opt_m.clone(),
            opt_v: self.state.opt_v.clone(),
        }
    }

    fn restore(&mut self, snap: &StageSnapshot) -> Result<()> {
        self.state = self.engine.stage_state_from_parts(
            &self.state.stage.clone(),
            snap.params.clone(),
            snap.opt_m.clone(),
            snap.opt_v.clone(),
        )?;
        Ok(())
    }

    fn n_params(&self) -> usize {
        self.state.n_params()
    }
}

// ---------------------------------------------------------------------------
// Simulated stages (pure rust, deterministic)
// ---------------------------------------------------------------------------

/// Model/config of the simulated pipeline: a residual-tanh LM.
///
/// * embed:  `h = W[tokens]`, `W: [vocab, dim]`
/// * block:  `y = x + tanh(x·A)`, `A: [dim, dim]`
/// * head:   `loss = CE(softmax(h·U), labels)`, `U: [dim, vocab]`
#[derive(Debug, Clone)]
pub struct SimStagesConfig {
    pub vocab: usize,
    pub dim: usize,
    pub batch: usize,
    pub seq: usize,
    pub n_blocks: usize,
    pub lr: f32,
}

impl Default for SimStagesConfig {
    fn default() -> SimStagesConfig {
        SimStagesConfig { vocab: 64, dim: 16, batch: 2, seq: 8, n_blocks: 2, lr: 0.01 }
    }
}

impl SimStagesConfig {
    /// Ordered stage names: `embed, block0…blockN-1, head`.
    pub fn stages(&self) -> Vec<String> {
        let mut s = vec!["embed".to_string()];
        s.extend((0..self.n_blocks).map(|i| format!("block{i}")));
        s.push("head".to_string());
        s
    }

    /// A programmatic [`Manifest`] so the trainer reads batch/seq/vocab
    /// through the same surface it uses for artifact directories.
    pub fn manifest(&self) -> Manifest {
        let mut config = std::collections::HashMap::new();
        config.insert("vocab".to_string(), self.vocab as f64);
        config.insert("dim".to_string(), self.dim as f64);
        config.insert("batch".to_string(), self.batch as f64);
        config.insert("seq".to_string(), self.seq as f64);
        let mut stage_params = std::collections::HashMap::new();
        let spec = |name: &str, shape: Vec<usize>| ParamSpec {
            name: name.to_string(),
            shape,
            init: InitKind::Normal { std: 0.02 },
        };
        stage_params
            .insert("embed".to_string(), vec![spec("wte", vec![self.vocab, self.dim])]);
        for i in 0..self.n_blocks {
            stage_params
                .insert(format!("block{i}"), vec![spec("a", vec![self.dim, self.dim])]);
        }
        stage_params.insert("head".to_string(), vec![spec("u", vec![self.dim, self.vocab])]);
        Manifest {
            preset: "sim".to_string(),
            config,
            artifacts: Vec::new(),
            stage_params,
            stages: self.stages(),
        }
    }
}

/// Factory for simulated stages.
pub struct SimStageFactory {
    pub cfg: SimStagesConfig,
}

impl StageBackendFactory for SimStageFactory {
    fn make(&self, stage: &str, stage_idx: usize, seed: u64) -> Result<Box<dyn StageBackend>> {
        let kind = stage_kind(stage)?;
        let c = &self.cfg;
        let mut rng = stage_rng(seed, stage_idx);
        let shape: &[usize] = match kind {
            StageKind::Embed => &[c.vocab, c.dim],
            StageKind::Block => &[c.dim, c.dim],
            StageKind::Head => &[c.dim, c.vocab],
        };
        let params = vec![Tensor::randn(shape, 0.02, &mut rng)];
        let opt_m = vec![Tensor::zeros(shape)];
        let opt_v = vec![Tensor::zeros(shape)];
        Ok(Box::new(SimStageBackend {
            stage: stage.to_string(),
            kind,
            vocab: c.vocab,
            dim: c.dim,
            lr: c.lr,
            params,
            opt_m,
            opt_v,
        }))
    }
}

struct SimStageBackend {
    stage: String,
    kind: StageKind,
    vocab: usize,
    dim: usize,
    lr: f32,
    params: Vec<Tensor>,
    opt_m: Vec<Tensor>,
    opt_v: Vec<Tensor>,
}

impl SimStageBackend {
    fn weight(&self) -> &[f32] {
        self.params[0].f()
    }

    /// Rows of an activation tensor `[.., dim]`.
    fn rows_of(&self, t: &Tensor) -> Result<usize> {
        if !t.is_f32() {
            bail!("stage '{}': expected f32 activations, got i32", self.stage);
        }
        let numel = t.f().len();
        if self.dim == 0 || numel % self.dim != 0 {
            bail!("stage '{}': activation numel {numel} not divisible by dim {}", self.stage, self.dim);
        }
        Ok(numel / self.dim)
    }

    fn token_row(&self, tok: i32) -> Result<usize> {
        let t = tok as usize;
        if tok < 0 || t >= self.vocab {
            bail!("stage '{}': token id {tok} outside vocab {}", self.stage, self.vocab);
        }
        Ok(t)
    }

    fn one(&self, inputs: &[&Tensor], want: usize) -> Result<()> {
        if inputs.len() != want {
            bail!("stage '{}' expects {want} input(s), got {}", self.stage, inputs.len());
        }
        Ok(())
    }

    /// logits (row-major `[rows, vocab]`) for the head stage.
    fn logits(&self, h: &Tensor) -> Result<(Vec<f32>, usize)> {
        let rows = self.rows_of(h)?;
        Ok((tensor::matmul(h.f(), self.weight(), rows, self.dim, self.vocab), rows))
    }
}

impl StageBackend for SimStageBackend {
    fn stage(&self) -> &str {
        &self.stage
    }

    fn forward(&mut self, inputs: &[&Tensor]) -> Result<Tensor> {
        match self.kind {
            StageKind::Embed => {
                self.one(inputs, 1)?;
                let tokens = inputs[0];
                if tokens.is_f32() {
                    bail!("embed expects i32 token ids");
                }
                let toks = tokens.i();
                let mut out = Vec::with_capacity(toks.len() * self.dim);
                let w = self.weight();
                for &t in toks {
                    let r = self.token_row(t)?;
                    out.extend_from_slice(&w[r * self.dim..(r + 1) * self.dim]);
                }
                let mut shape = tokens.shape().to_vec();
                shape.push(self.dim);
                Ok(Tensor::from_vec(&shape, out))
            }
            StageKind::Block => {
                self.one(inputs, 1)?;
                let x = inputs[0];
                let rows = self.rows_of(x)?;
                let mut z = tensor::matmul(x.f(), self.weight(), rows, self.dim, self.dim);
                for (zi, &xi) in z.iter_mut().zip(x.f()) {
                    *zi = xi + zi.tanh();
                }
                Ok(Tensor::from_vec(x.shape(), z))
            }
            StageKind::Head => {
                self.one(inputs, 2)?;
                let (logits, rows) = self.logits(inputs[0])?;
                if inputs[1].is_f32() {
                    bail!("head expects i32 labels");
                }
                let labels = inputs[1].i();
                if labels.len() != rows {
                    bail!("head: {} labels for {rows} rows", labels.len());
                }
                let mut probs = logits;
                tensor::softmax_lastaxis(&mut probs, self.vocab);
                let mut loss = 0.0f64;
                for (r, &lab) in labels.iter().enumerate() {
                    let l = self.token_row(lab)?;
                    loss -= (probs[r * self.vocab + l].max(1e-30) as f64).ln();
                }
                Ok(Tensor::scalar((loss / rows as f64) as f32))
            }
        }
    }

    fn backward(
        &mut self,
        inputs: &[&Tensor],
        out_grad: Option<&Tensor>,
    ) -> Result<(Option<Tensor>, Vec<Tensor>, Option<f32>)> {
        match self.kind {
            StageKind::Embed => {
                self.one(inputs, 1)?;
                let dh = out_grad
                    .ok_or_else(|| anyhow!("embed backward requires an upstream gradient"))?;
                if inputs[0].is_f32() || !dh.is_f32() {
                    bail!("embed backward expects i32 tokens and f32 dh");
                }
                let toks = inputs[0].i();
                let dhf = dh.f();
                if dhf.len() != toks.len() * self.dim {
                    bail!("embed: dh numel {} != tokens {} × dim {}", dhf.len(), toks.len(), self.dim);
                }
                let mut dw = vec![0.0f32; self.vocab * self.dim];
                // Row-ascending accumulation: the only floating-point sum
                // whose order matters here, fixed for bitwise replay.
                for (r, &t) in toks.iter().enumerate() {
                    let row = self.token_row(t)?;
                    for d in 0..self.dim {
                        dw[row * self.dim + d] += dhf[r * self.dim + d];
                    }
                }
                Ok((None, vec![Tensor::from_vec(&[self.vocab, self.dim], dw)], None))
            }
            StageKind::Block => {
                self.one(inputs, 1)?;
                let dy = out_grad
                    .ok_or_else(|| anyhow!("block backward requires an upstream gradient"))?;
                let x = inputs[0];
                let rows = self.rows_of(x)?;
                if !dy.is_f32() || dy.f().len() != rows * self.dim {
                    bail!("block: dy must be f32 with {} elements", rows * self.dim);
                }
                // Rematerialize z = x·A, then dz = dy ⊙ (1 − tanh²z).
                let z = tensor::matmul(x.f(), self.weight(), rows, self.dim, self.dim);
                let mut dz = Vec::with_capacity(z.len());
                for (&zi, &dyi) in z.iter().zip(dy.f()) {
                    let th = zi.tanh();
                    dz.push(dyi * (1.0 - th * th));
                }
                // y = x + tanh(x·A): dx = dy + dz·Aᵀ, dA = xᵀ·dz.
                let mut dx = tensor::matmul_bt(&dz, self.weight(), rows, self.dim, self.dim);
                for (dxi, &dyi) in dx.iter_mut().zip(dy.f()) {
                    *dxi += dyi;
                }
                let da = tensor::matmul_at(x.f(), &dz, self.dim, rows, self.dim);
                Ok((
                    Some(Tensor::from_vec(x.shape(), dx)),
                    vec![Tensor::from_vec(&[self.dim, self.dim], da)],
                    None,
                ))
            }
            StageKind::Head => {
                self.one(inputs, 2)?;
                let h = inputs[0];
                let (logits, rows) = self.logits(h)?;
                if inputs[1].is_f32() {
                    bail!("head expects i32 labels");
                }
                let labels = inputs[1].i();
                if labels.len() != rows {
                    bail!("head: {} labels for {rows} rows", labels.len());
                }
                let mut probs = logits;
                tensor::softmax_lastaxis(&mut probs, self.vocab);
                let mut loss = 0.0f64;
                for (r, &lab) in labels.iter().enumerate() {
                    let l = self.token_row(lab)?;
                    loss -= (probs[r * self.vocab + l].max(1e-30) as f64).ln();
                }
                // dlogits = (softmax − onehot) / rows, mean-reduced CE.
                let inv = 1.0 / rows as f32;
                for (r, &lab) in labels.iter().enumerate() {
                    probs[r * self.vocab + lab as usize] -= 1.0;
                }
                for p in probs.iter_mut() {
                    *p *= inv;
                }
                let dh = tensor::matmul_bt(&probs, self.weight(), rows, self.vocab, self.dim);
                let du = tensor::matmul_at(h.f(), &probs, self.dim, rows, self.vocab);
                Ok((
                    Some(Tensor::from_vec(h.shape(), dh)),
                    vec![Tensor::from_vec(&[self.dim, self.vocab], du)],
                    Some((loss / rows as f64) as f32),
                ))
            }
        }
    }

    fn update(&mut self, grads: &[Tensor], step: i32) -> Result<()> {
        if grads.len() != self.params.len() {
            bail!("stage '{}': {} grads for {} params", self.stage, grads.len(), self.params.len());
        }
        // Adam with bias correction from the *passed* step: stateless given
        // (m, v, step), which is exactly what exact resume needs.
        let (b1, b2, eps) = (0.9f32, 0.999f32, 1e-8f32);
        let b1t = 1.0 - b1.powi(step);
        let b2t = 1.0 - b2.powi(step);
        for ((p, g), (m, v)) in self
            .params
            .iter_mut()
            .zip(grads)
            .zip(self.opt_m.iter_mut().zip(self.opt_v.iter_mut()))
        {
            let pf = p.f_mut();
            let gf = g.f();
            let mf = m.f_mut();
            let vf = v.f_mut();
            for i in 0..pf.len() {
                mf[i] = b1 * mf[i] + (1.0 - b1) * gf[i];
                vf[i] = b2 * vf[i] + (1.0 - b2) * gf[i] * gf[i];
                pf[i] -= self.lr * (mf[i] / b1t) / ((vf[i] / b2t).sqrt() + eps);
            }
        }
        Ok(())
    }

    fn snapshot(&self) -> StageSnapshot {
        StageSnapshot {
            params: self.params.clone(),
            opt_m: self.opt_m.clone(),
            opt_v: self.opt_v.clone(),
        }
    }

    fn restore(&mut self, snap: &StageSnapshot) -> Result<()> {
        if snap.params.len() != self.params.len() {
            bail!("stage '{}': snapshot has {} params, backend {}", self.stage, snap.params.len(), self.params.len());
        }
        self.params = snap.params.clone();
        self.opt_m = snap.opt_m.clone();
        self.opt_v = snap.opt_v.clone();
        Ok(())
    }

    fn n_params(&self) -> usize {
        self.params.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> SimStagesConfig {
        SimStagesConfig { vocab: 11, dim: 6, batch: 2, seq: 3, n_blocks: 1, lr: 0.01 }
    }

    fn factory() -> SimStageFactory {
        SimStageFactory { cfg: cfg() }
    }

    fn tokens() -> Tensor {
        Tensor::from_ivec(&[2, 3], vec![1, 4, 7, 2, 0, 10])
    }

    fn labels() -> Tensor {
        Tensor::from_ivec(&[2, 3], vec![4, 7, 2, 0, 10, 1])
    }

    #[test]
    fn shapes_flow_through_the_pipeline() {
        let f = factory();
        let mut embed = f.make("embed", 0, 7).unwrap();
        let mut block = f.make("block0", 1, 7).unwrap();
        let mut head = f.make("head", 2, 7).unwrap();
        let h0 = embed.forward(&[&tokens()]).unwrap();
        assert_eq!(h0.shape(), &[2, 3, 6]);
        let h1 = block.forward(&[&h0]).unwrap();
        assert_eq!(h1.shape(), &[2, 3, 6]);
        let (dh, du, loss) = head.backward(&[&h1, &labels()], None).unwrap();
        let dh = dh.unwrap();
        assert_eq!(dh.shape(), &[2, 3, 6]);
        assert_eq!(du[0].shape(), &[6, 11]);
        let loss = loss.unwrap();
        assert!(loss.is_finite() && loss > 0.0);
        // Near-uniform softmax at init: loss ≈ ln(vocab).
        assert!((loss - (11.0f32).ln()).abs() < 0.1, "loss {loss}");
        let (dx, da, _) = block.backward(&[&h0], Some(&dh)).unwrap();
        assert_eq!(dx.as_ref().unwrap().shape(), &[2, 3, 6]);
        assert_eq!(da[0].shape(), &[6, 6]);
        let (none, dw, _) = embed.backward(&[&tokens()], dx.as_ref()).unwrap();
        assert!(none.is_none());
        assert_eq!(dw[0].shape(), &[11, 6]);
    }

    #[test]
    fn bad_inputs_error_not_panic() {
        let f = factory();
        let mut embed = f.make("embed", 0, 7).unwrap();
        assert!(embed.forward(&[&Tensor::from_ivec(&[1], vec![99])]).is_err(), "oov token");
        let mut head = f.make("head", 2, 7).unwrap();
        let h = Tensor::zeros(&[2, 3, 6]);
        assert!(head.backward(&[&h, &Tensor::from_ivec(&[2], vec![0, 1])], None).is_err());
        let mut block = f.make("block0", 1, 7).unwrap();
        assert!(block.forward(&[&Tensor::zeros(&[5])]).is_err(), "numel not divisible by dim");
        assert!(block.backward(&[&h], None).is_err(), "missing out_grad");
    }

    /// Finite-difference check of every analytic gradient the sim backend
    /// produces, composed through the full embed→block→head chain.
    #[test]
    fn gradients_match_finite_differences() {
        let f = factory();
        let mut embed = f.make("embed", 0, 3).unwrap();
        let mut block = f.make("block0", 1, 3).unwrap();
        let mut head = f.make("head", 2, 3).unwrap();
        let toks = tokens();
        let labs = labels();

        let loss_of = |embed: &mut Box<dyn StageBackend>,
                       block: &mut Box<dyn StageBackend>,
                       head: &mut Box<dyn StageBackend>| {
            let h0 = embed.forward(&[&toks]).unwrap();
            let h1 = block.forward(&[&h0]).unwrap();
            head.forward(&[&h1, &labs]).unwrap().item() as f64
        };

        // Analytic grads.
        let h0 = embed.forward(&[&toks]).unwrap();
        let h1 = block.forward(&[&h0]).unwrap();
        let (dh1, du, _) = head.backward(&[&h1, &labs], None).unwrap();
        let (dh0, da, _) = block.backward(&[&h0], dh1.as_ref()).unwrap();
        let (_, dw, _) = embed.backward(&[&toks], dh0.as_ref()).unwrap();
        let analytic = [(2usize, &du[0]), (1, &da[0]), (0, &dw[0])];

        // FD per parameter tensor, probing a few fixed elements.
        let eps = 1e-3f32;
        for (who, grad) in analytic {
            let n = grad.f().len();
            for &i in &[0usize, n / 3, n - 1] {
                let mut probe = |delta: f32| {
                    let snaps =
                        [embed.snapshot(), block.snapshot(), head.snapshot()];
                    let mut s = snaps[who].clone();
                    s.params[0].f_mut()[i] += delta;
                    match who {
                        0 => embed.restore(&s).unwrap(),
                        1 => block.restore(&s).unwrap(),
                        _ => head.restore(&s).unwrap(),
                    }
                    let l = loss_of(&mut embed, &mut block, &mut head);
                    match who {
                        0 => embed.restore(&snaps[0]).unwrap(),
                        1 => block.restore(&snaps[1]).unwrap(),
                        _ => head.restore(&snaps[2]).unwrap(),
                    }
                    l
                };
                let fd = (probe(eps) - probe(-eps)) / (2.0 * eps as f64);
                let an = grad.f()[i] as f64;
                assert!(
                    (fd - an).abs() < 1e-2 * (1.0 + an.abs()),
                    "param {who} elem {i}: fd {fd} vs analytic {an}"
                );
            }
        }
    }

    #[test]
    fn snapshot_restore_replays_bitwise() {
        let f = factory();
        let mut head = f.make("head", 2, 9).unwrap();
        let h = {
            let mut embed = f.make("embed", 0, 9).unwrap();
            embed.forward(&[&tokens()]).unwrap()
        };
        // Two updates, snapshot, two more, restore, redo: must be bitwise.
        for step in 1..=2 {
            let (_, du, _) = head.backward(&[&h, &labels()], None).unwrap();
            head.update(&du, step).unwrap();
        }
        let snap = head.snapshot();
        for step in 3..=4 {
            let (_, du, _) = head.backward(&[&h, &labels()], None).unwrap();
            head.update(&du, step).unwrap();
        }
        let end_a = head.snapshot();
        head.restore(&snap).unwrap();
        for step in 3..=4 {
            let (_, du, _) = head.backward(&[&h, &labels()], None).unwrap();
            head.update(&du, step).unwrap();
        }
        assert_eq!(head.snapshot(), end_a, "resume must be exact, not approximate");
    }

    #[test]
    fn same_seed_same_backend() {
        let f = factory();
        let a = f.make("block0", 1, 42).unwrap().snapshot();
        let b = f.make("block0", 1, 42).unwrap().snapshot();
        assert_eq!(a, b);
        let c = f.make("block0", 1, 43).unwrap().snapshot();
        assert_ne!(a, c);
    }

    #[test]
    fn training_reduces_loss() {
        let f = factory();
        let mut embed = f.make("embed", 0, 5).unwrap();
        let mut block = f.make("block0", 1, 5).unwrap();
        let mut head = f.make("head", 2, 5).unwrap();
        let toks = tokens();
        let labs = labels();
        let mut first = None;
        let mut last = 0.0f32;
        for step in 1..=30 {
            let h0 = embed.forward(&[&toks]).unwrap();
            let h1 = block.forward(&[&h0]).unwrap();
            let (dh1, du, loss) = head.backward(&[&h1, &labs], None).unwrap();
            let (dh0, da, _) = block.backward(&[&h0], dh1.as_ref()).unwrap();
            let (_, dw, _) = embed.backward(&[&toks], dh0.as_ref()).unwrap();
            head.update(&du, step).unwrap();
            block.update(&da, step).unwrap();
            embed.update(&dw, step).unwrap();
            last = loss.unwrap();
            first.get_or_insert(last);
        }
        assert!(last < first.unwrap() - 0.1, "loss {first:?} -> {last}");
    }

    #[test]
    fn sim_manifest_mirrors_config() {
        let m = cfg().manifest();
        assert_eq!(m.stages, vec!["embed", "block0", "head"]);
        assert_eq!(m.config_usize("batch"), Some(2));
        assert_eq!(m.config_usize("seq"), Some(3));
        assert_eq!(m.config_usize("vocab"), Some(11));
        assert_eq!(m.stage_params["head"][0].shape, vec![6, 11]);
    }
}
