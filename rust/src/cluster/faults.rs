//! Deterministic fault injection for the live trainer.
//!
//! Geo-distributed training treats peer failure as the common case, so
//! every recovery path in `cluster::train` must be exercisable on demand.
//! A [`FaultPlan`] is a list of one-shot faults armed against (stage, step)
//! or (hop, step) coordinates; the trainer consults it at the exact points
//! where a real fault would bite (worker step loop, `send_hop`, checkpoint
//! publish) and the plan "fires" each fault at most once — so a recovered
//! run replaying the same step does not re-trip the same fault.
//!
//! Plans parse from a compact grammar (CLI `--faults`, TOML
//! `[recovery] faults = "..."`). Semicolon-separated clauses, each
//! `kind:key=value,...`:
//!
//! ```text
//!   kill:stage=1,step=3           worker thread errors out at step 3
//!   stall:stage=0,step=2,ms=500   worker sleeps 500ms before step 2
//!   drop:from=0,to=1,step=2       one activation/grad hop is lost
//!   delay:from=1,to=2,step=4,ms=100   one hop is late by 100ms
//!   truncate:step=4,keep=32       checkpoint written at step 4 is cut to 32 bytes
//! ```

use std::sync::Mutex;

use anyhow::{bail, Context, Result};

/// One injectable fault, armed at a (stage/hop, step) coordinate.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Fault {
    /// Stage `stage`'s worker returns an error at the top of step `step`.
    Kill { stage: usize, step: usize },
    /// Stage `stage` sleeps `ms` before step `step` (exercises heartbeat
    /// timeouts without a hard failure).
    Stall { stage: usize, step: usize, ms: u64 },
    /// The first `from`→`to` hop of step `step` is lost in flight.
    DropHop { from: usize, to: usize, step: usize },
    /// The first `from`→`to` hop of step `step` arrives `ms` late.
    DelayHop { from: usize, to: usize, step: usize, ms: u64 },
    /// The v2 checkpoint written at the end of step `step` is truncated to
    /// `keep` bytes after publish (exercises the `.prev` fallback).
    TruncateCheckpoint { step: usize, keep: u64 },
}

/// What `fire_hop` tells `send_hop` to do to a message.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HopFault {
    Drop,
    DelayMs(u64),
}

/// A set of one-shot faults shared (behind `Arc`) between the coordinator
/// and every stage thread. Interior mutability so firing needs only `&self`.
#[derive(Debug, Default)]
pub struct FaultPlan {
    slots: Mutex<Vec<(Fault, bool)>>,
}

impl FaultPlan {
    pub fn new(faults: Vec<Fault>) -> FaultPlan {
        FaultPlan { slots: Mutex::new(faults.into_iter().map(|f| (f, false)).collect()) }
    }

    /// Parse the `--faults` grammar (see module docs). Empty string → empty
    /// plan.
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut faults = Vec::new();
        for clause in spec.split(';').map(str::trim).filter(|c| !c.is_empty()) {
            faults.push(parse_clause(clause).with_context(|| format!("fault clause '{clause}'"))?);
        }
        Ok(FaultPlan::new(faults))
    }

    /// Number of faults that have not fired yet.
    pub fn remaining(&self) -> usize {
        self.slots.lock().unwrap().iter().filter(|(_, fired)| !fired).count()
    }

    fn fire<T>(&self, mut hit: impl FnMut(&Fault) -> Option<T>) -> Option<T> {
        let mut slots = self.slots.lock().unwrap();
        for (fault, fired) in slots.iter_mut() {
            if *fired {
                continue;
            }
            if let Some(v) = hit(fault) {
                *fired = true;
                return Some(v);
            }
        }
        None
    }

    /// True if a `kill` fault is armed for this stage at this step.
    pub fn fire_kill(&self, stage: usize, step: usize) -> bool {
        self.fire(|f| match f {
            Fault::Kill { stage: s, step: k } if *s == stage && *k == step => Some(()),
            _ => None,
        })
        .is_some()
    }

    /// Milliseconds to stall, if a `stall` fault is armed here.
    pub fn fire_stall(&self, stage: usize, step: usize) -> Option<u64> {
        self.fire(|f| match f {
            Fault::Stall { stage: s, step: k, ms } if *s == stage && *k == step => Some(*ms),
            _ => None,
        })
    }

    /// Hop-level fault for a `from`→`to` message in `step`, if armed.
    pub fn fire_hop(&self, from: usize, to: usize, step: usize) -> Option<HopFault> {
        self.fire(|f| match f {
            Fault::DropHop { from: a, to: b, step: k } if (*a, *b, *k) == (from, to, step) => {
                Some(HopFault::Drop)
            }
            Fault::DelayHop { from: a, to: b, step: k, ms }
                if (*a, *b, *k) == (from, to, step) =>
            {
                Some(HopFault::DelayMs(*ms))
            }
            _ => None,
        })
    }

    /// Bytes to keep of the checkpoint just written at `step`, if a
    /// `truncate` fault is armed.
    pub fn fire_truncate(&self, step: usize) -> Option<u64> {
        self.fire(|f| match f {
            Fault::TruncateCheckpoint { step: k, keep } if *k == step => Some(*keep),
            _ => None,
        })
    }
}

fn parse_clause(clause: &str) -> Result<Fault> {
    let (kind, rest) = clause.split_once(':').unwrap_or((clause, ""));
    let mut kv = std::collections::HashMap::new();
    for pair in rest.split(',').map(str::trim).filter(|p| !p.is_empty()) {
        let (k, v) = pair.split_once('=').with_context(|| format!("expected key=value in '{pair}'"))?;
        let v: u64 = v.trim().parse().with_context(|| format!("non-numeric value in '{pair}'"))?;
        kv.insert(k.trim().to_string(), v);
    }
    let mut get = |key: &str| -> Result<u64> {
        kv.remove(key).with_context(|| format!("'{kind}' fault needs '{key}='"))
    };
    let fault = match kind {
        "kill" => Fault::Kill { stage: get("stage")? as usize, step: get("step")? as usize },
        "stall" => Fault::Stall {
            stage: get("stage")? as usize,
            step: get("step")? as usize,
            ms: get("ms")?,
        },
        "drop" => Fault::DropHop {
            from: get("from")? as usize,
            to: get("to")? as usize,
            step: get("step")? as usize,
        },
        "delay" => Fault::DelayHop {
            from: get("from")? as usize,
            to: get("to")? as usize,
            step: get("step")? as usize,
            ms: get("ms")?,
        },
        "truncate" => {
            Fault::TruncateCheckpoint { step: get("step")? as usize, keep: get("keep")? }
        }
        other => bail!("unknown fault kind '{other}' (kill|stall|drop|delay|truncate)"),
    };
    if let Some(stray) = kv.keys().next() {
        bail!("unknown key '{stray}' for '{kind}' fault");
    }
    Ok(fault)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_all_kinds() {
        let plan = FaultPlan::parse(
            "kill:stage=1,step=3; stall:stage=0,step=2,ms=500; drop:from=0,to=1,step=2; \
             delay:from=1,to=2,step=4,ms=100; truncate:step=4,keep=32",
        )
        .unwrap();
        assert_eq!(plan.remaining(), 5);
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(FaultPlan::parse("explode:stage=1").is_err());
        assert!(FaultPlan::parse("kill:stage=1").is_err(), "missing step");
        assert!(FaultPlan::parse("kill:stage=1,step=2,bogus=3").is_err(), "stray key");
        assert!(FaultPlan::parse("kill:stage=x,step=2").is_err(), "non-numeric");
        assert_eq!(FaultPlan::parse("").unwrap().remaining(), 0);
        assert_eq!(FaultPlan::parse(" ; ").unwrap().remaining(), 0);
    }

    #[test]
    fn faults_fire_exactly_once() {
        let plan = FaultPlan::parse("kill:stage=1,step=3").unwrap();
        assert!(!plan.fire_kill(0, 3), "wrong stage");
        assert!(!plan.fire_kill(1, 2), "wrong step");
        assert!(plan.fire_kill(1, 3));
        assert!(!plan.fire_kill(1, 3), "one-shot: replay must not re-trip");
        assert_eq!(plan.remaining(), 0);
    }

    #[test]
    fn hop_faults_match_coordinates() {
        let plan = FaultPlan::parse("drop:from=0,to=1,step=2; delay:from=1,to=2,step=2,ms=50")
            .unwrap();
        assert_eq!(plan.fire_hop(0, 1, 1), None);
        assert_eq!(plan.fire_hop(0, 1, 2), Some(HopFault::Drop));
        assert_eq!(plan.fire_hop(0, 1, 2), None, "one-shot");
        assert_eq!(plan.fire_hop(1, 2, 2), Some(HopFault::DelayMs(50)));
    }

    #[test]
    fn stall_and_truncate() {
        let plan = FaultPlan::parse("stall:stage=0,step=2,ms=500; truncate:step=4,keep=32")
            .unwrap();
        assert_eq!(plan.fire_stall(0, 2), Some(500));
        assert_eq!(plan.fire_stall(0, 2), None);
        assert_eq!(plan.fire_truncate(3), None);
        assert_eq!(plan.fire_truncate(4), Some(32));
    }
}
