//! Synthetic corpus + DHT data provider (paper §3.9).
//!
//! "Compnodes that have Input or Label placeholders consistently retrieve
//! data from these data providers" — here the provider materializes
//! deterministic synthetic token batches (a Zipf-ish mixture with enough
//! structure that a language model's loss visibly drops) and publishes them
//! into the DHT under `data/<step>/<microbatch>/{tokens,labels}`; consumers
//! fetch and deserialize.

use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Result};

use crate::dht::Dht;
use crate::tensor::Tensor;
use crate::util::Rng;

/// Deterministic synthetic corpus: next-token-predictable sequences.
///
/// Tokens follow a noisy arithmetic progression modulo the vocab with a
/// per-sequence stride — a structure a transformer learns quickly, so loss
/// curves show real learning instead of noise-floor wandering.
#[derive(Debug, Clone)]
pub struct SyntheticCorpus {
    pub vocab: usize,
    pub seq: usize,
    pub batch: usize,
    noise: f64,
}

impl SyntheticCorpus {
    pub fn new(vocab: usize, seq: usize, batch: usize) -> SyntheticCorpus {
        SyntheticCorpus { vocab, seq, batch, noise: 0.05 }
    }

    /// Batch `idx` as `(tokens[B,S], labels[B,S])` — labels are the
    /// next-token shift.
    pub fn batch(&self, idx: u64) -> (Tensor, Tensor) {
        let mut rng = Rng::new(0xDA7A ^ idx.wrapping_mul(0x9E37));
        let mut toks = Vec::with_capacity(self.batch * self.seq);
        let mut labs = Vec::with_capacity(self.batch * self.seq);
        for _ in 0..self.batch {
            // Stride in [1, 16], start anywhere; sequences wrap the vocab.
            let stride = 1 + rng.below(16) as usize;
            let start = rng.below(self.vocab as u64) as usize;
            let mut seq_toks = Vec::with_capacity(self.seq + 1);
            for t in 0..=self.seq {
                let mut tok = (start + t * stride) % self.vocab;
                if rng.chance(self.noise) {
                    tok = rng.below(self.vocab as u64) as usize;
                }
                seq_toks.push(tok as i32);
            }
            toks.extend_from_slice(&seq_toks[..self.seq]);
            labs.extend_from_slice(&seq_toks[1..]);
        }
        (
            Tensor::from_ivec(&[self.batch, self.seq], toks),
            Tensor::from_ivec(&[self.batch, self.seq], labs),
        )
    }
}

/// Serialize an i32 tensor for DHT storage (LE, shape-free — the consumer
/// knows the shape from the manifest).
pub fn tokens_to_bytes(t: &Tensor) -> Vec<u8> {
    let mut out = Vec::with_capacity(t.numel() * 4);
    for &v in t.i() {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

/// Deserialize an i32 tensor of the given shape.
pub fn tokens_from_bytes(bytes: &[u8], shape: &[usize]) -> Result<Tensor> {
    let n: usize = shape.iter().product();
    if bytes.len() != 4 * n {
        return Err(anyhow!("token blob has {} bytes, want {}", bytes.len(), 4 * n));
    }
    let vals: Vec<i32> =
        bytes.chunks_exact(4).map(|c| i32::from_le_bytes(c.try_into().unwrap())).collect();
    Ok(Tensor::from_ivec(shape, vals))
}

/// DHT keys for one (step, microbatch) pair.
pub fn data_key(step: usize, mb: usize, what: &str) -> String {
    format!("data/{step}/{mb}/{what}")
}

/// The provider: publishes `microbatches` batches per step.
pub struct DataProvider {
    pub corpus: SyntheticCorpus,
    dht: Arc<Mutex<Dht>>,
}

impl DataProvider {
    pub fn new(corpus: SyntheticCorpus, dht: Arc<Mutex<Dht>>) -> DataProvider {
        DataProvider { corpus, dht }
    }

    /// Publish all microbatches of `step`.
    pub fn publish_step(&self, step: usize, microbatches: usize) -> Result<()> {
        let mut dht = self.dht.lock().unwrap();
        for mb in 0..microbatches {
            let idx = (step * microbatches + mb) as u64;
            let (toks, labs) = self.corpus.batch(idx);
            dht.put(&data_key(step, mb, "tokens"), tokens_to_bytes(&toks))?;
            dht.put(&data_key(step, mb, "labels"), tokens_to_bytes(&labs))?;
        }
        Ok(())
    }

    /// Drop a step's data after consumption (bounded storage).
    pub fn retire_step(&self, step: usize, microbatches: usize) {
        let mut dht = self.dht.lock().unwrap();
        for mb in 0..microbatches {
            dht.delete(&data_key(step, mb, "tokens"));
            dht.delete(&data_key(step, mb, "labels"));
        }
    }
}

/// Consumer-side fetch.
pub fn fetch_tokens(
    dht: &Arc<Mutex<Dht>>,
    step: usize,
    mb: usize,
    what: &str,
    shape: &[usize],
) -> Result<Tensor> {
    let dht = dht.lock().unwrap();
    let bytes = dht.get(&data_key(step, mb, what)).map_err(|e| anyhow!("{e}"))?;
    tokens_from_bytes(bytes, shape)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_is_deterministic_and_shifted() {
        let c = SyntheticCorpus::new(64, 8, 2);
        let (t1, l1) = c.batch(7);
        let (t2, _) = c.batch(7);
        assert_eq!(t1, t2);
        assert_eq!(t1.shape(), &[2, 8]);
        // labels are a shift: label[i] == token[i+1] wherever no noise hit;
        // check the relation holds for most positions.
        let mut matches = 0;
        for b in 0..2 {
            for i in 0..7 {
                if l1.i()[b * 8 + i] == t1.i()[b * 8 + i + 1] {
                    matches += 1;
                }
            }
        }
        assert!(matches >= 12, "only {matches}/14 shifted positions match");
        // all in vocab
        assert!(t1.i().iter().all(|&t| (t as usize) < 64));
    }

    #[test]
    fn different_batches_differ() {
        let c = SyntheticCorpus::new(64, 8, 2);
        assert_ne!(c.batch(0).0, c.batch(1).0);
    }

    #[test]
    fn bytes_roundtrip() {
        let c = SyntheticCorpus::new(100, 6, 3);
        let (t, _) = c.batch(0);
        let b = tokens_to_bytes(&t);
        assert_eq!(tokens_from_bytes(&b, &[3, 6]).unwrap(), t);
        assert!(tokens_from_bytes(&b, &[4, 6]).is_err());
    }

    #[test]
    fn provider_publish_fetch_retire() {
        let mut dht = Dht::new(2);
        for p in 0..4 {
            dht.join(p).unwrap();
        }
        let dht = Arc::new(Mutex::new(dht));
        let corpus = SyntheticCorpus::new(64, 8, 2);
        let provider = DataProvider::new(corpus.clone(), dht.clone());
        provider.publish_step(3, 2).unwrap();
        let t = fetch_tokens(&dht, 3, 1, "tokens", &[2, 8]).unwrap();
        let (want, _) = corpus.batch(7); // step 3, mb 1 ⇒ idx 3*2+1
        assert_eq!(t, want);
        provider.retire_step(3, 2);
        assert!(fetch_tokens(&dht, 3, 1, "tokens", &[2, 8]).is_err());
    }
}
