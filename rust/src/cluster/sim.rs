//! Deterministic in-process cluster over fine-grained DAGs (RefEngine).
//!
//! One [`SimCluster`] wires: a decomposed graph, one [`SubDagExecutor`] per
//! sub-graph (the compnodes), an α-β [`NetworkSim`] for every cross-compnode
//! message (virtual time — nothing sleeps), parameter **checkpointing to
//! the supernode** (paper §3.5: "the parameters of parametric OPs […]
//! require to be optimized and synchronized with the supernode in case of
//! compnode failures") and churn recovery that restores a failed compnode's
//! sub-DAG on a fresh executor from the last checkpoint.
//!
//! This is the substrate of `examples/quickstart.rs` and
//! `examples/churn_tolerance.rs`, and of the integration tests.

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::compnode::SubDagExecutor;
use crate::dag::autodiff::{backward_plan, BackwardPlan};
use crate::dag::{Graph, NodeId, OpCategory, PassManager};
use crate::decompose::Decomposition;
use crate::exec::{Engine, Optimizer};
use crate::net::NetworkSim;
use crate::tensor::Tensor;
use crate::util::Rng;

/// Per-step report.
#[derive(Debug, Clone)]
pub struct StepReport {
    pub loss: Option<f32>,
    /// Modelled communication seconds this step (Σ over messages).
    pub comm_seconds: f64,
    /// Bytes crossing compnode boundaries this step.
    pub comm_bytes: u64,
    /// Parametric ops updated.
    pub updated: usize,
    /// Largest per-compnode peak of resident activation+gradient bytes this
    /// step (liveness-driven freeing keeps this far below the sum of all
    /// activations; see `SubDagExecutor::set_liveness_freeing`).
    pub peak_resident_bytes: u64,
}

/// The simulated cluster.
pub struct SimCluster {
    pub graph: Arc<Graph>,
    pub decomp: Arc<Decomposition>,
    executors: Vec<Option<SubDagExecutor>>,
    /// Sub-graph execution order (topological over the sub-graph DAG).
    sub_order: Vec<usize>,
    plan: BackwardPlan,
    net: Arc<NetworkSim>,
    /// Supernode-side parameter checkpoints per sub-graph.
    checkpoints: HashMap<usize, HashMap<NodeId, Vec<Tensor>>>,
    engine_factory: Box<dyn Fn() -> Box<dyn Engine>>,
    opt_factory: Box<dyn Fn() -> Box<dyn Optimizer>>,
    rng: Rng,
}

impl SimCluster {
    pub fn new(
        mut graph: Graph,
        decomp: Decomposition,
        net: Arc<NetworkSim>,
        engine_factory: Box<dyn Fn() -> Box<dyn Engine>>,
        opt_factory: Box<dyn Fn() -> Box<dyn Optimizer>>,
        seed: u64,
    ) -> Result<SimCluster> {
        // Reject malformed graphs up front (stale shapes, broken reverse
        // adjacency, cycles) — id-stable, so the decomposition stays valid.
        PassManager::validation().run(&mut graph)?;
        let graph = Arc::new(graph);
        let decomp = Arc::new(decomp);
        let plan = backward_plan(&graph);
        let sub_order = subgraph_topo_order(&graph, &decomp)?;
        let mut rng = Rng::new(seed);
        let mut executors = Vec::new();
        for s in 0..decomp.num_subgraphs() {
            executors.push(Some(SubDagExecutor::new(
                graph.clone(),
                decomp.clone(),
                s,
                engine_factory(),
                &*opt_factory,
                &mut rng,
            )?));
        }
        let mut cluster = SimCluster {
            graph,
            decomp,
            executors,
            sub_order,
            plan,
            net,
            checkpoints: HashMap::new(),
            engine_factory,
            opt_factory,
            rng,
        };
        cluster.checkpoint_all();
        Ok(cluster)
    }

    fn exec(&mut self, s: usize) -> Result<&mut SubDagExecutor> {
        self.executors[s].as_mut().ok_or_else(|| anyhow!("compnode {s} is offline"))
    }

    /// Feed a placeholder by node name (routed to the owning compnode).
    pub fn feed(&mut self, name: &str, tensor: Tensor) -> Result<()> {
        let node = self
            .graph
            .by_name(name)
            .ok_or_else(|| anyhow!("no node '{name}'"))?
            .id;
        let owner = self.decomp.of_node[node];
        self.exec(owner)?.feed(node, tensor);
        Ok(())
    }

    /// Run one full FP (+BP +Update when the graph has a loss) cycle.
    pub fn train_step(&mut self) -> Result<StepReport> {
        let mut comm_seconds = 0.0;
        let mut comm_bytes = 0u64;

        // FP sweep in sub-graph topological order.
        for idx in 0..self.sub_order.len() {
            let s = self.sub_order[idx];
            let msgs = self.exec(s)?.run_fp()?;
            for m in msgs {
                comm_bytes += m.tensor.bytes();
                comm_seconds += self.net.delay(s, m.to_sub, m.tensor.bytes());
                self.exec(m.to_sub)?.feed(m.node, m.tensor);
            }
        }

        // Read the loss (if any).
        let loss = self.graph.loss_nodes().first().and_then(|&l| {
            let owner = self.decomp.of_node[l];
            self.executors[owner].as_ref().and_then(|e| e.activation(l)).map(Tensor::item)
        });

        let mut updated = 0;
        if !self.plan.is_empty() {
            // BP sweep in reverse order.
            for idx in (0..self.sub_order.len()).rev() {
                let s = self.sub_order[idx];
                let msgs = {
                    let plan = self.plan.clone();
                    self.exec(s)?.run_bp(&plan)?
                };
                for m in msgs {
                    comm_bytes += m.tensor.bytes();
                    comm_seconds += self.net.delay(s, m.to_sub, m.tensor.bytes());
                    self.exec(m.to_sub)?.receive_grad(m.node, m.tensor);
                }
            }
            // Update everywhere, then checkpoint to the supernode.
            for s in 0..self.executors.len() {
                if let Some(e) = self.executors[s].as_mut() {
                    updated += e.run_update();
                }
            }
            self.checkpoint_all();
        }

        // Peaks survive end_batch; reset them so each report is per-step.
        let peak_resident_bytes = self.peak_resident_bytes();
        for e in self.executors.iter_mut().flatten() {
            e.end_batch();
            e.reset_peak_resident();
        }
        Ok(StepReport { loss, comm_seconds, comm_bytes, updated, peak_resident_bytes })
    }

    /// Inference: FP only; returns the activation of `output_name`.
    pub fn infer(&mut self, output_name: &str) -> Result<Tensor> {
        for idx in 0..self.sub_order.len() {
            let s = self.sub_order[idx];
            let msgs = self.exec(s)?.run_fp()?;
            for m in msgs {
                self.net.delay(s, m.to_sub, m.tensor.bytes());
                self.exec(m.to_sub)?.feed(m.node, m.tensor);
            }
        }
        let node = self
            .graph
            .by_name(output_name)
            .ok_or_else(|| anyhow!("no node '{output_name}'"))?
            .id;
        let owner = self.decomp.of_node[node];
        let out = self.executors[owner]
            .as_ref()
            .and_then(|e| e.activation(node))
            .cloned()
            .ok_or_else(|| anyhow!("output '{output_name}' not computed"))?;
        for e in self.executors.iter_mut().flatten() {
            e.end_batch();
        }
        Ok(out)
    }

    /// Sync every compnode's parameters to the supernode checkpoint store.
    fn checkpoint_all(&mut self) {
        for (s, e) in self.executors.iter().enumerate() {
            if let Some(e) = e {
                self.checkpoints.insert(s, e.checkpoint());
            }
        }
    }

    /// Kill compnode `s` (crash: all its state is lost).
    pub fn fail_compnode(&mut self, s: usize) {
        self.executors[s] = None;
    }

    pub fn is_alive(&self, s: usize) -> bool {
        self.executors[s].is_some()
    }

    /// Recover compnode `s` on a replacement device: rebuild the sub-DAG
    /// executor and restore parameters from the supernode checkpoint
    /// (paper §3.2's backup-pool takeover, §3.5's parameter sync).
    pub fn recover_compnode(&mut self, s: usize) -> Result<()> {
        let mut exec = SubDagExecutor::new(
            self.graph.clone(),
            self.decomp.clone(),
            s,
            (self.engine_factory)(),
            &*self.opt_factory,
            &mut self.rng,
        )?;
        if let Some(ckpt) = self.checkpoints.get(&s) {
            exec.restore(ckpt.clone());
        }
        self.executors[s] = Some(exec);
        Ok(())
    }

    pub fn network(&self) -> &NetworkSim {
        &self.net
    }

    /// Largest per-compnode peak of resident activation+gradient bytes
    /// since the peaks were last reset (i.e. this step).
    pub fn peak_resident_bytes(&self) -> u64 {
        self.executors
            .iter()
            .flatten()
            .map(|e| e.peak_resident_bytes())
            .max()
            .unwrap_or(0)
    }

    /// Toggle liveness-driven activation freeing on every live compnode
    /// (off = keep-everything baseline for memory comparisons).
    pub fn set_liveness_freeing(&mut self, on: bool) {
        for e in self.executors.iter_mut().flatten() {
            e.set_liveness_freeing(on);
        }
    }

    /// Export execution gauges (per-compnode and cluster-wide peak resident
    /// bytes) into a metrics registry.
    pub fn observe_metrics(&self, m: &crate::metrics::Metrics) {
        for e in self.executors.iter().flatten() {
            m.set_max_gauge(
                &format!("compnode.{}.peak_resident_bytes", e.sub_id),
                e.peak_resident_bytes() as f64,
            );
        }
        m.set_max_gauge("cluster.peak_resident_bytes", self.peak_resident_bytes() as f64);
    }
}

/// Topological order over sub-graphs induced by cut edges.
fn subgraph_topo_order(g: &Graph, d: &Decomposition) -> Result<Vec<usize>> {
    let k = d.num_subgraphs();
    let mut edges: Vec<(usize, usize)> = d
        .cut_edges(g)
        .into_iter()
        .map(|(a, b)| (d.of_node[a], d.of_node[b]))
        .collect();
    edges.sort();
    edges.dedup();
    let mut indeg = vec![0usize; k];
    for &(_, b) in &edges {
        indeg[b] += 1;
    }
    let mut queue: Vec<usize> = (0..k).filter(|&s| indeg[s] == 0).collect();
    let mut order = Vec::with_capacity(k);
    let mut head = 0;
    while head < queue.len() {
        let u = queue[head];
        head += 1;
        order.push(u);
        for &(a, b) in &edges {
            if a == u {
                indeg[b] -= 1;
                if indeg[b] == 0 {
                    queue.push(b);
                }
            }
        }
    }
    if order.len() != k {
        return Err(anyhow!("sub-graph dependency graph is cyclic; use a contiguous partition"));
    }
    Ok(order)
}

/// Convenience: placeholders of the graph that the caller must feed.
pub fn required_feeds(g: &Graph) -> Vec<String> {
    g.nodes
        .iter()
        .filter(|n| n.kind.category() == OpCategory::Placeholder)
        .map(|n| n.name.clone())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::{Adam, RefEngine};
    use crate::models::fig3;
    use crate::net::Topology;
    use crate::perf::comm::LinkModel;

    fn fig3_cluster(link: LinkModel) -> SimCluster {
        let g = fig3::build();
        let d = Decomposition::from_assignment(&g, &fig3::paper_partition(&g));
        let net = Arc::new(NetworkSim::new(Topology::uniform(link), 0.0));
        SimCluster::new(
            g,
            d,
            net,
            Box::new(|| Box::new(RefEngine::new())),
            Box::new(|| Box::new(Adam::new(0.02))),
            7,
        )
        .unwrap()
    }

    fn feed_fig3(c: &mut SimCluster, seed: u64) {
        let mut rng = Rng::new(seed);
        let input = Tensor::randn(&[fig3::BATCH, fig3::CH, fig3::HW, fig3::HW], 1.0, &mut rng);
        let n_lab = fig3::BATCH * 2 * fig3::CH * fig3::HW;
        let labels = Tensor::from_ivec(
            &[fig3::BATCH, 2 * fig3::CH, fig3::HW],
            (0..n_lab).map(|i| (i % fig3::CLASSES) as i32).collect(),
        );
        c.feed("Input", input).unwrap();
        c.feed("Label", labels).unwrap();
    }

    #[test]
    fn step_reports_loss_and_comm() {
        let mut c = fig3_cluster(LinkModel::from_ms_mbps(10.0, 100.0));
        feed_fig3(&mut c, 1);
        let r = c.train_step().unwrap();
        assert!(r.loss.unwrap() > 0.0);
        // FP: 3 messages; BP: 3 gradient messages (paper Fig. 3 black lines,
        // both directions).
        assert!(r.comm_bytes > 0);
        assert!(r.comm_seconds > 0.05, "6 messages × ≥10 ms latency");
        assert_eq!(r.updated, 3);
    }

    #[test]
    fn training_converges() {
        let mut c = fig3_cluster(LinkModel::local());
        let mut first = None;
        let mut last = 0.0;
        for _ in 0..30 {
            feed_fig3(&mut c, 7);
            let r = c.train_step().unwrap();
            let l = r.loss.unwrap();
            first.get_or_insert(l);
            last = l;
        }
        assert!(last < first.unwrap() * 0.8, "{first:?} → {last}");
    }

    #[test]
    fn churn_recovery_resumes_from_checkpoint() {
        let mut c = fig3_cluster(LinkModel::local());
        for _ in 0..5 {
            feed_fig3(&mut c, 7);
            c.train_step().unwrap();
        }
        // Crash compnode 1 (owns Tensor A + Multiply).
        c.fail_compnode(1);
        assert!(!c.is_alive(1));
        feed_fig3(&mut c, 7);
        assert!(c.train_step().is_err(), "offline compnode must break the step");
        // Recover and continue; loss should be near the pre-crash level,
        // not the fresh-init level.
        c.recover_compnode(1).unwrap();
        // clean leftover state from failed step
        for e in c.executors.iter_mut().flatten() {
            e.end_batch();
        }
        feed_fig3(&mut c, 7);
        let after = c.train_step().unwrap().loss.unwrap();
        // Fresh cluster baseline at same step count without crash:
        let mut fresh = fig3_cluster(LinkModel::local());
        feed_fig3(&mut fresh, 7);
        let init_loss = fresh.train_step().unwrap().loss.unwrap();
        assert!(after < init_loss, "recovered loss {after} vs fresh {init_loss}");
    }

    #[test]
    fn step_report_tracks_peak_resident_and_freeing_beats_baseline() {
        let mut freeing = fig3_cluster(LinkModel::local());
        feed_fig3(&mut freeing, 5);
        let r1 = freeing.train_step().unwrap();
        assert!(r1.peak_resident_bytes > 0);

        let mut baseline = fig3_cluster(LinkModel::local());
        baseline.set_liveness_freeing(false);
        feed_fig3(&mut baseline, 5);
        let r2 = baseline.train_step().unwrap();
        assert!(
            r1.peak_resident_bytes < r2.peak_resident_bytes,
            "freeing {} must undercut keep-everything {}",
            r1.peak_resident_bytes,
            r2.peak_resident_bytes
        );
        // Identical numerics either way.
        assert_eq!(r1.loss.unwrap().to_bits(), r2.loss.unwrap().to_bits());

        // Gauges export as high-water marks.
        let m = crate::metrics::Metrics::new();
        baseline.observe_metrics(&m);
        assert!(m.gauge("cluster.peak_resident_bytes").is_some());
    }

    #[test]
    fn infer_runs_fp_only() {
        let mut c = fig3_cluster(LinkModel::local());
        feed_fig3(&mut c, 2);
        let out = c.infer("Linear").unwrap();
        assert_eq!(out.shape(), &[fig3::BATCH, 2 * fig3::CH, fig3::HW, fig3::CLASSES]);
    }

    #[test]
    fn required_feeds_lists_placeholders() {
        let g = fig3::build();
        assert_eq!(required_feeds(&g), vec!["Input".to_string(), "Label".to_string()]);
    }
}
