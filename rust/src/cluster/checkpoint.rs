//! Binary checkpointing of stage parameters (paper §3.5: parameters are
//! "synchronized with the supernode in case of compnode failures"; here
//! also the bridge from *training* to *deploying* — `serve` loads what
//! `train` saved).
//!
//! Two formats, both little-endian:
//!
//! **v1** (`FAICKPT1`) — parameters only, f32-only; what `serve` consumes:
//! ```text
//!   magic "FAICKPT1" | u32 n_stages |
//!   per stage: u32 name_len | name bytes | u32 n_tensors |
//!     per tensor: u32 rank | u64 dims[rank] | f32 data[numel]
//! ```
//!
//! **v2** (`FAICKPT2`) — the recovery format: a global step counter plus
//! per-stage parameters *and* Adam moments, with a dtype tag per tensor so
//! resume is exact (the supervisor replays from the step the checkpoint
//! carries and the optimizer trajectory is bitwise-identical):
//! ```text
//!   magic "FAICKPT2" | u64 step | u32 n_stages |
//!   per stage: u32 name_len | name bytes |
//!     3 groups (params, m, v), each: u32 n_tensors | tensors
//!   tensor: u8 dtype (0 = f32, 1 = i32) | u32 rank | u64 dims[rank] | data
//! ```
//!
//! All reads use checked arithmetic bounded by the remaining bytes, so a
//! truncated or corrupt file yields an error, never a panic or an
//! overflow-sized allocation.

use std::collections::BTreeMap;
use std::io::Read;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::tensor::Tensor;

const MAGIC_V1: &[u8; 8] = b"FAICKPT1";
const MAGIC_V2: &[u8; 8] = b"FAICKPT2";

/// Dimensions beyond this are corrupt, not big.
const MAX_RANK: usize = 8;

/// Parameters of every stage, keyed by stage name (the v1 payload).
pub type Checkpoint = BTreeMap<String, Vec<Tensor>>;

/// Full training state of one stage: parameters plus Adam moments.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct StageSnapshot {
    pub params: Vec<Tensor>,
    pub opt_m: Vec<Tensor>,
    pub opt_v: Vec<Tensor>,
}

impl StageSnapshot {
    /// Snapshot with zeroed optimizer moments (fresh training state).
    pub fn fresh(params: Vec<Tensor>) -> StageSnapshot {
        let opt_m = params.iter().map(|p| Tensor::zeros(p.shape())).collect();
        let opt_v = params.iter().map(|p| Tensor::zeros(p.shape())).collect();
        StageSnapshot { params, opt_m, opt_v }
    }
}

/// A step-boundary recovery checkpoint: every stage's training state as of
/// the end of step `step` (i.e. resume by running steps `step..`).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct CheckpointV2 {
    pub step: u64,
    pub stages: BTreeMap<String, StageSnapshot>,
}

// ---------------------------------------------------------------------------
// Writers
// ---------------------------------------------------------------------------

fn put_tensor_v1(out: &mut Vec<u8>, t: &Tensor) {
    let dims = t.shape();
    out.extend_from_slice(&(dims.len() as u32).to_le_bytes());
    for &d in dims {
        out.extend_from_slice(&(d as u64).to_le_bytes());
    }
    for &v in t.f() {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

fn put_tensor_v2(out: &mut Vec<u8>, t: &Tensor) {
    out.push(if t.is_f32() { 0u8 } else { 1u8 });
    let dims = t.shape();
    out.extend_from_slice(&(dims.len() as u32).to_le_bytes());
    for &d in dims {
        out.extend_from_slice(&(d as u64).to_le_bytes());
    }
    if t.is_f32() {
        for &v in t.f() {
            out.extend_from_slice(&v.to_le_bytes());
        }
    } else {
        for &v in t.i() {
            out.extend_from_slice(&v.to_le_bytes());
        }
    }
}

/// Atomic publish: write to a temp file in the same directory, then
/// rename — concurrent readers never observe a torn checkpoint.
fn publish(path: &Path, bytes: Vec<u8>) -> Result<()> {
    let tmp = path.with_extension(format!("tmp.{}", std::process::id()));
    std::fs::write(&tmp, bytes).with_context(|| format!("writing {}", tmp.display()))?;
    std::fs::rename(&tmp, path).with_context(|| format!("publishing {}", path.display()))?;
    Ok(())
}

/// Serialize a v1 (parameters-only) checkpoint. The v1 format has no dtype
/// tag, so non-f32 tensors are rejected here instead of panicking inside
/// `Tensor::f()` mid-write.
pub fn save(path: &Path, ckpt: &Checkpoint) -> Result<()> {
    for (stage, tensors) in ckpt {
        if let Some(i) = tensors.iter().position(|t| !t.is_f32()) {
            bail!(
                "checkpoint v1 is f32-only: stage '{stage}' tensor {i} is i32 \
                 (use save_v2, which tags dtypes)"
            );
        }
    }
    let mut out = Vec::new();
    out.extend_from_slice(MAGIC_V1);
    out.extend_from_slice(&(ckpt.len() as u32).to_le_bytes());
    for (stage, tensors) in ckpt {
        out.extend_from_slice(&(stage.len() as u32).to_le_bytes());
        out.extend_from_slice(stage.as_bytes());
        out.extend_from_slice(&(tensors.len() as u32).to_le_bytes());
        for t in tensors {
            put_tensor_v1(&mut out, t);
        }
    }
    publish(path, out)
}

/// Serialize a v2 recovery checkpoint.
pub fn save_v2(path: &Path, ckpt: &CheckpointV2) -> Result<()> {
    let mut out = Vec::new();
    out.extend_from_slice(MAGIC_V2);
    out.extend_from_slice(&ckpt.step.to_le_bytes());
    out.extend_from_slice(&(ckpt.stages.len() as u32).to_le_bytes());
    for (stage, snap) in &ckpt.stages {
        out.extend_from_slice(&(stage.len() as u32).to_le_bytes());
        out.extend_from_slice(stage.as_bytes());
        for group in [&snap.params, &snap.opt_m, &snap.opt_v] {
            out.extend_from_slice(&(group.len() as u32).to_le_bytes());
            for t in group {
                put_tensor_v2(&mut out, t);
            }
        }
    }
    publish(path, out)
}

/// Path of the previous-generation checkpoint kept by
/// [`save_v2_rotating`].
pub fn prev_path(path: &Path) -> PathBuf {
    PathBuf::from(format!("{}.prev", path.display()))
}

/// Save a v2 checkpoint, first rotating any existing file to `<path>.prev`
/// so a torn/corrupted write of the newest generation still leaves a
/// loadable fallback.
pub fn save_v2_rotating(path: &Path, ckpt: &CheckpointV2) -> Result<()> {
    if path.exists() {
        std::fs::rename(path, prev_path(path))
            .with_context(|| format!("rotating {}", path.display()))?;
    }
    save_v2(path, ckpt)
}

// ---------------------------------------------------------------------------
// Readers
// ---------------------------------------------------------------------------

/// Load a checkpoint's parameters, auto-detecting the format: v1 files load
/// directly, v2 files are reduced to their parameter groups (what `serve`
/// needs; use [`load_v2`] for full recovery state).
pub fn load(path: &Path) -> Result<Checkpoint> {
    let buf = read_file(path)?;
    match magic_of(&buf)? {
        2 => {
            let v2 = parse_v2(&buf)?;
            Ok(v2.stages.into_iter().map(|(k, s)| (k, s.params)).collect())
        }
        _ => parse_v1(&buf),
    }
}

/// Load a v2 recovery checkpoint (errors on v1 files: they carry no
/// optimizer state or step counter, so exact resume is impossible).
pub fn load_v2(path: &Path) -> Result<CheckpointV2> {
    let buf = read_file(path)?;
    if magic_of(&buf)? != 2 {
        bail!("{} is not a v2 recovery checkpoint", path.display());
    }
    parse_v2(&buf)
}

/// Try the newest checkpoint generation, then the `.prev` rotation.
/// Returns the loaded checkpoint (if any) and how many *existing* candidate
/// files failed to parse (surfaced as a metric by the trainer).
pub fn load_latest_v2(path: &Path) -> (Option<CheckpointV2>, u64) {
    let mut failures = 0;
    for candidate in [path.to_path_buf(), prev_path(path)] {
        if !candidate.exists() {
            continue;
        }
        match load_v2(&candidate) {
            Ok(ckpt) => return (Some(ckpt), failures),
            Err(e) => {
                log::warn!("unreadable checkpoint {}: {e:#}", candidate.display());
                failures += 1;
            }
        }
    }
    (None, failures)
}

fn read_file(path: &Path) -> Result<Vec<u8>> {
    let mut f = std::fs::File::open(path)
        .with_context(|| format!("opening checkpoint {}", path.display()))?;
    let mut buf = Vec::new();
    f.read_to_end(&mut buf)?;
    Ok(buf)
}

fn magic_of(buf: &[u8]) -> Result<u8> {
    if buf.len() < 8 {
        bail!("checkpoint shorter than its magic");
    }
    match &buf[..8] {
        m if m == MAGIC_V1 => Ok(1),
        m if m == MAGIC_V2 => Ok(2),
        _ => bail!("bad checkpoint magic"),
    }
}

fn parse_v1(buf: &[u8]) -> Result<Checkpoint> {
    let mut r = Reader { b: buf, i: 8 };
    let n_stages = r.u32()? as usize;
    let mut ckpt = Checkpoint::new();
    for _ in 0..n_stages {
        let name = r.name()?;
        let n_tensors = r.u32()? as usize;
        let mut tensors = Vec::new();
        for _ in 0..n_tensors {
            tensors.push(r.tensor(false)?);
        }
        ckpt.insert(name, tensors);
    }
    Ok(ckpt)
}

fn parse_v2(buf: &[u8]) -> Result<CheckpointV2> {
    let mut r = Reader { b: buf, i: 8 };
    let step = r.u64()?;
    let n_stages = r.u32()? as usize;
    let mut stages = BTreeMap::new();
    for _ in 0..n_stages {
        let name = r.name()?;
        let mut groups: [Vec<Tensor>; 3] = [Vec::new(), Vec::new(), Vec::new()];
        for group in groups.iter_mut() {
            let n = r.u32()? as usize;
            for _ in 0..n {
                group.push(r.tensor(true)?);
            }
        }
        let [params, opt_m, opt_v] = groups;
        stages.insert(name, StageSnapshot { params, opt_m, opt_v });
    }
    Ok(CheckpointV2 { step, stages })
}

struct Reader<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Reader<'a> {
    fn remaining(&self) -> usize {
        self.b.len().saturating_sub(self.i)
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if n > self.remaining() {
            bail!("truncated checkpoint (need {n} bytes at {})", self.i);
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn name(&mut self) -> Result<String> {
        let len = self.u32()? as usize;
        String::from_utf8(self.take(len)?.to_vec()).map_err(|e| anyhow!("bad stage name: {e}"))
    }

    /// One tensor record. Dims come from an untrusted file, so the element
    /// count is computed with checked arithmetic and bounded by the bytes
    /// actually remaining before any allocation happens.
    fn tensor(&mut self, tagged: bool) -> Result<Tensor> {
        let dtype = if tagged { self.u8()? } else { 0 };
        if dtype > 1 {
            bail!("unknown tensor dtype tag {dtype}");
        }
        let rank = self.u32()? as usize;
        if rank > MAX_RANK {
            bail!("corrupt tensor rank {rank} (max {MAX_RANK})");
        }
        let mut dims = Vec::with_capacity(rank);
        for _ in 0..rank {
            dims.push(self.u64()? as usize);
        }
        let numel = dims
            .iter()
            .try_fold(1usize, |acc, &d| acc.checked_mul(d))
            .ok_or_else(|| anyhow!("corrupt tensor dims {dims:?}: element count overflows"))?;
        let nbytes = numel
            .checked_mul(4)
            .filter(|&b| b <= self.remaining())
            .ok_or_else(|| {
                anyhow!(
                    "corrupt tensor dims {dims:?}: {numel} elements exceed the {} bytes left",
                    self.remaining()
                )
            })?;
        let bytes = self.take(nbytes)?;
        if dtype == 0 {
            let data: Vec<f32> = bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            Ok(Tensor::from_vec(&dims, data))
        } else {
            let data: Vec<i32> = bytes
                .chunks_exact(4)
                .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            Ok(Tensor::from_ivec(&dims, data))
        }
    }
}

/// Write a checkpoint atomically next to the artifact dir convention:
/// `<artifacts>/<preset>/checkpoint.bin`.
pub fn default_path(artifacts_dir: &Path) -> PathBuf {
    artifacts_dir.join("checkpoint.bin")
}

/// The recovery (v2) checkpoint path convention.
pub fn recovery_path(artifacts_dir: &Path) -> PathBuf {
    artifacts_dir.join("recovery.ckpt")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn tmpdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("fa_ckpt_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn roundtrip() {
        let mut rng = Rng::new(4);
        let mut ckpt = Checkpoint::new();
        ckpt.insert(
            "embed".into(),
            vec![Tensor::randn(&[16, 8], 1.0, &mut rng), Tensor::randn(&[4, 8], 1.0, &mut rng)],
        );
        ckpt.insert("head".into(), vec![Tensor::scalar(3.5)]);
        let path = tmpdir("v1").join("c.bin");
        save(&path, &ckpt).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back["embed"][0], ckpt["embed"][0]);
        assert_eq!(back["head"][0].item(), 3.5);
    }

    #[test]
    fn v1_rejects_i32_tensors() {
        let mut ckpt = Checkpoint::new();
        ckpt.insert("embed".into(), vec![Tensor::from_ivec(&[2], vec![1, 2])]);
        let path = tmpdir("v1i32").join("c.bin");
        let err = save(&path, &ckpt).unwrap_err().to_string();
        assert!(err.contains("f32-only"), "got: {err}");
        assert!(!path.exists(), "rejected save must not leave a file");
    }

    #[test]
    fn corrupt_rejected() {
        let dir = tmpdir("bad");
        let path = dir.join("bad.bin");
        std::fs::write(&path, b"NOTACKPT").unwrap();
        assert!(load(&path).is_err());
        std::fs::write(&path, &b"FAICKPT1\x01\x00\x00\x00"[..]).unwrap();
        assert!(load(&path).is_err(), "truncated body must error");
        std::fs::write(&path, b"FAI").unwrap();
        assert!(load(&path).is_err(), "shorter than magic must error");
    }

    #[test]
    fn hostile_dims_cannot_overflow() {
        // v1 record claiming a tensor of 2^62 × 2^62 elements: the checked
        // product must reject it instead of wrapping into a small alloc.
        let mut buf = Vec::new();
        buf.extend_from_slice(b"FAICKPT1");
        buf.extend_from_slice(&1u32.to_le_bytes()); // one stage
        buf.extend_from_slice(&1u32.to_le_bytes()); // name len
        buf.push(b'x');
        buf.extend_from_slice(&1u32.to_le_bytes()); // one tensor
        buf.extend_from_slice(&2u32.to_le_bytes()); // rank 2
        buf.extend_from_slice(&(1u64 << 62).to_le_bytes());
        buf.extend_from_slice(&(1u64 << 62).to_le_bytes());
        let path = tmpdir("hostile").join("h.bin");
        std::fs::write(&path, &buf).unwrap();
        let err = load(&path).unwrap_err().to_string();
        assert!(err.contains("overflow") || err.contains("exceed"), "got: {err}");
        // Absurd rank is rejected before reading 10^9 dim words.
        let mut buf2 = Vec::new();
        buf2.extend_from_slice(b"FAICKPT1");
        buf2.extend_from_slice(&1u32.to_le_bytes());
        buf2.extend_from_slice(&1u32.to_le_bytes());
        buf2.push(b'x');
        buf2.extend_from_slice(&1u32.to_le_bytes());
        buf2.extend_from_slice(&u32::MAX.to_le_bytes()); // rank 2^32-1
        std::fs::write(&path, &buf2).unwrap();
        assert!(load(&path).unwrap_err().to_string().contains("rank"));
    }

    fn snap(rng: &mut Rng) -> StageSnapshot {
        let params =
            vec![Tensor::randn(&[4, 3], 1.0, rng), Tensor::from_ivec(&[2], vec![7, -9])];
        let opt_m = vec![Tensor::randn(&[4, 3], 0.1, rng), Tensor::zeros(&[2])];
        let opt_v = vec![Tensor::randn(&[4, 3], 0.1, rng), Tensor::zeros(&[2])];
        StageSnapshot { params, opt_m, opt_v }
    }

    #[test]
    fn v2_roundtrip_with_step_and_moments() {
        let mut rng = Rng::new(11);
        let mut ckpt = CheckpointV2 { step: 42, stages: BTreeMap::new() };
        ckpt.stages.insert("embed".into(), snap(&mut rng));
        ckpt.stages.insert("head".into(), snap(&mut rng));
        let path = tmpdir("v2").join("r.ckpt");
        save_v2(&path, &ckpt).unwrap();
        let back = load_v2(&path).unwrap();
        assert_eq!(back, ckpt);
        // load() reduces v2 to its parameter groups (the serve bridge).
        let params_only = load(&path).unwrap();
        assert_eq!(params_only["embed"], ckpt.stages["embed"].params);
        // i32 tensors survive the tagged format.
        assert_eq!(back.stages["head"].params[1].i(), &[7, -9]);
    }

    #[test]
    fn v2_rotation_keeps_previous_generation() {
        let mut rng = Rng::new(12);
        let path = tmpdir("rot").join("r.ckpt");
        let mut gen1 = CheckpointV2 { step: 10, stages: BTreeMap::new() };
        gen1.stages.insert("s".into(), snap(&mut rng));
        save_v2_rotating(&path, &gen1).unwrap();
        let mut gen2 = CheckpointV2 { step: 20, stages: BTreeMap::new() };
        gen2.stages.insert("s".into(), snap(&mut rng));
        save_v2_rotating(&path, &gen2).unwrap();
        assert_eq!(load_v2(&path).unwrap().step, 20);
        assert_eq!(load_v2(&prev_path(&path)).unwrap().step, 10);
        // Corrupt the newest generation: load_latest falls back to prev.
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
        let (latest, failures) = load_latest_v2(&path);
        assert_eq!(latest.unwrap().step, 10);
        assert_eq!(failures, 1);
    }

    #[test]
    fn load_latest_on_missing_files_is_none() {
        let path = tmpdir("missing").join("nope.ckpt");
        let (latest, failures) = load_latest_v2(&path);
        assert!(latest.is_none());
        assert_eq!(failures, 0);
    }

    #[test]
    fn truncation_fuzz_never_panics() {
        // Every prefix of a valid v2 file must load-or-error, never panic.
        let mut rng = Rng::new(13);
        let mut ckpt = CheckpointV2 { step: 7, stages: BTreeMap::new() };
        ckpt.stages.insert("embed".into(), snap(&mut rng));
        let path = tmpdir("fuzz").join("f.ckpt");
        save_v2(&path, &ckpt).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        let cut = path.with_extension("cut");
        for len in (0..bytes.len()).step_by(7).chain([bytes.len() - 1]) {
            std::fs::write(&cut, &bytes[..len]).unwrap();
            assert!(load_v2(&cut).is_err(), "prefix of {len} bytes must error");
        }
        // Flipped-byte corruption in headers errors or round-trips, never
        // panics (flips in the f32 payload simply change values).
        for pos in 8..bytes.len().min(64) {
            let mut b = bytes.clone();
            b[pos] ^= 0xFF;
            std::fs::write(&cut, &b).unwrap();
            let _ = load_v2(&cut);
        }
    }
}
