//! Binary checkpointing of stage parameters (paper §3.5: parameters are
//! "synchronized with the supernode in case of compnode failures"; here
//! also the bridge from *training* to *deploying* — `serve` loads what
//! `train` saved).
//!
//! Format (little-endian, versioned):
//! ```text
//!   magic "FAICKPT1" | u32 n_stages |
//!   per stage: u32 name_len | name bytes | u32 n_tensors |
//!     per tensor: u32 rank | u64 dims[rank] | f32 data[numel]
//! ```

use std::collections::BTreeMap;
use std::io::Read;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::tensor::Tensor;

const MAGIC: &[u8; 8] = b"FAICKPT1";

/// Parameters of every stage, keyed by stage name.
pub type Checkpoint = BTreeMap<String, Vec<Tensor>>;

/// Serialize a checkpoint to a file.
pub fn save(path: &Path, ckpt: &Checkpoint) -> Result<()> {
    let mut out = Vec::new();
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&(ckpt.len() as u32).to_le_bytes());
    for (stage, tensors) in ckpt {
        out.extend_from_slice(&(stage.len() as u32).to_le_bytes());
        out.extend_from_slice(stage.as_bytes());
        out.extend_from_slice(&(tensors.len() as u32).to_le_bytes());
        for t in tensors {
            let dims = t.shape();
            out.extend_from_slice(&(dims.len() as u32).to_le_bytes());
            for &d in dims {
                out.extend_from_slice(&(d as u64).to_le_bytes());
            }
            for &v in t.f() {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
    }
    // Atomic publish: write to a temp file in the same directory, then
    // rename — concurrent readers never observe a torn checkpoint.
    let tmp = path.with_extension(format!("tmp.{}", std::process::id()));
    std::fs::write(&tmp, out).with_context(|| format!("writing {}", tmp.display()))?;
    std::fs::rename(&tmp, path).with_context(|| format!("publishing {}", path.display()))?;
    Ok(())
}

/// Load a checkpoint from a file.
pub fn load(path: &Path) -> Result<Checkpoint> {
    let mut f = std::fs::File::open(path)
        .with_context(|| format!("opening checkpoint {}", path.display()))?;
    let mut buf = Vec::new();
    f.read_to_end(&mut buf)?;
    let mut r = Reader { b: &buf, i: 0 };
    let magic = r.take(8)?;
    if magic != MAGIC {
        bail!("bad checkpoint magic");
    }
    let n_stages = r.u32()? as usize;
    let mut ckpt = Checkpoint::new();
    for _ in 0..n_stages {
        let name_len = r.u32()? as usize;
        let name = String::from_utf8(r.take(name_len)?.to_vec())
            .map_err(|e| anyhow!("bad stage name: {e}"))?;
        let n_tensors = r.u32()? as usize;
        let mut tensors = Vec::with_capacity(n_tensors);
        for _ in 0..n_tensors {
            let rank = r.u32()? as usize;
            let mut dims = Vec::with_capacity(rank);
            for _ in 0..rank {
                dims.push(r.u64()? as usize);
            }
            let numel: usize = dims.iter().product();
            let bytes = r.take(4 * numel)?;
            let data: Vec<f32> = bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            tensors.push(Tensor::from_vec(&dims, data));
        }
        ckpt.insert(name, tensors);
    }
    Ok(ckpt)
}

struct Reader<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.i + n > self.b.len() {
            bail!("truncated checkpoint (need {n} bytes at {})", self.i);
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }
    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
}

/// Write a checkpoint atomically next to the artifact dir convention:
/// `<artifacts>/<preset>/checkpoint.bin`.
pub fn default_path(artifacts_dir: &Path) -> std::path::PathBuf {
    artifacts_dir.join("checkpoint.bin")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn roundtrip() {
        let mut rng = Rng::new(4);
        let mut ckpt = Checkpoint::new();
        ckpt.insert(
            "embed".into(),
            vec![Tensor::randn(&[16, 8], 1.0, &mut rng), Tensor::randn(&[4, 8], 1.0, &mut rng)],
        );
        ckpt.insert("head".into(), vec![Tensor::scalar(3.5)]);
        let dir = std::env::temp_dir().join(format!("fa_ckpt_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("c.bin");
        save(&path, &ckpt).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back["embed"][0], ckpt["embed"][0]);
        assert_eq!(back["head"][0].item(), 3.5);
    }

    #[test]
    fn corrupt_rejected() {
        let dir = std::env::temp_dir().join(format!("fa_ckpt_bad_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.bin");
        std::fs::write(&path, b"NOTACKPT").unwrap();
        assert!(load(&path).is_err());
        std::fs::write(&path, &b"FAICKPT1\x01\x00\x00\x00"[..]).unwrap();
        assert!(load(&path).is_err(), "truncated body must error");
    }
}
