//! The live pipeline trainer: decentralized GPipe training over XLA/PJRT
//! artifacts (the end-to-end production path).
//!
//! One OS thread per pipeline-stage compnode, each with a **private PJRT
//! runtime** (PJRT objects are not `Send`) holding only its stage's
//! artifacts and parameters — exactly the paper's picture of a sub-DAG per
//! compnode. Activations and gradients move over channels whose payloads
//! pay α-β WAN delays on the [`NetworkSim`] clock and can be compressed
//! with a [`Codec`] (§2.3). Tokens and labels come from the DHT data
//! provider (§3.9). Backward rematerializes forward inside the artifact,
//! so only stage *inputs* are stashed per microbatch (§2.4).

use std::path::PathBuf;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use crate::compress::Codec;
use crate::cluster::data::{fetch_tokens, DataProvider, SyntheticCorpus};
use crate::dht::Dht;
use crate::exec::xla_engine::XlaEngine;
use crate::metrics::LossCurve;
use crate::net::{NetworkSim, Topology};
use crate::perf::comm::LinkModel;
use crate::runtime::Manifest;
use crate::tensor::Tensor;
use crate::util::Rng;

/// Trainer configuration.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Artifact directory (e.g. `artifacts/gpt-e2e`).
    pub artifacts_dir: PathBuf,
    pub steps: usize,
    pub microbatches: usize,
    /// Activation/gradient codec (None = raw f32).
    pub codec: Option<Codec>,
    /// Inter-compnode link model (for accounting and optional slowdown).
    pub link: LinkModel,
    /// Real-sleep multiplier on modelled delays (0 = account only).
    pub time_scale: f64,
    pub seed: u64,
    pub log_every: usize,
    /// Save final parameters to `<artifacts>/checkpoint.bin` (what `serve`
    /// loads).
    pub save_checkpoint: bool,
    /// Row-partition fan-out for the host GEMMs (1 = single-threaded).
    /// Results are bitwise-independent of this value.
    pub gemm_threads: usize,
}

impl TrainConfig {
    pub fn new(artifacts_dir: impl Into<PathBuf>) -> TrainConfig {
        TrainConfig {
            artifacts_dir: artifacts_dir.into(),
            steps: 50,
            microbatches: 2,
            codec: None,
            link: LinkModel::from_ms_mbps(5.0, 1000.0),
            time_scale: 0.0,
            seed: 42,
            log_every: 10,
            save_checkpoint: true,
            gemm_threads: 1,
        }
    }
}

/// What the trainer returns.
#[derive(Debug)]
pub struct TrainReport {
    pub losses: LossCurve,
    pub steps: usize,
    pub wall_seconds: f64,
    pub tokens_per_second: f64,
    /// Total bytes that crossed compnode boundaries.
    pub comm_bytes: u64,
    /// Modelled WAN seconds (virtual).
    pub comm_model_seconds: f64,
}

/// A tensor on the wire.
struct WireMsg {
    mb: usize,
    tensor: Tensor,
}

/// Send one activation/gradient hop: pays the WAN delay and (optionally)
/// round-trips the payload through the codec so the numeric effect of
/// compression is real, not just accounted.
fn send_hop(
    net: &NetworkSim,
    from: usize,
    to: usize,
    codec: Option<Codec>,
    tx: &Sender<WireMsg>,
    mb: usize,
    tensor: Tensor,
) -> Result<()> {
    let (payload, wire_bytes) = match codec {
        None => {
            let b = tensor.bytes();
            (tensor, b)
        }
        Some(c) => {
            let shape = tensor.shape().to_vec();
            let n = tensor.numel();
            let encoded = c.encode(tensor.f());
            let bytes = encoded.len() as u64;
            let decoded = Tensor::from_vec(&shape, c.decode(&encoded, n));
            (decoded, bytes)
        }
    };
    net.transfer(from, to, wire_bytes);
    tx.send(WireMsg { mb, tensor: payload }).map_err(|_| anyhow!("pipeline channel closed"))
}

/// The trainer.
pub struct PipelineTrainer {
    pub config: TrainConfig,
    pub manifest: Manifest,
}

impl PipelineTrainer {
    /// Load the manifest (cheap) and validate the configuration.
    pub fn new(config: TrainConfig) -> Result<PipelineTrainer> {
        let manifest = Manifest::load(&config.artifacts_dir.join("manifest.json"))
            .context("loading artifact manifest (run `make artifacts` first)")?;
        if manifest.stages.len() < 2 {
            return Err(anyhow!("need ≥2 stages, manifest has {}", manifest.stages.len()));
        }
        Ok(PipelineTrainer { config, manifest })
    }

    /// Run the full training loop. Spawns one thread per stage; blocks
    /// until all steps complete.
    pub fn run(&self) -> Result<TrainReport> {
        let cfg = &self.config;
        crate::tensor::set_gemm_threads(cfg.gemm_threads);
        let stages = self.manifest.stages.clone();
        let n_stages = stages.len();
        let batch = self.manifest.config_usize("batch").ok_or_else(|| anyhow!("manifest missing batch"))?;
        let seq = self.manifest.config_usize("seq").ok_or_else(|| anyhow!("manifest missing seq"))?;
        let vocab = self.manifest.config_usize("vocab").ok_or_else(|| anyhow!("manifest missing vocab"))?;

        // DHT with one storage peer per stage + provider replication 2.
        let mut dht = Dht::new(2);
        for p in 0..n_stages.max(2) {
            dht.join(p).unwrap();
        }
        let dht = Arc::new(Mutex::new(dht));
        let provider =
            DataProvider::new(SyntheticCorpus::new(vocab, seq, batch), dht.clone());
        for step in 0..cfg.steps {
            provider.publish_step(step, cfg.microbatches)?;
        }

        let net = Arc::new(NetworkSim::new(Topology::uniform(cfg.link), cfg.time_scale));

        // Channels, one slot per stage: stage i sends activations forward
        // on act_txs[i] (received by i+1 on act_rxs[i+1]) and gradients
        // backward on grad_txs[i] (received by i-1 on grad_rxs[i-1]). The
        // pipeline ends leave the unused slots None.
        let mut act_txs: Vec<Option<Sender<WireMsg>>> = (0..n_stages).map(|_| None).collect();
        let mut act_rxs: Vec<Option<Receiver<WireMsg>>> = (0..n_stages).map(|_| None).collect();
        let mut grad_txs: Vec<Option<Sender<WireMsg>>> = (0..n_stages).map(|_| None).collect();
        let mut grad_rxs: Vec<Option<Receiver<WireMsg>>> = (0..n_stages).map(|_| None).collect();
        for i in 0..n_stages - 1 {
            let (tx, rx) = channel::<WireMsg>();
            act_txs[i] = Some(tx);
            act_rxs[i + 1] = Some(rx);
            let (tx, rx) = channel::<WireMsg>();
            grad_txs[i + 1] = Some(tx);
            grad_rxs[i] = Some(rx);
        }

        let (loss_tx, loss_rx) = channel::<(usize, f32)>();
        let (ckpt_tx, ckpt_rx) = channel::<(String, Vec<Tensor>)>();

        let t0 = Instant::now();
        let mut handles = Vec::new();
        for (si, stage) in stages.iter().enumerate() {
            let stage = stage.clone();
            let dir = cfg.artifacts_dir.clone();
            let steps = cfg.steps;
            let microbatches = cfg.microbatches;
            let codec = cfg.codec;
            let net = net.clone();
            let dht = dht.clone();
            let seed = cfg.seed;
            let act_rx = act_rxs[si].take();
            let act_tx = act_txs[si].take();
            let grad_rx = grad_rxs[si].take();
            let grad_tx = grad_txs[si].take();
            let loss_tx = if si == n_stages - 1 { Some(loss_tx.clone()) } else { None };
            let ckpt_tx = ckpt_tx.clone();
            let is_first = si == 0;
            let is_last = si == n_stages - 1;
            handles.push(std::thread::spawn(move || -> Result<()> {
                let result = stage_worker(StageCtx {
                    stage,
                    stage_idx: si,
                    dir,
                    steps,
                    microbatches,
                    batch,
                    seq,
                    codec,
                    net,
                    dht,
                    seed,
                    act_rx,
                    act_tx,
                    grad_rx,
                    grad_tx,
                    loss_tx,
                    ckpt_tx: Some(ckpt_tx),
                    is_first,
                    is_last,
                });
                if let Err(e) = &result {
                    eprintln!("stage {si} worker failed: {e:#}");
                }
                result
            }));
        }
        drop(loss_tx);
        drop(ckpt_tx);

        // Collect per-step losses, logging progress every `log_every`.
        let mut losses = LossCurve::new();
        while let Ok((step, loss)) = loss_rx.recv() {
            if cfg.log_every > 0 && step % cfg.log_every == 0 {
                log::info!("step {step}: loss {loss:.4}");
                eprintln!("  [train] step {step:>5}  loss {loss:.4}");
            }
            losses.record(step, loss);
        }
        for h in handles {
            h.join().map_err(|_| anyhow!("stage thread panicked"))??;
        }
        if cfg.save_checkpoint {
            let mut ckpt = crate::cluster::checkpoint::Checkpoint::new();
            while let Ok((stage, params)) = ckpt_rx.try_recv() {
                ckpt.insert(stage, params);
            }
            if ckpt.len() == n_stages {
                let path = crate::cluster::checkpoint::default_path(&cfg.artifacts_dir);
                crate::cluster::checkpoint::save(&path, &ckpt)?;
                log::info!("checkpoint written to {}", path.display());
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        let tokens = (cfg.steps * cfg.microbatches * batch * seq) as f64;
        Ok(TrainReport {
            losses,
            steps: cfg.steps,
            wall_seconds: wall,
            tokens_per_second: tokens / wall,
            comm_bytes: net.total_remote_bytes(),
            comm_model_seconds: net.total_remote_seconds(),
        })
    }
}

struct StageCtx {
    stage: String,
    stage_idx: usize,
    dir: PathBuf,
    steps: usize,
    microbatches: usize,
    batch: usize,
    seq: usize,
    codec: Option<Codec>,
    net: Arc<NetworkSim>,
    dht: Arc<Mutex<Dht>>,
    seed: u64,
    act_rx: Option<Receiver<WireMsg>>,
    act_tx: Option<Sender<WireMsg>>,
    grad_rx: Option<Receiver<WireMsg>>,
    grad_tx: Option<Sender<WireMsg>>,
    loss_tx: Option<Sender<(usize, f32)>>,
    ckpt_tx: Option<Sender<(String, Vec<Tensor>)>>,
    is_first: bool,
    is_last: bool,
}

/// One compnode's whole life: load artifacts, init params, run the GPipe
/// schedule for every step.
fn stage_worker(ctx: StageCtx) -> Result<()> {
    let engine = XlaEngine::load_stage(&ctx.dir, &ctx.stage)
        .with_context(|| format!("loading stage '{}'", ctx.stage))?;
    let mut rng = Rng::new(ctx.seed ^ (ctx.stage_idx as u64) << 17);
    // Device-resident parameters/optimizer state: only activations,
    // gradients and the step counter cross the host boundary per call
    // (§Perf: this removed the dominant per-microbatch parameter copies).
    let mut state = engine.new_stage_state(&ctx.stage, &mut rng)?;

    let mb_count = ctx.microbatches;
    for step in 0..ctx.steps {
        // ---- forward phase: stash this stage's inputs per microbatch ----
        let mut stash: Vec<Option<Tensor>> = (0..mb_count).map(|_| None).collect();
        let mut grads_acc: Option<Vec<Tensor>> = None;
        let mut loss_sum = 0.0f32;

        if ctx.is_last {
            // Head: consume activations as they arrive; immediately run the
            // backward (which internally computes forward + loss).
            for _ in 0..mb_count {
                let msg = ctx.act_rx.as_ref().unwrap().recv().map_err(|_| anyhow!("upstream closed"))?;
                let labels =
                    fetch_tokens(&ctx.dht, step, msg.mb, "labels", &[ctx.batch, ctx.seq])?;
                let (dx, dparams, loss) =
                    engine.backward_cached(&state, &[&msg.tensor, &labels], None)?;
                loss_sum += loss.unwrap_or(f32::NAN);
                accumulate(&mut grads_acc, dparams);
                send_hop(
                    &ctx.net,
                    ctx.stage_idx,
                    ctx.stage_idx - 1,
                    ctx.codec,
                    ctx.grad_tx.as_ref().unwrap(),
                    msg.mb,
                    dx.unwrap(),
                )?;
                let _ = &stash; // head stashes nothing
            }
            if let Some(tx) = &ctx.loss_tx {
                let _ = tx.send((step, loss_sum / mb_count as f32));
            }
        } else {
            // Forward all microbatches.
            for mb in 0..mb_count {
                let input = if ctx.is_first {
                    fetch_tokens(&ctx.dht, step, mb, "tokens", &[ctx.batch, ctx.seq])?
                } else {
                    let WireMsg { mb, tensor } = ctx
                        .act_rx
                        .as_ref()
                        .unwrap()
                        .recv()
                        .map_err(|_| anyhow!("upstream closed"))?;
                    // use arrival mb index; stash by move once forwarded
                    let out = engine.forward_cached(&state, &[&tensor])?;
                    stash[mb] = Some(tensor);
                    send_hop(
                        &ctx.net,
                        ctx.stage_idx,
                        ctx.stage_idx + 1,
                        ctx.codec,
                        ctx.act_tx.as_ref().unwrap(),
                        mb,
                        out,
                    )?;
                    continue;
                };
                // first stage path
                let out = engine.forward_cached(&state, &[&input])?;
                stash[mb] = Some(input);
                send_hop(
                    &ctx.net,
                    ctx.stage_idx,
                    ctx.stage_idx + 1,
                    ctx.codec,
                    ctx.act_tx.as_ref().unwrap(),
                    mb,
                    out,
                )?;
            }
            // Backward: consume gradients in arrival order.
            for _ in 0..mb_count {
                let msg = ctx
                    .grad_rx
                    .as_ref()
                    .unwrap()
                    .recv()
                    .map_err(|_| anyhow!("downstream closed"))?;
                let input = stash[msg.mb]
                    .take()
                    .ok_or_else(|| anyhow!("no stashed input for microbatch {}", msg.mb))?;
                let (dx, dparams, _) =
                    engine.backward_cached(&state, &[&input], Some(&msg.tensor))?;
                accumulate(&mut grads_acc, dparams);
                if let (Some(tx), Some(dx)) = (&ctx.grad_tx, dx) {
                    send_hop(&ctx.net, ctx.stage_idx, ctx.stage_idx - 1, ctx.codec, tx, msg.mb, dx)?;
                }
            }
        }

        // ---- update phase ----
        let grads = grads_acc.ok_or_else(|| anyhow!("no gradients accumulated"))?;
        engine.update_cached(&mut state, &grads, step as i32 + 1)?;
    }
    // Ship the final host parameter copy back for checkpointing.
    if let Some(tx) = &ctx.ckpt_tx {
        let _ = tx.send((ctx.stage.clone(), state.params.clone()));
    }
    Ok(())
}

fn accumulate(acc: &mut Option<Vec<Tensor>>, grads: Vec<Tensor>) {
    match acc {
        None => *acc = Some(grads),
        Some(a) => {
            for (x, g) in a.iter_mut().zip(&grads) {
                x.axpy(1.0, g);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_defaults_sane() {
        let c = TrainConfig::new("artifacts/gpt-tiny");
        assert!(c.steps > 0 && c.microbatches > 0);
        assert!(c.codec.is_none());
    }

    // Full trainer runs are exercised in rust/tests/integration_runtime.rs
    // (they need `make artifacts`).
}
