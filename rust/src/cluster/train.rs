//! The live pipeline trainer: decentralized GPipe training under a
//! supervising coordinator (the end-to-end production path).
//!
//! One OS thread per pipeline-stage compnode, each owning a private
//! [`StageBackend`] (PJRT artifacts in production — PJRT objects are not
//! `Send` — or the deterministic host simulator in tests). Activations and
//! gradients move over channels whose payloads pay α-β WAN delays on the
//! [`NetworkSim`] clock and can be compressed with a [`Codec`] (§2.3).
//! Tokens and labels come from the DHT data provider (§3.9); the provider
//! publishes every step up front, so a replayed step refetches identical
//! data. Backward rematerializes forward inside the backend, so only stage
//! *inputs* are stashed per microbatch (§2.4).
//!
//! # Supervision & recovery (paper §3.2/§3.5)
//!
//! The coordinator owns every stage thread's lifecycle. Stage health flows
//! back on a single event channel — heartbeats piggybacked on the loss and
//! snapshot traffic plus explicit ticks while a stage waits on a hop — and
//! the coordinator mirrors them into a [`Broker`], whose liveness sweep is
//! the arbiter of "dead". Every blocking receive in the pipeline is a
//! `recv_timeout` loop that watches an abort flag, so no failure path can
//! leave a thread parked on an unbounded `recv`.
//!
//! On failure the coordinator tears the attempt down (abort flag + join
//! *all* threads, aggregating every stage's error), deregisters the failed
//! stage's broker node, promotes a replacement from the backup pool, and
//! replays from the last step-boundary v2 checkpoint (params + Adam
//! moments + step counter — see [`checkpoint`]). Replay is *exact*: data is
//! refetched from the DHT, per-channel FIFO fixes the gradient accumulation
//! order, and Adam bias correction is driven by the explicit step counter,
//! so a recovered run's losses are bitwise-identical to an uninterrupted
//! one (asserted by `tests/integration_recovery.rs`).
//!
//! Deterministic fault injection ([`FaultPlan`]) is threaded through
//! [`TrainConfig::faults`] so every one of these paths is exercised in CI.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::broker::{Broker, Event, NodeClass, NodeState};
use crate::cluster::checkpoint::{self, CheckpointV2, StageSnapshot};
use crate::cluster::data::{fetch_tokens, DataProvider, SyntheticCorpus};
use crate::cluster::faults::{FaultPlan, HopFault};
use crate::cluster::stage_backend::{StageBackend, StageBackendFactory, XlaStageFactory};
use crate::compress::Codec;
use crate::dht::Dht;
use crate::metrics::{LossCurve, Metrics};
use crate::net::{NetworkSim, Topology};
use crate::perf::comm::LinkModel;
use crate::perf::gpus::GPU_DB;
use crate::runtime::Manifest;
use crate::tensor::Tensor;

/// Error text of a worker that exited because the supervisor tore the
/// attempt down (not a root-cause failure; filtered out of aggregation).
const ABORTED: &str = "aborted by supervisor";

/// Trainer configuration.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Artifact directory (e.g. `artifacts/gpt-e2e`). Also where
    /// checkpoints land, so sim-backend runs need a writable dir too.
    pub artifacts_dir: PathBuf,
    pub steps: usize,
    pub microbatches: usize,
    /// Activation/gradient codec (None = raw f32).
    pub codec: Option<Codec>,
    /// Inter-compnode link model (for accounting and optional slowdown).
    pub link: LinkModel,
    /// Real-sleep multiplier on modelled delays (0 = account only).
    pub time_scale: f64,
    pub seed: u64,
    pub log_every: usize,
    /// Save final parameters to `<artifacts>/checkpoint.bin` (what `serve`
    /// loads).
    pub save_checkpoint: bool,
    /// Row-partition fan-out for the host GEMMs (1 = single-threaded).
    /// Results are bitwise-independent of this value.
    pub gemm_threads: usize,
    /// Write a v2 recovery checkpoint every N steps (0 = final step only).
    pub ckpt_every: usize,
    /// Broker liveness: seconds without a stage heartbeat before the node
    /// is declared dead. Generous by default — artifact compilation on
    /// spawn can be slow.
    pub heartbeat_timeout_s: f64,
    /// Max seconds a stage waits on one activation/gradient hop before it
    /// reports the peer as hung.
    pub hop_timeout_s: f64,
    /// How many supervised restarts to attempt before giving up.
    pub max_recoveries: usize,
    /// Size of the broker's standby pool (each recovery consumes one).
    pub backup_nodes: usize,
    /// Base backoff before a restart; doubles per recovery.
    pub recovery_backoff_ms: u64,
    /// Deterministic fault injection (None in production).
    pub faults: Option<Arc<FaultPlan>>,
}

impl TrainConfig {
    pub fn new(artifacts_dir: impl Into<PathBuf>) -> TrainConfig {
        TrainConfig {
            artifacts_dir: artifacts_dir.into(),
            steps: 50,
            microbatches: 2,
            codec: None,
            link: LinkModel::from_ms_mbps(5.0, 1000.0),
            time_scale: 0.0,
            seed: 42,
            log_every: 10,
            save_checkpoint: true,
            gemm_threads: 1,
            ckpt_every: 10,
            heartbeat_timeout_s: 60.0,
            hop_timeout_s: 30.0,
            max_recoveries: 2,
            backup_nodes: 2,
            recovery_backoff_ms: 50,
            faults: None,
        }
    }
}

/// What the trainer returns.
#[derive(Debug)]
pub struct TrainReport {
    pub losses: LossCurve,
    pub steps: usize,
    pub wall_seconds: f64,
    pub tokens_per_second: f64,
    /// Total bytes that crossed compnode boundaries.
    pub comm_bytes: u64,
    /// Modelled WAN seconds (virtual).
    pub comm_model_seconds: f64,
    /// Supervised restarts that were needed to finish.
    pub recoveries: usize,
    /// Root-cause stage failures observed across all attempts.
    pub stage_failures: usize,
    /// v2 recovery checkpoints written.
    pub checkpoints_written: usize,
    /// Messages lost in flight (fault injection).
    pub messages_dropped: u64,
    /// The broker's event log (registrations, deaths, promotions).
    pub broker_events: Vec<Event>,
}

/// A tensor on the wire.
struct WireMsg {
    mb: usize,
    tensor: Tensor,
}

/// Everything a stage reports to the coordinator rides one channel, so
/// every message doubles as a liveness signal.
enum StageEvent {
    /// "Still alive" — sent on spawn and while waiting on a hop.
    Heartbeat { stage: usize },
    /// Per-step mean loss (head stage only).
    Loss { step: usize, loss: f32 },
    /// Step-boundary training state; `step` counts *completed* steps.
    Snapshot { stage: usize, step: u64, snap: StageSnapshot },
    Done { stage: usize },
    Failed { stage: usize, error: String },
}

/// Send one activation/gradient hop: pays the WAN delay, (optionally)
/// round-trips the payload through the codec so the numeric effect of
/// compression is real, and consults the fault plan — an armed drop burns
/// the transfer and never delivers, letting the receiver's hop timeout
/// exercise the recovery path.
#[allow(clippy::too_many_arguments)]
fn send_hop(
    net: &NetworkSim,
    from: usize,
    to: usize,
    step: usize,
    codec: Option<Codec>,
    faults: Option<&FaultPlan>,
    tx: &Sender<WireMsg>,
    mb: usize,
    tensor: Tensor,
) -> Result<()> {
    let (payload, wire_bytes) = match codec {
        None => {
            let b = tensor.bytes();
            (tensor, b)
        }
        Some(c) => {
            let shape = tensor.shape().to_vec();
            let n = tensor.numel();
            let encoded = c.encode(tensor.f());
            let bytes = encoded.len() as u64;
            let decoded = Tensor::from_vec(&shape, c.decode(&encoded, n));
            (decoded, bytes)
        }
    };
    if let Some(f) = faults {
        match f.fire_hop(from, to, step) {
            Some(HopFault::Drop) => {
                net.drop_message(from, to, wire_bytes);
                log::warn!("injected fault: dropped {from}->{to} hop at step {step}");
                return Ok(());
            }
            Some(HopFault::DelayMs(ms)) => {
                log::warn!("injected fault: delaying {from}->{to} hop at step {step} by {ms}ms");
                std::thread::sleep(Duration::from_millis(ms));
            }
            None => {}
        }
    }
    net.transfer(from, to, wire_bytes);
    tx.send(WireMsg { mb, tensor: payload }).map_err(|_| anyhow!("pipeline channel closed"))
}

/// The trainer.
pub struct PipelineTrainer {
    pub config: TrainConfig,
    pub manifest: Manifest,
    /// Recovery/supervision counters and gauges, live during `run()`.
    pub metrics: Arc<Metrics>,
    factory: Arc<dyn StageBackendFactory>,
}

impl PipelineTrainer {
    /// Production constructor: loads the artifact manifest (cheap) and
    /// trains through per-stage `XlaEngine`s.
    pub fn new(config: TrainConfig) -> Result<PipelineTrainer> {
        let manifest = Manifest::load(&config.artifacts_dir.join("manifest.json"))
            .context("loading artifact manifest (run `make artifacts` first)")?;
        let factory = Arc::new(XlaStageFactory { dir: config.artifacts_dir.clone() });
        PipelineTrainer::with_backend(config, manifest, factory)
    }

    /// Train an arbitrary backend (the fault-injection tests drive the
    /// whole supervisor with `SimStageFactory`, no artifacts needed).
    pub fn with_backend(
        config: TrainConfig,
        manifest: Manifest,
        factory: Arc<dyn StageBackendFactory>,
    ) -> Result<PipelineTrainer> {
        if manifest.stages.len() < 2 {
            bail!("need ≥2 stages, manifest has {}", manifest.stages.len());
        }
        Ok(PipelineTrainer { config, manifest, metrics: Arc::new(Metrics::new()), factory })
    }

    /// Run the full training loop under supervision. Blocks until all steps
    /// complete or the recovery budget is exhausted.
    pub fn run(&self) -> Result<TrainReport> {
        let cfg = &self.config;
        crate::tensor::set_gemm_threads(cfg.gemm_threads);
        let stages = self.manifest.stages.clone();
        let n_stages = stages.len();
        let batch = self
            .manifest
            .config_usize("batch")
            .ok_or_else(|| anyhow!("manifest missing batch"))?;
        let seq =
            self.manifest.config_usize("seq").ok_or_else(|| anyhow!("manifest missing seq"))?;
        let vocab = self
            .manifest
            .config_usize("vocab")
            .ok_or_else(|| anyhow!("manifest missing vocab"))?;

        // DHT with one storage peer per stage + provider replication 2. All
        // steps are published up front and never retired during the run, so
        // replayed steps fetch bitwise-identical batches.
        let mut dht = Dht::new(2);
        for p in 0..n_stages.max(2) {
            dht.join(p).unwrap();
        }
        let dht = Arc::new(Mutex::new(dht));
        let provider = DataProvider::new(SyntheticCorpus::new(vocab, seq, batch), dht.clone());
        for step in 0..cfg.steps {
            provider.publish_step(step, cfg.microbatches)?;
        }

        let net = Arc::new(NetworkSim::new(Topology::uniform(cfg.link), cfg.time_scale));

        // Broker bookkeeping: one active node per stage plus the standby
        // pool the paper's §3.2 recovery story draws replacements from.
        let mut broker = Broker::new(cfg.heartbeat_timeout_s);
        let node_of_stage: Vec<usize> = (0..n_stages)
            .map(|si| {
                broker.register(&GPU_DB[si % GPU_DB.len()], 1.0, NodeClass::Supernode, 0.0, false)
            })
            .collect();
        for b in 0..cfg.backup_nodes {
            broker.register(
                &GPU_DB[(n_stages + b) % GPU_DB.len()],
                1.0,
                NodeClass::Antnode,
                0.0,
                true,
            );
        }

        let ckpt_path = checkpoint::recovery_path(&cfg.artifacts_dir);
        // A stale recovery file from an earlier run must not leak into this
        // one's replay decisions.
        let _ = std::fs::remove_file(&ckpt_path);
        let _ = std::fs::remove_file(checkpoint::prev_path(&ckpt_path));
        if let Some(dir) = ckpt_path.parent() {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating {}", dir.display()))?;
        }

        let mut sup = Supervisor {
            cfg,
            factory: self.factory.clone(),
            metrics: self.metrics.clone(),
            stages,
            batch,
            seq,
            net: net.clone(),
            dht,
            broker,
            node_of_stage,
            ckpt_path,
            t0: Instant::now(),
            losses: BTreeMap::new(),
            pending_snaps: BTreeMap::new(),
            final_snaps: None,
            recoveries: 0,
            stage_failures: 0,
            ckpts_written: 0,
        };

        let mut start_step = 0usize;
        let mut restore: Option<CheckpointV2> = None;
        loop {
            match sup.run_attempt(start_step, restore.as_ref())? {
                AttemptOutcome::Finished => break,
                AttemptOutcome::Failed(failures) => {
                    (start_step, restore) = sup.plan_recovery(failures)?;
                }
            }
        }

        if cfg.save_checkpoint {
            sup.publish_final_checkpoint()?;
        }
        let broker_events = std::mem::take(&mut sup.broker.events);
        let wall = sup.t0.elapsed().as_secs_f64();
        let tokens = (cfg.steps * cfg.microbatches * batch * seq) as f64;
        let mut losses = LossCurve::new();
        for (&step, &loss) in &sup.losses {
            losses.record(step, loss);
        }
        Ok(TrainReport {
            losses,
            steps: cfg.steps,
            wall_seconds: wall,
            tokens_per_second: tokens / wall,
            comm_bytes: net.total_remote_bytes(),
            comm_model_seconds: net.total_remote_seconds(),
            recoveries: sup.recoveries,
            stage_failures: sup.stage_failures,
            checkpoints_written: sup.ckpts_written,
            messages_dropped: net.total_dropped(),
            broker_events,
        })
    }
}

enum AttemptOutcome {
    Finished,
    /// Root-cause failures, `(stage index, error)`, in arrival order.
    Failed(Vec<(usize, String)>),
}

/// The coordinator: owns the broker mirror, the checkpoint assembly and
/// the per-attempt thread lifecycle.
struct Supervisor<'a> {
    cfg: &'a TrainConfig,
    factory: Arc<dyn StageBackendFactory>,
    metrics: Arc<Metrics>,
    stages: Vec<String>,
    batch: usize,
    seq: usize,
    net: Arc<NetworkSim>,
    dht: Arc<Mutex<Dht>>,
    broker: Broker,
    /// Stage index → broker node currently hosting it (rewired on
    /// backup promotion).
    node_of_stage: Vec<usize>,
    ckpt_path: PathBuf,
    t0: Instant,
    /// Per-step losses; replays overwrite with bitwise-identical values.
    losses: BTreeMap<usize, f32>,
    /// Step → stage → snapshot, assembled until all stages report.
    pending_snaps: BTreeMap<u64, BTreeMap<usize, StageSnapshot>>,
    /// The last fully-assembled snapshot set (for the final v1 bridge).
    final_snaps: Option<(u64, BTreeMap<usize, StageSnapshot>)>,
    recoveries: usize,
    stage_failures: usize,
    ckpts_written: usize,
}

impl Supervisor<'_> {
    /// One supervised attempt: spawn all stages at `start_step`, pump
    /// events until every stage is done or something fails, then join
    /// *every* thread and aggregate their results.
    fn run_attempt(
        &mut self,
        start_step: usize,
        restore: Option<&CheckpointV2>,
    ) -> Result<AttemptOutcome> {
        let cfg = self.cfg;
        let n_stages = self.stages.len();

        // Channels, one slot per stage: stage i sends activations forward
        // on act_txs[i] (received by i+1 on act_rxs[i+1]) and gradients
        // backward on grad_txs[i] (received by i-1 on grad_rxs[i-1]). The
        // pipeline ends leave the unused slots None. Fresh channels per
        // attempt: messages from a torn-down step die with them.
        let mut act_txs: Vec<Option<Sender<WireMsg>>> = (0..n_stages).map(|_| None).collect();
        let mut act_rxs: Vec<Option<Receiver<WireMsg>>> = (0..n_stages).map(|_| None).collect();
        let mut grad_txs: Vec<Option<Sender<WireMsg>>> = (0..n_stages).map(|_| None).collect();
        let mut grad_rxs: Vec<Option<Receiver<WireMsg>>> = (0..n_stages).map(|_| None).collect();
        for i in 0..n_stages - 1 {
            let (tx, rx) = channel::<WireMsg>();
            act_txs[i] = Some(tx);
            act_rxs[i + 1] = Some(rx);
            let (tx, rx) = channel::<WireMsg>();
            grad_txs[i + 1] = Some(tx);
            grad_rxs[i] = Some(rx);
        }
        let (ev_tx, ev_rx) = channel::<StageEvent>();
        let abort = Arc::new(AtomicBool::new(false));

        let mut handles = Vec::with_capacity(n_stages);
        for (si, stage) in self.stages.iter().enumerate() {
            let ctx = StageCtx {
                stage: stage.clone(),
                stage_idx: si,
                factory: self.factory.clone(),
                start_step,
                steps: cfg.steps,
                microbatches: cfg.microbatches,
                batch: self.batch,
                seq: self.seq,
                ckpt_every: cfg.ckpt_every,
                hop_timeout: Duration::from_secs_f64(cfg.hop_timeout_s.max(0.001)),
                codec: cfg.codec,
                net: self.net.clone(),
                dht: self.dht.clone(),
                seed: cfg.seed,
                restore: restore.and_then(|c| c.stages.get(stage).cloned()),
                faults: cfg.faults.clone(),
                abort: abort.clone(),
                act_rx: act_rxs[si].take(),
                act_tx: act_txs[si].take(),
                grad_rx: grad_rxs[si].take(),
                grad_tx: grad_txs[si].take(),
                events: ev_tx.clone(),
                is_first: si == 0,
                is_last: si == n_stages - 1,
            };
            let events = ev_tx.clone();
            let abort_flag = abort.clone();
            handles.push(std::thread::spawn(move || -> Result<()> {
                let result = stage_worker(ctx);
                if let Err(e) = &result {
                    let msg = format!("{e:#}");
                    if !abort_flag.load(Ordering::SeqCst) && !msg.contains(ABORTED) {
                        log::warn!("stage {si} worker failed: {msg}");
                        let _ = events.send(StageEvent::Failed { stage: si, error: msg });
                    }
                }
                result
            }));
        }
        drop(ev_tx);

        // Event pump: drain stage traffic, mirror liveness into the broker,
        // sweep for silent deaths. recv_timeout keeps the sweep running
        // even when every stage is stuck.
        let mut done = vec![false; n_stages];
        let mut failures: Vec<(usize, String)> = Vec::new();
        let poll = Duration::from_millis(25);
        while !done.iter().all(|&d| d) && failures.is_empty() {
            match ev_rx.recv_timeout(poll) {
                Ok(ev) => self.absorb(ev, &mut done, &mut failures)?,
                Err(RecvTimeoutError::Timeout) => {}
                // Every worker exited (all senders dropped) — results are
                // in the join handles below.
                Err(RecvTimeoutError::Disconnected) => break,
            }
            let now = self.t0.elapsed().as_secs_f64();
            // The standby pool is healthy by definition while unpromoted —
            // without these ticks the broker's sweep would expire it.
            for b in self.broker.backup_pool() {
                let _ = self.broker.heartbeat(b, now);
            }
            for node in self.broker.check_liveness(now) {
                if let Some(si) = self.node_of_stage.iter().position(|&n| n == node) {
                    failures.push((si, "missed heartbeats (liveness timeout)".to_string()));
                    self.metrics.inc("train.liveness_expirations", 1);
                }
            }
        }

        // Tear down: every surviving thread sees the flag at its next hop
        // poll or step boundary. Then join ALL of them — first error must
        // not detach the rest — aggregating every root-cause failure. Once
        // we initiated the abort, peer errors (closed channels, hop
        // timeouts) are collateral of the teardown, not new root causes.
        let teardown = !failures.is_empty();
        if teardown {
            abort.store(true, Ordering::SeqCst);
        }
        for (si, h) in handles.into_iter().enumerate() {
            match h.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    let msg = format!("{e:#}");
                    if !teardown
                        && !msg.contains(ABORTED)
                        && !failures.iter().any(|(s, _)| *s == si)
                    {
                        failures.push((si, msg));
                    }
                }
                // A panic is always a real failure, teardown or not.
                Err(_) => {
                    if !failures.iter().any(|(s, _)| *s == si) {
                        failures.push((si, "worker thread panicked".to_string()));
                    }
                }
            }
        }
        // Late events (snapshots finished just before a peer died) still
        // count toward checkpoint assembly.
        while let Ok(ev) = ev_rx.try_recv() {
            self.absorb(ev, &mut done, &mut failures)?;
        }

        if failures.is_empty() && done.iter().all(|&d| d) {
            Ok(AttemptOutcome::Finished)
        } else if failures.is_empty() {
            // Threads exited cleanly but not every stage reported Done —
            // defensive; should be unreachable.
            let missing: Vec<&str> = (0..n_stages)
                .filter(|&si| !done[si])
                .map(|si| self.stages[si].as_str())
                .collect();
            bail!("stages [{}] exited without completing", missing.join(", "));
        } else {
            Ok(AttemptOutcome::Failed(failures))
        }
    }

    /// Fold one stage event into supervisor state. Every event refreshes
    /// the sender's broker heartbeat.
    fn absorb(
        &mut self,
        ev: StageEvent,
        done: &mut [bool],
        failures: &mut Vec<(usize, String)>,
    ) -> Result<()> {
        let now = self.t0.elapsed().as_secs_f64();
        match ev {
            StageEvent::Heartbeat { stage } => {
                let _ = self.broker.heartbeat(self.node_of_stage[stage], now);
            }
            StageEvent::Loss { step, loss } => {
                let _ = self.broker.heartbeat(self.node_of_stage[self.stages.len() - 1], now);
                if self.cfg.log_every > 0 && step % self.cfg.log_every == 0 {
                    log::info!("step {step}: loss {loss:.4}");
                    eprintln!("  [train] step {step:>5}  loss {loss:.4}");
                }
                self.losses.insert(step, loss);
            }
            StageEvent::Snapshot { stage, step, snap } => {
                let _ = self.broker.heartbeat(self.node_of_stage[stage], now);
                let set = self.pending_snaps.entry(step).or_default();
                set.insert(stage, snap);
                if set.len() == self.stages.len() {
                    let set = self.pending_snaps.remove(&step).unwrap();
                    self.write_recovery_checkpoint(step, &set)?;
                    // Older boundaries can never complete once a newer one
                    // has; drop the stale partial sets.
                    self.pending_snaps.retain(|&s, _| s > step);
                    self.final_snaps = Some((step, set));
                }
            }
            StageEvent::Done { stage } => done[stage] = true,
            StageEvent::Failed { stage, error } => {
                if !failures.iter().any(|(s, _)| *s == stage) {
                    failures.push((stage, error));
                }
            }
        }
        Ok(())
    }

    /// Write the assembled step-boundary state as a rotating v2 checkpoint,
    /// then give an armed truncate fault its chance to corrupt it.
    fn write_recovery_checkpoint(
        &mut self,
        step: u64,
        set: &BTreeMap<usize, StageSnapshot>,
    ) -> Result<()> {
        let ckpt = CheckpointV2 {
            step,
            stages: set
                .iter()
                .map(|(&si, snap)| (self.stages[si].clone(), snap.clone()))
                .collect(),
        };
        checkpoint::save_v2_rotating(&self.ckpt_path, &ckpt)
            .with_context(|| format!("writing recovery checkpoint at step {step}"))?;
        self.ckpts_written += 1;
        self.metrics.inc("train.checkpoints_written", 1);
        self.metrics.set_gauge("train.last_checkpoint_step", step as f64);
        if let Some(f) = &self.cfg.faults {
            if let Some(keep) = f.fire_truncate(step as usize) {
                let bytes = std::fs::read(&self.ckpt_path)?;
                let keep = (keep as usize).min(bytes.len());
                std::fs::write(&self.ckpt_path, &bytes[..keep])?;
                log::warn!("injected fault: truncated step-{step} checkpoint to {keep} bytes");
            }
        }
        Ok(())
    }

    /// Decide how to restart after a failed attempt: broker bookkeeping
    /// (deregister the root-cause node, promote a backup), exponential
    /// backoff, then reload the newest readable recovery checkpoint.
    /// Returns `(start_step, restore)` for the next attempt.
    fn plan_recovery(
        &mut self,
        failures: Vec<(usize, String)>,
    ) -> Result<(usize, Option<CheckpointV2>)> {
        self.stage_failures += failures.len();
        self.metrics.inc("train.stage_failures", failures.len() as u64);
        let desc: Vec<String> = failures
            .iter()
            .map(|(si, e)| format!("stage {si} ({}): {e}", self.stages[*si]))
            .collect();
        let desc = desc.join("; ");
        if self.recoveries >= self.cfg.max_recoveries {
            bail!(
                "pipeline failed after {} recover{}: {desc}",
                self.recoveries,
                if self.recoveries == 1 { "y" } else { "ies" }
            );
        }

        // The first reported failure is the root cause (peers that died of
        // closed channels / aborts were filtered); its node leaves the
        // cluster and a standby takes over the stage.
        let (primary, _) = failures[0];
        let node = self.node_of_stage[primary];
        if self.broker.state(node) != Some(NodeState::Offline) {
            self.broker.deregister(node);
        }
        let replacement = self.broker.promote_backup(node).ok_or_else(|| {
            anyhow!("backup pool exhausted while replacing stage {primary}: {desc}")
        })?;
        self.node_of_stage[primary] = replacement;
        let _ = self.broker.heartbeat(replacement, self.t0.elapsed().as_secs_f64());
        self.recoveries += 1;
        self.metrics.inc("train.recoveries", 1);

        let backoff = self.cfg.recovery_backoff_ms << (self.recoveries - 1).min(6);
        self.metrics.observe("train.recovery_backoff_ms", backoff as f64);
        if backoff > 0 {
            std::thread::sleep(Duration::from_millis(backoff));
        }

        // Newest readable generation wins; a truncated newest falls back to
        // `.prev`; nothing readable restarts from scratch (same seed ⇒ same
        // init ⇒ still deterministic).
        let (latest, unreadable) = checkpoint::load_latest_v2(&self.ckpt_path);
        self.metrics.inc("train.checkpoint_load_failures", unreadable);
        let (start_step, restore) = match latest {
            Some(ck) => {
                let s = ck.step as usize;
                (s, Some(ck))
            }
            None => (0, None),
        };
        // Replayed steps regenerate their losses and snapshots bitwise;
        // drop what the failed attempt produced past the restore point.
        self.losses.retain(|&s, _| s < start_step);
        self.pending_snaps.clear();
        log::warn!(
            "supervisor: recovery #{} — {desc}; node {node} → backup {replacement}, \
             replaying from step {start_step}",
            self.recoveries
        );
        eprintln!(
            "  [train] recovery #{}: {desc}; replaying from step {start_step}",
            self.recoveries
        );
        Ok((start_step, restore))
    }

    /// Bridge to `serve`: write the final parameters as a v1 checkpoint.
    /// An incomplete set is an error naming every absent stage — never a
    /// silent skip.
    fn publish_final_checkpoint(&self) -> Result<()> {
        let (step, set) = self
            .final_snaps
            .as_ref()
            .ok_or_else(|| anyhow!("training finished but no complete snapshot set arrived"))?;
        if *step != self.cfg.steps as u64 || set.len() != self.stages.len() {
            let missing: Vec<&str> = (0..self.stages.len())
                .filter(|si| !set.contains_key(si))
                .map(|&si| self.stages[si].as_str())
                .collect();
            bail!(
                "final checkpoint incomplete: have step {step}/{} with {}/{} stages \
                 (missing [{}])",
                self.cfg.steps,
                set.len(),
                self.stages.len(),
                missing.join(", ")
            );
        }
        let ckpt: checkpoint::Checkpoint = set
            .iter()
            .map(|(&si, snap)| (self.stages[si].clone(), snap.params.clone()))
            .collect();
        let path = checkpoint::default_path(&self.cfg.artifacts_dir);
        checkpoint::save(&path, &ckpt)?;
        log::info!("checkpoint written to {}", path.display());
        Ok(())
    }
}

struct StageCtx {
    stage: String,
    stage_idx: usize,
    factory: Arc<dyn StageBackendFactory>,
    start_step: usize,
    steps: usize,
    microbatches: usize,
    batch: usize,
    seq: usize,
    ckpt_every: usize,
    hop_timeout: Duration,
    codec: Option<Codec>,
    net: Arc<NetworkSim>,
    dht: Arc<Mutex<Dht>>,
    seed: u64,
    restore: Option<StageSnapshot>,
    faults: Option<Arc<FaultPlan>>,
    abort: Arc<AtomicBool>,
    act_rx: Option<Receiver<WireMsg>>,
    act_tx: Option<Sender<WireMsg>>,
    grad_rx: Option<Receiver<WireMsg>>,
    grad_tx: Option<Sender<WireMsg>>,
    events: Sender<StageEvent>,
    is_first: bool,
    is_last: bool,
}

impl StageCtx {
    fn check_abort(&self) -> Result<()> {
        if self.abort.load(Ordering::SeqCst) {
            bail!("{ABORTED}");
        }
        Ok(())
    }

    /// Bounded receive: polls so the abort flag is honored within ~25ms,
    /// heartbeats the coordinator every tick (a stage waiting on a slow
    /// peer is alive, not dead), and gives up after `hop_timeout` — the
    /// unbounded `recv` this replaces could hang the pipeline forever on a
    /// dead peer.
    fn recv_hop(&self, rx: &Receiver<WireMsg>, what: &str) -> Result<WireMsg> {
        let poll = Duration::from_millis(25);
        let deadline = Instant::now() + self.hop_timeout;
        loop {
            self.check_abort()?;
            match rx.recv_timeout(poll.min(self.hop_timeout)) {
                Ok(msg) => return Ok(msg),
                Err(RecvTimeoutError::Timeout) => {
                    let _ = self.events.send(StageEvent::Heartbeat { stage: self.stage_idx });
                    if Instant::now() >= deadline {
                        bail!(
                            "stage {} ({}): timed out after {:.1}s waiting for {what}",
                            self.stage_idx,
                            self.stage,
                            self.hop_timeout.as_secs_f64()
                        );
                    }
                }
                Err(RecvTimeoutError::Disconnected) => {
                    // Distinguish supervisor teardown from a dead peer.
                    self.check_abort()?;
                    bail!("stage {} ({}): {what} channel closed", self.stage_idx, self.stage)
                }
            }
        }
    }

    fn send_fwd(&self, step: usize, mb: usize, tensor: Tensor) -> Result<()> {
        send_hop(
            &self.net,
            self.stage_idx,
            self.stage_idx + 1,
            step,
            self.codec,
            self.faults.as_deref(),
            self.act_tx.as_ref().ok_or_else(|| anyhow!("no downstream"))?,
            mb,
            tensor,
        )
    }

    fn send_bwd(&self, step: usize, mb: usize, tensor: Tensor) -> Result<()> {
        send_hop(
            &self.net,
            self.stage_idx,
            self.stage_idx - 1,
            step,
            self.codec,
            self.faults.as_deref(),
            self.grad_tx.as_ref().ok_or_else(|| anyhow!("no upstream"))?,
            mb,
            tensor,
        )
    }
}

/// One compnode's whole life for one supervised attempt: build the
/// backend, optionally restore it from the recovery snapshot, then run the
/// GPipe schedule for steps `start_step..steps`.
fn stage_worker(ctx: StageCtx) -> Result<()> {
    // First signs of life before the (possibly slow) backend build.
    let _ = ctx.events.send(StageEvent::Heartbeat { stage: ctx.stage_idx });
    let mut backend: Box<dyn StageBackend> = ctx
        .factory
        .make(&ctx.stage, ctx.stage_idx, ctx.seed)
        .with_context(|| format!("building backend for stage '{}'", ctx.stage))?;
    if let Some(snap) = &ctx.restore {
        backend
            .restore(snap)
            .with_context(|| format!("restoring stage '{}' from checkpoint", ctx.stage))?;
    }
    let _ = ctx.events.send(StageEvent::Heartbeat { stage: ctx.stage_idx });

    let mb_count = ctx.microbatches;
    for step in ctx.start_step..ctx.steps {
        ctx.check_abort()?;
        if let Some(f) = &ctx.faults {
            if f.fire_kill(ctx.stage_idx, step) {
                bail!("injected fault: kill stage {} at step {step}", ctx.stage_idx);
            }
            if let Some(ms) = f.fire_stall(ctx.stage_idx, step) {
                log::warn!("injected fault: stage {} stalling {ms}ms at step {step}", ctx.stage_idx);
                std::thread::sleep(Duration::from_millis(ms));
            }
        }

        let mut grads_acc: Option<Vec<Tensor>> = None;

        if ctx.is_last {
            // Head: consume activations as they arrive; immediately run the
            // backward (which internally computes forward + loss).
            let mut loss_sum = 0.0f32;
            for _ in 0..mb_count {
                let msg =
                    ctx.recv_hop(ctx.act_rx.as_ref().unwrap(), "an upstream activation")?;
                let labels =
                    fetch_tokens(&ctx.dht, step, msg.mb, "labels", &[ctx.batch, ctx.seq])?;
                let (dx, dparams, loss) =
                    backend.backward(&[&msg.tensor, &labels], None)?;
                loss_sum += loss.unwrap_or(f32::NAN);
                accumulate(&mut grads_acc, dparams);
                ctx.send_bwd(step, msg.mb, dx.ok_or_else(|| anyhow!("head produced no dx"))?)?;
            }
            let _ =
                ctx.events.send(StageEvent::Loss { step, loss: loss_sum / mb_count as f32 });
        } else {
            // Forward all microbatches, stashing this stage's inputs per
            // microbatch for the rematerializing backward.
            let mut stash: Vec<Option<Tensor>> = (0..mb_count).map(|_| None).collect();
            for mb in 0..mb_count {
                let (mb, input) = if ctx.is_first {
                    (mb, fetch_tokens(&ctx.dht, step, mb, "tokens", &[ctx.batch, ctx.seq])?)
                } else {
                    // Use arrival mb index; stash by move once forwarded.
                    let msg =
                        ctx.recv_hop(ctx.act_rx.as_ref().unwrap(), "an upstream activation")?;
                    (msg.mb, msg.tensor)
                };
                let out = backend.forward(&[&input])?;
                stash[mb] = Some(input);
                ctx.send_fwd(step, mb, out)?;
            }
            // Backward: consume gradients in arrival order — single
            // producer per channel, so the accumulation order (and the f32
            // sum) is identical on every run and replay.
            for _ in 0..mb_count {
                let msg =
                    ctx.recv_hop(ctx.grad_rx.as_ref().unwrap(), "a downstream gradient")?;
                let input = stash[msg.mb]
                    .take()
                    .ok_or_else(|| anyhow!("no stashed input for microbatch {}", msg.mb))?;
                let (dx, dparams, _) = backend.backward(&[&input], Some(&msg.tensor))?;
                accumulate(&mut grads_acc, dparams);
                if let Some(dx) = dx {
                    if ctx.grad_tx.is_some() {
                        ctx.send_bwd(step, msg.mb, dx)?;
                    }
                }
            }
        }

        // ---- update phase ----
        let grads = grads_acc.ok_or_else(|| anyhow!("no gradients accumulated"))?;
        backend.update(&grads, step as i32 + 1)?;
        let _ = ctx.events.send(StageEvent::Heartbeat { stage: ctx.stage_idx });

        // ---- step boundary: ship recovery state ----
        let completed = step + 1;
        let at_boundary = ctx.ckpt_every != 0 && completed % ctx.ckpt_every == 0;
        if at_boundary || completed == ctx.steps {
            let _ = ctx.events.send(StageEvent::Snapshot {
                stage: ctx.stage_idx,
                step: completed as u64,
                snap: backend.snapshot(),
            });
        }
    }
    let _ = ctx.events.send(StageEvent::Done { stage: ctx.stage_idx });
    Ok(())
}

fn accumulate(acc: &mut Option<Vec<Tensor>>, grads: Vec<Tensor>) {
    match acc {
        None => *acc = Some(grads),
        Some(a) => {
            for (x, g) in a.iter_mut().zip(&grads) {
                x.axpy(1.0, g);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_defaults_sane() {
        let c = TrainConfig::new("artifacts/gpt-tiny");
        assert!(c.steps > 0 && c.microbatches > 0);
        assert!(c.codec.is_none());
        assert!(c.ckpt_every > 0 && c.max_recoveries > 0 && c.backup_nodes > 0);
        assert!(c.heartbeat_timeout_s > 0.0 && c.hop_timeout_s > 0.0);
        assert!(c.faults.is_none());
    }

    // Full supervised runs (clean, kill-at-step-k, drop-hop, truncated
    // checkpoint) are exercised in rust/tests/integration_recovery.rs with
    // the sim backend, and against real artifacts in
    // rust/tests/integration_runtime.rs (needs `make artifacts`).
}
