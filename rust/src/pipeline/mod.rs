//! Pipeline parallelism: the paper's §4 analytic model and a concrete
//! microbatch schedule generator used by the live runtime.
//!
//! * [`analytics`] — Equations 3 & 4: FP latency of a partitioned DAG and
//!   the pipelined cost of processing `n_b` batches, the model behind
//!   Figures 5 and 6;
//! * [`schedule`] — a deterministic GPipe-style (all-forward, all-backward)
//!   microbatch schedule with bubble accounting, consumed by
//!   [`crate::cluster`] when actually training.

pub mod analytics;
pub mod schedule;

pub use analytics::{PipelineEstimate, StageCost};
pub use schedule::{MicrobatchSchedule, PipeEvent, PipeEventKind};
