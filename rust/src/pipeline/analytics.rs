//! The paper's §4 performance analysis, implemented exactly.
//!
//! Equation 3 — forward-pass latency of a pipeline-partitioned DAG:
//!
//! ```text
//!   T(G)_lat = Σ_{p∈P} T_p = Σ_{p∈P} (C_p + R_p)
//! ```
//!
//! where `C_p` is peer p's compute time over its assigned sub-DAGs and
//! `R_p = Σ_{f: P(f) ≠ P(Pa(f))} T_comm(M_f)` the time to receive remote
//! parent activations.
//!
//! Equation 4 — pipelining `n_b` batches overlaps compute and communication
//! of different batches:
//!
//! ```text
//!   T(G)_{n_b,pipe} = Σ_p (C_p + R_p) + (n_b − 1) · max_p max(C_p, R_p)
//! ```
//!
//! so for large `n_b` throughput is governed by the slowest stage term —
//! this is why 50 slow-linked RTX 3080s can match 4 H100s in *throughput*
//! while losing badly on *latency* (the paper's headline observation).

use crate::dag::{flops, Graph};
use crate::decompose::Decomposition;
use crate::perf::comm::LinkModel;
use crate::perf::paleo::PaleoModel;

/// Per-stage cost pair `(C_p, R_p)`.
#[derive(Debug, Clone, Copy)]
pub struct StageCost {
    /// Compute seconds for one batch through this stage.
    pub compute_s: f64,
    /// Seconds receiving remote parent activations for one batch.
    pub comm_s: f64,
}

impl StageCost {
    /// The stage's pipeline-limiting term `max(C_p, R_p)`.
    pub fn bottleneck(&self) -> f64 {
        self.compute_s.max(self.comm_s)
    }
}

/// The assembled estimate for one configuration.
#[derive(Debug, Clone)]
pub struct PipelineEstimate {
    pub stages: Vec<StageCost>,
}

impl PipelineEstimate {
    /// Build from a decomposition: stage k runs on device k (the paper's §4
    /// setting: sub-DAGs sequentially executed, one per peer), all
    /// cross-stage edges traverse `link`.
    ///
    /// `models[k]` is the PALEO model of the device hosting sub-graph k.
    /// If `training` is set, compute includes the backward pass (≈3× fwd).
    pub fn from_decomposition(
        g: &Graph,
        d: &Decomposition,
        models: &[PaleoModel],
        link: LinkModel,
        training: bool,
    ) -> PipelineEstimate {
        assert_eq!(models.len(), d.num_subgraphs(), "one device per sub-graph");
        let mut stages = Vec::with_capacity(d.num_subgraphs());
        for (k, model) in models.iter().enumerate() {
            let mut compute = 0.0;
            for &n in &d.subgraphs[k].nodes {
                let node = g.node(n);
                compute += model.compute_time(node) + model.write_time(node);
                if training {
                    compute += model.compute_time_bwd(node);
                }
            }
            // R_p: remote parent activations entering stage k. Each remote
            // source tensor is transferred ONCE even if several local ops
            // consume it — matching the executor's per-destination dedup
            // (compnode::SubDagExecutor::run_fp).
            let mut seen_sources = std::collections::BTreeSet::new();
            let mut comm = 0.0;
            for &n in &d.subgraphs[k].nodes {
                for &a in &g.node(n).args {
                    if d.of_node[a] != k && seen_sources.insert(a) {
                        let bytes = flops::activation_bytes(g.node(a));
                        comm += link.time(bytes);
                        if training {
                            // The gradient flows back over the same edge.
                            comm += link.time(bytes);
                        }
                    }
                }
            }
            stages.push(StageCost { compute_s: compute, comm_s: comm });
        }
        PipelineEstimate { stages }
    }

    /// Equation 3: single-batch latency `Σ_p (C_p + R_p)`.
    pub fn latency(&self) -> f64 {
        self.stages.iter().map(|s| s.compute_s + s.comm_s).sum()
    }

    /// Equation 4: total time for `n_b` pipelined batches.
    pub fn pipelined_time(&self, n_b: usize) -> f64 {
        assert!(n_b >= 1);
        let steady = self.stages.iter().map(|s| s.bottleneck()).fold(0.0, f64::max);
        self.latency() + (n_b as f64 - 1.0) * steady
    }

    /// Throughput in batches/second at depth `n_b`.
    pub fn throughput(&self, n_b: usize) -> f64 {
        n_b as f64 / self.pipelined_time(n_b)
    }

    /// Asymptotic throughput `1 / max_p max(C_p, R_p)` (n_b → ∞).
    pub fn steady_state_throughput(&self) -> f64 {
        let steady = self.stages.iter().map(|s| s.bottleneck()).fold(0.0, f64::max);
        1.0 / steady
    }

    /// Fraction of total device-time lost to pipeline fill/drain at `n_b`
    /// (the "bubble" the paper's load-balance scheduling §3.8 reduces).
    pub fn bubble_fraction(&self, n_b: usize) -> f64 {
        let ideal = n_b as f64 * self.stages.iter().map(|s| s.bottleneck()).fold(0.0, f64::max);
        1.0 - ideal / self.pipelined_time(n_b)
    }

    /// Whether the pipeline is communication-bound (some stage's `R_p`
    /// exceeds every stage's `C_p`).
    pub fn comm_bound(&self) -> bool {
        let max_c = self.stages.iter().map(|s| s.compute_s).fold(0.0, f64::max);
        let max_r = self.stages.iter().map(|s| s.comm_s).fold(0.0, f64::max);
        max_r > max_c
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::transformer::TransformerConfig;
    use crate::perf::gpus::lookup;
    use crate::perf::paleo::DeviceProfile;

    fn estimate(n_stages: usize, gpu: &str, link: LinkModel) -> PipelineEstimate {
        let g = TransformerConfig::bert_large().build_graph();
        let d = Decomposition::chain_balanced(&g, n_stages);
        let models: Vec<PaleoModel> = (0..n_stages)
            .map(|_| PaleoModel::new(DeviceProfile::with_lambda(lookup(gpu).unwrap(), 0.5)))
            .collect();
        PipelineEstimate::from_decomposition(&g, &d, &models, link, false)
    }

    #[test]
    fn eq3_latency_is_sum_of_stage_terms() {
        let e = estimate(4, "RTX 3080", LinkModel::from_ms_mbps(10.0, 100.0));
        let manual: f64 = e.stages.iter().map(|s| s.compute_s + s.comm_s).sum();
        assert!((e.latency() - manual).abs() < 1e-15);
    }

    #[test]
    fn estimates_unchanged_by_pass_normalization() {
        // Builder graphs are already normal, so running the PassManager
        // before estimation must not move any cost term.
        use crate::dag::PassManager;
        let raw = TransformerConfig::bert_large().build_graph();
        let mut normed = TransformerConfig::bert_large().build_graph();
        assert!(!PassManager::standard().run(&mut normed).unwrap().changed());
        let link = LinkModel::from_ms_mbps(10.0, 100.0);
        let models: Vec<PaleoModel> = (0..4)
            .map(|_| PaleoModel::new(DeviceProfile::with_lambda(lookup("RTX 3080").unwrap(), 0.5)))
            .collect();
        let a = PipelineEstimate::from_decomposition(
            &raw,
            &Decomposition::chain_balanced(&raw, 4),
            &models,
            link,
            false,
        );
        let b = PipelineEstimate::from_decomposition(
            &normed,
            &Decomposition::chain_balanced(&normed, 4),
            &models,
            link,
            false,
        );
        assert_eq!(a.latency(), b.latency());
    }

    #[test]
    fn eq4_reduces_to_eq3_at_nb1() {
        let e = estimate(4, "RTX 3080", LinkModel::from_ms_mbps(10.0, 100.0));
        assert!((e.pipelined_time(1) - e.latency()).abs() < 1e-12);
    }

    #[test]
    fn pipelining_amortizes_latency() {
        let e = estimate(8, "RTX 3080", LinkModel::from_ms_mbps(10.0, 100.0));
        let t1 = e.pipelined_time(1);
        let t512 = e.pipelined_time(512);
        // 512 batches take far less than 512× one batch.
        assert!(t512 < 0.3 * 512.0 * t1, "t512={t512} t1={t1}");
        // Throughput at 512 approaches the steady-state bound.
        let r = e.throughput(512) / e.steady_state_throughput();
        assert!(r > 0.8 && r <= 1.0, "ratio {r}");
    }

    #[test]
    fn headline_50x3080_vs_4xh100() {
        // The paper's core claim: once links are fast enough that every
        // stage is compute-bound (R_p ≤ C_p), 50 consumer GPUs match 4 H100s
        // in steady-state throughput while losing badly on latency
        // (§4: "the throughput between them is similar, because the cost of
        // pipeline is (n_b−1)·max(C_p, R_p), if n_b is large").
        let dc = LinkModel::datacenter();
        let datacenter = estimate(4, "H100", dc);
        // Fast links (compute-bound regime): with Bert-Large at batch 8 the
        // per-stage compute slot is ~1 ms, so links must move ~34 MB of cut
        // activations well under that — datacenter-class bandwidth. (At true
        // consumer-WAN speeds Eq. 4 is comm-bound; see EXPERIMENTS.md and
        // the fig5 bench for the full sweep + compression mitigation.)
        let consumer_fast = estimate(50, "RTX 3080", LinkModel::datacenter());
        assert!(!consumer_fast.comm_bound());
        let ratio =
            consumer_fast.steady_state_throughput() / datacenter.steady_state_throughput();
        assert!(ratio > 0.5 && ratio < 2.0, "compute-bound throughput ratio {ratio}");
        // Latency: consumer is much worse at ANY bandwidth (50 hops).
        assert!(consumer_fast.latency() > datacenter.latency());
        // At consumer-broadband bandwidth the pipeline turns comm-bound and
        // throughput collapses — the Figure-5 left-hand regime.
        let consumer_slow = estimate(50, "RTX 3080", LinkModel::from_ms_mbps(5.0, 100.0));
        assert!(consumer_slow.comm_bound());
        let slow_ratio =
            consumer_slow.steady_state_throughput() / datacenter.steady_state_throughput();
        assert!(slow_ratio < 0.1, "comm-bound ratio {slow_ratio}");
    }

    #[test]
    fn low_bandwidth_makes_comm_bound() {
        let slow = estimate(50, "RTX 3080", LinkModel::from_ms_mbps(50.0, 10.0));
        assert!(slow.comm_bound());
        let fast = estimate(50, "RTX 3080", LinkModel::datacenter());
        assert!(!fast.comm_bound());
        // And the slow pipeline's throughput collapses.
        assert!(fast.steady_state_throughput() > 10.0 * slow.steady_state_throughput());
    }

    #[test]
    fn bubble_shrinks_with_depth() {
        let e = estimate(8, "RTX 3080", LinkModel::from_ms_mbps(10.0, 100.0));
        assert!(e.bubble_fraction(4) > e.bubble_fraction(64));
        assert!(e.bubble_fraction(512) < 0.2);
    }

    #[test]
    fn training_costs_more_than_inference() {
        // Use a compute-dominated model (bert-large); on toy dims the W(f,p)
        // memory-write term dominates and hides the backward FLOPs.
        let g = TransformerConfig::bert_large().build_graph();
        let d = Decomposition::chain_balanced(&g, 4);
        let models: Vec<PaleoModel> = (0..4)
            .map(|_| PaleoModel::new(DeviceProfile::with_lambda(lookup("A100").unwrap(), 0.5)))
            .collect();
        let inf = PipelineEstimate::from_decomposition(&g, &d, &models, LinkModel::local(), false);
        let tr = PipelineEstimate::from_decomposition(&g, &d, &models, LinkModel::local(), true);
        let ratio = tr.latency() / inf.latency();
        assert!(ratio > 2.0, "train/infer latency ratio {ratio}");
    }
}
