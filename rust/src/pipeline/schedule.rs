//! Deterministic microbatch schedule for the live pipeline runtime.
//!
//! GPipe-style all-forward/all-backward over `S` stages and `M` microbatches:
//! forward of microbatch m at stage s may start once stage s finished m−1
//! and stage s−1 finished m; backward symmetrically in reverse. Gradients
//! accumulate across microbatches and a single Update task per stage closes
//! the step (paper §3.6 "Update task").
//!
//! The schedule is a pure data structure so it can be unit-tested and used
//! both by the simulator and the real executor in [`crate::cluster`].

/// What a pipeline event does.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PipeEventKind {
    Forward,
    Backward,
    Update,
}

/// One unit of pipeline work: (stage, microbatch, kind).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PipeEvent {
    pub stage: usize,
    pub microbatch: usize,
    pub kind: PipeEventKind,
}

/// A complete schedule: per-stage ordered event lists plus, for each event,
/// its dependencies (events that must complete first).
#[derive(Debug, Clone)]
pub struct MicrobatchSchedule {
    pub stages: usize,
    pub microbatches: usize,
    /// Event list per stage in execution order.
    pub per_stage: Vec<Vec<PipeEvent>>,
}

impl MicrobatchSchedule {
    /// Build the GPipe schedule for `stages` × `microbatches`.
    pub fn gpipe(stages: usize, microbatches: usize) -> MicrobatchSchedule {
        assert!(stages > 0 && microbatches > 0);
        let mut per_stage = vec![Vec::new(); stages];
        for (s, evs) in per_stage.iter_mut().enumerate() {
            for m in 0..microbatches {
                evs.push(PipeEvent { stage: s, microbatch: m, kind: PipeEventKind::Forward });
            }
            for m in (0..microbatches).rev() {
                evs.push(PipeEvent { stage: s, microbatch: m, kind: PipeEventKind::Backward });
            }
            evs.push(PipeEvent { stage: s, microbatch: 0, kind: PipeEventKind::Update });
        }
        let sched = MicrobatchSchedule { stages, microbatches, per_stage };
        // Self-verification (debug builds / FUSIONAI_VERIFY=1): coverage,
        // dependency acyclicity and head-pointer progress.
        if crate::verify::verify_enabled() {
            let report = crate::verify::check_schedule(&sched);
            assert!(!report.has_errors(), "gpipe schedule failed verification:\n{}", report.render());
        }
        sched
    }

    /// The events `ev` depends on (cross-stage + same-stage-previous).
    ///
    /// * Forward(s, m): Forward(s−1, m);
    /// * Backward(s, m): Backward(s+1, m) — stage s+1 produces dh for s —
    ///   and Forward(s, m) (stashed input);
    /// * Update(s): every Backward(s, ·).
    pub fn deps(&self, ev: PipeEvent) -> Vec<PipeEvent> {
        let mut d = Vec::new();
        match ev.kind {
            PipeEventKind::Forward => {
                if ev.stage > 0 {
                    d.push(PipeEvent {
                        stage: ev.stage - 1,
                        microbatch: ev.microbatch,
                        kind: PipeEventKind::Forward,
                    });
                }
            }
            PipeEventKind::Backward => {
                d.push(PipeEvent {
                    stage: ev.stage,
                    microbatch: ev.microbatch,
                    kind: PipeEventKind::Forward,
                });
                if ev.stage + 1 < self.stages {
                    d.push(PipeEvent {
                        stage: ev.stage + 1,
                        microbatch: ev.microbatch,
                        kind: PipeEventKind::Backward,
                    });
                }
            }
            PipeEventKind::Update => {
                for m in 0..self.microbatches {
                    d.push(PipeEvent { stage: ev.stage, microbatch: m, kind: PipeEventKind::Backward });
                }
            }
        }
        d
    }

    /// Simulate the schedule with constant per-event durations and return the
    /// makespan (used by tests and the ablation bench to verify the Eq.-4
    /// bubble structure on the *operational* schedule, not just the analytic
    /// formula).
    pub fn simulate(&self, fwd_s: f64, bwd_s: f64, update_s: f64) -> f64 {
        use std::collections::HashMap;
        let mut finish: HashMap<(usize, usize, u8), f64> = HashMap::new();
        let key = |e: &PipeEvent| (e.stage, e.microbatch, e.kind as u8);
        // Stages execute their event lists in order; an event starts at
        // max(stage-free time, deps-finish time).
        let mut stage_free = vec![0.0f64; self.stages];
        // Iterate in a global order that respects dependencies: repeatedly
        // scan stages for runnable head events.
        let mut heads = vec![0usize; self.stages];
        let total: usize = self.per_stage.iter().map(|v| v.len()).sum();
        let mut done = 0;
        while done < total {
            let mut progressed = false;
            for s in 0..self.stages {
                while heads[s] < self.per_stage[s].len() {
                    let ev = self.per_stage[s][heads[s]];
                    let deps = self.deps(ev);
                    if !deps.iter().all(|d| finish.contains_key(&key(d))) {
                        break;
                    }
                    let ready =
                        deps.iter().map(|d| finish[&key(d)]).fold(0.0f64, f64::max);
                    let start = ready.max(stage_free[s]);
                    let dur = match ev.kind {
                        PipeEventKind::Forward => fwd_s,
                        PipeEventKind::Backward => bwd_s,
                        PipeEventKind::Update => update_s,
                    };
                    let end = start + dur;
                    finish.insert(key(&ev), end);
                    stage_free[s] = end;
                    heads[s] += 1;
                    done += 1;
                    progressed = true;
                }
            }
            assert!(progressed, "schedule deadlocked");
        }
        stage_free.iter().copied().fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn event_counts() {
        let s = MicrobatchSchedule::gpipe(4, 8);
        for evs in &s.per_stage {
            // 8 fwd + 8 bwd + 1 update
            assert_eq!(evs.len(), 17);
        }
    }

    #[test]
    fn forward_order_then_backward_reversed() {
        let s = MicrobatchSchedule::gpipe(2, 3);
        let evs = &s.per_stage[0];
        assert_eq!(evs[0].kind, PipeEventKind::Forward);
        assert_eq!(evs[0].microbatch, 0);
        assert_eq!(evs[2].microbatch, 2);
        assert_eq!(evs[3].kind, PipeEventKind::Backward);
        assert_eq!(evs[3].microbatch, 2);
        assert_eq!(evs[5].microbatch, 0);
        assert_eq!(evs[6].kind, PipeEventKind::Update);
    }

    #[test]
    fn deps_structure() {
        let s = MicrobatchSchedule::gpipe(3, 2);
        let f = PipeEvent { stage: 1, microbatch: 0, kind: PipeEventKind::Forward };
        assert_eq!(
            s.deps(f),
            vec![PipeEvent { stage: 0, microbatch: 0, kind: PipeEventKind::Forward }]
        );
        let b = PipeEvent { stage: 1, microbatch: 1, kind: PipeEventKind::Backward };
        let d = s.deps(b);
        assert!(d.contains(&PipeEvent { stage: 1, microbatch: 1, kind: PipeEventKind::Forward }));
        assert!(d.contains(&PipeEvent { stage: 2, microbatch: 1, kind: PipeEventKind::Backward }));
        // Last stage's backward needs no downstream gradient.
        let blast = PipeEvent { stage: 2, microbatch: 0, kind: PipeEventKind::Backward };
        assert_eq!(s.deps(blast).len(), 1);
    }

    #[test]
    fn simulated_makespan_matches_gpipe_formula() {
        // Classic GPipe makespan with equal fwd=bwd=1, S stages, M microbatches:
        // (M + S − 1)·(f+b) per the bubble analysis (+update).
        let (s_n, m_n) = (4usize, 8usize);
        let s = MicrobatchSchedule::gpipe(s_n, m_n);
        let t = s.simulate(1.0, 1.0, 0.0);
        let expected = (m_n as f64 + s_n as f64 - 1.0) * 2.0;
        assert!((t - expected).abs() < 1e-9, "t={t} expected={expected}");
    }

    #[test]
    fn more_microbatches_lower_bubble() {
        let s4 = MicrobatchSchedule::gpipe(4, 4).simulate(1.0, 2.0, 0.5);
        let s32 = MicrobatchSchedule::gpipe(4, 32).simulate(1.0, 2.0, 0.5);
        // Per-microbatch cost shrinks toward (fwd+bwd) = 3.
        assert!(s32 / 32.0 < s4 / 4.0);
        assert!(s32 / 32.0 < 3.5);
    }

    #[test]
    fn single_stage_degenerates_to_serial() {
        let s = MicrobatchSchedule::gpipe(1, 5);
        let t = s.simulate(1.0, 2.0, 1.0);
        assert!((t - (5.0 * 3.0 + 1.0)).abs() < 1e-9);
    }
}
