//! Minimal JSON reader/writer.
//!
//! Used for the artifact manifest (`artifacts/<preset>/manifest.json`,
//! produced by `python/compile/aot.py`), job definition interchange and
//! metrics dumps. Supports the full JSON data model; numbers are parsed as
//! f64 with an i64 fast path.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use a `BTreeMap` so output is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field access; `None` for non-objects or missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Build an object from pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    #[allow(clippy::float_cmp)] // fract() == 0.0 is the exact integrality test
    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{}", n));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string())
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document. Returns an error message with byte offset on
/// malformed input.
pub fn parse(input: &str) -> Result<Json, String> {
    let bytes = input.as_bytes();
    let mut p = Parser { b: bytes, i: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != bytes.len() {
        return Err(format!("trailing data at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).map_err(|e| e.to_string())?;
        s.parse::<f64>().map(Json::Num).map_err(|e| format!("bad number '{s}': {e}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|e| e.to_string())?;
                            let cp = u32::from_str_radix(hex, 16).map_err(|e| e.to_string())?;
                            // Surrogate pairs are not needed for our manifests;
                            // map unpaired surrogates to the replacement char.
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Advance over one UTF-8 scalar.
                    let s = std::str::from_utf8(&self.b[self.i..]).map_err(|e| e.to_string())?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "42", "-3.5", "\"hi\""] {
            let v = parse(src).unwrap();
            assert_eq!(parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "x\ny"}], "c": null}"#).unwrap();
        assert_eq!(v.get("c"), Some(&Json::Null));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_i64(), Some(1));
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("x\ny"));
    }

    #[test]
    fn roundtrip_deep() {
        let src = r#"{"m":{"shapes":[[2,3],[4]],"dtype":"f32","n":128,"ok":true}}"#;
        let v = parse(src).unwrap();
        let v2 = parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn errors_on_malformed() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("tru").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("\"abc").is_err());
    }

    #[test]
    fn escapes() {
        let v = Json::Str("a\"b\\c\nd".into());
        assert_eq!(parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn int_formatting_is_integral() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(2.5).to_string(), "2.5");
    }
}
