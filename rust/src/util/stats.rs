//! Summary statistics and least-squares fitting helpers.
//!
//! Used by the benchmark harness ([`crate::benchutil`]), the hardware
//! profiler (fitting the PALEO scaling-down factor λ and the α-β link
//! parameters, paper §3.7) and the metrics module.

/// Streaming summary of a sample: count / mean / variance (Welford) plus
/// min/max. Percentiles require the retained-sample [`Sample`] type.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Summary { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        self.mean
    }
    pub fn min(&self) -> f64 {
        self.min
    }
    pub fn max(&self) -> f64 {
        self.max
    }
    /// Sample variance (n-1 denominator).
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }
    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
}

/// A retained sample supporting percentiles.
#[derive(Debug, Clone, Default)]
pub struct Sample {
    xs: Vec<f64>,
}

impl Sample {
    pub fn new() -> Self {
        Sample { xs: Vec::new() }
    }
    pub fn add(&mut self, x: f64) {
        self.xs.push(x);
    }
    pub fn len(&self) -> usize {
        self.xs.len()
    }
    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }
    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() {
            return 0.0;
        }
        self.xs.iter().sum::<f64>() / self.xs.len() as f64
    }
    /// Percentile by linear interpolation; `q` in `[0, 1]`.
    pub fn percentile(&self, q: f64) -> f64 {
        if self.xs.is_empty() {
            return 0.0;
        }
        let mut s = self.xs.clone();
        s.sort_by(|a, b| a.total_cmp(b));
        let pos = q.clamp(0.0, 1.0) * (s.len() - 1) as f64;
        let lo = pos.floor() as usize;
        let hi = pos.ceil() as usize;
        if lo == hi {
            s[lo]
        } else {
            s[lo] + (pos - lo as f64) * (s[hi] - s[lo])
        }
    }
    pub fn median(&self) -> f64 {
        self.percentile(0.5)
    }
    pub fn p99(&self) -> f64 {
        self.percentile(0.99)
    }
    pub fn min(&self) -> f64 {
        self.xs.iter().copied().fold(f64::INFINITY, f64::min)
    }
    pub fn max(&self) -> f64 {
        self.xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }
}

/// Ordinary least squares for `y ≈ a + b·x`. Returns `(a, b)`.
///
/// This is exactly the α-β model fit the paper uses for links
/// (`T_comm = α + β·M`, §3.3) and the λ scaling-factor regression (§3.7,
/// with x = predicted peak-speed time, intercept pinned by the caller if
/// needed).
#[allow(clippy::float_cmp)] // sxx == 0.0 iff all xs identical: degenerate fit, exact test
pub fn linfit(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let mut sxx = 0.0;
    let mut sxy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        sxx += (x - mx) * (x - mx);
        sxy += (x - mx) * (y - my);
    }
    if sxx == 0.0 {
        return (my, 0.0);
    }
    let b = sxy / sxx;
    (my - b * mx, b)
}

/// Least squares through the origin: `y ≈ b·x`. Returns `b`.
/// Used for the λ fit where S(p) = λ·S*(p) has no intercept.
#[allow(clippy::float_cmp)] // sxx == 0.0: exact degenerate-input test
pub fn linfit_origin(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| x * y).sum();
    let sxx: f64 = xs.iter().map(|x| x * x).sum();
    if sxx == 0.0 {
        0.0
    } else {
        sxy / sxx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_matches_naive() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut s = Summary::new();
        for &x in &xs {
            s.add(x);
        }
        assert_eq!(s.count(), 5);
        assert!((s.mean() - 4.0).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 10.0);
        let naive_var = xs.iter().map(|x| (x - 4.0) * (x - 4.0)).sum::<f64>() / 4.0;
        assert!((s.var() - naive_var).abs() < 1e-12);
    }

    #[test]
    fn percentiles() {
        let mut s = Sample::new();
        for i in 1..=100 {
            s.add(i as f64);
        }
        assert!((s.median() - 50.5).abs() < 1e-9);
        assert!((s.percentile(0.0) - 1.0).abs() < 1e-9);
        assert!((s.percentile(1.0) - 100.0).abs() < 1e-9);
        assert!(s.p99() > 98.0);
    }

    #[test]
    fn linfit_recovers_line() {
        let xs: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 + 0.5 * x).collect();
        let (a, b) = linfit(&xs, &ys);
        assert!((a - 3.0).abs() < 1e-9);
        assert!((b - 0.5).abs() < 1e-9);
    }

    #[test]
    fn linfit_origin_recovers_slope() {
        let xs = [1.0, 2.0, 4.0];
        let ys = [0.8, 1.6, 3.2];
        let b = linfit_origin(&xs, &ys);
        assert!((b - 0.8).abs() < 1e-9);
    }

    #[test]
    fn linfit_degenerate() {
        let (a, b) = linfit(&[2.0, 2.0], &[5.0, 7.0]);
        assert_eq!(b, 0.0);
        assert_eq!(a, 6.0);
    }
}
