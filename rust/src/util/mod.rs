//! Small self-contained utilities: deterministic PRNG, statistics helpers,
//! human-readable formatting, and a minimal JSON implementation.
//!
//! The build environment is offline and the vendored crate set does not
//! include `rand`, `serde` or `serde_json`, so these are implemented in-tree.

pub mod json;
pub mod stats;

/// SplitMix64 — tiny, fast, high-quality 64-bit PRNG.
///
/// Used everywhere determinism matters: synthetic data generation, fault
/// injection schedules, property-test case generation and scheduler
/// tie-breaking. Same seed → same sequence on every platform.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Create a generator from a seed. Any seed (including 0) is fine.
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E3779B97F4A7C15) }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, 1)`.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in `[0, n)`. `n` must be > 0.
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection-free approximation is fine here;
        // modulo bias is irrelevant for our use cases but we use widening
        // multiply anyway for uniformity.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform integer in `[lo, hi)`.
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(hi > lo);
        lo + self.below((hi - lo) as u64) as i64
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.next_f64() * (hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-12);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Bernoulli trial with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element (panics on empty slice).
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

/// FNV-1a 64-bit hash — stable across runs (unlike `DefaultHasher` which is
/// randomly seeded per process). Used for DHT keys and content addressing.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Format a byte count as a human-readable string (`1.5 GiB`).
pub fn human_bytes(n: u64) -> String {
    const UNITS: [&str; 6] = ["B", "KiB", "MiB", "GiB", "TiB", "PiB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{} {}", n, UNITS[0])
    } else {
        format!("{:.2} {}", v, UNITS[u])
    }
}

/// Format a FLOP count (`1.23 TFLOPs`).
pub fn human_flops(n: f64) -> String {
    const UNITS: [&str; 6] = ["FLOPs", "KFLOPs", "MFLOPs", "GFLOPs", "TFLOPs", "PFLOPs"];
    let mut v = n;
    let mut u = 0;
    while v >= 1000.0 && u < UNITS.len() - 1 {
        v /= 1000.0;
        u += 1;
    }
    format!("{:.2} {}", v, UNITS[u])
}

/// Format seconds adaptively (`13.2 ms`, `4.71 s`, `2.1 min`).
pub fn human_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else if s < 120.0 {
        format!("{:.2} s", s)
    } else {
        format!("{:.1} min", s / 60.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_is_deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn rng_uniform_bounds() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
            let y = r.range(-5, 5);
            assert!((-5..5).contains(&y));
        }
    }

    #[test]
    fn rng_below_covers_all_residues() {
        let mut r = Rng::new(3);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[r.below(7) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn rng_normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<u32>>());
    }

    #[test]
    fn fnv_stable() {
        assert_eq!(fnv1a(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a(b"a"), fnv1a(b"a"));
        assert_ne!(fnv1a(b"a"), fnv1a(b"b"));
    }

    #[test]
    fn human_formats() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(1536), "1.50 KiB");
        assert!(human_flops(2.5e12).contains("TFLOPs"));
        assert!(human_secs(0.0021).contains("ms"));
    }
}
