//! The broker (paper §3.2): bridges job submitters and compnodes.
//!
//! Responsibilities, exactly as the paper lists them:
//! * register computing providers as compnodes with unique IDs;
//! * periodically ping-pong compnodes to detect offline peers;
//! * keep a **backup pool** of registered-but-idle compnodes and promote a
//!   replacement when an active compnode with unfinished tasks goes offline;
//! * decompose submitted jobs into sub-tasks (via [`crate::decompose`]) and
//!   schedule them onto compnodes with balanced workloads (via
//!   [`crate::sched`], using the §3.7 hardware performance predictor).
//!
//! The broker is a deterministic state machine over a caller-supplied clock
//! (virtual seconds), so every interleaving is testable; the live cluster
//! drives it from real time.

use std::collections::HashMap;

use anyhow::{anyhow, bail, Result};

use crate::dag::Graph;
use crate::decompose::Decomposition;
use crate::perf::gpus::GpuSpec;
use crate::perf::paleo::DeviceProfile;
use crate::sched::{self, PeerSpec, Schedule, TaskSpec};

/// Compnode classification (paper §3.3): supernodes are stable long-term
/// providers; antnodes come and go.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodeClass {
    Supernode,
    Antnode,
}

/// Liveness/duty state of a registered compnode.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodeState {
    /// Executing assigned tasks.
    Active,
    /// Registered, healthy, held in the backup pool.
    Backup,
    /// Missed heartbeats; presumed gone.
    Offline,
}

/// Registration record for one compnode.
#[derive(Debug, Clone)]
pub struct CompnodeInfo {
    pub id: usize,
    pub gpu: GpuSpec,
    /// Fitted scaling-down factor λ_p (paper §3.7).
    pub lambda: f64,
    pub class: NodeClass,
}

impl CompnodeInfo {
    /// Convert to a scheduler peer spec.
    pub fn peer_spec(&self) -> PeerSpec {
        PeerSpec {
            id: self.id,
            profile: DeviceProfile::with_lambda(&self.gpu, self.lambda),
            gpu_capacity: self.gpu.memory_bytes(),
            cpu_capacity: 2 * self.gpu.memory_bytes(),
            disk_capacity: 64 * self.gpu.memory_bytes(),
        }
    }
}

/// Broker event log entry (observability + test assertions).
#[derive(Debug, Clone, PartialEq)]
pub enum Event {
    Registered { node: usize, backup: bool },
    Offline { node: usize },
    Promoted { backup: usize, replacing: usize },
    JobSubmitted { job: usize, subtasks: usize },
    Rescheduled { job: usize, from: usize, moved: usize },
}

/// A scheduled job: the decomposition plus the current assignment.
#[derive(Debug)]
pub struct Job {
    pub id: usize,
    pub graph: Graph,
    pub decomposition: Decomposition,
    pub tasks: Vec<TaskSpec>,
    /// Peer ids (broker node ids) in scheduler order.
    pub peer_ids: Vec<usize>,
    pub schedule: Schedule,
}

impl Job {
    /// Which broker node runs sub-task `k`.
    pub fn node_of_task(&self, k: usize) -> usize {
        self.peer_ids[self.schedule.of_task[k]]
    }
}

/// The broker state machine.
pub struct Broker {
    next_id: usize,
    next_job: usize,
    nodes: HashMap<usize, (CompnodeInfo, NodeState)>,
    last_seen: HashMap<usize, f64>,
    /// Seconds without a heartbeat before a node is declared offline.
    pub heartbeat_timeout: f64,
    pub events: Vec<Event>,
    jobs: HashMap<usize, Job>,
}

impl Broker {
    pub fn new(heartbeat_timeout: f64) -> Broker {
        Broker {
            next_id: 0,
            next_job: 0,
            nodes: HashMap::new(),
            last_seen: HashMap::new(),
            heartbeat_timeout,
            events: Vec::new(),
            jobs: HashMap::new(),
        }
    }

    /// Register a provider; returns its unique compnode id.
    pub fn register(&mut self, gpu: &GpuSpec, lambda: f64, class: NodeClass, now: f64, backup: bool) -> usize {
        let id = self.next_id;
        self.next_id += 1;
        let info = CompnodeInfo { id, gpu: gpu.clone(), lambda, class };
        let state = if backup { NodeState::Backup } else { NodeState::Active };
        self.nodes.insert(id, (info, state));
        self.last_seen.insert(id, now);
        self.events.push(Event::Registered { node: id, backup });
        id
    }

    /// Record a ping-pong response.
    pub fn heartbeat(&mut self, node: usize, now: f64) -> Result<()> {
        if !self.nodes.contains_key(&node) {
            bail!("heartbeat from unknown node {node}");
        }
        self.last_seen.insert(node, now);
        Ok(())
    }

    /// Sweep for nodes that missed the timeout; marks them offline and
    /// returns the newly offline ids.
    pub fn check_liveness(&mut self, now: f64) -> Vec<usize> {
        let mut dead = Vec::new();
        for (&id, (_, state)) in self.nodes.iter_mut() {
            if *state == NodeState::Offline {
                continue;
            }
            let seen = self.last_seen.get(&id).copied().unwrap_or(f64::NEG_INFINITY);
            if now - seen > self.heartbeat_timeout {
                *state = NodeState::Offline;
                dead.push(id);
            }
        }
        dead.sort();
        for &id in &dead {
            self.events.push(Event::Offline { node: id });
        }
        dead
    }

    /// Voluntary departure (graceful quit).
    pub fn deregister(&mut self, node: usize) {
        if let Some((_, state)) = self.nodes.get_mut(&node) {
            *state = NodeState::Offline;
            self.events.push(Event::Offline { node });
        }
    }

    pub fn state(&self, node: usize) -> Option<NodeState> {
        self.nodes.get(&node).map(|(_, s)| *s)
    }

    pub fn info(&self, node: usize) -> Option<&CompnodeInfo> {
        self.nodes.get(&node).map(|(i, _)| i)
    }

    /// Currently active node ids (sorted for determinism).
    pub fn active_nodes(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .nodes
            .iter()
            .filter(|(_, (_, s))| *s == NodeState::Active)
            .map(|(&id, _)| id)
            .collect();
        v.sort();
        v
    }

    /// Backup pool (sorted).
    pub fn backup_pool(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .nodes
            .iter()
            .filter(|(_, (_, s))| *s == NodeState::Backup)
            .map(|(&id, _)| id)
            .collect();
        v.sort();
        v
    }

    /// Promote a backup to replace `failed`. Prefers supernodes, then the
    /// fastest device (best achieved FLOPS).
    pub fn promote_backup(&mut self, failed: usize) -> Option<usize> {
        let pick = self
            .nodes
            .iter()
            .filter(|(_, (_, s))| *s == NodeState::Backup)
            .max_by(|(_, (a, _)), (_, (b, _))| {
                let ka = (a.class == NodeClass::Supernode, a.lambda * a.gpu.peak_tensor_flops());
                let kb = (b.class == NodeClass::Supernode, b.lambda * b.gpu.peak_tensor_flops());
                ka.0.cmp(&kb.0).then(ka.1.total_cmp(&kb.1))
            })
            .map(|(&id, _)| id)?;
        self.nodes.get_mut(&pick).unwrap().1 = NodeState::Active;
        self.events.push(Event::Promoted { backup: pick, replacing: failed });
        Some(pick)
    }

    /// Submit a job: decompose `graph` into `n_subtasks` balanced sub-DAGs
    /// and schedule them over the active nodes (paper §3.8). Returns the job
    /// id.
    pub fn submit_job(&mut self, graph: Graph, n_subtasks: usize, training: bool) -> Result<usize> {
        let peers_ids = self.active_nodes();
        if peers_ids.is_empty() {
            bail!("no active compnodes");
        }
        let d = Decomposition::chain_balanced(&graph, n_subtasks);
        let tasks = sched::build::tasks_from_decomposition(&graph, &d, training);
        let peers: Vec<PeerSpec> =
            peers_ids.iter().map(|&id| self.nodes[&id].0.peer_spec()).collect();
        let schedule = sched::schedule(&tasks, &peers)
            .map_err(|e| anyhow!("scheduling failed: {e}"))?;
        let id = self.next_job;
        self.next_job += 1;
        self.events.push(Event::JobSubmitted { job: id, subtasks: tasks.len() });
        self.jobs.insert(
            id,
            Job { id, graph, decomposition: d, tasks, peer_ids: peers_ids, schedule },
        );
        Ok(id)
    }

    pub fn job(&self, id: usize) -> Option<&Job> {
        self.jobs.get(&id)
    }

    /// Handle a node failure for a job: promote a backup (if any) and move
    /// the failed node's sub-tasks (paper §3.2). Every *offline* peer of the
    /// job is treated as zero-capacity so rescheduling can never place work
    /// on a node the broker already knows is gone. Returns the moved task
    /// ids.
    pub fn handle_failure(&mut self, job_id: usize, failed: usize) -> Result<Vec<usize>> {
        let replacement = self.promote_backup(failed);
        // Snapshot liveness before borrowing the job mutably.
        let offline: Vec<usize> = self
            .nodes
            .iter()
            .filter(|(_, (_, s))| *s == NodeState::Offline)
            .map(|(&id, _)| id)
            .collect();
        let job = self.jobs.get_mut(&job_id).ok_or_else(|| anyhow!("unknown job {job_id}"))?;
        // Extend the peer set if a fresh backup joined the job.
        if let Some(r) = replacement {
            if !job.peer_ids.contains(&r) {
                job.peer_ids.push(r);
                job.schedule.loads.push(0.0);
                job.schedule.gpu_used.push(0);
                job.schedule.cpu_used.push(0);
                job.schedule.disk_used.push(0);
            }
        }
        let mut peers: Vec<PeerSpec> = Vec::new();
        let mut repl_idx = None;
        for (i, &id) in job.peer_ids.iter().enumerate() {
            let mut spec = self.nodes[&id].0.peer_spec();
            if offline.contains(&id) {
                spec.gpu_capacity = 0;
                spec.cpu_capacity = 0;
                spec.disk_capacity = 0;
            }
            peers.push(spec);
            if Some(id) == replacement {
                repl_idx = Some(i);
            }
        }
        // Evacuate every offline carrier, starting with `failed`.
        let mut all_moved = Vec::new();
        let mut victims: Vec<usize> = vec![failed];
        for &id in &offline {
            if id != failed && job.peer_ids.contains(&id) {
                victims.push(id);
            }
        }
        for victim in victims {
            let idx = job
                .peer_ids
                .iter()
                .position(|&id| id == victim)
                .ok_or_else(|| anyhow!("node {victim} not part of job {job_id}"))?;
            let carries = job.schedule.of_task.iter().any(|&p| p == idx);
            if !carries && victim != failed {
                continue;
            }
            let moved =
                sched::reschedule_failure(&mut job.schedule, &job.tasks, &peers, idx, repl_idx)
                    .map_err(|e| anyhow!("rescheduling failed: {e}"))?;
            all_moved.extend(moved);
        }
        self.events
            .push(Event::Rescheduled { job: job_id, from: failed, moved: all_moved.len() });
        Ok(all_moved)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::transformer::TransformerConfig;
    use crate::perf::gpus::lookup;

    fn broker_with(n_active: usize, n_backup: usize) -> Broker {
        let mut b = Broker::new(5.0);
        let gpu = lookup("RTX 3080").unwrap();
        for _ in 0..n_active {
            b.register(gpu, 0.5, NodeClass::Antnode, 0.0, false);
        }
        for _ in 0..n_backup {
            b.register(gpu, 0.5, NodeClass::Antnode, 0.0, true);
        }
        b
    }

    #[test]
    fn register_assigns_unique_ids() {
        let b = broker_with(3, 2);
        assert_eq!(b.active_nodes(), vec![0, 1, 2]);
        assert_eq!(b.backup_pool(), vec![3, 4]);
    }

    #[test]
    fn heartbeat_timeout_marks_offline() {
        let mut b = broker_with(2, 0);
        b.heartbeat(0, 4.0).unwrap();
        // node 1 last seen at 0.0, timeout 5.0 ⇒ dead at t=6.
        let dead = b.check_liveness(6.0);
        assert_eq!(dead, vec![1]);
        assert_eq!(b.state(1), Some(NodeState::Offline));
        assert_eq!(b.state(0), Some(NodeState::Active));
        // Idempotent: no double-report.
        assert!(b.check_liveness(7.0).is_empty());
    }

    #[test]
    fn unknown_heartbeat_rejected() {
        let mut b = broker_with(1, 0);
        assert!(b.heartbeat(99, 0.0).is_err());
    }

    #[test]
    fn promote_prefers_supernode() {
        let mut b = Broker::new(5.0);
        let g3080 = lookup("RTX 3080").unwrap();
        let h100 = lookup("H100").unwrap();
        b.register(g3080, 0.5, NodeClass::Antnode, 0.0, false); // 0 active
        let ant = b.register(h100, 0.9, NodeClass::Antnode, 0.0, true); // fast antnode
        let sup = b.register(g3080, 0.5, NodeClass::Supernode, 0.0, true); // slow supernode
        let picked = b.promote_backup(0).unwrap();
        assert_eq!(picked, sup, "supernode wins over faster antnode");
        assert_eq!(b.state(sup), Some(NodeState::Active));
        // Next promotion takes the remaining antnode.
        assert_eq!(b.promote_backup(0), Some(ant));
        // Pool exhausted.
        assert_eq!(b.promote_backup(0), None);
    }

    #[test]
    fn submit_job_schedules_all_subtasks() {
        let mut b = broker_with(4, 0);
        let g = TransformerConfig::tiny().build_graph();
        let job_id = b.submit_job(g, 8, true).unwrap();
        let job = b.job(job_id).unwrap();
        assert_eq!(job.tasks.len(), 8);
        job.schedule
            .validate(&job.tasks, &job.peer_ids.iter().map(|&id| b.info(id).unwrap().peer_spec()).collect::<Vec<_>>())
            .unwrap();
        // Every task maps to a real node id.
        for k in 0..8 {
            assert!(job.peer_ids.contains(&job.node_of_task(k)));
        }
    }

    #[test]
    fn failure_promotes_backup_and_moves_tasks() {
        let mut b = broker_with(3, 1);
        let g = TransformerConfig::tiny().build_graph();
        let job_id = b.submit_job(g, 6, true).unwrap();
        let victim = b.job(job_id).unwrap().node_of_task(0);
        b.deregister(victim);
        let moved = b.handle_failure(job_id, victim).unwrap();
        assert!(!moved.is_empty());
        let job = b.job(job_id).unwrap();
        for k in 0..6 {
            assert_ne!(job.node_of_task(k), victim, "task {k} still on failed node");
        }
        // Backup got activated.
        assert!(b.backup_pool().is_empty());
        assert!(b.events.iter().any(|e| matches!(e, Event::Promoted { .. })));
    }

    #[test]
    fn failure_without_backup_redistributes() {
        let mut b = broker_with(3, 0);
        let g = TransformerConfig::tiny().build_graph();
        let job_id = b.submit_job(g, 6, false).unwrap();
        let victim = b.job(job_id).unwrap().node_of_task(0);
        let moved = b.handle_failure(job_id, victim).unwrap();
        assert!(!moved.is_empty());
        let job = b.job(job_id).unwrap();
        for k in 0..6 {
            assert_ne!(job.node_of_task(k), victim);
        }
    }

    #[test]
    fn submit_without_nodes_fails() {
        let mut b = Broker::new(5.0);
        let g = TransformerConfig::tiny().build_graph();
        assert!(b.submit_job(g, 2, false).is_err());
    }

    #[test]
    fn event_log_records_lifecycle() {
        let mut b = broker_with(1, 1);
        let g = TransformerConfig::tiny().build_graph();
        let j = b.submit_job(g, 2, false).unwrap();
        b.deregister(0);
        b.handle_failure(j, 0).unwrap();
        let kinds: Vec<&str> = b
            .events
            .iter()
            .map(|e| match e {
                Event::Registered { .. } => "reg",
                Event::Offline { .. } => "off",
                Event::Promoted { .. } => "promo",
                Event::JobSubmitted { .. } => "job",
                Event::Rescheduled { .. } => "resched",
            })
            .collect();
        assert_eq!(kinds, vec!["reg", "reg", "job", "off", "promo", "resched"]);
    }
}
