//! Incentive mechanism (paper §2.5): a contribution ledger.
//!
//! The paper argues decentralized training needs economic catalysts robust
//! to (1) online arrival/departure, (2) competing uses of the hardware and
//! (3) malicious free-riders. We implement the accounting substrate those
//! mechanisms need: per-node contribution records (compute + traffic +
//! storage), credit pricing, and a verification hook that discounts
//! unverified work — the "contribute nothing but endeavor to get large
//! paybacks" defense.

use std::collections::BTreeMap;

/// One node's accumulated (verified and claimed) contributions.
#[derive(Debug, Default, Clone)]
pub struct Contribution {
    /// FLOPs of task work whose outputs passed verification.
    pub verified_flops: f64,
    /// FLOPs claimed but not (yet) verified.
    pub unverified_flops: f64,
    /// Bytes served over the network (activations, DHT traffic).
    pub bytes_served: u64,
    /// Byte-seconds of DHT storage provided.
    pub storage_byte_secs: f64,
    /// Seconds of liveness (heartbeats honored).
    pub uptime_secs: f64,
}

/// Credit pricing: how contributions convert to credits.
#[derive(Debug, Clone)]
pub struct Pricing {
    /// Credits per verified TFLOP.
    pub per_tflop: f64,
    /// Credits per GiB served.
    pub per_gib: f64,
    /// Credits per GiB·hour stored.
    pub per_gib_hour: f64,
    /// Credits per hour of uptime (availability reward for supernodes).
    pub per_uptime_hour: f64,
    /// Fraction of the verified rate paid for *unverified* work. Keeping
    /// this well below 1 removes the incentive to fabricate results.
    pub unverified_discount: f64,
}

impl Default for Pricing {
    fn default() -> Pricing {
        Pricing {
            per_tflop: 1.0,
            per_gib: 0.05,
            per_gib_hour: 0.01,
            per_uptime_hour: 0.1,
            unverified_discount: 0.1,
        }
    }
}

/// The ledger: contribution records + settled credit balances.
#[derive(Debug, Default)]
pub struct Ledger {
    contrib: BTreeMap<usize, Contribution>,
    balance: BTreeMap<usize, f64>,
}

impl Ledger {
    pub fn new() -> Ledger {
        Ledger::default()
    }

    fn entry(&mut self, node: usize) -> &mut Contribution {
        self.contrib.entry(node).or_default()
    }

    /// Record task work. `verified` marks whether an independent check
    /// (e.g. recompute-on-supernode spot check) confirmed the output.
    pub fn record_compute(&mut self, node: usize, flops: f64, verified: bool) {
        let c = self.entry(node);
        if verified {
            c.verified_flops += flops;
        } else {
            c.unverified_flops += flops;
        }
    }

    /// Promote previously unverified work after a successful audit.
    pub fn verify(&mut self, node: usize, flops: f64) {
        let c = self.entry(node);
        let moved = flops.min(c.unverified_flops);
        c.unverified_flops -= moved;
        c.verified_flops += moved;
    }

    pub fn record_traffic(&mut self, node: usize, bytes: u64) {
        self.entry(node).bytes_served += bytes;
    }

    pub fn record_storage(&mut self, node: usize, bytes: u64, secs: f64) {
        self.entry(node).storage_byte_secs += bytes as f64 * secs;
    }

    pub fn record_uptime(&mut self, node: usize, secs: f64) {
        self.entry(node).uptime_secs += secs;
    }

    pub fn contribution(&self, node: usize) -> Option<&Contribution> {
        self.contrib.get(&node)
    }

    /// Settle all pending contributions into credit balances and reset the
    /// contribution accumulators (one billing period).
    pub fn settle(&mut self, pricing: &Pricing) {
        const GIB: f64 = 1024.0 * 1024.0 * 1024.0;
        for (&node, c) in self.contrib.iter_mut() {
            let credits = c.verified_flops / 1e12 * pricing.per_tflop
                + c.unverified_flops / 1e12 * pricing.per_tflop * pricing.unverified_discount
                + c.bytes_served as f64 / GIB * pricing.per_gib
                + c.storage_byte_secs / GIB / 3600.0 * pricing.per_gib_hour
                + c.uptime_secs / 3600.0 * pricing.per_uptime_hour;
            *self.balance.entry(node).or_insert(0.0) += credits;
            *c = Contribution::default();
        }
    }

    pub fn balance(&self, node: usize) -> f64 {
        self.balance.get(&node).copied().unwrap_or(0.0)
    }

    /// Nodes whose claimed work is mostly unverified — audit candidates.
    pub fn suspicious(&self, min_claimed_tflops: f64) -> Vec<usize> {
        self.contrib
            .iter()
            .filter(|(_, c)| {
                let total = c.verified_flops + c.unverified_flops;
                total / 1e12 >= min_claimed_tflops
                    && c.unverified_flops > 0.8 * total.max(f64::EPSILON)
            })
            .map(|(&n, _)| n)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn verified_work_pays_full_rate() {
        let mut l = Ledger::new();
        l.record_compute(1, 5e12, true);
        l.settle(&Pricing::default());
        assert!((l.balance(1) - 5.0).abs() < 1e-9);
    }

    #[test]
    fn unverified_work_is_discounted() {
        let mut l = Ledger::new();
        l.record_compute(1, 5e12, true);
        l.record_compute(2, 5e12, false);
        l.settle(&Pricing::default());
        assert!(l.balance(2) < 0.2 * l.balance(1));
    }

    #[test]
    fn audit_promotes_unverified() {
        let mut l = Ledger::new();
        l.record_compute(3, 10e12, false);
        l.verify(3, 10e12);
        l.settle(&Pricing::default());
        assert!((l.balance(3) - 10.0).abs() < 1e-9);
    }

    #[test]
    fn traffic_storage_uptime_accrue() {
        let mut l = Ledger::new();
        const GIB: u64 = 1 << 30;
        l.record_traffic(4, 20 * GIB);
        l.record_storage(4, 10 * GIB, 7200.0);
        l.record_uptime(4, 3600.0);
        l.settle(&Pricing::default());
        let expect = 20.0 * 0.05 + 10.0 * 2.0 * 0.01 + 0.1;
        assert!((l.balance(4) - expect).abs() < 1e-9, "{}", l.balance(4));
    }

    #[test]
    fn settle_resets_period() {
        let mut l = Ledger::new();
        l.record_compute(1, 1e12, true);
        l.settle(&Pricing::default());
        l.settle(&Pricing::default());
        assert!((l.balance(1) - 1.0).abs() < 1e-9, "no double billing");
    }

    #[test]
    fn suspicious_flags_freeriders() {
        let mut l = Ledger::new();
        l.record_compute(1, 9e12, false); // 100% unverified
        l.record_compute(2, 9e12, true); // honest
        l.record_compute(3, 0.1e12, false); // too small to matter
        assert_eq!(l.suspicious(1.0), vec![1]);
    }
}
