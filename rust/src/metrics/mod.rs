//! Metrics: counters, gauges, loss curves and step timing.
//!
//! The coordinator emits everything the experiment reports need — the
//! examples dump these to stdout/CSV and `EXPERIMENTS.md` quotes them.

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Instant;

use crate::util::stats::Sample;

/// Thread-safe registry of named counters/gauges/samples.
#[derive(Default)]
pub struct Metrics {
    counters: Mutex<BTreeMap<String, u64>>,
    gauges: Mutex<BTreeMap<String, f64>>,
    samples: Mutex<BTreeMap<String, Sample>>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn inc(&self, name: &str, by: u64) {
        *self.counters.lock().unwrap().entry(name.to_string()).or_insert(0) += by;
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.lock().unwrap().get(name).copied().unwrap_or(0)
    }

    pub fn set_gauge(&self, name: &str, v: f64) {
        self.gauges.lock().unwrap().insert(name.to_string(), v);
    }

    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.lock().unwrap().get(name).copied()
    }

    /// Keep the gauge at the maximum of its current and `v` (high-water
    /// marks such as peak resident bytes).
    pub fn set_max_gauge(&self, name: &str, v: f64) {
        let mut gauges = self.gauges.lock().unwrap();
        let e = gauges.entry(name.to_string()).or_insert(v);
        if v > *e {
            *e = v;
        }
    }

    pub fn observe(&self, name: &str, v: f64) {
        self.samples.lock().unwrap().entry(name.to_string()).or_default().add(v);
    }

    pub fn sample(&self, name: &str) -> Option<Sample> {
        self.samples.lock().unwrap().get(name).cloned()
    }

    /// Render everything as a sorted human-readable report.
    pub fn report(&self) -> String {
        let mut out = String::new();
        for (k, v) in self.counters.lock().unwrap().iter() {
            out.push_str(&format!("counter {k} = {v}\n"));
        }
        for (k, v) in self.gauges.lock().unwrap().iter() {
            out.push_str(&format!("gauge   {k} = {v:.6}\n"));
        }
        for (k, s) in self.samples.lock().unwrap().iter() {
            out.push_str(&format!(
                "sample  {k}: n={} mean={:.6} p50={:.6} p99={:.6}\n",
                s.len(),
                s.mean(),
                s.median(),
                s.p99()
            ));
        }
        out
    }
}

/// Loss-curve recorder with CSV export (the e2e driver's main artifact).
#[derive(Debug, Default, Clone)]
pub struct LossCurve {
    points: Vec<(usize, f32)>,
}

impl LossCurve {
    pub fn new() -> LossCurve {
        LossCurve::default()
    }
    pub fn record(&mut self, step: usize, loss: f32) {
        self.points.push((step, loss));
    }
    pub fn len(&self) -> usize {
        self.points.len()
    }
    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }
    pub fn last(&self) -> Option<(usize, f32)> {
        self.points.last().copied()
    }
    pub fn first(&self) -> Option<(usize, f32)> {
        self.points.first().copied()
    }

    /// Mean loss over the last `k` points (smoothing).
    pub fn tail_mean(&self, k: usize) -> f32 {
        if self.points.is_empty() {
            return f32::NAN;
        }
        let tail = &self.points[self.points.len().saturating_sub(k)..];
        tail.iter().map(|&(_, l)| l).sum::<f32>() / tail.len() as f32
    }

    pub fn to_csv(&self) -> String {
        let mut s = String::from("step,loss\n");
        for (step, loss) in &self.points {
            s.push_str(&format!("{step},{loss}\n"));
        }
        s
    }

    pub fn save_csv(&self, path: &std::path::Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_csv())
    }
}

/// Scoped wall-clock timer feeding a [`Metrics`] sample.
pub struct Timer<'a> {
    metrics: &'a Metrics,
    name: &'a str,
    start: Instant,
}

impl<'a> Timer<'a> {
    pub fn start(metrics: &'a Metrics, name: &'a str) -> Timer<'a> {
        Timer { metrics, name, start: Instant::now() }
    }
}

impl Drop for Timer<'_> {
    fn drop(&mut self) {
        self.metrics.observe(self.name, self.start.elapsed().as_secs_f64());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges() {
        let m = Metrics::new();
        m.inc("msgs", 3);
        m.inc("msgs", 2);
        m.set_gauge("loss", 1.5);
        assert_eq!(m.counter("msgs"), 5);
        assert_eq!(m.counter("other"), 0);
        assert_eq!(m.gauge("loss"), Some(1.5));
    }

    #[test]
    fn max_gauge_is_a_high_water_mark() {
        let m = Metrics::new();
        m.set_max_gauge("peak", 10.0);
        m.set_max_gauge("peak", 4.0);
        assert_eq!(m.gauge("peak"), Some(10.0));
        m.set_max_gauge("peak", 12.5);
        assert_eq!(m.gauge("peak"), Some(12.5));
    }

    #[test]
    fn samples_and_report() {
        let m = Metrics::new();
        for i in 1..=100 {
            m.observe("lat", i as f64);
        }
        let s = m.sample("lat").unwrap();
        assert_eq!(s.len(), 100);
        let rep = m.report();
        assert!(rep.contains("sample  lat"));
    }

    #[test]
    fn timer_records() {
        let m = Metrics::new();
        {
            let _t = Timer::start(&m, "op");
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        let s = m.sample("op").unwrap();
        assert_eq!(s.len(), 1);
        assert!(s.mean() >= 0.004);
    }

    #[test]
    fn loss_curve_csv() {
        let mut c = LossCurve::new();
        c.record(0, 5.0);
        c.record(10, 3.0);
        c.record(20, 2.0);
        assert_eq!(c.first(), Some((0, 5.0)));
        assert_eq!(c.last(), Some((20, 2.0)));
        assert!((c.tail_mean(2) - 2.5).abs() < 1e-6);
        let csv = c.to_csv();
        assert!(csv.starts_with("step,loss\n"));
        assert_eq!(csv.lines().count(), 4);
    }
}
