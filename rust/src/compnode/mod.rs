//! Compnode task executor (paper §3.3, §3.6).
//!
//! "We employ a task executor to manage the message passing between OPs and
//! perform the computations of the OPs with their inputs."
//!
//! A [`SubDagExecutor`] owns one compnode's share of a decomposed graph. At
//! construction it **compiles** that share into a cached
//! [`ExecPlan`](crate::exec::ExecPlan) — topological waves of mutually
//! independent nodes plus liveness refcounts — and every step then just
//! replays the plan:
//!
//! * **FP** walks the forward waves. A wave whose engine is registry-backed
//!   and whose FLOPs clear the threshold fans out across worker threads
//!   (bitwise identical to serial — see `exec::executor`). As soon as an
//!   activation's last in-set consumer has run, its buffer is returned to
//!   the scratch pool unless the plan keeps it (loss, sink, backward stash,
//!   or messaged to another compnode).
//! * **BP** walks the backward waves. Upstream-gradient contributions are
//!   collected as keyed parts and folded in backward-plan position order,
//!   so accumulation order — and therefore every bit of every gradient —
//!   never depends on wave width or message timing. Forward stashes are
//!   freed the moment their last consumer grad fires.
//! * **Update** applies the optimizer, unchanged.
//!
//! The executor tracks resident activation/gradient bytes and their peak, so
//! the memory effect of liveness-driven freeing is observable (and can be
//! compared against the keep-everything baseline via
//! [`SubDagExecutor::set_liveness_freeing`]).
//!
//! Data that must cross compnodes is returned as outbound messages — the
//! cluster layer (or a test) moves them and feeds the receiving executor,
//! exactly the send-side/receive-side split of §3.6 "Message passing".

use std::collections::{BTreeSet, HashMap};

use anyhow::{anyhow, bail, Result};

use crate::dag::autodiff::{backward_plan, BackwardPlan};
use crate::dag::{Graph, NodeId, OpCategory};
use crate::decompose::Decomposition;
use crate::exec::{
    wave_threads, BwdJob, Engine, ExecPlan, Optimizer, WaveRunner, WAVE_PAR_MIN_FLOPS,
};
use crate::tensor::Tensor;
use crate::util::Rng;

/// Keys ≥ this mark locally produced gradient parts; below it, remote parts
/// in arrival order. Sorting parts by key reproduces the serial sweep's
/// accumulation order (remote grads land before `run_bp`, local ones in
/// backward-plan position order).
const LOCAL_BASE: u32 = 1 << 24;

/// An outbound activation or gradient message.
#[derive(Debug, Clone)]
pub struct OutMsg {
    /// The forward node whose output (FP) or arg-gradient (BP) this carries.
    pub node: NodeId,
    /// Destination sub-graph id.
    pub to_sub: usize,
    pub tensor: Tensor,
    /// True for BP gradient messages (keyed differently on receive).
    pub is_grad: bool,
}

/// One compnode's executor over its assigned sub-graph.
pub struct SubDagExecutor {
    pub sub_id: usize,
    graph: std::sync::Arc<Graph>,
    decomp: std::sync::Arc<Decomposition>,
    engine: Box<dyn Engine>,
    /// Compiled once at construction, replayed every step.
    plan: ExecPlan,
    runner: WaveRunner,
    /// Parameters of owned parametric ops / variables.
    pub params: HashMap<NodeId, Vec<Tensor>>,
    /// Forward activations (own nodes + received outer-required data),
    /// dense by NodeId.
    acts: Vec<Option<Tensor>>,
    /// Pending upstream-gradient contributions per node, folded by key
    /// (see [`LOCAL_BASE`]) right before the node's backward task runs.
    grad_parts: Vec<Vec<(u32, Tensor)>>,
    /// Parameter gradients accumulated across microbatches.
    pub param_grads: HashMap<NodeId, Vec<Tensor>>,
    optimizers: HashMap<NodeId, Box<dyn Optimizer>>,
    /// Eager drop-after-last-use (default). When off, every activation and
    /// consumed gradient is retained to the end of the step — the
    /// keep-everything baseline the memory numbers are measured against.
    liveness: bool,
    /// Baseline-mode graveyard: tensors that liveness would have freed.
    retired: Vec<Tensor>,
    /// Currently resident activation + gradient bytes (params excluded).
    resident: u64,
    peak_resident: u64,
    /// Arrival counter keying remote gradient parts.
    remote_seq: u32,
}

impl SubDagExecutor {
    /// Reconstruct sub-DAG `sub_id`, compile its execution plan, and
    /// initialize its parameters.
    pub fn new(
        graph: std::sync::Arc<Graph>,
        decomp: std::sync::Arc<Decomposition>,
        sub_id: usize,
        mut engine: Box<dyn Engine>,
        opt_factory: &dyn Fn() -> Box<dyn Optimizer>,
        rng: &mut Rng,
    ) -> Result<SubDagExecutor> {
        let in_set: Vec<bool> =
            (0..graph.len()).map(|n| decomp.of_node[n] == sub_id).collect();
        let plan = ExecPlan::compile(&graph, &in_set, &backward_plan(&graph))?;
        let mut params = HashMap::new();
        let mut optimizers = HashMap::new();
        for &n in &plan.order {
            let node = graph.node(n);
            let p = engine.init_params(node, rng)?;
            if !p.is_empty() {
                params.insert(n, p);
                optimizers.insert(n, opt_factory());
            }
        }
        let n = graph.len();
        Ok(SubDagExecutor {
            sub_id,
            graph,
            decomp,
            engine,
            plan,
            runner: WaveRunner::new(),
            params,
            acts: vec![None; n],
            grad_parts: vec![Vec::new(); n],
            param_grads: HashMap::new(),
            optimizers,
            liveness: true,
            retired: Vec::new(),
            resident: 0,
            peak_resident: 0,
            remote_seq: 0,
        })
    }

    /// The compiled plan (wave structure, refcounts, keep sets).
    pub fn exec_plan(&self) -> &ExecPlan {
        &self.plan
    }

    /// Toggle liveness-driven freeing. Off = keep-everything baseline:
    /// nothing is dropped until [`end_batch`](Self::end_batch), so
    /// [`peak_resident_bytes`](Self::peak_resident_bytes) reports what the
    /// step would cost without the plan's refcounts.
    pub fn set_liveness_freeing(&mut self, on: bool) {
        self.liveness = on;
    }

    pub fn liveness_freeing(&self) -> bool {
        self.liveness
    }

    /// Currently resident activation + gradient bytes (params excluded).
    pub fn resident_bytes(&self) -> u64 {
        self.resident
    }

    /// High-water mark of [`resident_bytes`](Self::resident_bytes) since
    /// construction (or the last [`reset_peak_resident`](Self::reset_peak_resident)).
    pub fn peak_resident_bytes(&self) -> u64 {
        self.peak_resident
    }

    pub fn reset_peak_resident(&mut self) {
        self.peak_resident = self.resident;
    }

    fn note_resident(&mut self, bytes: u64) {
        self.resident += bytes;
        if self.resident > self.peak_resident {
            self.peak_resident = self.resident;
        }
    }

    /// A tensor is dead: uncount it and park its buffer for reuse.
    fn release(&mut self, t: Tensor) {
        self.resident = self.resident.saturating_sub(t.bytes());
        self.runner.recycle(t);
    }

    /// Liveness says `t` is dead; the baseline keeps it resident anyway.
    fn retire(&mut self, t: Tensor) {
        if self.liveness {
            self.release(t);
        } else {
            self.retired.push(t);
        }
    }

    /// Feed a placeholder value or received outer-required activation.
    pub fn feed(&mut self, node: NodeId, tensor: Tensor) {
        self.note_resident(tensor.bytes());
        if let Some(old) = self.acts[node].replace(tensor) {
            self.resident = self.resident.saturating_sub(old.bytes());
        }
    }

    /// Receive a gradient message for one of our nodes. Remote parts fold
    /// before local ones, in arrival order — the same order the serial
    /// sweep accumulated them in.
    pub fn receive_grad(&mut self, node: NodeId, grad: Tensor) {
        self.note_resident(grad.bytes());
        let key = self.remote_seq;
        self.remote_seq += 1;
        self.grad_parts[node].push((key, grad));
    }

    /// Fold a node's pending gradient parts into one tensor, in key order.
    fn fold_grad(&mut self, node: NodeId) -> Option<Tensor> {
        let mut parts = std::mem::take(&mut self.grad_parts[node]);
        if parts.is_empty() {
            return None;
        }
        parts.sort_by_key(|&(k, _)| k);
        let mut it = parts.into_iter();
        let (_, mut acc) = it.next().unwrap();
        for (_, g) in it {
            acc.axpy(1.0, &g);
            self.retire(g);
        }
        Some(acc)
    }

    /// FP task (paper §3.6): replay the forward waves; returns messages
    /// destined for other compnodes. Activations die (and their buffers
    /// recycle) as soon as their last in-set consumer has run, unless the
    /// plan's keep set pins them.
    pub fn run_fp(&mut self) -> Result<Vec<OutMsg>> {
        let graph = self.graph.clone();
        let threads = wave_threads();
        let fan_out = threads > 1 && self.engine.registry_backed();
        let mut live = self.plan.fwd_uses.clone();
        for wi in 0..self.plan.waves.len() {
            let wave = self.plan.waves[wi].clone();
            let mut jobs: Vec<NodeId> = Vec::with_capacity(wave.len());
            for &n in &wave {
                let node = graph.node(n);
                if node.kind.category() == OpCategory::Placeholder {
                    if self.acts[n].is_none() {
                        bail!("placeholder '{}' was not fed", node.name);
                    }
                } else {
                    jobs.push(n);
                }
            }
            let outs: Vec<(NodeId, Tensor)> = if fan_out
                && jobs.len() > 1
                && self.plan.wave_flops[wi] >= WAVE_PAR_MIN_FLOPS
            {
                self.runner.forward_wave(&graph, &jobs, &self.acts, &self.params, threads)?
            } else {
                let mut outs = Vec::with_capacity(jobs.len());
                for &n in &jobs {
                    let node = graph.node(n);
                    let inputs: Vec<&Tensor> = node
                        .args
                        .iter()
                        .map(|&a| {
                            self.acts[a].as_ref().ok_or_else(|| {
                                anyhow!("missing input {} for '{}'", a, node.name)
                            })
                        })
                        .collect::<Result<_>>()?;
                    let params = self.params.get(&n).map(Vec::as_slice).unwrap_or(&[]);
                    outs.push((n, self.engine.forward(node, &inputs, params)?));
                }
                outs
            };
            for (n, t) in outs {
                self.note_resident(t.bytes());
                if let Some(old) = self.acts[n].replace(t) {
                    self.resident = self.resident.saturating_sub(old.bytes());
                }
            }
            // Drop-after-last-use: this wave consumed its args once more.
            for &n in &jobs {
                for &a in &graph.node(n).args {
                    live[a] -= 1;
                    if live[a] == 0 && self.liveness && !self.plan.keep_after_fp[a] {
                        if let Some(t) = self.acts[a].take() {
                            self.release(t);
                        }
                    }
                }
            }
        }
        // Outward data: owned nodes with external users (Table 3). These
        // are in the keep set, so their activations survived the sweep.
        let mut msgs = Vec::new();
        for &n in &self.plan.order {
            let mut sent_to = BTreeSet::new();
            for &u in graph.users(n) {
                let dst = self.decomp.of_node[u];
                if dst != self.sub_id && sent_to.insert(dst) {
                    let t = self.acts[n]
                        .as_ref()
                        .ok_or_else(|| {
                            anyhow!("activation of '{}' missing for send", graph.node(n).name)
                        })?
                        .clone();
                    msgs.push(OutMsg { node: n, to_sub: dst, tensor: t, is_grad: false });
                }
            }
        }
        Ok(msgs)
    }

    /// BP task: replay the backward waves, folding upstream gradients in
    /// backward-plan position order, producing gradients for args
    /// (messaging remote ones) and accumulating parameter gradients.
    /// Forward stashes are freed as soon as their last consumer grad fires.
    ///
    /// `plan` is the global backward plan; this executor runs the portion
    /// covering its nodes. The caller must have delivered all remote
    /// gradient messages for the frontier nodes before invoking.
    pub fn run_bp(&mut self, plan: &BackwardPlan) -> Result<Vec<OutMsg>> {
        let graph = self.graph.clone();
        let threads = wave_threads();
        let fan_out = threads > 1 && self.engine.registry_backed();
        let mut stash_live = self.plan.stash_uses.clone();
        // Activations nothing in the backward pass will read (e.g. outputs
        // kept only for messaging) are dead from the first backward wave.
        if self.liveness {
            for n in 0..stash_live.len() {
                if stash_live[n] == 0 && !self.plan.keep_always[n] {
                    if let Some(t) = self.acts[n].take() {
                        self.release(t);
                    }
                }
            }
        }
        let mut msgs = Vec::new();
        for wi in 0..self.plan.bwd_waves.len() {
            let wave = self.plan.bwd_waves[wi].clone();
            let mut jobs: Vec<BwdJob> = Vec::with_capacity(wave.len());
            for &n in &wave {
                let node = graph.node(n);
                let upstream = if node.kind.category() == OpCategory::Loss {
                    None
                } else {
                    Some(
                        self.fold_grad(n)
                            .ok_or_else(|| anyhow!("no upstream grad for '{}'", node.name))?,
                    )
                };
                jobs.push(BwdJob { node: n, upstream });
            }
            let outs: Vec<(NodeId, crate::exec::BackwardOut)> = if fan_out
                && jobs.len() > 1
                && self.plan.bwd_wave_flops[wi] >= WAVE_PAR_MIN_FLOPS
            {
                self.runner.backward_wave(&graph, &jobs, &self.acts, &self.params, threads)?
            } else {
                let mut outs = Vec::with_capacity(jobs.len());
                for job in &jobs {
                    let node = graph.node(job.node);
                    let inputs: Vec<&Tensor> = node
                        .args
                        .iter()
                        .map(|&a| {
                            self.acts[a].as_ref().ok_or_else(|| {
                                anyhow!("missing stashed input {a} for '{}'", node.name)
                            })
                        })
                        .collect::<Result<_>>()?;
                    let params =
                        self.params.get(&job.node).map(Vec::as_slice).unwrap_or(&[]);
                    outs.push((
                        job.node,
                        self.engine.backward(node, &inputs, params, job.upstream.as_ref())?,
                    ));
                }
                outs
            };
            // The folded upstream grads are consumed.
            for job in jobs {
                if let Some(g) = job.upstream {
                    self.retire(g);
                }
            }
            // Apply results sequentially in wave order: accumulation order
            // is a function of the plan, never of scheduling.
            for (n, bwd) in outs {
                let task = plan.task(n).expect("compiled backward wave nodes participate");
                // Parameter gradients accumulate (microbatching).
                if !bwd.param_grads.is_empty() {
                    match self.param_grads.get_mut(&n) {
                        Some(acc) => {
                            for (a, g) in acc.iter_mut().zip(&bwd.param_grads) {
                                a.axpy(1.0, g);
                            }
                        }
                        None => {
                            self.param_grads.insert(n, bwd.param_grads);
                        }
                    }
                }
                // Route input gradients: local targets become keyed parts,
                // remote ones are sent to the arg's owner (paper: "the
                // computed gradients are returned to their Arg Nodes").
                for (ai, g) in bwd.input_grads.into_iter().enumerate() {
                    let Some(g) = g else { continue };
                    let arg = graph.node(n).args[ai];
                    if !task.grad_targets.contains(&arg) {
                        continue;
                    }
                    let owner = self.decomp.of_node[arg];
                    if owner == self.sub_id {
                        self.note_resident(g.bytes());
                        let key = LOCAL_BASE + self.plan.bwd_pos[n] as u32;
                        self.grad_parts[arg].push((key, g));
                    } else {
                        msgs.push(OutMsg { node: arg, to_sub: owner, tensor: g, is_grad: true });
                    }
                }
            }
            // This wave's VJPs re-read their stashes; free the exhausted ones.
            for &n in &wave {
                for &a in &graph.node(n).args {
                    stash_live[a] -= 1;
                    if stash_live[a] == 0 && self.liveness && !self.plan.keep_always[a] {
                        if let Some(t) = self.acts[a].take() {
                            self.release(t);
                        }
                    }
                }
            }
        }
        Ok(msgs)
    }

    /// Update task: apply the optimizer to every owned parametric op whose
    /// gradient is ready, then clear gradients. Returns how many ops were
    /// updated.
    pub fn run_update(&mut self) -> usize {
        let mut updated = 0;
        for (&n, grads) in self.param_grads.iter() {
            if let (Some(params), Some(opt)) =
                (self.params.get_mut(&n), self.optimizers.get_mut(&n))
            {
                opt.step(params, grads);
                updated += 1;
            }
        }
        self.param_grads.clear();
        updated
    }

    /// Clear per-batch state (activations + pending grads), keeping params.
    /// Buffers go back to the scratch pool; `peak_resident_bytes` persists.
    pub fn end_batch(&mut self) {
        for i in 0..self.acts.len() {
            if let Some(t) = self.acts[i].take() {
                self.runner.recycle(t);
            }
            for (_, t) in std::mem::take(&mut self.grad_parts[i]) {
                self.runner.recycle(t);
            }
        }
        self.retired.clear();
        self.resident = 0;
        self.remote_seq = 0;
    }

    /// The activation of an owned node (e.g. the loss). Mid-step, only
    /// nodes the plan keeps (losses, sinks, stashes, messaged outputs) are
    /// still resident once their last consumer has run.
    pub fn activation(&self, node: NodeId) -> Option<&Tensor> {
        self.acts.get(node).and_then(|t| t.as_ref())
    }

    /// Parameter bytes hosted here (what a checkpoint to the supernode
    /// would cost, §3.5).
    pub fn param_bytes(&self) -> u64 {
        self.params.values().flat_map(|v| v.iter().map(Tensor::bytes)).sum()
    }

    /// Export a deep copy of the parameter state (checkpoint).
    pub fn checkpoint(&self) -> HashMap<NodeId, Vec<Tensor>> {
        self.params.clone()
    }

    /// Restore parameters from a checkpoint (backup-node takeover).
    pub fn restore(&mut self, ckpt: HashMap<NodeId, Vec<Tensor>>) {
        self.params = ckpt;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dag::autodiff::backward_plan;
    use crate::dag::{DType, OpKind, Shape};
    use crate::exec::{set_wave_threads, Adam, RefEngine};
    use crate::models::fig3;
    use std::sync::Arc;

    /// Wire 3 executors over the paper's Figure-3 partition and run a full
    /// FP→BP→Update cycle, moving messages by hand.
    fn fig3_cluster() -> (Arc<Graph>, Arc<Decomposition>, Vec<SubDagExecutor>) {
        let g = Arc::new(fig3::build());
        let d = Arc::new(Decomposition::from_assignment(&g, &fig3::paper_partition(&g)));
        let mut rng = Rng::new(42);
        let execs: Vec<SubDagExecutor> = (0..3)
            .map(|s| {
                SubDagExecutor::new(
                    g.clone(),
                    d.clone(),
                    s,
                    Box::new(RefEngine::new()),
                    &|| Box::new(Adam::new(0.02)),
                    &mut rng,
                )
                .unwrap()
            })
            .collect();
        (g, d, execs)
    }

    fn feed_fig3(g: &Graph, execs: &mut [SubDagExecutor], seed: u64) {
        let mut rng = Rng::new(seed);
        let input = Tensor::randn(&[fig3::BATCH, fig3::CH, fig3::HW, fig3::HW], 1.0, &mut rng);
        let n_lab = fig3::BATCH * 2 * fig3::CH * fig3::HW;
        let labels = Tensor::from_ivec(
            &[fig3::BATCH, 2 * fig3::CH, fig3::HW],
            (0..n_lab).map(|i| (i % fig3::CLASSES) as i32).collect(),
        );
        execs[0].feed(g.by_name("Input").unwrap().id, input);
        execs[2].feed(g.by_name("Label").unwrap().id, labels);
    }

    /// One FP sweep across sub-DAGs in order, delivering messages.
    fn run_fp_all(execs: &mut [SubDagExecutor]) -> Result<()> {
        for s in 0..execs.len() {
            let msgs = execs[s].run_fp()?;
            for m in msgs {
                assert!(!m.is_grad);
                execs[m.to_sub].feed(m.node, m.tensor);
            }
        }
        Ok(())
    }

    fn run_bp_all(execs: &mut [SubDagExecutor], plan: &BackwardPlan) -> Result<()> {
        for s in (0..execs.len()).rev() {
            let msgs = execs[s].run_bp(plan)?;
            for m in msgs {
                assert!(m.is_grad);
                execs[m.to_sub].receive_grad(m.node, m.tensor);
            }
        }
        Ok(())
    }

    #[test]
    fn fp_produces_loss_on_compnode3() {
        let (g, _, mut execs) = fig3_cluster();
        feed_fig3(&g, &mut execs, 1);
        run_fp_all(&mut execs).unwrap();
        let loss_id = g.by_name("CrossEntropy").unwrap().id;
        let loss = execs[2].activation(loss_id).unwrap().item();
        assert!(loss.is_finite() && loss > 0.0);
    }

    #[test]
    fn fp_message_pattern_matches_table3() {
        let (g, _, mut execs) = fig3_cluster();
        feed_fig3(&g, &mut execs, 2);
        let m0 = execs[0].run_fp().unwrap();
        // Subgraph 1 sends Add→sub2 and Pool→sub3.
        let mut sends: Vec<(String, usize)> =
            m0.iter().map(|m| (g.node(m.node).name.clone(), m.to_sub)).collect();
        sends.sort();
        assert_eq!(sends, vec![("Add".to_string(), 1), ("Pool".to_string(), 2)]);
        for m in m0 {
            execs[m.to_sub].feed(m.node, m.tensor);
        }
        let m1 = execs[1].run_fp().unwrap();
        assert_eq!(m1.len(), 1);
        assert_eq!(g.node(m1[0].node).name, "Multiply");
        assert_eq!(m1[0].to_sub, 2);
        for m in m1 {
            execs[m.to_sub].feed(m.node, m.tensor);
        }
        assert!(execs[2].run_fp().unwrap().is_empty());
    }

    #[test]
    fn full_training_cycle_reduces_loss() {
        let (g, _, mut execs) = fig3_cluster();
        let plan = backward_plan(&g);
        let loss_id = g.by_name("CrossEntropy").unwrap().id;
        let mut losses = Vec::new();
        for step in 0..30 {
            // Same data every step: loss must drop.
            feed_fig3(&g, &mut execs, 7);
            run_fp_all(&mut execs).unwrap();
            losses.push(execs[2].activation(loss_id).unwrap().item());
            run_bp_all(&mut execs, &plan).unwrap();
            let updated: usize = execs.iter_mut().map(|e| e.run_update()).sum();
            // Conv (sub1), Tensor A (sub2), Linear (sub3).
            assert_eq!(updated, 3, "step {step}");
            for e in execs.iter_mut() {
                e.end_batch();
            }
        }
        assert!(
            losses.last().unwrap() < &(losses[0] * 0.8),
            "loss did not drop: {:?}",
            &losses
        );
    }

    #[test]
    fn bp_routes_gradients_to_remote_arg_owners() {
        let (g, _, mut execs) = fig3_cluster();
        let plan = backward_plan(&g);
        feed_fig3(&g, &mut execs, 3);
        run_fp_all(&mut execs).unwrap();
        // Sub 3 backward must send grads to Pool (sub1) and Multiply (sub2).
        let msgs = execs[2].run_bp(&plan).unwrap();
        let mut dests: Vec<(String, usize)> =
            msgs.iter().map(|m| (g.node(m.node).name.clone(), m.to_sub)).collect();
        dests.sort();
        assert_eq!(dests, vec![("Multiply".to_string(), 1), ("Pool".to_string(), 0)]);
    }

    #[test]
    fn checkpoint_restore_roundtrip() {
        let (g, _, mut execs) = fig3_cluster();
        let plan = backward_plan(&g);
        feed_fig3(&g, &mut execs, 4);
        run_fp_all(&mut execs).unwrap();
        run_bp_all(&mut execs, &plan).unwrap();
        let ckpt = execs[0].checkpoint();
        execs[0].run_update();
        let conv = g.by_name("Conv").unwrap().id;
        let after = execs[0].params[&conv][0].clone();
        execs[0].restore(ckpt);
        let restored = &execs[0].params[&conv][0];
        assert_ne!(after.f(), restored.f(), "update must have changed params");
    }

    #[test]
    fn missing_feed_is_reported() {
        let (_, _, mut execs) = fig3_cluster();
        let err = execs[0].run_fp().unwrap_err().to_string();
        assert!(err.contains("Input"), "got: {err}");
    }

    /// A single-sub inference chain: mid-chain activations die as soon as
    /// their consumer ran; the sink survives; peak stays far below the
    /// keep-everything baseline.
    #[test]
    fn liveness_frees_dead_activations_and_lowers_peak() {
        let mut g = Graph::new();
        let mut prev = g.placeholder("x", Shape::of(&[4, 256]), DType::F32);
        let mut ids = vec![prev];
        for i in 0..6 {
            prev = g.op(&format!("r{i}"), OpKind::Relu, &[prev]).unwrap();
            ids.push(prev);
        }
        let g = Arc::new(g);
        let assign: Vec<(NodeId, usize)> = (0..g.len()).map(|n| (n, 0)).collect();
        let d = Arc::new(Decomposition::from_assignment(&g, &assign));
        let run = |freeing: bool| -> (SubDagExecutor, u64) {
            let mut rng = Rng::new(5);
            let mut e = SubDagExecutor::new(
                g.clone(),
                d.clone(),
                0,
                Box::new(RefEngine::new()),
                &|| Box::new(Adam::new(0.01)),
                &mut rng,
            )
            .unwrap();
            e.set_liveness_freeing(freeing);
            let mut rng = Rng::new(6);
            e.feed(ids[0], Tensor::randn(&[4, 256], 1.0, &mut rng));
            e.run_fp().unwrap();
            let peak = e.peak_resident_bytes();
            (e, peak)
        };
        let (freed, peak_freed) = run(true);
        // Mid-chain gone, sink kept.
        assert!(freed.activation(ids[2]).is_none(), "r1 should be freed");
        assert!(freed.activation(*ids.last().unwrap()).is_some());
        let (kept, peak_kept) = run(false);
        assert!(kept.activation(ids[2]).is_some(), "baseline keeps everything");
        assert!(
            peak_freed < peak_kept,
            "freeing peak {peak_freed} must undercut baseline {peak_kept}"
        );
        // Freeing holds ≤ 3 live tensors (arg + output + kept sink) of the
        // 7-tensor chain.
        assert!(peak_freed <= 3 * 4 * 256 * 4);
    }

    /// Any wave width is bitwise identical to the serial sweep: loss and
    /// every parameter gradient agree bit for bit.
    #[test]
    fn wavefront_training_step_is_bitwise_deterministic() {
        let collect = |threads: usize| -> (f32, Vec<Vec<u32>>) {
            set_wave_threads(threads);
            let (g, _, mut execs) = fig3_cluster();
            let plan = backward_plan(&g);
            feed_fig3(&g, &mut execs, 11);
            run_fp_all(&mut execs).unwrap();
            let loss_id = g.by_name("CrossEntropy").unwrap().id;
            let loss = execs[2].activation(loss_id).unwrap().item();
            run_bp_all(&mut execs, &plan).unwrap();
            let mut grads: Vec<Vec<u32>> = Vec::new();
            for e in &execs {
                let mut keys: Vec<&NodeId> = e.param_grads.keys().collect();
                keys.sort();
                for k in keys {
                    for t in &e.param_grads[k] {
                        grads.push(t.f().iter().map(|v| v.to_bits()).collect());
                    }
                }
            }
            (loss, grads)
        };
        let (l1, g1) = collect(1);
        for t in [2, 8] {
            let (lt, gt) = collect(t);
            assert_eq!(l1.to_bits(), lt.to_bits(), "loss diverged at {t} threads");
            assert_eq!(g1, gt, "param grads diverged at {t} threads");
        }
        set_wave_threads(1);
    }
}
