//! Compnode task executor (paper §3.3, §3.6).
//!
//! "We employ a task executor to manage the message passing between OPs and
//! perform the computations of the OPs with their inputs."
//!
//! A [`SubDagExecutor`] owns one compnode's share of a decomposed graph: it
//! reconstructs the sub-DAG from the IR, initializes/loads the parameters of
//! its parametric OPs, and executes **FP**, **BP** and **Update** tasks. Data
//! that must cross compnodes is returned as outbound messages — the cluster
//! layer (or a test) moves them and feeds the receiving executor, exactly
//! the send-side/receive-side split of §3.6 "Message passing".

use std::collections::{BTreeSet, HashMap};

use anyhow::{anyhow, bail, Result};

use crate::dag::autodiff::BackwardPlan;
use crate::dag::{Graph, NodeId, OpCategory};
use crate::decompose::Decomposition;
use crate::exec::{Engine, Optimizer};
use crate::tensor::Tensor;
use crate::util::Rng;

/// An outbound activation or gradient message.
#[derive(Debug, Clone)]
pub struct OutMsg {
    /// The forward node whose output (FP) or arg-gradient (BP) this carries.
    pub node: NodeId,
    /// Destination sub-graph id.
    pub to_sub: usize,
    pub tensor: Tensor,
    /// True for BP gradient messages (keyed differently on receive).
    pub is_grad: bool,
}

/// One compnode's executor over its assigned sub-graph.
pub struct SubDagExecutor {
    pub sub_id: usize,
    graph: std::sync::Arc<Graph>,
    decomp: std::sync::Arc<Decomposition>,
    engine: Box<dyn Engine>,
    /// Nodes this executor owns, in topological order.
    my_nodes: Vec<NodeId>,
    mine: BTreeSet<NodeId>,
    /// Parameters of owned parametric ops / variables.
    pub params: HashMap<NodeId, Vec<Tensor>>,
    /// Forward activations (own nodes + received outer-required data).
    acts: HashMap<NodeId, Tensor>,
    /// Upstream gradients accumulated per node (from local + remote users).
    grads_in: HashMap<NodeId, Tensor>,
    /// Parameter gradients accumulated across microbatches.
    pub param_grads: HashMap<NodeId, Vec<Tensor>>,
    optimizers: HashMap<NodeId, Box<dyn Optimizer>>,
}

impl SubDagExecutor {
    /// Reconstruct sub-DAG `sub_id` and initialize its parameters.
    pub fn new(
        graph: std::sync::Arc<Graph>,
        decomp: std::sync::Arc<Decomposition>,
        sub_id: usize,
        mut engine: Box<dyn Engine>,
        opt_factory: &dyn Fn() -> Box<dyn Optimizer>,
        rng: &mut Rng,
    ) -> Result<SubDagExecutor> {
        let topo = graph.topo_order().map_err(|e| anyhow!("{e}"))?;
        let my_nodes: Vec<NodeId> =
            topo.into_iter().filter(|&n| decomp.of_node[n] == sub_id).collect();
        let mine: BTreeSet<NodeId> = my_nodes.iter().copied().collect();
        let mut params = HashMap::new();
        let mut optimizers = HashMap::new();
        for &n in &my_nodes {
            let node = graph.node(n);
            let p = engine.init_params(node, rng)?;
            if !p.is_empty() {
                params.insert(n, p);
                optimizers.insert(n, opt_factory());
            }
        }
        Ok(SubDagExecutor {
            sub_id,
            graph,
            decomp,
            engine,
            my_nodes,
            mine,
            params,
            acts: HashMap::new(),
            grads_in: HashMap::new(),
            param_grads: HashMap::new(),
            optimizers,
        })
    }

    /// Feed a placeholder value or received outer-required activation.
    pub fn feed(&mut self, node: NodeId, tensor: Tensor) {
        self.acts.insert(node, tensor);
    }

    /// Receive a gradient message for one of our nodes.
    pub fn receive_grad(&mut self, node: NodeId, grad: Tensor) {
        self.accumulate_grad(node, grad);
    }

    fn accumulate_grad(&mut self, node: NodeId, grad: Tensor) {
        match self.grads_in.get_mut(&node) {
            Some(g) => g.axpy(1.0, &grad),
            None => {
                self.grads_in.insert(node, grad);
            }
        }
    }

    /// FP task (paper §3.6): execute owned nodes in topo order once their
    /// inputs are available; returns messages destined for other compnodes.
    pub fn run_fp(&mut self) -> Result<Vec<OutMsg>> {
        let graph = self.graph.clone();
        for &n in &self.my_nodes.clone() {
            let node = graph.node(n);
            if node.kind.category() == OpCategory::Placeholder {
                if !self.acts.contains_key(&n) {
                    bail!("placeholder '{}' was not fed", node.name);
                }
                continue;
            }
            let inputs: Vec<&Tensor> = node
                .args
                .iter()
                .map(|a| {
                    self.acts
                        .get(a)
                        .ok_or_else(|| anyhow!("missing input {} for '{}'", a, node.name))
                })
                .collect::<Result<_>>()?;
            let params = self.params.get(&n).map(Vec::as_slice).unwrap_or(&[]);
            let out = self.engine.forward(node, &inputs, params)?;
            self.acts.insert(n, out);
        }
        // Outward data: owned nodes with external users (Table 3).
        let mut msgs = Vec::new();
        for &n in &self.my_nodes {
            let mut sent_to = BTreeSet::new();
            for &u in graph.users(n) {
                let dst = self.decomp.of_node[u];
                if dst != self.sub_id && sent_to.insert(dst) {
                    msgs.push(OutMsg {
                        node: n,
                        to_sub: dst,
                        tensor: self.acts[&n].clone(),
                        is_grad: false,
                    });
                }
            }
        }
        Ok(msgs)
    }

    /// BP task: consume accumulated upstream gradients in reverse topo
    /// order, produce gradients for args (messaging remote ones) and
    /// accumulate parameter gradients.
    ///
    /// `plan` is the global backward plan; this executor runs the portion
    /// covering its nodes. The caller must have delivered all remote
    /// gradient messages for the frontier nodes before invoking.
    pub fn run_bp(&mut self, plan: &BackwardPlan) -> Result<Vec<OutMsg>> {
        let graph = self.graph.clone();
        let mut msgs = Vec::new();
        for &n in plan.order.iter() {
            if !self.mine.contains(&n) {
                continue;
            }
            let task = plan.task(n).unwrap();
            let node = graph.node(n);
            let is_loss = node.kind.category() == OpCategory::Loss;
            let out_grad = if is_loss {
                None
            } else {
                Some(
                    self.grads_in
                        .remove(&n)
                        .ok_or_else(|| anyhow!("no upstream grad for '{}'", node.name))?,
                )
            };
            let inputs: Vec<&Tensor> = node
                .args
                .iter()
                .map(|a| {
                    self.acts
                        .get(a)
                        .ok_or_else(|| anyhow!("missing stashed input {a} for '{}'", node.name))
                })
                .collect::<Result<_>>()?;
            let params = self.params.get(&n).map(Vec::as_slice).unwrap_or(&[]);
            let bwd = self.engine.backward(node, &inputs, params, out_grad.as_ref())?;
            // Parameter gradients accumulate (microbatching).
            if !bwd.param_grads.is_empty() {
                match self.param_grads.get_mut(&n) {
                    Some(acc) => {
                        for (a, g) in acc.iter_mut().zip(&bwd.param_grads) {
                            a.axpy(1.0, g);
                        }
                    }
                    None => {
                        self.param_grads.insert(n, bwd.param_grads);
                    }
                }
            }
            // Route input gradients: local targets accumulate, remote ones
            // are sent to the arg's owner (paper: "the computed gradients
            // are returned to their Arg Nodes").
            for (ai, g) in bwd.input_grads.into_iter().enumerate() {
                let Some(g) = g else { continue };
                let arg = node.args[ai];
                if !task.grad_targets.contains(&arg) {
                    continue;
                }
                let owner = self.decomp.of_node[arg];
                if owner == self.sub_id {
                    self.accumulate_grad(arg, g);
                } else {
                    msgs.push(OutMsg { node: arg, to_sub: owner, tensor: g, is_grad: true });
                }
            }
        }
        Ok(msgs)
    }

    /// Update task: apply the optimizer to every owned parametric op whose
    /// gradient is ready, then clear gradients. Returns how many ops were
    /// updated.
    pub fn run_update(&mut self) -> usize {
        let mut updated = 0;
        for (&n, grads) in self.param_grads.iter() {
            if let (Some(params), Some(opt)) =
                (self.params.get_mut(&n), self.optimizers.get_mut(&n))
            {
                opt.step(params, grads);
                updated += 1;
            }
        }
        self.param_grads.clear();
        updated
    }

    /// Clear per-batch state (activations + pending grads), keeping params.
    pub fn end_batch(&mut self) {
        self.acts.clear();
        self.grads_in.clear();
    }

    /// The activation of an owned node (e.g. the loss).
    pub fn activation(&self, node: NodeId) -> Option<&Tensor> {
        self.acts.get(&node)
    }

    /// Parameter bytes hosted here (what a checkpoint to the supernode
    /// would cost, §3.5).
    pub fn param_bytes(&self) -> u64 {
        self.params.values().flat_map(|v| v.iter().map(Tensor::bytes)).sum()
    }

    /// Export a deep copy of the parameter state (checkpoint).
    pub fn checkpoint(&self) -> HashMap<NodeId, Vec<Tensor>> {
        self.params.clone()
    }

    /// Restore parameters from a checkpoint (backup-node takeover).
    pub fn restore(&mut self, ckpt: HashMap<NodeId, Vec<Tensor>>) {
        self.params = ckpt;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dag::autodiff::backward_plan;
    use crate::exec::{Adam, RefEngine};
    use crate::models::fig3;
    use std::sync::Arc;

    /// Wire 3 executors over the paper's Figure-3 partition and run a full
    /// FP→BP→Update cycle, moving messages by hand.
    fn fig3_cluster() -> (Arc<Graph>, Arc<Decomposition>, Vec<SubDagExecutor>) {
        let g = Arc::new(fig3::build());
        let d = Arc::new(Decomposition::from_assignment(&g, &fig3::paper_partition(&g)));
        let mut rng = Rng::new(42);
        let execs: Vec<SubDagExecutor> = (0..3)
            .map(|s| {
                SubDagExecutor::new(
                    g.clone(),
                    d.clone(),
                    s,
                    Box::new(RefEngine::new()),
                    &|| Box::new(Adam::new(0.02)),
                    &mut rng,
                )
                .unwrap()
            })
            .collect();
        (g, d, execs)
    }

    fn feed_fig3(g: &Graph, execs: &mut [SubDagExecutor], seed: u64) {
        let mut rng = Rng::new(seed);
        let input = Tensor::randn(&[fig3::BATCH, fig3::CH, fig3::HW, fig3::HW], 1.0, &mut rng);
        let n_lab = fig3::BATCH * 2 * fig3::CH * fig3::HW;
        let labels = Tensor::from_ivec(
            &[fig3::BATCH, 2 * fig3::CH, fig3::HW],
            (0..n_lab).map(|i| (i % fig3::CLASSES) as i32).collect(),
        );
        execs[0].feed(g.by_name("Input").unwrap().id, input);
        execs[2].feed(g.by_name("Label").unwrap().id, labels);
    }

    /// One FP sweep across sub-DAGs in order, delivering messages.
    fn run_fp_all(execs: &mut [SubDagExecutor]) -> Result<()> {
        for s in 0..execs.len() {
            let msgs = execs[s].run_fp()?;
            for m in msgs {
                assert!(!m.is_grad);
                execs[m.to_sub].feed(m.node, m.tensor);
            }
        }
        Ok(())
    }

    fn run_bp_all(execs: &mut [SubDagExecutor], plan: &BackwardPlan) -> Result<()> {
        for s in (0..execs.len()).rev() {
            let msgs = execs[s].run_bp(plan)?;
            for m in msgs {
                assert!(m.is_grad);
                execs[m.to_sub].receive_grad(m.node, m.tensor);
            }
        }
        Ok(())
    }

    #[test]
    fn fp_produces_loss_on_compnode3() {
        let (g, _, mut execs) = fig3_cluster();
        feed_fig3(&g, &mut execs, 1);
        run_fp_all(&mut execs).unwrap();
        let loss_id = g.by_name("CrossEntropy").unwrap().id;
        let loss = execs[2].activation(loss_id).unwrap().item();
        assert!(loss.is_finite() && loss > 0.0);
    }

    #[test]
    fn fp_message_pattern_matches_table3() {
        let (g, _, mut execs) = fig3_cluster();
        feed_fig3(&g, &mut execs, 2);
        let m0 = execs[0].run_fp().unwrap();
        // Subgraph 1 sends Add→sub2 and Pool→sub3.
        let mut sends: Vec<(String, usize)> =
            m0.iter().map(|m| (g.node(m.node).name.clone(), m.to_sub)).collect();
        sends.sort();
        assert_eq!(sends, vec![("Add".to_string(), 1), ("Pool".to_string(), 2)]);
        for m in m0 {
            execs[m.to_sub].feed(m.node, m.tensor);
        }
        let m1 = execs[1].run_fp().unwrap();
        assert_eq!(m1.len(), 1);
        assert_eq!(g.node(m1[0].node).name, "Multiply");
        assert_eq!(m1[0].to_sub, 2);
        for m in m1 {
            execs[m.to_sub].feed(m.node, m.tensor);
        }
        assert!(execs[2].run_fp().unwrap().is_empty());
    }

    #[test]
    fn full_training_cycle_reduces_loss() {
        let (g, _, mut execs) = fig3_cluster();
        let plan = backward_plan(&g);
        let loss_id = g.by_name("CrossEntropy").unwrap().id;
        let mut losses = Vec::new();
        for step in 0..30 {
            // Same data every step: loss must drop.
            feed_fig3(&g, &mut execs, 7);
            run_fp_all(&mut execs).unwrap();
            losses.push(execs[2].activation(loss_id).unwrap().item());
            run_bp_all(&mut execs, &plan).unwrap();
            let updated: usize = execs.iter_mut().map(|e| e.run_update()).sum();
            // Conv (sub1), Tensor A (sub2), Linear (sub3).
            assert_eq!(updated, 3, "step {step}");
            for e in execs.iter_mut() {
                e.end_batch();
            }
        }
        assert!(
            losses.last().unwrap() < &(losses[0] * 0.8),
            "loss did not drop: {:?}",
            &losses
        );
    }

    #[test]
    fn bp_routes_gradients_to_remote_arg_owners() {
        let (g, _, mut execs) = fig3_cluster();
        let plan = backward_plan(&g);
        feed_fig3(&g, &mut execs, 3);
        run_fp_all(&mut execs).unwrap();
        // Sub 3 backward must send grads to Pool (sub1) and Multiply (sub2).
        let msgs = execs[2].run_bp(&plan).unwrap();
        let mut dests: Vec<(String, usize)> =
            msgs.iter().map(|m| (g.node(m.node).name.clone(), m.to_sub)).collect();
        dests.sort();
        assert_eq!(dests, vec![("Multiply".to_string(), 1), ("Pool".to_string(), 0)]);
    }

    #[test]
    fn checkpoint_restore_roundtrip() {
        let (g, _, mut execs) = fig3_cluster();
        let plan = backward_plan(&g);
        feed_fig3(&g, &mut execs, 4);
        run_fp_all(&mut execs).unwrap();
        run_bp_all(&mut execs, &plan).unwrap();
        let ckpt = execs[0].checkpoint();
        execs[0].run_update();
        let conv = g.by_name("Conv").unwrap().id;
        let after = execs[0].params[&conv][0].clone();
        execs[0].restore(ckpt);
        let restored = &execs[0].params[&conv][0];
        assert_ne!(after.f(), restored.f(), "update must have changed params");
    }

    #[test]
    fn missing_feed_is_reported() {
        let (_, _, mut execs) = fig3_cluster();
        let err = execs[0].run_fp().unwrap_err().to_string();
        assert!(err.contains("Input"), "got: {err}");
    }
}
