//! Stage partitioning as a compiler pass.
//!
//! [`ChainPartitionPass`] runs the §4 min-max chain decomposition inside the
//! [`PassManager`](crate::dag::PassManager) pipeline and records the result
//! *in the graph itself*: every node gets a `"subgraph"` kwarg (Table 2
//! "Kwargs") naming its pipeline segment. Downstream consumers recover the
//! partition with [`Decomposition::from_kwargs`] instead of re-running the
//! DP, so a serialized graph carries its own placement.

use crate::dag::{Graph, GraphError, GraphPass};
use crate::decompose::Decomposition;

/// Kwarg key under which the pass stores each node's segment index.
pub const SUBGRAPH_KEY: &str = "subgraph";

/// Annotate every node with its min-max balanced chain segment.
pub struct ChainPartitionPass {
    pub k: usize,
}

impl ChainPartitionPass {
    pub fn new(k: usize) -> ChainPartitionPass {
        assert!(k > 0, "need at least one segment");
        ChainPartitionPass { k }
    }
}

impl GraphPass for ChainPartitionPass {
    fn name(&self) -> &'static str {
        "chain-partition"
    }

    fn run(&self, g: &mut Graph) -> Result<bool, GraphError> {
        let d = Decomposition::chain_balanced(g, self.k);
        let mut changed = false;
        for id in 0..g.len() {
            let val = d.of_node[id].to_string();
            if g.node(id).kwargs.get(SUBGRAPH_KEY) != Some(&val) {
                g.set_kwarg(id, SUBGRAPH_KEY, &val);
                changed = true;
            }
        }
        Ok(changed)
    }
}

impl Decomposition {
    /// Rebuild a partition from the `"subgraph"` kwargs written by
    /// [`ChainPartitionPass`] (or hand-annotated / deserialized graphs).
    pub fn from_kwargs(g: &Graph) -> Result<Decomposition, GraphError> {
        let mut assign = Vec::with_capacity(g.len());
        for node in &g.nodes {
            let raw = node.kwargs.get(SUBGRAPH_KEY).ok_or_else(|| {
                GraphError::Invalid(format!(
                    "node '{}' has no '{SUBGRAPH_KEY}' kwarg — run ChainPartitionPass first",
                    node.name
                ))
            })?;
            let seg: usize = raw.parse().map_err(|_| {
                GraphError::Invalid(format!(
                    "node '{}': bad '{SUBGRAPH_KEY}' kwarg '{raw}'",
                    node.name
                ))
            })?;
            assign.push((node.id, seg));
        }
        Ok(Decomposition::from_assignment(g, &assign))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dag::PassManager;
    use crate::models::transformer::TransformerConfig;

    #[test]
    fn pass_annotates_and_roundtrips() {
        let mut g = TransformerConfig::tiny().build_graph();
        let direct = Decomposition::chain_balanced(&g, 4);

        let report =
            PassManager::new().with_pass(ChainPartitionPass::new(4)).run(&mut g).unwrap();
        assert!(report.changed());

        let via_kwargs = Decomposition::from_kwargs(&g).unwrap();
        via_kwargs.validate(&g).unwrap();
        assert_eq!(via_kwargs.of_node, direct.of_node);

        // Re-running is a no-op: annotations already match.
        let again =
            PassManager::new().with_pass(ChainPartitionPass::new(4)).run(&mut g).unwrap();
        assert!(!again.changed());
    }

    #[test]
    fn kwargs_survive_json_roundtrip() {
        let mut g = TransformerConfig::tiny().build_graph();
        ChainPartitionPass::new(3).run(&mut g).unwrap();
        let g2 = crate::dag::Graph::from_json(&g.to_json()).unwrap();
        let d2 = Decomposition::from_kwargs(&g2).unwrap();
        assert_eq!(d2.of_node, Decomposition::from_kwargs(&g).unwrap().of_node);
    }

    #[test]
    fn from_kwargs_requires_annotations() {
        let g = TransformerConfig::tiny().build_graph();
        assert!(Decomposition::from_kwargs(&g).is_err());
    }
}
