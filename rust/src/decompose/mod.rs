//! DAG decomposition into sub-DAGs (paper §3.5, Tables 2–3).
//!
//! "The original complete DAG can be decomposed into sub-DAGs to be
//! reconstructed and executed on different compnodes according to the
//! scheduling." A [`Decomposition`] assigns every node to exactly one
//! sub-graph and derives the Table-3 attributes the executor uses for
//! message passing:
//!
//! * **Inner required data** — producer nodes inside the sub-graph;
//! * **Outer required data** — nodes on *other* compnodes whose outputs this
//!   sub-graph consumes (activations that must arrive over the network);
//! * **Outwards data** — local nodes whose outputs other compnodes consume;
//! * **Compnode users** — the set of downstream sub-graphs.

pub mod passes;

pub use passes::{ChainPartitionPass, SUBGRAPH_KEY};

use std::collections::BTreeSet;

use crate::dag::{flops, Graph, NodeId};

/// One sub-DAG (task unit `G_Sk` of the paper).
#[derive(Debug, Clone)]
pub struct SubGraph {
    pub id: usize,
    /// Node ids of the original graph belonging to this sub-graph.
    pub nodes: Vec<NodeId>,
}

/// A full partition of a graph's nodes into sub-DAGs.
#[derive(Debug, Clone)]
pub struct Decomposition {
    pub subgraphs: Vec<SubGraph>,
    /// node id → subgraph id.
    pub of_node: Vec<usize>,
}

/// Table-3 row for one sub-graph.
#[derive(Debug, Clone)]
pub struct SubGraphAttrs {
    pub subgraph: usize,
    pub inner_required: Vec<NodeId>,
    pub outer_required: Vec<NodeId>,
    pub outwards: Vec<NodeId>,
    pub compnode_users: Vec<usize>,
}

impl Decomposition {
    /// Build from an explicit node→subgraph assignment (ids may be sparse;
    /// they are compacted preserving order of first appearance).
    pub fn from_assignment(g: &Graph, assign: &[(NodeId, usize)]) -> Decomposition {
        assert_eq!(assign.len(), g.len(), "assignment must cover every node");
        let mut ids: Vec<usize> = Vec::new();
        let mut of_node = vec![usize::MAX; g.len()];
        for &(n, raw) in assign {
            let compact = match ids.iter().position(|&r| r == raw) {
                Some(i) => i,
                None => {
                    ids.push(raw);
                    ids.len() - 1
                }
            };
            of_node[n] = compact;
        }
        let mut subgraphs: Vec<SubGraph> =
            (0..ids.len()).map(|id| SubGraph { id, nodes: vec![] }).collect();
        for n in 0..g.len() {
            subgraphs[of_node[n]].nodes.push(n);
        }
        Decomposition { subgraphs, of_node }
    }

    /// Contiguous topological split into `k` parts, balancing forward FLOPs.
    ///
    /// This is the pipeline-parallel decomposition of §4 ("sub-DAGs are
    /// sequentially executed"): nodes are laid out in topological order and
    /// cut into `k` contiguous segments minimizing the maximum segment
    /// weight (exact O(n²k) dynamic program).
    pub fn chain_balanced(g: &Graph, k: usize) -> Decomposition {
        let order = g.topo_order().expect("acyclic");
        let w: Vec<f64> = order.iter().map(|&n| flops::fwd_flops(g.node(n))).collect();
        let cuts = min_max_contiguous(&w, k);
        let mut assign = vec![0usize; g.len()];
        for (seg, window) in cuts.iter().enumerate() {
            for &pos in window {
                assign[order[pos]] = seg;
            }
        }
        let pairs: Vec<(NodeId, usize)> = (0..g.len()).map(|n| (n, assign[n])).collect();
        Decomposition::from_assignment(g, &pairs)
    }

    /// Contiguous topological split balanced **proportionally to device
    /// speeds** (heterogeneous pipeline): segment i's weight should be
    /// ≈ total · speed_i / Σspeed.
    pub fn chain_proportional(g: &Graph, speeds: &[f64]) -> Decomposition {
        let order = g.topo_order().expect("acyclic");
        let w: Vec<f64> = order.iter().map(|&n| flops::fwd_flops(g.node(n))).collect();
        let segs = proportional_contiguous(&w, speeds);
        let mut assign = vec![0usize; g.len()];
        for (seg, window) in segs.iter().enumerate() {
            for &pos in window {
                assign[order[pos]] = seg;
            }
        }
        let pairs: Vec<(NodeId, usize)> = (0..g.len()).map(|n| (n, assign[n])).collect();
        Decomposition::from_assignment(g, &pairs)
    }

    pub fn num_subgraphs(&self) -> usize {
        self.subgraphs.len()
    }

    /// Edges of the original DAG that cross sub-graph boundaries — exactly
    /// the messages that consume communication resources ("black lines" in
    /// Figure 3).
    pub fn cut_edges(&self, g: &Graph) -> Vec<(NodeId, NodeId)> {
        let mut cuts = Vec::new();
        for node in &g.nodes {
            for &a in &node.args {
                if self.of_node[a] != self.of_node[node.id] {
                    cuts.push((a, node.id));
                }
            }
        }
        cuts
    }

    /// Bytes flowing over each cut edge (the activation of the source node).
    pub fn cut_bytes(&self, g: &Graph) -> u64 {
        self.cut_edges(g)
            .iter()
            .map(|&(src, _)| flops::activation_bytes(g.node(src)))
            .sum()
    }

    /// Table-3 attributes for one sub-graph.
    pub fn attrs(&self, g: &Graph, sub: usize) -> SubGraphAttrs {
        let mut inner = BTreeSet::new();
        let mut outer = BTreeSet::new();
        let mut outwards = BTreeSet::new();
        let mut users = BTreeSet::new();
        for &n in &self.subgraphs[sub].nodes {
            for &a in &g.node(n).args {
                if self.of_node[a] == sub {
                    inner.insert(a);
                } else {
                    outer.insert(a);
                }
            }
            for &u in g.users(n) {
                if self.of_node[u] != sub {
                    outwards.insert(n);
                    users.insert(self.of_node[u]);
                }
            }
        }
        SubGraphAttrs {
            subgraph: sub,
            inner_required: inner.into_iter().collect(),
            outer_required: outer.into_iter().collect(),
            outwards: outwards.into_iter().collect(),
            compnode_users: users.into_iter().collect(),
        }
    }

    /// Aggregate forward FLOPs of a sub-graph.
    pub fn sub_flops(&self, g: &Graph, sub: usize) -> f64 {
        self.subgraphs[sub].nodes.iter().map(|&n| flops::fwd_flops(g.node(n))).sum()
    }

    /// Aggregate GPU memory (training) of a sub-graph — `D_gpu(G_Sk)` of Eq. 2.
    pub fn sub_gpu_bytes(&self, g: &Graph, sub: usize) -> u64 {
        self.subgraphs[sub].nodes.iter().map(|&n| flops::gpu_bytes_train(g.node(n))).sum()
    }

    /// Aggregate parameter bytes (what must be checkpointed / synchronized
    /// with the supernode, §3.5).
    pub fn sub_param_bytes(&self, g: &Graph, sub: usize) -> u64 {
        self.subgraphs[sub].nodes.iter().map(|&n| flops::param_bytes(g.node(n))).sum()
    }

    /// Validate the partition invariants (used by property tests):
    /// every node in exactly one sub-graph, ids dense.
    pub fn validate(&self, g: &Graph) -> Result<(), String> {
        if self.of_node.len() != g.len() {
            return Err("of_node length mismatch".into());
        }
        let mut seen = vec![false; g.len()];
        for sg in &self.subgraphs {
            for &n in &sg.nodes {
                if n >= g.len() {
                    return Err(format!("node {n} out of range"));
                }
                if seen[n] {
                    return Err(format!("node {n} in two subgraphs"));
                }
                seen[n] = true;
                if self.of_node[n] != sg.id {
                    return Err(format!("of_node[{n}] inconsistent"));
                }
            }
        }
        if !seen.iter().all(|&s| s) {
            return Err("some node unassigned".into());
        }
        Ok(())
    }
}

/// Exact min-max contiguous partition of `w` into `k` segments (DP).
/// Returns the index ranges of each segment. Segments may be empty only when
/// k > len(w).
fn min_max_contiguous(w: &[f64], k: usize) -> Vec<Vec<usize>> {
    let n = w.len();
    let k = k.min(n.max(1));
    // prefix[i] = sum of w[..i]
    let mut prefix = vec![0.0; n + 1];
    for i in 0..n {
        prefix[i + 1] = prefix[i] + w[i];
    }
    let seg = |a: usize, b: usize| prefix[b] - prefix[a]; // w[a..b]
    // dp[j][i] = minimal max-load splitting w[..i] into j segments
    let mut dp = vec![vec![f64::INFINITY; n + 1]; k + 1];
    let mut cut = vec![vec![0usize; n + 1]; k + 1];
    dp[0][0] = 0.0;
    for j in 1..=k {
        for i in j..=n {
            // last segment = w[m..i]
            for m in (j - 1)..i {
                let cost = dp[j - 1][m].max(seg(m, i));
                if cost < dp[j][i] {
                    dp[j][i] = cost;
                    cut[j][i] = m;
                }
            }
        }
    }
    // Reconstruct.
    let mut bounds = vec![n];
    let mut i = n;
    for j in (1..=k).rev() {
        i = cut[j][i];
        bounds.push(i);
    }
    bounds.reverse(); // 0 = bounds[0] .. bounds[k] = n
    let mut out = Vec::with_capacity(k);
    for s in 0..k {
        out.push((bounds[s]..bounds[s + 1]).collect());
    }
    out
}

/// Contiguous split where segment i receives ≈ `speeds[i]/Σspeeds` of the
/// total weight (greedy sweep; used for heterogeneous pipelines).
fn proportional_contiguous(w: &[f64], speeds: &[f64]) -> Vec<Vec<usize>> {
    let total: f64 = w.iter().sum();
    let sum_speed: f64 = speeds.iter().sum();
    let mut out = Vec::with_capacity(speeds.len());
    let mut pos = 0usize;
    let mut acc_target = 0.0;
    let mut acc = 0.0;
    for (i, &s) in speeds.iter().enumerate() {
        acc_target += total * s / sum_speed;
        let mut seg = Vec::new();
        let last = i == speeds.len() - 1;
        while pos < w.len() && (last || acc + w[pos] / 2.0 < acc_target) {
            acc += w[pos];
            seg.push(pos);
            pos += 1;
        }
        out.push(seg);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::fig3;
    use crate::models::transformer::TransformerConfig;

    #[test]
    fn fig3_table3_attrs() {
        let g = fig3::build();
        let d = Decomposition::from_assignment(&g, &fig3::paper_partition(&g));
        d.validate(&g).unwrap();
        assert_eq!(d.num_subgraphs(), 3);

        let name = |id: NodeId| g.node(id).name.clone();
        // Subgraph 1 (index 0): outward data = Add, Pool; users = {2,3}.
        let a0 = d.attrs(&g, 0);
        let outw: Vec<String> = a0.outwards.iter().map(|&n| name(n)).collect();
        assert_eq!(outw, vec!["Add", "Pool"]);
        assert_eq!(a0.compnode_users, vec![1, 2]);
        assert!(a0.outer_required.is_empty());

        // Subgraph 2: outer required = Add; outwards = Multiply; users = {3}.
        let a1 = d.attrs(&g, 1);
        assert_eq!(a1.outer_required.iter().map(|&n| name(n)).collect::<Vec<_>>(), vec!["Add"]);
        assert_eq!(a1.outwards.iter().map(|&n| name(n)).collect::<Vec<_>>(), vec!["Multiply"]);
        assert_eq!(a1.compnode_users, vec![2]);

        // Subgraph 3: outer required = {Pool, Multiply}; no outwards.
        let a2 = d.attrs(&g, 2);
        let mut outer: Vec<String> = a2.outer_required.iter().map(|&n| name(n)).collect();
        outer.sort();
        assert_eq!(outer, vec!["Multiply", "Pool"]);
        assert!(a2.outwards.is_empty());
        assert!(a2.compnode_users.is_empty());
    }

    #[test]
    fn fig3_cut_edges_match_paper() {
        let g = fig3::build();
        let d = Decomposition::from_assignment(&g, &fig3::paper_partition(&g));
        let cuts: Vec<(String, String)> = d
            .cut_edges(&g)
            .iter()
            .map(|&(a, b)| (g.node(a).name.clone(), g.node(b).name.clone()))
            .collect();
        // Black lines in Figure 3: Add→Multiply, Pool→Concat, Multiply→Concat.
        assert!(cuts.contains(&("Add".into(), "Multiply".into())));
        assert!(cuts.contains(&("Pool".into(), "Concat".into())));
        assert!(cuts.contains(&("Multiply".into(), "Concat".into())));
        assert_eq!(cuts.len(), 3);
    }

    #[test]
    fn chain_balanced_covers_and_balances() {
        let g = TransformerConfig::tiny().build_graph();
        let d = Decomposition::chain_balanced(&g, 4);
        d.validate(&g).unwrap();
        assert_eq!(d.num_subgraphs(), 4);
        let loads: Vec<f64> = (0..4).map(|s| d.sub_flops(&g, s)).collect();
        let max = loads.iter().cloned().fold(0.0, f64::max);
        let total: f64 = loads.iter().sum();
        // Min-max DP: max segment ≤ total/k × slack (model has a huge head
        // node so allow generous slack, but it must beat the trivial bound).
        assert!(max < total, "must actually split");
    }

    #[test]
    fn chain_balanced_respects_topology() {
        // Contiguity in topo order ⇒ all cut edges go forward (lower seg →
        // higher seg).
        let g = TransformerConfig::tiny().build_graph();
        let d = Decomposition::chain_balanced(&g, 3);
        for (src, dst) in d.cut_edges(&g) {
            assert!(d.of_node[src] <= d.of_node[dst]);
        }
    }

    #[test]
    fn minmax_dp_exact_small_case() {
        let w = [3.0, 1.0, 1.0, 3.0];
        let segs = min_max_contiguous(&w, 2);
        // optimal split: [3,1] [1,3] with max 4
        let loads: Vec<f64> =
            segs.iter().map(|s| s.iter().map(|&i| w[i]).sum()).collect();
        assert_eq!(loads, vec![4.0, 4.0]);
    }

    #[test]
    fn proportional_split_tracks_speeds() {
        let w = vec![1.0; 100];
        let segs = proportional_contiguous(&w, &[1.0, 3.0]);
        assert!(segs[0].len() >= 20 && segs[0].len() <= 30, "got {}", segs[0].len());
        assert_eq!(segs[0].len() + segs[1].len(), 100);
    }

    #[test]
    fn bert_large_50way_partition() {
        // Figure 4: Bert-Large over 50 devices.
        let g = TransformerConfig::bert_large().build_graph();
        let d = Decomposition::chain_balanced(&g, 50);
        d.validate(&g).unwrap();
        assert_eq!(d.num_subgraphs(), 50);
        // Every segment non-empty and the load spread is sane.
        let loads: Vec<f64> = (0..50).map(|s| d.sub_flops(&g, s)).collect();
        assert!(loads.iter().all(|&l| l >= 0.0));
        let nonzero = loads.iter().filter(|&&l| l > 0.0).count();
        assert!(nonzero >= 45, "only {nonzero} segments carry work");
    }
}
