//! FusionAI command-line launcher.
//!
//! Subcommands (hand-rolled parser — clap is unavailable offline):
//!
//! ```text
//! fusionai estimate --config <fleet.toml>     analytic latency/throughput (Eq. 3/4)
//! fusionai train    --artifacts <dir> [--steps N] [--microbatches M] [--codec int8|topk|none]
//!                   [--backend xla|sim] [--faults <spec>] [--ckpt-every N]
//!                   [--max-recoveries N] [--backup-nodes N] [--hop-timeout-s S]
//! fusionai serve    --artifacts <dir> [--requests N] [--new-tokens K]
//! fusionai schedule --model <preset> --subtasks K --nodes N --gpu <name>
//! fusionai lint     --graph <g.json> | --model <preset> [--partition K] [--emit <out.json>]
//! fusionai info                                GPU database + trend summary
//! ```

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use fusionai::benchutil::Table;
use fusionai::cluster::{
    FaultPlan, PipelineTrainer, SimStageFactory, SimStagesConfig, TrainConfig,
};
use fusionai::compress::Codec;
use fusionai::config::{model_by_name, ExperimentConfig};
use fusionai::dag::autodiff::backward_plan;
use fusionai::dag::{Graph, GraphPass};
use fusionai::decompose::{ChainPartitionPass, Decomposition};
use fusionai::exec::ExecPlan;
use fusionai::perf::gpus::{lookup, GPU_DB};
use fusionai::perf::paleo::{DeviceProfile, PaleoModel};
use fusionai::perf::trends;
use fusionai::pipeline::analytics::PipelineEstimate;
use fusionai::sched;
use fusionai::serve::{run_trace, InferenceServer, Request};
use fusionai::util::{human_bytes, human_flops, human_secs, Rng};
use fusionai::verify::{check_plan, lint_graph};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else {
        print_usage();
        return Ok(());
    };
    let flags = parse_flags(&args[1..])?;
    match cmd.as_str() {
        "estimate" => cmd_estimate(&flags),
        "train" => cmd_train(&flags),
        "serve" => cmd_serve(&flags),
        "schedule" => cmd_schedule(&flags),
        "lint" => cmd_lint(&flags),
        "info" => cmd_info(),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown subcommand '{other}' (try `fusionai help`)"),
    }
}

fn print_usage() {
    println!(
        "fusionai — decentralized LLM training/serving on consumer GPUs\n\
         \n\
         usage:\n\
           fusionai estimate --config <fleet.toml>\n\
           fusionai train    --artifacts <dir> [--steps N] [--microbatches M] [--codec int8|topk|none]\n\
                             [--backend xla|sim] [--faults <spec>] [--ckpt-every N]\n\
                             [--max-recoveries N] [--backup-nodes N] [--hop-timeout-s S]\n\
           fusionai serve    --artifacts <dir> [--requests N] [--new-tokens K]\n\
           fusionai schedule --model <preset> --subtasks K --nodes N --gpu <name>\n\
           fusionai lint     --graph <g.json> | --model <preset> [--partition K] [--emit <out.json>]\n\
           fusionai info\n"
    );
}

fn parse_flags(args: &[String]) -> Result<HashMap<String, String>> {
    let mut map = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        let key = args[i]
            .strip_prefix("--")
            .ok_or_else(|| anyhow!("expected --flag, got '{}'", args[i]))?;
        let val = args.get(i + 1).ok_or_else(|| anyhow!("--{key} needs a value"))?;
        map.insert(key.to_string(), val.clone());
        i += 2;
    }
    Ok(map)
}

fn flag_usize(flags: &HashMap<String, String>, key: &str, default: usize) -> Result<usize> {
    match flags.get(key) {
        None => Ok(default),
        Some(v) => v.parse().map_err(|_| anyhow!("--{key} wants an integer, got '{v}'")),
    }
}

/// `estimate`: the paper's §4 analysis on a user-described fleet.
fn cmd_estimate(flags: &HashMap<String, String>) -> Result<()> {
    let path = flags.get("config").ok_or_else(|| anyhow!("estimate needs --config"))?;
    let cfg = ExperimentConfig::from_toml(&std::fs::read_to_string(path)?)?;
    let g = cfg.model.build_graph();
    let n: usize = cfg.total_devices();
    println!(
        "model {} | {} params | {} fwd FLOPs/batch | {} devices",
        cfg.model.name,
        cfg.model.param_count(),
        human_flops(g.total_fwd_flops()),
        n
    );
    let d = Decomposition::chain_balanced(&g, n);
    let mut models = Vec::new();
    for f in &cfg.fleet {
        for _ in 0..f.count {
            models.push(PaleoModel::new(DeviceProfile::with_lambda(&f.gpu, f.lambda)));
        }
    }
    let est = PipelineEstimate::from_decomposition(&g, &d, &models, cfg.link, cfg.training);
    println!("latency (Eq.3, 1 batch):        {}", human_secs(est.latency()));
    println!(
        "pipelined (Eq.4, {} batches):  {}",
        cfg.batches,
        human_secs(est.pipelined_time(cfg.batches))
    );
    println!(
        "throughput @n_b={}:            {:.3} batches/s (steady-state {:.3})",
        cfg.batches,
        est.throughput(cfg.batches),
        est.steady_state_throughput()
    );
    println!("bubble fraction:               {:.1}%", est.bubble_fraction(cfg.batches) * 100.0);
    println!("comm-bound:                    {}", est.comm_bound());
    Ok(())
}

/// `train`: the live pipeline trainer under supervision.
fn cmd_train(flags: &HashMap<String, String>) -> Result<()> {
    let backend = flags.get("backend").map(String::as_str).unwrap_or("xla");
    // The sim backend needs no compiled artifacts; its dir only holds
    // checkpoints.
    let dir = match flags.get("artifacts") {
        Some(d) => d.clone(),
        None if backend == "sim" => "artifacts/sim".to_string(),
        None => bail!("train needs --artifacts (unless --backend sim)"),
    };
    let mut cfg = TrainConfig::new(dir);
    cfg.steps = flag_usize(flags, "steps", 50)?;
    cfg.microbatches = flag_usize(flags, "microbatches", 2)?;
    cfg.codec = match flags.get("codec").map(String::as_str) {
        None | Some("none") => None,
        Some("int8") => Some(Codec::Int8),
        Some("topk") => Some(Codec::TopK { ratio: 0.1 }),
        Some(other) => bail!("unknown codec '{other}'"),
    };
    cfg.ckpt_every = flag_usize(flags, "ckpt-every", cfg.ckpt_every)?;
    cfg.max_recoveries = flag_usize(flags, "max-recoveries", cfg.max_recoveries)?;
    cfg.backup_nodes = flag_usize(flags, "backup-nodes", cfg.backup_nodes)?;
    cfg.hop_timeout_s = flag_f64(flags, "hop-timeout-s", cfg.hop_timeout_s)?;
    if let Some(spec) = flags.get("faults") {
        cfg.faults = Some(Arc::new(FaultPlan::parse(spec)?));
    }
    let trainer = match backend {
        "xla" => PipelineTrainer::new(cfg)?,
        "sim" => {
            let sim = SimStagesConfig::default();
            let manifest = sim.manifest();
            PipelineTrainer::with_backend(cfg, manifest, Arc::new(SimStageFactory { cfg: sim }))?
        }
        other => bail!("unknown backend '{other}' (xla|sim)"),
    };
    println!(
        "training preset '{}' for {} steps × {} microbatches over {} stages",
        trainer.manifest.preset,
        trainer.config.steps,
        trainer.config.microbatches,
        trainer.manifest.stages.len()
    );
    let report = trainer.run()?;
    if let (Some((s0, l0)), Some((s1, l1))) = (report.losses.first(), report.losses.last()) {
        println!("loss: step {s0} = {l0:.4}  →  step {s1} = {l1:.4}");
    }
    println!(
        "wall {:.1}s | {:.0} tokens/s | comm {} (modelled WAN time {})",
        report.wall_seconds,
        report.tokens_per_second,
        human_bytes(report.comm_bytes),
        human_secs(report.comm_model_seconds)
    );
    if report.recoveries > 0 || report.stage_failures > 0 || report.messages_dropped > 0 {
        println!(
            "recovery: {} restart(s) over {} stage failure(s) | {} checkpoint(s) written | \
             {} message(s) dropped",
            report.recoveries,
            report.stage_failures,
            report.checkpoints_written,
            report.messages_dropped
        );
        for ev in &report.broker_events {
            println!("  broker: {ev:?}");
        }
    }
    Ok(())
}

fn flag_f64(flags: &HashMap<String, String>, key: &str, default: f64) -> Result<f64> {
    match flags.get(key) {
        None => Ok(default),
        Some(v) => v.parse().map_err(|_| anyhow!("--{key} wants a number, got '{v}'")),
    }
}

/// `serve`: batched greedy-decoding inference.
fn cmd_serve(flags: &HashMap<String, String>) -> Result<()> {
    let dir = flags.get("artifacts").ok_or_else(|| anyhow!("serve needs --artifacts"))?;
    let n_requests = flag_usize(flags, "requests", 16)?;
    let n_new = flag_usize(flags, "new-tokens", 8)?;
    let server = InferenceServer::load(std::path::Path::new(dir), 7)?;
    let mut rng = Rng::new(123);
    let prompt_len = (server.seq / 4).max(1);
    let requests: Vec<Request> = (0..n_requests)
        .map(|id| Request {
            id,
            prompt: (0..prompt_len)
                .map(|_| rng.below(server.vocab as u64) as i32)
                .collect(),
            arrival_s: id as f64 * 0.01,
        })
        .collect();
    let (responses, stats) = run_trace(&server, requests, n_new)?;
    println!(
        "served {} requests in {:.2}s | {:.2} req/s | {:.1} tokens/s | p50 latency {} | p99 {}",
        stats.completed,
        stats.wall_seconds,
        stats.requests_per_second,
        stats.tokens_per_second,
        human_secs(stats.latency.median()),
        human_secs(stats.latency.p99()),
    );
    println!("first response: {:?}", &responses[0].tokens[..responses[0].tokens.len().min(16)]);
    Ok(())
}

/// `schedule`: show the Eq.2 assignment for a preset over a uniform fleet.
fn cmd_schedule(flags: &HashMap<String, String>) -> Result<()> {
    let model = model_by_name(flags.get("model").map(String::as_str).unwrap_or("bert-large"))?;
    let subtasks = flag_usize(flags, "subtasks", 50)?;
    let nodes = flag_usize(flags, "nodes", 50)?;
    let gpu_name = flags.get("gpu").map(String::as_str).unwrap_or("RTX 3080");
    let gpu = lookup(gpu_name).ok_or_else(|| anyhow!("unknown GPU '{gpu_name}'"))?;
    let g = model.build_graph();
    let d = Decomposition::chain_balanced(&g, subtasks);
    let tasks = sched::build::tasks_from_decomposition(&g, &d, true);
    let peers = sched::build::uniform_peers(gpu, 0.5, nodes);
    let s = sched::schedule(&tasks, &peers)?;
    println!(
        "{} sub-tasks over {}×{} | makespan {} | load spread {:.1}%",
        subtasks,
        nodes,
        gpu.name,
        human_secs(s.makespan()),
        100.0 * (s.makespan() - s.loads.iter().cloned().fold(f64::INFINITY, f64::min))
            / s.makespan()
    );
    Ok(())
}

/// `lint`: run the static verifier over a graph (JSON file or preset) and
/// its compiled execution plan. Exits non-zero on any error diagnostic.
fn cmd_lint(flags: &HashMap<String, String>) -> Result<()> {
    let mut g: Graph = match (flags.get("graph"), flags.get("model")) {
        (Some(path), _) => {
            Graph::from_json(&std::fs::read_to_string(path)?).map_err(|e| anyhow!("{path}: {e}"))?
        }
        (None, Some(preset)) => model_by_name(preset)?.build_graph(),
        (None, None) => bail!("lint needs --graph <g.json> or --model <preset>"),
    };
    if let Some(k) = flags.get("partition") {
        let k: usize = k.parse().map_err(|_| anyhow!("--partition wants an integer, got '{k}'"))?;
        ChainPartitionPass::new(k)
            .run(&mut g)
            .map_err(|e| anyhow!("partitioning failed: {e}"))?;
    }
    if let Some(out) = flags.get("emit") {
        std::fs::write(out, g.to_json())?;
        println!("wrote {out}");
    }
    println!(
        "graph: {} node(s) | {} trainable | {} loss node(s) | {} fwd FLOPs",
        g.len(),
        g.trainable_nodes().len(),
        g.loss_nodes().len(),
        human_flops(g.total_fwd_flops())
    );
    let mut report = lint_graph(&g);
    if !report.has_errors() {
        // The graph is sound — compile its plan and verify that too.
        let bwd = backward_plan(&g);
        let plan = ExecPlan::compile_full(&g, &bwd)?;
        println!(
            "plan:  {} fwd wave(s) (max width {}) | {} bwd wave(s) | {} bwd task(s)",
            plan.waves.len(),
            plan.max_wave_width(),
            plan.bwd_waves.len(),
            plan.bwd_order.len()
        );
        report.merge(check_plan(&g, &bwd, &plan));
    }
    println!("{}", report.render());
    if report.has_errors() {
        bail!("{} error diagnostic(s)", report.error_count());
    }
    Ok(())
}

/// `info`: Table 1 + Figure 1 summaries.
fn cmd_info() -> Result<()> {
    let mut t = Table::new(&["GPU", "TFLOPS (FP32)", "TFLOPS (Tensor)", "Memory", "Level", "Price"]);
    for g in GPU_DB {
        t.row(&[
            g.name.to_string(),
            format!("{:.2}", g.tflops_fp32),
            format!("{:.2}", g.tflops_tensor),
            format!("{:.0} GB", g.memory_gb),
            g.level.to_string(),
            format!("${:.0}", g.price_usd),
        ]);
    }
    t.print();
    let (model_cagr, gpu_cagr) = trends::growth_gap();
    println!(
        "\nFigure-1 trend: model-memory CAGR {:.0}%/yr vs GPU-memory CAGR {:.0}%/yr",
        model_cagr * 100.0,
        gpu_cagr * 100.0
    );
    Ok(())
}
