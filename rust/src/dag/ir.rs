//! Core IR types: dtypes, shapes, operator kinds, nodes and the graph.
//!
//! This is the data model of the IR plane (paper §3.5, Table 2). Everything
//! that *transforms* a graph lives in [`crate::dag::passes`]; everything
//! that moves a graph across the wire lives in [`crate::dag::serde`].

use std::collections::BTreeMap;
use std::fmt;

/// Node identifier within one [`Graph`] (dense, 0-based).
pub type NodeId = usize;

/// Element type of a tensor edge.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    pub fn size_bytes(self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
        }
    }
}

impl fmt::Display for DType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            DType::F32 => write!(f, "f32"),
            DType::I32 => write!(f, "i32"),
        }
    }
}

/// Tensor shape (row-major).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Shape(pub Vec<usize>);

impl Shape {
    pub fn scalar() -> Shape {
        Shape(vec![])
    }
    pub fn of(dims: &[usize]) -> Shape {
        Shape(dims.to_vec())
    }
    pub fn rank(&self) -> usize {
        self.0.len()
    }
    pub fn numel(&self) -> usize {
        self.0.iter().product()
    }
    pub fn bytes(&self, dt: DType) -> usize {
        self.numel() * dt.size_bytes()
    }
    pub fn dims(&self) -> &[usize] {
        &self.0
    }
}

impl fmt::Display for Shape {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "[")?;
        for (i, d) in self.0.iter().enumerate() {
            if i > 0 {
                write!(f, ",")?;
            }
            write!(f, "{}", d)?;
        }
        write!(f, "]")
    }
}

/// Operator kind. Structural hyperparameters live inside the variant;
/// everything needed for shape inference, FLOP counting and reference
/// execution is here.
#[derive(Debug, Clone, PartialEq)]
pub enum OpKind {
    /// Leaf input without gradient (inputs, labels). Paper: "Placeholder".
    Placeholder,
    /// Leaf tensor that is optimized directly. Paper: "Variable".
    Variable,
    /// 2-D convolution over NCHW. Parametric (weight + bias).
    Conv2d { in_ch: usize, out_ch: usize, kernel: usize, stride: usize, padding: usize },
    /// Affine layer `y = xW + b` over the last axis. Parametric.
    Linear { in_features: usize, out_features: usize, bias: bool },
    /// Token embedding lookup. Parametric (table `[vocab, dim]`).
    Embedding { vocab: usize, dim: usize },
    /// Layer normalization over the last axis. Parametric (γ, β).
    LayerNorm { dim: usize },
    /// Multi-head self-attention over `[B, S, D]` (QKV + output projection).
    /// Parametric. The L1 Pallas kernel implements this operator's core.
    Attention { heads: usize, dim: usize, causal: bool },
    /// Transformer FFN block `W2·gelu(W1·x)`. Parametric.
    FeedForward { dim: usize, hidden: usize },
    /// Elementwise addition (broadcast on equal shapes only).
    Add,
    /// Elementwise multiplication.
    Multiply,
    /// ReLU.
    Relu,
    /// GELU (tanh approximation).
    Gelu,
    /// Softmax over the last axis.
    Softmax,
    /// 2-D max pooling over NCHW.
    MaxPool2d { kernel: usize, stride: usize },
    /// Concatenate along an axis.
    Concat { axis: usize },
    /// Mean cross-entropy between logits `[N, C]` (or `[B, S, C]`) and
    /// integer labels. Loss function.
    CrossEntropy { weight: f64 },
    /// Mean squared error between two equal-shaped tensors. Loss function.
    MseLoss,
    /// Coarse-grained pipeline-stage operator backed by an AOT-compiled XLA
    /// artifact (the e2e training path). `stage` names the artifact set in
    /// the manifest; parameters live in the artifact's flat param list.
    StageCall { stage: String, param_count: usize, flops: f64, param_bytes: u64 },
}

impl OpKind {
    /// Paper Table 2 "Type" column.
    pub fn category(&self) -> OpCategory {
        use OpKind::*;
        match self {
            Placeholder => OpCategory::Placeholder,
            Variable => OpCategory::Variable,
            Conv2d { .. } | Linear { .. } | Embedding { .. } | LayerNorm { .. }
            | Attention { .. } | FeedForward { .. } => OpCategory::Parametric,
            StageCall { param_count, .. } => {
                if *param_count > 0 {
                    OpCategory::Parametric
                } else {
                    OpCategory::NonParametric
                }
            }
            Add | Multiply | Relu | Gelu | Softmax | MaxPool2d { .. } | Concat { .. } => {
                OpCategory::NonParametric
            }
            CrossEntropy { .. } | MseLoss => OpCategory::Loss,
        }
    }

    /// Short display name used in tables and DOT dumps.
    pub fn name(&self) -> &'static str {
        use OpKind::*;
        match self {
            Placeholder => "Placeholder",
            Variable => "Variable",
            Conv2d { .. } => "Conv",
            Linear { .. } => "Linear",
            Embedding { .. } => "Embedding",
            LayerNorm { .. } => "LayerNorm",
            Attention { .. } => "Attention",
            FeedForward { .. } => "FeedForward",
            Add => "Add",
            Multiply => "Multiply",
            Relu => "Relu",
            Gelu => "Gelu",
            Softmax => "Softmax",
            MaxPool2d { .. } => "Pool",
            Concat { .. } => "Concat",
            CrossEntropy { .. } => "CrossEntropy",
            MseLoss => "MseLoss",
            StageCall { .. } => "StageCall",
        }
    }
}

/// Paper Table 2 operator categories.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpCategory {
    Placeholder,
    Variable,
    Parametric,
    NonParametric,
    Loss,
}

impl fmt::Display for OpCategory {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            OpCategory::Placeholder => "Placeholder",
            OpCategory::Variable => "Variable",
            OpCategory::Parametric => "Parametric OP",
            OpCategory::NonParametric => "Non-Parametric OP",
            OpCategory::Loss => "Loss Function",
        };
        write!(f, "{s}")
    }
}

/// One operator node (paper Table 2 row).
#[derive(Debug, Clone)]
pub struct Node {
    pub id: NodeId,
    /// Human-readable unique name ("Conv", "layer3.attn", …).
    pub name: String,
    pub kind: OpKind,
    /// Data dependencies: which nodes' outputs feed this op (Table 2 "Args").
    pub args: Vec<NodeId>,
    /// Constant attributes (Table 2 "Kwargs").
    pub kwargs: BTreeMap<String, String>,
    /// Inferred output shape/dtype.
    pub out_shape: Shape,
    pub out_dtype: DType,
}

/// The forward-pass DAG.
#[derive(Debug, Clone, Default)]
pub struct Graph {
    pub nodes: Vec<Node>,
    /// Reverse adjacency, kept in sync by the builder (Table 2 "OP users").
    users: Vec<Vec<NodeId>>,
}

/// Shape-inference or construction error.
#[derive(Debug)]
pub enum GraphError {
    Shape { op: String, msg: String },
    UnknownNode(NodeId),
    Cycle(NodeId),
    DuplicateName(String),
    Invalid(String),
}

impl std::fmt::Display for GraphError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            GraphError::Shape { op, msg } => write!(f, "shape mismatch at op '{op}': {msg}"),
            GraphError::UnknownNode(id) => write!(f, "unknown node id {id}"),
            GraphError::Cycle(id) => write!(f, "graph has a cycle involving node {id}"),
            GraphError::DuplicateName(name) => write!(f, "duplicate node name '{name}'"),
            GraphError::Invalid(msg) => write!(f, "invalid graph: {msg}"),
        }
    }
}

impl std::error::Error for GraphError {}

impl Graph {
    pub fn new() -> Graph {
        Graph::default()
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id]
    }

    /// Nodes consuming `id`'s output (paper Table 2 "OP users").
    pub fn users(&self, id: NodeId) -> &[NodeId] {
        &self.users[id]
    }

    pub fn by_name(&self, name: &str) -> Option<&Node> {
        self.nodes.iter().find(|n| n.name == name)
    }

    /// Add a leaf placeholder (input/label).
    pub fn placeholder(&mut self, name: &str, shape: Shape, dtype: DType) -> NodeId {
        self.push(name, OpKind::Placeholder, vec![], shape, dtype).unwrap()
    }

    /// Add an optimizable variable leaf.
    pub fn variable(&mut self, name: &str, shape: Shape) -> NodeId {
        self.push(name, OpKind::Variable, vec![], shape, DType::F32).unwrap()
    }

    /// Add an operator, inferring its output shape from its arguments.
    pub fn op(&mut self, name: &str, kind: OpKind, args: &[NodeId]) -> Result<NodeId, GraphError> {
        for &a in args {
            if a >= self.nodes.len() {
                return Err(GraphError::UnknownNode(a));
            }
        }
        let arg_shapes: Vec<(&Shape, DType)> =
            args.iter().map(|&a| (&self.nodes[a].out_shape, self.nodes[a].out_dtype)).collect();
        let (shape, dtype) = infer_shape(name, &kind, &arg_shapes)?;
        self.push(name, kind, args.to_vec(), shape, dtype)
    }

    /// Attach a constant attribute to a node (Table 2 "Kwargs").
    pub fn set_kwarg(&mut self, id: NodeId, key: &str, val: &str) {
        self.nodes[id].kwargs.insert(key.to_string(), val.to_string());
    }

    /// Append an extra data dependency to an existing node, keeping the
    /// reverse adjacency in sync. Used by coarse-graph builders that add
    /// edges (e.g. labels into a pipeline head) after construction.
    pub fn add_arg(&mut self, id: NodeId, arg: NodeId) {
        assert!(arg < self.nodes.len() && id < self.nodes.len());
        self.nodes[id].args.push(arg);
        self.users[arg].push(id);
    }

    fn push(
        &mut self,
        name: &str,
        kind: OpKind,
        args: Vec<NodeId>,
        shape: Shape,
        dtype: DType,
    ) -> Result<NodeId, GraphError> {
        if self.nodes.iter().any(|n| n.name == name) {
            return Err(GraphError::DuplicateName(name.to_string()));
        }
        let id = self.nodes.len();
        for &a in &args {
            self.users[a].push(id);
        }
        self.nodes.push(Node {
            id,
            name: name.to_string(),
            kind,
            args,
            kwargs: BTreeMap::new(),
            out_shape: shape,
            out_dtype: dtype,
        });
        self.users.push(Vec::new());
        Ok(id)
    }

    /// Rebuild a graph from raw nodes (the deserialization path). Validates
    /// dense ids, arg bounds, unique names and acyclicity.
    pub fn from_nodes(nodes: Vec<Node>) -> Result<Graph, GraphError> {
        let n = nodes.len();
        let mut names = std::collections::BTreeSet::new();
        for (i, node) in nodes.iter().enumerate() {
            if node.id != i {
                return Err(GraphError::Invalid(format!(
                    "node id {} at index {i} (ids must be dense)",
                    node.id
                )));
            }
            if !names.insert(node.name.as_str()) {
                return Err(GraphError::DuplicateName(node.name.clone()));
            }
            for &a in &node.args {
                if a >= n {
                    return Err(GraphError::UnknownNode(a));
                }
            }
        }
        drop(names);
        let mut g = Graph { nodes, users: Vec::new() };
        g.rebuild_users();
        g.topo_order()?; // rejects cycles
        Ok(g)
    }

    /// Recompute the reverse adjacency from scratch (used after pass
    /// rewrites and deserialization).
    pub(crate) fn rebuild_users(&mut self) {
        self.users = vec![Vec::new(); self.nodes.len()];
        for i in 0..self.nodes.len() {
            for &a in &self.nodes[i].args {
                self.users[a].push(i);
            }
        }
    }

    /// Redirect every consumer of `from` to read `to` instead. Returns how
    /// many argument slots moved. Used by folding passes; the `from` node is
    /// left in place (dead) for a later DCE sweep.
    pub fn redirect_users(&mut self, from: NodeId, to: NodeId) -> usize {
        if from == to {
            return 0;
        }
        let mut moved = 0;
        for node in self.nodes.iter_mut() {
            for a in node.args.iter_mut() {
                if *a == from {
                    *a = to;
                    moved += 1;
                }
            }
        }
        if moved > 0 {
            self.rebuild_users();
        }
        moved
    }

    /// Drop every node whose `live` flag is false, compacting ids. Returns
    /// the old-id → new-id mapping (`None` for removed nodes). Callers must
    /// ensure no live node references a dead one.
    pub fn retain_nodes(&mut self, live: &[bool]) -> Result<Vec<Option<NodeId>>, GraphError> {
        assert_eq!(live.len(), self.nodes.len());
        let mut remap: Vec<Option<NodeId>> = vec![None; self.nodes.len()];
        let mut next = 0;
        for (i, &keep) in live.iter().enumerate() {
            if keep {
                remap[i] = Some(next);
                next += 1;
            }
        }
        for node in &self.nodes {
            if !live[node.id] {
                continue;
            }
            for &a in &node.args {
                if remap[a].is_none() {
                    return Err(GraphError::Invalid(format!(
                        "live node '{}' consumes dead node {a}",
                        node.name
                    )));
                }
            }
        }
        let old = std::mem::take(&mut self.nodes);
        self.nodes = old
            .into_iter()
            .filter(|n| live[n.id])
            .map(|mut n| {
                n.id = remap[n.id].unwrap();
                n.args = n.args.iter().map(|&a| remap[a].unwrap()).collect();
                n
            })
            .collect();
        self.rebuild_users();
        Ok(remap)
    }

    /// Kahn topological order. Errors with [`GraphError::Cycle`] if the edge
    /// set is cyclic (cannot normally happen through the builder API, but
    /// deserialized graphs are validated through this).
    pub fn topo_order(&self) -> Result<Vec<NodeId>, GraphError> {
        let n = self.nodes.len();
        let mut indeg = vec![0usize; n];
        for node in &self.nodes {
            indeg[node.id] = node.args.len();
        }
        let mut queue: Vec<NodeId> = (0..n).filter(|&i| indeg[i] == 0).collect();
        let mut order = Vec::with_capacity(n);
        let mut head = 0;
        while head < queue.len() {
            let u = queue[head];
            head += 1;
            order.push(u);
            for &v in &self.users[u] {
                indeg[v] -= 1;
                if indeg[v] == 0 {
                    queue.push(v);
                }
            }
        }
        if order.len() != n {
            let stuck = (0..n).find(|&i| indeg[i] > 0).unwrap();
            return Err(GraphError::Cycle(stuck));
        }
        Ok(order)
    }

    /// All loss nodes (graph sinks for training).
    pub fn loss_nodes(&self) -> Vec<NodeId> {
        self.nodes
            .iter()
            .filter(|n| n.kind.category() == OpCategory::Loss)
            .map(|n| n.id)
            .collect()
    }

    /// Parametric nodes + variables — everything the Update task optimizes.
    pub fn trainable_nodes(&self) -> Vec<NodeId> {
        self.nodes
            .iter()
            .filter(|n| {
                matches!(n.kind.category(), OpCategory::Parametric | OpCategory::Variable)
            })
            .map(|n| n.id)
            .collect()
    }

    /// Total trainable parameter count (elements, not bytes).
    pub fn param_count(&self) -> u64 {
        self.nodes.iter().map(|n| super::flops::param_count(n) as u64).sum()
    }

    /// Total forward FLOPs of the whole graph.
    pub fn total_fwd_flops(&self) -> f64 {
        self.nodes.iter().map(super::flops::fwd_flops).sum()
    }

    /// Override a node's output shape (used by coarse `StageCall` builders
    /// where the artifact, not the IR, is the source of shape truth).
    pub fn set_shape(&mut self, id: NodeId, shape: Shape, dtype: DType) {
        self.nodes[id].out_shape = shape;
        self.nodes[id].out_dtype = dtype;
    }

    /// Render as GraphViz DOT (debugging / docs).
    pub fn to_dot(&self) -> String {
        let mut s = String::from("digraph G {\n  rankdir=LR;\n");
        for n in &self.nodes {
            let color = match n.kind.category() {
                OpCategory::Placeholder => "lightgray",
                OpCategory::Variable => "lightyellow",
                OpCategory::Parametric => "lightblue",
                OpCategory::NonParametric => "white",
                OpCategory::Loss => "lightcoral",
            };
            s.push_str(&format!(
                "  n{} [label=\"{}\\n{}\" style=filled fillcolor={}];\n",
                n.id, n.name, n.out_shape, color
            ));
        }
        for n in &self.nodes {
            for &a in &n.args {
                s.push_str(&format!("  n{} -> n{};\n", a, n.id));
            }
        }
        s.push_str("}\n");
        s
    }
}

/// Shape inference for every operator kind. Public so passes (and alternate
/// frontends) can re-derive shapes without going through the builder.
pub fn infer_shape(
    op_name: &str,
    kind: &OpKind,
    args: &[(&Shape, DType)],
) -> Result<(Shape, DType), GraphError> {
    use OpKind::*;
    let err = |msg: String| GraphError::Shape { op: op_name.to_string(), msg };
    let need = |n: usize| -> Result<(), GraphError> {
        if args.len() != n {
            Err(GraphError::Shape {
                op: op_name.to_string(),
                msg: format!("expected {} args, got {}", n, args.len()),
            })
        } else {
            Ok(())
        }
    };
    match kind {
        Placeholder | Variable => unreachable!("leaves are added via dedicated builders"),
        Conv2d { in_ch, out_ch, kernel, stride, padding } => {
            need(1)?;
            let s = args[0].0.dims();
            if s.len() != 4 || s[1] != *in_ch {
                return Err(err(format!("Conv2d wants [N,{},H,W], got {}", in_ch, args[0].0)));
            }
            let h = (s[2] + 2 * padding - kernel) / stride + 1;
            let w = (s[3] + 2 * padding - kernel) / stride + 1;
            Ok((Shape::of(&[s[0], *out_ch, h, w]), DType::F32))
        }
        Linear { in_features, out_features, .. } => {
            need(1)?;
            let s = args[0].0.dims();
            if s.is_empty() || *s.last().unwrap() != *in_features {
                return Err(err(format!(
                    "Linear wants [..,{}], got {}",
                    in_features, args[0].0
                )));
            }
            let mut out = s.to_vec();
            *out.last_mut().unwrap() = *out_features;
            Ok((Shape(out), DType::F32))
        }
        Embedding { dim, .. } => {
            need(1)?;
            if args[0].1 != DType::I32 {
                return Err(err("Embedding wants i32 token ids".into()));
            }
            let mut out = args[0].0.dims().to_vec();
            out.push(*dim);
            Ok((Shape(out), DType::F32))
        }
        LayerNorm { dim } => {
            need(1)?;
            if args[0].0.dims().last() != Some(dim) {
                return Err(err(format!("LayerNorm dim {} vs input {}", dim, args[0].0)));
            }
            Ok((args[0].0.clone(), DType::F32))
        }
        Attention { dim, heads, .. } => {
            need(1)?;
            let s = args[0].0.dims();
            if s.len() != 3 || s[2] != *dim {
                return Err(err(format!("Attention wants [B,S,{}], got {}", dim, args[0].0)));
            }
            if dim % heads != 0 {
                return Err(err(format!("dim {} not divisible by heads {}", dim, heads)));
            }
            Ok((args[0].0.clone(), DType::F32))
        }
        FeedForward { dim, .. } => {
            need(1)?;
            if args[0].0.dims().last() != Some(dim) {
                return Err(err(format!("FeedForward dim {} vs input {}", dim, args[0].0)));
            }
            Ok((args[0].0.clone(), DType::F32))
        }
        Add | Multiply => {
            need(2)?;
            if args[0].0 != args[1].0 {
                return Err(err(format!("elementwise {} vs {}", args[0].0, args[1].0)));
            }
            Ok((args[0].0.clone(), DType::F32))
        }
        Relu | Gelu | Softmax => {
            need(1)?;
            Ok((args[0].0.clone(), DType::F32))
        }
        MaxPool2d { kernel, stride } => {
            need(1)?;
            let s = args[0].0.dims();
            if s.len() != 4 {
                return Err(err(format!("MaxPool2d wants NCHW, got {}", args[0].0)));
            }
            let h = (s[2] - kernel) / stride + 1;
            let w = (s[3] - kernel) / stride + 1;
            Ok((Shape::of(&[s[0], s[1], h, w]), DType::F32))
        }
        Concat { axis } => {
            if args.is_empty() {
                return Err(err("Concat needs ≥1 arg".into()));
            }
            let base = args[0].0.dims();
            if *axis >= base.len() {
                return Err(err(format!("axis {} out of rank {}", axis, base.len())));
            }
            let mut out = base.to_vec();
            for (s, _) in &args[1..] {
                let d = s.dims();
                if d.len() != base.len() {
                    return Err(err("rank mismatch in Concat".into()));
                }
                for (i, (&a, &b)) in base.iter().zip(d).enumerate() {
                    if i != *axis && a != b {
                        return Err(err(format!("dim {} mismatch: {} vs {}", i, a, b)));
                    }
                }
                out[*axis] += d[*axis];
            }
            Ok((Shape(out), DType::F32))
        }
        CrossEntropy { .. } => {
            need(2)?;
            // args: (labels i32 [..], logits f32 [.., C]) in either order.
            Ok((Shape::scalar(), DType::F32))
        }
        MseLoss => {
            need(2)?;
            if args[0].0 != args[1].0 {
                return Err(err("MSE wants equal shapes".into()));
            }
            Ok((Shape::scalar(), DType::F32))
        }
        StageCall { .. } => {
            // Stage ops are shape-opaque at the IR level: output shape equals
            // declared activation shape = first arg's shape by convention for
            // mid-pipeline stages; builders override via `set_shape` when the
            // stage changes shape (embed / head).
            need(1).or(Ok(()))?;
            Ok((args.first().map(|(s, _)| (*s).clone()).unwrap_or(Shape::scalar()), DType::F32))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mlp() -> Graph {
        let mut g = Graph::new();
        let x = g.placeholder("x", Shape::of(&[8, 32]), DType::F32);
        let y = g.placeholder("y", Shape::of(&[8, 16]), DType::F32);
        let h = g
            .op("fc1", OpKind::Linear { in_features: 32, out_features: 64, bias: true }, &[x])
            .unwrap();
        let r = g.op("relu", OpKind::Relu, &[h]).unwrap();
        let o = g
            .op("fc2", OpKind::Linear { in_features: 64, out_features: 16, bias: true }, &[r])
            .unwrap();
        g.op("loss", OpKind::MseLoss, &[o, y]).unwrap();
        g
    }

    #[test]
    fn build_and_topo() {
        let g = mlp();
        assert_eq!(g.len(), 6);
        let order = g.topo_order().unwrap();
        // every arg precedes its user
        let pos: Vec<usize> = {
            let mut p = vec![0; g.len()];
            for (i, &n) in order.iter().enumerate() {
                p[n] = i;
            }
            p
        };
        for n in &g.nodes {
            for &a in &n.args {
                assert!(pos[a] < pos[n.id]);
            }
        }
    }

    #[test]
    fn users_tracked() {
        let g = mlp();
        let x = g.by_name("x").unwrap().id;
        let fc1 = g.by_name("fc1").unwrap().id;
        assert_eq!(g.users(x), &[fc1]);
    }

    #[test]
    fn linear_shape() {
        let g = mlp();
        assert_eq!(g.by_name("fc1").unwrap().out_shape, Shape::of(&[8, 64]));
        assert_eq!(g.by_name("loss").unwrap().out_shape, Shape::scalar());
    }

    #[test]
    fn shape_errors() {
        let mut g = Graph::new();
        let x = g.placeholder("x", Shape::of(&[8, 32]), DType::F32);
        assert!(g
            .op("bad", OpKind::Linear { in_features: 99, out_features: 4, bias: true }, &[x])
            .is_err());
        let y = g.placeholder("y", Shape::of(&[4, 32]), DType::F32);
        assert!(g.op("bad_add", OpKind::Add, &[x, y]).is_err());
    }

    #[test]
    fn duplicate_names_rejected() {
        let mut g = Graph::new();
        g.placeholder("x", Shape::of(&[2]), DType::F32);
        let r = g.op("x", OpKind::Relu, &[0]);
        assert!(matches!(r, Err(GraphError::DuplicateName(_))));
    }

    #[test]
    fn conv_pool_shapes() {
        let mut g = Graph::new();
        let x = g.placeholder("img", Shape::of(&[1, 3, 32, 32]), DType::F32);
        let c = g
            .op(
                "conv",
                OpKind::Conv2d { in_ch: 3, out_ch: 8, kernel: 3, stride: 1, padding: 1 },
                &[x],
            )
            .unwrap();
        assert_eq!(g.node(c).out_shape, Shape::of(&[1, 8, 32, 32]));
        let p = g.op("pool", OpKind::MaxPool2d { kernel: 2, stride: 2 }, &[c]).unwrap();
        assert_eq!(g.node(p).out_shape, Shape::of(&[1, 8, 16, 16]));
    }

    #[test]
    fn concat_shape() {
        let mut g = Graph::new();
        let a = g.placeholder("a", Shape::of(&[2, 3]), DType::F32);
        let b = g.placeholder("b", Shape::of(&[2, 5]), DType::F32);
        let c = g.op("cat", OpKind::Concat { axis: 1 }, &[a, b]).unwrap();
        assert_eq!(g.node(c).out_shape, Shape::of(&[2, 8]));
        assert!(g.op("bad", OpKind::Concat { axis: 0 }, &[a, b]).is_err());
    }

    #[test]
    fn embedding_wants_i32() {
        let mut g = Graph::new();
        let t = g.placeholder("tok", Shape::of(&[4, 16]), DType::I32);
        let e = g.op("emb", OpKind::Embedding { vocab: 100, dim: 8 }, &[t]).unwrap();
        assert_eq!(g.node(e).out_shape, Shape::of(&[4, 16, 8]));
        let f = g.placeholder("f", Shape::of(&[4]), DType::F32);
        assert!(g.op("bad", OpKind::Embedding { vocab: 100, dim: 8 }, &[f]).is_err());
    }

    #[test]
    fn categories() {
        let g = mlp();
        assert_eq!(g.by_name("x").unwrap().kind.category(), OpCategory::Placeholder);
        assert_eq!(g.by_name("fc1").unwrap().kind.category(), OpCategory::Parametric);
        assert_eq!(g.by_name("relu").unwrap().kind.category(), OpCategory::NonParametric);
        assert_eq!(g.by_name("loss").unwrap().kind.category(), OpCategory::Loss);
    }

    #[test]
    fn trainable_and_loss_lists() {
        let g = mlp();
        let t = g.trainable_nodes();
        assert_eq!(t.len(), 2);
        assert_eq!(g.loss_nodes().len(), 1);
    }

    #[test]
    fn dot_renders() {
        let d = mlp().to_dot();
        assert!(d.contains("digraph"));
        assert!(d.contains("fc1"));
    }

    #[test]
    fn redirect_users_moves_edges() {
        let mut g = mlp();
        let fc1 = g.by_name("fc1").unwrap().id;
        let relu = g.by_name("relu").unwrap().id;
        let fc2 = g.by_name("fc2").unwrap().id;
        // Make fc2 read fc1 directly, bypassing the relu.
        let moved = g.redirect_users(relu, fc1);
        assert_eq!(moved, 1);
        assert_eq!(g.node(fc2).args, vec![fc1]);
        assert!(g.users(relu).is_empty());
        assert!(g.users(fc1).contains(&fc2));
    }

    #[test]
    fn retain_nodes_compacts_and_remaps() {
        let mut g = mlp();
        let relu = g.by_name("relu").unwrap().id;
        let fc1 = g.by_name("fc1").unwrap().id;
        g.redirect_users(relu, fc1);
        let mut live = vec![true; g.len()];
        live[relu] = false;
        let remap = g.retain_nodes(&live).unwrap();
        assert_eq!(g.len(), 5);
        assert!(remap[relu].is_none());
        assert!(g.by_name("relu").is_none());
        // ids dense + args remapped + topo still valid
        for (i, n) in g.nodes.iter().enumerate() {
            assert_eq!(n.id, i);
        }
        g.topo_order().unwrap();
    }

    #[test]
    fn retain_refuses_dangling_args() {
        let mut g = mlp();
        let relu = g.by_name("relu").unwrap().id;
        let mut live = vec![true; g.len()];
        live[relu] = false; // fc2 still consumes relu
        assert!(g.retain_nodes(&live).is_err());
    }

    #[test]
    fn from_nodes_roundtrips_and_validates() {
        let g = mlp();
        let rebuilt = Graph::from_nodes(g.nodes.clone()).unwrap();
        assert_eq!(rebuilt.len(), g.len());
        let x = rebuilt.by_name("x").unwrap().id;
        assert_eq!(rebuilt.users(x), g.users(x));
        // Cycle rejected.
        let mut nodes = g.nodes.clone();
        let fc1 = g.by_name("fc1").unwrap().id;
        let fc2 = g.by_name("fc2").unwrap().id;
        nodes[fc1].args = vec![fc2];
        assert!(matches!(Graph::from_nodes(nodes), Err(GraphError::Cycle(_))));
    }
}
