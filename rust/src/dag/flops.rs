//! Per-operator cost accounting: FLOPs, parameter counts and memory
//! footprints.
//!
//! These feed the PALEO-style analytic performance model (paper §3.7,
//! `C(f,p) = FLOPs(f)/S(p)`) and the memory constraints of the scheduling
//! problem (Eq. 2: `D_gpu(G_Sk)`, `D_cpu(G_Sk)`, `D_disk(G_Sk)`).
//!
//! Conventions (standard in the performance-modeling literature, e.g. PALEO):
//! * a multiply-accumulate counts as 2 FLOPs;
//! * backward pass ≈ 2× forward FLOPs for parametric ops (grad wrt inputs +
//!   grad wrt weights), ≈ 1× for non-parametric ops;
//! * attention FLOPs include the `S²` score/value terms.

use super::{Node, OpKind, Shape};

/// Number of trainable parameters owned by the node.
pub fn param_count(node: &Node) -> usize {
    use OpKind::*;
    match &node.kind {
        Conv2d { in_ch, out_ch, kernel, .. } => out_ch * in_ch * kernel * kernel + out_ch,
        Linear { in_features, out_features, bias } => {
            in_features * out_features + if *bias { *out_features } else { 0 }
        }
        Embedding { vocab, dim } => vocab * dim,
        LayerNorm { dim } => 2 * dim,
        // QKV projections + output projection.
        Attention { dim, .. } => 4 * dim * dim + 4 * dim,
        FeedForward { dim, hidden } => dim * hidden + hidden + hidden * dim + dim,
        Variable => node.out_shape.numel(),
        StageCall { param_count, .. } => *param_count,
        _ => 0,
    }
}

/// Bytes of parameter storage (f32).
pub fn param_bytes(node: &Node) -> u64 {
    if let OpKind::StageCall { param_bytes, .. } = &node.kind {
        return *param_bytes;
    }
    param_count(node) as u64 * 4
}

/// Forward-pass FLOPs of the node for its inferred shapes.
pub fn fwd_flops(node: &Node) -> f64 {
    use OpKind::*;
    let out = node.out_shape.numel() as f64;
    match &node.kind {
        Placeholder | Variable => 0.0,
        Conv2d { in_ch, kernel, .. } => {
            // out elements × (2 · in_ch · k²) MAC-derived FLOPs
            out * 2.0 * (*in_ch as f64) * (*kernel as f64) * (*kernel as f64)
        }
        Linear { in_features, out_features, bias } => {
            let rows = out / *out_features as f64;
            let mut f = rows * 2.0 * (*in_features as f64) * (*out_features as f64);
            if *bias {
                f += out;
            }
            f
        }
        Embedding { .. } => out, // gather ≈ 1 op/element copied
        LayerNorm { .. } => 8.0 * out,
        Attention { dim, .. } => attention_flops(&node.out_shape, *dim),
        FeedForward { dim, hidden } => {
            let tokens = out / *dim as f64;
            // two matmuls + gelu
            tokens * 2.0 * (*dim as f64) * (*hidden as f64) * 2.0 + tokens * (*hidden as f64) * 8.0
        }
        Add | Multiply | Relu => out,
        Gelu => 8.0 * out,
        Softmax => 5.0 * out,
        MaxPool2d { kernel, .. } => out * (*kernel as f64) * (*kernel as f64),
        Concat { .. } => out, // memory movement, count as 1/elt
        CrossEntropy { .. } | MseLoss => 5.0 * out.max(1.0),
        StageCall { flops, .. } => *flops,
    }
}

/// `[B, S, D]` self-attention FLOPs: QKV + scores + context + out-proj.
fn attention_flops(shape: &Shape, dim: usize) -> f64 {
    let d = shape.dims();
    let (b, s) = (d[0] as f64, d[1] as f64);
    let dm = dim as f64;
    let proj = 4.0 * b * s * 2.0 * dm * dm; // Q,K,V,O projections
    let scores = b * s * s * 2.0 * dm; // QKᵀ
    let context = b * s * s * 2.0 * dm; // attn·V
    let softmax = b * s * s * 5.0;
    proj + scores + context + softmax
}

/// Backward-pass FLOPs (0 for leaves that don't require grad).
pub fn bwd_flops(node: &Node) -> f64 {
    use super::OpCategory::*;
    match node.kind.category() {
        Placeholder => 0.0,
        Variable => 0.0, // grad arrives from users; no local compute
        Parametric | Loss => 2.0 * fwd_flops(node),
        NonParametric => fwd_flops(node),
    }
}

/// Bytes of the node's output activation.
pub fn activation_bytes(node: &Node) -> u64 {
    node.out_shape.bytes(node.out_dtype) as u64
}

/// GPU memory required to *execute* the node during training:
/// parameters + gradients + output activation + a working-set factor for the
/// op itself. This instantiates `D_gpu(G_Sk)` of Eq. 2 at node granularity.
pub fn gpu_bytes_train(node: &Node) -> u64 {
    let p = param_bytes(node);
    // params + grads + Adam m/v states
    4 * p + 2 * activation_bytes(node)
}

/// GPU memory for inference only (params + activation).
pub fn gpu_bytes_infer(node: &Node) -> u64 {
    param_bytes(node) + activation_bytes(node)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dag::{DType, Graph, OpKind, Shape};

    #[test]
    fn linear_flops_and_params() {
        let mut g = Graph::new();
        let x = g.placeholder("x", Shape::of(&[4, 128]), DType::F32);
        let l = g
            .op("fc", OpKind::Linear { in_features: 128, out_features: 256, bias: true }, &[x])
            .unwrap();
        let n = g.node(l);
        assert_eq!(param_count(n), 128 * 256 + 256);
        // 4 rows × 2·128·256 + bias adds
        assert_eq!(fwd_flops(n), 4.0 * 2.0 * 128.0 * 256.0 + 4.0 * 256.0);
        assert_eq!(bwd_flops(n), 2.0 * fwd_flops(n));
    }

    #[test]
    fn conv_flops() {
        let mut g = Graph::new();
        let x = g.placeholder("x", Shape::of(&[1, 3, 8, 8]), DType::F32);
        let c = g
            .op(
                "conv",
                OpKind::Conv2d { in_ch: 3, out_ch: 4, kernel: 3, stride: 1, padding: 1 },
                &[x],
            )
            .unwrap();
        let n = g.node(c);
        assert_eq!(param_count(n), 4 * 3 * 9 + 4);
        let out_elems = (1 * 4 * 8 * 8) as f64;
        assert_eq!(fwd_flops(n), out_elems * 2.0 * 3.0 * 9.0);
    }

    #[test]
    fn attention_flops_quadratic_in_seq() {
        let mut g = Graph::new();
        let x1 = g.placeholder("x1", Shape::of(&[1, 64, 128]), DType::F32);
        let x2 = g.placeholder("x2", Shape::of(&[1, 128, 128]), DType::F32);
        let a1 =
            g.op("attn1", OpKind::Attention { heads: 4, dim: 128, causal: true }, &[x1]).unwrap();
        let a2 =
            g.op("attn2", OpKind::Attention { heads: 4, dim: 128, causal: true }, &[x2]).unwrap();
        let f1 = fwd_flops(g.node(a1));
        let f2 = fwd_flops(g.node(a2));
        // Doubling S more than doubles FLOPs (quadratic score term).
        assert!(f2 > 2.0 * f1);
    }

    #[test]
    fn leaves_cost_nothing() {
        let mut g = Graph::new();
        let x = g.placeholder("x", Shape::of(&[10]), DType::F32);
        assert_eq!(fwd_flops(g.node(x)), 0.0);
        assert_eq!(bwd_flops(g.node(x)), 0.0);
    }

    #[test]
    fn memory_accounting() {
        let mut g = Graph::new();
        let x = g.placeholder("x", Shape::of(&[4, 128]), DType::F32);
        let l = g
            .op("fc", OpKind::Linear { in_features: 128, out_features: 128, bias: false }, &[x])
            .unwrap();
        let n = g.node(l);
        let p = (128 * 128 * 4) as u64;
        let act = (4 * 128 * 4) as u64;
        assert_eq!(param_bytes(n), p);
        assert_eq!(activation_bytes(n), act);
        assert_eq!(gpu_bytes_train(n), 4 * p + 2 * act);
        assert_eq!(gpu_bytes_infer(n), p + act);
    }

    #[test]
    fn stagecall_uses_declared_costs() {
        let mut g = Graph::new();
        let x = g.placeholder("x", Shape::of(&[2, 8, 16]), DType::F32);
        let s = g
            .op(
                "stage0",
                OpKind::StageCall {
                    stage: "block".into(),
                    param_count: 1000,
                    flops: 5e6,
                    param_bytes: 4000,
                },
                &[x],
            )
            .unwrap();
        let n = g.node(s);
        assert_eq!(param_count(n), 1000);
        assert_eq!(fwd_flops(n), 5e6);
        assert_eq!(param_bytes(n), 4000);
    }
}
