//! Graph ⇄ JSON serialization.
//!
//! The IR plane is the interchange format between the coordinator and
//! compute nodes (paper §3.5): a graph serialized here can be shipped over
//! the broker, deserialized with [`from_json`] and executed on any plane.
//! `to_json` → `from_json` is lossless: kinds (with all structural
//! hyperparameters), args, kwargs, shapes and dtypes round-trip exactly.

use std::collections::BTreeMap;

use super::ir::{DType, Graph, GraphError, Node, OpKind, Shape};
use crate::util::json::{parse, Json};

/// Serialize a graph to compact JSON.
pub fn to_json(g: &Graph) -> String {
    let nodes: Vec<Json> = g
        .nodes
        .iter()
        .map(|n| {
            Json::obj(vec![
                ("id", Json::Num(n.id as f64)),
                ("name", Json::Str(n.name.clone())),
                ("kind", kind_to_json(&n.kind)),
                ("args", Json::Arr(n.args.iter().map(|&a| Json::Num(a as f64)).collect())),
                (
                    "kwargs",
                    Json::Obj(
                        n.kwargs
                            .iter()
                            .map(|(k, v)| (k.clone(), Json::Str(v.clone())))
                            .collect(),
                    ),
                ),
                (
                    "shape",
                    Json::Arr(n.out_shape.dims().iter().map(|&d| Json::Num(d as f64)).collect()),
                ),
                ("dtype", Json::Str(n.out_dtype.to_string())),
            ])
        })
        .collect();
    Json::obj(vec![("nodes", Json::Arr(nodes))]).to_string()
}

/// Deserialize a graph produced by [`to_json`]. Validates ids, args,
/// names and acyclicity; declared shapes are trusted (not re-inferred) so
/// `set_shape` overrides on `StageCall` graphs survive the round-trip.
pub fn from_json(src: &str) -> Result<Graph, GraphError> {
    let doc = parse(src).map_err(|e| GraphError::Invalid(format!("bad JSON: {e}")))?;
    let nodes_json = doc
        .get("nodes")
        .and_then(|n| n.as_arr())
        .ok_or_else(|| GraphError::Invalid("missing 'nodes' array".into()))?;
    let mut nodes = Vec::with_capacity(nodes_json.len());
    for (i, nj) in nodes_json.iter().enumerate() {
        let field = |key: &str| {
            nj.get(key).ok_or_else(|| {
                GraphError::Invalid(format!("node {i}: missing field '{key}'"))
            })
        };
        let id = field("id")?
            .as_usize()
            .ok_or_else(|| GraphError::Invalid(format!("node {i}: bad id")))?;
        let name = field("name")?
            .as_str()
            .ok_or_else(|| GraphError::Invalid(format!("node {i}: bad name")))?
            .to_string();
        let kind = kind_from_json(field("kind")?)
            .map_err(|msg| GraphError::Invalid(format!("node '{name}': {msg}")))?;
        let args = field("args")?
            .as_arr()
            .ok_or_else(|| GraphError::Invalid(format!("node '{name}': bad args")))?
            .iter()
            .map(|a| {
                a.as_usize()
                    .ok_or_else(|| GraphError::Invalid(format!("node '{name}': bad arg")))
            })
            .collect::<Result<Vec<usize>, GraphError>>()?;
        let mut kwargs = BTreeMap::new();
        if let Some(kw) = nj.get("kwargs").and_then(|k| k.as_obj()) {
            for (k, v) in kw {
                let s = v.as_str().ok_or_else(|| {
                    GraphError::Invalid(format!("node '{name}': kwarg '{k}' not a string"))
                })?;
                kwargs.insert(k.clone(), s.to_string());
            }
        }
        let dims = field("shape")?
            .as_arr()
            .ok_or_else(|| GraphError::Invalid(format!("node '{name}': bad shape")))?
            .iter()
            .map(|d| {
                d.as_usize()
                    .ok_or_else(|| GraphError::Invalid(format!("node '{name}': bad dim")))
            })
            .collect::<Result<Vec<usize>, GraphError>>()?;
        let dtype = match field("dtype")?.as_str() {
            Some("f32") => DType::F32,
            Some("i32") => DType::I32,
            other => {
                return Err(GraphError::Invalid(format!(
                    "node '{name}': unknown dtype {other:?}"
                )))
            }
        };
        nodes.push(Node {
            id,
            name,
            kind,
            args,
            kwargs,
            out_shape: Shape(dims),
            out_dtype: dtype,
        });
    }
    Graph::from_nodes(nodes)
}

fn kind_to_json(kind: &OpKind) -> Json {
    use OpKind::*;
    let num = |v: usize| Json::Num(v as f64);
    let mut fields: Vec<(&str, Json)> = vec![("op", Json::Str(variant_tag(kind).into()))];
    match kind {
        Placeholder | Variable | Add | Multiply | Relu | Gelu | Softmax | MseLoss => {}
        Conv2d { in_ch, out_ch, kernel, stride, padding } => {
            fields.push(("in_ch", num(*in_ch)));
            fields.push(("out_ch", num(*out_ch)));
            fields.push(("kernel", num(*kernel)));
            fields.push(("stride", num(*stride)));
            fields.push(("padding", num(*padding)));
        }
        Linear { in_features, out_features, bias } => {
            fields.push(("in_features", num(*in_features)));
            fields.push(("out_features", num(*out_features)));
            fields.push(("bias", Json::Bool(*bias)));
        }
        Embedding { vocab, dim } => {
            fields.push(("vocab", num(*vocab)));
            fields.push(("dim", num(*dim)));
        }
        LayerNorm { dim } => fields.push(("dim", num(*dim))),
        Attention { heads, dim, causal } => {
            fields.push(("heads", num(*heads)));
            fields.push(("dim", num(*dim)));
            fields.push(("causal", Json::Bool(*causal)));
        }
        FeedForward { dim, hidden } => {
            fields.push(("dim", num(*dim)));
            fields.push(("hidden", num(*hidden)));
        }
        MaxPool2d { kernel, stride } => {
            fields.push(("kernel", num(*kernel)));
            fields.push(("stride", num(*stride)));
        }
        Concat { axis } => fields.push(("axis", num(*axis))),
        CrossEntropy { weight } => fields.push(("weight", Json::Num(*weight))),
        StageCall { stage, param_count, flops, param_bytes } => {
            fields.push(("stage", Json::Str(stage.clone())));
            fields.push(("param_count", num(*param_count)));
            fields.push(("flops", Json::Num(*flops)));
            fields.push(("param_bytes", Json::Num(*param_bytes as f64)));
        }
    }
    Json::obj(fields)
}

fn variant_tag(kind: &OpKind) -> &'static str {
    use OpKind::*;
    match kind {
        Placeholder => "Placeholder",
        Variable => "Variable",
        Conv2d { .. } => "Conv2d",
        Linear { .. } => "Linear",
        Embedding { .. } => "Embedding",
        LayerNorm { .. } => "LayerNorm",
        Attention { .. } => "Attention",
        FeedForward { .. } => "FeedForward",
        Add => "Add",
        Multiply => "Multiply",
        Relu => "Relu",
        Gelu => "Gelu",
        Softmax => "Softmax",
        MaxPool2d { .. } => "MaxPool2d",
        Concat { .. } => "Concat",
        CrossEntropy { .. } => "CrossEntropy",
        MseLoss => "MseLoss",
        StageCall { .. } => "StageCall",
    }
}

fn kind_from_json(j: &Json) -> Result<OpKind, String> {
    let tag = j.get("op").and_then(|t| t.as_str()).ok_or("kind missing 'op' tag")?;
    let us = |key: &str| -> Result<usize, String> {
        j.get(key).and_then(|v| v.as_usize()).ok_or(format!("kind missing '{key}'"))
    };
    let b = |key: &str| -> Result<bool, String> {
        j.get(key).and_then(|v| v.as_bool()).ok_or(format!("kind missing '{key}'"))
    };
    Ok(match tag {
        "Placeholder" => OpKind::Placeholder,
        "Variable" => OpKind::Variable,
        "Conv2d" => OpKind::Conv2d {
            in_ch: us("in_ch")?,
            out_ch: us("out_ch")?,
            kernel: us("kernel")?,
            stride: us("stride")?,
            padding: us("padding")?,
        },
        "Linear" => OpKind::Linear {
            in_features: us("in_features")?,
            out_features: us("out_features")?,
            bias: b("bias")?,
        },
        "Embedding" => OpKind::Embedding { vocab: us("vocab")?, dim: us("dim")? },
        "LayerNorm" => OpKind::LayerNorm { dim: us("dim")? },
        "Attention" => OpKind::Attention {
            heads: us("heads")?,
            dim: us("dim")?,
            causal: b("causal")?,
        },
        "FeedForward" => OpKind::FeedForward { dim: us("dim")?, hidden: us("hidden")? },
        "Add" => OpKind::Add,
        "Multiply" => OpKind::Multiply,
        "Relu" => OpKind::Relu,
        "Gelu" => OpKind::Gelu,
        "Softmax" => OpKind::Softmax,
        "MaxPool2d" => OpKind::MaxPool2d { kernel: us("kernel")?, stride: us("stride")? },
        "Concat" => OpKind::Concat { axis: us("axis")? },
        "CrossEntropy" => OpKind::CrossEntropy {
            weight: j.get("weight").and_then(|v| v.as_f64()).ok_or("kind missing 'weight'")?,
        },
        "MseLoss" => OpKind::MseLoss,
        "StageCall" => OpKind::StageCall {
            stage: j
                .get("stage")
                .and_then(|v| v.as_str())
                .ok_or("kind missing 'stage'")?
                .to_string(),
            param_count: us("param_count")?,
            flops: j.get("flops").and_then(|v| v.as_f64()).ok_or("kind missing 'flops'")?,
            param_bytes: j
                .get("param_bytes")
                .and_then(|v| v.as_f64())
                .ok_or("kind missing 'param_bytes'")? as u64,
        },
        other => return Err(format!("unknown op tag '{other}'")),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::fig3;
    use crate::models::transformer::{pipeline_graph, PipelineSpec, TransformerConfig};

    fn assert_roundtrip(g: &Graph) {
        let json = to_json(g);
        let back = from_json(&json).expect("from_json");
        assert_eq!(back.len(), g.len());
        for (a, b) in g.nodes.iter().zip(&back.nodes) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.name, b.name);
            assert_eq!(a.kind, b.kind, "kind mismatch at '{}'", a.name);
            assert_eq!(a.args, b.args);
            assert_eq!(a.kwargs, b.kwargs);
            assert_eq!(a.out_shape, b.out_shape);
            assert_eq!(a.out_dtype, b.out_dtype);
        }
        for id in 0..g.len() {
            assert_eq!(g.users(id), back.users(id), "users mismatch at node {id}");
        }
        // Second hop is byte-identical (canonical form).
        assert_eq!(to_json(&back), json);
    }

    #[test]
    fn roundtrip_transformer() {
        assert_roundtrip(&TransformerConfig::tiny().build_graph());
    }

    #[test]
    fn roundtrip_fig3_with_kwargs() {
        // fig3 carries kwargs and conv/pool/concat kinds.
        assert_roundtrip(&fig3::build());
    }

    #[test]
    fn roundtrip_stagecall_pipeline() {
        // StageCall kinds carry name/param/flop payloads and set_shape
        // overrides; all must survive.
        let spec = PipelineSpec::new(TransformerConfig::tiny(), 2);
        assert_roundtrip(&pipeline_graph(&spec));
    }

    #[test]
    fn from_json_rejects_garbage() {
        assert!(from_json("not json").is_err());
        assert!(from_json("{}").is_err());
        assert!(from_json(r#"{"nodes":[{"id":0}]}"#).is_err());
        // Arg out of range.
        let bad = r#"{"nodes":[{"id":0,"name":"x","kind":{"op":"Relu"},"args":[7],"kwargs":{},"shape":[2],"dtype":"f32"}]}"#;
        assert!(from_json(bad).is_err());
        // Unknown op tag.
        let bad = r#"{"nodes":[{"id":0,"name":"x","kind":{"op":"Wat"},"args":[],"kwargs":{},"shape":[2],"dtype":"f32"}]}"#;
        assert!(from_json(bad).is_err());
    }

    #[test]
    fn kwargs_preserved() {
        let mut g = Graph::new();
        let x = g.placeholder("x", Shape::of(&[2, 4]), DType::F32);
        let r = g.op("r", OpKind::Relu, &[x]).unwrap();
        g.set_kwarg(r, "device", "cuda:1");
        g.set_kwarg(r, "subgraph", "3");
        let back = from_json(&to_json(&g)).unwrap();
        assert_eq!(back.node(r).kwargs.get("device").map(String::as_str), Some("cuda:1"));
        assert_eq!(back.node(r).kwargs.get("subgraph").map(String::as_str), Some("3"));
    }
}
