//! Backward-graph construction (paper §3.5–3.6, "BP task").
//!
//! The paper formulates backward propagation as the *reverse* of the forward
//! DAG: "In most cases, the BP edges are the reverse of the FP edges, except
//! for the edges directed towards leaf nodes that do not require gradients".
//! We implement exactly that as a task-level transform: each forward node
//! that participates in the backward pass gets one [`BwdTask`] whose
//! dependencies are the backward tasks of the node's *users* (the gradients
//! flowing back along reversed edges), plus the locally stashed forward
//! values. The execution plane (`crate::exec`) knows how to compute each
//! op's vector-Jacobian product.
//!
//! The transform also decides which nodes participate:
//! * placeholders never require grad and are pruned;
//! * a non-leaf node is pruned if no trainable node is reachable *upstream*
//!   of it (its gradient would be dead);
//! * loss nodes seed the backward pass with dL/dL = 1.

use super::{Graph, NodeId, OpCategory};

/// One backward task: compute gradients flowing *into* forward node `fwd`.
#[derive(Debug, Clone)]
pub struct BwdTask {
    /// The forward node whose VJP this task evaluates.
    pub fwd: NodeId,
    /// Forward users of `fwd` that supply upstream gradients. Empty iff
    /// `fwd` is a loss node (seeded with 1).
    pub grad_sources: Vec<NodeId>,
    /// Forward args of `fwd` that require grad — the VJP must produce a
    /// gradient for each of these (paper: "the computed gradients are
    /// returned to their Arg Nodes").
    pub grad_targets: Vec<NodeId>,
    /// Whether this node's own parameters receive a gradient (parametric
    /// ops and variables).
    pub wants_param_grad: bool,
}

/// The backward plan for a whole graph.
#[derive(Debug, Clone)]
pub struct BackwardPlan {
    /// One task per participating forward node, indexed by forward NodeId.
    pub tasks: Vec<Option<BwdTask>>,
    /// Forward-node ids in a valid backward execution order (reverse
    /// topological over participating nodes).
    pub order: Vec<NodeId>,
}

impl BackwardPlan {
    pub fn task(&self, fwd: NodeId) -> Option<&BwdTask> {
        self.tasks.get(fwd).and_then(|t| t.as_ref())
    }

    /// Number of participating backward tasks.
    pub fn len(&self) -> usize {
        self.order.len()
    }

    pub fn is_empty(&self) -> bool {
        self.order.is_empty()
    }

    /// Position of every forward node's task in `order` (`usize::MAX` for
    /// non-participating nodes). Gradient contributions into a shared arg
    /// are folded in ascending producer position, which reproduces the
    /// serial sweep's accumulation order bit for bit no matter how the
    /// tasks were scheduled.
    pub fn positions(&self) -> Vec<usize> {
        let mut pos = vec![usize::MAX; self.tasks.len()];
        for (i, &id) in self.order.iter().enumerate() {
            pos[id] = i;
        }
        pos
    }

    /// How many backward tasks read each forward activation as a VJP input
    /// (every task re-reads its node's `args`). Once a node's count drops
    /// to zero during the backward sweep, its forward stash is dead and can
    /// be returned to the scratch pool — "backward waves free forward
    /// stashes as soon as their last consumer grad fires".
    pub fn stash_refcounts(&self, g: &Graph) -> Vec<u32> {
        let mut uses = vec![0u32; g.len()];
        for &id in &self.order {
            for &a in &g.node(id).args {
                uses[a] += 1;
            }
        }
        uses
    }
}

/// Build the backward plan for `g`.
///
/// Returns an empty plan when the graph has no loss node (inference-only
/// DAGs are legal: the FP task is the whole job, paper §3.1).
pub fn backward_plan(g: &Graph) -> BackwardPlan {
    let n = g.len();
    let losses = g.loss_nodes();
    if losses.is_empty() {
        return BackwardPlan { tasks: vec![None; n], order: vec![] };
    }

    // 1. requires_grad: does any trainable tensor feed this node (transitively)?
    let topo = g.topo_order().expect("builder graphs are acyclic");
    let mut requires_grad = vec![false; n];
    for &id in &topo {
        let node = g.node(id);
        requires_grad[id] = match node.kind.category() {
            OpCategory::Variable | OpCategory::Parametric => true,
            OpCategory::Placeholder => false,
            _ => node.args.iter().any(|&a| requires_grad[a]),
        };
    }

    // 2. reachable-from-loss along reversed edges: gradient actually flows.
    let mut grad_flows = vec![false; n];
    let mut stack = losses.clone();
    for &l in &losses {
        grad_flows[l] = true;
    }
    while let Some(u) = stack.pop() {
        for &a in &g.node(u).args {
            if requires_grad[a] && !grad_flows[a] {
                grad_flows[a] = true;
                stack.push(a);
            }
        }
    }

    // 3. Emit tasks in reverse topological order.
    let mut tasks: Vec<Option<BwdTask>> = vec![None; n];
    let mut order = Vec::new();
    for &id in topo.iter().rev() {
        if !grad_flows[id] {
            continue;
        }
        let node = g.node(id);
        let is_loss = node.kind.category() == OpCategory::Loss;
        let grad_sources: Vec<NodeId> = if is_loss {
            vec![]
        } else {
            g.users(id).iter().copied().filter(|&u| grad_flows[u]).collect()
        };
        let grad_targets: Vec<NodeId> =
            node.args.iter().copied().filter(|&a| grad_flows[a]).collect();
        let wants_param_grad = matches!(
            node.kind.category(),
            OpCategory::Parametric | OpCategory::Variable
        );
        tasks[id] = Some(BwdTask { fwd: id, grad_sources, grad_targets, wants_param_grad });
        order.push(id);
    }

    BackwardPlan { tasks, order }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dag::{DType, Graph, OpKind, Shape};

    /// x → fc1 → relu → fc2 → loss(y)
    fn mlp() -> Graph {
        let mut g = Graph::new();
        let x = g.placeholder("x", Shape::of(&[8, 32]), DType::F32);
        let y = g.placeholder("y", Shape::of(&[8, 16]), DType::F32);
        let h = g
            .op("fc1", OpKind::Linear { in_features: 32, out_features: 64, bias: true }, &[x])
            .unwrap();
        let r = g.op("relu", OpKind::Relu, &[h]).unwrap();
        let o = g
            .op("fc2", OpKind::Linear { in_features: 64, out_features: 16, bias: true }, &[r])
            .unwrap();
        g.op("loss", OpKind::MseLoss, &[o, y]).unwrap();
        g
    }

    #[test]
    fn plan_covers_expected_nodes() {
        let g = mlp();
        let plan = backward_plan(&g);
        // loss, fc2, relu, fc1 participate; x, y placeholders do not.
        assert_eq!(plan.len(), 4);
        assert!(plan.task(g.by_name("x").unwrap().id).is_none());
        assert!(plan.task(g.by_name("y").unwrap().id).is_none());
        assert!(plan.task(g.by_name("fc1").unwrap().id).is_some());
    }

    #[test]
    fn loss_seeds_backward() {
        let g = mlp();
        let plan = backward_plan(&g);
        let loss = g.by_name("loss").unwrap().id;
        let t = plan.task(loss).unwrap();
        assert!(t.grad_sources.is_empty());
        // Gradient flows to fc2's output but NOT to the label placeholder.
        assert_eq!(t.grad_targets, vec![g.by_name("fc2").unwrap().id]);
    }

    #[test]
    fn reverse_edges_match_paper() {
        let g = mlp();
        let plan = backward_plan(&g);
        let fc2 = g.by_name("fc2").unwrap().id;
        let relu = g.by_name("relu").unwrap().id;
        let t = plan.task(relu).unwrap();
        // relu's upstream gradient comes from its forward user fc2.
        assert_eq!(t.grad_sources, vec![fc2]);
        assert!(!t.wants_param_grad);
        assert!(plan.task(fc2).unwrap().wants_param_grad);
    }

    #[test]
    fn order_is_reverse_topological() {
        let g = mlp();
        let plan = backward_plan(&g);
        let pos: std::collections::HashMap<_, _> =
            plan.order.iter().enumerate().map(|(i, &n)| (n, i)).collect();
        for &id in &plan.order {
            let t = plan.task(id).unwrap();
            for &src in &t.grad_sources {
                assert!(pos[&src] < pos[&id], "grad source must run before consumer");
            }
        }
    }

    #[test]
    fn inference_graph_has_empty_plan() {
        let mut g = Graph::new();
        let x = g.placeholder("x", Shape::of(&[4, 8]), DType::F32);
        g.op("fc", OpKind::Linear { in_features: 8, out_features: 8, bias: false }, &[x])
            .unwrap();
        let plan = backward_plan(&g);
        assert!(plan.is_empty());
    }

    #[test]
    fn dead_branches_pruned() {
        // A side branch with no parameters upstream gets no backward task.
        let mut g = Graph::new();
        let x = g.placeholder("x", Shape::of(&[4, 8]), DType::F32);
        let y = g.placeholder("y", Shape::of(&[4, 8]), DType::F32);
        // dead: pure function of placeholders only, feeding nothing trainable
        let dead = g.op("dead", OpKind::Relu, &[x]).unwrap();
        let fc = g
            .op("fc", OpKind::Linear { in_features: 8, out_features: 8, bias: false }, &[dead])
            .unwrap();
        g.op("loss", OpKind::MseLoss, &[fc, y]).unwrap();
        let plan = backward_plan(&g);
        // fc is parametric → participates. dead relu's input is a placeholder
        // and it owns no params, but gradient STILL must flow through fc back
        // to... dead? fc's arg `dead` requires_grad = false (placeholder-only
        // upstream), so dead is pruned.
        assert!(plan.task(fc).is_some());
        assert!(plan.task(dead).is_none());
    }

    #[test]
    fn positions_and_stash_refcounts_cover_plan() {
        let g = mlp();
        let plan = backward_plan(&g);
        let pos = plan.positions();
        for (i, &id) in plan.order.iter().enumerate() {
            assert_eq!(pos[id], i);
        }
        assert_eq!(pos[g.by_name("x").unwrap().id], usize::MAX);
        let uses = plan.stash_refcounts(&g);
        // x is read once: by fc1's VJP. relu's output twice would require
        // two users; here fc2's VJP is its only reader.
        assert_eq!(uses[g.by_name("x").unwrap().id], 1);
        assert_eq!(uses[g.by_name("relu").unwrap().id], 1);
        // The loss output is never a VJP input (its VJP reads fc2 and y).
        assert_eq!(uses[g.by_name("loss").unwrap().id], 0);
        assert_eq!(uses[g.by_name("y").unwrap().id], 1);
    }

    #[test]
    fn variables_receive_grad() {
        // Paper: variables (e.g. adversarial samples) are optimized leaves.
        let mut g = Graph::new();
        let v = g.variable("styvar", Shape::of(&[4, 8]));
        let y = g.placeholder("y", Shape::of(&[4, 8]), DType::F32);
        let r = g.op("relu", OpKind::Relu, &[v]).unwrap();
        g.op("loss", OpKind::MseLoss, &[r, y]).unwrap();
        let plan = backward_plan(&g);
        let t = plan.task(v).unwrap();
        assert!(t.wants_param_grad);
        assert_eq!(t.grad_sources, vec![r]);
    }
}
