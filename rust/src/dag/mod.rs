//! The IR plane: a framework-independent DAG of ML operators (paper §3.5).
//!
//! A model's forward pass is a directed acyclic graph `G = ⟨{oᶦ}, {(oᶦ,oʲ)}⟩`
//! whose nodes are operators and whose edges carry tensors. Operators follow
//! the paper's Table 2 categories (placeholders, variables, parametric OPs,
//! non-parametric OPs, loss functions). The IR is what users submit to the
//! broker and what the decomposer splits into sub-DAGs; the *execution
//! plane* ([`crate::exec::Engine`]) then interprets it on whatever backend a
//! compnode prefers (goals P3–P6).
//!
//! This module is a thin hub; the substance lives in the submodules:
//!
//! - [`ir`] — the data model: [`DType`], [`Shape`], [`OpKind`], [`Node`],
//!   [`Graph`] and shape inference.
//! - [`passes`] — composable graph rewrites: [`passes::GraphPass`],
//!   [`passes::PassManager`] and the standard normalization pipeline.
//! - [`serde`] — lossless `Graph` ⇄ JSON interchange
//!   ([`serde::to_json`] / [`serde::from_json`]).
//! - [`autodiff`] — task-level reverse-mode planning over the forward DAG.
//! - [`flops`] — per-op cost model (params, FLOPs, activation/GPU bytes).
//!
//! The core types are re-exported here, so `crate::dag::Graph` et al. keep
//! working; passes and serde are addressed through their submodules.

pub mod autodiff;
pub mod flops;
pub mod ir;
pub mod passes;
pub mod serde;

pub use ir::{
    infer_shape, DType, Graph, GraphError, Node, NodeId, OpCategory, OpKind, Shape,
};
pub use passes::{GraphPass, Liveness, PassManager, PassReport};

impl Graph {
    /// Serialize to JSON — see [`serde::to_json`].
    pub fn to_json(&self) -> String {
        serde::to_json(self)
    }

    /// Deserialize from JSON — see [`serde::from_json`].
    pub fn from_json(src: &str) -> Result<Graph, GraphError> {
        serde::from_json(src)
    }
}
