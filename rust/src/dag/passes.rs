//! Pass-based graph transformation pipeline.
//!
//! Graph rewrites are composable [`GraphPass`]es run by a [`PassManager`]
//! (the shape FusionLLM's adaptive-compression rewrites assume). A pass
//! mutates the graph in place and reports whether anything changed; the
//! manager chains passes and returns a per-pass [`PassReport`].
//!
//! Passes that remove nodes ([`DeadNodeElimination`], via folding) compact
//! node ids — run them *before* taking `NodeId` references into the graph,
//! not after.

use super::ir::{infer_shape, DType, Graph, GraphError, NodeId, OpKind, Shape};

/// One composable graph transformation.
pub trait GraphPass {
    /// Stable pass name for reports and logs.
    fn name(&self) -> &'static str;
    /// Run over `g`; `Ok(true)` iff the graph was modified.
    fn run(&self, g: &mut Graph) -> Result<bool, GraphError>;
}

/// Ordered pipeline of passes.
#[derive(Default)]
pub struct PassManager {
    passes: Vec<Box<dyn GraphPass>>,
}

/// Which passes ran and whether each changed the graph.
#[derive(Debug, Clone)]
pub struct PassReport {
    pub entries: Vec<(&'static str, bool)>,
}

impl PassReport {
    /// True iff any pass modified the graph.
    pub fn changed(&self) -> bool {
        self.entries.iter().any(|&(_, c)| c)
    }
}

impl PassManager {
    /// Empty pipeline; add passes with [`PassManager::with_pass`].
    pub fn new() -> PassManager {
        PassManager::default()
    }

    /// The standard normalization pipeline: re-infer shapes, fold
    /// structural identities, drop dead nodes, then validate invariants.
    pub fn standard() -> PassManager {
        PassManager::new()
            .with_pass(ShapeInference)
            .with_pass(ConstantFolding)
            .with_pass(DeadNodeElimination)
            .with_pass(TopoValidate)
    }

    /// Validation only — no rewrites, `NodeId`s stay stable. Ends with the
    /// static linter (`verify::GraphLintPass`): lint *errors* fail the
    /// pipeline, lint warnings (dead code) pass through.
    pub fn validation() -> PassManager {
        PassManager::new()
            .with_pass(ShapeInference)
            .with_pass(TopoValidate)
            .with_pass(crate::verify::GraphLintPass)
    }

    pub fn with_pass(mut self, p: impl GraphPass + 'static) -> PassManager {
        self.passes.push(Box::new(p));
        self
    }

    pub fn run(&self, g: &mut Graph) -> Result<PassReport, GraphError> {
        let mut entries = Vec::with_capacity(self.passes.len());
        for p in &self.passes {
            entries.push((p.name(), p.run(g)?));
        }
        Ok(PassReport { entries })
    }
}

/// Recompute output shapes/dtypes in topological order.
///
/// Leaves keep their declared shapes and `StageCall` nodes keep their
/// builder-set overrides (the artifact, not the IR, owns stage shapes);
/// every other node is re-derived through [`infer_shape`], so stale shapes
/// after a rewrite become consistent again — or surface as a
/// [`GraphError::Shape`].
pub struct ShapeInference;

impl GraphPass for ShapeInference {
    fn name(&self) -> &'static str {
        "shape-inference"
    }

    fn run(&self, g: &mut Graph) -> Result<bool, GraphError> {
        let order = g.topo_order()?;
        let mut changed = false;
        for id in order {
            match g.nodes[id].kind {
                OpKind::Placeholder | OpKind::Variable | OpKind::StageCall { .. } => continue,
                _ => {}
            }
            let arg_meta: Vec<(Shape, DType)> = g.nodes[id]
                .args
                .iter()
                .map(|&a| (g.nodes[a].out_shape.clone(), g.nodes[a].out_dtype))
                .collect();
            let refs: Vec<(&Shape, DType)> = arg_meta.iter().map(|(s, d)| (s, *d)).collect();
            let node = &g.nodes[id];
            let (shape, dtype) = infer_shape(&node.name, &node.kind, &refs)?;
            if g.nodes[id].out_shape != shape || g.nodes[id].out_dtype != dtype {
                g.nodes[id].out_shape = shape;
                g.nodes[id].out_dtype = dtype;
                changed = true;
            }
        }
        Ok(changed)
    }
}

/// Fold structural identities by redirecting consumers past no-op nodes.
///
/// The IR carries no literal tensor constants, so classic constant folding
/// degenerates to identity elimination: `Relu(Relu(x)) → Relu(x)`,
/// 1×1/stride-1 `MaxPool2d(x) → x`, single-input `Concat(x) → x`. Folded
/// nodes are left dead for [`DeadNodeElimination`] to sweep.
pub struct ConstantFolding;

impl GraphPass for ConstantFolding {
    fn name(&self) -> &'static str {
        "constant-folding"
    }

    fn run(&self, g: &mut Graph) -> Result<bool, GraphError> {
        let mut changed = false;
        for id in 0..g.len() {
            let replacement: Option<NodeId> = match &g.nodes[id].kind {
                OpKind::MaxPool2d { kernel: 1, stride: 1 } => Some(g.nodes[id].args[0]),
                OpKind::Concat { .. } if g.nodes[id].args.len() == 1 => {
                    Some(g.nodes[id].args[0])
                }
                OpKind::Relu => {
                    let a = g.nodes[id].args[0];
                    matches!(g.nodes[a].kind, OpKind::Relu).then_some(a)
                }
                _ => None,
            };
            if let Some(to) = replacement {
                if g.redirect_users(id, to) > 0 {
                    changed = true;
                }
            }
        }
        Ok(changed)
    }
}

/// Remove nodes that cannot influence any root.
///
/// Roots are the loss nodes when the graph has any (training graphs), else
/// every sink (inference graphs — conservative, removes nothing). Removal
/// compacts node ids; callers holding `NodeId`s must re-resolve by name.
pub struct DeadNodeElimination;

impl GraphPass for DeadNodeElimination {
    fn name(&self) -> &'static str {
        "dead-node-elimination"
    }

    fn run(&self, g: &mut Graph) -> Result<bool, GraphError> {
        let losses = g.loss_nodes();
        let roots: Vec<NodeId> = if losses.is_empty() {
            (0..g.len()).filter(|&i| g.users(i).is_empty()).collect()
        } else {
            losses
        };
        let mut live = vec![false; g.len()];
        let mut stack = roots;
        while let Some(id) = stack.pop() {
            if std::mem::replace(&mut live[id], true) {
                continue;
            }
            stack.extend(g.nodes[id].args.iter().copied());
        }
        if live.iter().all(|&l| l) {
            return Ok(false);
        }
        g.retain_nodes(&live)?;
        Ok(true)
    }
}

/// Pure validation: dense ids, in-bounds args, unique names, reverse
/// adjacency consistent with `args`, and acyclicity. Never mutates.
pub struct TopoValidate;

impl GraphPass for TopoValidate {
    fn name(&self) -> &'static str {
        "topo-validate"
    }

    fn run(&self, g: &mut Graph) -> Result<bool, GraphError> {
        let n = g.len();
        let mut names = std::collections::BTreeSet::new();
        for (i, node) in g.nodes.iter().enumerate() {
            if node.id != i {
                return Err(GraphError::Invalid(format!(
                    "node '{}' has id {} at index {i}",
                    node.name, node.id
                )));
            }
            if !names.insert(node.name.as_str()) {
                return Err(GraphError::DuplicateName(node.name.clone()));
            }
            for &a in &node.args {
                if a >= n {
                    return Err(GraphError::UnknownNode(a));
                }
            }
        }
        // Reverse adjacency must be exactly the transpose of `args`.
        let mut expected: Vec<Vec<NodeId>> = vec![Vec::new(); n];
        for node in &g.nodes {
            for &a in &node.args {
                expected[a].push(node.id);
            }
        }
        for i in 0..n {
            let mut got = g.users(i).to_vec();
            got.sort_unstable();
            let mut want = expected[i].clone();
            want.sort_unstable();
            if got != want {
                return Err(GraphError::Invalid(format!(
                    "reverse adjacency of node {i} is {got:?}, expected {want:?}"
                )));
            }
        }
        g.topo_order()?;
        Ok(false)
    }
}

/// Liveness / last-use analysis over a graph (or a sub-DAG of it).
///
/// Pure analysis, not a [`GraphPass`]: it never mutates the graph. For an
/// execution order (topological, optionally restricted to the nodes one
/// compnode owns) it answers, per node: how many in-set consumers read its
/// output, and at which position the *last* of them runs. The execution
/// plan (`exec::plan`) turns this into per-tensor refcounts so activations
/// return to the scratch pool right after their last use instead of living
/// to the end of the step — the paper's memory constraint on consumer
/// devices is about peak-resident bytes, not totals.
#[derive(Debug, Clone)]
pub struct Liveness {
    /// The execution order positions are relative to: the graph's
    /// topological order restricted to the analyzed set.
    pub order: Vec<NodeId>,
    /// Position of each node in `order`; `usize::MAX` for out-of-set nodes.
    pub pos: Vec<usize>,
    /// Number of in-set consumers reading each node's output (indexed by
    /// `NodeId`, covering out-of-set producers whose outputs flow in).
    pub use_count: Vec<u32>,
    /// Position in `order` of the last in-set consumer; `None` if nothing
    /// in the set reads the node.
    pub last_use: Vec<Option<usize>>,
}

impl Liveness {
    /// Analyze the whole graph.
    pub fn analyze(g: &Graph) -> Result<Liveness, GraphError> {
        let all = vec![true; g.len()];
        Liveness::analyze_subset(g, &all)
    }

    /// Analyze the sub-DAG `in_set` (e.g. one compnode's share). Producers
    /// outside the set still get `use_count`/`last_use` entries when in-set
    /// nodes consume them — that is exactly the lifetime of a received
    /// activation on the consuming compnode.
    pub fn analyze_subset(g: &Graph, in_set: &[bool]) -> Result<Liveness, GraphError> {
        let n = g.len();
        let order: Vec<NodeId> =
            g.topo_order()?.into_iter().filter(|&id| in_set[id]).collect();
        let mut pos = vec![usize::MAX; n];
        for (i, &id) in order.iter().enumerate() {
            pos[id] = i;
        }
        let mut use_count = vec![0u32; n];
        let mut last_use = vec![None; n];
        for (i, &id) in order.iter().enumerate() {
            for &a in &g.node(id).args {
                use_count[a] += 1;
                last_use[a] = Some(i);
            }
        }
        Ok(Liveness { order, pos, use_count, last_use })
    }

    /// Peak resident activation bytes of a forward sweep in `order` when
    /// every activation is freed immediately after its last use (outputs
    /// nothing consumes — sinks — are kept). A planning-time estimate of
    /// what `exec::ExecPlan` achieves at run time for inference DAGs.
    pub fn peak_resident_bytes(&self, g: &Graph) -> u64 {
        let mut resident = 0u64;
        let mut peak = 0u64;
        for (i, &id) in self.order.iter().enumerate() {
            resident += crate::dag::flops::activation_bytes(g.node(id));
            peak = peak.max(resident);
            let node = g.node(id);
            for &a in &node.args {
                if self.last_use[a] == Some(i) {
                    resident =
                        resident.saturating_sub(crate::dag::flops::activation_bytes(g.node(a)));
                }
            }
            // A node nothing consumes was counted in; it stays resident.
        }
        peak
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dag::ir::{DType, OpKind, Shape};
    use crate::models::transformer::TransformerConfig;

    /// Training graph with a relu chain, an identity pool and a dead branch.
    fn messy_graph() -> Graph {
        let mut g = Graph::new();
        let x = g.placeholder("x", Shape::of(&[1, 2, 4, 4]), DType::F32);
        let y = g.placeholder("y", Shape::of(&[1, 2, 4, 4]), DType::F32);
        let r1 = g.op("r1", OpKind::Relu, &[x]).unwrap();
        let r2 = g.op("r2", OpKind::Relu, &[r1]).unwrap();
        let p = g.op("p", OpKind::MaxPool2d { kernel: 1, stride: 1 }, &[r2]).unwrap();
        // Dead branch: never reaches the loss.
        let dead = g.op("dead", OpKind::Gelu, &[p]).unwrap();
        g.op("dead2", OpKind::Softmax, &[dead]).unwrap();
        g.op("loss", OpKind::MseLoss, &[p, y]).unwrap();
        g
    }

    #[test]
    fn folding_then_dce_shrinks_messy_graph() {
        let mut g = messy_graph();
        let report = PassManager::standard().run(&mut g).unwrap();
        assert!(report.changed());
        // r2 (relu-of-relu), p (identity pool), dead, dead2 all gone.
        assert!(g.by_name("r2").is_none());
        assert!(g.by_name("p").is_none());
        assert!(g.by_name("dead").is_none());
        assert!(g.by_name("dead2").is_none());
        assert!(g.by_name("r1").is_some());
        // Loss now reads r1 directly.
        let loss = g.by_name("loss").unwrap();
        let r1 = g.by_name("r1").unwrap().id;
        assert_eq!(loss.args[0], r1);
        g.topo_order().unwrap();
    }

    #[test]
    fn standard_pipeline_is_idempotent() {
        let mut g = messy_graph();
        let pm = PassManager::standard();
        pm.run(&mut g).unwrap();
        let snapshot = crate::dag::serde::to_json(&g);
        let second = pm.run(&mut g).unwrap();
        assert!(!second.changed(), "second run changed the graph: {:?}", second.entries);
        assert_eq!(crate::dag::serde::to_json(&g), snapshot);
    }

    #[test]
    fn transformer_graph_is_already_normal() {
        // The e2e training graph contains no foldable patterns and no dead
        // nodes — the standard pipeline must be a structural no-op (this is
        // what makes PassManager safe on the training path).
        let mut g = TransformerConfig::tiny().build_graph();
        let before = crate::dag::serde::to_json(&g);
        let report = PassManager::standard().run(&mut g).unwrap();
        assert!(!report.changed(), "{:?}", report.entries);
        assert_eq!(crate::dag::serde::to_json(&g), before);
    }

    #[test]
    fn shape_inference_repairs_stale_shapes() {
        let mut g = Graph::new();
        let x = g.placeholder("x", Shape::of(&[2, 8]), DType::F32);
        let l = g
            .op("fc", OpKind::Linear { in_features: 8, out_features: 4, bias: true }, &[x])
            .unwrap();
        let r = g.op("r", OpKind::Relu, &[l]).unwrap();
        // Corrupt downstream shapes, as a rewrite that forgot to re-infer would.
        g.set_shape(r, Shape::of(&[99]), DType::F32);
        let changed = ShapeInference.run(&mut g).unwrap();
        assert!(changed);
        assert_eq!(g.node(r).out_shape, Shape::of(&[2, 4]));
        // Second run: fixpoint.
        assert!(!ShapeInference.run(&mut g).unwrap());
    }

    #[test]
    fn shape_inference_preserves_stagecall_overrides() {
        use crate::models::transformer::{pipeline_graph, PipelineSpec};
        let spec = PipelineSpec::new(TransformerConfig::tiny(), 2);
        let mut g = pipeline_graph(&spec);
        let head_shape = g.by_name("head").map(|n| n.out_shape.clone());
        assert!(!ShapeInference.run(&mut g).unwrap());
        assert_eq!(g.by_name("head").map(|n| n.out_shape.clone()), head_shape);
    }

    #[test]
    fn dce_keeps_sinks_without_loss() {
        // Inference graph: no loss ⇒ sinks are roots ⇒ nothing removed.
        let mut g = Graph::new();
        let x = g.placeholder("x", Shape::of(&[2, 4]), DType::F32);
        g.op("a", OpKind::Relu, &[x]).unwrap();
        g.op("b", OpKind::Gelu, &[x]).unwrap();
        assert!(!DeadNodeElimination.run(&mut g).unwrap());
        assert_eq!(g.len(), 3);
    }

    #[test]
    fn liveness_counts_uses_and_last_positions() {
        // messy_graph: x → r1 → r2 → p → {dead, loss}, dead → dead2.
        let g = messy_graph();
        let lv = Liveness::analyze(&g).unwrap();
        let x = g.by_name("x").unwrap().id;
        let r1 = g.by_name("r1").unwrap().id;
        let loss = g.by_name("loss").unwrap().id;
        assert_eq!(lv.use_count[x], 1, "x feeds r1 only");
        assert_eq!(lv.use_count[r1], 1);
        assert_eq!(lv.use_count[loss], 0, "loss is a sink");
        assert_eq!(lv.last_use[loss], None);
        // r1's last use is at r2's position.
        let r2 = g.by_name("r2").unwrap().id;
        assert_eq!(lv.last_use[r1], Some(lv.pos[r2]));
        // Every last_use points at a position that really consumes the node.
        for id in 0..g.len() {
            if let Some(p) = lv.last_use[id] {
                assert!(g.node(lv.order[p]).args.contains(&id));
            }
        }
    }

    #[test]
    fn liveness_subset_tracks_received_inputs() {
        let g = messy_graph();
        // Analyze only {r1, r2}: x is an out-of-set producer they consume.
        let mut in_set = vec![false; g.len()];
        in_set[g.by_name("r1").unwrap().id] = true;
        in_set[g.by_name("r2").unwrap().id] = true;
        let lv = Liveness::analyze_subset(&g, &in_set).unwrap();
        let x = g.by_name("x").unwrap().id;
        assert_eq!(lv.order.len(), 2);
        assert_eq!(lv.pos[x], usize::MAX, "x is out of set");
        assert_eq!(lv.use_count[x], 1, "but r1 reads it");
        assert_eq!(lv.last_use[x], Some(0));
    }

    #[test]
    fn liveness_peak_is_below_sum_of_activations_on_chains() {
        // A long chain frees each link after its single consumer, so the
        // peak is far below the keep-everything total.
        let mut g = Graph::new();
        let mut prev = g.placeholder("x", Shape::of(&[4, 64]), DType::F32);
        for i in 0..16 {
            prev = g.op(&format!("r{i}"), OpKind::Relu, &[prev]).unwrap();
        }
        let lv = Liveness::analyze(&g).unwrap();
        let peak = lv.peak_resident_bytes(&g);
        let total: u64 =
            g.nodes.iter().map(crate::dag::flops::activation_bytes).sum();
        assert!(peak <= 3 * 4 * 64 * 4, "chain peak holds ≤3 links, got {peak}");
        assert!(peak < total / 4, "peak {peak} vs total {total}");
    }

    #[test]
    fn validate_catches_broken_reverse_adjacency() {
        let mut g = messy_graph();
        assert!(TopoValidate.run(&mut g).is_ok());
        // Sever an arg directly (bypassing the builder) — users go stale.
        let loss = g.by_name("loss").unwrap().id;
        g.nodes[loss].args[0] = g.by_name("x").unwrap().id;
        assert!(TopoValidate.run(&mut g).is_err());
    }
}
