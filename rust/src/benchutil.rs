//! Benchmark harness (criterion is not available offline, so `cargo bench`
//! targets use `harness = false` binaries built on this module).
//!
//! Provides wall-clock micro-benchmarking with warmup + outlier-robust
//! statistics, and fixed-width table rendering for the figure/table
//! regeneration benches.

use std::time::Instant;

use crate::util::human_secs;
use crate::util::stats::Sample;

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub median_s: f64,
    pub mean_s: f64,
    pub p99_s: f64,
    pub min_s: f64,
}

impl BenchResult {
    pub fn per_iter(&self) -> f64 {
        self.median_s
    }
}

/// Time `f` for `iters` iterations after `warmup` unmeasured runs.
/// `f` receives the iteration index and returns a value that is
/// black-boxed to keep the optimizer honest.
pub fn bench<T>(name: &str, warmup: usize, iters: usize, mut f: impl FnMut(usize) -> T) -> BenchResult {
    for i in 0..warmup {
        black_box(f(i));
    }
    let mut sample = Sample::new();
    for i in 0..iters {
        let t0 = Instant::now();
        black_box(f(i));
        sample.add(t0.elapsed().as_secs_f64());
    }
    let r = BenchResult {
        name: name.to_string(),
        iters,
        median_s: sample.median(),
        mean_s: sample.mean(),
        p99_s: sample.p99(),
        min_s: sample.min(),
    };
    println!(
        "bench {:<42} median {:>12}  mean {:>12}  p99 {:>12}  (n={})",
        r.name,
        human_secs(r.median_s),
        human_secs(r.mean_s),
        human_secs(r.p99_s),
        iters
    );
    r
}

/// Prevent the optimizer from discarding a value (stable-rust black box).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Fixed-width table renderer for regenerating the paper's tables/figures
/// as text.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Table {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = h.len();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], width: &[usize]| -> String {
            let mut line = String::from("|");
            for (c, w) in cells.iter().zip(width) {
                line.push_str(&format!(" {:<w$} |", c, w = w));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.header, &width));
        let mut sep = String::from("|");
        for w in &width {
            sep.push_str(&format!("{}-|", "-".repeat(w + 2 - 1)));
        }
        sep.push('\n');
        out.push_str(&sep);
        for row in &self.rows {
            out.push_str(&fmt_row(row, &width));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("spin", 2, 10, |_| {
            let mut acc = 0u64;
            for i in 0..10_000 {
                acc = acc.wrapping_add(i);
            }
            acc
        });
        assert!(r.median_s > 0.0);
        assert!(r.min_s <= r.median_s && r.median_s <= r.p99_s);
        assert_eq!(r.iters, 10);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["GPU", "TFLOPS"]);
        t.row(&["RTX 3080".to_string(), "59.5".to_string()]);
        t.row(&["H100".to_string(), "756".to_string()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        // all lines same width
        assert!(lines.iter().all(|l| l.len() == lines[0].len()));
        assert!(s.contains("RTX 3080"));
    }

    #[test]
    #[should_panic(expected = "row arity mismatch")]
    fn table_arity_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".to_string()]);
    }
}
