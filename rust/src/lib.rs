//! # FusionAI — decentralized training & deployment of LLMs on consumer GPUs
//!
//! Reproduction of *FusionAI: Decentralized Training and Deploying LLMs with
//! Massive Consumer-Level GPUs* (Tang et al., 2023).
//!
//! The crate is the **Layer-3 rust coordinator** of a three-layer stack:
//!
//! * **L3 (this crate)** — broker, compnodes, DHT, DAG IR + decomposer,
//!   scheduler, analytic performance model, pipeline engine, simulated WAN,
//!   compression, metrics and the CLI. Python never runs on this path.
//! * **L2 (python/compile/model.py)** — the pipeline-stage compute (embedding,
//!   transformer blocks, head+loss, Adam update) written in JAX and AOT-lowered
//!   to HLO text in `artifacts/`.
//! * **L1 (python/compile/kernels/)** — Pallas kernels (tiled attention,
//!   int8 quantization) called from L2, validated against pure-jnp oracles.
//!
//! The [`runtime`] module loads the AOT artifacts through the PJRT C API
//! (`xla` crate) and [`exec::XlaEngine`] exposes them to the coordinator;
//! [`exec::RefEngine`] is a pure-rust fallback engine used by the simulator
//! and tests (the paper's "execution plane" pluggability, goals P3/P4).
//!
//! See `DESIGN.md` for the full system inventory and the experiment index.

pub mod util;
pub mod tensor;
pub mod dag;
pub mod models;
pub mod perf;
pub mod decompose;
pub mod sched;
pub mod net;
pub mod dht;
pub mod compress;
pub mod broker;
pub mod compnode;
pub mod exec;
pub mod runtime;
pub mod pipeline;
pub mod incentive;
pub mod config;
pub mod metrics;
pub mod benchutil;
pub mod proptesting;
pub mod cluster;
pub mod serve;
pub mod verify;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
