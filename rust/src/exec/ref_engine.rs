//! The pure-rust reference engine: interprets every IR operator on CPU.
//!
//! All backward implementations are hand-derived VJPs and are verified
//! against central finite differences in the test suite (`fd_check`). The
//! engine is deterministic and dependency-free, which makes it the
//! execution-plane backend for the simulator, the quickstart example, and
//! the oracle opposite the XLA artifact engine.

use anyhow::{anyhow, bail, Result};

use crate::dag::{Node, OpKind};
use crate::exec::{BackwardOut, Engine};
use crate::tensor::{
    gelu, gelu_grad, matmul, matmul_at, matmul_bt, softmax_lastaxis, Tensor,
};
use crate::util::Rng;

/// Pure-rust execution-plane backend.
#[derive(Debug, Default)]
pub struct RefEngine;

impl RefEngine {
    pub fn new() -> RefEngine {
        RefEngine
    }
}

impl Engine for RefEngine {
    fn name(&self) -> &'static str {
        "ref"
    }

    fn init_params(&mut self, node: &Node, rng: &mut Rng) -> Result<Vec<Tensor>> {
        use OpKind::*;
        Ok(match &node.kind {
            Variable => vec![Tensor::randn(node.out_shape.dims(), 0.02, rng)],
            Conv2d { in_ch, out_ch, kernel, .. } => {
                let std = (2.0 / (*in_ch as f32 * (*kernel * *kernel) as f32)).sqrt();
                vec![
                    Tensor::randn(&[*out_ch, *in_ch, *kernel, *kernel], std, rng),
                    Tensor::zeros(&[*out_ch]),
                ]
            }
            Linear { in_features, out_features, bias } => {
                let std = 1.0 / (*in_features as f32).sqrt();
                let mut p = vec![Tensor::randn(&[*in_features, *out_features], std, rng)];
                if *bias {
                    p.push(Tensor::zeros(&[*out_features]));
                }
                p
            }
            Embedding { vocab, dim } => vec![Tensor::randn(&[*vocab, *dim], 0.02, rng)],
            LayerNorm { dim } => vec![
                Tensor::from_vec(&[*dim], vec![1.0; *dim]),
                Tensor::zeros(&[*dim]),
            ],
            Attention { dim, .. } => {
                let std = 1.0 / (*dim as f32).sqrt();
                vec![
                    Tensor::randn(&[*dim, 3 * *dim], std, rng),
                    Tensor::zeros(&[3 * *dim]),
                    Tensor::randn(&[*dim, *dim], std, rng),
                    Tensor::zeros(&[*dim]),
                ]
            }
            FeedForward { dim, hidden } => {
                let s1 = 1.0 / (*dim as f32).sqrt();
                let s2 = 1.0 / (*hidden as f32).sqrt();
                vec![
                    Tensor::randn(&[*dim, *hidden], s1, rng),
                    Tensor::zeros(&[*hidden]),
                    Tensor::randn(&[*hidden, *dim], s2, rng),
                    Tensor::zeros(&[*dim]),
                ]
            }
            _ => vec![],
        })
    }

    fn forward(&mut self, node: &Node, inputs: &[&Tensor], params: &[Tensor]) -> Result<Tensor> {
        use OpKind::*;
        match &node.kind {
            Placeholder => bail!("placeholders are fed, not executed"),
            Variable => Ok(params[0].clone()),
            Linear { in_features, out_features, bias } => {
                linear_fwd(inputs[0], params, *in_features, *out_features, *bias)
            }
            Conv2d { in_ch, out_ch, kernel, stride, padding } => {
                conv2d_fwd(inputs[0], &params[0], &params[1], *in_ch, *out_ch, *kernel, *stride, *padding)
            }
            Embedding { vocab, dim } => embedding_fwd(inputs[0], &params[0], *vocab, *dim),
            LayerNorm { dim } => Ok(layernorm_fwd(inputs[0], &params[0], &params[1], *dim).0),
            Attention { heads, dim, causal } => {
                Ok(attention_fwd(inputs[0], params, *heads, *dim, *causal))
            }
            FeedForward { dim, hidden } => Ok(ffn_fwd(inputs[0], params, *dim, *hidden)),
            Add => Ok(inputs[0].zip(inputs[1], |a, b| a + b)),
            Multiply => Ok(inputs[0].zip(inputs[1], |a, b| a * b)),
            Relu => Ok(inputs[0].map(|x| x.max(0.0))),
            Gelu => Ok(inputs[0].map(gelu)),
            Softmax => {
                let mut out = inputs[0].clone();
                let row = *out.shape().last().unwrap();
                softmax_lastaxis(out.f_mut(), row);
                Ok(out)
            }
            MaxPool2d { kernel, stride } => Ok(maxpool_fwd(inputs[0], *kernel, *stride).0),
            Concat { axis } => concat_fwd(inputs, *axis),
            CrossEntropy { weight } => {
                let (labels, logits) = split_ce_inputs(inputs)?;
                Ok(Tensor::scalar(cross_entropy_fwd(logits, labels) * *weight as f32))
            }
            MseLoss => {
                let a = inputs[0].f();
                let b = inputs[1].f();
                let n = a.len() as f32;
                let mse = a.iter().zip(b).map(|(&x, &y)| (x - y) * (x - y)).sum::<f32>() / n;
                Ok(Tensor::scalar(mse))
            }
            StageCall { stage, .. } => {
                Err(anyhow!("RefEngine cannot execute StageCall '{stage}' (use XlaEngine)"))
            }
        }
    }

    fn backward(
        &mut self,
        node: &Node,
        inputs: &[&Tensor],
        params: &[Tensor],
        out_grad: Option<&Tensor>,
    ) -> Result<BackwardOut> {
        use OpKind::*;
        // Loss nodes may be seeded; everything else requires an upstream grad.
        let seeded = Tensor::scalar(1.0);
        let dy = out_grad.unwrap_or(&seeded);
        match &node.kind {
            Placeholder => bail!("placeholders have no backward"),
            Variable => Ok(BackwardOut { input_grads: vec![], param_grads: vec![dy.clone()] }),
            Linear { in_features, out_features, bias } => {
                linear_bwd(inputs[0], params, dy, *in_features, *out_features, *bias)
            }
            Conv2d { in_ch, out_ch, kernel, stride, padding } => {
                conv2d_bwd(inputs[0], &params[0], dy, *in_ch, *out_ch, *kernel, *stride, *padding)
            }
            Embedding { vocab, dim } => {
                let mut dtable = Tensor::zeros(&[*vocab, *dim]);
                let ids = inputs[0].i();
                let dyf = dy.f();
                let dt = dtable.f_mut();
                for (pos, &id) in ids.iter().enumerate() {
                    let row = id as usize * *dim;
                    for d in 0..*dim {
                        dt[row + d] += dyf[pos * *dim + d];
                    }
                }
                Ok(BackwardOut { input_grads: vec![None], param_grads: vec![dtable] })
            }
            LayerNorm { dim } => layernorm_bwd(inputs[0], &params[0], dy, *dim),
            Attention { heads, dim, causal } => {
                attention_bwd(inputs[0], params, dy, *heads, *dim, *causal)
            }
            FeedForward { dim, hidden } => ffn_bwd(inputs[0], params, dy, *dim, *hidden),
            Add => Ok(BackwardOut {
                input_grads: vec![Some(dy.clone()), Some(dy.clone())],
                param_grads: vec![],
            }),
            Multiply => Ok(BackwardOut {
                input_grads: vec![
                    Some(dy.zip(inputs[1], |g, b| g * b)),
                    Some(dy.zip(inputs[0], |g, a| g * a)),
                ],
                param_grads: vec![],
            }),
            Relu => Ok(BackwardOut {
                input_grads: vec![Some(dy.zip(inputs[0], |g, x| if x > 0.0 { g } else { 0.0 }))],
                param_grads: vec![],
            }),
            Gelu => Ok(BackwardOut {
                input_grads: vec![Some(dy.zip(inputs[0], |g, x| g * gelu_grad(x)))],
                param_grads: vec![],
            }),
            Softmax => {
                let mut y = inputs[0].clone();
                let row = *y.shape().last().unwrap();
                softmax_lastaxis(y.f_mut(), row);
                let yf = y.f();
                let gf = dy.f();
                let mut dx = vec![0.0f32; yf.len()];
                for r in 0..yf.len() / row {
                    let o = r * row;
                    let dot: f32 =
                        (0..row).map(|j| gf[o + j] * yf[o + j]).sum();
                    for j in 0..row {
                        dx[o + j] = yf[o + j] * (gf[o + j] - dot);
                    }
                }
                Ok(BackwardOut {
                    input_grads: vec![Some(Tensor::from_vec(inputs[0].shape(), dx))],
                    param_grads: vec![],
                })
            }
            MaxPool2d { kernel, stride } => {
                let (_, argmax) = maxpool_fwd(inputs[0], *kernel, *stride);
                let mut dx = Tensor::zeros(inputs[0].shape());
                let dxf = dx.f_mut();
                for (o, &src) in argmax.iter().enumerate() {
                    dxf[src] += dy.f()[o];
                }
                Ok(BackwardOut { input_grads: vec![Some(dx)], param_grads: vec![] })
            }
            Concat { axis } => concat_bwd(inputs, dy, *axis),
            CrossEntropy { weight } => {
                let (labels, logits) = split_ce_inputs(inputs)?;
                let scale = dy.item() * *weight as f32;
                let dlogits = cross_entropy_bwd(logits, labels, scale);
                // Align grads with the arg order (labels get None).
                let grads = if inputs[0].is_f32() {
                    vec![Some(dlogits), None]
                } else {
                    vec![None, Some(dlogits)]
                };
                Ok(BackwardOut { input_grads: grads, param_grads: vec![] })
            }
            MseLoss => {
                let a = inputs[0].f();
                let b = inputs[1].f();
                let n = a.len() as f32;
                let s = 2.0 * dy.item() / n;
                let da: Vec<f32> = a.iter().zip(b).map(|(&x, &y)| s * (x - y)).collect();
                let db: Vec<f32> = da.iter().map(|&g| -g).collect();
                Ok(BackwardOut {
                    input_grads: vec![
                        Some(Tensor::from_vec(inputs[0].shape(), da)),
                        Some(Tensor::from_vec(inputs[1].shape(), db)),
                    ],
                    param_grads: vec![],
                })
            }
            StageCall { stage, .. } => {
                Err(anyhow!("RefEngine cannot execute StageCall '{stage}' (use XlaEngine)"))
            }
        }
    }
}

// ---------------------------------------------------------------------------
// op implementations
// ---------------------------------------------------------------------------

fn linear_fwd(
    x: &Tensor,
    params: &[Tensor],
    in_f: usize,
    out_f: usize,
    bias: bool,
) -> Result<Tensor> {
    let m = x.numel() / in_f;
    let mut y = matmul(x.f(), params[0].f(), m, in_f, out_f);
    if bias {
        let b = params[1].f();
        for row in y.chunks_mut(out_f) {
            for (v, &bv) in row.iter_mut().zip(b) {
                *v += bv;
            }
        }
    }
    let mut shape = x.shape().to_vec();
    *shape.last_mut().unwrap() = out_f;
    Ok(Tensor::from_vec(&shape, y))
}

fn linear_bwd(
    x: &Tensor,
    params: &[Tensor],
    dy: &Tensor,
    in_f: usize,
    out_f: usize,
    bias: bool,
) -> Result<BackwardOut> {
    let m = x.numel() / in_f;
    // dx[m,in] = dy[m,out] · Wᵀ[out,in]; with W[in,out] use matmul_bt.
    let dx = matmul_bt(dy.f(), params[0].f(), m, out_f, in_f);
    // dW[in,out] = xᵀ[in,m] · dy[m,out]
    let dw = matmul_at(x.f(), dy.f(), in_f, m, out_f);
    let mut grads = vec![Tensor::from_vec(&[in_f, out_f], dw)];
    if bias {
        let mut db = vec![0.0f32; out_f];
        for row in dy.f().chunks(out_f) {
            for (d, &v) in db.iter_mut().zip(row) {
                *d += v;
            }
        }
        grads.push(Tensor::from_vec(&[out_f], db));
    }
    Ok(BackwardOut {
        input_grads: vec![Some(Tensor::from_vec(x.shape(), dx))],
        param_grads: grads,
    })
}

#[allow(clippy::too_many_arguments)]
fn conv2d_fwd(
    x: &Tensor,
    w: &Tensor,
    b: &Tensor,
    in_ch: usize,
    out_ch: usize,
    k: usize,
    stride: usize,
    pad: usize,
) -> Result<Tensor> {
    let s = x.shape();
    let (n, h, wd) = (s[0], s[2], s[3]);
    let oh = (h + 2 * pad - k) / stride + 1;
    let ow = (wd + 2 * pad - k) / stride + 1;
    let xf = x.f();
    let wf = w.f();
    let bf = b.f();
    let mut out = vec![0.0f32; n * out_ch * oh * ow];
    for ni in 0..n {
        for oc in 0..out_ch {
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut acc = bf[oc];
                    for ic in 0..in_ch {
                        for ky in 0..k {
                            for kx in 0..k {
                                let iy = oy * stride + ky;
                                let ix = ox * stride + kx;
                                if iy < pad || ix < pad {
                                    continue;
                                }
                                let (iy, ix) = (iy - pad, ix - pad);
                                if iy >= h || ix >= wd {
                                    continue;
                                }
                                acc += xf[((ni * in_ch + ic) * h + iy) * wd + ix]
                                    * wf[((oc * in_ch + ic) * k + ky) * k + kx];
                            }
                        }
                    }
                    out[((ni * out_ch + oc) * oh + oy) * ow + ox] = acc;
                }
            }
        }
    }
    Ok(Tensor::from_vec(&[n, out_ch, oh, ow], out))
}

#[allow(clippy::too_many_arguments)]
fn conv2d_bwd(
    x: &Tensor,
    w: &Tensor,
    dy: &Tensor,
    in_ch: usize,
    out_ch: usize,
    k: usize,
    stride: usize,
    pad: usize,
) -> Result<BackwardOut> {
    let s = x.shape();
    let (n, h, wd) = (s[0], s[2], s[3]);
    let os = dy.shape();
    let (oh, ow) = (os[2], os[3]);
    let xf = x.f();
    let wf = w.f();
    let dyf = dy.f();
    let mut dx = vec![0.0f32; xf.len()];
    let mut dw = vec![0.0f32; wf.len()];
    let mut db = vec![0.0f32; out_ch];
    for ni in 0..n {
        for oc in 0..out_ch {
            for oy in 0..oh {
                for ox in 0..ow {
                    let g = dyf[((ni * out_ch + oc) * oh + oy) * ow + ox];
                    db[oc] += g;
                    for ic in 0..in_ch {
                        for ky in 0..k {
                            for kx in 0..k {
                                let iy = oy * stride + ky;
                                let ix = ox * stride + kx;
                                if iy < pad || ix < pad {
                                    continue;
                                }
                                let (iy, ix) = (iy - pad, ix - pad);
                                if iy >= h || ix >= wd {
                                    continue;
                                }
                                let xi = ((ni * in_ch + ic) * h + iy) * wd + ix;
                                let wi = ((oc * in_ch + ic) * k + ky) * k + kx;
                                dx[xi] += g * wf[wi];
                                dw[wi] += g * xf[xi];
                            }
                        }
                    }
                }
            }
        }
    }
    Ok(BackwardOut {
        input_grads: vec![Some(Tensor::from_vec(x.shape(), dx))],
        param_grads: vec![
            Tensor::from_vec(w.shape(), dw),
            Tensor::from_vec(&[out_ch], db),
        ],
    })
}

fn embedding_fwd(ids: &Tensor, table: &Tensor, vocab: usize, dim: usize) -> Result<Tensor> {
    let tf = table.f();
    let mut out = Vec::with_capacity(ids.numel() * dim);
    for &id in ids.i() {
        let id = id as usize;
        if id >= vocab {
            bail!("token id {id} out of vocab {vocab}");
        }
        out.extend_from_slice(&tf[id * dim..(id + 1) * dim]);
    }
    let mut shape = ids.shape().to_vec();
    shape.push(dim);
    Ok(Tensor::from_vec(&shape, out))
}

/// Returns (output, per-row (mean, inv_std)) — backward recomputes them.
fn layernorm_fwd(x: &Tensor, gamma: &Tensor, beta: &Tensor, dim: usize) -> (Tensor, Vec<(f32, f32)>) {
    const EPS: f32 = 1e-5;
    let xf = x.f();
    let gf = gamma.f();
    let bf = beta.f();
    let rows = xf.len() / dim;
    let mut out = vec![0.0f32; xf.len()];
    let mut stats = Vec::with_capacity(rows);
    for r in 0..rows {
        let seg = &xf[r * dim..(r + 1) * dim];
        let mean = seg.iter().sum::<f32>() / dim as f32;
        let var = seg.iter().map(|&v| (v - mean) * (v - mean)).sum::<f32>() / dim as f32;
        let inv = 1.0 / (var + EPS).sqrt();
        for j in 0..dim {
            out[r * dim + j] = gf[j] * (seg[j] - mean) * inv + bf[j];
        }
        stats.push((mean, inv));
    }
    (Tensor::from_vec(x.shape(), out), stats)
}

fn layernorm_bwd(x: &Tensor, gamma: &Tensor, dy: &Tensor, dim: usize) -> Result<BackwardOut> {
    let (_, stats) = layernorm_fwd(x, gamma, &Tensor::zeros(&[dim]), dim);
    let xf = x.f();
    let gf = gamma.f();
    let dyf = dy.f();
    let rows = xf.len() / dim;
    let mut dx = vec![0.0f32; xf.len()];
    let mut dgamma = vec![0.0f32; dim];
    let mut dbeta = vec![0.0f32; dim];
    for r in 0..rows {
        let (mean, inv) = stats[r];
        let o = r * dim;
        // xhat and dyhat = dy·γ
        let mut sum_dyh = 0.0f32;
        let mut sum_dyh_xh = 0.0f32;
        for j in 0..dim {
            let xh = (xf[o + j] - mean) * inv;
            let dyh = dyf[o + j] * gf[j];
            sum_dyh += dyh;
            sum_dyh_xh += dyh * xh;
            dgamma[j] += dyf[o + j] * xh;
            dbeta[j] += dyf[o + j];
        }
        let nd = dim as f32;
        for j in 0..dim {
            let xh = (xf[o + j] - mean) * inv;
            let dyh = dyf[o + j] * gf[j];
            dx[o + j] = inv * (dyh - sum_dyh / nd - xh * sum_dyh_xh / nd);
        }
    }
    Ok(BackwardOut {
        input_grads: vec![Some(Tensor::from_vec(x.shape(), dx))],
        param_grads: vec![Tensor::from_vec(&[dim], dgamma), Tensor::from_vec(&[dim], dbeta)],
    })
}

/// Multi-head self-attention forward. params = [Wqkv, bqkv, Wo, bo].
fn attention_fwd(x: &Tensor, params: &[Tensor], heads: usize, dim: usize, causal: bool) -> Tensor {
    let (ctx, _) = attention_core(x, params, heads, dim, causal);
    let s = x.shape();
    let (b, sl) = (s[0], s[1]);
    // out = ctx·Wo + bo
    let mut out = matmul(&ctx, params[2].f(), b * sl, dim, dim);
    let bo = params[3].f();
    for row in out.chunks_mut(dim) {
        for (v, &bv) in row.iter_mut().zip(bo) {
            *v += bv;
        }
    }
    Tensor::from_vec(s, out)
}

/// Shared fwd computation: returns (concat context [B*S, D], per-(b,h)
/// softmax probabilities P [S,S] flattened) for reuse in backward.
fn attention_core(
    x: &Tensor,
    params: &[Tensor],
    heads: usize,
    dim: usize,
    causal: bool,
) -> (Vec<f32>, Vec<Vec<f32>>) {
    let s = x.shape();
    let (b, sl) = (s[0], s[1]);
    let hd = dim / heads;
    let scale = 1.0 / (hd as f32).sqrt();
    // qkv[B*S, 3D]
    let mut qkv = matmul(x.f(), params[0].f(), b * sl, dim, 3 * dim);
    let bqkv = params[1].f();
    for row in qkv.chunks_mut(3 * dim) {
        for (v, &bv) in row.iter_mut().zip(bqkv) {
            *v += bv;
        }
    }
    let mut ctx = vec![0.0f32; b * sl * dim];
    let mut probs = Vec::with_capacity(b * heads);
    for bi in 0..b {
        for h in 0..heads {
            // Q,K,V [S,hd] slices of qkv rows.
            let q_off = h * hd;
            let k_off = dim + h * hd;
            let v_off = 2 * dim + h * hd;
            let mut scores = vec![f32::NEG_INFINITY; sl * sl];
            for i in 0..sl {
                let qrow = &qkv[(bi * sl + i) * 3 * dim + q_off..][..hd];
                let jmax = if causal { i + 1 } else { sl };
                for j in 0..jmax {
                    let krow = &qkv[(bi * sl + j) * 3 * dim + k_off..][..hd];
                    let mut dot = 0.0;
                    for d in 0..hd {
                        dot += qrow[d] * krow[d];
                    }
                    scores[i * sl + j] = dot * scale;
                }
            }
            softmax_lastaxis(&mut scores, sl);
            // ctx_i = Σ_j P_ij · V_j
            for i in 0..sl {
                for j in 0..sl {
                    let p = scores[i * sl + j];
                    if p == 0.0 {
                        continue;
                    }
                    let vrow = &qkv[(bi * sl + j) * 3 * dim + v_off..][..hd];
                    let crow = &mut ctx[(bi * sl + i) * dim + h * hd..][..hd];
                    for d in 0..hd {
                        crow[d] += p * vrow[d];
                    }
                }
            }
            probs.push(scores);
        }
    }
    (ctx, probs)
}

fn attention_bwd(
    x: &Tensor,
    params: &[Tensor],
    dy: &Tensor,
    heads: usize,
    dim: usize,
    causal: bool,
) -> Result<BackwardOut> {
    let s = x.shape();
    let (b, sl) = (s[0], s[1]);
    let hd = dim / heads;
    let scale = 1.0 / (hd as f32).sqrt();
    let rows = b * sl;

    // Recompute forward intermediates.
    let mut qkv = matmul(x.f(), params[0].f(), rows, dim, 3 * dim);
    let bqkv = params[1].f();
    for row in qkv.chunks_mut(3 * dim) {
        for (v, &bv) in row.iter_mut().zip(bqkv) {
            *v += bv;
        }
    }
    let (ctx, probs) = attention_core(x, params, heads, dim, causal);

    // out = ctx·Wo + bo  ⇒  dctx = dy·Woᵀ ; dWo = ctxᵀ·dy ; dbo = Σ dy.
    let dctx = matmul_bt(dy.f(), params[2].f(), rows, dim, dim);
    let dwo = matmul_at(&ctx, dy.f(), dim, rows, dim);
    let mut dbo = vec![0.0f32; dim];
    for row in dy.f().chunks(dim) {
        for (d, &v) in dbo.iter_mut().zip(row) {
            *d += v;
        }
    }

    // Per (batch, head): dP, dscores, dQ, dK, dV.
    let mut dqkv = vec![0.0f32; rows * 3 * dim];
    for bi in 0..b {
        for h in 0..heads {
            let p = &probs[bi * heads + h]; // [S,S]
            let q_off = h * hd;
            let k_off = dim + h * hd;
            let v_off = 2 * dim + h * hd;
            // dP_ij = dctx_i · V_j ; dV_j = Σ_i P_ij dctx_i
            let mut dp = vec![0.0f32; sl * sl];
            for i in 0..sl {
                let dci = &dctx[(bi * sl + i) * dim + h * hd..][..hd];
                for j in 0..sl {
                    if p[i * sl + j] == 0.0 && !causal {
                        // still need dp for softmax bwd; compute anyway below
                    }
                    let vrow = &qkv[(bi * sl + j) * 3 * dim + v_off..][..hd];
                    let mut dot = 0.0;
                    for d in 0..hd {
                        dot += dci[d] * vrow[d];
                    }
                    dp[i * sl + j] = dot;
                    // dV
                    let pv = p[i * sl + j];
                    if pv != 0.0 {
                        let dvrow = &mut dqkv[(bi * sl + j) * 3 * dim + v_off..][..hd];
                        for d in 0..hd {
                            dvrow[d] += pv * dci[d];
                        }
                    }
                }
            }
            // softmax backward per row: ds = P ∘ (dP − Σ_j dP·P)
            let mut ds = vec![0.0f32; sl * sl];
            for i in 0..sl {
                let o = i * sl;
                let dot: f32 = (0..sl).map(|j| dp[o + j] * p[o + j]).sum();
                for j in 0..sl {
                    ds[o + j] = p[o + j] * (dp[o + j] - dot);
                }
            }
            // dQ_i = scale Σ_j ds_ij K_j ; dK_j = scale Σ_i ds_ij Q_i
            for i in 0..sl {
                for j in 0..sl {
                    let g = ds[i * sl + j] * scale;
                    if g == 0.0 {
                        continue;
                    }
                    let (qi, kj) = ((bi * sl + i) * 3 * dim, (bi * sl + j) * 3 * dim);
                    for d in 0..hd {
                        dqkv[qi + q_off + d] += g * qkv[kj + k_off + d];
                        dqkv[kj + k_off + d] += g * qkv[qi + q_off + d];
                    }
                }
            }
        }
    }

    // qkv = x·Wqkv + b ⇒ dx = dqkv·Wqkvᵀ ; dWqkv = xᵀ·dqkv ; dbqkv = Σ dqkv.
    let dx = matmul_bt(&dqkv, params[0].f(), rows, 3 * dim, dim);
    let dwqkv = matmul_at(x.f(), &dqkv, dim, rows, 3 * dim);
    let mut dbqkv = vec![0.0f32; 3 * dim];
    for row in dqkv.chunks(3 * dim) {
        for (d, &v) in dbqkv.iter_mut().zip(row) {
            *d += v;
        }
    }

    Ok(BackwardOut {
        input_grads: vec![Some(Tensor::from_vec(x.shape(), dx))],
        param_grads: vec![
            Tensor::from_vec(&[dim, 3 * dim], dwqkv),
            Tensor::from_vec(&[3 * dim], dbqkv),
            Tensor::from_vec(&[dim, dim], dwo),
            Tensor::from_vec(&[dim], dbo),
        ],
    })
}

fn ffn_fwd(x: &Tensor, params: &[Tensor], dim: usize, hidden: usize) -> Tensor {
    let rows = x.numel() / dim;
    let mut h = matmul(x.f(), params[0].f(), rows, dim, hidden);
    let b1 = params[1].f();
    for row in h.chunks_mut(hidden) {
        for (v, &bv) in row.iter_mut().zip(b1) {
            *v += bv;
        }
    }
    let a: Vec<f32> = h.iter().map(|&v| gelu(v)).collect();
    let mut y = matmul(&a, params[2].f(), rows, hidden, dim);
    let b2 = params[3].f();
    for row in y.chunks_mut(dim) {
        for (v, &bv) in row.iter_mut().zip(b2) {
            *v += bv;
        }
    }
    Tensor::from_vec(x.shape(), y)
}

fn ffn_bwd(
    x: &Tensor,
    params: &[Tensor],
    dy: &Tensor,
    dim: usize,
    hidden: usize,
) -> Result<BackwardOut> {
    let rows = x.numel() / dim;
    // Recompute h and a.
    let mut h = matmul(x.f(), params[0].f(), rows, dim, hidden);
    let b1 = params[1].f();
    for row in h.chunks_mut(hidden) {
        for (v, &bv) in row.iter_mut().zip(b1) {
            *v += bv;
        }
    }
    let a: Vec<f32> = h.iter().map(|&v| gelu(v)).collect();
    // y = a·W2 + b2
    let da = matmul_bt(dy.f(), params[2].f(), rows, dim, hidden);
    let dw2 = matmul_at(&a, dy.f(), hidden, rows, dim);
    let mut db2 = vec![0.0f32; dim];
    for row in dy.f().chunks(dim) {
        for (d, &v) in db2.iter_mut().zip(row) {
            *d += v;
        }
    }
    // a = gelu(h)
    let dh: Vec<f32> = da.iter().zip(&h).map(|(&g, &hv)| g * gelu_grad(hv)).collect();
    // h = x·W1 + b1
    let dx = matmul_bt(&dh, params[0].f(), rows, hidden, dim);
    let dw1 = matmul_at(x.f(), &dh, dim, rows, hidden);
    let mut db1 = vec![0.0f32; hidden];
    for row in dh.chunks(hidden) {
        for (d, &v) in db1.iter_mut().zip(row) {
            *d += v;
        }
    }
    Ok(BackwardOut {
        input_grads: vec![Some(Tensor::from_vec(x.shape(), dx))],
        param_grads: vec![
            Tensor::from_vec(&[dim, hidden], dw1),
            Tensor::from_vec(&[hidden], db1),
            Tensor::from_vec(&[hidden, dim], dw2),
            Tensor::from_vec(&[dim], db2),
        ],
    })
}

/// Returns (output, flat argmax indices into the input) for pooling.
fn maxpool_fwd(x: &Tensor, k: usize, stride: usize) -> (Tensor, Vec<usize>) {
    let s = x.shape();
    let (n, c, h, w) = (s[0], s[1], s[2], s[3]);
    let oh = (h - k) / stride + 1;
    let ow = (w - k) / stride + 1;
    let xf = x.f();
    let mut out = vec![0.0f32; n * c * oh * ow];
    let mut arg = vec![0usize; out.len()];
    for ni in 0..n {
        for ci in 0..c {
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut best = f32::NEG_INFINITY;
                    let mut bi = 0;
                    for ky in 0..k {
                        for kx in 0..k {
                            let idx = ((ni * c + ci) * h + oy * stride + ky) * w
                                + ox * stride
                                + kx;
                            if xf[idx] > best {
                                best = xf[idx];
                                bi = idx;
                            }
                        }
                    }
                    let o = ((ni * c + ci) * oh + oy) * ow + ox;
                    out[o] = best;
                    arg[o] = bi;
                }
            }
        }
    }
    (Tensor::from_vec(&[n, c, oh, ow], out), arg)
}

fn concat_fwd(inputs: &[&Tensor], axis: usize) -> Result<Tensor> {
    let base = inputs[0].shape();
    let outer: usize = base[..axis].iter().product();
    let inner: usize = base[axis + 1..].iter().product();
    let mut axis_total = 0;
    for t in inputs {
        axis_total += t.shape()[axis];
    }
    let mut shape = base.to_vec();
    shape[axis] = axis_total;
    let mut out = vec![0.0f32; outer * axis_total * inner];
    for o in 0..outer {
        let mut dst_off = o * axis_total * inner;
        for t in inputs {
            let a = t.shape()[axis];
            let src = &t.f()[o * a * inner..(o + 1) * a * inner];
            out[dst_off..dst_off + a * inner].copy_from_slice(src);
            dst_off += a * inner;
        }
    }
    Ok(Tensor::from_vec(&shape, out))
}

fn concat_bwd(inputs: &[&Tensor], dy: &Tensor, axis: usize) -> Result<BackwardOut> {
    let base = inputs[0].shape();
    let outer: usize = base[..axis].iter().product();
    let inner: usize = base[axis + 1..].iter().product();
    let axis_total: usize = inputs.iter().map(|t| t.shape()[axis]).sum();
    let dyf = dy.f();
    let mut grads: Vec<Option<Tensor>> = Vec::with_capacity(inputs.len());
    let mut axis_off = 0;
    for t in inputs {
        let a = t.shape()[axis];
        let mut g = vec![0.0f32; t.numel()];
        for o in 0..outer {
            let src = &dyf[(o * axis_total + axis_off) * inner..][..a * inner];
            g[o * a * inner..(o + 1) * a * inner].copy_from_slice(src);
        }
        grads.push(Some(Tensor::from_vec(t.shape(), g)));
        axis_off += a;
    }
    Ok(BackwardOut { input_grads: grads, param_grads: vec![] })
}

/// Identify (labels, logits) from a CrossEntropy node's inputs (either order).
fn split_ce_inputs<'a>(inputs: &[&'a Tensor]) -> Result<(&'a Tensor, &'a Tensor)> {
    match (inputs[0].is_f32(), inputs[1].is_f32()) {
        (false, true) => Ok((inputs[0], inputs[1])),
        (true, false) => Ok((inputs[1], inputs[0])),
        _ => bail!("CrossEntropy wants one i32 label tensor and one f32 logits tensor"),
    }
}

fn cross_entropy_fwd(logits: &Tensor, labels: &Tensor) -> f32 {
    let c = *logits.shape().last().unwrap();
    let n = logits.numel() / c;
    let mut probs = logits.f().to_vec();
    softmax_lastaxis(&mut probs, c);
    let mut loss = 0.0f32;
    for (r, &lab) in labels.i().iter().enumerate() {
        loss -= (probs[r * c + lab as usize]).max(1e-12).ln();
    }
    loss / n as f32
}

fn cross_entropy_bwd(logits: &Tensor, labels: &Tensor, scale: f32) -> Tensor {
    let c = *logits.shape().last().unwrap();
    let n = logits.numel() / c;
    let mut probs = logits.f().to_vec();
    softmax_lastaxis(&mut probs, c);
    let s = scale / n as f32;
    for (r, &lab) in labels.i().iter().enumerate() {
        probs[r * c + lab as usize] -= 1.0;
    }
    for v in probs.iter_mut() {
        *v *= s;
    }
    Tensor::from_vec(logits.shape(), probs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dag::{DType, Graph, NodeId, Shape};

    /// Central finite-difference check of input & parameter gradients for a
    /// single-op graph. `loss(y) = Σ w∘y` for a fixed random weighting.
    fn fd_check(kind: OpKind, in_shapes: &[(&[usize], DType)], tol: f32) {
        let mut g = Graph::new();
        let mut args: Vec<NodeId> = Vec::new();
        for (i, (sh, dt)) in in_shapes.iter().enumerate() {
            args.push(g.placeholder(&format!("in{i}"), Shape::of(sh), *dt));
        }
        let id = g.op("op", kind, &args).unwrap();
        let node = g.node(id).clone();

        let mut rng = Rng::new(77);
        let mut eng = RefEngine::new();
        let params = eng.init_params(&node, &mut rng).unwrap();
        let inputs: Vec<Tensor> = in_shapes
            .iter()
            .map(|(sh, dt)| match dt {
                DType::F32 => Tensor::randn(sh, 1.0, &mut rng),
                DType::I32 => {
                    let n: usize = sh.iter().product();
                    Tensor::from_ivec(sh, (0..n).map(|i| (i % 3) as i32).collect())
                }
            })
            .collect();
        let input_refs: Vec<&Tensor> = inputs.iter().collect();

        let out = eng.forward(&node, &input_refs, &params).unwrap();
        let w: Vec<f32> = (0..out.numel()).map(|_| rng.normal() as f32).collect();
        let weight = Tensor::from_vec(out.shape(), w);
        let loss = |eng: &mut RefEngine, inputs: &[&Tensor], params: &[Tensor]| -> f32 {
            let y = eng.forward(&node, inputs, params).unwrap();
            y.f().iter().zip(weight.f()).map(|(&a, &b)| a * b).sum()
        };

        let bwd = eng.backward(&node, &input_refs, &params, Some(&weight)).unwrap();

        // Check input grads.
        const H: f32 = 1e-2;
        for (ai, inp) in inputs.iter().enumerate() {
            if !inp.is_f32() {
                continue;
            }
            let analytic = bwd.input_grads[ai].as_ref().expect("f32 inputs need grads");
            // Probe a handful of coordinates.
            let n = inp.numel();
            for probe in 0..n.min(6) {
                let idx = (probe * 7919) % n;
                let mut plus = inputs.clone();
                plus[ai] = {
                    let mut t = inp.clone();
                    t.f_mut()[idx] += H;
                    t
                };
                let mut minus = inputs.clone();
                minus[ai] = {
                    let mut t = inp.clone();
                    t.f_mut()[idx] -= H;
                    t
                };
                let rp: Vec<&Tensor> = plus.iter().collect();
                let rm: Vec<&Tensor> = minus.iter().collect();
                let fd = (loss(&mut eng, &rp, &params) - loss(&mut eng, &rm, &params)) / (2.0 * H);
                let an = analytic.f()[idx];
                assert!(
                    (fd - an).abs() <= tol * (1.0 + fd.abs().max(an.abs())),
                    "input {ai} idx {idx}: fd={fd} analytic={an}"
                );
            }
        }
        // Check param grads.
        for (pi, p) in params.iter().enumerate() {
            let analytic = &bwd.param_grads[pi];
            let n = p.numel();
            for probe in 0..n.min(6) {
                let idx = (probe * 6007) % n;
                let mut pp = params.clone();
                pp[pi].f_mut()[idx] += H;
                let mut pm = params.clone();
                pm[pi].f_mut()[idx] -= H;
                let fd = (loss(&mut eng, &input_refs, &pp) - loss(&mut eng, &input_refs, &pm))
                    / (2.0 * H);
                let an = analytic.f()[idx];
                assert!(
                    (fd - an).abs() <= tol * (1.0 + fd.abs().max(an.abs())),
                    "param {pi} idx {idx}: fd={fd} analytic={an}"
                );
            }
        }
    }

    #[test]
    fn grad_linear() {
        fd_check(
            OpKind::Linear { in_features: 5, out_features: 4, bias: true },
            &[(&[3, 5], DType::F32)],
            2e-2,
        );
    }

    #[test]
    fn grad_conv2d() {
        fd_check(
            OpKind::Conv2d { in_ch: 2, out_ch: 3, kernel: 3, stride: 1, padding: 1 },
            &[(&[1, 2, 5, 5], DType::F32)],
            2e-2,
        );
    }

    #[test]
    fn grad_conv2d_strided_nopad() {
        fd_check(
            OpKind::Conv2d { in_ch: 1, out_ch: 2, kernel: 2, stride: 2, padding: 0 },
            &[(&[1, 1, 6, 6], DType::F32)],
            2e-2,
        );
    }

    #[test]
    fn grad_layernorm() {
        fd_check(OpKind::LayerNorm { dim: 6 }, &[(&[4, 6], DType::F32)], 3e-2);
    }

    #[test]
    fn grad_attention() {
        fd_check(
            OpKind::Attention { heads: 2, dim: 8, causal: false },
            &[(&[1, 4, 8], DType::F32)],
            4e-2,
        );
    }

    #[test]
    fn grad_attention_causal() {
        fd_check(
            OpKind::Attention { heads: 2, dim: 8, causal: true },
            &[(&[1, 4, 8], DType::F32)],
            4e-2,
        );
    }

    #[test]
    fn grad_ffn() {
        fd_check(
            OpKind::FeedForward { dim: 6, hidden: 10 },
            &[(&[3, 6], DType::F32)],
            3e-2,
        );
    }

    #[test]
    fn grad_elementwise() {
        fd_check(OpKind::Add, &[(&[2, 3], DType::F32), (&[2, 3], DType::F32)], 1e-2);
        fd_check(OpKind::Multiply, &[(&[2, 3], DType::F32), (&[2, 3], DType::F32)], 1e-2);
        fd_check(OpKind::Gelu, &[(&[2, 5], DType::F32)], 1e-2);
        fd_check(OpKind::Softmax, &[(&[3, 4], DType::F32)], 2e-2);
    }

    #[test]
    fn grad_maxpool() {
        fd_check(
            OpKind::MaxPool2d { kernel: 2, stride: 2 },
            &[(&[1, 2, 4, 4], DType::F32)],
            2e-2,
        );
    }

    #[test]
    fn grad_concat() {
        fd_check(
            OpKind::Concat { axis: 1 },
            &[(&[2, 2, 3], DType::F32), (&[2, 4, 3], DType::F32)],
            1e-2,
        );
    }

    #[test]
    fn grad_mse() {
        fd_check(OpKind::MseLoss, &[(&[2, 3], DType::F32), (&[2, 3], DType::F32)], 1e-2);
    }

    #[test]
    fn grad_cross_entropy() {
        // Loss seeds with the scalar weighting; use a direct FD on the loss.
        let mut g = Graph::new();
        let lab = g.placeholder("lab", Shape::of(&[4]), DType::I32);
        let log = g.placeholder("log", Shape::of(&[4, 3]), DType::F32);
        let id = g.op("ce", OpKind::CrossEntropy { weight: 1.0 }, &[lab, log]).unwrap();
        let node = g.node(id).clone();
        let mut rng = Rng::new(3);
        let mut eng = RefEngine::new();
        let labels = Tensor::from_ivec(&[4], vec![0, 2, 1, 1]);
        let logits = Tensor::randn(&[4, 3], 1.0, &mut rng);
        let bwd = eng.backward(&node, &[&labels, &logits], &[], None).unwrap();
        assert!(bwd.input_grads[0].is_none());
        let analytic = bwd.input_grads[1].as_ref().unwrap();
        const H: f32 = 1e-3;
        for idx in 0..12 {
            let mut p = logits.clone();
            p.f_mut()[idx] += H;
            let mut m = logits.clone();
            m.f_mut()[idx] -= H;
            let fp = eng.forward(&node, &[&labels, &p], &[]).unwrap().item();
            let fm = eng.forward(&node, &[&labels, &m], &[]).unwrap().item();
            let fd = (fp - fm) / (2.0 * H);
            assert!((fd - analytic.f()[idx]).abs() < 2e-3, "idx {idx}");
        }
    }

    #[test]
    fn grad_embedding_scatter() {
        let mut g = Graph::new();
        let tok = g.placeholder("tok", Shape::of(&[3]), DType::I32);
        let id = g.op("emb", OpKind::Embedding { vocab: 5, dim: 2 }, &[tok]).unwrap();
        let node = g.node(id).clone();
        let mut rng = Rng::new(5);
        let mut eng = RefEngine::new();
        let params = eng.init_params(&node, &mut rng).unwrap();
        let ids = Tensor::from_ivec(&[3], vec![1, 3, 1]);
        let dy = Tensor::from_vec(&[3, 2], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let bwd = eng.backward(&node, &[&ids], &params, Some(&dy)).unwrap();
        let dt = bwd.param_grads[0].f();
        // row 1 accumulates positions 0 and 2; row 3 gets position 1.
        assert_eq!(&dt[2..4], &[1.0 + 5.0, 2.0 + 6.0]);
        assert_eq!(&dt[6..8], &[3.0, 4.0]);
        assert_eq!(&dt[0..2], &[0.0, 0.0]);
    }

    #[test]
    fn causal_attention_masks_future() {
        // Changing a future token must not change earlier outputs.
        let mut g = Graph::new();
        let x = g.placeholder("x", Shape::of(&[1, 4, 8]), DType::F32);
        let id = g.op("attn", OpKind::Attention { heads: 2, dim: 8, causal: true }, &[x]).unwrap();
        let node = g.node(id).clone();
        let mut rng = Rng::new(11);
        let mut eng = RefEngine::new();
        let params = eng.init_params(&node, &mut rng).unwrap();
        let a = Tensor::randn(&[1, 4, 8], 1.0, &mut rng);
        let mut b = a.clone();
        // Perturb the last token only.
        for d in 0..8 {
            b.f_mut()[3 * 8 + d] += 1.0;
        }
        let ya = eng.forward(&node, &[&a], &params).unwrap();
        let yb = eng.forward(&node, &[&b], &params).unwrap();
        for t in 0..3 {
            for d in 0..8 {
                assert!(
                    (ya.f()[t * 8 + d] - yb.f()[t * 8 + d]).abs() < 1e-6,
                    "leak at token {t}"
                );
            }
        }
        // And the last token's output must differ.
        let diff: f32 =
            (0..8).map(|d| (ya.f()[3 * 8 + d] - yb.f()[3 * 8 + d]).abs()).sum();
        assert!(diff > 1e-3);
    }

    #[test]
    fn cross_entropy_matches_uniform_bound() {
        // Uniform logits ⇒ loss = ln(C).
        let mut g = Graph::new();
        let lab = g.placeholder("lab", Shape::of(&[2]), DType::I32);
        let log = g.placeholder("log", Shape::of(&[2, 7]), DType::F32);
        let id = g.op("ce", OpKind::CrossEntropy { weight: 1.0 }, &[lab, log]).unwrap();
        let node = g.node(id).clone();
        let mut eng = RefEngine::new();
        let labels = Tensor::from_ivec(&[2], vec![3, 6]);
        let logits = Tensor::zeros(&[2, 7]);
        let loss = eng.forward(&node, &[&labels, &logits], &[]).unwrap().item();
        assert!((loss - (7.0f32).ln()).abs() < 1e-5);
    }
}
