//! The pure-rust reference engine: a thin driver over the op-kernel registry.
//!
//! All numerics live in `exec::kernels::*` — one `OpKernel` per op family,
//! each with a hand-derived VJP verified against central finite differences
//! in its own test module. The engine's job is only to translate the
//! stateful `Engine` trait calls into stateless registry lookups, including
//! seeding the backward pass of loss nodes with `d(loss)/d(loss) = 1`.
//!
//! The engine is deterministic and dependency-free, which makes it the
//! execution-plane backend for the simulator, the quickstart example, and
//! the oracle opposite the XLA artifact engine.

use anyhow::Result;

use crate::dag::Node;
use crate::exec::kernels::kernel_for;
use crate::exec::{BackwardOut, Engine, Scratch};
use crate::tensor::Tensor;
use crate::util::Rng;

/// Pure-rust execution-plane backend. Owns the scratch pool its kernels
/// draw temporaries from, so buffers are recycled across all forward and
/// backward calls of a compnode's lifetime.
#[derive(Debug, Default)]
pub struct RefEngine {
    scratch: Scratch,
}

impl RefEngine {
    pub fn new() -> RefEngine {
        RefEngine { scratch: Scratch::new() }
    }

    /// Scratch-pool statistics (hits, misses) — observability for tests
    /// and the profiler.
    pub fn scratch_stats(&self) -> (u64, u64) {
        (self.scratch.hits(), self.scratch.misses())
    }
}

impl Engine for RefEngine {
    fn name(&self) -> &'static str {
        "ref"
    }

    fn init_params(&mut self, node: &Node, rng: &mut Rng) -> Result<Vec<Tensor>> {
        kernel_for(&node.kind).init_params(node, rng)
    }

    fn forward(&mut self, node: &Node, inputs: &[&Tensor], params: &[Tensor]) -> Result<Tensor> {
        kernel_for(&node.kind).forward(node, inputs, params, &mut self.scratch)
    }

    fn backward(
        &mut self,
        node: &Node,
        inputs: &[&Tensor],
        params: &[Tensor],
        out_grad: Option<&Tensor>,
    ) -> Result<BackwardOut> {
        // Loss nodes may be seeded; everything else requires an upstream grad.
        let seeded = Tensor::scalar(1.0);
        let dy = out_grad.unwrap_or(&seeded);
        kernel_for(&node.kind).vjp(node, inputs, params, dy, &mut self.scratch)
    }

    /// Every call above is a stateless registry dispatch, so the wavefront
    /// executor may fan waves out across threads without changing a bit.
    fn registry_backed(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dag::{DType, Graph, OpKind, Shape};

    /// End-to-end smoke test through the Engine trait: a tiny MLP step.
    #[test]
    fn mlp_forward_backward_through_registry() {
        let mut g = Graph::new();
        let x = g.placeholder("x", Shape::of(&[4, 6]), DType::F32);
        let h = g
            .op("fc1", OpKind::Linear { in_features: 6, out_features: 5, bias: true }, &[x])
            .unwrap();
        let a = g.op("act", OpKind::Relu, &[h]).unwrap();
        let y = g
            .op("fc2", OpKind::Linear { in_features: 5, out_features: 3, bias: false }, &[a])
            .unwrap();
        let t = g.placeholder("t", Shape::of(&[4, 3]), DType::F32);
        let loss = g.op("loss", OpKind::MseLoss, &[y, t]).unwrap();

        let mut eng = RefEngine::new();
        let mut rng = Rng::new(9);
        let xs = Tensor::randn(&[4, 6], 1.0, &mut rng);
        let ts = Tensor::zeros(&[4, 3]);

        let p1 = eng.init_params(&g.node(h).clone(), &mut rng).unwrap();
        let p2 = eng.init_params(&g.node(y).clone(), &mut rng).unwrap();
        assert_eq!(p1.len(), 2);
        assert_eq!(p2.len(), 1);

        let hv = eng.forward(&g.node(h).clone(), &[&xs], &p1).unwrap();
        let av = eng.forward(&g.node(a).clone(), &[&hv], &[]).unwrap();
        let yv = eng.forward(&g.node(y).clone(), &[&av], &p2).unwrap();
        let lv = eng.forward(&g.node(loss).clone(), &[&yv, &ts], &[]).unwrap();
        assert!(lv.item().is_finite());

        // Backward: loss seeds itself, the rest chain upstream grads.
        let bl = eng.backward(&g.node(loss).clone(), &[&yv, &ts], &[], None).unwrap();
        let dy = bl.input_grads[0].as_ref().unwrap();
        let b2 = eng.backward(&g.node(y).clone(), &[&av], &p2, Some(dy)).unwrap();
        assert_eq!(b2.param_grads.len(), 1);
        let da = b2.input_grads[0].as_ref().unwrap();
        let br = eng.backward(&g.node(a).clone(), &[&hv], &[], Some(da)).unwrap();
        let dh = br.input_grads[0].as_ref().unwrap();
        let b1 = eng.backward(&g.node(h).clone(), &[&xs], &p1, Some(dh)).unwrap();
        assert_eq!(b1.param_grads.len(), 2);
        assert_eq!(b1.param_grads[0].shape(), &[6, 5]);
    }

    /// The engine's pooled scratch buffers must be invisible in the
    /// numerics: repeating a forward through the same engine reuses
    /// buffers (hits > 0) yet reproduces the output bitwise.
    #[test]
    fn scratch_pool_reuse_is_bitwise_invisible() {
        let mut g = Graph::new();
        let x = g.placeholder("x", Shape::of(&[2, 4, 8]), DType::F32);
        let f = g.op("ffn", OpKind::FeedForward { dim: 8, hidden: 16 }, &[x]).unwrap();
        let node = g.node(f).clone();
        let mut eng = RefEngine::new();
        let mut rng = Rng::new(21);
        let params = eng.init_params(&node, &mut rng).unwrap();
        let xs = Tensor::randn(&[2, 4, 8], 1.0, &mut rng);
        let y1 = eng.forward(&node, &[&xs], &params).unwrap();
        let b1 = eng.backward(&node, &[&xs], &params, Some(&y1)).unwrap();
        let (_, misses_after_first) = eng.scratch_stats();
        assert!(misses_after_first > 0);
        let y2 = eng.forward(&node, &[&xs], &params).unwrap();
        let b2 = eng.backward(&node, &[&xs], &params, Some(&y2)).unwrap();
        let (hits, _) = eng.scratch_stats();
        assert!(hits > 0, "second pass must be served from the pool");
        let bits = |t: &Tensor| t.f().iter().map(|v| v.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&y1), bits(&y2));
        assert_eq!(
            bits(b1.input_grads[0].as_ref().unwrap()),
            bits(b2.input_grads[0].as_ref().unwrap())
        );
        for (p1, p2) in b1.param_grads.iter().zip(&b2.param_grads) {
            assert_eq!(bits(p1), bits(p2));
        }
    }

    #[test]
    fn stagecall_error_is_stable() {
        let mut g = Graph::new();
        let x = g.placeholder("x", Shape::of(&[2, 4]), DType::F32);
        let sc = g
            .op(
                "stage0",
                OpKind::StageCall {
                    stage: "blocks_0_1".into(),
                    param_count: 0,
                    flops: 0.0,
                    param_bytes: 0,
                },
                &[x],
            )
            .unwrap();
        g.set_shape(sc, Shape::of(&[2, 4]), DType::F32);
        let node = g.node(sc).clone();
        let mut eng = RefEngine::new();
        let t = Tensor::zeros(&[2, 4]);
        let fwd_err = eng.forward(&node, &[&t], &[]).unwrap_err().to_string();
        let bwd_err = eng.backward(&node, &[&t], &[], None).unwrap_err().to_string();
        let want = "RefEngine cannot execute StageCall 'blocks_0_1' (use XlaEngine)";
        assert_eq!(fwd_err, want);
        assert_eq!(bwd_err, want);
    }
}
