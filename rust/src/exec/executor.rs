//! Wave execution: run the mutually independent nodes of one [`ExecPlan`]
//! wave on worker threads.
//!
//! Determinism contract: every node's forward/VJP is the **same** stateless
//! kernel call ([`kernel_for`]) whether it runs on the caller's thread or a
//! worker — kernels take scratch buffers zero-filled, so per-thread scratch
//! pools are numerically invisible. Results are joined back in wave order,
//! which makes any wave width bitwise identical to the serial sweep. (What
//! needs ordering care is gradient *accumulation*, and that lives in the
//! caller: contributions are folded by backward-plan position, never by
//! completion order.)
//!
//! Threading mirrors the GEMM fan-out from the tensor layer: opt-in via
//! [`set_wave_threads`] or `FUSIONAI_WAVE_THREADS` (default 1 = serial), and
//! a wave only fans out when its total FLOPs clear
//! [`WAVE_PAR_MIN_FLOPS`] — spawn/join overhead dominates tiny waves.
//!
//! [`kernel_for`]: crate::exec::kernels::kernel_for

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};

use anyhow::anyhow;

use crate::dag::{Graph, NodeId};
use crate::exec::kernels::kernel_for;
use crate::exec::{BackwardOut, Scratch};
use crate::tensor::Tensor;

/// 0 = unresolved; resolved lazily from `FUSIONAI_WAVE_THREADS` (default 1).
static WAVE_THREADS: AtomicUsize = AtomicUsize::new(0);

/// Below this many forward FLOPs a wave always runs on the caller's thread.
pub const WAVE_PAR_MIN_FLOPS: f64 = (1usize << 21) as f64;

/// Set the process-wide wave fan-out (1 = serial, the default).
pub fn set_wave_threads(threads: usize) {
    WAVE_THREADS.store(threads.max(1), Ordering::Relaxed);
}

/// Current wave fan-out; first call resolves `FUSIONAI_WAVE_THREADS`.
pub fn wave_threads() -> usize {
    match WAVE_THREADS.load(Ordering::Relaxed) {
        0 => {
            let n = std::env::var("FUSIONAI_WAVE_THREADS")
                .ok()
                .and_then(|v| v.parse::<usize>().ok())
                .filter(|&n| n >= 1)
                .unwrap_or(1);
            WAVE_THREADS.store(n, Ordering::Relaxed);
            n
        }
        n => n,
    }
}

/// One backward task handed to a wave: the forward node plus its folded
/// upstream gradient (`None` seeds a loss node with dL/dL = 1).
#[derive(Debug)]
pub struct BwdJob {
    pub node: NodeId,
    pub upstream: Option<Tensor>,
}

/// Runs plan waves, owning one [`Scratch`] pool per worker slot so freed
/// activation buffers can be recycled into kernel temporaries.
#[derive(Debug, Default)]
pub struct WaveRunner {
    pools: Vec<Scratch>,
}

impl WaveRunner {
    pub fn new() -> WaveRunner {
        WaveRunner { pools: vec![Scratch::new()] }
    }

    /// Park a dead activation's buffer for reuse by later kernel calls.
    pub fn recycle(&mut self, t: Tensor) {
        if let Tensor::F32 { data, .. } = t {
            self.pools[0].put(data);
        }
    }

    /// Scratch-pool hit/miss counters summed over all worker slots.
    pub fn scratch_stats(&self) -> (u64, u64) {
        self.pools.iter().fold((0, 0), |(h, m), p| (h + p.hits(), m + p.misses()))
    }

    fn ensure_pools(&mut self, n: usize) {
        while self.pools.len() < n {
            self.pools.push(Scratch::new());
        }
    }

    /// Forward one wave of mutually independent `jobs` on up to `threads`
    /// workers. Returns `(node, output)` pairs **in wave order**.
    pub fn forward_wave(
        &mut self,
        g: &Graph,
        jobs: &[NodeId],
        acts: &[Option<Tensor>],
        params: &HashMap<NodeId, Vec<Tensor>>,
        threads: usize,
    ) -> crate::Result<Vec<(NodeId, Tensor)>> {
        if jobs.is_empty() {
            return Ok(vec![]);
        }
        let t = threads.min(jobs.len()).max(1);
        self.ensure_pools(t);
        let chunk = jobs.len().div_ceil(t);
        let mut results: Vec<crate::Result<Vec<(NodeId, Tensor)>>> = Vec::with_capacity(t);
        std::thread::scope(|s| {
            let handles: Vec<_> = jobs
                .chunks(chunk)
                .zip(self.pools.iter_mut())
                .map(|(ids, pool)| {
                    s.spawn(move || {
                        let mut out = Vec::with_capacity(ids.len());
                        for &id in ids {
                            out.push((id, run_forward(g, id, acts, params, pool)?));
                        }
                        Ok(out)
                    })
                })
                .collect();
            for h in handles {
                results.push(h.join().unwrap_or_else(|e| std::panic::resume_unwind(e)));
            }
        });
        let mut outs = Vec::with_capacity(jobs.len());
        for r in results {
            outs.extend(r?);
        }
        Ok(outs)
    }

    /// Backward one wave of independent VJP `jobs`. Returns
    /// `(node, BackwardOut)` pairs **in wave order**; the caller applies
    /// them sequentially so accumulation order never depends on scheduling.
    pub fn backward_wave(
        &mut self,
        g: &Graph,
        jobs: &[BwdJob],
        acts: &[Option<Tensor>],
        params: &HashMap<NodeId, Vec<Tensor>>,
        threads: usize,
    ) -> crate::Result<Vec<(NodeId, BackwardOut)>> {
        if jobs.is_empty() {
            return Ok(vec![]);
        }
        let t = threads.min(jobs.len()).max(1);
        self.ensure_pools(t);
        let chunk = jobs.len().div_ceil(t);
        let mut results: Vec<crate::Result<Vec<(NodeId, BackwardOut)>>> = Vec::with_capacity(t);
        std::thread::scope(|s| {
            let handles: Vec<_> = jobs
                .chunks(chunk)
                .zip(self.pools.iter_mut())
                .map(|(batch, pool)| {
                    s.spawn(move || {
                        let mut out = Vec::with_capacity(batch.len());
                        for job in batch {
                            out.push((job.node, run_backward(g, job, acts, params, pool)?));
                        }
                        Ok(out)
                    })
                })
                .collect();
            for h in handles {
                results.push(h.join().unwrap_or_else(|e| std::panic::resume_unwind(e)));
            }
        });
        let mut outs = Vec::with_capacity(jobs.len());
        for r in results {
            outs.extend(r?);
        }
        Ok(outs)
    }
}

fn gather<'a>(
    g: &Graph,
    id: NodeId,
    acts: &'a [Option<Tensor>],
) -> crate::Result<Vec<&'a Tensor>> {
    let node = g.node(id);
    node.args
        .iter()
        .map(|&a| {
            acts[a]
                .as_ref()
                .ok_or_else(|| anyhow!("missing input {} for '{}'", a, node.name))
        })
        .collect()
}

fn run_forward(
    g: &Graph,
    id: NodeId,
    acts: &[Option<Tensor>],
    params: &HashMap<NodeId, Vec<Tensor>>,
    scratch: &mut Scratch,
) -> crate::Result<Tensor> {
    let node = g.node(id);
    let inputs = gather(g, id, acts)?;
    let p = params.get(&id).map(Vec::as_slice).unwrap_or(&[]);
    kernel_for(&node.kind).forward(node, &inputs, p, scratch)
}

fn run_backward(
    g: &Graph,
    job: &BwdJob,
    acts: &[Option<Tensor>],
    params: &HashMap<NodeId, Vec<Tensor>>,
    scratch: &mut Scratch,
) -> crate::Result<BackwardOut> {
    let node = g.node(job.node);
    let inputs = gather(g, job.node, acts)?;
    let p = params.get(&job.node).map(Vec::as_slice).unwrap_or(&[]);
    let seed;
    let dy = match &job.upstream {
        Some(t) => t,
        None => {
            seed = Tensor::scalar(1.0);
            &seed
        }
    };
    kernel_for(&node.kind).vjp(node, &inputs, p, dy, scratch)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dag::{DType, OpKind, Shape};
    use crate::util::Rng;

    /// A one-wave graph: `k` independent Linears over the same fed input.
    fn fanout_graph(k: usize) -> (Graph, Vec<NodeId>) {
        let mut g = Graph::new();
        let x = g.placeholder("x", Shape::of(&[4, 16]), DType::F32);
        let ids = (0..k)
            .map(|i| {
                g.op(
                    &format!("fc{i}"),
                    OpKind::Linear { in_features: 16, out_features: 8, bias: true },
                    &[x],
                )
                .unwrap()
            })
            .collect();
        (g, ids)
    }

    fn setup(g: &Graph, ids: &[NodeId]) -> (Vec<Option<Tensor>>, HashMap<NodeId, Vec<Tensor>>) {
        let mut rng = Rng::new(7);
        let mut acts = vec![None; g.len()];
        acts[0] = Some(Tensor::randn(&[4, 16], 1.0, &mut rng));
        let mut params = HashMap::new();
        for &id in ids {
            let node = g.node(id);
            params.insert(id, kernel_for(&node.kind).init_params(node, &mut rng).unwrap());
        }
        (acts, params)
    }

    #[test]
    fn forward_wave_is_bitwise_identical_across_widths() {
        let (g, ids) = fanout_graph(5);
        let (acts, params) = setup(&g, &ids);
        let mut serial = WaveRunner::new();
        let base = serial.forward_wave(&g, &ids, &acts, &params, 1).unwrap();
        for threads in [2, 3, 8] {
            let mut runner = WaveRunner::new();
            let outs = runner.forward_wave(&g, &ids, &acts, &params, threads).unwrap();
            assert_eq!(outs.len(), base.len());
            for ((id_a, a), (id_b, b)) in base.iter().zip(&outs) {
                assert_eq!(id_a, id_b, "wave order must be preserved");
                assert_eq!(a.f(), b.f(), "t={threads} node {id_a} diverged");
            }
        }
    }

    #[test]
    fn backward_wave_matches_serial_and_seeds_losses() {
        let (g, ids) = fanout_graph(3);
        let (acts, params) = setup(&g, &ids);
        let mk_jobs = || -> Vec<BwdJob> {
            ids.iter()
                .map(|&id| {
                    let dy = Tensor::F32 { shape: vec![4, 8], data: vec![1.0; 32] };
                    BwdJob { node: id, upstream: Some(dy) }
                })
                .collect()
        };
        let mut serial = WaveRunner::new();
        let base = serial.backward_wave(&g, &mk_jobs(), &acts, &params, 1).unwrap();
        let mut par = WaveRunner::new();
        let wide = par.backward_wave(&g, &mk_jobs(), &acts, &params, 8).unwrap();
        for ((id_a, a), (_, b)) in base.iter().zip(&wide) {
            assert_eq!(a.param_grads[0].f(), b.param_grads[0].f(), "node {id_a}");
            assert_eq!(
                a.input_grads[0].as_ref().unwrap().f(),
                b.input_grads[0].as_ref().unwrap().f()
            );
        }
    }

    #[test]
    fn recycled_buffers_feed_scratch_hits() {
        let mut runner = WaveRunner::new();
        runner.recycle(Tensor::zeros(&[64, 64]));
        let (hits, _) = runner.scratch_stats();
        assert_eq!(hits, 0);
        // The parked buffer satisfies the next same-size take.
        let buf = runner.pools[0].take(64 * 64);
        assert_eq!(buf.len(), 64 * 64);
        let (hits, _) = runner.scratch_stats();
        assert_eq!(hits, 1);
    }

    #[test]
    fn missing_input_is_an_error_not_a_panic() {
        let (g, ids) = fanout_graph(2);
        let (mut acts, params) = setup(&g, &ids);
        acts[0] = None;
        let mut runner = WaveRunner::new();
        let err = runner.forward_wave(&g, &ids, &acts, &params, 2).unwrap_err();
        assert!(err.to_string().contains("missing input"), "{err}");
    }

    #[test]
    fn wave_threads_env_roundtrip() {
        set_wave_threads(3);
        assert_eq!(wave_threads(), 3);
        set_wave_threads(0); // clamps to 1
        assert_eq!(wave_threads(), 1);
    }
}
