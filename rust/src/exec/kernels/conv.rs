//! Spatial kernels: 2-D convolution and max pooling over NCHW.
//!
//! Conv2d lowers to im2col + GEMM: per image, patches are gathered into a
//! `[in_ch·k², oh·ow]` column buffer (from the scratch pool) and the
//! convolution becomes `W[out_ch, in_ch·k²] · cols`, which hits the
//! blocked matmul instead of a 7-deep scalar loop nest.

use anyhow::{bail, Result};

use super::OpKernel;
use crate::dag::{Node, OpKind};
use crate::exec::{BackwardOut, Scratch};
use crate::tensor::{matmul_at_into, matmul_bt_into, matmul_into, Tensor};
use crate::util::Rng;

pub struct Conv2dKernel;

#[allow(clippy::type_complexity)]
fn unpack_conv(node: &Node) -> Result<(usize, usize, usize, usize, usize)> {
    match node.kind {
        OpKind::Conv2d { in_ch, out_ch, kernel, stride, padding } => {
            Ok((in_ch, out_ch, kernel, stride, padding))
        }
        _ => bail!("Conv2dKernel dispatched on {}", node.kind.name()),
    }
}

impl OpKernel for Conv2dKernel {
    fn name(&self) -> &'static str {
        "conv2d"
    }

    fn init_params(&self, node: &Node, rng: &mut Rng) -> Result<Vec<Tensor>> {
        let (in_ch, out_ch, k, _, _) = unpack_conv(node)?;
        let std = (2.0 / (in_ch as f32 * (k * k) as f32)).sqrt();
        Ok(vec![
            Tensor::randn(&[out_ch, in_ch, k, k], std, rng),
            Tensor::zeros(&[out_ch]),
        ])
    }

    fn forward(
        &self,
        node: &Node,
        inputs: &[&Tensor],
        params: &[Tensor],
        scratch: &mut Scratch,
    ) -> Result<Tensor> {
        let (in_ch, out_ch, k, stride, pad) = unpack_conv(node)?;
        conv2d_fwd(inputs[0], &params[0], &params[1], in_ch, out_ch, k, stride, pad, scratch)
    }

    fn vjp(
        &self,
        node: &Node,
        inputs: &[&Tensor],
        params: &[Tensor],
        dy: &Tensor,
        scratch: &mut Scratch,
    ) -> Result<BackwardOut> {
        let (in_ch, out_ch, k, stride, pad) = unpack_conv(node)?;
        conv2d_bwd(inputs[0], &params[0], dy, in_ch, out_ch, k, stride, pad, scratch)
    }
}

pub struct MaxPool2dKernel;

impl OpKernel for MaxPool2dKernel {
    fn name(&self) -> &'static str {
        "maxpool2d"
    }

    fn forward(
        &self,
        node: &Node,
        inputs: &[&Tensor],
        _params: &[Tensor],
        _scratch: &mut Scratch,
    ) -> Result<Tensor> {
        let OpKind::MaxPool2d { kernel, stride } = node.kind else {
            bail!("MaxPool2dKernel dispatched on {}", node.kind.name());
        };
        Ok(maxpool_fwd(inputs[0], kernel, stride).0)
    }

    fn vjp(
        &self,
        node: &Node,
        inputs: &[&Tensor],
        _params: &[Tensor],
        dy: &Tensor,
        _scratch: &mut Scratch,
    ) -> Result<BackwardOut> {
        let OpKind::MaxPool2d { kernel, stride } = node.kind else {
            bail!("MaxPool2dKernel dispatched on {}", node.kind.name());
        };
        let (_, argmax) = maxpool_fwd(inputs[0], kernel, stride);
        let mut dx = Tensor::zeros(inputs[0].shape());
        let dxf = dx.f_mut();
        for (o, &src) in argmax.iter().enumerate() {
            dxf[src] += dy.f()[o];
        }
        Ok(BackwardOut { input_grads: vec![Some(dx)], param_grads: vec![] })
    }
}

/// Gather one image's patches: `cols[(ic·k+ky)·k+kx, oy·ow+ox]` =
/// `x[ni,ic,iy,ix]` or `0.0` for padding. Every entry is written — the
/// buffer is recycled across images, so stale values must never survive.
#[allow(clippy::too_many_arguments)]
fn im2col(
    xf: &[f32],
    cols: &mut [f32],
    ni: usize,
    in_ch: usize,
    h: usize,
    wd: usize,
    (oh, ow): (usize, usize),
    k: usize,
    stride: usize,
    pad: usize,
) {
    let ohow = oh * ow;
    for ic in 0..in_ch {
        for ky in 0..k {
            for kx in 0..k {
                let row = ((ic * k + ky) * k + kx) * ohow;
                for oy in 0..oh {
                    let iy = oy * stride + ky;
                    let in_y = iy >= pad && iy - pad < h;
                    for ox in 0..ow {
                        let ix = ox * stride + kx;
                        cols[row + oy * ow + ox] = if in_y && ix >= pad && ix - pad < wd {
                            xf[((ni * in_ch + ic) * h + (iy - pad)) * wd + (ix - pad)]
                        } else {
                            0.0
                        };
                    }
                }
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn conv2d_fwd(
    x: &Tensor,
    w: &Tensor,
    b: &Tensor,
    in_ch: usize,
    out_ch: usize,
    k: usize,
    stride: usize,
    pad: usize,
    scratch: &mut Scratch,
) -> Result<Tensor> {
    let s = x.shape();
    let (n, h, wd) = (s[0], s[2], s[3]);
    let oh = (h + 2 * pad - k) / stride + 1;
    let ow = (wd + 2 * pad - k) / stride + 1;
    let (xf, wf, bf) = (x.f(), w.f(), b.f());
    let ick2 = in_ch * k * k;
    let ohow = oh * ow;
    let mut out = vec![0.0f32; n * out_ch * ohow];
    let mut cols = scratch.take(ick2 * ohow);
    for ni in 0..n {
        im2col(xf, &mut cols, ni, in_ch, h, wd, (oh, ow), k, stride, pad);
        // W's flat layout [out_ch, in_ch, k, k] is exactly [out_ch, ick2].
        let yimg = &mut out[ni * out_ch * ohow..][..out_ch * ohow];
        matmul_into(wf, &cols, yimg, out_ch, ick2, ohow);
        for (oc, row) in yimg.chunks_mut(ohow).enumerate() {
            for v in row.iter_mut() {
                *v += bf[oc];
            }
        }
    }
    scratch.put(cols);
    Ok(Tensor::from_vec(&[n, out_ch, oh, ow], out))
}

#[allow(clippy::too_many_arguments)]
fn conv2d_bwd(
    x: &Tensor,
    w: &Tensor,
    dy: &Tensor,
    in_ch: usize,
    out_ch: usize,
    k: usize,
    stride: usize,
    pad: usize,
    scratch: &mut Scratch,
) -> Result<BackwardOut> {
    let s = x.shape();
    let (n, h, wd) = (s[0], s[2], s[3]);
    let os = dy.shape();
    let (oh, ow) = (os[2], os[3]);
    let (xf, wf, dyf) = (x.f(), w.f(), dy.f());
    let ick2 = in_ch * k * k;
    let ohow = oh * ow;
    let mut dx = vec![0.0f32; xf.len()];
    let mut dw = vec![0.0f32; wf.len()];
    let mut db = vec![0.0f32; out_ch];
    let mut cols = scratch.take(ick2 * ohow);
    let mut dcols = scratch.take(ick2 * ohow);
    let mut dwp = scratch.take(out_ch * ick2);
    for ni in 0..n {
        let dyimg = &dyf[ni * out_ch * ohow..][..out_ch * ohow];
        for (oc, row) in dyimg.chunks(ohow).enumerate() {
            for &g in row {
                db[oc] += g;
            }
        }
        im2col(xf, &mut cols, ni, in_ch, h, wd, (oh, ow), k, stride, pad);
        // dW += dy_img[out_ch, ohow] · colsᵀ (accumulated across images).
        matmul_bt_into(dyimg, &cols, &mut dwp, out_ch, ohow, ick2);
        for (d, &p) in dw.iter_mut().zip(&dwp) {
            *d += p;
        }
        // dcols[ick2, ohow] = Wᵀ · dy_img, then col2im scatter-add.
        matmul_at_into(wf, dyimg, &mut dcols, ick2, out_ch, ohow);
        for ic in 0..in_ch {
            for ky in 0..k {
                for kx in 0..k {
                    let row = ((ic * k + ky) * k + kx) * ohow;
                    for oy in 0..oh {
                        let iy = oy * stride + ky;
                        if iy < pad || iy - pad >= h {
                            continue;
                        }
                        for ox in 0..ow {
                            let ix = ox * stride + kx;
                            if ix < pad || ix - pad >= wd {
                                continue;
                            }
                            dx[((ni * in_ch + ic) * h + (iy - pad)) * wd + (ix - pad)] +=
                                dcols[row + oy * ow + ox];
                        }
                    }
                }
            }
        }
    }
    scratch.put(dwp);
    scratch.put(dcols);
    scratch.put(cols);
    Ok(BackwardOut {
        input_grads: vec![Some(Tensor::from_vec(x.shape(), dx))],
        param_grads: vec![
            Tensor::from_vec(w.shape(), dw),
            Tensor::from_vec(&[out_ch], db),
        ],
    })
}

/// Returns (output, flat argmax indices into the input) for pooling.
fn maxpool_fwd(x: &Tensor, k: usize, stride: usize) -> (Tensor, Vec<usize>) {
    let s = x.shape();
    let (n, c, h, w) = (s[0], s[1], s[2], s[3]);
    let oh = (h - k) / stride + 1;
    let ow = (w - k) / stride + 1;
    let xf = x.f();
    let mut out = vec![0.0f32; n * c * oh * ow];
    let mut arg = vec![0usize; out.len()];
    for ni in 0..n {
        for ci in 0..c {
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut best = f32::NEG_INFINITY;
                    let mut bi = 0;
                    for ky in 0..k {
                        for kx in 0..k {
                            let idx = ((ni * c + ci) * h + oy * stride + ky) * w
                                + ox * stride
                                + kx;
                            if xf[idx] > best {
                                best = xf[idx];
                                bi = idx;
                            }
                        }
                    }
                    let o = ((ni * c + ci) * oh + oy) * ow + ox;
                    out[o] = best;
                    arg[o] = bi;
                }
            }
        }
    }
    (Tensor::from_vec(&[n, c, oh, ow], out), arg)
}

#[cfg(test)]
mod tests {
    use crate::dag::{DType, OpKind};
    use crate::exec::kernels::testutil::fd_check;

    #[test]
    fn grad_conv2d() {
        fd_check(
            OpKind::Conv2d { in_ch: 2, out_ch: 3, kernel: 3, stride: 1, padding: 1 },
            &[(&[1, 2, 5, 5], DType::F32)],
            2e-2,
        );
    }

    #[test]
    fn grad_conv2d_strided_nopad() {
        fd_check(
            OpKind::Conv2d { in_ch: 1, out_ch: 2, kernel: 2, stride: 2, padding: 0 },
            &[(&[1, 1, 6, 6], DType::F32)],
            2e-2,
        );
    }

    #[test]
    fn grad_maxpool() {
        fd_check(
            OpKind::MaxPool2d { kernel: 2, stride: 2 },
            &[(&[1, 2, 4, 4], DType::F32)],
            2e-2,
        );
    }
}
