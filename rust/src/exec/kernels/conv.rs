//! Spatial kernels: 2-D convolution and max pooling over NCHW.

use anyhow::{bail, Result};

use super::OpKernel;
use crate::dag::{Node, OpKind};
use crate::exec::BackwardOut;
use crate::tensor::Tensor;
use crate::util::Rng;

pub struct Conv2dKernel;

#[allow(clippy::type_complexity)]
fn unpack_conv(node: &Node) -> Result<(usize, usize, usize, usize, usize)> {
    match node.kind {
        OpKind::Conv2d { in_ch, out_ch, kernel, stride, padding } => {
            Ok((in_ch, out_ch, kernel, stride, padding))
        }
        _ => bail!("Conv2dKernel dispatched on {}", node.kind.name()),
    }
}

impl OpKernel for Conv2dKernel {
    fn name(&self) -> &'static str {
        "conv2d"
    }

    fn init_params(&self, node: &Node, rng: &mut Rng) -> Result<Vec<Tensor>> {
        let (in_ch, out_ch, k, _, _) = unpack_conv(node)?;
        let std = (2.0 / (in_ch as f32 * (k * k) as f32)).sqrt();
        Ok(vec![
            Tensor::randn(&[out_ch, in_ch, k, k], std, rng),
            Tensor::zeros(&[out_ch]),
        ])
    }

    fn forward(&self, node: &Node, inputs: &[&Tensor], params: &[Tensor]) -> Result<Tensor> {
        let (in_ch, out_ch, k, stride, pad) = unpack_conv(node)?;
        conv2d_fwd(inputs[0], &params[0], &params[1], in_ch, out_ch, k, stride, pad)
    }

    fn vjp(
        &self,
        node: &Node,
        inputs: &[&Tensor],
        params: &[Tensor],
        dy: &Tensor,
    ) -> Result<BackwardOut> {
        let (in_ch, out_ch, k, stride, pad) = unpack_conv(node)?;
        conv2d_bwd(inputs[0], &params[0], dy, in_ch, out_ch, k, stride, pad)
    }
}

pub struct MaxPool2dKernel;

impl OpKernel for MaxPool2dKernel {
    fn name(&self) -> &'static str {
        "maxpool2d"
    }

    fn forward(&self, node: &Node, inputs: &[&Tensor], _params: &[Tensor]) -> Result<Tensor> {
        let OpKind::MaxPool2d { kernel, stride } = node.kind else {
            bail!("MaxPool2dKernel dispatched on {}", node.kind.name());
        };
        Ok(maxpool_fwd(inputs[0], kernel, stride).0)
    }

    fn vjp(
        &self,
        node: &Node,
        inputs: &[&Tensor],
        _params: &[Tensor],
        dy: &Tensor,
    ) -> Result<BackwardOut> {
        let OpKind::MaxPool2d { kernel, stride } = node.kind else {
            bail!("MaxPool2dKernel dispatched on {}", node.kind.name());
        };
        let (_, argmax) = maxpool_fwd(inputs[0], kernel, stride);
        let mut dx = Tensor::zeros(inputs[0].shape());
        let dxf = dx.f_mut();
        for (o, &src) in argmax.iter().enumerate() {
            dxf[src] += dy.f()[o];
        }
        Ok(BackwardOut { input_grads: vec![Some(dx)], param_grads: vec![] })
    }
}

#[allow(clippy::too_many_arguments)]
fn conv2d_fwd(
    x: &Tensor,
    w: &Tensor,
    b: &Tensor,
    in_ch: usize,
    out_ch: usize,
    k: usize,
    stride: usize,
    pad: usize,
) -> Result<Tensor> {
    let s = x.shape();
    let (n, h, wd) = (s[0], s[2], s[3]);
    let oh = (h + 2 * pad - k) / stride + 1;
    let ow = (wd + 2 * pad - k) / stride + 1;
    let xf = x.f();
    let wf = w.f();
    let bf = b.f();
    let mut out = vec![0.0f32; n * out_ch * oh * ow];
    for ni in 0..n {
        for oc in 0..out_ch {
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut acc = bf[oc];
                    for ic in 0..in_ch {
                        for ky in 0..k {
                            for kx in 0..k {
                                let iy = oy * stride + ky;
                                let ix = ox * stride + kx;
                                if iy < pad || ix < pad {
                                    continue;
                                }
                                let (iy, ix) = (iy - pad, ix - pad);
                                if iy >= h || ix >= wd {
                                    continue;
                                }
                                acc += xf[((ni * in_ch + ic) * h + iy) * wd + ix]
                                    * wf[((oc * in_ch + ic) * k + ky) * k + kx];
                            }
                        }
                    }
                    out[((ni * out_ch + oc) * oh + oy) * ow + ox] = acc;
                }
            }
        }
    }
    Ok(Tensor::from_vec(&[n, out_ch, oh, ow], out))
}

#[allow(clippy::too_many_arguments)]
fn conv2d_bwd(
    x: &Tensor,
    w: &Tensor,
    dy: &Tensor,
    in_ch: usize,
    out_ch: usize,
    k: usize,
    stride: usize,
    pad: usize,
) -> Result<BackwardOut> {
    let s = x.shape();
    let (n, h, wd) = (s[0], s[2], s[3]);
    let os = dy.shape();
    let (oh, ow) = (os[2], os[3]);
    let xf = x.f();
    let wf = w.f();
    let dyf = dy.f();
    let mut dx = vec![0.0f32; xf.len()];
    let mut dw = vec![0.0f32; wf.len()];
    let mut db = vec![0.0f32; out_ch];
    for ni in 0..n {
        for oc in 0..out_ch {
            for oy in 0..oh {
                for ox in 0..ow {
                    let g = dyf[((ni * out_ch + oc) * oh + oy) * ow + ox];
                    db[oc] += g;
                    for ic in 0..in_ch {
                        for ky in 0..k {
                            for kx in 0..k {
                                let iy = oy * stride + ky;
                                let ix = ox * stride + kx;
                                if iy < pad || ix < pad {
                                    continue;
                                }
                                let (iy, ix) = (iy - pad, ix - pad);
                                if iy >= h || ix >= wd {
                                    continue;
                                }
                                let xi = ((ni * in_ch + ic) * h + iy) * wd + ix;
                                let wi = ((oc * in_ch + ic) * k + ky) * k + kx;
                                dx[xi] += g * wf[wi];
                                dw[wi] += g * xf[xi];
                            }
                        }
                    }
                }
            }
        }
    }
    Ok(BackwardOut {
        input_grads: vec![Some(Tensor::from_vec(x.shape(), dx))],
        param_grads: vec![
            Tensor::from_vec(w.shape(), dw),
            Tensor::from_vec(&[out_ch], db),
        ],
    })
}

/// Returns (output, flat argmax indices into the input) for pooling.
fn maxpool_fwd(x: &Tensor, k: usize, stride: usize) -> (Tensor, Vec<usize>) {
    let s = x.shape();
    let (n, c, h, w) = (s[0], s[1], s[2], s[3]);
    let oh = (h - k) / stride + 1;
    let ow = (w - k) / stride + 1;
    let xf = x.f();
    let mut out = vec![0.0f32; n * c * oh * ow];
    let mut arg = vec![0usize; out.len()];
    for ni in 0..n {
        for ci in 0..c {
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut best = f32::NEG_INFINITY;
                    let mut bi = 0;
                    for ky in 0..k {
                        for kx in 0..k {
                            let idx = ((ni * c + ci) * h + oy * stride + ky) * w
                                + ox * stride
                                + kx;
                            if xf[idx] > best {
                                best = xf[idx];
                                bi = idx;
                            }
                        }
                    }
                    let o = ((ni * c + ci) * oh + oy) * ow + ox;
                    out[o] = best;
                    arg[o] = bi;
                }
            }
        }
    }
    (Tensor::from_vec(&[n, c, oh, ow], out), arg)
}

#[cfg(test)]
mod tests {
    use crate::dag::{DType, OpKind};
    use crate::exec::kernels::testutil::fd_check;

    #[test]
    fn grad_conv2d() {
        fd_check(
            OpKind::Conv2d { in_ch: 2, out_ch: 3, kernel: 3, stride: 1, padding: 1 },
            &[(&[1, 2, 5, 5], DType::F32)],
            2e-2,
        );
    }

    #[test]
    fn grad_conv2d_strided_nopad() {
        fd_check(
            OpKind::Conv2d { in_ch: 1, out_ch: 2, kernel: 2, stride: 2, padding: 0 },
            &[(&[1, 1, 6, 6], DType::F32)],
            2e-2,
        );
    }

    #[test]
    fn grad_maxpool() {
        fd_check(
            OpKind::MaxPool2d { kernel: 2, stride: 2 },
            &[(&[1, 2, 4, 4], DType::F32)],
            2e-2,
        );
    }
}
