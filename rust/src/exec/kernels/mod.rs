//! Per-op kernel registry for the execution plane.
//!
//! Each IR operator family implements [`OpKernel`] — parameter init, a
//! forward, and a hand-derived VJP — in its own file. Engines dispatch
//! through [`kernel_for`], so adding an op means adding one kernel file and
//! one registry line instead of threading three `match`es through every
//! engine. All VJPs are verified against central finite differences
//! (`testutil::fd_check`).

pub mod attention;
pub mod concat;
pub mod conv;
pub mod elementwise;
pub mod embedding;
pub mod ffn;
pub mod leaf;
pub mod linear;
pub mod loss;
pub mod norm;
pub mod stage;

use anyhow::Result;

use crate::dag::{Node, OpKind};
use crate::exec::{BackwardOut, Scratch};
use crate::tensor::Tensor;
use crate::util::Rng;

pub use stage::stagecall_unsupported;

/// One operator family's execution rules. Kernels are stateless unit
/// structs; all instance data comes from the [`Node`] and its tensors.
/// Intra-call f32 temporaries come from the engine-owned [`Scratch`] pool
/// (take zero-filled, put back before returning) instead of fresh
/// allocations; buffers that escape as output tensors never do.
pub trait OpKernel: Sync {
    /// Kernel name, for error messages and logs.
    fn name(&self) -> &'static str;

    /// Initialize the node's parameter list (empty for non-parametric ops).
    fn init_params(&self, _node: &Node, _rng: &mut Rng) -> Result<Vec<Tensor>> {
        Ok(vec![])
    }

    /// Forward: `inputs` aligned with `node.args`.
    fn forward(
        &self,
        node: &Node,
        inputs: &[&Tensor],
        params: &[Tensor],
        scratch: &mut Scratch,
    ) -> Result<Tensor>;

    /// Vector-Jacobian product: pull `dy` back onto inputs and params
    /// (rematerializing forward intermediates as needed).
    fn vjp(
        &self,
        node: &Node,
        inputs: &[&Tensor],
        params: &[Tensor],
        dy: &Tensor,
        scratch: &mut Scratch,
    ) -> Result<BackwardOut>;
}

/// The registry: the single place an op kind maps to its kernel.
pub fn kernel_for(kind: &OpKind) -> &'static dyn OpKernel {
    use OpKind::*;
    match kind {
        Placeholder => &leaf::PlaceholderKernel,
        Variable => &leaf::VariableKernel,
        Conv2d { .. } => &conv::Conv2dKernel,
        Linear { .. } => &linear::LinearKernel,
        Embedding { .. } => &embedding::EmbeddingKernel,
        LayerNorm { .. } => &norm::LayerNormKernel,
        Attention { .. } => &attention::AttentionKernel,
        FeedForward { .. } => &ffn::FeedForwardKernel,
        Add => &elementwise::AddKernel,
        Multiply => &elementwise::MultiplyKernel,
        Relu => &elementwise::ReluKernel,
        Gelu => &elementwise::GeluKernel,
        Softmax => &norm::SoftmaxKernel,
        MaxPool2d { .. } => &conv::MaxPool2dKernel,
        Concat { .. } => &concat::ConcatKernel,
        CrossEntropy { .. } => &loss::CrossEntropyKernel,
        MseLoss => &loss::MseLossKernel,
        StageCall { .. } => &stage::StageCallKernel,
    }
}

/// `buf[r, :] += bias` for every row of a `[rows, width]` buffer.
pub(crate) fn add_row_bias(buf: &mut [f32], width: usize, bias: &[f32]) {
    for row in buf.chunks_mut(width) {
        for (v, &bv) in row.iter_mut().zip(bias) {
            *v += bv;
        }
    }
}

/// Column sums of a `[rows, width]` buffer (the bias-gradient reduction).
pub(crate) fn sum_rows(buf: &[f32], width: usize) -> Vec<f32> {
    let mut acc = vec![0.0f32; width];
    for row in buf.chunks(width) {
        for (d, &v) in acc.iter_mut().zip(row) {
            *d += v;
        }
    }
    acc
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::dag::{DType, Graph, NodeId, Shape};

    /// Central finite-difference check of input & parameter gradients for a
    /// single-op kernel. `loss(y) = Σ w∘y` for a fixed random weighting.
    pub(crate) fn fd_check(kind: OpKind, in_shapes: &[(&[usize], DType)], tol: f32) {
        let mut g = Graph::new();
        let mut args: Vec<NodeId> = Vec::new();
        for (i, (sh, dt)) in in_shapes.iter().enumerate() {
            args.push(g.placeholder(&format!("in{i}"), Shape::of(sh), *dt));
        }
        let id = g.op("op", kind, &args).unwrap();
        let node = g.node(id).clone();
        let kernel = kernel_for(&node.kind);

        let mut rng = Rng::new(77);
        let params = kernel.init_params(&node, &mut rng).unwrap();
        let inputs: Vec<Tensor> = in_shapes
            .iter()
            .map(|(sh, dt)| match dt {
                DType::F32 => Tensor::randn(sh, 1.0, &mut rng),
                DType::I32 => {
                    let n: usize = sh.iter().product();
                    Tensor::from_ivec(sh, (0..n).map(|i| (i % 3) as i32).collect())
                }
            })
            .collect();
        let input_refs: Vec<&Tensor> = inputs.iter().collect();

        let mut scratch = Scratch::new();
        let out = kernel.forward(&node, &input_refs, &params, &mut scratch).unwrap();
        let w: Vec<f32> = (0..out.numel()).map(|_| rng.normal() as f32).collect();
        let weight = Tensor::from_vec(out.shape(), w);
        let loss = |inputs: &[&Tensor], params: &[Tensor], scratch: &mut Scratch| -> f32 {
            let y = kernel.forward(&node, inputs, params, scratch).unwrap();
            y.f().iter().zip(weight.f()).map(|(&a, &b)| a * b).sum()
        };

        let bwd = kernel.vjp(&node, &input_refs, &params, &weight, &mut scratch).unwrap();

        // Check input grads.
        const H: f32 = 1e-2;
        for (ai, inp) in inputs.iter().enumerate() {
            if !inp.is_f32() {
                continue;
            }
            let analytic = bwd.input_grads[ai].as_ref().expect("f32 inputs need grads");
            // Probe a handful of coordinates.
            let n = inp.numel();
            for probe in 0..n.min(6) {
                let idx = (probe * 7919) % n;
                let mut plus = inputs.clone();
                plus[ai] = {
                    let mut t = inp.clone();
                    t.f_mut()[idx] += H;
                    t
                };
                let mut minus = inputs.clone();
                minus[ai] = {
                    let mut t = inp.clone();
                    t.f_mut()[idx] -= H;
                    t
                };
                let rp: Vec<&Tensor> = plus.iter().collect();
                let rm: Vec<&Tensor> = minus.iter().collect();
                let fd =
                    (loss(&rp, &params, &mut scratch) - loss(&rm, &params, &mut scratch)) / (2.0 * H);
                let an = analytic.f()[idx];
                assert!(
                    (fd - an).abs() <= tol * (1.0 + fd.abs().max(an.abs())),
                    "input {ai} idx {idx}: fd={fd} analytic={an}"
                );
            }
        }
        // Check param grads.
        for (pi, p) in params.iter().enumerate() {
            let analytic = &bwd.param_grads[pi];
            let n = p.numel();
            for probe in 0..n.min(6) {
                let idx = (probe * 6007) % n;
                let mut pp = params.clone();
                pp[pi].f_mut()[idx] += H;
                let mut pm = params.clone();
                pm[pi].f_mut()[idx] -= H;
                let fd = (loss(&input_refs, &pp, &mut scratch)
                    - loss(&input_refs, &pm, &mut scratch))
                    / (2.0 * H);
                let an = analytic.f()[idx];
                assert!(
                    (fd - an).abs() <= tol * (1.0 + fd.abs().max(an.abs())),
                    "param {pi} idx {idx}: fd={fd} analytic={an}"
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dag::{DType, Graph, Shape};

    #[test]
    fn registry_covers_every_kind() {
        let kinds = vec![
            OpKind::Placeholder,
            OpKind::Variable,
            OpKind::Conv2d { in_ch: 1, out_ch: 1, kernel: 1, stride: 1, padding: 0 },
            OpKind::Linear { in_features: 1, out_features: 1, bias: false },
            OpKind::Embedding { vocab: 1, dim: 1 },
            OpKind::LayerNorm { dim: 1 },
            OpKind::Attention { heads: 1, dim: 1, causal: false },
            OpKind::FeedForward { dim: 1, hidden: 1 },
            OpKind::Add,
            OpKind::Multiply,
            OpKind::Relu,
            OpKind::Gelu,
            OpKind::Softmax,
            OpKind::MaxPool2d { kernel: 1, stride: 1 },
            OpKind::Concat { axis: 0 },
            OpKind::CrossEntropy { weight: 1.0 },
            OpKind::MseLoss,
            OpKind::StageCall { stage: "s".into(), param_count: 0, flops: 0.0, param_bytes: 0 },
        ];
        for k in kinds {
            // Every kind resolves; names are non-empty.
            assert!(!kernel_for(&k).name().is_empty());
        }
    }

    #[test]
    fn kernels_reject_wrong_kind() {
        let mut g = Graph::new();
        let x = g.placeholder("x", Shape::of(&[2, 2]), DType::F32);
        let relu = g.op("r", OpKind::Relu, &[x]).unwrap();
        let node = g.node(relu).clone();
        let t = Tensor::zeros(&[2, 2]);
        // Dispatching a Relu node to the Linear kernel is a programming
        // error and must fail loudly, not silently misexecute.
        let mut scratch = Scratch::new();
        assert!(linear::LinearKernel.forward(&node, &[&t], &[], &mut scratch).is_err());
    }
}
