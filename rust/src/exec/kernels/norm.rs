//! Normalization kernels: layer norm (with affine) and softmax.

use anyhow::{bail, Result};

use super::OpKernel;
use crate::dag::{Node, OpKind};
use crate::exec::BackwardOut;
use crate::tensor::{softmax_lastaxis, Tensor};
use crate::util::Rng;

pub struct LayerNormKernel;

fn unpack_ln(node: &Node) -> Result<usize> {
    match node.kind {
        OpKind::LayerNorm { dim } => Ok(dim),
        _ => bail!("LayerNormKernel dispatched on {}", node.kind.name()),
    }
}

impl OpKernel for LayerNormKernel {
    fn name(&self) -> &'static str {
        "layernorm"
    }

    fn init_params(&self, node: &Node, _rng: &mut Rng) -> Result<Vec<Tensor>> {
        let dim = unpack_ln(node)?;
        Ok(vec![Tensor::from_vec(&[dim], vec![1.0; dim]), Tensor::zeros(&[dim])])
    }

    fn forward(&self, node: &Node, inputs: &[&Tensor], params: &[Tensor]) -> Result<Tensor> {
        let dim = unpack_ln(node)?;
        Ok(layernorm_fwd(inputs[0], &params[0], &params[1], dim).0)
    }

    fn vjp(
        &self,
        node: &Node,
        inputs: &[&Tensor],
        params: &[Tensor],
        dy: &Tensor,
    ) -> Result<BackwardOut> {
        let dim = unpack_ln(node)?;
        layernorm_bwd(inputs[0], &params[0], dy, dim)
    }
}

pub struct SoftmaxKernel;

impl OpKernel for SoftmaxKernel {
    fn name(&self) -> &'static str {
        "softmax"
    }

    fn forward(&self, _node: &Node, inputs: &[&Tensor], _params: &[Tensor]) -> Result<Tensor> {
        let mut out = inputs[0].clone();
        let row = *out.shape().last().unwrap();
        softmax_lastaxis(out.f_mut(), row);
        Ok(out)
    }

    fn vjp(
        &self,
        _node: &Node,
        inputs: &[&Tensor],
        _params: &[Tensor],
        dy: &Tensor,
    ) -> Result<BackwardOut> {
        let mut y = inputs[0].clone();
        let row = *y.shape().last().unwrap();
        softmax_lastaxis(y.f_mut(), row);
        let yf = y.f();
        let gf = dy.f();
        let mut dx = vec![0.0f32; yf.len()];
        for r in 0..yf.len() / row {
            let o = r * row;
            let dot: f32 = (0..row).map(|j| gf[o + j] * yf[o + j]).sum();
            for j in 0..row {
                dx[o + j] = yf[o + j] * (gf[o + j] - dot);
            }
        }
        Ok(BackwardOut {
            input_grads: vec![Some(Tensor::from_vec(inputs[0].shape(), dx))],
            param_grads: vec![],
        })
    }
}

/// Returns (output, per-row (mean, inv_std)) — backward recomputes them.
fn layernorm_fwd(
    x: &Tensor,
    gamma: &Tensor,
    beta: &Tensor,
    dim: usize,
) -> (Tensor, Vec<(f32, f32)>) {
    const EPS: f32 = 1e-5;
    let xf = x.f();
    let gf = gamma.f();
    let bf = beta.f();
    let rows = xf.len() / dim;
    let mut out = vec![0.0f32; xf.len()];
    let mut stats = Vec::with_capacity(rows);
    for r in 0..rows {
        let seg = &xf[r * dim..(r + 1) * dim];
        let mean = seg.iter().sum::<f32>() / dim as f32;
        let var = seg.iter().map(|&v| (v - mean) * (v - mean)).sum::<f32>() / dim as f32;
        let inv = 1.0 / (var + EPS).sqrt();
        for j in 0..dim {
            out[r * dim + j] = gf[j] * (seg[j] - mean) * inv + bf[j];
        }
        stats.push((mean, inv));
    }
    (Tensor::from_vec(x.shape(), out), stats)
}

fn layernorm_bwd(x: &Tensor, gamma: &Tensor, dy: &Tensor, dim: usize) -> Result<BackwardOut> {
    let (_, stats) = layernorm_fwd(x, gamma, &Tensor::zeros(&[dim]), dim);
    let xf = x.f();
    let gf = gamma.f();
    let dyf = dy.f();
    let rows = xf.len() / dim;
    let mut dx = vec![0.0f32; xf.len()];
    let mut dgamma = vec![0.0f32; dim];
    let mut dbeta = vec![0.0f32; dim];
    for r in 0..rows {
        let (mean, inv) = stats[r];
        let o = r * dim;
        // xhat and dyhat = dy·γ
        let mut sum_dyh = 0.0f32;
        let mut sum_dyh_xh = 0.0f32;
        for j in 0..dim {
            let xh = (xf[o + j] - mean) * inv;
            let dyh = dyf[o + j] * gf[j];
            sum_dyh += dyh;
            sum_dyh_xh += dyh * xh;
            dgamma[j] += dyf[o + j] * xh;
            dbeta[j] += dyf[o + j];
        }
        let nd = dim as f32;
        for j in 0..dim {
            let xh = (xf[o + j] - mean) * inv;
            let dyh = dyf[o + j] * gf[j];
            dx[o + j] = inv * (dyh - sum_dyh / nd - xh * sum_dyh_xh / nd);
        }
    }
    Ok(BackwardOut {
        input_grads: vec![Some(Tensor::from_vec(x.shape(), dx))],
        param_grads: vec![Tensor::from_vec(&[dim], dgamma), Tensor::from_vec(&[dim], dbeta)],
    })
}

#[cfg(test)]
mod tests {
    use crate::dag::{DType, OpKind};
    use crate::exec::kernels::testutil::fd_check;

    #[test]
    fn grad_layernorm() {
        fd_check(OpKind::LayerNorm { dim: 6 }, &[(&[4, 6], DType::F32)], 3e-2);
    }

    #[test]
    fn grad_softmax() {
        fd_check(OpKind::Softmax, &[(&[3, 4], DType::F32)], 2e-2);
    }
}
