//! Normalization kernels: layer norm (with affine) and softmax.

use anyhow::{bail, Result};

use super::OpKernel;
use crate::dag::{Node, OpKind};
use crate::exec::{BackwardOut, Scratch};
use crate::tensor::{softmax_lastaxis, Tensor};
use crate::util::Rng;

pub struct LayerNormKernel;

fn unpack_ln(node: &Node) -> Result<usize> {
    match node.kind {
        OpKind::LayerNorm { dim } => Ok(dim),
        _ => bail!("LayerNormKernel dispatched on {}", node.kind.name()),
    }
}

impl OpKernel for LayerNormKernel {
    fn name(&self) -> &'static str {
        "layernorm"
    }

    fn init_params(&self, node: &Node, _rng: &mut Rng) -> Result<Vec<Tensor>> {
        let dim = unpack_ln(node)?;
        Ok(vec![Tensor::from_vec(&[dim], vec![1.0; dim]), Tensor::zeros(&[dim])])
    }

    fn forward(
        &self,
        node: &Node,
        inputs: &[&Tensor],
        params: &[Tensor],
        _scratch: &mut Scratch,
    ) -> Result<Tensor> {
        let dim = unpack_ln(node)?;
        Ok(layernorm_fwd(inputs[0], &params[0], &params[1], dim))
    }

    fn vjp(
        &self,
        node: &Node,
        inputs: &[&Tensor],
        params: &[Tensor],
        dy: &Tensor,
        _scratch: &mut Scratch,
    ) -> Result<BackwardOut> {
        let dim = unpack_ln(node)?;
        layernorm_bwd(inputs[0], &params[0], dy, dim)
    }
}

pub struct SoftmaxKernel;

impl OpKernel for SoftmaxKernel {
    fn name(&self) -> &'static str {
        "softmax"
    }

    fn forward(
        &self,
        _node: &Node,
        inputs: &[&Tensor],
        _params: &[Tensor],
        _scratch: &mut Scratch,
    ) -> Result<Tensor> {
        let mut out = inputs[0].clone();
        let row = *out.shape().last().unwrap();
        softmax_lastaxis(out.f_mut(), row);
        Ok(out)
    }

    fn vjp(
        &self,
        _node: &Node,
        inputs: &[&Tensor],
        _params: &[Tensor],
        dy: &Tensor,
        scratch: &mut Scratch,
    ) -> Result<BackwardOut> {
        // Rematerialized y is intra-call only — recompute into a pooled
        // buffer instead of cloning the input tensor.
        let xf = inputs[0].f();
        let row = *inputs[0].shape().last().unwrap();
        let mut y = scratch.take(xf.len());
        y.copy_from_slice(xf);
        softmax_lastaxis(&mut y, row);
        let gf = dy.f();
        let mut dx = vec![0.0f32; y.len()];
        for r in 0..y.len() / row {
            let o = r * row;
            let dot: f32 = (0..row).map(|j| gf[o + j] * y[o + j]).sum();
            for j in 0..row {
                dx[o + j] = y[o + j] * (gf[o + j] - dot);
            }
        }
        scratch.put(y);
        Ok(BackwardOut {
            input_grads: vec![Some(Tensor::from_vec(inputs[0].shape(), dx))],
            param_grads: vec![],
        })
    }
}

/// Per-row (mean, inv_std) — shared by forward and backward so backward no
/// longer recomputes the whole normalized output just to discard it.
fn layernorm_stats(xf: &[f32], dim: usize) -> Vec<(f32, f32)> {
    const EPS: f32 = 1e-5;
    let rows = xf.len() / dim;
    let mut stats = Vec::with_capacity(rows);
    for r in 0..rows {
        let seg = &xf[r * dim..(r + 1) * dim];
        let mean = seg.iter().sum::<f32>() / dim as f32;
        let var = seg.iter().map(|&v| (v - mean) * (v - mean)).sum::<f32>() / dim as f32;
        stats.push((mean, 1.0 / (var + EPS).sqrt()));
    }
    stats
}

fn layernorm_fwd(x: &Tensor, gamma: &Tensor, beta: &Tensor, dim: usize) -> Tensor {
    let xf = x.f();
    let gf = gamma.f();
    let bf = beta.f();
    let stats = layernorm_stats(xf, dim);
    let mut out = vec![0.0f32; xf.len()];
    for (r, &(mean, inv)) in stats.iter().enumerate() {
        let seg = &xf[r * dim..(r + 1) * dim];
        for j in 0..dim {
            out[r * dim + j] = gf[j] * (seg[j] - mean) * inv + bf[j];
        }
    }
    Tensor::from_vec(x.shape(), out)
}

fn layernorm_bwd(x: &Tensor, gamma: &Tensor, dy: &Tensor, dim: usize) -> Result<BackwardOut> {
    let xf = x.f();
    let stats = layernorm_stats(xf, dim);
    let gf = gamma.f();
    let dyf = dy.f();
    let rows = xf.len() / dim;
    let mut dx = vec![0.0f32; xf.len()];
    let mut dgamma = vec![0.0f32; dim];
    let mut dbeta = vec![0.0f32; dim];
    for r in 0..rows {
        let (mean, inv) = stats[r];
        let o = r * dim;
        // xhat and dyhat = dy·γ
        let mut sum_dyh = 0.0f32;
        let mut sum_dyh_xh = 0.0f32;
        for j in 0..dim {
            let xh = (xf[o + j] - mean) * inv;
            let dyh = dyf[o + j] * gf[j];
            sum_dyh += dyh;
            sum_dyh_xh += dyh * xh;
            dgamma[j] += dyf[o + j] * xh;
            dbeta[j] += dyf[o + j];
        }
        let nd = dim as f32;
        for j in 0..dim {
            let xh = (xf[o + j] - mean) * inv;
            let dyh = dyf[o + j] * gf[j];
            dx[o + j] = inv * (dyh - sum_dyh / nd - xh * sum_dyh_xh / nd);
        }
    }
    Ok(BackwardOut {
        input_grads: vec![Some(Tensor::from_vec(x.shape(), dx))],
        param_grads: vec![Tensor::from_vec(&[dim], dgamma), Tensor::from_vec(&[dim], dbeta)],
    })
}

#[cfg(test)]
mod tests {
    use crate::dag::{DType, OpKind};
    use crate::exec::kernels::testutil::fd_check;

    #[test]
    fn grad_layernorm() {
        fd_check(OpKind::LayerNorm { dim: 6 }, &[(&[4, 6], DType::F32)], 3e-2);
    }

    #[test]
    fn grad_softmax() {
        fd_check(OpKind::Softmax, &[(&[3, 4], DType::F32)], 2e-2);
    }
}
