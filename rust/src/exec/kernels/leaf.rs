//! Leaf kernels: placeholders (fed, never executed) and variables
//! (directly-optimized tensors, paper Table 2).

use anyhow::{bail, Result};

use super::OpKernel;
use crate::dag::{Node, OpKind};
use crate::exec::{BackwardOut, Scratch};
use crate::tensor::Tensor;
use crate::util::Rng;

pub struct PlaceholderKernel;

impl OpKernel for PlaceholderKernel {
    fn name(&self) -> &'static str {
        "placeholder"
    }

    fn forward(
        &self,
        _node: &Node,
        _inputs: &[&Tensor],
        _params: &[Tensor],
        _scratch: &mut Scratch,
    ) -> Result<Tensor> {
        bail!("placeholders are fed, not executed")
    }

    fn vjp(
        &self,
        _node: &Node,
        _inputs: &[&Tensor],
        _params: &[Tensor],
        _dy: &Tensor,
        _scratch: &mut Scratch,
    ) -> Result<BackwardOut> {
        bail!("placeholders have no backward")
    }
}

pub struct VariableKernel;

impl OpKernel for VariableKernel {
    fn name(&self) -> &'static str {
        "variable"
    }

    fn init_params(&self, node: &Node, rng: &mut Rng) -> Result<Vec<Tensor>> {
        if !matches!(node.kind, OpKind::Variable) {
            bail!("VariableKernel dispatched on {}", node.kind.name());
        }
        Ok(vec![Tensor::randn(node.out_shape.dims(), 0.02, rng)])
    }

    fn forward(
        &self,
        _node: &Node,
        _inputs: &[&Tensor],
        params: &[Tensor],
        _scratch: &mut Scratch,
    ) -> Result<Tensor> {
        Ok(params[0].clone())
    }

    fn vjp(
        &self,
        _node: &Node,
        _inputs: &[&Tensor],
        _params: &[Tensor],
        dy: &Tensor,
        _scratch: &mut Scratch,
    ) -> Result<BackwardOut> {
        Ok(BackwardOut { input_grads: vec![], param_grads: vec![dy.clone()] })
    }
}
