//! Affine layer `y = xW + b` over the last axis.

use anyhow::{bail, Result};

use super::{add_row_bias, sum_rows, OpKernel};
use crate::dag::{Node, OpKind};
use crate::exec::{BackwardOut, Scratch};
use crate::tensor::{matmul, matmul_at, matmul_bt, Tensor};
use crate::util::Rng;

pub struct LinearKernel;

fn unpack(node: &Node) -> Result<(usize, usize, bool)> {
    match node.kind {
        OpKind::Linear { in_features, out_features, bias } => {
            Ok((in_features, out_features, bias))
        }
        _ => bail!("LinearKernel dispatched on {}", node.kind.name()),
    }
}

impl OpKernel for LinearKernel {
    fn name(&self) -> &'static str {
        "linear"
    }

    fn init_params(&self, node: &Node, rng: &mut Rng) -> Result<Vec<Tensor>> {
        let (in_f, out_f, bias) = unpack(node)?;
        let std = 1.0 / (in_f as f32).sqrt();
        let mut p = vec![Tensor::randn(&[in_f, out_f], std, rng)];
        if bias {
            p.push(Tensor::zeros(&[out_f]));
        }
        Ok(p)
    }

    // Every buffer here escapes as an output tensor, so nothing comes from
    // the scratch pool (its buffers must stay inside the call).
    fn forward(
        &self,
        node: &Node,
        inputs: &[&Tensor],
        params: &[Tensor],
        _scratch: &mut Scratch,
    ) -> Result<Tensor> {
        let (in_f, out_f, bias) = unpack(node)?;
        let x = inputs[0];
        let m = x.numel() / in_f;
        let mut y = matmul(x.f(), params[0].f(), m, in_f, out_f);
        if bias {
            add_row_bias(&mut y, out_f, params[1].f());
        }
        let mut shape = x.shape().to_vec();
        *shape.last_mut().unwrap() = out_f;
        Ok(Tensor::from_vec(&shape, y))
    }

    fn vjp(
        &self,
        node: &Node,
        inputs: &[&Tensor],
        params: &[Tensor],
        dy: &Tensor,
        _scratch: &mut Scratch,
    ) -> Result<BackwardOut> {
        let (in_f, out_f, bias) = unpack(node)?;
        let x = inputs[0];
        let m = x.numel() / in_f;
        // dx[m,in] = dy[m,out] · Wᵀ[out,in]; with W[in,out] use matmul_bt.
        let dx = matmul_bt(dy.f(), params[0].f(), m, out_f, in_f);
        // dW[in,out] = xᵀ[in,m] · dy[m,out]
        let dw = matmul_at(x.f(), dy.f(), in_f, m, out_f);
        let mut grads = vec![Tensor::from_vec(&[in_f, out_f], dw)];
        if bias {
            grads.push(Tensor::from_vec(&[out_f], sum_rows(dy.f(), out_f)));
        }
        Ok(BackwardOut {
            input_grads: vec![Some(Tensor::from_vec(x.shape(), dx))],
            param_grads: grads,
        })
    }
}

#[cfg(test)]
mod tests {
    use crate::dag::{DType, OpKind};
    use crate::exec::kernels::testutil::fd_check;

    #[test]
    fn grad_linear() {
        fd_check(
            OpKind::Linear { in_features: 5, out_features: 4, bias: true },
            &[(&[3, 5], DType::F32)],
            2e-2,
        );
    }

    #[test]
    fn grad_linear_no_bias() {
        fd_check(
            OpKind::Linear { in_features: 4, out_features: 3, bias: false },
            &[(&[2, 4], DType::F32)],
            2e-2,
        );
    }
}
