//! StageCall "kernel": a deliberate dead end on the host planes.
//!
//! StageCall nodes reference compiled pipeline-stage artifacts and can only
//! be executed by an engine that understands the artifact manifest (the
//! XlaEngine). Any host-side registry lookup lands here and fails with a
//! single shared message — previously this error string was copy-pasted
//! across the forward and backward match arms of the reference engine.

use anyhow::{anyhow, bail, Result};

use super::OpKernel;
use crate::dag::{Node, OpKind};
use crate::exec::{BackwardOut, Scratch};
use crate::tensor::Tensor;

/// The one place the "host engine cannot run a StageCall" error is built.
pub fn stagecall_unsupported(engine: &str, stage: &str) -> anyhow::Error {
    anyhow!("{engine} cannot execute StageCall '{stage}' (use XlaEngine)")
}

pub struct StageCallKernel;

fn stage_name(node: &Node) -> Result<&str> {
    match &node.kind {
        OpKind::StageCall { stage, .. } => Ok(stage),
        _ => bail!("StageCallKernel dispatched on {}", node.kind.name()),
    }
}

impl OpKernel for StageCallKernel {
    fn name(&self) -> &'static str {
        "stage_call"
    }

    fn forward(
        &self,
        node: &Node,
        _inputs: &[&Tensor],
        _params: &[Tensor],
        _scratch: &mut Scratch,
    ) -> Result<Tensor> {
        Err(stagecall_unsupported("RefEngine", stage_name(node)?))
    }

    fn vjp(
        &self,
        node: &Node,
        _inputs: &[&Tensor],
        _params: &[Tensor],
        _dy: &Tensor,
        _scratch: &mut Scratch,
    ) -> Result<BackwardOut> {
        Err(stagecall_unsupported("RefEngine", stage_name(node)?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn message_names_engine_stage_and_remedy() {
        let err = stagecall_unsupported("RefEngine", "blocks_0_1");
        assert_eq!(
            err.to_string(),
            "RefEngine cannot execute StageCall 'blocks_0_1' (use XlaEngine)"
        );
    }
}
