//! Concatenation kernel along an arbitrary axis.

use anyhow::{bail, Result};

use super::OpKernel;
use crate::dag::{Node, OpKind};
use crate::exec::{BackwardOut, Scratch};
use crate::tensor::Tensor;

pub struct ConcatKernel;

fn unpack(node: &Node) -> Result<usize> {
    match node.kind {
        OpKind::Concat { axis } => Ok(axis),
        _ => bail!("ConcatKernel dispatched on {}", node.kind.name()),
    }
}

impl OpKernel for ConcatKernel {
    fn name(&self) -> &'static str {
        "concat"
    }

    fn forward(
        &self,
        node: &Node,
        inputs: &[&Tensor],
        _params: &[Tensor],
        _scratch: &mut Scratch,
    ) -> Result<Tensor> {
        let axis = unpack(node)?;
        let base = inputs[0].shape();
        let outer: usize = base[..axis].iter().product();
        let inner: usize = base[axis + 1..].iter().product();
        let mut axis_total = 0;
        for t in inputs {
            axis_total += t.shape()[axis];
        }
        let mut shape = base.to_vec();
        shape[axis] = axis_total;
        let mut out = vec![0.0f32; outer * axis_total * inner];
        for o in 0..outer {
            let mut dst_off = o * axis_total * inner;
            for t in inputs {
                let a = t.shape()[axis];
                let src = &t.f()[o * a * inner..(o + 1) * a * inner];
                out[dst_off..dst_off + a * inner].copy_from_slice(src);
                dst_off += a * inner;
            }
        }
        Ok(Tensor::from_vec(&shape, out))
    }

    fn vjp(
        &self,
        node: &Node,
        inputs: &[&Tensor],
        _params: &[Tensor],
        dy: &Tensor,
        _scratch: &mut Scratch,
    ) -> Result<BackwardOut> {
        let axis = unpack(node)?;
        let base = inputs[0].shape();
        let outer: usize = base[..axis].iter().product();
        let inner: usize = base[axis + 1..].iter().product();
        let axis_total: usize = inputs.iter().map(|t| t.shape()[axis]).sum();
        let dyf = dy.f();
        let mut grads: Vec<Option<Tensor>> = Vec::with_capacity(inputs.len());
        let mut axis_off = 0;
        for t in inputs {
            let a = t.shape()[axis];
            let mut g = vec![0.0f32; t.numel()];
            for o in 0..outer {
                let src = &dyf[(o * axis_total + axis_off) * inner..][..a * inner];
                g[o * a * inner..(o + 1) * a * inner].copy_from_slice(src);
            }
            grads.push(Some(Tensor::from_vec(t.shape(), g)));
            axis_off += a;
        }
        Ok(BackwardOut { input_grads: grads, param_grads: vec![] })
    }
}

#[cfg(test)]
mod tests {
    use crate::dag::{DType, OpKind};
    use crate::exec::kernels::testutil::fd_check;

    #[test]
    fn grad_concat() {
        fd_check(
            OpKind::Concat { axis: 1 },
            &[(&[2, 2, 3], DType::F32), (&[2, 4, 3], DType::F32)],
            1e-2,
        );
    }
}
