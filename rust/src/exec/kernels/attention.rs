//! Multi-head self-attention kernel over `[B, S, D]`.
//! params = [Wqkv, bqkv, Wo, bo].

use anyhow::{bail, Result};

use super::{add_row_bias, sum_rows, OpKernel};
use crate::dag::{Node, OpKind};
use crate::exec::BackwardOut;
use crate::tensor::{matmul, matmul_at, matmul_bt, Tensor};
use crate::util::Rng;

pub struct AttentionKernel;

fn unpack(node: &Node) -> Result<(usize, usize, bool)> {
    match node.kind {
        OpKind::Attention { heads, dim, causal } => Ok((heads, dim, causal)),
        _ => bail!("AttentionKernel dispatched on {}", node.kind.name()),
    }
}

impl OpKernel for AttentionKernel {
    fn name(&self) -> &'static str {
        "attention"
    }

    fn init_params(&self, node: &Node, rng: &mut Rng) -> Result<Vec<Tensor>> {
        let (_, dim, _) = unpack(node)?;
        let std = 1.0 / (dim as f32).sqrt();
        Ok(vec![
            Tensor::randn(&[dim, 3 * dim], std, rng),
            Tensor::zeros(&[3 * dim]),
            Tensor::randn(&[dim, dim], std, rng),
            Tensor::zeros(&[dim]),
        ])
    }

    fn forward(&self, node: &Node, inputs: &[&Tensor], params: &[Tensor]) -> Result<Tensor> {
        let (heads, dim, causal) = unpack(node)?;
        let x = inputs[0];
        let (ctx, _) = attention_core(x, params, heads, dim, causal);
        let s = x.shape();
        let (b, sl) = (s[0], s[1]);
        // out = ctx·Wo + bo
        let mut out = matmul(&ctx, params[2].f(), b * sl, dim, dim);
        add_row_bias(&mut out, dim, params[3].f());
        Ok(Tensor::from_vec(s, out))
    }

    fn vjp(
        &self,
        node: &Node,
        inputs: &[&Tensor],
        params: &[Tensor],
        dy: &Tensor,
    ) -> Result<BackwardOut> {
        let (heads, dim, causal) = unpack(node)?;
        attention_bwd(inputs[0], params, dy, heads, dim, causal)
    }
}

/// Shared fwd computation: returns (concat context [B*S, D], per-(b,h)
/// softmax probabilities P [S,S] flattened) for reuse in backward.
fn attention_core(
    x: &Tensor,
    params: &[Tensor],
    heads: usize,
    dim: usize,
    causal: bool,
) -> (Vec<f32>, Vec<Vec<f32>>) {
    use crate::tensor::softmax_lastaxis;
    let s = x.shape();
    let (b, sl) = (s[0], s[1]);
    let hd = dim / heads;
    let scale = 1.0 / (hd as f32).sqrt();
    // qkv[B*S, 3D]
    let mut qkv = matmul(x.f(), params[0].f(), b * sl, dim, 3 * dim);
    add_row_bias(&mut qkv, 3 * dim, params[1].f());
    let mut ctx = vec![0.0f32; b * sl * dim];
    let mut probs = Vec::with_capacity(b * heads);
    for bi in 0..b {
        for h in 0..heads {
            // Q,K,V [S,hd] slices of qkv rows.
            let q_off = h * hd;
            let k_off = dim + h * hd;
            let v_off = 2 * dim + h * hd;
            let mut scores = vec![f32::NEG_INFINITY; sl * sl];
            for i in 0..sl {
                let qrow = &qkv[(bi * sl + i) * 3 * dim + q_off..][..hd];
                let jmax = if causal { i + 1 } else { sl };
                for j in 0..jmax {
                    let krow = &qkv[(bi * sl + j) * 3 * dim + k_off..][..hd];
                    let mut dot = 0.0;
                    for d in 0..hd {
                        dot += qrow[d] * krow[d];
                    }
                    scores[i * sl + j] = dot * scale;
                }
            }
            softmax_lastaxis(&mut scores, sl);
            // ctx_i = Σ_j P_ij · V_j
            for i in 0..sl {
                for j in 0..sl {
                    let p = scores[i * sl + j];
                    if p == 0.0 {
                        continue;
                    }
                    let vrow = &qkv[(bi * sl + j) * 3 * dim + v_off..][..hd];
                    let crow = &mut ctx[(bi * sl + i) * dim + h * hd..][..hd];
                    for d in 0..hd {
                        crow[d] += p * vrow[d];
                    }
                }
            }
            probs.push(scores);
        }
    }
    (ctx, probs)
}

fn attention_bwd(
    x: &Tensor,
    params: &[Tensor],
    dy: &Tensor,
    heads: usize,
    dim: usize,
    causal: bool,
) -> Result<BackwardOut> {
    let s = x.shape();
    let (b, sl) = (s[0], s[1]);
    let hd = dim / heads;
    let scale = 1.0 / (hd as f32).sqrt();
    let rows = b * sl;

    // Recompute forward intermediates.
    let mut qkv = matmul(x.f(), params[0].f(), rows, dim, 3 * dim);
    add_row_bias(&mut qkv, 3 * dim, params[1].f());
    let (ctx, probs) = attention_core(x, params, heads, dim, causal);

    // out = ctx·Wo + bo  ⇒  dctx = dy·Woᵀ ; dWo = ctxᵀ·dy ; dbo = Σ dy.
    let dctx = matmul_bt(dy.f(), params[2].f(), rows, dim, dim);
    let dwo = matmul_at(&ctx, dy.f(), dim, rows, dim);
    let dbo = sum_rows(dy.f(), dim);

    // Per (batch, head): dP, dscores, dQ, dK, dV.
    let mut dqkv = vec![0.0f32; rows * 3 * dim];
    for bi in 0..b {
        for h in 0..heads {
            let p = &probs[bi * heads + h]; // [S,S]
            let q_off = h * hd;
            let k_off = dim + h * hd;
            let v_off = 2 * dim + h * hd;
            // dP_ij = dctx_i · V_j ; dV_j = Σ_i P_ij dctx_i
            let mut dp = vec![0.0f32; sl * sl];
            for i in 0..sl {
                let dci = &dctx[(bi * sl + i) * dim + h * hd..][..hd];
                for j in 0..sl {
                    let vrow = &qkv[(bi * sl + j) * 3 * dim + v_off..][..hd];
                    let mut dot = 0.0;
                    for d in 0..hd {
                        dot += dci[d] * vrow[d];
                    }
                    dp[i * sl + j] = dot;
                    // dV
                    let pv = p[i * sl + j];
                    if pv != 0.0 {
                        let dvrow = &mut dqkv[(bi * sl + j) * 3 * dim + v_off..][..hd];
                        for d in 0..hd {
                            dvrow[d] += pv * dci[d];
                        }
                    }
                }
            }
            // softmax backward per row: ds = P ∘ (dP − Σ_j dP·P)
            let mut ds = vec![0.0f32; sl * sl];
            for i in 0..sl {
                let o = i * sl;
                let dot: f32 = (0..sl).map(|j| dp[o + j] * p[o + j]).sum();
                for j in 0..sl {
                    ds[o + j] = p[o + j] * (dp[o + j] - dot);
                }
            }
            // dQ_i = scale Σ_j ds_ij K_j ; dK_j = scale Σ_i ds_ij Q_i
            for i in 0..sl {
                for j in 0..sl {
                    let g = ds[i * sl + j] * scale;
                    if g == 0.0 {
                        continue;
                    }
                    let (qi, kj) = ((bi * sl + i) * 3 * dim, (bi * sl + j) * 3 * dim);
                    for d in 0..hd {
                        dqkv[qi + q_off + d] += g * qkv[kj + k_off + d];
                        dqkv[kj + k_off + d] += g * qkv[qi + q_off + d];
                    }
                }
            }
        }
    }

    // qkv = x·Wqkv + b ⇒ dx = dqkv·Wqkvᵀ ; dWqkv = xᵀ·dqkv ; dbqkv = Σ dqkv.
    let dx = matmul_bt(&dqkv, params[0].f(), rows, 3 * dim, dim);
    let dwqkv = matmul_at(x.f(), &dqkv, dim, rows, 3 * dim);
    let dbqkv = sum_rows(&dqkv, 3 * dim);

    Ok(BackwardOut {
        input_grads: vec![Some(Tensor::from_vec(x.shape(), dx))],
        param_grads: vec![
            Tensor::from_vec(&[dim, 3 * dim], dwqkv),
            Tensor::from_vec(&[3 * dim], dbqkv),
            Tensor::from_vec(&[dim, dim], dwo),
            Tensor::from_vec(&[dim], dbo),
        ],
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dag::{DType, Graph, Shape};
    use crate::exec::kernels::{kernel_for, testutil::fd_check};

    #[test]
    fn grad_attention() {
        fd_check(
            OpKind::Attention { heads: 2, dim: 8, causal: false },
            &[(&[1, 4, 8], DType::F32)],
            4e-2,
        );
    }

    #[test]
    fn grad_attention_causal() {
        fd_check(
            OpKind::Attention { heads: 2, dim: 8, causal: true },
            &[(&[1, 4, 8], DType::F32)],
            4e-2,
        );
    }

    #[test]
    fn causal_attention_masks_future() {
        // Changing a future token must not change earlier outputs.
        let mut g = Graph::new();
        let x = g.placeholder("x", Shape::of(&[1, 4, 8]), DType::F32);
        let id =
            g.op("attn", OpKind::Attention { heads: 2, dim: 8, causal: true }, &[x]).unwrap();
        let node = g.node(id).clone();
        let kernel = kernel_for(&node.kind);
        let mut rng = Rng::new(11);
        let params = kernel.init_params(&node, &mut rng).unwrap();
        let a = Tensor::randn(&[1, 4, 8], 1.0, &mut rng);
        let mut b = a.clone();
        // Perturb the last token only.
        for d in 0..8 {
            b.f_mut()[3 * 8 + d] += 1.0;
        }
        let ya = kernel.forward(&node, &[&a], &params).unwrap();
        let yb = kernel.forward(&node, &[&b], &params).unwrap();
        for t in 0..3 {
            for d in 0..8 {
                assert!(
                    (ya.f()[t * 8 + d] - yb.f()[t * 8 + d]).abs() < 1e-6,
                    "leak at token {t}"
                );
            }
        }
        // And the last token's output must differ.
        let diff: f32 = (0..8).map(|d| (ya.f()[3 * 8 + d] - yb.f()[3 * 8 + d]).abs()).sum();
        assert!(diff > 1e-3);
    }
}
