//! Multi-head self-attention kernel over `[B, S, D]`.
//! params = [Wqkv, bqkv, Wo, bo].

use anyhow::{bail, Result};

use super::{add_row_bias, sum_rows, OpKernel};
use crate::dag::{Node, OpKind};
use crate::exec::{BackwardOut, Scratch};
use crate::tensor::{matmul, matmul_at, matmul_bt, softmax_lastaxis, Tensor};
use crate::util::Rng;

pub struct AttentionKernel;

fn unpack(node: &Node) -> Result<(usize, usize, bool)> {
    match node.kind {
        OpKind::Attention { heads, dim, causal } => Ok((heads, dim, causal)),
        _ => bail!("AttentionKernel dispatched on {}", node.kind.name()),
    }
}

impl OpKernel for AttentionKernel {
    fn name(&self) -> &'static str {
        "attention"
    }

    fn init_params(&self, node: &Node, rng: &mut Rng) -> Result<Vec<Tensor>> {
        let (_, dim, _) = unpack(node)?;
        let std = 1.0 / (dim as f32).sqrt();
        Ok(vec![
            Tensor::randn(&[dim, 3 * dim], std, rng),
            Tensor::zeros(&[3 * dim]),
            Tensor::randn(&[dim, dim], std, rng),
            Tensor::zeros(&[dim]),
        ])
    }

    fn forward(
        &self,
        node: &Node,
        inputs: &[&Tensor],
        params: &[Tensor],
        scratch: &mut Scratch,
    ) -> Result<Tensor> {
        let (heads, dim, causal) = unpack(node)?;
        let x = inputs[0];
        let core = attention_core(x, params, heads, dim, causal, scratch);
        let s = x.shape();
        let (b, sl) = (s[0], s[1]);
        // out = ctx·Wo + bo (escapes as the output tensor: fresh buffer).
        let mut out = matmul(&core.ctx, params[2].f(), b * sl, dim, dim);
        add_row_bias(&mut out, dim, params[3].f());
        core.release(scratch);
        Ok(Tensor::from_vec(s, out))
    }

    fn vjp(
        &self,
        node: &Node,
        inputs: &[&Tensor],
        params: &[Tensor],
        dy: &Tensor,
        scratch: &mut Scratch,
    ) -> Result<BackwardOut> {
        let (heads, dim, causal) = unpack(node)?;
        attention_bwd(inputs[0], params, dy, heads, dim, causal, scratch)
    }
}

/// Forward intermediates shared by forward and backward, all backed by
/// scratch-pool buffers — callers must hand them back via [`Core::release`].
struct Core {
    /// `[B*S, 3D]` projected queries/keys/values.
    qkv: Vec<f32>,
    /// `[B*S, D]` concatenated per-head context.
    ctx: Vec<f32>,
    /// `[B·heads, S, S]` softmax probabilities, flattened.
    probs: Vec<f32>,
}

impl Core {
    fn release(self, scratch: &mut Scratch) {
        scratch.put(self.qkv);
        scratch.put(self.ctx);
        scratch.put(self.probs);
    }
}

/// Shared forward computation. Scratch buffers arrive zero-filled; the
/// score rows are therefore written explicitly — finite logits for the
/// visible prefix, `-inf` beyond it — before the in-place softmax.
fn attention_core(
    x: &Tensor,
    params: &[Tensor],
    heads: usize,
    dim: usize,
    causal: bool,
    scratch: &mut Scratch,
) -> Core {
    let s = x.shape();
    let (b, sl) = (s[0], s[1]);
    let hd = dim / heads;
    let scale = 1.0 / (hd as f32).sqrt();
    // qkv[B*S, 3D]
    let mut qkv = scratch.take(b * sl * 3 * dim);
    crate::tensor::matmul_into(x.f(), params[0].f(), &mut qkv, b * sl, dim, 3 * dim);
    add_row_bias(&mut qkv, 3 * dim, params[1].f());
    let mut ctx = scratch.take(b * sl * dim);
    let mut probs = scratch.take(b * heads * sl * sl);
    {
        for bi in 0..b {
            for h in 0..heads {
                // Q,K,V [S,hd] slices of qkv rows.
                let q_off = h * hd;
                let k_off = dim + h * hd;
                let v_off = 2 * dim + h * hd;
                let scores = &mut probs[(bi * heads + h) * sl * sl..][..sl * sl];
                for i in 0..sl {
                    let qrow = &qkv[(bi * sl + i) * 3 * dim + q_off..][..hd];
                    let jmax = if causal { i + 1 } else { sl };
                    for j in 0..jmax {
                        let krow = &qkv[(bi * sl + j) * 3 * dim + k_off..][..hd];
                        let mut dot = 0.0;
                        for d in 0..hd {
                            dot += qrow[d] * krow[d];
                        }
                        scores[i * sl + j] = dot * scale;
                    }
                    scores[i * sl + jmax..(i + 1) * sl].fill(f32::NEG_INFINITY);
                }
                softmax_lastaxis(scores, sl);
                // ctx_i = Σ_j P_ij · V_j (masked positions contribute an
                // exact 0.0 probability, so no skip is needed).
                for i in 0..sl {
                    for j in 0..sl {
                        let p = scores[i * sl + j];
                        let vrow = &qkv[(bi * sl + j) * 3 * dim + v_off..][..hd];
                        let crow = &mut ctx[(bi * sl + i) * dim + h * hd..][..hd];
                        for d in 0..hd {
                            crow[d] += p * vrow[d];
                        }
                    }
                }
            }
        }
    }
    Core { qkv, ctx, probs }
}

#[allow(clippy::float_cmp)] // exact zero-skip on ds entries, not a tolerance check
fn attention_bwd(
    x: &Tensor,
    params: &[Tensor],
    dy: &Tensor,
    heads: usize,
    dim: usize,
    causal: bool,
    scratch: &mut Scratch,
) -> Result<BackwardOut> {
    let s = x.shape();
    let (b, sl) = (s[0], s[1]);
    let hd = dim / heads;
    let scale = 1.0 / (hd as f32).sqrt();
    let rows = b * sl;

    // One forward recomputation, shared with the output projection.
    let core = attention_core(x, params, heads, dim, causal, scratch);

    // out = ctx·Wo + bo  ⇒  dctx = dy·Woᵀ ; dWo = ctxᵀ·dy ; dbo = Σ dy.
    let mut dctx = scratch.take(rows * dim);
    crate::tensor::matmul_bt_into(dy.f(), params[2].f(), &mut dctx, rows, dim, dim);
    let dwo = matmul_at(&core.ctx, dy.f(), dim, rows, dim);
    let dbo = sum_rows(dy.f(), dim);

    // Per (batch, head): dP, dscores, dQ, dK, dV. dp/ds are fully
    // rewritten every head, so one scratch buffer each serves all heads.
    let mut dqkv = scratch.take(rows * 3 * dim);
    let mut dp = scratch.take(sl * sl);
    let mut ds = scratch.take(sl * sl);
    for bi in 0..b {
        for h in 0..heads {
            let p = &core.probs[(bi * heads + h) * sl * sl..][..sl * sl];
            let q_off = h * hd;
            let k_off = dim + h * hd;
            let v_off = 2 * dim + h * hd;
            // dP_ij = dctx_i · V_j ; dV_j = Σ_i P_ij dctx_i
            for i in 0..sl {
                let dci = &dctx[(bi * sl + i) * dim + h * hd..][..hd];
                for j in 0..sl {
                    let vrow = &core.qkv[(bi * sl + j) * 3 * dim + v_off..][..hd];
                    let mut dot = 0.0;
                    for d in 0..hd {
                        dot += dci[d] * vrow[d];
                    }
                    dp[i * sl + j] = dot;
                    // dV
                    let pv = p[i * sl + j];
                    let dvrow = &mut dqkv[(bi * sl + j) * 3 * dim + v_off..][..hd];
                    for d in 0..hd {
                        dvrow[d] += pv * dci[d];
                    }
                }
            }
            // softmax backward per row: ds = P ∘ (dP − Σ_j dP·P)
            for i in 0..sl {
                let o = i * sl;
                let dot: f32 = (0..sl).map(|j| dp[o + j] * p[o + j]).sum();
                for j in 0..sl {
                    ds[o + j] = p[o + j] * (dp[o + j] - dot);
                }
            }
            // dQ_i = scale Σ_j ds_ij K_j ; dK_j = scale Σ_i ds_ij Q_i
            for i in 0..sl {
                for j in 0..sl {
                    let g = ds[i * sl + j] * scale;
                    if g == 0.0 {
                        continue;
                    }
                    let (qi, kj) = ((bi * sl + i) * 3 * dim, (bi * sl + j) * 3 * dim);
                    for d in 0..hd {
                        dqkv[qi + q_off + d] += g * core.qkv[kj + k_off + d];
                        dqkv[kj + k_off + d] += g * core.qkv[qi + q_off + d];
                    }
                }
            }
        }
    }

    // qkv = x·Wqkv + b ⇒ dx = dqkv·Wqkvᵀ ; dWqkv = xᵀ·dqkv ; dbqkv = Σ dqkv.
    let dx = matmul_bt(&dqkv, params[0].f(), rows, 3 * dim, dim);
    let dwqkv = matmul_at(x.f(), &dqkv, dim, rows, 3 * dim);
    let dbqkv = sum_rows(&dqkv, 3 * dim);

    scratch.put(ds);
    scratch.put(dp);
    scratch.put(dqkv);
    scratch.put(dctx);
    core.release(scratch);

    Ok(BackwardOut {
        input_grads: vec![Some(Tensor::from_vec(x.shape(), dx))],
        param_grads: vec![
            Tensor::from_vec(&[dim, 3 * dim], dwqkv),
            Tensor::from_vec(&[3 * dim], dbqkv),
            Tensor::from_vec(&[dim, dim], dwo),
            Tensor::from_vec(&[dim], dbo),
        ],
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dag::{DType, Graph, Shape};
    use crate::exec::kernels::{kernel_for, testutil::fd_check};

    #[test]
    fn grad_attention() {
        fd_check(
            OpKind::Attention { heads: 2, dim: 8, causal: false },
            &[(&[1, 4, 8], DType::F32)],
            4e-2,
        );
    }

    #[test]
    fn grad_attention_causal() {
        fd_check(
            OpKind::Attention { heads: 2, dim: 8, causal: true },
            &[(&[1, 4, 8], DType::F32)],
            4e-2,
        );
    }

    #[test]
    fn causal_attention_masks_future() {
        // Changing a future token must not change earlier outputs.
        let mut g = Graph::new();
        let x = g.placeholder("x", Shape::of(&[1, 4, 8]), DType::F32);
        let id =
            g.op("attn", OpKind::Attention { heads: 2, dim: 8, causal: true }, &[x]).unwrap();
        let node = g.node(id).clone();
        let kernel = kernel_for(&node.kind);
        let mut rng = Rng::new(11);
        let params = kernel.init_params(&node, &mut rng).unwrap();
        let a = Tensor::randn(&[1, 4, 8], 1.0, &mut rng);
        let mut b = a.clone();
        // Perturb the last token only.
        for d in 0..8 {
            b.f_mut()[3 * 8 + d] += 1.0;
        }
        let mut scratch = Scratch::new();
        let ya = kernel.forward(&node, &[&a], &params, &mut scratch).unwrap();
        let yb = kernel.forward(&node, &[&b], &params, &mut scratch).unwrap();
        for t in 0..3 {
            for d in 0..8 {
                assert!(
                    (ya.f()[t * 8 + d] - yb.f()[t * 8 + d]).abs() < 1e-6,
                    "leak at token {t}"
                );
            }
        }
        // And the last token's output must differ.
        let diff: f32 = (0..8).map(|d| (ya.f()[3 * 8 + d] - yb.f()[3 * 8 + d]).abs()).sum();
        assert!(diff > 1e-3);
    }

    /// Pool reuse must not change attention numerics: the second forward
    /// (served from recycled buffers) is bitwise-identical to the first.
    #[test]
    fn scratch_reuse_is_bitwise_stable() {
        let mut g = Graph::new();
        let x = g.placeholder("x", Shape::of(&[2, 3, 8]), DType::F32);
        let id =
            g.op("attn", OpKind::Attention { heads: 2, dim: 8, causal: true }, &[x]).unwrap();
        let node = g.node(id).clone();
        let kernel = kernel_for(&node.kind);
        let mut rng = Rng::new(5);
        let params = kernel.init_params(&node, &mut rng).unwrap();
        let a = Tensor::randn(&[2, 3, 8], 1.0, &mut rng);
        let mut scratch = Scratch::new();
        let y1 = kernel.forward(&node, &[&a], &params, &mut scratch).unwrap();
        assert_eq!(scratch.hits(), 0);
        let y2 = kernel.forward(&node, &[&a], &params, &mut scratch).unwrap();
        assert!(scratch.hits() > 0, "second call must reuse pooled buffers");
        let bits = |t: &Tensor| t.f().iter().map(|v| v.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&y1), bits(&y2));
    }
}
