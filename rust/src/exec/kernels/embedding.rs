//! Token-embedding lookup kernel (table `[vocab, dim]`).

use anyhow::{bail, Result};

use super::OpKernel;
use crate::dag::{Node, OpKind};
use crate::exec::{BackwardOut, Scratch};
use crate::tensor::Tensor;
use crate::util::Rng;

pub struct EmbeddingKernel;

fn unpack(node: &Node) -> Result<(usize, usize)> {
    match node.kind {
        OpKind::Embedding { vocab, dim } => Ok((vocab, dim)),
        _ => bail!("EmbeddingKernel dispatched on {}", node.kind.name()),
    }
}

impl OpKernel for EmbeddingKernel {
    fn name(&self) -> &'static str {
        "embedding"
    }

    fn init_params(&self, node: &Node, rng: &mut Rng) -> Result<Vec<Tensor>> {
        let (vocab, dim) = unpack(node)?;
        Ok(vec![Tensor::randn(&[vocab, dim], 0.02, rng)])
    }

    fn forward(
        &self,
        node: &Node,
        inputs: &[&Tensor],
        params: &[Tensor],
        _scratch: &mut Scratch,
    ) -> Result<Tensor> {
        let (vocab, dim) = unpack(node)?;
        let ids = inputs[0];
        let tf = params[0].f();
        let mut out = Vec::with_capacity(ids.numel() * dim);
        for &id in ids.i() {
            let id = id as usize;
            if id >= vocab {
                bail!("token id {id} out of vocab {vocab}");
            }
            out.extend_from_slice(&tf[id * dim..(id + 1) * dim]);
        }
        let mut shape = ids.shape().to_vec();
        shape.push(dim);
        Ok(Tensor::from_vec(&shape, out))
    }

    fn vjp(
        &self,
        node: &Node,
        inputs: &[&Tensor],
        _params: &[Tensor],
        dy: &Tensor,
        _scratch: &mut Scratch,
    ) -> Result<BackwardOut> {
        let (vocab, dim) = unpack(node)?;
        let mut dtable = Tensor::zeros(&[vocab, dim]);
        let ids = inputs[0].i();
        let dyf = dy.f();
        let dt = dtable.f_mut();
        for (pos, &id) in ids.iter().enumerate() {
            let row = id as usize * dim;
            for d in 0..dim {
                dt[row + d] += dyf[pos * dim + d];
            }
        }
        Ok(BackwardOut { input_grads: vec![None], param_grads: vec![dtable] })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dag::{DType, Graph, Shape};
    use crate::exec::kernels::kernel_for;

    #[test]
    fn grad_embedding_scatter() {
        let mut g = Graph::new();
        let tok = g.placeholder("tok", Shape::of(&[3]), DType::I32);
        let id = g.op("emb", OpKind::Embedding { vocab: 5, dim: 2 }, &[tok]).unwrap();
        let node = g.node(id).clone();
        let kernel = kernel_for(&node.kind);
        let mut rng = Rng::new(5);
        let params = kernel.init_params(&node, &mut rng).unwrap();
        let ids = Tensor::from_ivec(&[3], vec![1, 3, 1]);
        let dy = Tensor::from_vec(&[3, 2], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let mut scratch = crate::exec::Scratch::new();
        let bwd = kernel.vjp(&node, &[&ids], &params, &dy, &mut scratch).unwrap();
        let dt = bwd.param_grads[0].f();
        // row 1 accumulates positions 0 and 2; row 3 gets position 1.
        assert_eq!(&dt[2..4], &[1.0 + 5.0, 2.0 + 6.0]);
        assert_eq!(&dt[6..8], &[3.0, 4.0]);
        assert_eq!(&dt[0..2], &[0.0, 0.0]);
    }

    #[test]
    fn rejects_out_of_vocab() {
        let mut g = Graph::new();
        let tok = g.placeholder("tok", Shape::of(&[1]), DType::I32);
        let id = g.op("emb", OpKind::Embedding { vocab: 3, dim: 2 }, &[tok]).unwrap();
        let node = g.node(id).clone();
        let kernel = kernel_for(&node.kind);
        let mut rng = Rng::new(5);
        let params = kernel.init_params(&node, &mut rng).unwrap();
        let ids = Tensor::from_ivec(&[1], vec![9]);
        let mut scratch = crate::exec::Scratch::new();
        assert!(kernel.forward(&node, &[&ids], &params, &mut scratch).is_err());
    }
}
