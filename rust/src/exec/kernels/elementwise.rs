//! Elementwise kernels: Add, Multiply, Relu, Gelu.

use anyhow::Result;

use super::OpKernel;
use crate::dag::Node;
use crate::exec::{BackwardOut, Scratch};
use crate::tensor::{gelu, gelu_grad, Tensor};

pub struct AddKernel;

impl OpKernel for AddKernel {
    fn name(&self) -> &'static str {
        "add"
    }

    fn forward(
        &self,
        _node: &Node,
        inputs: &[&Tensor],
        _params: &[Tensor],
        _scratch: &mut Scratch,
    ) -> Result<Tensor> {
        Ok(inputs[0].zip(inputs[1], |a, b| a + b))
    }

    fn vjp(
        &self,
        _node: &Node,
        _inputs: &[&Tensor],
        _params: &[Tensor],
        dy: &Tensor,
        _scratch: &mut Scratch,
    ) -> Result<BackwardOut> {
        Ok(BackwardOut {
            input_grads: vec![Some(dy.clone()), Some(dy.clone())],
            param_grads: vec![],
        })
    }
}

pub struct MultiplyKernel;

impl OpKernel for MultiplyKernel {
    fn name(&self) -> &'static str {
        "multiply"
    }

    fn forward(
        &self,
        _node: &Node,
        inputs: &[&Tensor],
        _params: &[Tensor],
        _scratch: &mut Scratch,
    ) -> Result<Tensor> {
        Ok(inputs[0].zip(inputs[1], |a, b| a * b))
    }

    fn vjp(
        &self,
        _node: &Node,
        inputs: &[&Tensor],
        _params: &[Tensor],
        dy: &Tensor,
        _scratch: &mut Scratch,
    ) -> Result<BackwardOut> {
        Ok(BackwardOut {
            input_grads: vec![
                Some(dy.zip(inputs[1], |g, b| g * b)),
                Some(dy.zip(inputs[0], |g, a| g * a)),
            ],
            param_grads: vec![],
        })
    }
}

pub struct ReluKernel;

impl OpKernel for ReluKernel {
    fn name(&self) -> &'static str {
        "relu"
    }

    fn forward(
        &self,
        _node: &Node,
        inputs: &[&Tensor],
        _params: &[Tensor],
        _scratch: &mut Scratch,
    ) -> Result<Tensor> {
        Ok(inputs[0].map(|x| x.max(0.0)))
    }

    fn vjp(
        &self,
        _node: &Node,
        inputs: &[&Tensor],
        _params: &[Tensor],
        dy: &Tensor,
        _scratch: &mut Scratch,
    ) -> Result<BackwardOut> {
        Ok(BackwardOut {
            input_grads: vec![Some(dy.zip(inputs[0], |g, x| if x > 0.0 { g } else { 0.0 }))],
            param_grads: vec![],
        })
    }
}

pub struct GeluKernel;

impl OpKernel for GeluKernel {
    fn name(&self) -> &'static str {
        "gelu"
    }

    fn forward(
        &self,
        _node: &Node,
        inputs: &[&Tensor],
        _params: &[Tensor],
        _scratch: &mut Scratch,
    ) -> Result<Tensor> {
        Ok(inputs[0].map(gelu))
    }

    fn vjp(
        &self,
        _node: &Node,
        inputs: &[&Tensor],
        _params: &[Tensor],
        dy: &Tensor,
        _scratch: &mut Scratch,
    ) -> Result<BackwardOut> {
        Ok(BackwardOut {
            input_grads: vec![Some(dy.zip(inputs[0], |g, x| g * gelu_grad(x)))],
            param_grads: vec![],
        })
    }
}

#[cfg(test)]
mod tests {
    use crate::dag::{DType, OpKind};
    use crate::exec::kernels::testutil::fd_check;

    #[test]
    fn grad_elementwise() {
        fd_check(OpKind::Add, &[(&[2, 3], DType::F32), (&[2, 3], DType::F32)], 1e-2);
        fd_check(OpKind::Multiply, &[(&[2, 3], DType::F32), (&[2, 3], DType::F32)], 1e-2);
        fd_check(OpKind::Gelu, &[(&[2, 5], DType::F32)], 1e-2);
    }
}
