//! Loss kernels: cross-entropy (over logits + i32 labels) and MSE.

use anyhow::{bail, Result};

use super::OpKernel;
use crate::dag::{Node, OpKind};
use crate::exec::{BackwardOut, Scratch};
use crate::tensor::{softmax_lastaxis, Tensor};

pub struct CrossEntropyKernel;

fn unpack_ce(node: &Node) -> Result<f64> {
    match node.kind {
        OpKind::CrossEntropy { weight } => Ok(weight),
        _ => bail!("CrossEntropyKernel dispatched on {}", node.kind.name()),
    }
}

impl OpKernel for CrossEntropyKernel {
    fn name(&self) -> &'static str {
        "cross_entropy"
    }

    fn forward(
        &self,
        node: &Node,
        inputs: &[&Tensor],
        _params: &[Tensor],
        scratch: &mut Scratch,
    ) -> Result<Tensor> {
        let weight = unpack_ce(node)?;
        let (labels, logits) = split_ce_inputs(inputs)?;
        Ok(Tensor::scalar(cross_entropy_fwd(logits, labels, scratch) * weight as f32))
    }

    fn vjp(
        &self,
        node: &Node,
        inputs: &[&Tensor],
        _params: &[Tensor],
        dy: &Tensor,
        _scratch: &mut Scratch,
    ) -> Result<BackwardOut> {
        let weight = unpack_ce(node)?;
        let (labels, logits) = split_ce_inputs(inputs)?;
        let scale = dy.item() * weight as f32;
        // The probability buffer escapes as dlogits, so it is allocated
        // fresh rather than drawn from the pool.
        let dlogits = cross_entropy_bwd(logits, labels, scale);
        // Align grads with the arg order (labels get None).
        let grads = if inputs[0].is_f32() {
            vec![Some(dlogits), None]
        } else {
            vec![None, Some(dlogits)]
        };
        Ok(BackwardOut { input_grads: grads, param_grads: vec![] })
    }
}

pub struct MseLossKernel;

impl OpKernel for MseLossKernel {
    fn name(&self) -> &'static str {
        "mse_loss"
    }

    fn forward(
        &self,
        _node: &Node,
        inputs: &[&Tensor],
        _params: &[Tensor],
        _scratch: &mut Scratch,
    ) -> Result<Tensor> {
        let a = inputs[0].f();
        let b = inputs[1].f();
        let n = a.len() as f32;
        let mse = a.iter().zip(b).map(|(&x, &y)| (x - y) * (x - y)).sum::<f32>() / n;
        Ok(Tensor::scalar(mse))
    }

    fn vjp(
        &self,
        _node: &Node,
        inputs: &[&Tensor],
        _params: &[Tensor],
        dy: &Tensor,
        _scratch: &mut Scratch,
    ) -> Result<BackwardOut> {
        let a = inputs[0].f();
        let b = inputs[1].f();
        let n = a.len() as f32;
        let s = 2.0 * dy.item() / n;
        let da: Vec<f32> = a.iter().zip(b).map(|(&x, &y)| s * (x - y)).collect();
        let db: Vec<f32> = da.iter().map(|&g| -g).collect();
        Ok(BackwardOut {
            input_grads: vec![
                Some(Tensor::from_vec(inputs[0].shape(), da)),
                Some(Tensor::from_vec(inputs[1].shape(), db)),
            ],
            param_grads: vec![],
        })
    }
}

/// Identify (labels, logits) from a CrossEntropy node's inputs (either order).
fn split_ce_inputs<'a>(inputs: &[&'a Tensor]) -> Result<(&'a Tensor, &'a Tensor)> {
    match (inputs[0].is_f32(), inputs[1].is_f32()) {
        (false, true) => Ok((inputs[0], inputs[1])),
        (true, false) => Ok((inputs[1], inputs[0])),
        _ => bail!("CrossEntropy wants one i32 label tensor and one f32 logits tensor"),
    }
}

fn cross_entropy_fwd(logits: &Tensor, labels: &Tensor, scratch: &mut Scratch) -> f32 {
    let c = *logits.shape().last().unwrap();
    let n = logits.numel() / c;
    let mut probs = scratch.take(logits.numel());
    probs.copy_from_slice(logits.f());
    softmax_lastaxis(&mut probs, c);
    let mut loss = 0.0f32;
    for (r, &lab) in labels.i().iter().enumerate() {
        loss -= (probs[r * c + lab as usize]).max(1e-12).ln();
    }
    scratch.put(probs);
    loss / n as f32
}

fn cross_entropy_bwd(logits: &Tensor, labels: &Tensor, scale: f32) -> Tensor {
    let c = *logits.shape().last().unwrap();
    let n = logits.numel() / c;
    let mut probs = logits.f().to_vec();
    softmax_lastaxis(&mut probs, c);
    let s = scale / n as f32;
    for (r, &lab) in labels.i().iter().enumerate() {
        probs[r * c + lab as usize] -= 1.0;
    }
    for v in probs.iter_mut() {
        *v *= s;
    }
    Tensor::from_vec(logits.shape(), probs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dag::{DType, Graph, Shape};
    use crate::exec::kernels::{kernel_for, testutil::fd_check};

    #[test]
    fn grad_mse() {
        fd_check(OpKind::MseLoss, &[(&[2, 3], DType::F32), (&[2, 3], DType::F32)], 1e-2);
    }

    #[test]
    fn grad_cross_entropy() {
        // Loss seeds with the scalar weighting; use a direct FD on the loss.
        let mut g = Graph::new();
        let lab = g.placeholder("lab", Shape::of(&[4]), DType::I32);
        let log = g.placeholder("log", Shape::of(&[4, 3]), DType::F32);
        let id = g.op("ce", OpKind::CrossEntropy { weight: 1.0 }, &[lab, log]).unwrap();
        let node = g.node(id).clone();
        let mut rng = crate::util::Rng::new(3);
        let kernel = kernel_for(&node.kind);
        let labels = Tensor::from_ivec(&[4], vec![0, 2, 1, 1]);
        let logits = Tensor::randn(&[4, 3], 1.0, &mut rng);
        let seed = Tensor::scalar(1.0);
        let mut scratch = Scratch::new();
        let bwd = kernel.vjp(&node, &[&labels, &logits], &[], &seed, &mut scratch).unwrap();
        assert!(bwd.input_grads[0].is_none());
        let analytic = bwd.input_grads[1].as_ref().unwrap();
        const H: f32 = 1e-3;
        for idx in 0..12 {
            let mut p = logits.clone();
            p.f_mut()[idx] += H;
            let mut m = logits.clone();
            m.f_mut()[idx] -= H;
            let fp = kernel.forward(&node, &[&labels, &p], &[], &mut scratch).unwrap().item();
            let fm = kernel.forward(&node, &[&labels, &m], &[], &mut scratch).unwrap().item();
            let fd = (fp - fm) / (2.0 * H);
            assert!((fd - analytic.f()[idx]).abs() < 2e-3, "idx {idx}");
        }
    }

    #[test]
    fn cross_entropy_matches_uniform_bound() {
        // Uniform logits ⇒ loss = ln(C).
        let mut g = Graph::new();
        let lab = g.placeholder("lab", Shape::of(&[2]), DType::I32);
        let log = g.placeholder("log", Shape::of(&[2, 7]), DType::F32);
        let id = g.op("ce", OpKind::CrossEntropy { weight: 1.0 }, &[lab, log]).unwrap();
        let node = g.node(id).clone();
        let kernel = kernel_for(&node.kind);
        let labels = Tensor::from_ivec(&[2], vec![3, 6]);
        let logits = Tensor::zeros(&[2, 7]);
        let mut scratch = Scratch::new();
        let loss =
            kernel.forward(&node, &[&labels, &logits], &[], &mut scratch).unwrap().item();
        assert!((loss - (7.0f32).ln()).abs() < 1e-5);
    }

    #[test]
    fn rejects_two_f32_inputs() {
        let mut g = Graph::new();
        let a = g.placeholder("a", Shape::of(&[2, 3]), DType::F32);
        let b = g.placeholder("b", Shape::of(&[2, 3]), DType::F32);
        // Bypass graph-level dtype checks by building the node directly.
        let id = g.op("mse", OpKind::MseLoss, &[a, b]).unwrap();
        let mut node = g.node(id).clone();
        node.kind = OpKind::CrossEntropy { weight: 1.0 };
        let kernel = kernel_for(&node.kind);
        let x = Tensor::zeros(&[2, 3]);
        let y = Tensor::zeros(&[2, 3]);
        let mut scratch = Scratch::new();
        let err = kernel.forward(&node, &[&x, &y], &[], &mut scratch).unwrap_err();
        assert!(err.to_string().contains("i32 label"));
    }
}
