//! Transformer feed-forward kernel `y = W2·gelu(x·W1 + b1) + b2`.
//! params = [W1, b1, W2, b2].

use anyhow::{bail, Result};

use super::{add_row_bias, sum_rows, OpKernel};
use crate::dag::{Node, OpKind};
use crate::exec::{BackwardOut, Scratch};
use crate::tensor::{
    gelu, gelu_grad, matmul, matmul_at, matmul_bt, matmul_bt_into, matmul_into, Tensor,
};
use crate::util::Rng;

pub struct FeedForwardKernel;

fn unpack(node: &Node) -> Result<(usize, usize)> {
    match node.kind {
        OpKind::FeedForward { dim, hidden } => Ok((dim, hidden)),
        _ => bail!("FeedForwardKernel dispatched on {}", node.kind.name()),
    }
}

impl OpKernel for FeedForwardKernel {
    fn name(&self) -> &'static str {
        "feedforward"
    }

    fn init_params(&self, node: &Node, rng: &mut Rng) -> Result<Vec<Tensor>> {
        let (dim, hidden) = unpack(node)?;
        let s1 = 1.0 / (dim as f32).sqrt();
        let s2 = 1.0 / (hidden as f32).sqrt();
        Ok(vec![
            Tensor::randn(&[dim, hidden], s1, rng),
            Tensor::zeros(&[hidden]),
            Tensor::randn(&[hidden, dim], s2, rng),
            Tensor::zeros(&[dim]),
        ])
    }

    fn forward(
        &self,
        node: &Node,
        inputs: &[&Tensor],
        params: &[Tensor],
        scratch: &mut Scratch,
    ) -> Result<Tensor> {
        let (dim, hidden) = unpack(node)?;
        let x = inputs[0];
        let rows = x.numel() / dim;
        // Hidden pre-activation and activation are intra-call temporaries.
        let mut h = scratch.take(rows * hidden);
        matmul_into(x.f(), params[0].f(), &mut h, rows, dim, hidden);
        add_row_bias(&mut h, hidden, params[1].f());
        let mut a = scratch.take(rows * hidden);
        for (av, &hv) in a.iter_mut().zip(&h) {
            *av = gelu(hv);
        }
        let mut y = matmul(&a, params[2].f(), rows, hidden, dim);
        add_row_bias(&mut y, dim, params[3].f());
        scratch.put(a);
        scratch.put(h);
        Ok(Tensor::from_vec(x.shape(), y))
    }

    fn vjp(
        &self,
        node: &Node,
        inputs: &[&Tensor],
        params: &[Tensor],
        dy: &Tensor,
        scratch: &mut Scratch,
    ) -> Result<BackwardOut> {
        let (dim, hidden) = unpack(node)?;
        let x = inputs[0];
        let rows = x.numel() / dim;
        // Recompute h and a.
        let mut h = scratch.take(rows * hidden);
        matmul_into(x.f(), params[0].f(), &mut h, rows, dim, hidden);
        add_row_bias(&mut h, hidden, params[1].f());
        let mut a = scratch.take(rows * hidden);
        for (av, &hv) in a.iter_mut().zip(&h) {
            *av = gelu(hv);
        }
        // y = a·W2 + b2
        let mut da = scratch.take(rows * hidden);
        matmul_bt_into(dy.f(), params[2].f(), &mut da, rows, dim, hidden);
        let dw2 = matmul_at(&a, dy.f(), hidden, rows, dim);
        let db2 = sum_rows(dy.f(), dim);
        // a = gelu(h): overwrite da in place with dh = da ∘ gelu'(h).
        let mut dh = da;
        for (g, &hv) in dh.iter_mut().zip(&h) {
            *g *= gelu_grad(hv);
        }
        // h = x·W1 + b1
        let dx = matmul_bt(&dh, params[0].f(), rows, hidden, dim);
        let dw1 = matmul_at(x.f(), &dh, dim, rows, hidden);
        let db1 = sum_rows(&dh, hidden);
        scratch.put(dh);
        scratch.put(a);
        scratch.put(h);
        Ok(BackwardOut {
            input_grads: vec![Some(Tensor::from_vec(x.shape(), dx))],
            param_grads: vec![
                Tensor::from_vec(&[dim, hidden], dw1),
                Tensor::from_vec(&[hidden], db1),
                Tensor::from_vec(&[hidden, dim], dw2),
                Tensor::from_vec(&[dim], db2),
            ],
        })
    }
}

#[cfg(test)]
mod tests {
    use crate::dag::{DType, OpKind};
    use crate::exec::kernels::testutil::fd_check;

    #[test]
    fn grad_ffn() {
        fd_check(OpKind::FeedForward { dim: 6, hidden: 10 }, &[(&[3, 6], DType::F32)], 3e-2);
    }
}
