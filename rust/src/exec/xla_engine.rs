//! The XLA execution-plane backend: runs `StageCall` operators through
//! AOT-compiled PJRT artifacts (the production hot path).
//!
//! Artifact calling conventions (fixed jointly with `python/compile/aot.py`):
//!
//! | artifact          | inputs                                | outputs                          |
//! |-------------------|---------------------------------------|----------------------------------|
//! | `embed_fwd`       | params…, tokens                       | h                                |
//! | `embed_bwd`       | params…, tokens, dh                   | dparams…                         |
//! | `block{i}_fwd`    | params…, h                            | h'                               |
//! | `block{i}_bwd`    | params…, h, dh'                       | dh, dparams…                     |
//! | `head_fwd`        | params…, h, labels                    | loss                             |
//! | `head_bwd`        | params…, h, labels                    | dh, dparams…, loss               |
//! | `{stage}_update`  | params…, grads…, m…, v…, step         | params…, m…, v…                  |
//!
//! Backward artifacts **rematerialize** the forward internally, so the only
//! state a compnode must stash per microbatch is the stage *input* — the
//! "trading memory for computation" design the paper cites for low-memory
//! devices (§2.4).

use anyhow::{anyhow, bail, Result};

use crate::dag::{Node, OpKind};
use crate::exec::{kernels, BackwardOut, Engine, Scratch};
use crate::runtime::{Manifest, Runtime};
use crate::tensor::Tensor;
use crate::util::Rng;

/// Stage role, derived from the stage name.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StageKind {
    Embed,
    Block,
    Head,
}

/// Classify a stage by name (`embed`, `block{i}`, `head`).
pub fn stage_kind(stage: &str) -> Result<StageKind> {
    if stage == "embed" {
        Ok(StageKind::Embed)
    } else if stage == "head" {
        Ok(StageKind::Head)
    } else if stage.starts_with("block") {
        Ok(StageKind::Block)
    } else {
        bail!("unknown stage name '{stage}'")
    }
}

/// XLA-backed engine for coarse `StageCall` graphs.
pub struct XlaEngine {
    runtime: Runtime,
    manifest: Manifest,
    /// Temporaries pool for the host-kernel fallback path.
    scratch: Scratch,
}

impl XlaEngine {
    /// Load all artifacts from `dir` (a preset directory with
    /// `manifest.json`).
    pub fn load(dir: &std::path::Path) -> Result<XlaEngine> {
        let mut runtime = Runtime::cpu()?;
        let manifest = runtime.load_dir(dir)?;
        Ok(XlaEngine { runtime, manifest, scratch: Scratch::new() })
    }

    /// Load only the artifacts belonging to `stage` (what a compnode hosting
    /// a single pipeline stage does).
    pub fn load_stage(dir: &std::path::Path, stage: &str) -> Result<XlaEngine> {
        let mut runtime = Runtime::cpu()?;
        let prefix = format!("{stage}_");
        let manifest = runtime.load_dir_filtered(dir, |name| name.starts_with(&prefix))?;
        Ok(XlaEngine { runtime, manifest, scratch: Scratch::new() })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn runtime(&self) -> &Runtime {
        &self.runtime
    }

    /// Initialize the parameter list of `stage` from the manifest specs.
    pub fn init_stage_params(&self, stage: &str, rng: &mut Rng) -> Result<Vec<Tensor>> {
        let specs = self
            .manifest
            .stage_params
            .get(stage)
            .ok_or_else(|| anyhow!("stage '{stage}' not in manifest"))?;
        Ok(specs.iter().map(|s| s.materialize(rng)).collect())
    }

    /// Forward one stage. `inputs` is `[tokens]` / `[h]` / `[h, labels]`.
    pub fn stage_forward(
        &self,
        stage: &str,
        params: &[Tensor],
        inputs: &[&Tensor],
    ) -> Result<Tensor> {
        let mut args: Vec<Tensor> = params.to_vec();
        args.extend(inputs.iter().map(|t| (*t).clone()));
        let mut out = self.runtime.run(&format!("{stage}_fwd"), &args)?;
        if out.is_empty() {
            bail!("{stage}_fwd produced no outputs");
        }
        Ok(out.remove(0))
    }

    /// Backward one stage. Returns `(dx, dparams, loss)` where `dx` is
    /// `None` for the embed stage and `loss` is `Some` for the head stage.
    pub fn stage_backward(
        &self,
        stage: &str,
        params: &[Tensor],
        inputs: &[&Tensor],
        out_grad: Option<&Tensor>,
    ) -> Result<(Option<Tensor>, Vec<Tensor>, Option<f32>)> {
        let kind = stage_kind(stage)?;
        let mut args: Vec<Tensor> = params.to_vec();
        args.extend(inputs.iter().map(|t| (*t).clone()));
        if let Some(g) = out_grad {
            args.push(g.clone());
        } else if kind != StageKind::Head {
            bail!("stage '{stage}' backward requires an upstream gradient");
        }
        let mut out = self.runtime.run(&format!("{stage}_bwd"), &args)?;
        let n_params = params.len();
        match kind {
            StageKind::Embed => {
                if out.len() != n_params {
                    bail!("embed_bwd arity {} != params {}", out.len(), n_params);
                }
                Ok((None, out, None))
            }
            StageKind::Block => {
                if out.len() != n_params + 1 {
                    bail!("block bwd arity {} != 1+params {}", out.len(), n_params);
                }
                let dx = out.remove(0);
                Ok((Some(dx), out, None))
            }
            StageKind::Head => {
                if out.len() != n_params + 2 {
                    bail!("head_bwd arity {} != 2+params {}", out.len(), n_params);
                }
                let dx = out.remove(0);
                let loss = out.pop().unwrap().item();
                Ok((Some(dx), out, Some(loss)))
            }
        }
    }

    /// Adam update through the `{stage}_update` artifact. Mutates `params`,
    /// `m`, `v` in place; `step` is 1-based.
    pub fn stage_update(
        &self,
        stage: &str,
        params: &mut Vec<Tensor>,
        grads: &[Tensor],
        m: &mut Vec<Tensor>,
        v: &mut Vec<Tensor>,
        step: i32,
    ) -> Result<()> {
        let n = params.len();
        if grads.len() != n || m.len() != n || v.len() != n {
            bail!("update arity mismatch for stage '{stage}'");
        }
        let mut args: Vec<Tensor> = Vec::with_capacity(3 * n + 1);
        args.extend(params.iter().cloned());
        args.extend(grads.iter().cloned());
        args.extend(m.iter().cloned());
        args.extend(v.iter().cloned());
        args.push(Tensor::from_ivec(&[], vec![step]));
        let mut out = self.runtime.run(&format!("{stage}_update"), &args)?;
        if out.len() != 3 * n {
            bail!("{stage}_update returned {} outputs, want {}", out.len(), 3 * n);
        }
        let new_v = out.split_off(2 * n);
        let new_m = out.split_off(n);
        *params = out;
        *m = new_m;
        *v = new_v;
        Ok(())
    }
}

/// Device-resident training state of one pipeline stage (hot-path variant).
///
/// Parameters (and Adam moments) live as PJRT buffers that survive across
/// microbatches; only activations/gradients cross the host boundary per
/// call. See EXPERIMENTS.md §Perf for the before/after.
pub struct StageState {
    pub stage: String,
    /// Host copy of the parameters (checkpointing / inspection).
    pub params: Vec<Tensor>,
    /// Host copies of the Adam moments — kept in lockstep with the device
    /// buffers so a v2 recovery checkpoint can snapshot exact optimizer
    /// state without a device read-back.
    pub opt_m: Vec<Tensor>,
    pub opt_v: Vec<Tensor>,
    param_bufs: Vec<xla::PjRtBuffer>,
    m_bufs: Vec<xla::PjRtBuffer>,
    v_bufs: Vec<xla::PjRtBuffer>,
}

impl StageState {
    pub fn n_params(&self) -> usize {
        self.params.len()
    }
}

impl XlaEngine {
    /// Initialize a device-resident stage state.
    pub fn new_stage_state(&self, stage: &str, rng: &mut Rng) -> Result<StageState> {
        let params = self.init_stage_params(stage, rng)?;
        let zeros: Vec<Tensor> = params.iter().map(|p| Tensor::zeros(p.shape())).collect();
        self.stage_state_from_parts(stage, params, zeros.clone(), zeros)
    }

    /// Build a device-resident stage state from explicit host tensors
    /// (restoring from a recovery checkpoint).
    pub fn stage_state_from_parts(
        &self,
        stage: &str,
        params: Vec<Tensor>,
        opt_m: Vec<Tensor>,
        opt_v: Vec<Tensor>,
    ) -> Result<StageState> {
        if opt_m.len() != params.len() || opt_v.len() != params.len() {
            bail!(
                "stage '{stage}' state arity mismatch: {} params, {} m, {} v",
                params.len(),
                opt_m.len(),
                opt_v.len()
            );
        }
        let param_bufs =
            params.iter().map(|p| self.runtime.to_buffer(p)).collect::<Result<Vec<_>>>()?;
        let m_bufs =
            opt_m.iter().map(|t| self.runtime.to_buffer(t)).collect::<Result<Vec<_>>>()?;
        let v_bufs =
            opt_v.iter().map(|t| self.runtime.to_buffer(t)).collect::<Result<Vec<_>>>()?;
        Ok(StageState {
            stage: stage.to_string(),
            params,
            opt_m,
            opt_v,
            param_bufs,
            m_bufs,
            v_bufs,
        })
    }

    /// Forward with cached parameter buffers.
    pub fn forward_cached(&self, st: &StageState, inputs: &[&Tensor]) -> Result<Tensor> {
        let in_bufs: Vec<xla::PjRtBuffer> =
            inputs.iter().map(|t| self.runtime.to_buffer(t)).collect::<Result<_>>()?;
        let mut args: Vec<&xla::PjRtBuffer> = st.param_bufs.iter().collect();
        args.extend(in_bufs.iter());
        let mut out = self.runtime.execute_buffers(&format!("{}_fwd", st.stage), &args)?;
        if out.is_empty() {
            bail!("{}_fwd produced no outputs", st.stage);
        }
        Ok(out.remove(0))
    }

    /// Backward with cached parameter buffers; same contract as
    /// [`Self::stage_backward`].
    pub fn backward_cached(
        &self,
        st: &StageState,
        inputs: &[&Tensor],
        out_grad: Option<&Tensor>,
    ) -> Result<(Option<Tensor>, Vec<Tensor>, Option<f32>)> {
        let kind = stage_kind(&st.stage)?;
        let mut in_bufs: Vec<xla::PjRtBuffer> =
            inputs.iter().map(|t| self.runtime.to_buffer(t)).collect::<Result<_>>()?;
        if let Some(g) = out_grad {
            in_bufs.push(self.runtime.to_buffer(g)?);
        } else if kind != StageKind::Head {
            bail!("stage '{}' backward requires an upstream gradient", st.stage);
        }
        let mut args: Vec<&xla::PjRtBuffer> = st.param_bufs.iter().collect();
        args.extend(in_bufs.iter());
        let mut out = self.runtime.execute_buffers(&format!("{}_bwd", st.stage), &args)?;
        let n = st.params.len();
        match kind {
            StageKind::Embed => {
                if out.len() != n {
                    bail!("embed_bwd arity {} != params {}", out.len(), n);
                }
                Ok((None, out, None))
            }
            StageKind::Block => {
                if out.len() != n + 1 {
                    bail!("block bwd arity {} != 1+params {}", out.len(), n);
                }
                let dx = out.remove(0);
                Ok((Some(dx), out, None))
            }
            StageKind::Head => {
                if out.len() != n + 2 {
                    bail!("head_bwd arity {} != 2+params {}", out.len(), n);
                }
                let dx = out.remove(0);
                let loss = out.pop().unwrap().item();
                Ok((Some(dx), out, Some(loss)))
            }
        }
    }

    /// Adam update keeping params/m/v device-resident: only the gradients
    /// and the step scalar cross the host boundary per step.
    pub fn update_cached(
        &self,
        st: &mut StageState,
        grads: &[Tensor],
        step: i32,
    ) -> Result<()> {
        let n = st.params.len();
        if grads.len() != n {
            bail!("update arity mismatch for stage '{}'", st.stage);
        }
        let grad_bufs: Vec<xla::PjRtBuffer> =
            grads.iter().map(|g| self.runtime.to_buffer(g)).collect::<Result<_>>()?;
        let step_buf = self.runtime.to_buffer(&Tensor::from_ivec(&[], vec![step]))?;
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(4 * n + 1);
        args.extend(st.param_bufs.iter());
        args.extend(grad_bufs.iter());
        args.extend(st.m_bufs.iter());
        args.extend(st.v_bufs.iter());
        args.push(&step_buf);
        let mut out =
            self.runtime.execute_buffers(&format!("{}_update", st.stage), &args)?;
        if out.len() != 3 * n {
            bail!("{}_update returned {} outputs, want {}", st.stage, out.len(), 3 * n);
        }
        let new_v = out.split_off(2 * n);
        let new_m = out.split_off(n);
        st.params = out;
        st.param_bufs =
            st.params.iter().map(|p| self.runtime.to_buffer(p)).collect::<Result<_>>()?;
        st.m_bufs = new_m.iter().map(|t| self.runtime.to_buffer(t)).collect::<Result<_>>()?;
        st.v_bufs = new_v.iter().map(|t| self.runtime.to_buffer(t)).collect::<Result<_>>()?;
        st.opt_m = new_m;
        st.opt_v = new_v;
        Ok(())
    }
}

impl Engine for XlaEngine {
    fn name(&self) -> &'static str {
        "xla"
    }

    fn init_params(&mut self, node: &Node, rng: &mut Rng) -> Result<Vec<Tensor>> {
        match &node.kind {
            OpKind::StageCall { stage, .. } => self.init_stage_params(stage, rng),
            other => kernels::kernel_for(other).init_params(node, rng),
        }
    }

    fn forward(&mut self, node: &Node, inputs: &[&Tensor], params: &[Tensor]) -> Result<Tensor> {
        match &node.kind {
            OpKind::StageCall { stage, .. } => self.stage_forward(stage, params, inputs),
            // Non-StageCall ops are not compiled into artifacts; run them on
            // the shared host kernels instead of refusing outright.
            other => kernels::kernel_for(other).forward(node, inputs, params, &mut self.scratch),
        }
    }

    fn backward(
        &mut self,
        node: &Node,
        inputs: &[&Tensor],
        params: &[Tensor],
        out_grad: Option<&Tensor>,
    ) -> Result<BackwardOut> {
        match &node.kind {
            OpKind::StageCall { stage, .. } => {
                let (dx, dparams, _loss) =
                    self.stage_backward(stage, params, inputs, out_grad)?;
                let mut input_grads: Vec<Option<Tensor>> = vec![dx];
                // Extra args (labels on the head stage) get no gradient.
                while input_grads.len() < node.args.len() {
                    input_grads.push(None);
                }
                Ok(BackwardOut { input_grads, param_grads: dparams })
            }
            other => {
                let seeded = Tensor::scalar(1.0);
                let dy = out_grad.unwrap_or(&seeded);
                kernels::kernel_for(other).vjp(node, inputs, params, dy, &mut self.scratch)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_kind_classification() {
        assert_eq!(stage_kind("embed").unwrap(), StageKind::Embed);
        assert_eq!(stage_kind("block0").unwrap(), StageKind::Block);
        assert_eq!(stage_kind("block11").unwrap(), StageKind::Block);
        assert_eq!(stage_kind("head").unwrap(), StageKind::Head);
        assert!(stage_kind("decoder").is_err());
    }
}
