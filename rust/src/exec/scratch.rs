//! Size-bucketed scratch-buffer pool for kernel temporaries.
//!
//! Per-step kernel temporaries (attention score matrices, FFN hidden
//! activations, im2col patch buffers, softmax probabilities) used to be
//! freshly allocated `Vec<f32>`s on every forward/vjp call. A [`Scratch`]
//! pool owned by the engine recycles them across calls: kernels `take` a
//! buffer of the length they need and `put` it back before returning.
//!
//! Determinism contract (DESIGN.md §Perf): `take` always returns a
//! **zero-filled** buffer of exactly the requested length, so a recycled
//! buffer is indistinguishable from `vec![0.0; len]` and pool reuse can
//! never change numerics. Buffers that escape a kernel as output tensors
//! must NOT come from the pool — only intra-call temporaries do.

use std::collections::BTreeMap;

/// Keep at most this many f32s parked in the pool (16 MiB). Oversized
/// returns are dropped instead of parked so one huge conv doesn't pin
/// memory for the rest of training.
const DEFAULT_CAP_FLOATS: usize = 4 << 20;

/// A size-bucketed pool of reusable `Vec<f32>` temporaries.
#[derive(Debug)]
pub struct Scratch {
    /// Free buffers keyed by capacity; each bucket is a LIFO stack.
    free: BTreeMap<usize, Vec<Vec<f32>>>,
    /// Total f32 capacity currently parked in `free`.
    held: usize,
    /// Park limit in f32s.
    cap: usize,
    hits: u64,
    misses: u64,
}

impl Default for Scratch {
    fn default() -> Self {
        Scratch::new()
    }
}

impl Scratch {
    pub fn new() -> Scratch {
        Scratch { free: BTreeMap::new(), held: 0, cap: DEFAULT_CAP_FLOATS, hits: 0, misses: 0 }
    }

    /// Pool with a custom park limit (tests).
    pub fn with_capacity_limit(cap_floats: usize) -> Scratch {
        Scratch { cap: cap_floats, ..Scratch::new() }
    }

    /// Take a zero-filled buffer of exactly `len` elements, reusing the
    /// smallest parked buffer whose capacity fits.
    pub fn take(&mut self, len: usize) -> Vec<f32> {
        // Smallest-fit: first bucket at or above len.
        let bucket = self.free.range(len..).next().map(|(&cap, _)| cap);
        if let Some(cap) = bucket {
            let stack = self.free.get_mut(&cap).expect("bucket exists");
            let mut buf = stack.pop().expect("non-empty bucket");
            if stack.is_empty() {
                self.free.remove(&cap);
            }
            self.held -= buf.capacity();
            self.hits += 1;
            buf.clear();
            buf.resize(len, 0.0);
            buf
        } else {
            self.misses += 1;
            // Round up so nearby sizes land in the same bucket on return.
            let cap = len.next_power_of_two().max(1);
            let mut buf = Vec::with_capacity(cap);
            buf.resize(len, 0.0);
            buf
        }
    }

    /// Return a buffer to the pool. Dropped (not parked) if parking it
    /// would exceed the capacity limit.
    pub fn put(&mut self, buf: Vec<f32>) {
        let cap = buf.capacity();
        if cap == 0 || self.held + cap > self.cap {
            return;
        }
        self.held += cap;
        self.free.entry(cap).or_default().push(buf);
    }

    /// Pool hits since construction (take satisfied from a parked buffer).
    pub fn hits(&self) -> u64 {
        self.hits
    }

    /// Pool misses since construction (take had to allocate).
    pub fn misses(&self) -> u64 {
        self.misses
    }

    /// Total f32 capacity currently parked.
    pub fn held_floats(&self) -> usize {
        self.held
    }

    /// Drop all parked buffers (stats are kept).
    pub fn clear(&mut self) {
        self.free.clear();
        self.held = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_is_zero_filled_after_reuse() {
        let mut s = Scratch::new();
        let mut a = s.take(100);
        a.iter_mut().for_each(|x| *x = 7.0);
        s.put(a);
        let b = s.take(64);
        assert_eq!(b.len(), 64);
        assert!(b.iter().all(|&x| x == 0.0), "recycled buffer must be zeroed");
        assert_eq!(s.hits(), 1);
        assert_eq!(s.misses(), 1);
    }

    #[test]
    fn smallest_fit_bucket_is_chosen() {
        let mut s = Scratch::new();
        let small = s.take(10); // cap 16
        let big = s.take(1000); // cap 1024
        s.put(big);
        s.put(small);
        let got = s.take(12);
        assert_eq!(got.capacity(), 16, "should reuse the 16-cap buffer, not the 1024");
        assert_eq!(s.hits(), 1);
    }

    #[test]
    fn capacity_limit_drops_oversized_returns() {
        let mut s = Scratch::with_capacity_limit(100);
        let buf = s.take(1000);
        s.put(buf);
        assert_eq!(s.held_floats(), 0, "over-limit buffer must be dropped");
        let small = s.take(10);
        s.put(small);
        assert!(s.held_floats() > 0);
        s.clear();
        assert_eq!(s.held_floats(), 0);
    }

    #[test]
    fn zero_len_take_works() {
        let mut s = Scratch::new();
        let b = s.take(0);
        assert!(b.is_empty());
        s.put(b);
    }
}
