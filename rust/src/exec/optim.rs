//! Optimizers for the Update task (paper §3.6).
//!
//! "To support adaptive optimizers for different parametric OPs, users can
//! define optimizers and corresponding hyperparameters in the configuration
//! file. The broker assigns the appropriate optimizers to the target
//! compnode based on its assigned OPs."
//!
//! SGD (+momentum) and Adam are provided; both operate on per-node parameter
//! lists so each compnode updates exactly the parameters it hosts.

use crate::tensor::Tensor;

/// A stateful optimizer over one parameter list.
pub trait Optimizer: Send {
    /// Apply one update step given gradients aligned with `params`.
    fn step(&mut self, params: &mut [Tensor], grads: &[Tensor]);
    /// Name for config/logging.
    fn name(&self) -> &'static str;
}

/// SGD with optional momentum.
#[derive(Debug, Clone)]
pub struct Sgd {
    pub lr: f32,
    pub momentum: f32,
    velocity: Vec<Tensor>,
}

impl Sgd {
    pub fn new(lr: f32) -> Sgd {
        Sgd { lr, momentum: 0.0, velocity: vec![] }
    }
    pub fn with_momentum(lr: f32, momentum: f32) -> Sgd {
        Sgd { lr, momentum, velocity: vec![] }
    }
}

impl Optimizer for Sgd {
    #[allow(clippy::float_cmp)] // momentum == 0.0 selects the no-velocity path exactly
    fn step(&mut self, params: &mut [Tensor], grads: &[Tensor]) {
        assert_eq!(params.len(), grads.len());
        if self.momentum == 0.0 {
            for (p, g) in params.iter_mut().zip(grads) {
                p.axpy(-self.lr, g);
            }
            return;
        }
        if self.velocity.is_empty() {
            self.velocity = params.iter().map(|p| Tensor::zeros(p.shape())).collect();
        }
        for ((p, g), v) in params.iter_mut().zip(grads).zip(&mut self.velocity) {
            // v = momentum·v + g ; p -= lr·v
            for (vv, gg) in v.f_mut().iter_mut().zip(g.f()) {
                *vv = self.momentum * *vv + gg;
            }
            p.axpy(-self.lr, v);
        }
    }
    fn name(&self) -> &'static str {
        "sgd"
    }
}

/// Adam (Kingma & Ba) with bias correction — mirrors the L2 `adam_update`
/// artifact so RefEngine and XlaEngine training trajectories are comparable.
#[derive(Debug, Clone)]
pub struct Adam {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    t: i32,
    m: Vec<Tensor>,
    v: Vec<Tensor>,
}

impl Adam {
    pub fn new(lr: f32) -> Adam {
        Adam { lr, beta1: 0.9, beta2: 0.999, eps: 1e-8, t: 0, m: vec![], v: vec![] }
    }
}

impl Optimizer for Adam {
    fn step(&mut self, params: &mut [Tensor], grads: &[Tensor]) {
        assert_eq!(params.len(), grads.len());
        if self.m.is_empty() {
            self.m = params.iter().map(|p| Tensor::zeros(p.shape())).collect();
            self.v = params.iter().map(|p| Tensor::zeros(p.shape())).collect();
        }
        self.t += 1;
        let b1t = 1.0 - self.beta1.powi(self.t);
        let b2t = 1.0 - self.beta2.powi(self.t);
        for (((p, g), m), v) in params.iter_mut().zip(grads).zip(&mut self.m).zip(&mut self.v) {
            let pf = p.f_mut();
            let gf = g.f();
            let mf = m.f_mut();
            let vf = v.f_mut();
            for i in 0..pf.len() {
                mf[i] = self.beta1 * mf[i] + (1.0 - self.beta1) * gf[i];
                vf[i] = self.beta2 * vf[i] + (1.0 - self.beta2) * gf[i] * gf[i];
                let mhat = mf[i] / b1t;
                let vhat = vf[i] / b2t;
                pf[i] -= self.lr * mhat / (vhat.sqrt() + self.eps);
            }
        }
    }
    fn name(&self) -> &'static str {
        "adam"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Minimize f(p) = ||p - target||² and check convergence.
    fn quadratic_descent(opt: &mut dyn Optimizer, steps: usize) -> f32 {
        let target = [1.0f32, -2.0, 3.0];
        let mut params = vec![Tensor::from_vec(&[3], vec![0.0, 0.0, 0.0])];
        for _ in 0..steps {
            let g: Vec<f32> =
                params[0].f().iter().zip(&target).map(|(&p, &t)| 2.0 * (p - t)).collect();
            let grads = vec![Tensor::from_vec(&[3], g)];
            opt.step(&mut params, &grads);
        }
        params[0].f().iter().zip(&target).map(|(&p, &t)| (p - t).abs()).fold(0.0, f32::max)
    }

    #[test]
    fn sgd_converges_on_quadratic() {
        let mut opt = Sgd::new(0.1);
        assert!(quadratic_descent(&mut opt, 200) < 1e-4);
    }

    #[test]
    fn momentum_accelerates() {
        let mut plain = Sgd::new(0.02);
        let mut mom = Sgd::with_momentum(0.02, 0.9);
        let e_plain = quadratic_descent(&mut plain, 50);
        let e_mom = quadratic_descent(&mut mom, 50);
        assert!(e_mom < e_plain, "momentum {e_mom} vs plain {e_plain}");
    }

    #[test]
    fn adam_converges_on_quadratic() {
        let mut opt = Adam::new(0.1);
        assert!(quadratic_descent(&mut opt, 300) < 1e-3);
    }

    #[test]
    fn adam_first_step_is_lr_sized() {
        // With bias correction, the first Adam step ≈ lr in each coordinate.
        let mut opt = Adam::new(0.1);
        let mut params = vec![Tensor::from_vec(&[2], vec![0.0, 0.0])];
        let grads = vec![Tensor::from_vec(&[2], vec![5.0, -0.3])];
        opt.step(&mut params, &grads);
        for (&p, &g) in params[0].f().iter().zip(grads[0].f()) {
            assert!((p.abs() - 0.1).abs() < 1e-3);
            assert!(p.signum() == -g.signum());
        }
    }
}
