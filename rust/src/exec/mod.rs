//! The execution plane (paper §3.1, goals P3/P4).
//!
//! "The execution plane is responsible for designing and implementing
//! general interfaces to adapt different ML Engines to execute DAGs. Thus,
//! the compnodes can utilize devices and DL frameworks according to their
//! preference."
//!
//! [`Engine`] is that general interface: a backend that can initialize
//! parameters, run one operator's forward, and run its backward
//! (vector-Jacobian product). The per-operator numerics live in the
//! [`kernels`] registry — one [`kernels::OpKernel`] per op family — and
//! both engines dispatch through it. Two engines ship in-tree:
//!
//! * [`RefEngine`] — pure-rust f32 interpreter of every IR operator; used by
//!   the simulator, the quickstart and as the numerics oracle;
//! * [`XlaEngine`](crate::exec::xla_engine::XlaEngine) — executes
//!   AOT-compiled HLO artifacts through PJRT (the production hot path for
//!   `StageCall` graphs), falling back to the host kernels for any
//!   non-`StageCall` op.

pub mod executor;
pub mod kernels;
pub mod optim;
pub mod plan;
pub mod ref_engine;
pub mod scratch;
pub mod xla_engine;

pub use executor::{set_wave_threads, wave_threads, BwdJob, WaveRunner, WAVE_PAR_MIN_FLOPS};
pub use kernels::{kernel_for, OpKernel};
pub use optim::{Adam, Optimizer, Sgd};
pub use plan::ExecPlan;
pub use ref_engine::RefEngine;
pub use scratch::Scratch;

use crate::dag::Node;
use crate::tensor::Tensor;
use crate::util::Rng;

/// Result of one backward task.
#[derive(Debug)]
pub struct BackwardOut {
    /// Gradient wrt each forward arg (aligned with `node.args`; `None`
    /// where no gradient flows, e.g. integer labels).
    pub input_grads: Vec<Option<Tensor>>,
    /// Gradient wrt each parameter (aligned with the node's param list;
    /// empty for non-parametric ops).
    pub param_grads: Vec<Tensor>,
}

/// A pluggable ML engine (the execution plane's "general interface").
///
/// Deliberately not `Send`: PJRT handles are thread-local, so every
/// compnode thread constructs its own engine (see `cluster::train`).
pub trait Engine {
    /// Backend name, for logs and the compnode registry.
    fn name(&self) -> &'static str;

    /// Initialize the node's parameter list (empty for non-parametric ops).
    fn init_params(&mut self, node: &Node, rng: &mut Rng) -> crate::Result<Vec<Tensor>>;

    /// Forward: `inputs` aligned with `node.args`. Returns the output.
    fn forward(
        &mut self,
        node: &Node,
        inputs: &[&Tensor],
        params: &[Tensor],
    ) -> crate::Result<Tensor>;

    /// Backward (rematerializing: recomputes whatever forward intermediates
    /// it needs from `inputs`). `out_grad = None` seeds a loss node with
    /// dL/dL = 1.
    fn backward(
        &mut self,
        node: &Node,
        inputs: &[&Tensor],
        params: &[Tensor],
        out_grad: Option<&Tensor>,
    ) -> crate::Result<BackwardOut>;

    /// True when this engine's numerics are pure dispatches into the
    /// stateless kernel registry with scratch as the only state. The
    /// wavefront executor may then run a wave's nodes on worker threads
    /// with per-thread scratch pools — bitwise identical, because each
    /// node's computation is the exact same kernel call either way.
    /// Engines with thread-affine state (PJRT handles) keep the default.
    fn registry_backed(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dag::{DType, Graph, OpKind, Shape};

    /// The trait must be object-safe (compnodes hold `Box<dyn Engine>`).
    #[test]
    fn engine_is_object_safe() {
        let mut e: Box<dyn Engine> = Box::new(RefEngine::new());
        assert_eq!(e.name(), "ref");
        let mut g = Graph::new();
        let x = g.placeholder("x", Shape::of(&[2, 4]), DType::F32);
        let id = g
            .op("fc", OpKind::Linear { in_features: 4, out_features: 3, bias: true }, &[x])
            .unwrap();
        let mut rng = Rng::new(0);
        let params = e.init_params(g.node(id), &mut rng).unwrap();
        assert_eq!(params.len(), 2);
    }
}
