//! Compile-then-execute: a [`Graph`] (or one compnode's share of it) is
//! compiled **once** into an [`ExecPlan`] and the plan is cached for every
//! subsequent step.
//!
//! The plan carries everything the per-step sweeps used to rediscover node
//! by node:
//!
//! * **Waves** — the topological levels of the (sub-)DAG: every node in a
//!   wave depends only on earlier waves (or on data fed from outside the
//!   set), so the nodes of one wave are mutually independent and may run on
//!   worker threads (`exec::executor`).
//! * **Per-tensor refcounts** — `fwd_uses` (forward consumers inside the
//!   set, from [`Liveness`]) and `stash_uses` (backward tasks reading the
//!   activation as a VJP input). When a count hits zero the tensor is dead
//!   and its buffer returns to the scratch pool instead of living to the
//!   end of the step.
//! * **Keep sets** — nodes whose activation must survive the forward sweep
//!   (losses, sinks, outputs messaged to other compnodes, backward
//!   stashes) or the whole step (`keep_always`: losses and sinks, which
//!   remain queryable via `activation()`).
//! * **FLOP totals per wave** — the threshold gate for the thread fan-out,
//!   mirroring the GEMM-level `GEMM_PAR_MIN_FLOPS` opt-in from the tensor
//!   layer.

use crate::dag::autodiff::BackwardPlan;
use crate::dag::{flops, Graph, Liveness, NodeId, OpCategory};

/// A compiled execution plan for one set of nodes of a graph.
#[derive(Debug, Clone)]
pub struct ExecPlan {
    /// Executed nodes in topological order (the serial oracle order; the
    /// concatenation of `waves` equals this, level-major).
    pub order: Vec<NodeId>,
    /// Membership of the executed set, indexed by `NodeId`.
    pub mine: Vec<bool>,
    /// Forward wavefront: topological levels partitioning `order`.
    pub waves: Vec<Vec<NodeId>>,
    /// Total forward FLOPs per wave (thread fan-out gate).
    pub wave_flops: Vec<f64>,
    /// In-set forward consumers per node (liveness refcount seed).
    pub fwd_uses: Vec<u32>,
    /// Activations that must survive the forward sweep.
    pub keep_after_fp: Vec<bool>,
    /// Activations kept for the whole step (losses, sinks).
    pub keep_always: Vec<bool>,
    /// In-set backward tasks in global backward-plan order.
    pub bwd_order: Vec<NodeId>,
    /// Backward wavefront over `bwd_order` (levels of the reversed DAG
    /// restricted to in-set gradient flow).
    pub bwd_waves: Vec<Vec<NodeId>>,
    /// Total backward FLOPs per backward wave.
    pub bwd_wave_flops: Vec<f64>,
    /// Global backward-plan position per forward node (`usize::MAX` when
    /// not participating) — the key that orders gradient folds.
    pub bwd_pos: Vec<usize>,
    /// In-set backward tasks reading each activation as a VJP input.
    pub stash_uses: Vec<u32>,
}

impl ExecPlan {
    /// Compile the whole graph as one executed set.
    pub fn compile_full(g: &Graph, bwd: &BackwardPlan) -> crate::Result<ExecPlan> {
        let all = vec![true; g.len()];
        ExecPlan::compile(g, &all, bwd)
    }

    /// Compile the plan for the nodes with `in_set[id] == true` (one
    /// compnode's sub-DAG). `bwd` is the *global* backward plan of `g`.
    pub fn compile(g: &Graph, in_set: &[bool], bwd: &BackwardPlan) -> crate::Result<ExecPlan> {
        let n = g.len();
        let lv = Liveness::analyze_subset(g, in_set)?;
        let order = lv.order;
        let fwd_uses = lv.use_count;

        // Forward waves: level(n) = 1 + max(level of in-set args); data from
        // outside the set is available before the sweep starts (level -1).
        let mut level = vec![0usize; n];
        let mut n_waves = 0usize;
        for &id in &order {
            let l = g
                .node(id)
                .args
                .iter()
                .filter(|&&a| in_set[a])
                .map(|&a| level[a] + 1)
                .max()
                .unwrap_or(0);
            level[id] = l;
            n_waves = n_waves.max(l + 1);
        }
        let mut waves: Vec<Vec<NodeId>> = vec![Vec::new(); n_waves];
        let mut wave_flops = vec![0.0f64; n_waves];
        for &id in &order {
            waves[level[id]].push(id);
            wave_flops[level[id]] += flops::fwd_flops(g.node(id));
        }

        // Backward: tasks owned here, in global plan order.
        let bwd_pos = bwd.positions();
        let bwd_order: Vec<NodeId> =
            bwd.order.iter().copied().filter(|&id| in_set[id]).collect();
        // stash_uses: every in-set task re-reads its node's args in the VJP.
        let mut stash_uses = vec![0u32; n];
        for &id in &bwd_order {
            for &a in &g.node(id).args {
                stash_uses[a] += 1;
            }
        }
        // Backward waves: a task depends on the tasks of its in-set grad
        // sources (the users supplying its upstream gradient); gradients
        // from other compnodes arrive before the sweep starts.
        let mut blevel = vec![0usize; n];
        let mut n_bwaves = 0usize;
        for &id in &bwd_order {
            let task = bwd.task(id).expect("bwd_order holds participating nodes");
            let l = task
                .grad_sources
                .iter()
                .filter(|&&s| in_set[s])
                .map(|&s| blevel[s] + 1)
                .max()
                .unwrap_or(0);
            blevel[id] = l;
            n_bwaves = n_bwaves.max(l + 1);
        }
        let mut bwd_waves: Vec<Vec<NodeId>> = vec![Vec::new(); n_bwaves];
        let mut bwd_wave_flops = vec![0.0f64; n_bwaves];
        for &id in &bwd_order {
            bwd_waves[blevel[id]].push(id);
            bwd_wave_flops[blevel[id]] += flops::bwd_flops(g.node(id));
        }

        // Keep sets.
        let mut keep_after_fp = vec![false; n];
        let mut keep_always = vec![false; n];
        for id in 0..n {
            if stash_uses[id] > 0 {
                keep_after_fp[id] = true; // backward re-reads the stash
            }
            if !in_set[id] {
                continue;
            }
            let is_loss = g.node(id).kind.category() == OpCategory::Loss;
            let is_sink = g.users(id).is_empty();
            if is_loss || is_sink {
                // Queryable via activation() for the whole step.
                keep_after_fp[id] = true;
                keep_always[id] = true;
            }
            if g.users(id).iter().any(|&u| !in_set[u]) {
                keep_after_fp[id] = true; // messaged to another compnode
            }
        }

        let plan = ExecPlan {
            order,
            mine: in_set.to_vec(),
            waves,
            wave_flops,
            fwd_uses,
            keep_after_fp,
            keep_always,
            bwd_order,
            bwd_waves,
            bwd_wave_flops,
            bwd_pos,
            stash_uses,
        };

        // Self-verification: prove the plan race- and use-after-free-free
        // before anything caches it. Always on in debug builds, opt-in for
        // release via FUSIONAI_VERIFY=1 (see `crate::verify`).
        if crate::verify::verify_enabled() {
            let report = crate::verify::check_plan(g, bwd, &plan);
            if report.has_errors() {
                anyhow::bail!("ExecPlan verification failed:\n{}", report.render());
            }
        }

        Ok(plan)
    }

    /// Widest forward wave (how much node-level parallelism exists).
    pub fn max_wave_width(&self) -> usize {
        self.waves.iter().map(Vec::len).max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dag::autodiff::backward_plan;
    use crate::dag::{DType, OpKind, Shape};
    use crate::models::fig3;

    fn check_wave_invariants(g: &Graph, plan: &ExecPlan) {
        // Concatenated waves are a permutation of `order` respecting deps.
        let flat: Vec<NodeId> = plan.waves.iter().flatten().copied().collect();
        assert_eq!(flat.len(), plan.order.len());
        let mut wave_of = vec![usize::MAX; g.len()];
        for (wi, wave) in plan.waves.iter().enumerate() {
            for &id in wave {
                wave_of[id] = wi;
            }
        }
        for &id in &plan.order {
            for &a in &g.node(id).args {
                if plan.mine[a] {
                    assert!(
                        wave_of[a] < wave_of[id],
                        "arg {a} of {id} must sit in an earlier wave"
                    );
                }
            }
        }
    }

    #[test]
    fn fig3_full_plan_waves_are_valid_and_parallel() {
        let g = fig3::build();
        let plan = ExecPlan::compile_full(&g, &backward_plan(&g)).unwrap();
        check_wave_invariants(&g, &plan);
        // Fig. 3 has a diamond (Add → {Pool, Multiply}): width ≥ 2.
        assert!(plan.max_wave_width() >= 2, "waves: {:?}", plan.waves);
        // Backward also has a wave with Pool's and Multiply's tasks together.
        let pool = g.by_name("Pool").unwrap().id;
        let mult = g.by_name("Multiply").unwrap().id;
        let bw = |id| {
            plan.bwd_waves
                .iter()
                .position(|w| w.contains(&id))
                .expect("participates")
        };
        assert_eq!(bw(pool), bw(mult));
    }

    #[test]
    fn fig3_keep_sets_cover_stash_loss_and_cut_edges() {
        let g = fig3::build();
        let mut in_set = vec![false; g.len()];
        for (id, sub) in fig3::paper_partition(&g) {
            in_set[id] = sub == 1;
        }
        let plan = ExecPlan::compile(&g, &in_set, &backward_plan(&g)).unwrap();
        check_wave_invariants(&g, &plan);
        // Sub 1 owns Input/Conv/Add/Pool; Add and Pool cross to subs 2/3.
        let add = g.by_name("Add").unwrap().id;
        let pool = g.by_name("Pool").unwrap().id;
        assert!(plan.keep_after_fp[add]);
        assert!(plan.keep_after_fp[pool]);
        // Conv's output is re-read by Add's local VJP: stash.
        let conv = g.by_name("Conv").unwrap().id;
        assert!(plan.stash_uses[conv] > 0);
        assert!(plan.keep_after_fp[conv]);
        // The loss lives on sub 3, not here.
        let ce = g.by_name("CrossEntropy").unwrap().id;
        assert!(!plan.mine[ce]);
        assert!(plan.bwd_order.iter().all(|&id| plan.mine[id]));
    }

    #[test]
    fn chain_graph_has_singleton_waves_and_frees_everything_mid_chain() {
        let mut g = Graph::new();
        let mut prev = g.placeholder("x", Shape::of(&[2, 8]), DType::F32);
        for i in 0..5 {
            prev = g.op(&format!("r{i}"), OpKind::Relu, &[prev]).unwrap();
        }
        let plan = ExecPlan::compile_full(&g, &backward_plan(&g)).unwrap();
        assert_eq!(plan.max_wave_width(), 1);
        assert_eq!(plan.waves.len(), 6);
        // Inference chain (no loss): only the sink survives the sweep.
        let kept: Vec<&str> = (0..g.len())
            .filter(|&i| plan.keep_after_fp[i])
            .map(|i| g.node(i).name.as_str())
            .collect();
        assert_eq!(kept, vec!["r4"]);
    }
}
