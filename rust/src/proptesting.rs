//! Minimal property-testing harness (proptest is not in the offline crate
//! set). Runs a property over many seeded random cases; on failure it
//! reports the first failing seed so the case is reproducible, then panics.
//!
//! Used by `rust/tests/prop_invariants.rs` for scheduler / DAG / DHT /
//! compression invariants.

use crate::util::Rng;

/// Case generator handed to properties.
pub struct Gen {
    pub rng: Rng,
    pub seed: u64,
}

impl Gen {
    /// Integer in `[lo, hi)`.
    pub fn int(&mut self, lo: i64, hi: i64) -> i64 {
        self.rng.range(lo, hi)
    }
    /// usize in `[lo, hi)`.
    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.range(lo as i64, hi as i64) as usize
    }
    /// Uniform f64 in `[lo, hi)`.
    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.uniform(lo, hi)
    }
    /// f32 vector with entries ~N(0, scale).
    pub fn vec_f32(&mut self, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| self.rng.normal() as f32 * scale).collect()
    }
    /// Vector of usizes each in `[lo, hi)`.
    pub fn vec_usize(&mut self, n: usize, lo: usize, hi: usize) -> Vec<usize> {
        (0..n).map(|_| self.usize(lo, hi)).collect()
    }
    /// Bernoulli.
    pub fn bool(&mut self, p: f64) -> bool {
        self.rng.chance(p)
    }
    /// Pick one element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        self.rng.choose(xs)
    }
}

/// Run `prop` over `cases` generated cases. The property returns
/// `Err(description)` (or panics) to signal failure.
pub fn check(name: &str, cases: u64, mut prop: impl FnMut(&mut Gen) -> Result<(), String>) {
    // A fixed base seed keeps CI deterministic; vary per-case.
    const BASE: u64 = 0xF05100AD;
    for case in 0..cases {
        let seed = BASE.wrapping_add(case.wrapping_mul(0x9E3779B97F4A7C15));
        let mut g = Gen { rng: Rng::new(seed), seed };
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut g)));
        match outcome {
            Ok(Ok(())) => {}
            Ok(Err(msg)) => {
                panic!("property '{name}' failed on case {case} (seed {seed:#x}): {msg}")
            }
            Err(payload) => {
                let msg = payload
                    .downcast_ref::<String>()
                    .cloned()
                    .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                    .unwrap_or_else(|| "panic".into());
                panic!("property '{name}' panicked on case {case} (seed {seed:#x}): {msg}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        check("tautology", 50, |g| {
            n += 1;
            let x = g.int(0, 100);
            if (0..100).contains(&x) {
                Ok(())
            } else {
                Err(format!("{x} out of range"))
            }
        });
        assert_eq!(n, 50);
    }

    #[test]
    #[should_panic(expected = "always-false")]
    fn failing_property_reports() {
        check("always-false", 10, |_| Err("always-false".into()));
    }

    #[test]
    #[should_panic(expected = "panicked")]
    fn panicking_property_reports() {
        check("panics", 5, |g| {
            let v = g.vec_f32(3, 1.0);
            assert!(v.len() == 4, "deliberate");
            Ok(())
        });
    }

    #[test]
    fn generators_are_deterministic_per_seed() {
        let mut first: Vec<i64> = vec![];
        check("gen-a", 3, |g| {
            first.push(g.int(0, 1000));
            Ok(())
        });
        let mut second: Vec<i64> = vec![];
        check("gen-b", 3, |g| {
            second.push(g.int(0, 1000));
            Ok(())
        });
        assert_eq!(first, second, "same base seed ⇒ same cases");
    }
}
