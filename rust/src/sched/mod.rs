//! Task scheduling (paper §3.8, Eq. 2):
//!
//! ```text
//!   min_A  max_{p∈P}  Σ_{k∈A_p} T(G_Sk)
//!   s.t.   D_gpu^p  ≥ Σ_{k∈A_p} D_gpu(G_Sk)
//!          D_cpu^p  ≥ Σ_{k∈A_p} D_cpu(G_Sk)
//!          D_disk^p ≥ Σ_{k∈A_p} D_disk(G_Sk)
//! ```
//!
//! Makespan minimization with per-peer memory capacities. The problem is
//! NP-hard (multiprocessor scheduling); we implement the classical
//! **LPT greedy** (longest processing time first onto the least-loaded
//! feasible peer) followed by a **move/swap local search**, plus baseline
//! strategies (random, round-robin) used by the ablation bench. Peers are
//! heterogeneous: a task's processing time on peer `p` is
//! `flops / achieved_flops(p)` (paper §3.7).

use crate::perf::paleo::DeviceProfile;
use crate::util::Rng;

/// Resource demands + compute weight of one task (sub-DAG `G_Sk`).
#[derive(Debug, Clone)]
pub struct TaskSpec {
    pub id: usize,
    /// Forward (or fwd+bwd) FLOPs of the sub-DAG.
    pub flops: f64,
    pub gpu_bytes: u64,
    pub cpu_bytes: u64,
    pub disk_bytes: u64,
}

/// One candidate peer with capacities (paper §3.3: `D_gpu`, `D_cpu`,
/// `D_disk`) and an achieved-speed profile.
#[derive(Debug, Clone)]
pub struct PeerSpec {
    pub id: usize,
    pub profile: DeviceProfile,
    pub gpu_capacity: u64,
    pub cpu_capacity: u64,
    pub disk_capacity: u64,
}

impl PeerSpec {
    /// Time for `task` on this peer: `C = FLOPs / S(p)`.
    pub fn task_time(&self, task: &TaskSpec) -> f64 {
        task.flops / self.profile.achieved_flops()
    }
}

/// The result: which tasks run where.
#[derive(Debug, Clone)]
pub struct Schedule {
    /// task id → peer index (into the peers slice used to build it).
    pub of_task: Vec<usize>,
    /// Per-peer total time (the objective terms).
    pub loads: Vec<f64>,
    /// Per-peer residual memory after assignment.
    pub gpu_used: Vec<u64>,
    pub cpu_used: Vec<u64>,
    pub disk_used: Vec<u64>,
}

impl Schedule {
    /// The Eq.-2 objective.
    pub fn makespan(&self) -> f64 {
        self.loads.iter().copied().fold(0.0, f64::max)
    }

    /// Check all constraints of Eq. 2 hold (property tests use this).
    pub fn validate(&self, tasks: &[TaskSpec], peers: &[PeerSpec]) -> Result<(), String> {
        if self.of_task.len() != tasks.len() {
            return Err("not all tasks assigned".into());
        }
        let mut gpu = vec![0u64; peers.len()];
        let mut cpu = vec![0u64; peers.len()];
        let mut disk = vec![0u64; peers.len()];
        let mut loads = vec![0.0; peers.len()];
        for (t, &p) in self.of_task.iter().enumerate() {
            if p >= peers.len() {
                return Err(format!("task {t} on unknown peer {p}"));
            }
            gpu[p] += tasks[t].gpu_bytes;
            cpu[p] += tasks[t].cpu_bytes;
            disk[p] += tasks[t].disk_bytes;
            loads[p] += peers[p].task_time(&tasks[t]);
        }
        for p in 0..peers.len() {
            if gpu[p] > peers[p].gpu_capacity {
                return Err(format!("peer {p} GPU over capacity"));
            }
            if cpu[p] > peers[p].cpu_capacity {
                return Err(format!("peer {p} CPU over capacity"));
            }
            if disk[p] > peers[p].disk_capacity {
                return Err(format!("peer {p} disk over capacity"));
            }
            if (loads[p] - self.loads[p]).abs() > 1e-9 * loads[p].max(1.0) {
                return Err(format!("peer {p} load bookkeeping diverged"));
            }
        }
        Ok(())
    }
}

/// Scheduling failure.
#[derive(Debug)]
pub enum SchedError {
    Infeasible(usize),
}

impl std::fmt::Display for SchedError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SchedError::Infeasible(t) => {
                write!(f, "task {t} fits on no peer (memory constraints)")
            }
        }
    }
}

impl std::error::Error for SchedError {}

fn fits(task: &TaskSpec, peer: &PeerSpec, gpu: u64, cpu: u64, disk: u64) -> bool {
    gpu + task.gpu_bytes <= peer.gpu_capacity
        && cpu + task.cpu_bytes <= peer.cpu_capacity
        && disk + task.disk_bytes <= peer.disk_capacity
}

/// LPT greedy: tasks in decreasing reference time, each onto the feasible
/// peer whose *resulting* load is smallest.
pub fn lpt(tasks: &[TaskSpec], peers: &[PeerSpec]) -> Result<Schedule, SchedError> {
    let mut order: Vec<usize> = (0..tasks.len()).collect();
    // Reference time on the fastest peer — any consistent monotone key works.
    order.sort_by(|&a, &b| tasks[b].flops.total_cmp(&tasks[a].flops));

    let mut sched = Schedule {
        of_task: vec![usize::MAX; tasks.len()],
        loads: vec![0.0; peers.len()],
        gpu_used: vec![0; peers.len()],
        cpu_used: vec![0; peers.len()],
        disk_used: vec![0; peers.len()],
    };
    for &t in &order {
        let task = &tasks[t];
        let mut best: Option<(usize, f64)> = None;
        for (p, peer) in peers.iter().enumerate() {
            if !fits(task, peer, sched.gpu_used[p], sched.cpu_used[p], sched.disk_used[p]) {
                continue;
            }
            let new_load = sched.loads[p] + peer.task_time(task);
            if best.map(|(_, l)| new_load < l).unwrap_or(true) {
                best = Some((p, new_load));
            }
        }
        let (p, _) = best.ok_or(SchedError::Infeasible(t))?;
        sched.of_task[t] = p;
        sched.loads[p] += peers[p].task_time(task);
        sched.gpu_used[p] += task.gpu_bytes;
        sched.cpu_used[p] += task.cpu_bytes;
        sched.disk_used[p] += task.disk_bytes;
    }
    Ok(sched)
}

/// Local-search refinement: repeatedly try moving a task off the makespan
/// peer (or swapping with a task elsewhere) while the makespan strictly
/// improves. Bounded iterations keep it O(rounds·n·p).
pub fn refine(sched: &mut Schedule, tasks: &[TaskSpec], peers: &[PeerSpec], max_rounds: usize) {
    for _ in 0..max_rounds {
        let (hot, _) = sched
            .loads
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .unwrap();
        let mut improved = false;

        // Move: take each task on the hot peer, try every other peer.
        let hot_tasks: Vec<usize> =
            (0..tasks.len()).filter(|&t| sched.of_task[t] == hot).collect();
        'outer: for &t in &hot_tasks {
            for p in 0..peers.len() {
                if p == hot {
                    continue;
                }
                if !fits(
                    &tasks[t],
                    &peers[p],
                    sched.gpu_used[p],
                    sched.cpu_used[p],
                    sched.disk_used[p],
                ) {
                    continue;
                }
                let new_hot = sched.loads[hot] - peers[hot].task_time(&tasks[t]);
                let new_p = sched.loads[p] + peers[p].task_time(&tasks[t]);
                if new_hot.max(new_p) + 1e-15 < sched.makespan() {
                    apply_move(sched, tasks, peers, t, p);
                    improved = true;
                    break 'outer;
                }
            }
        }
        if !improved {
            break;
        }
    }
}

fn apply_move(sched: &mut Schedule, tasks: &[TaskSpec], peers: &[PeerSpec], t: usize, to: usize) {
    let from = sched.of_task[t];
    sched.loads[from] -= peers[from].task_time(&tasks[t]);
    sched.gpu_used[from] -= tasks[t].gpu_bytes;
    sched.cpu_used[from] -= tasks[t].cpu_bytes;
    sched.disk_used[from] -= tasks[t].disk_bytes;
    sched.of_task[t] = to;
    sched.loads[to] += peers[to].task_time(&tasks[t]);
    sched.gpu_used[to] += tasks[t].gpu_bytes;
    sched.cpu_used[to] += tasks[t].cpu_bytes;
    sched.disk_used[to] += tasks[t].disk_bytes;
}

/// The production entry point: LPT + refinement.
pub fn schedule(tasks: &[TaskSpec], peers: &[PeerSpec]) -> Result<Schedule, SchedError> {
    let mut s = lpt(tasks, peers)?;
    refine(&mut s, tasks, peers, 4 * tasks.len().max(8));
    Ok(s)
}

/// Baseline: uniformly random feasible peer (ablation).
pub fn random_schedule(
    tasks: &[TaskSpec],
    peers: &[PeerSpec],
    rng: &mut Rng,
) -> Result<Schedule, SchedError> {
    let mut sched = Schedule {
        of_task: vec![usize::MAX; tasks.len()],
        loads: vec![0.0; peers.len()],
        gpu_used: vec![0; peers.len()],
        cpu_used: vec![0; peers.len()],
        disk_used: vec![0; peers.len()],
    };
    for (t, task) in tasks.iter().enumerate() {
        let feasible: Vec<usize> = (0..peers.len())
            .filter(|&p| {
                fits(task, &peers[p], sched.gpu_used[p], sched.cpu_used[p], sched.disk_used[p])
            })
            .collect();
        if feasible.is_empty() {
            return Err(SchedError::Infeasible(t));
        }
        let p = *rng.choose(&feasible);
        sched.of_task[t] = p;
        sched.loads[p] += peers[p].task_time(task);
        sched.gpu_used[p] += task.gpu_bytes;
        sched.cpu_used[p] += task.cpu_bytes;
        sched.disk_used[p] += task.disk_bytes;
    }
    Ok(sched)
}

/// Baseline: round-robin ignoring speeds (ablation — what a heterogeneity-
/// unaware system like the ones §2.2 critiques would do).
pub fn round_robin(tasks: &[TaskSpec], peers: &[PeerSpec]) -> Result<Schedule, SchedError> {
    let mut sched = Schedule {
        of_task: vec![usize::MAX; tasks.len()],
        loads: vec![0.0; peers.len()],
        gpu_used: vec![0; peers.len()],
        cpu_used: vec![0; peers.len()],
        disk_used: vec![0; peers.len()],
    };
    for (t, task) in tasks.iter().enumerate() {
        // try peers starting at t % n until one fits
        let n = peers.len();
        let mut placed = false;
        for off in 0..n {
            let p = (t + off) % n;
            if fits(task, &peers[p], sched.gpu_used[p], sched.cpu_used[p], sched.disk_used[p]) {
                sched.of_task[t] = p;
                sched.loads[p] += peers[p].task_time(task);
                sched.gpu_used[p] += task.gpu_bytes;
                sched.cpu_used[p] += task.cpu_bytes;
                sched.disk_used[p] += task.disk_bytes;
                placed = true;
                break;
            }
        }
        if !placed {
            return Err(SchedError::Infeasible(t));
        }
    }
    Ok(sched)
}

/// Rescheduling after a peer failure (paper §3.2: "the broker selects a
/// replacement from the backup compnode pool"): move the failed peer's tasks
/// onto the replacement (preferred) or, if they don't fit, onto the
/// least-loaded survivors.
pub fn reschedule_failure(
    sched: &mut Schedule,
    tasks: &[TaskSpec],
    peers: &[PeerSpec],
    failed: usize,
    replacement: Option<usize>,
) -> Result<Vec<usize>, SchedError> {
    let moved: Vec<usize> =
        (0..tasks.len()).filter(|&t| sched.of_task[t] == failed).collect();
    for &t in &moved {
        // Remove from failed peer's books.
        apply_move_out(sched, tasks, peers, t);
        let mut target = None;
        if let Some(r) = replacement {
            if r != failed
                && fits(&tasks[t], &peers[r], sched.gpu_used[r], sched.cpu_used[r], sched.disk_used[r])
            {
                target = Some(r);
            }
        }
        if target.is_none() {
            let mut best: Option<(usize, f64)> = None;
            for p in 0..peers.len() {
                if p == failed {
                    continue;
                }
                if !fits(&tasks[t], &peers[p], sched.gpu_used[p], sched.cpu_used[p], sched.disk_used[p]) {
                    continue;
                }
                let load = sched.loads[p] + peers[p].task_time(&tasks[t]);
                if best.map(|(_, l)| load < l).unwrap_or(true) {
                    best = Some((p, load));
                }
            }
            target = best.map(|(p, _)| p);
        }
        let p = target.ok_or(SchedError::Infeasible(t))?;
        sched.of_task[t] = p;
        sched.loads[p] += peers[p].task_time(&tasks[t]);
        sched.gpu_used[p] += tasks[t].gpu_bytes;
        sched.cpu_used[p] += tasks[t].cpu_bytes;
        sched.disk_used[p] += tasks[t].disk_bytes;
    }
    Ok(moved)
}

fn apply_move_out(sched: &mut Schedule, tasks: &[TaskSpec], peers: &[PeerSpec], t: usize) {
    let from = sched.of_task[t];
    sched.loads[from] -= peers[from].task_time(&tasks[t]);
    sched.gpu_used[from] -= tasks[t].gpu_bytes;
    sched.cpu_used[from] -= tasks[t].cpu_bytes;
    sched.disk_used[from] -= tasks[t].disk_bytes;
    sched.of_task[t] = usize::MAX;
}

/// Helpers to build specs from a decomposition + device list.
pub mod build {
    use super::*;
    use crate::dag::Graph;
    use crate::decompose::Decomposition;
    use crate::perf::gpus::GpuSpec;

    /// Task specs from a decomposition (fwd+bwd FLOPs; training memory).
    pub fn tasks_from_decomposition(g: &Graph, d: &Decomposition, training: bool) -> Vec<TaskSpec> {
        (0..d.num_subgraphs())
            .map(|s| {
                let fwd = d.sub_flops(g, s);
                let bwd: f64 = d.subgraphs[s]
                    .nodes
                    .iter()
                    .map(|&n| crate::dag::flops::bwd_flops(g.node(n)))
                    .sum();
                let gpu = if training {
                    d.sub_gpu_bytes(g, s)
                } else {
                    d.subgraphs[s]
                        .nodes
                        .iter()
                        .map(|&n| crate::dag::flops::gpu_bytes_infer(g.node(n)))
                        .sum()
                };
                TaskSpec {
                    id: s,
                    flops: if training { fwd + bwd } else { fwd },
                    gpu_bytes: gpu,
                    cpu_bytes: gpu / 2,
                    disk_bytes: d.sub_param_bytes(g, s),
                }
            })
            .collect()
    }

    /// A fleet of identical peers from one GPU spec.
    pub fn uniform_peers(gpu: &GpuSpec, lambda: f64, count: usize) -> Vec<PeerSpec> {
        (0..count)
            .map(|id| PeerSpec {
                id,
                profile: DeviceProfile::with_lambda(gpu, lambda),
                gpu_capacity: gpu.memory_bytes(),
                cpu_capacity: 2 * gpu.memory_bytes(),
                disk_capacity: 64 * gpu.memory_bytes(),
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perf::gpus::lookup;

    fn peers(n: usize, gpu: &str, lambda: f64) -> Vec<PeerSpec> {
        build::uniform_peers(lookup(gpu).unwrap(), lambda, n)
    }

    fn simple_tasks(flops: &[f64]) -> Vec<TaskSpec> {
        flops
            .iter()
            .enumerate()
            .map(|(id, &f)| TaskSpec { id, flops: f, gpu_bytes: 1, cpu_bytes: 1, disk_bytes: 1 })
            .collect()
    }

    #[test]
    fn lpt_balances_uniform_peers() {
        let tasks = simple_tasks(&[5.0, 4.0, 3.0, 3.0, 3.0, 2.0]);
        let ps = peers(2, "RTX 3080", 0.5);
        let s = schedule(&tasks, &ps).unwrap();
        s.validate(&tasks, &ps).unwrap();
        // Optimal makespan splits 20 FLOPs as 10/10.
        let t_unit = ps[0].task_time(&tasks[5]) / 2.0; // time per flop
        assert!((s.makespan() / t_unit - 10.0).abs() < 1e-6, "makespan {}", s.makespan());
    }

    #[test]
    fn heterogeneous_peers_get_proportional_load() {
        // One H100 + one 3080: H100 should take much more work.
        let mut ps = peers(1, "H100", 0.5);
        ps.extend(peers(1, "RTX 3080", 0.5).into_iter().map(|mut p| {
            p.id = 1;
            p
        }));
        let tasks = simple_tasks(&vec![1e12; 40]);
        let s = schedule(&tasks, &ps).unwrap();
        s.validate(&tasks, &ps).unwrap();
        let on_h100 = s.of_task.iter().filter(|&&p| p == 0).count();
        assert!(on_h100 > 25, "H100 got only {on_h100}/40 tasks");
    }

    #[test]
    fn memory_constraints_respected() {
        let mut ps = peers(2, "RTX 3080", 0.5);
        ps[0].gpu_capacity = 10; // tiny
        let tasks: Vec<TaskSpec> = (0..4)
            .map(|id| TaskSpec { id, flops: 1e9, gpu_bytes: 8, cpu_bytes: 1, disk_bytes: 1 })
            .collect();
        let s = schedule(&tasks, &ps).unwrap();
        s.validate(&tasks, &ps).unwrap();
        // peer 0 can hold at most one task (8 ≤ 10 < 16).
        assert!(s.of_task.iter().filter(|&&p| p == 0).count() <= 1);
    }

    #[test]
    fn infeasible_detected() {
        let ps = {
            let mut ps = peers(1, "RTX 3080", 0.5);
            ps[0].gpu_capacity = 4;
            ps
        };
        let tasks =
            vec![TaskSpec { id: 0, flops: 1.0, gpu_bytes: 100, cpu_bytes: 0, disk_bytes: 0 }];
        assert!(matches!(schedule(&tasks, &ps), Err(SchedError::Infeasible(0))));
    }

    #[test]
    fn refine_never_worsens() {
        let mut rng = Rng::new(9);
        for trial in 0..20 {
            let n = 5 + (trial % 10);
            let tasks = simple_tasks(
                &(0..n).map(|i| ((i * 37 + trial * 11) % 17 + 1) as f64).collect::<Vec<_>>(),
            );
            let ps = peers(3, "RTX 3080", 0.5);
            let before = random_schedule(&tasks, &ps, &mut rng).unwrap();
            let mut after = before.clone();
            refine(&mut after, &tasks, &ps, 100);
            after.validate(&tasks, &ps).unwrap();
            assert!(after.makespan() <= before.makespan() + 1e-12);
        }
    }

    #[test]
    fn lpt_beats_random_usually() {
        let mut rng = Rng::new(1234);
        let tasks = simple_tasks(&(1..=30).map(|i| i as f64).collect::<Vec<_>>());
        let ps = peers(5, "RTX 3080", 0.5);
        let good = schedule(&tasks, &ps).unwrap().makespan();
        let mut wins = 0;
        for _ in 0..10 {
            let r = random_schedule(&tasks, &ps, &mut rng).unwrap().makespan();
            if good <= r + 1e-12 {
                wins += 1;
            }
        }
        assert!(wins >= 9, "LPT beaten too often ({wins}/10)");
    }

    #[test]
    fn reschedule_moves_all_failed_tasks() {
        let tasks = simple_tasks(&[4.0, 3.0, 2.0, 2.0, 1.0]);
        let ps = peers(3, "RTX 3080", 0.5);
        let mut s = schedule(&tasks, &ps).unwrap();
        let victim = s.of_task[0];
        let moved = reschedule_failure(&mut s, &tasks, &ps, victim, None).unwrap();
        assert!(!moved.is_empty());
        assert!(s.of_task.iter().all(|&p| p != victim));
        s.validate(&tasks, &ps).unwrap();
    }

    #[test]
    fn reschedule_prefers_replacement() {
        let tasks = simple_tasks(&[4.0, 3.0]);
        let ps = peers(3, "RTX 3080", 0.5);
        // Put everything on peer 0 manually.
        let mut s = Schedule {
            of_task: vec![0, 0],
            loads: vec![ps[0].task_time(&tasks[0]) + ps[0].task_time(&tasks[1]), 0.0, 0.0],
            gpu_used: vec![2, 0, 0],
            cpu_used: vec![2, 0, 0],
            disk_used: vec![2, 0, 0],
        };
        reschedule_failure(&mut s, &tasks, &ps, 0, Some(2)).unwrap();
        assert!(s.of_task.iter().all(|&p| p == 2));
        s.validate(&tasks, &ps).unwrap();
    }
}
