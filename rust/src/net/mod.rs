//! Simulated wide-area network between compnodes.
//!
//! The paper's testbed is consumer devices connected over the Internet; our
//! substitute (DESIGN.md §5) is an in-process network with per-pair α-β
//! links ([`crate::perf::comm::LinkModel`]). The simulator supports two
//! clocks:
//!
//! * **virtual time** — `delay()` returns the modelled seconds; schedulers
//!   and benches accumulate them without sleeping;
//! * **scaled real time** — the live cluster multiplies modelled delay by
//!   `time_scale` and actually sleeps, so churn/heartbeat interleavings are
//!   exercised for real while keeping wall-clock budgets small.
//!
//! All traffic is accounted per link for the experiment reports.

use std::collections::HashMap;
use std::sync::Mutex;

use crate::perf::comm::LinkModel;

/// Node address in the simulated network (same id space as compnodes).
pub type Addr = usize;

/// Static topology: explicit per-pair links with a default fallback.
#[derive(Debug)]
pub struct Topology {
    default: LinkModel,
    links: HashMap<(Addr, Addr), LinkModel>,
    /// Self-loop model (local message passing — "gray lines" of Fig. 3).
    local: LinkModel,
}

impl Topology {
    pub fn uniform(default: LinkModel) -> Topology {
        Topology { default, links: HashMap::new(), local: LinkModel::local() }
    }

    /// Set a specific directed link.
    pub fn set_link(&mut self, from: Addr, to: Addr, link: LinkModel) {
        self.links.insert((from, to), link);
    }

    /// Set a symmetric link.
    pub fn set_link_sym(&mut self, a: Addr, b: Addr, link: LinkModel) {
        self.links.insert((a, b), link);
        self.links.insert((b, a), link);
    }

    pub fn link(&self, from: Addr, to: Addr) -> LinkModel {
        if from == to {
            return self.local;
        }
        *self.links.get(&(from, to)).unwrap_or(&self.default)
    }
}

/// Per-link traffic counters.
#[derive(Debug, Default, Clone)]
pub struct LinkStats {
    pub messages: u64,
    pub bytes: u64,
    pub model_seconds: f64,
    /// Messages lost in flight (fault injection; see `cluster::faults`).
    pub dropped: u64,
}

/// The network simulator: topology + accounting + clock policy.
pub struct NetworkSim {
    topo: Topology,
    stats: Mutex<HashMap<(Addr, Addr), LinkStats>>,
    /// Multiplier from modelled seconds to real sleep. 0 disables sleeping.
    time_scale: f64,
}

impl NetworkSim {
    pub fn new(topo: Topology, time_scale: f64) -> NetworkSim {
        NetworkSim { topo, stats: Mutex::new(HashMap::new()), time_scale }
    }

    /// Counters survive a panicked sender thread: the map holds no invariant
    /// a panic can break (every update is a single saturating bump), so a
    /// poisoned lock is recovered instead of cascading the panic into every
    /// other stage thread.
    fn stats_guard(&self) -> std::sync::MutexGuard<'_, HashMap<(Addr, Addr), LinkStats>> {
        self.stats.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Modelled transfer seconds for `bytes` from→to, with accounting.
    pub fn delay(&self, from: Addr, to: Addr, bytes: u64) -> f64 {
        let t = self.topo.link(from, to).time(bytes);
        let mut stats = self.stats_guard();
        let e = stats.entry((from, to)).or_default();
        e.messages += 1;
        e.bytes += bytes;
        e.model_seconds += t;
        t
    }

    /// Like [`delay`](Self::delay) but also sleeps `time_scale × t` (live
    /// cluster mode).
    pub fn transfer(&self, from: Addr, to: Addr, bytes: u64) -> f64 {
        let t = self.delay(from, to, bytes);
        if self.time_scale > 0.0 && t > 0.0 {
            std::thread::sleep(std::time::Duration::from_secs_f64(t * self.time_scale));
        }
        t
    }

    /// Account a message that was lost in flight: the sender paid the
    /// serialization + transfer time, the receiver never sees it. Returns
    /// the modelled seconds burned (and sleeps them in live mode, like
    /// [`transfer`](Self::transfer) — a drop is not observable faster than
    /// a delivery).
    pub fn drop_message(&self, from: Addr, to: Addr, bytes: u64) -> f64 {
        let t = self.transfer(from, to, bytes);
        let mut stats = self.stats_guard();
        let e = stats.entry((from, to)).or_default();
        e.dropped += 1;
        t
    }

    /// Total messages dropped across all links.
    pub fn total_dropped(&self) -> u64 {
        self.stats_guard().values().map(|s| s.dropped).sum()
    }

    pub fn link(&self, from: Addr, to: Addr) -> LinkModel {
        self.topo.link(from, to)
    }

    /// Snapshot of all per-link stats.
    pub fn stats(&self) -> HashMap<(Addr, Addr), LinkStats> {
        self.stats_guard().clone()
    }

    /// Total bytes moved across remote links.
    pub fn total_remote_bytes(&self) -> u64 {
        self.stats_guard()
            .iter()
            .filter(|((f, t), _)| f != t)
            .map(|(_, s)| s.bytes)
            .sum()
    }

    /// Total modelled seconds across remote links.
    pub fn total_remote_seconds(&self) -> f64 {
        self.stats_guard()
            .iter()
            .filter(|((f, t), _)| f != t)
            .map(|(_, s)| s.model_seconds)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_and_specific_links() {
        let mut topo = Topology::uniform(LinkModel::from_ms_mbps(10.0, 100.0));
        topo.set_link_sym(0, 1, LinkModel::from_ms_mbps(1.0, 1000.0));
        assert!(topo.link(0, 1).alpha < topo.link(0, 2).alpha);
        assert_eq!(topo.link(0, 1).alpha, topo.link(1, 0).alpha);
        // local is free
        assert_eq!(topo.link(3, 3).time(1 << 30), 0.0);
    }

    #[test]
    fn accounting() {
        let sim = NetworkSim::new(Topology::uniform(LinkModel::from_ms_mbps(10.0, 100.0)), 0.0);
        sim.delay(0, 1, 1000);
        sim.delay(0, 1, 2000);
        sim.delay(2, 2, 500); // local, excluded from remote totals
        assert_eq!(sim.total_remote_bytes(), 3000);
        let stats = sim.stats();
        assert_eq!(stats[&(0, 1)].messages, 2);
        assert!(sim.total_remote_seconds() > 0.02);
    }

    #[test]
    fn dropped_messages_are_accounted() {
        let sim = NetworkSim::new(Topology::uniform(LinkModel::from_ms_mbps(10.0, 100.0)), 0.0);
        sim.delay(0, 1, 1000);
        let t = sim.drop_message(0, 1, 2000);
        assert!(t > 0.0, "a drop still burns transfer time");
        let stats = sim.stats();
        assert_eq!(stats[&(0, 1)].messages, 2, "drops count as sent messages");
        assert_eq!(stats[&(0, 1)].dropped, 1);
        assert_eq!(sim.total_dropped(), 1);
        assert_eq!(sim.total_remote_bytes(), 3000, "sender paid the bytes");
    }

    #[test]
    fn delay_matches_link_model() {
        let link = LinkModel::from_ms_mbps(5.0, 50.0);
        let sim = NetworkSim::new(Topology::uniform(link), 0.0);
        let t = sim.delay(0, 1, 1_000_000);
        assert!((t - link.time(1_000_000)).abs() < 1e-12);
    }

    #[test]
    fn scaled_sleep_is_bounded() {
        // With a tiny scale, transfer() should return quickly but still
        // account full modelled time.
        let sim =
            NetworkSim::new(Topology::uniform(LinkModel::from_ms_mbps(100.0, 1.0)), 1e-6);
        let start = std::time::Instant::now();
        let t = sim.transfer(0, 1, 10_000_000);
        assert!(t > 1.0, "modelled {t}");
        assert!(start.elapsed().as_secs_f64() < 0.5);
    }
}
