//! Transformer graph builders (paper §4, Figures 4–6).
//!
//! Two granularities:
//!
//! 1. **Fine-grained** ([`TransformerConfig::build_graph`]): every layer is
//!    split into an *attention block* and an *FFN block* exactly as in
//!    Figure 4 ("There are 24 transformer layers, each of which is split
//!    into attention block and FFN block"), plus embedding and LM head.
//!    This is what the decomposer and the analytic performance model consume.
//! 2. **Coarse** ([`pipeline_graph`]): one [`OpKind::StageCall`] node per
//!    pipeline stage, each backed by an AOT-compiled XLA artifact. This is
//!    the live end-to-end training/serving representation.

use crate::dag::{flops, DType, Graph, OpKind, Shape};

/// Structural hyperparameters of a decoder-only / encoder transformer.
#[derive(Debug, Clone)]
pub struct TransformerConfig {
    pub name: String,
    pub vocab: usize,
    pub seq: usize,
    pub batch: usize,
    pub layers: usize,
    pub dim: usize,
    pub heads: usize,
    pub ffn_hidden: usize,
    pub causal: bool,
    /// Full LM head (`dim → vocab`, GPT-style training/serving) vs a small
    /// classification pooler (`dim → n_classes`, the BERT inference setting
    /// of the paper's Figures 4–5 where the sub-DAG inventory is embedding
    /// + 48 attention/FFN blocks and no vocab-sized projection).
    pub lm_head: bool,
}

impl TransformerConfig {
    /// Bert-Large: 24 layers, hidden 1024, 16 heads, FFN 4096 (paper Fig. 4/5).
    pub fn bert_large() -> Self {
        TransformerConfig {
            name: "bert-large".into(),
            vocab: 30522,
            seq: 512,
            batch: 8,
            layers: 24,
            dim: 1024,
            heads: 16,
            ffn_hidden: 4096,
            causal: false,
            lm_head: false,
        }
    }

    /// The paper's GPT-3 variant: "24 layers with the hidden size of 4096"
    /// (Figure 6). Heads/FFN follow the GPT-3 architecture family ratios.
    pub fn gpt3_24x4096() -> Self {
        TransformerConfig {
            name: "gpt3-24x4096".into(),
            vocab: 50257,
            seq: 2048,
            batch: 1,
            layers: 24,
            dim: 4096,
            heads: 32,
            ffn_hidden: 16384,
            causal: true,
            lm_head: false,
        }
    }

    /// ~110M-parameter GPT used by the live end-to-end example
    /// (`examples/train_pipeline.rs`), sized to what a CPU PJRT backend can
    /// train for a few hundred steps.
    pub fn gpt_e2e() -> Self {
        TransformerConfig {
            name: "gpt-e2e".into(),
            vocab: 16384,
            seq: 128,
            batch: 8,
            layers: 12,
            dim: 768,
            heads: 12,
            ffn_hidden: 3072,
            causal: true,
            lm_head: true,
        }
    }

    /// Tiny config for unit/integration tests and the quickstart.
    pub fn tiny() -> Self {
        TransformerConfig {
            name: "gpt-tiny".into(),
            vocab: 256,
            seq: 16,
            batch: 2,
            layers: 2,
            dim: 32,
            heads: 2,
            ffn_hidden: 64,
            causal: true,
            lm_head: true,
        }
    }

    /// Output projection width: vocab for LM heads, 2 classes for the
    /// BERT-style pooler.
    pub fn head_width(&self) -> usize {
        if self.lm_head {
            self.vocab
        } else {
            2
        }
    }

    /// Trainable parameter count of the full model (matches
    /// [`Self::build_graph`] exactly; the L2 jax model adds a `seq×dim`
    /// positional embedding — ~0.4% — accounted through the artifact
    /// manifest, not here).
    pub fn param_count(&self) -> u64 {
        let per_layer = 2 * (2 * self.dim) as u64            // two LayerNorms
            + (4 * self.dim * self.dim + 4 * self.dim) as u64 // attention
            + (2 * self.dim * self.ffn_hidden + self.dim + self.ffn_hidden) as u64; // ffn
        let embed = (self.vocab * self.dim) as u64;
        let head =
            (2 * self.dim) as u64 + (self.dim * self.head_width() + self.head_width()) as u64;
        embed + self.layers as u64 * per_layer + head
    }

    /// Build the fine-grained FP graph: embedding → 24×(attn block + ffn
    /// block) → final LN → LM head → cross-entropy.
    ///
    /// Block structure is pre-LN: `x + Attn(LN(x))`, `x + FFN(LN(x))`.
    pub fn build_graph(&self) -> Graph {
        let mut g = Graph::new();
        let tokens = g.placeholder("tokens", Shape::of(&[self.batch, self.seq]), DType::I32);
        let labels = g.placeholder("labels", Shape::of(&[self.batch, self.seq]), DType::I32);
        let mut h = g
            .op("embed", OpKind::Embedding { vocab: self.vocab, dim: self.dim }, &[tokens])
            .unwrap();
        for l in 0..self.layers {
            let ln1 = g
                .op(&format!("layer{l}.ln1"), OpKind::LayerNorm { dim: self.dim }, &[h])
                .unwrap();
            let attn = g
                .op(
                    &format!("layer{l}.attn"),
                    OpKind::Attention { heads: self.heads, dim: self.dim, causal: self.causal },
                    &[ln1],
                )
                .unwrap();
            let res1 = g.op(&format!("layer{l}.res1"), OpKind::Add, &[h, attn]).unwrap();
            let ln2 = g
                .op(&format!("layer{l}.ln2"), OpKind::LayerNorm { dim: self.dim }, &[res1])
                .unwrap();
            let ffn = g
                .op(
                    &format!("layer{l}.ffn"),
                    OpKind::FeedForward { dim: self.dim, hidden: self.ffn_hidden },
                    &[ln2],
                )
                .unwrap();
            h = g.op(&format!("layer{l}.res2"), OpKind::Add, &[res1, ffn]).unwrap();
        }
        let lnf = g.op("ln_f", OpKind::LayerNorm { dim: self.dim }, &[h]).unwrap();
        let logits = g
            .op(
                "lm_head",
                OpKind::Linear {
                    in_features: self.dim,
                    out_features: self.head_width(),
                    bias: true,
                },
                &[lnf],
            )
            .unwrap();
        g.op("loss", OpKind::CrossEntropy { weight: 1.0 }, &[labels, logits]).unwrap();
        g
    }
}

/// Convenience constructors matching the paper's two evaluation models.
pub fn bert_large() -> Graph {
    TransformerConfig::bert_large().build_graph()
}
pub fn gpt3_24x4096() -> Graph {
    TransformerConfig::gpt3_24x4096().build_graph()
}

/// A coarse pipeline split of a transformer: how many `StageCall` nodes and
/// how many layers each holds.
#[derive(Debug, Clone)]
pub struct PipelineSpec {
    pub config: TransformerConfig,
    /// Number of transformer-block stages (embedding and head are separate
    /// stages around them).
    pub block_stages: usize,
}

impl PipelineSpec {
    pub fn new(config: TransformerConfig, block_stages: usize) -> Self {
        assert!(block_stages > 0 && config.layers % block_stages == 0,
            "layers {} must divide evenly into {} stages", config.layers, block_stages);
        PipelineSpec { config, block_stages }
    }

    pub fn layers_per_stage(&self) -> usize {
        self.config.layers / self.block_stages
    }

    /// Total number of stages (embed + blocks + head).
    pub fn num_stages(&self) -> usize {
        self.block_stages + 2
    }
}

/// Build the coarse `StageCall` graph for the live pipeline: one node per
/// stage with FLOPs/params pre-computed from an equivalent fine-grained
/// graph, so the scheduler and perf model treat it identically.
pub fn pipeline_graph(spec: &PipelineSpec) -> Graph {
    let c = &spec.config;
    let mut g = Graph::new();
    let act_shape = Shape::of(&[c.batch, c.seq, c.dim]);
    let tokens = g.placeholder("tokens", Shape::of(&[c.batch, c.seq]), DType::I32);
    let labels = g.placeholder("labels", Shape::of(&[c.batch, c.seq]), DType::I32);

    // Cost model: reuse the fine-grained per-op FLOP counters.
    let fine = c.build_graph();
    let layer_fwd_flops = |l: usize| -> f64 {
        fine.nodes
            .iter()
            .filter(|n| n.name.starts_with(&format!("layer{l}.")))
            .map(flops::fwd_flops)
            .sum()
    };
    let layer_params = |l: usize| -> usize {
        fine.nodes
            .iter()
            .filter(|n| n.name.starts_with(&format!("layer{l}.")))
            .map(flops::param_count)
            .sum()
    };

    let embed_params = c.vocab * c.dim;
    let embed = g
        .op(
            "stage.embed",
            OpKind::StageCall {
                stage: "embed".into(),
                param_count: embed_params,
                flops: (c.batch * c.seq * c.dim) as f64,
                param_bytes: embed_params as u64 * 4,
            },
            &[tokens],
        )
        .unwrap();
    g.set_shape(embed, act_shape.clone(), DType::F32);

    let mut h = embed;
    let lps = spec.layers_per_stage();
    for s in 0..spec.block_stages {
        let lo = s * lps;
        let hi = lo + lps;
        let fl: f64 = (lo..hi).map(layer_fwd_flops).sum();
        let pc: usize = (lo..hi).map(layer_params).sum();
        let node = g
            .op(
                &format!("stage.block{s}"),
                OpKind::StageCall {
                    stage: format!("block{s}"),
                    param_count: pc,
                    flops: fl,
                    param_bytes: pc as u64 * 4,
                },
                &[h],
            )
            .unwrap();
        g.set_shape(node, act_shape.clone(), DType::F32);
        h = node;
    }

    let head_params = 2 * c.dim + c.dim * c.head_width() + c.head_width();
    let head_flops = fine
        .nodes
        .iter()
        .filter(|n| matches!(n.name.as_str(), "ln_f" | "lm_head" | "loss"))
        .map(flops::fwd_flops)
        .sum();
    let head = g
        .op(
            "stage.head",
            OpKind::StageCall {
                stage: "head".into(),
                param_count: head_params,
                flops: head_flops,
                param_bytes: head_params as u64 * 4,
            },
            &[h],
        )
        .unwrap();
    g.set_shape(head, Shape::scalar(), DType::F32);
    // The head also consumes labels; model as an extra edge.
    g.add_arg(head, labels);
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bert_large_structure() {
        let g = bert_large();
        // embed + 24×6 ops + ln_f + head + loss + 2 placeholders
        assert_eq!(g.len(), 2 + 1 + 24 * 6 + 3);
        assert!(g.by_name("layer23.ffn").is_some());
        assert!(g.topo_order().is_ok());
    }

    #[test]
    fn bert_large_param_count_plausible() {
        // Bert-Large is ~340M params (ours differs slightly: learned pos-emb
        // + untied LM head). Accept 300–420M.
        let c = TransformerConfig::bert_large();
        let p = c.param_count();
        assert!(p > 300_000_000 && p < 420_000_000, "params {p}");
        // graph-level accounting must agree with the closed form
        let g = c.build_graph();
        assert_eq!(g.param_count(), p);
    }

    #[test]
    fn gpt3_variant_params() {
        // 24 layers × ~201M/layer + embeddings ≈ 5B-ish; just sanity-band it.
        let c = TransformerConfig::gpt3_24x4096();
        let p = c.param_count();
        assert!(p > 4_000_000_000 && p < 6_000_000_000, "params {p}");
    }

    #[test]
    fn e2e_preset_is_about_100m() {
        let p = TransformerConfig::gpt_e2e().param_count();
        assert!(p > 90_000_000 && p < 140_000_000, "params {p}");
    }

    #[test]
    fn fwd_flops_scale_with_layers() {
        let mut small = TransformerConfig::tiny();
        let mut big = TransformerConfig::tiny();
        small.layers = 2;
        big.layers = 4;
        let f_small = small.build_graph().total_fwd_flops();
        let f_big = big.build_graph().total_fwd_flops();
        assert!(f_big > 1.5 * f_small);
    }

    #[test]
    fn pipeline_graph_costs_match_fine_graph() {
        let c = TransformerConfig::tiny();
        let fine = c.build_graph();
        let spec = PipelineSpec::new(c, 2);
        let coarse = pipeline_graph(&spec);
        assert_eq!(coarse.len(), 2 + spec.num_stages());
        // Params must match exactly (same closed forms).
        assert_eq!(coarse.param_count(), fine.param_count());
        // FLOPs: coarse embed stage is approximated; require within 2%.
        let ratio = coarse.total_fwd_flops() / fine.total_fwd_flops();
        assert!((ratio - 1.0).abs() < 0.02, "flops ratio {ratio}");
    }

    #[test]
    fn pipeline_spec_validates_divisibility() {
        let c = TransformerConfig::tiny(); // 2 layers
        assert_eq!(PipelineSpec::new(c.clone(), 2).layers_per_stage(), 1);
        let result = std::panic::catch_unwind(|| PipelineSpec::new(c, 3));
        assert!(result.is_err());
    }

    #[test]
    fn fig4_blocks_are_separable() {
        // Figure 4 splits each layer into attention + FFN blocks; verify the
        // graph exposes them as distinct nodes with distinct costs.
        let g = TransformerConfig::bert_large().build_graph();
        let attn = g.by_name("layer0.attn").unwrap();
        let ffn = g.by_name("layer0.ffn").unwrap();
        assert!(crate::dag::flops::fwd_flops(attn) > 0.0);
        assert!(crate::dag::flops::fwd_flops(ffn) > 0.0);
    }
}
