//! Model zoo: DAG builders for the models used in the paper's evaluation.
//!
//! * [`fig3`] — the exact 10-operator example DAG of paper Figure 3 /
//!   Tables 2–3 (Conv/Add/Pool/Multiply/Concat/Linear/CrossEntropy with an
//!   optimizable `Tensor A` variable);
//! * [`transformer`] — fine-grained transformer graphs: **Bert-Large**
//!   (24 layers, hidden 1024) and the paper's **GPT-3 variant** (24 layers,
//!   hidden 4096), each layer split into an attention block and an FFN block
//!   exactly as in Figure 4, plus arbitrary custom configs;
//! * [`transformer::pipeline_graph`] — the coarse `StageCall` representation
//!   used by the live end-to-end training path, where each stage is backed
//!   by an AOT-compiled XLA artifact.

pub mod fig3;
pub mod transformer;

pub use transformer::{
    bert_large, gpt3_24x4096, pipeline_graph, PipelineSpec, TransformerConfig,
};
