//! The example DAG of paper Figure 3 / Tables 2–3.
//!
//! Ten operators: `Input → Conv → Add → {Pool, Multiply} → Concat → Linear →
//! CrossEntropy(Label)`, with an optimizable leaf `Tensor A` feeding
//! `Multiply`. The paper partitions it over three compnodes:
//!
//! | Subgraph | Compnode | Nodes |
//! |---|---|---|
//! | 1 | 1 | Input, Conv, Add, Pool |
//! | 2 | 2 | Tensor A, Multiply |
//! | 3 | 3 | Concat, Linear, Label, CrossEntropy |
//!
//! [`build`] reproduces the graph (with concrete toy shapes so every shape
//! rule checks out); [`paper_partition`] returns the exact 3-way split above,
//! which `benches/table23_dag.rs` uses to regenerate both tables.

use crate::dag::{DType, Graph, NodeId, OpKind, Shape};

/// Concrete shapes for the toy DAG. The paper gives none, so we pick small
/// ones that satisfy every operator contract (the residual `Add` forces
/// `out_ch == in_ch`; `Concat` along channels forces equal spatial dims, so
/// the `Pool` is a 1×1/stride-1 window).
pub const BATCH: usize = 2;
pub const CH: usize = 3;
pub const HW: usize = 8;
pub const CLASSES: usize = 10;

/// Build the Figure-3 DAG. Node names match the paper exactly.
pub fn build() -> Graph {
    let mut g = Graph::new();
    let input = g.placeholder("Input", Shape::of(&[BATCH, CH, HW, HW]), DType::F32);
    let conv = g
        .op(
            "Conv",
            OpKind::Conv2d { in_ch: CH, out_ch: CH, kernel: 3, stride: 1, padding: 1 },
            &[input],
        )
        .unwrap();
    // Residual connection: Table 2 lists `Add` among Input's users.
    let add = g.op("Add", OpKind::Add, &[conv, input]).unwrap();
    let pool = g.op("Pool", OpKind::MaxPool2d { kernel: 1, stride: 1 }, &[add]).unwrap();
    let tensor_a = g.variable("Tensor A", Shape::of(&[BATCH, CH, HW, HW]));
    let mult = g.op("Multiply", OpKind::Multiply, &[tensor_a, add]).unwrap();
    let concat = g.op("Concat", OpKind::Concat { axis: 1 }, &[mult, pool]).unwrap();
    let linear = g
        .op("Linear", OpKind::Linear { in_features: HW, out_features: CLASSES, bias: true }, &[concat])
        .unwrap();
    let label = g.placeholder("Label", Shape::of(&[BATCH, 2 * CH, HW]), DType::I32);
    let ce = g.op("CrossEntropy", OpKind::CrossEntropy { weight: 1.0 }, &[label, linear]).unwrap();
    g.set_kwarg(ce, "weight", "1.0");
    g
}

/// The paper's Table-3 partition: node-name → compnode (1-based, as printed).
pub fn paper_partition(g: &Graph) -> Vec<(NodeId, usize)> {
    let place = |name: &str| -> usize {
        match name {
            "Input" | "Conv" | "Add" | "Pool" => 1,
            "Tensor A" | "Multiply" => 2,
            "Concat" | "Linear" | "Label" | "CrossEntropy" => 3,
            other => panic!("unknown fig3 node {other}"),
        }
    };
    g.nodes.iter().map(|n| (n.id, place(&n.name))).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dag::OpCategory;

    #[test]
    fn has_ten_ops_matching_table2() {
        let g = build();
        assert_eq!(g.len(), 10);
        for name in
            ["Input", "Conv", "Add", "Pool", "Tensor A", "Multiply", "Concat", "Linear", "Label", "CrossEntropy"]
        {
            assert!(g.by_name(name).is_some(), "missing {name}");
        }
    }

    #[test]
    fn categories_match_table2() {
        let g = build();
        let cat = |n: &str| g.by_name(n).unwrap().kind.category();
        assert_eq!(cat("Input"), OpCategory::Placeholder);
        assert_eq!(cat("Label"), OpCategory::Placeholder);
        assert_eq!(cat("Conv"), OpCategory::Parametric);
        assert_eq!(cat("Linear"), OpCategory::Parametric);
        assert_eq!(cat("Tensor A"), OpCategory::Variable);
        assert_eq!(cat("Add"), OpCategory::NonParametric);
        assert_eq!(cat("Pool"), OpCategory::NonParametric);
        assert_eq!(cat("Multiply"), OpCategory::NonParametric);
        assert_eq!(cat("Concat"), OpCategory::NonParametric);
        assert_eq!(cat("CrossEntropy"), OpCategory::Loss);
    }

    #[test]
    fn users_match_table2() {
        let g = build();
        let users = |n: &str| -> Vec<String> {
            g.users(g.by_name(n).unwrap().id)
                .iter()
                .map(|&u| g.node(u).name.clone())
                .collect()
        };
        assert_eq!(users("Input"), vec!["Conv", "Add"]);
        assert_eq!(users("Conv"), vec!["Add"]);
        assert_eq!(users("Add"), vec!["Pool", "Multiply"]);
        assert_eq!(users("Pool"), vec!["Concat"]);
        assert_eq!(users("Tensor A"), vec!["Multiply"]);
        assert_eq!(users("Multiply"), vec!["Concat"]);
        assert_eq!(users("Concat"), vec!["Linear"]);
        assert_eq!(users("Linear"), vec!["CrossEntropy"]);
        assert_eq!(users("Label"), vec!["CrossEntropy"]);
        assert!(users("CrossEntropy").is_empty());
    }

    #[test]
    fn partition_matches_table3() {
        let g = build();
        let part = paper_partition(&g);
        let of = |n: &str| {
            part.iter().find(|(id, _)| g.node(*id).name == n).unwrap().1
        };
        assert_eq!(of("Pool"), 1);
        assert_eq!(of("Tensor A"), 2);
        assert_eq!(of("CrossEntropy"), 3);
    }

    #[test]
    fn backward_plan_exists() {
        let g = build();
        let plan = crate::dag::autodiff::backward_plan(&g);
        // Conv, Linear, Tensor A participate with param grads.
        assert!(plan.task(g.by_name("Conv").unwrap().id).unwrap().wants_param_grad);
        assert!(plan.task(g.by_name("Tensor A").unwrap().id).unwrap().wants_param_grad);
        // Placeholders don't.
        assert!(plan.task(g.by_name("Input").unwrap().id).is_none());
        assert!(plan.task(g.by_name("Label").unwrap().id).is_none());
    }

    #[test]
    fn normalization_would_fold_the_identity_pool() {
        // Figure 3's Pool is deliberately a 1×1/stride-1 identity so the
        // example matches the paper's tables. The standard pass pipeline
        // folds it away — which is why fig3 consumers (Table 2/3 benches,
        // the paper_partition) must use the graph as built, never a
        // PassManager::standard()-normalized copy.
        let mut g = build();
        let report = crate::dag::PassManager::standard().run(&mut g).unwrap();
        assert!(report.changed());
        assert!(g.by_name("Pool").is_none(), "identity pool should fold");
        assert_eq!(g.len(), 9);
        // The partition helper still covers the *original* graph exactly.
        let orig = build();
        assert_eq!(paper_partition(&orig).len(), orig.len());
    }
}
